"""Dynamic graph updates (paper Section 6.2): static CSR vs dynamic
array-of-linked-lists built on PIM-malloc.

Methodology follows the paper: a loc-gowalla-scale graph is partitioned
across PIM cores (node hashing); edges are randomly sampled 1:2 into
(new : pre-existing). The pre-existing part builds the initial structure;
the new edges stream in as per-round batches (one edge per hardware
thread). We simulate ONE core's partition functionally (the others are
identical by symmetry / vmap) and cost it with the DPU model:

  static CSR    : each insert shifts the EdgeIdx suffix and rewrites
                  NodePtr — DMA traffic ~ half the partition per insert
                  (the paper's Fig 3(c) size-dependence).
  dynamic       : pimMalloc(16 B) node {dst, next}, two WRAM/MRAM writes,
                  head-pointer update — O(1) regardless of graph size.

The dynamic structure is *functionally real*: node cells live in a heap
array addressed by allocator pointers, and tests traverse the linked lists
to verify the adjacency exactly matches a Python reference.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import api, cost_model, heap as heap_api, system as sysm

NODE_BYTES = 16  # one edge cell: dst (4B) + next (4B) + padding to size class


@dataclasses.dataclass(frozen=True)
class GraphConfig:
    n_nodes: int = 384          # per-core partition (loc-gowalla/512 cores)
    n_edges_pre: int = 4000     # ~1.9M directed edges / 512 cores
    n_edges_new: int = 2000     # 1:2 new:existing (paper methodology)
    num_threads: int = 16
    heap_bytes: int = 1 << 21
    seed: int = 0


def synth_edges(cfg: GraphConfig):
    """Power-law-ish synthetic partition (loc-gowalla-like degree skew)."""
    rng = np.random.default_rng(cfg.seed)
    n = cfg.n_nodes
    # Zipf-weighted endpoints
    w = 1.0 / np.arange(1, n + 1) ** 0.8
    p = w / w.sum()
    total = cfg.n_edges_pre + cfg.n_edges_new
    src = rng.choice(n, size=total, p=p)
    dst = rng.choice(n, size=total, p=p)
    return (src[:cfg.n_edges_pre], dst[:cfg.n_edges_pre],
            src[cfg.n_edges_pre:], dst[cfg.n_edges_pre:])


# --------------------------------------------------------------- static CSR
def static_update_cost_us(cfg: GraphConfig, dpu: cost_model.DPUCost = None):
    """Per-round latency series for batched CSR rebuild (no allocator).

    A round applies up to T inserts by rewriting the partition's EdgeIdx and
    NodePtr arrays once (sorted merge) — the *best-case* static strategy,
    still O(partition size) per round (Fig 3(c) size dependence).
    Returns (per_round_us array, us_per_edge).
    """
    dpu = dpu or cost_model.DPUCost()
    m = cfg.n_edges_pre
    T = cfg.num_threads
    lat = []
    total = cfg.n_edges_new
    done = 0
    while done < total:
        k = min(T, total - done)
        edge_bytes = (m + done) * 4
        nodeptr_bytes = cfg.n_nodes * 4
        moved = 2 * (edge_bytes + nodeptr_bytes)   # read + write both arrays
        cyc = float(cost_model.mram_access_cyc(dpu, moved))
        cyc += 120.0 * k                            # per-edge locate/merge
        lat.append(cyc / dpu.freq_hz * 1e6)
        done += k
    lat = np.asarray(lat)
    return lat, float(lat.sum() / total)


# ------------------------------------------------- dynamic (PIM-malloc heap)
class DynamicGraph:
    """Array-of-linked-lists adjacency on a PIM-malloc heap (one core).

    Every allocation round goes through one `repro.core.api.HeapClient`
    (the unified heap protocol), so the whole workload — insertion AND
    deletion — is recordable as an `AllocRequest` tape: pass a
    `repro.workloads.trace.RecordingAllocator` as ``client`` to capture it.
    """

    def __init__(self, cfg: GraphConfig, kind: str = "sw", client=None,
                 alloc=None):
        """``alloc`` is the deprecated pre-PR-8 injection hook (bare
        Allocator-style handles); it warns once per call and is adapted
        via `HeapClient.wrap`. Pass ``client=`` instead."""
        self.cfg = cfg
        if alloc is not None:
            import warnings
            warnings.warn(
                "DynamicGraph(alloc=...) is deprecated: pass client="
                "HeapClient (or any HeapClient subclass); bare handles are "
                "adapted via HeapClient.wrap for now",
                DeprecationWarning, stacklevel=2)
            if client is not None:
                raise TypeError("pass either client= or (deprecated) alloc=")
            client = api.HeapClient.wrap(alloc)
        if client is None:
            client = api.Allocator(
                heap_bytes=cfg.heap_bytes, num_threads=cfg.num_threads,
                kind=kind)
        self.client = client
        # back-compat alias: pre-PR-9 callers read `g.alloc.last_info`
        self.alloc = client
        self.sys_cfg = self.alloc.cfg
        self.head = jnp.full((cfg.n_nodes,), -1, jnp.int32)
        self.heap = jnp.zeros((cfg.heap_bytes // 4,), jnp.int32)
        self._insert = jax.jit(self._insert_impl)

    @property
    def state(self):
        return self.alloc.state

    @staticmethod
    def _insert_impl(heap, head, ptrs, srcs, dsts):
        """Serialized pointer splice for one round (order = thread order)."""

        def one(carry, x):
            heap, head = carry
            ptr, u, v = x
            ok = ptr >= 0
            w = jnp.maximum(ptr // 4, 0)
            old = head[u]
            heap = heap.at[w].set(jnp.where(ok, v, heap[w]))           # dst
            heap = heap.at[w + 1].set(jnp.where(ok, old, heap[w + 1]))  # next
            head = head.at[u].set(jnp.where(ok, ptr, head[u]))
            return (heap, head), None

        (heap, head), _ = lax.scan(one, (heap, head), (ptrs, srcs, dsts))
        return heap, head

    def insert_round(self, srcs, dsts):
        """One batched round: up to T edges. Returns the AllocResponse."""
        T = self.cfg.num_threads
        n = len(srcs)
        sizes = jnp.where(jnp.arange(T) < n, NODE_BYTES, 0).astype(jnp.int32)
        info = self.alloc.request(heap_api.malloc_request(sizes))
        srcs = jnp.asarray(np.pad(srcs, (0, T - n)), jnp.int32)
        dsts = jnp.asarray(np.pad(dsts, (0, T - n)), jnp.int32)
        self.heap, self.head = self._insert(self.heap, self.head, info.ptr,
                                            srcs, dsts)
        return info

    def delete_round(self, srcs, dsts):
        """Remove up to T edges (u, v): unlink the first matching cell from
        u's list and pimFree its node cell. Returns the AllocResponse (a
        miss — edge not present — frees nothing on that thread slot).
        """
        T = self.cfg.num_threads
        assert len(srcs) <= T
        heap_np = np.asarray(self.heap).copy()
        head_np = np.asarray(self.head).copy()
        free_ptrs = np.full((T,), -1, np.int32)
        for t, (u, v) in enumerate(zip(srcs, dsts)):
            u, v = int(u), int(v)
            prev = -1
            ptr = int(head_np[u])
            while ptr >= 0:
                w = ptr // 4
                if int(heap_np[w]) == v:          # unlink this cell
                    nxt = int(heap_np[w + 1])
                    if prev < 0:
                        head_np[u] = nxt
                    else:
                        heap_np[prev // 4 + 1] = nxt
                    free_ptrs[t] = ptr
                    break
                prev, ptr = ptr, int(heap_np[w + 1])
        self.heap = jnp.asarray(heap_np)
        self.head = jnp.asarray(head_np)
        return self.alloc.request(heap_api.free_request(
            jnp.asarray(free_ptrs)))

    def neighbors(self, u: int):
        """Traverse u's linked list (host-side; test/verification)."""
        out = []
        ptr = int(self.head[u])
        heap = np.asarray(self.heap)
        while ptr >= 0 and len(out) <= self.cfg.heap_bytes:
            w = ptr // 4
            out.append(int(heap[w]))
            ptr = int(heap[w + 1])
        return out


def run_dynamic(cfg: GraphConfig, kind: str):
    """Build the pre-update graph (untimed), then stream + time the new
    edges. Returns (graph, per-round RoundInfo list, per_round_us, us/edge).

    Round latency = max over active threads (threads run concurrently; the
    mutex queue is inside the cost model) + the serialized splice cost.
    """
    g = DynamicGraph(cfg, kind=kind)
    pre_src, pre_dst, new_src, new_dst = synth_edges(cfg)
    T = cfg.num_threads
    dpu = g.sys_cfg.dpu
    for i in range(0, len(pre_src), T):            # untimed pre-build
        g.insert_round(pre_src[i:i + T], pre_dst[i:i + T])
    lat_rounds = []
    infos = []
    for i in range(0, len(new_src), T):
        info = g.insert_round(new_src[i:i + T], new_dst[i:i + T])
        # 'Run' phase per edge: node-cell MRAM write (DMA) + WRAM head update
        splice_cyc = 140.0
        active = np.asarray(info.path) >= 0
        lat = np.asarray(info.latency_cyc) + splice_cyc
        lat_rounds.append(float(lat[active].max()) if active.any() else 0.0)
        infos.append(info)
    per_round_us = np.asarray(lat_rounds) / dpu.freq_hz * 1e6
    per_edge_us = float(np.sum(lat_rounds) / max(len(new_src), 1)
                        / dpu.freq_hz * 1e6)
    return g, infos, per_round_us, per_edge_us


def compare_all(cfg: GraphConfig = GraphConfig()):
    """Fig 16(a)-style comparison. Returns dict of per-edge us + throughput."""
    out = {}
    _, us_static = static_update_cost_us(cfg)
    out["static_csr"] = {
        "us_per_edge": us_static,
        "edges_per_s": 1e6 / us_static,
    }
    for kind in sysm.KINDS:
        g, infos, per_round, us = run_dynamic(cfg, kind)
        dram = int(np.sum([np.asarray(i.dram_bytes).sum() for i in infos]))
        alloc_us = float(np.mean([np.asarray(i.latency_cyc)[
            np.asarray(i.path) >= 0].mean() for i in infos])) / 350e6 * 1e6
        frontend = int(np.sum([np.sum(np.asarray(i.path) == 0) for i in infos]))
        backend = int(np.sum([np.isin(np.asarray(i.path), (1, 2)).sum()
                              for i in infos]))
        out[kind] = {
            "us_per_edge": us,
            "edges_per_s": 1e6 / us if us > 0 else float("inf"),
            "alloc_us_mean": alloc_us,
            "dram_bytes": dram,
            "frontend_ops": frontend,
            "backend_ops": backend,
        }
    return out
