"""Pallas TPU kernel: thread-cache freelist pop/push (PIM-malloc frontend).

One grid step = one thread cache (grid = T threads x C cores flattened by the
wrapper). Each thread's NC size-class LIFO stacks live in a VMEM block; a pop
or push is O(1) — the paper's lock-free frontend. Batched across threads this
is the vectorized analogue of 24 tasklets independently hitting their caches.

Ops (per thread): op = 0 pop(class), 1 push(class, ptr), -1 idle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _kernel(op_ref, cls_ref, ptr_in_ref, stacks_ref, counts_ref,
            ptr_out_ref, counts_out_ref, stacks_out_ref, *, cap: int):
    op = op_ref[0]
    c = jnp.maximum(cls_ref[0], 0)
    cnt = counts_ref[0, c]

    is_pop = (op == 0) & (cnt > 0)
    is_push = (op == 1) & (cnt < cap)

    pos_pop = jnp.maximum(cnt - 1, 0)
    popped = stacks_ref[0, c, pos_pop]
    ptr_out_ref[0] = jnp.where(is_pop, popped, jnp.int32(-1))

    pos_push = jnp.minimum(cnt, cap - 1)
    old = stacks_ref[0, c, pos_push]
    stacks_out_ref[0, :, :] = stacks_ref[0, :, :]
    stacks_out_ref[0, c, pos_push] = jnp.where(is_push, ptr_in_ref[0], old)

    delta = jnp.where(is_pop, -1, jnp.where(is_push, 1, 0))
    counts_out_ref[0, :] = counts_ref[0, :]
    counts_out_ref[0, c] = cnt + delta


def bulk_refill(stacks, counts, sel, cls, rows, new_counts):
    """Vectorized same-round freelist refill (batched backend fast path).

    For every thread ``t`` with ``sel[t]``: replace
    ``stacks[t, cls[t], :rows.shape[1]]`` with ``rows[t]`` and set
    ``counts[t, cls[t]] = new_counts[t]``; other threads, classes and stack
    slots beyond the refill width are untouched. Pure jnp (traces inside
    the fused Pallas body); bitwise-equal to the serial per-thread refill
    in `heap_step.protocol_round`'s backend loop.
    """
    T, NC, CAP = stacks.shape
    width = rows.shape[1]
    pick_cls = sel[:, None] & (
        jnp.arange(NC, dtype=jnp.int32)[None, :] == cls[:, None])
    lane = jnp.arange(CAP, dtype=jnp.int32)[None, None, :] < width
    rows_cap = jnp.pad(rows, ((0, 0), (0, CAP - width)))
    stacks = jnp.where(pick_cls[:, :, None] & lane, rows_cap[:, None, :],
                       stacks)
    counts = jnp.where(pick_cls, new_counts[:, None], counts)
    return stacks, counts


# ---------------------------------------------------------------------------
# arena frontend primitives (the bump-pointer fast path fused ahead of the
# buddy mutex phase — see repro.core.arena). Pure jnp so they trace inside
# jitted/fused step bodies and stay visible to the pimcheck verifier passes.
# ---------------------------------------------------------------------------
def arena_bump_shared(bump, cand, gneed, limit: int):
    """Shared-arena bump allocation: contenders serialize in thread order.

    bump: int32[] granules consumed; cand: bool[T] attempts this round;
    gneed: int32[T] granules wanted. A failed fit does NOT consume space —
    a later, smaller request can still be served (hence the scan, which is
    also the modeled serialization point of the shared atomic add).
    Returns (new_bump, start_granule int32[T] (-1 on fail), served bool[T]).
    """

    def body(b, x):
        want, need = x
        fits = want & (b + need <= limit)
        g0 = jnp.where(fits, b, jnp.int32(-1))
        return b + jnp.where(fits, need, 0), (g0, fits)

    bump, (g0, served) = lax.scan(body, bump, (cand, gneed))
    return bump, g0, served


def arena_bump_tl(bump, cand, gneed, region_gran: int):
    """Per-thread-region bump allocation: fully vectorized, no cross-thread
    serialization (the tlregion fast path). ``bump`` is int32[T], each entry
    an offset inside thread t's private region of ``region_gran`` granules.
    Returns (new_bump, absolute start granule int32[T] (-1 on fail), served).
    """
    T = bump.shape[0]
    fits = cand & (bump + gneed <= region_gran)
    base = jnp.arange(T, dtype=jnp.int32) * region_gran
    g0 = jnp.where(fits, base + bump, jnp.int32(-1))
    return bump + jnp.where(fits, gneed, 0), g0, fits


def arena_mark(cls_map, g, cls, on):
    """Record an arena placement: cls_map[g] = cls where ``on`` (scatter with
    an out-of-bounds park slot for masked threads, drop-guarded)."""
    n = cls_map.shape[0]
    idx = jnp.where(on, jnp.clip(g, 0, n - 1), jnp.int32(n))
    return cls_map.at[idx].set(jnp.where(on, cls, jnp.int32(-1)), mode="drop")


def arena_hole(cls_map, g, on):
    """Retire an arena block: cls_map[g] = -1 where ``on`` (bump space is
    not reclaimed until the next epoch reset — holes stay holes)."""
    n = cls_map.shape[0]
    idx = jnp.where(on, jnp.clip(g, 0, n - 1), jnp.int32(n))
    return cls_map.at[idx].set(jnp.int32(-1), mode="drop")


def arena_region_reset(cls_map, class_sizes, region_mask):
    """Bulk epoch reset over ``region_mask`` granules: clears every placement
    in the region and returns (new_cls_map, freed_bytes) where freed_bytes
    is the rounded occupancy being retired (the telemetry delta)."""
    nc = class_sizes.shape[0]
    live = region_mask & (cls_map >= 0)
    freed = jnp.sum(jnp.where(
        live, class_sizes[jnp.clip(cls_map, 0, nc - 1)], 0))
    return jnp.where(region_mask, jnp.int32(-1), cls_map), freed


def freelist_op_kernel(stacks, counts, op, cls, ptr_in, *, interpret: bool = False):
    """Apply one freelist op per thread.

    stacks: int32[T, NC, CAP]; counts: int32[T, NC]
    op/cls/ptr_in: int32[T]
    Returns (ptr_out [T], new_counts, new_stacks).
    """
    T, NC, CAP = stacks.shape
    kern = functools.partial(_kernel, cap=CAP)
    return pl.pallas_call(
        kern,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),            # op
            pl.BlockSpec((1,), lambda i: (i,)),            # cls
            pl.BlockSpec((1,), lambda i: (i,)),            # ptr_in
            pl.BlockSpec((1, NC, CAP), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, NC), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, NC), lambda i: (i, 0)),
            pl.BlockSpec((1, NC, CAP), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T,), jnp.int32),
            jax.ShapeDtypeStruct((T, NC), jnp.int32),
            jax.ShapeDtypeStruct((T, NC, CAP), jnp.int32),
        ],
        interpret=interpret,
    )(op, cls, ptr_in, stacks, counts)
