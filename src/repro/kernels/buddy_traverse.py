"""Pallas TPU kernel: batched buddy-tree allocation with VMEM-resident metadata.

This is the TPU adaptation of the paper's *buddy cache* (Section 4.2). On
UPMEM, buddy metadata lives in MRAM (DRAM bank) and the HW buddy cache pins
the hot 64 B in a 1-cycle CAM. On TPU the analogous hierarchy is
HBM -> VMEM -> VREG: the kernel pins the **entire per-core ``longest[]``
tree in VMEM** for the duration of a request batch via an explicit
`BlockSpec`, so every one of the `O(B * depth)` metadata touches is a VMEM
access instead of an HBM round-trip. One grid step = one PIM-core heap
(grid = number of cores), which is exactly the paper's
PIM-Metadata/PIM-Executed placement: no cross-core metadata, embarrassing
parallelism across the grid.

VMEM budget: a 32 MB heap at 4 KB grain -> 16 K nodes * 4 B = 64 KB tree —
comfortably inside the ~16 MB/core VMEM, and the batch dimension B is padded
to a multiple of 128 lanes by the ops.py wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _next_pow2(x):
    x = jnp.maximum(x, 1).astype(jnp.int32) - 1
    x = x | (x >> 1)
    x = x | (x >> 2)
    x = x | (x >> 4)
    x = x | (x >> 8)
    x = x | (x >> 16)
    return x + 1


def _alloc_one(tree, size, *, heap_bytes: int, min_block: int, depth: int):
    """One buddy allocation against a VMEM-resident `tree` vector."""
    req = size
    size = jnp.maximum(_next_pow2(size), min_block)
    ok = (req > 0) & (size <= heap_bytes) & (tree[1] >= size)

    def down(_, carry):
        node, node_size = carry
        descend = node_size > size
        left = 2 * node
        go_left = tree[left] >= size
        nxt = jnp.where(go_left, left, left + 1)
        node = jnp.where(descend, nxt, node)
        node_size = jnp.where(descend, node_size >> 1, node_size)
        return node, node_size

    node, node_size = lax.fori_loop(
        0, depth, down, (jnp.int32(1), jnp.int32(heap_bytes))
    )
    offset = node * node_size - heap_bytes
    tree = tree.at[node].set(jnp.where(ok, 0, tree[node]))

    def up(_, carry):
        tree, n = carry
        parent = n >> 1
        active = ok & (parent >= 1)
        p = jnp.maximum(parent, 1)
        newval = jnp.maximum(tree[2 * p], tree[2 * p + 1])
        tree = tree.at[p].set(jnp.where(active, newval, tree[p]))
        return tree, jnp.where(active, p, jnp.int32(0))

    tree, _ = lax.fori_loop(0, depth, up, (tree, node))
    return tree, jnp.where(ok, offset, jnp.int32(-1))


# ---------------------------------------------------------------------------
# Pure-jnp run-carve helpers for the fused kernel's batched refill fast path
# (`heap_step.protocol_round`). All shapes are static, so they trace inside a
# Pallas body; every gather/scatter is clipped or drop-mode so the helpers
# stay safe when evaluated on ineligible data (e.g. under vmap-of-select).
# ---------------------------------------------------------------------------


def leftmost_block(tree, *, heap_bytes: int, block_bytes: int, depth: int):
    """Block index the serial leftmost-fit descent would carve next.

    Replicates `_alloc_one`'s descent at block granularity exactly (same
    ``tree[left] >= size`` rule), so a batched run-carve starting here lands
    on the same leaves the serial walks would. Garbage when the tree has no
    free block — callers gate on ``tree[1] >= block_bytes``.
    """
    nb = heap_bytes // block_bytes

    def down(_, node):
        left = 2 * node
        go_left = tree[left] >= block_bytes
        return jnp.where(go_left, left, left + 1)

    node = lax.fori_loop(0, depth, down, jnp.int32(1))
    return node - nb


def run_blocks_free(tree, b0, n, *, window: int, heap_bytes: int,
                    block_bytes: int):
    """True iff blocks ``b0 .. b0+n-1`` are all free (``n <= window``).

    A leaf may carry a stale ``longest`` after an ancestor was carved as a
    bigger chunk, so freeness is the min over the leaf's whole root path
    staying >= ``block_bytes``.
    """
    nb = heap_bytes // block_bytes
    depth = nb.bit_length() - 1
    leaves = nb + b0 + jnp.arange(window, dtype=jnp.int32)
    shifts = jnp.arange(depth + 1, dtype=jnp.int32)
    anc = jnp.minimum(leaves[:, None] >> shifts[None, :], 2 * nb - 1)
    free = jnp.min(tree[anc], axis=1) >= block_bytes
    return jnp.all(jnp.where(jnp.arange(window) < n, free, True))


def carve_run(tree, b0, n, *, window: int, heap_bytes: int, block_bytes: int):
    """Carve blocks ``b0 .. b0+n-1`` (all known-free) in one vectorized pass.

    Bitwise-equal to ``n`` serial leftmost `_alloc_one` walks at block
    granularity: leaves zero left-to-right and every affected ancestor ends
    at max(children) — the value the last serial up-walk through it writes,
    since the run's threads drain left subtree before right at every node.
    """
    nb = heap_bytes // block_bytes
    depth = nb.bit_length() - 1
    n_nodes = 2 * nb
    k = jnp.arange(window, dtype=jnp.int32)
    leaf_idx = jnp.where(k < n, nb + b0 + k, n_nodes)
    tree = tree.at[leaf_idx].set(0, mode="drop")
    for d in range(1, depth + 1):
        p_lo = (nb + b0) >> d
        p_hi = (nb + b0 + n - 1) >> d
        win = p_lo + jnp.arange(window + 1, dtype=jnp.int32)
        child = jnp.minimum(2 * win, n_nodes - 2)
        newval = jnp.maximum(tree[child], tree[child + 1])
        idx = jnp.where(win <= p_hi, win, n_nodes)
        tree = tree.at[idx].set(newval, mode="drop")
    return tree


def _kernel(sizes_ref, tree_ref, offs_ref, tree_out_ref, *, heap_bytes: int,
            min_block: int, depth: int):
    tree = tree_ref[0, :]
    B = sizes_ref.shape[1]

    def body(i, carry):
        tree, offs = carry
        tree, off = _alloc_one(tree, sizes_ref[0, i], heap_bytes=heap_bytes,
                               min_block=min_block, depth=depth)
        offs = offs.at[i].set(off)
        return tree, offs

    tree, offs = lax.fori_loop(
        0, B, body, (tree, jnp.full((B,), -1, jnp.int32))
    )
    offs_ref[0, :] = offs
    tree_out_ref[0, :] = tree


def buddy_alloc_batch_kernel(tree, sizes, *, heap_bytes: int, min_block: int,
                             interpret: bool = False):
    """Allocate a [C, B] batch of requests against [C, n_nodes] buddy trees.

    C cores proceed in parallel (grid); within a core requests are serviced
    in order (the shared-mutex semantics of the paper's backend).
    Returns (offsets [C, B], new_tree [C, n_nodes]).
    """
    C, n_nodes = tree.shape
    _, B = sizes.shape
    depth = (heap_bytes // min_block).bit_length() - 1
    kern = functools.partial(_kernel, heap_bytes=heap_bytes,
                             min_block=min_block, depth=depth)
    return pl.pallas_call(
        kern,
        grid=(C,),
        in_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),        # request batch
            pl.BlockSpec((1, n_nodes), lambda i: (i, 0)),  # whole tree in VMEM
        ],
        out_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, n_nodes), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C, B), jnp.int32),
            jax.ShapeDtypeStruct((C, n_nodes), jnp.int32),
        ],
        interpret=interpret,
    )(sizes, tree)
