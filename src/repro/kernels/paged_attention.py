"""Pallas TPU kernel: single-token decode attention over a *paged* KV cache.

This is where PIM-malloc becomes a first-class serving feature: the KV cache
is a per-device page pool managed by `repro.core.pim_malloc` (thread-cache
frontend = per-sequence freelists, buddy backend = contiguous extents), and
attention consumes the resulting page tables directly.

TPU-native structure (mirrors jax's official TPU paged-attention design):
  * grid = (batch, kv_head, pages_per_seq); the page axis is the innermost,
    sequentially-iterated grid dim.
  * the page table is a **scalar-prefetch** operand: the KV BlockSpec's
    index_map reads `page_table[b, j]` to choose which physical page the
    pipeline DMAs HBM->VMEM next — dynamic gather expressed as block
    indexing, so the MXU never stalls on it.
  * online softmax (m, l, acc) in VMEM scratch across page steps.

Validated in interpret mode against `ref.paged_attention_ref` (pure jnp).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pt_ref, sl_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, page_size: int, scale: float, pages_per_seq: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)       # [G, D] query heads of this kv head
    k = k_ref[0, :, 0].astype(jnp.float32)    # [page_size, D]
    v = v_ref[0, :, 0].astype(jnp.float32)    # [page_size, D]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # [G, P]
    pos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
    valid = pos < sl_ref[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)                  # [G, 1]
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                     # [G, P]
    p = jnp.where(valid, p, 0.0)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_new = acc_prev * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(j == pages_per_seq - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_attention_kernel(q, k_pages, v_pages, page_table, seq_lens, *,
                           interpret: bool = False):
    """Decode attention: one new token per sequence against paged KV.

    q:          [B, H, D] current-step queries (H = KVH * G)
    k_pages:    [N_pages, page_size, KVH, D] physical page pool
    v_pages:    [N_pages, page_size, KVH, D]
    page_table: int32[B, P] physical page ids per sequence (-1 = unmapped)
    seq_lens:   int32[B] valid tokens per sequence
    Returns [B, H, D].
    """
    B, H, D = q.shape
    N, page_size, KVH, Dk = k_pages.shape
    assert Dk == D and H % KVH == 0
    G = H // KVH
    P = page_table.shape[1]
    scale = 1.0 / (D ** 0.5)

    q4 = q.reshape(B, KVH, G, D)
    pt = jnp.maximum(page_table, 0).astype(jnp.int32)

    grid = (B, KVH, P)
    kern = functools.partial(_kernel, page_size=page_size, scale=scale,
                             pages_per_seq=P)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # page_table, seq_lens
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D), lambda b, h, j, pt, sl: (b, h, 0, 0)),
                pl.BlockSpec((1, page_size, 1, D),
                             lambda b, h, j, pt, sl: (pt[b, j], 0, h, 0)),
                pl.BlockSpec((1, page_size, 1, D),
                             lambda b, h, j, pt, sl: (pt[b, j], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D),
                                   lambda b, h, j, pt, sl: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),   # m
                pltpu.VMEM((G, 1), jnp.float32),   # l
                pltpu.VMEM((G, D), jnp.float32),   # acc
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, D), q.dtype),
        interpret=interpret,
    )(pt, seq_lens, q4, k_pages, v_pages)
    return out.reshape(B, H, D)
