"""Jitted public wrappers for the Pallas kernels.

On this CPU-only container kernels run in interpret mode (the kernel body is
executed with JAX ops — bit-exact semantics, no TPU). On a TPU runtime set
``interpret=False`` (the default flips automatically via `on_tpu()`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import buddy_traverse, flash_attention, freelist, paged_attention, ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interp(interpret):
    return (not on_tpu()) if interpret is None else interpret


@functools.partial(jax.jit, static_argnames=("heap_bytes", "min_block", "interpret"))
def buddy_alloc_batch(tree, sizes, *, heap_bytes: int, min_block: int,
                      interpret: bool | None = None):
    """[C, B] buddy allocations over [C, n_nodes] trees (VMEM-resident)."""
    B = sizes.shape[1]
    pad = (-B) % 128  # lane-align the request batch for TPU
    if pad:
        sizes = jnp.pad(sizes, ((0, 0), (0, pad)))  # size 0 -> rounded to min,
        # but guarded: 0-size requests still allocate min_block; mask instead:
        sizes = sizes.at[:, B:].set(0)
    offs, new_tree = buddy_traverse.buddy_alloc_batch_kernel(
        tree, jnp.where(sizes > 0, sizes, 0),
        heap_bytes=heap_bytes, min_block=min_block, interpret=_interp(interpret),
    )
    return offs[:, :B], new_tree


@functools.partial(jax.jit, static_argnames=("interpret",))
def freelist_op(stacks, counts, op, cls, ptr_in, *, interpret: bool | None = None):
    return freelist.freelist_op_kernel(
        stacks, counts, op, cls, ptr_in, interpret=_interp(interpret)
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_op(q, k_pages, v_pages, page_table, seq_lens, *,
                       interpret: bool | None = None):
    return paged_attention.paged_attention_kernel(
        q, k_pages, v_pages, page_table, seq_lens, interpret=_interp(interpret)
    )


# re-exported oracles for tests/benchmarks
buddy_alloc_batch_ref = ref.buddy_alloc_batch_ref
freelist_op_ref = ref.freelist_op_ref
paged_attention_ref = ref.paged_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                              "block_kv", "interpret"))
def flash_attention_op(q, k, v, *, causal: bool = True, window: int = 0,
                       block_q: int = 512, block_kv: int = 512,
                       interpret: bool | None = None):
    """Pallas flash attention (fwd). Ref oracle: layers.attention."""
    return flash_attention.flash_attention_kernel(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_kv=block_kv, interpret=_interp(interpret))
