"""Pallas TPU kernel: flash attention (forward), causal/sliding-window GQA.

This is the kernel-level fix identified by EXPERIMENTS.md SSPerf IT-A4: the
pure-JAX chunked flash (layers.flash_attention) keeps its online-softmax
accumulators as scan carries, which round-trip HBM every block; here they
live in VMEM scratch across the sequentially-iterated KV-block grid dim, so
the only HBM traffic is the q/k/v tiles themselves — the S^2 score matrix
never exists anywhere.

Grid: (batch, kv_head, q_blocks, kv_blocks) with the KV-block axis
innermost (sequential on TPU). Blocks:
    q   [1, 1, G, bq, hd]   (GQA group of the kv head)
    k/v [1, 1, bkv, hd]
    out [1, 1, G, bq, hd]
Scratch: m/l [G, bq, 1] and acc [G, bq, hd] fp32 in VMEM.

Validated in interpret mode against layers.attention (tests/test_kernels.py
sweep: causal x window x dtypes x GQA/MQA/MHA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bkv: int, nk: int, scale: float, causal: bool,
            window: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)      # [G, bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)      # [bkv, hd]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # s: [G, bq, bkv]
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (1, bq, 1), 1)
    kpos = ik * bkv + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bkv), 2)
    mask = jnp.ones(s.shape, bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)          # [G, bq, 1]
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc_prev * alpha + jax.lax.dot_general(
        p, v, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           block_q: int = 512, block_kv: int = 512,
                           interpret: bool = False):
    """q [B,S,H,hd]; k, v [B,T,KVH,hd] -> [B,S,H,hd]."""
    B, S, H, hd = q.shape
    T, KVH = k.shape[1], k.shape[2]
    assert H % KVH == 0
    G = H // KVH

    def _fit(n, b):
        b = min(n, b)
        while n % b:
            b -= 1
        return b

    bq, bkv = _fit(S, block_q), _fit(T, block_kv)
    nq, nk = S // bq, T // bkv
    scale = 1.0 / (hd ** 0.5)

    q5 = jnp.moveaxis(q.reshape(B, S, KVH, G, hd), 1, 3)   # [B,KVH,G,S,hd]
    k4 = jnp.moveaxis(k, 1, 2)                             # [B,KVH,T,hd]
    v4 = jnp.moveaxis(v, 1, 2)

    kern = functools.partial(_kernel, bq=bq, bkv=bkv, nk=nk, scale=scale,
                             causal=causal, window=window)
    out = pl.pallas_call(
        kern,
        grid=(B, KVH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, bq, hd), lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, bq, hd),
                               lambda b, h, i, j: (b, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, bq, 1), jnp.float32),    # m
            pltpu.VMEM((G, bq, 1), jnp.float32),    # l
            pltpu.VMEM((G, bq, hd), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q5, k4, v4)
    return jnp.moveaxis(out, 3, 1).reshape(B, S, H, hd)
