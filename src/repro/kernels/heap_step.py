"""Fused Pallas kernel: one full heap-protocol round per PIM core.

This is the ``pallas`` design point of `repro.core.system`: the entire
`AllocRequest -> AllocResponse` round — per-thread op dispatch (MALLOC /
FREE / REALLOC / CALLOC / NOOP), the per-thread freelist frontend, the
shared buddy backend, and the 16-entry LRU *buddy cache* of metadata words
— executes as ONE `pl.pallas_call` per core instead of a chain of
`lax.scan`s stitched together at the JAX level.

Layout (one kernel invocation = one PIM core, batched across cores by
`vmap` — Pallas turns the batch into a grid dimension on TPU):

  * the whole per-core state pytree (buddy ``longest[]`` tree, freelist
    ``stacks``/``counts``, block metadata, LRU cache tags) is VMEM-resident
    for the duration of the round, generalizing `freelist.py` (LIFO stacks)
    and `buddy_traverse.py` (down/up tree walk) into one fused body;
  * frontend pops/pushes are vectorized across threads (the paper's
    lock-free thread caches);
  * cache misses fall back to the in-kernel buddy traversal, serialized in
    thread order (the paper's backend mutex), carving refilled blocks back
    into the thread cache (refill) and spilling bypass blocks;
  * every buddy-tree node touched passes through an in-kernel LRU word
    cache with hit/miss counters — the paper's HW buddy cache (Section
    4.2), fused with the access path rather than simulated afterwards.

Semantics are bit-identical to the ``hwsw`` reference round in
`repro.core.system._protocol_round` (pinned by tests/test_pallas_heap.py):
pointer sequences, full metadata state, and cache hit/miss counters all
match, so the cost model prices both paths identically and
fig15-style cache sweeps work unchanged on the kernel path.

`protocol_round` is the pure-jnp round body; the kernel loads refs, runs
it, and stores the results, so interpret mode (CPU CI) and the compiled
TPU path share one implementation.

**Batched same-class refill (``batch_refill``, default on).** When every
backend op of a round allocates at block granularity — refills always do,
and bypasses do whenever ``next_pow2(size) == block_bytes`` — leftmost-fit
guarantees the k-th needy thread (in mutex order) carves leaf ``b0 + k``,
where ``b0`` is the leftmost free block. The kernel then serves the whole
round with ONE vectorized run-carve (`buddy_traverse.carve_run`) plus a
bulk freelist refill (`freelist.bulk_refill`) and an exact replay of the
serial threads' LRU-cache access sequence, instead of T serial buddy
walks; rounds with no backend op skip the walk entirely, and rounds with
odd (> block) bypass classes fall back to the serial loop. All three
paths are bitwise-equal — responses, state, cache counters — so the hwsw
contract above is unchanged (pinned by tests/test_pallas_heap.py's
batch-vs-serial parametrization). Disable via
``PIM_MALLOC_BATCH_REFILL=0`` or ``SystemConfig(kernel_batch_refill=
False)`` (the wall-clock bench lane measures both).
"""
from __future__ import annotations

import functools
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.buddy import ilog2 as _ilog2
from repro.core.buddy import next_pow2 as _next_pow2
from repro.core.buddy_cache import NODES_PER_WORD
from repro.kernels.buddy_traverse import carve_run, leftmost_block, \
    run_blocks_free
from repro.kernels.freelist import bulk_refill

INVALID = -1  # plain int: Pallas kernels cannot close over array constants


def _access(cache, node):
    """One LRU buddy-cache access (node < 0 = inactive). Mirrors
    `buddy_cache.buddy_cache_access` exactly; returns (cache, hit, miss)."""
    tags, lu, clock = cache
    valid = node >= 0
    word = jnp.maximum(node, 0) // NODES_PER_WORD
    match = tags == word
    hit = valid & jnp.any(match)
    idx = jnp.where(hit, jnp.argmax(match), jnp.argmin(lu))
    tags = tags.at[idx].set(jnp.where(valid, word, tags[idx]))
    lu = lu.at[idx].set(jnp.where(valid, clock, lu[idx]))
    clock = clock + valid.astype(jnp.int32)
    return ((tags, lu, clock), (valid & hit).astype(jnp.int32),
            (valid & ~hit).astype(jnp.int32))


def _buddy_alloc(longest, cache, size, need, *, heap_bytes, block_bytes,
                 depth):
    """Buddy descent/up-walk fused with the LRU metadata cache.

    Equivalent to `buddy.alloc` + trace replay through the cache, with
    state committed only where `need`. Returns
    (longest, cache, off, lvd, lvu, hits, misses); lvd/lvu are unmasked
    (caller zeroes them where ~need, as the event path does).
    """
    size_r = jnp.maximum(_next_pow2(size), block_bytes)
    ok = (size_r <= heap_bytes) & (longest[1] >= size_r)
    cache, hh, mm = _access(cache, jnp.where(need, 1, INVALID))  # root visit

    def down(i, carry):
        node, node_size, lvd, cache, hh, mm = carry
        descend = node_size > size_r
        left = 2 * node
        go_left = longest[left] >= size_r
        node = jnp.where(descend, jnp.where(go_left, left, left + 1), node)
        node_size = jnp.where(descend, node_size >> 1, node_size)
        lvd = lvd + descend.astype(jnp.int32)
        cache, h, m = _access(cache, jnp.where(need & descend, node, INVALID))
        return node, node_size, lvd, cache, hh + h, mm + m

    node, node_size, lvd, cache, hh, mm = lax.fori_loop(
        0, depth, down,
        (jnp.int32(1), jnp.int32(heap_bytes), jnp.int32(0), cache, hh, mm))

    offset = node * node_size - heap_bytes
    longest = longest.at[node].set(jnp.where(need & ok, 0, longest[node]))

    def up(i, carry):
        longest, n, lvu, cache, hh, mm = carry
        parent = n >> 1
        active = ok & (parent >= 1)
        p = jnp.maximum(parent, 1)
        newval = jnp.maximum(longest[2 * p], longest[2 * p + 1])
        longest = longest.at[p].set(
            jnp.where(need & active, newval, longest[p]))
        lvu = lvu + active.astype(jnp.int32)
        cache, h, m = _access(cache, jnp.where(need & active, p, INVALID))
        return longest, jnp.where(active, p, jnp.int32(0)), lvu, cache, \
            hh + h, mm + m

    longest, _, lvu, cache, hh, mm = lax.fori_loop(
        0, depth, up, (longest, node, jnp.int32(0), cache, hh, mm))
    off = jnp.where(ok, offset, INVALID)
    return longest, cache, off, lvd, lvu, hh, mm


def _buddy_free(longest, cache, ptr, lg, big, *, heap_bytes, depth, n_nodes):
    """Buddy coalescing up-walk fused with the cache, committed where `big`.

    `lg` is the recorded log2(size) of the bypass block (from big_log2)."""
    fsize = jnp.int32(1) << jnp.maximum(lg, 0)
    node = jnp.clip((ptr + heap_bytes) // jnp.maximum(fsize, 1), 0,
                    n_nodes - 1)
    valid = big & (ptr >= 0) & (ptr < heap_bytes) & (longest[node] == 0)
    cache, hh, mm = _access(cache, jnp.where(big, node, INVALID))
    longest = longest.at[node].set(jnp.where(valid, fsize, longest[node]))

    def up(i, carry):
        longest, n, nsize, lvu, cache, hh, mm = carry
        parent = n >> 1
        active = valid & (parent >= 1)
        p = jnp.maximum(parent, 1)
        psize = nsize << 1
        l, r = longest[2 * p], longest[2 * p + 1]
        newval = jnp.where((l == nsize) & (r == nsize), psize,
                           jnp.maximum(l, r))
        longest = longest.at[p].set(jnp.where(active, newval, longest[p]))
        lvu = lvu + active.astype(jnp.int32)
        cache, h, m = _access(cache, jnp.where(big & active, p, INVALID))
        return longest, jnp.where(active, p, jnp.int32(0)), psize, lvu, \
            cache, hh + h, mm + m

    longest, _, _, lvu, cache, hh, mm = lax.fori_loop(
        0, depth, up,
        (longest, node, fsize, jnp.int32(0), cache, hh, mm))
    return longest, cache, lvu, hh, mm


class FusedRoundOut(NamedTuple):
    """Kernel outputs: new state leaves + per-thread int32 round records."""

    longest: jnp.ndarray
    counts: jnp.ndarray
    stacks: jnp.ndarray
    block_cls: jnp.ndarray
    block_free: jnp.ndarray
    big_log2: jnp.ndarray
    tags: jnp.ndarray
    last_used: jnp.ndarray
    clock: jnp.ndarray        # int32[1]
    m_ptr: jnp.ndarray        # malloc-phase result pointer (-1 idle/fail)
    m_hit: jnp.ndarray        # thread-cache hit (case 1)
    m_refill: jnp.ndarray     # thread-cache miss -> backend refill (case 2)
    m_bypass: jnp.ndarray     # > max class -> backend bypass (case 3)
    m_okb: jnp.ndarray        # backend op succeeded
    m_bpos: jnp.ndarray       # backend serialization order, -1 = frontend
    m_lvdown: jnp.ndarray
    m_lvup: jnp.ndarray
    m_hits: jnp.ndarray       # buddy-cache hits charged to this thread
    m_miss: jnp.ndarray
    f_push: jnp.ndarray       # free pushed to the caller's freelist
    f_big: jnp.ndarray        # free went to the buddy backend
    f_over: jnp.ndarray       # free dropped (freelist at capacity)
    f_bpos: jnp.ndarray
    f_lvup: jnp.ndarray
    f_hits: jnp.ndarray
    f_miss: jnp.ndarray
    valid_old: jnp.ndarray    # realloc meta: ptr maps to tracked metadata
    in_place: jnp.ndarray     # realloc served in place (live request)
    moved_raw: jnp.ndarray    # realloc needs relocation (pre-alloc-success)
    old_bytes: jnp.ndarray
    new_bytes: jnp.ndarray


def protocol_round(op, size, ptr, longest, counts, stacks, block_cls,
                   block_free, big_log2, tags, last_used, clock,
                   class_sizes=None, *, heap_bytes: int, block_bytes: int,
                   size_classes: tuple,
                   batch_refill: bool = False) -> FusedRoundOut:
    """Pure-jnp body of the fused round (the kernel runs exactly this).

    Mirrors `system._protocol_round` over the pim_malloc primitives: realloc
    size-class analysis on pre-round metadata, one batched malloc phase
    (MALLOC/CALLOC + relocating REALLOCs; vectorized frontend pops, then the
    serial backend), one batched free phase (FREE + vacated realloc blocks),
    with every backend tree touch passing through the in-kernel LRU cache in
    mutex serialization order (malloc phase drains first).

    ``batch_refill=True`` routes block-granularity backend rounds through
    the vectorized run-carve fast path (see module docstring); ``False``
    always runs the original serial walks — both are bitwise-identical.
    """
    T = op.shape[0]
    nb = heap_bytes // block_bytes
    n_nodes = 2 * nb
    depth = nb.bit_length() - 1
    nc = len(size_classes)
    cap = stacks.shape[-1]
    max_sub = block_bytes // min(size_classes)
    max_class = max(size_classes)
    log2_min_class = min(size_classes).bit_length() - 1
    if class_sizes is None:  # direct (non-kernel) calls build it inline
        class_sizes = jnp.array(size_classes, jnp.int32)
    t_idx = jnp.arange(T, dtype=jnp.int32)
    cache = (tags, last_used, clock)

    def class_of(z):
        rounded = _next_pow2(jnp.maximum(z, min(size_classes)))
        return jnp.clip(_ilog2(rounded) - log2_min_class, 0, nc - 1)

    is_alloc = (op == 1) | (op == 4)          # OP_MALLOC | OP_CALLOC
    is_re = op == 3                           # OP_REALLOC
    is_free = op == 2                         # OP_FREE
    # OP_EPOCH_RESET (5) intentionally matches none of the above: backends
    # without an arena frontend answer a reset round as idle (path -1),
    # exactly like `system._protocol_round`, so hwsw/pallas stay bitwise
    # equal on tapes containing resets. The arena/tlregion wrapper consumes
    # op 5 before forwarding, so the fused kernel only ever sees it on
    # raw-backend replays of arena-managed tapes.

    # ---- realloc size-class analysis on the pre-round metadata ------------
    pvalid = (ptr >= 0) & (ptr < heap_bytes)
    pb = jnp.where(pvalid, ptr // block_bytes, 0)
    pcls = block_cls[pb]
    small_old = pvalid & (pcls >= 0)
    big_old = (pvalid & (pcls < 0) & (big_log2[pb] >= 0)
               & (ptr % block_bytes == 0))
    old_bytes = jnp.where(
        small_old, class_sizes[jnp.maximum(pcls, 0)],
        jnp.where(big_old, jnp.int32(1) << jnp.maximum(big_log2[pb], 0), 0))
    new_small = size <= max_class
    new_bytes = jnp.where(new_small, class_sizes[class_of(size)],
                          _next_pow2(jnp.maximum(size, block_bytes)))
    in_place_meta = ((small_old & new_small) | (big_old & ~new_small)) & (
        new_bytes == old_bytes)
    valid_old = small_old | big_old
    re_live = is_re & (size > 0)
    in_place = re_live & in_place_meta
    moved = re_live & ~in_place_meta
    re_free0 = is_re & (size <= 0) & (ptr >= 0)

    # ---- malloc phase A: vectorized thread-cache pops ---------------------
    m_active = (is_alloc & (size > 0)) | moved
    msizes = jnp.where(m_active, size, 0)
    too_big = m_active & (msizes > heap_bytes)
    small = m_active & (msizes <= max_class) & (msizes > 0)
    c = class_of(msizes)
    cnt = counts[t_idx, c]
    hit = small & (cnt > 0)
    pos = jnp.maximum(cnt - 1, 0)
    ptr_a = stacks[t_idx, c, pos]
    counts = counts.at[t_idx, c].add(jnp.where(hit, -1, 0))
    blk_a = jnp.where(hit, ptr_a // block_bytes, nb)
    block_free = block_free.at[blk_a].add(-1, mode="drop")
    refill = small & ~hit
    bypass = m_active & (msizes > max_class) & ~too_big
    need = refill | bypass

    # ---- malloc phase B: serial backend (mutex order = thread order) ------
    z = jnp.zeros((T,), jnp.int32)

    def mstep(t, carry):
        (longest, counts, stacks, block_cls, block_free, big_log2, cache,
         border, m_ptr, m_bpos, m_okb, m_lvd, m_lvu, m_hits, m_miss) = carry
        need_t, refill_t, bypass_t = need[t], refill[t], bypass[t]
        size_t, c_t = msizes[t], c[t]
        alloc_size = jnp.where(
            bypass_t, _next_pow2(jnp.maximum(size_t, block_bytes)),
            jnp.int32(block_bytes))
        longest, cache, off, lvd, lvu, hh, mm = _buddy_alloc(
            longest, cache, alloc_size, need_t, heap_bytes=heap_bytes,
            block_bytes=block_bytes, depth=depth)
        ok = need_t & (off >= 0)

        # refill: carve the block into sub-blocks, push all, pop the top
        csize = class_sizes[c_t]
        sub = block_bytes // csize
        offs = off + jnp.arange(max_sub, dtype=jnp.int32) * csize
        row = jnp.where(jnp.arange(max_sub) < sub, offs, INVALID)
        do_refill = refill_t & ok
        stacks = stacks.at[t, c_t, :max_sub].set(
            jnp.where(do_refill, row, stacks[t, c_t, :max_sub]))
        counts = counts.at[t, c_t].set(
            jnp.where(do_refill, sub - 1, counts[t, c_t]))
        b = jnp.where(off >= 0, off // block_bytes, 0)
        block_cls = block_cls.at[b].set(
            jnp.where(do_refill, c_t, block_cls[b]))
        block_free = block_free.at[b].set(
            jnp.where(do_refill, sub - 1, block_free[b]))
        ptr_refill = off + (sub - 1) * csize

        # bypass: record size so a ptr-only free can recover it
        do_bypass = bypass_t & ok
        big_log2 = big_log2.at[b].set(
            jnp.where(do_bypass, _ilog2(alloc_size), big_log2[b]))

        ptr_t = jnp.where(do_refill, ptr_refill,
                          jnp.where(do_bypass, off, INVALID))
        m_ptr = m_ptr.at[t].set(ptr_t)
        m_bpos = m_bpos.at[t].set(jnp.where(need_t, border, INVALID))
        m_okb = m_okb.at[t].set(ok.astype(jnp.int32))
        m_lvd = m_lvd.at[t].set(jnp.where(need_t, lvd, 0))
        m_lvu = m_lvu.at[t].set(jnp.where(need_t, lvu, 0))
        m_hits = m_hits.at[t].set(hh)
        m_miss = m_miss.at[t].set(mm)
        border = border + need_t.astype(jnp.int32)
        return (longest, counts, stacks, block_cls, block_free, big_log2,
                cache, border, m_ptr, m_bpos, m_okb, m_lvd, m_lvu, m_hits,
                m_miss)

    carry = (longest, counts, stacks, block_cls, block_free, big_log2, cache,
             jnp.int32(0), z - 1, z - 1, z, z, z, z, z)

    def _serial_backend(ca):
        return lax.fori_loop(0, T, mstep, ca)

    if batch_refill:
        need_i = need.astype(jnp.int32)
        n_need = jnp.sum(need_i)
        rank = jnp.cumsum(need_i) - need_i  # mutex order among needy threads
        alloc_size = jnp.where(
            bypass, _next_pow2(jnp.maximum(msizes, block_bytes)),
            jnp.int32(block_bytes))
        all_block = jnp.all(jnp.where(need, alloc_size == block_bytes, True))
        b0 = leftmost_block(longest, heap_bytes=heap_bytes,
                            block_bytes=block_bytes, depth=depth)
        run_ok = run_blocks_free(longest, b0, n_need, window=T,
                                 heap_bytes=heap_bytes,
                                 block_bytes=block_bytes)
        eligible = (all_block & (longest[1] >= block_bytes)
                    & (b0 + n_need <= nb) & run_ok)

        def _skip_backend(ca):
            return ca  # idle defaults in `carry` already match the serial loop

        def _fast_backend(ca):
            (longest, counts, stacks, block_cls, block_free, big_log2, cache,
             border, m_ptr, m_bpos, m_okb, m_lvd, m_lvu, m_hits, m_miss) = ca
            blocks = b0 + rank
            leaf = nb + blocks
            # exact serial LRU access order: per needy thread root + down
            # path + up path (INVALID lanes for non-needy are state no-ops)
            sh_dn = depth - 1 - jnp.arange(depth, dtype=jnp.int32)
            sh_up = 1 + jnp.arange(depth, dtype=jnp.int32)
            seq = jnp.concatenate([
                jnp.full((T, 1), 1, jnp.int32),
                leaf[:, None] >> sh_dn[None, :],
                leaf[:, None] >> sh_up[None, :]], axis=1)
            seq = jnp.where(need[:, None], seq, INVALID).reshape(-1)

            def acc(cache, node):
                cache, h, m = _access(cache, node)
                return cache, (h, m)

            cache, (hh, mm) = lax.scan(acc, cache, seq, unroll=16)
            k = 2 * depth + 1
            m_hits = hh.reshape(T, k).sum(axis=1)
            m_miss = mm.reshape(T, k).sum(axis=1)

            longest = carve_run(longest, b0, n_need, window=T,
                                heap_bytes=heap_bytes,
                                block_bytes=block_bytes)

            off = blocks * block_bytes
            csize = class_sizes[c]
            sub = block_bytes // csize
            offs = (off[:, None]
                    + jnp.arange(max_sub, dtype=jnp.int32)[None, :]
                    * csize[:, None])
            rows = jnp.where(jnp.arange(max_sub)[None, :] < sub[:, None],
                             offs, INVALID)
            stacks, counts = bulk_refill(stacks, counts, refill, c, rows,
                                         sub - 1)
            bsel = jnp.where(refill, blocks, nb)
            block_cls = block_cls.at[bsel].set(c, mode="drop")
            block_free = block_free.at[bsel].set(sub - 1, mode="drop")
            big_log2 = big_log2.at[jnp.where(bypass, blocks, nb)].set(
                _ilog2(jnp.int32(block_bytes)), mode="drop")

            ptr_refill = off + (sub - 1) * csize
            m_ptr = jnp.where(refill, ptr_refill,
                              jnp.where(bypass, off, INVALID))
            m_bpos = jnp.where(need, rank, INVALID)
            lvl = jnp.full((T,), depth, jnp.int32)
            m_lvd = jnp.where(need, lvl, 0)
            m_lvu = jnp.where(need, lvl, 0)
            return (longest, counts, stacks, block_cls, block_free, big_log2,
                    cache, n_need, m_ptr, m_bpos, need_i, m_lvd, m_lvu,
                    m_hits, m_miss)

        branch = jnp.where(n_need == 0, 0,
                           jnp.where(eligible, 1, 2)).astype(jnp.int32)
        out_b = lax.switch(branch,
                           (_skip_backend, _fast_backend, _serial_backend),
                           carry)
    else:
        out_b = _serial_backend(carry)
    (longest, counts, stacks, block_cls, block_free, big_log2, cache, _,
     m_ptr_b, m_bpos, m_okb, m_lvd, m_lvu, m_hits, m_miss) = out_b
    mptrs = jnp.where(hit, ptr_a, m_ptr_b)
    mok = m_active & (mptrs >= 0)

    # ---- free phase: explicit frees + vacated realloc blocks --------------
    f_active = is_free | (moved & valid_old & mok) | re_free0
    fptr = jnp.where(f_active, ptr, INVALID)
    factive = f_active & (fptr >= 0) & (fptr < heap_bytes)
    fb = jnp.where(factive, fptr // block_bytes, 0)
    fcls = block_cls[fb]
    fsmall = factive & (fcls >= 0)
    fbig = (factive & (fcls < 0) & (big_log2[fb] >= 0)
            & (fptr % block_bytes == 0))
    csel = jnp.maximum(fcls, 0)
    fpos = counts[t_idx, csel]
    over = fsmall & (fpos >= cap)
    push = fsmall & ~over
    possafe = jnp.minimum(fpos, cap - 1)
    stacks = stacks.at[t_idx, csel, possafe].set(
        jnp.where(push, fptr, stacks[t_idx, csel, possafe]))
    counts = counts.at[t_idx, csel].add(jnp.where(push, 1, 0))
    block_free = block_free.at[jnp.where(push, fb, nb)].add(1, mode="drop")

    def fstep(t, carry):
        longest, big_log2, cache, border, f_bpos, f_lvu, f_hits, f_miss = \
            carry
        big_t = fbig[t]
        longest, cache, lvu, hh, mm = _buddy_free(
            longest, cache, fptr[t], big_log2[fb[t]], big_t,
            heap_bytes=heap_bytes, depth=depth, n_nodes=n_nodes)
        big_log2 = big_log2.at[fb[t]].set(
            jnp.where(big_t, INVALID, big_log2[fb[t]]))
        f_bpos = f_bpos.at[t].set(jnp.where(big_t, border, INVALID))
        f_lvu = f_lvu.at[t].set(jnp.where(big_t, lvu, 0))
        f_hits = f_hits.at[t].set(hh)
        f_miss = f_miss.at[t].set(mm)
        border = border + big_t.astype(jnp.int32)
        return longest, big_log2, cache, border, f_bpos, f_lvu, f_hits, f_miss

    fcarry = (longest, big_log2, cache, jnp.int32(0), z - 1, z, z, z)
    if batch_refill:
        # a round with no backend free skips the serial coalescing loop:
        # fstep with fbig[t]=False everywhere is a state no-op (INVALID
        # cache accesses, masked writes), so the defaults are bitwise-equal
        out_f = lax.cond(jnp.any(fbig),
                         lambda ca: lax.fori_loop(0, T, fstep, ca),
                         lambda ca: ca, fcarry)
    else:
        out_f = lax.fori_loop(0, T, fstep, fcarry)
    longest, big_log2, cache, _, f_bpos, f_lvu, f_hits, f_miss = out_f

    tags, last_used, clock = cache
    i32 = lambda m: m.astype(jnp.int32)  # noqa: E731
    return FusedRoundOut(
        longest=longest, counts=counts, stacks=stacks, block_cls=block_cls,
        block_free=block_free, big_log2=big_log2, tags=tags,
        last_used=last_used, clock=clock,
        m_ptr=mptrs, m_hit=i32(hit), m_refill=i32(refill),
        m_bypass=i32(bypass), m_okb=m_okb, m_bpos=m_bpos, m_lvdown=m_lvd,
        m_lvup=m_lvu, m_hits=m_hits, m_miss=m_miss,
        f_push=i32(push), f_big=i32(fbig), f_over=i32(over), f_bpos=f_bpos,
        f_lvup=f_lvu, f_hits=f_hits, f_miss=f_miss,
        valid_old=i32(valid_old), in_place=i32(in_place),
        moved_raw=i32(moved), old_bytes=old_bytes, new_bytes=new_bytes)


def _kernel(op_ref, size_ref, ptr_ref, longest_ref, counts_ref, stacks_ref,
            bcls_ref, bfree_ref, blog_ref, tags_ref, lu_ref, clock_ref,
            csizes_ref, *out_refs, heap_bytes: int, block_bytes: int,
            size_classes: tuple, batch_refill: bool):
    out = protocol_round(
        op_ref[...], size_ref[...], ptr_ref[...], longest_ref[...],
        counts_ref[...], stacks_ref[...], bcls_ref[...], bfree_ref[...],
        blog_ref[...], tags_ref[...], lu_ref[...], clock_ref[0],
        csizes_ref[...], heap_bytes=heap_bytes, block_bytes=block_bytes,
        size_classes=size_classes, batch_refill=batch_refill)
    vals = list(out)
    vals[8] = jnp.reshape(vals[8], (1,))  # clock back to its [1] slot
    for ref, val in zip(out_refs, vals):
        ref[...] = val


def _batch_refill_default() -> bool:
    """Env-resolved default for the batched fast path (on unless disabled)."""
    return os.environ.get("PIM_MALLOC_BATCH_REFILL", "1").lower() not in (
        "0", "false", "off")


def fused_heap_step(op, size, ptr, longest, counts, stacks, block_cls,
                    block_free, big_log2, tags, last_used, clock, *,
                    heap_bytes: int, block_bytes: int, size_classes: tuple,
                    interpret: bool | None = None,
                    batch_refill: bool | None = None) -> FusedRoundOut:
    """One fused protocol round for a single core (clock is int32[1]).

    Batch across cores/ranks with `vmap` — Pallas maps the batch onto the
    kernel grid; this is what `heap.MultiCoreHeap` / `heap.ShardedHeap` do
    through the registered ``pallas`` backend. ``batch_refill=None``
    resolves from ``PIM_MALLOC_BATCH_REFILL`` (default on); both settings
    are bitwise-identical, ``False`` merely forces the pre-batching serial
    walk (the wall-clock bench lane's comparison point).
    """
    if interpret is None:
        from repro.kernels.ops import on_tpu
        interpret = not on_tpu()
    if batch_refill is None:
        batch_refill = _batch_refill_default()
    return _fused_heap_step(
        op, size, ptr, longest, counts, stacks, block_cls, block_free,
        big_log2, tags, last_used, clock, heap_bytes=heap_bytes,
        block_bytes=block_bytes, size_classes=size_classes,
        interpret=bool(interpret), batch_refill=bool(batch_refill))


@functools.partial(jax.jit, static_argnames=("heap_bytes", "block_bytes",
                                             "size_classes", "interpret",
                                             "batch_refill"))
def _fused_heap_step(op, size, ptr, longest, counts, stacks, block_cls,
                     block_free, big_log2, tags, last_used, clock, *,
                     heap_bytes: int, block_bytes: int, size_classes: tuple,
                     interpret: bool, batch_refill: bool) -> FusedRoundOut:
    T = op.shape[0]
    out_shape = FusedRoundOut(
        longest=jax.ShapeDtypeStruct(longest.shape, jnp.int32),
        counts=jax.ShapeDtypeStruct(counts.shape, jnp.int32),
        stacks=jax.ShapeDtypeStruct(stacks.shape, jnp.int32),
        block_cls=jax.ShapeDtypeStruct(block_cls.shape, jnp.int32),
        block_free=jax.ShapeDtypeStruct(block_free.shape, jnp.int32),
        big_log2=jax.ShapeDtypeStruct(big_log2.shape, jnp.int32),
        tags=jax.ShapeDtypeStruct(tags.shape, jnp.int32),
        last_used=jax.ShapeDtypeStruct(last_used.shape, jnp.int32),
        clock=jax.ShapeDtypeStruct((1,), jnp.int32),
        **{f: jax.ShapeDtypeStruct((T,), jnp.int32)
           for f in FusedRoundOut._fields[9:]})
    kern = functools.partial(_kernel, heap_bytes=heap_bytes,
                             block_bytes=block_bytes,
                             size_classes=tuple(size_classes),
                             batch_refill=batch_refill)
    out = pl.pallas_call(kern, out_shape=list(out_shape),
                         interpret=interpret)(
        op, size, ptr, longest, counts, stacks, block_cls, block_free,
        big_log2, tags, last_used, clock,
        jnp.array(size_classes, jnp.int32))
    return FusedRoundOut(*out)
