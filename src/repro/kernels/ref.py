"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import buddy
from repro.core.buddy import BuddyConfig, BuddyState


def buddy_alloc_batch_ref(tree, sizes, *, heap_bytes: int, min_block: int):
    """Reference for kernels.buddy_traverse: vmapped scan of core.buddy.alloc."""
    cfg = BuddyConfig(heap_bytes=heap_bytes, min_block=min_block)

    def per_core(tree_row, sizes_row):
        st = BuddyState(longest=tree_row)
        st, offs, _ = buddy.alloc_batch(cfg, st, sizes_row)
        return offs, st.longest

    offs, new_tree = jax.vmap(per_core)(tree, sizes)
    return offs, new_tree


def freelist_op_ref(stacks, counts, op, cls, ptr_in):
    """Reference for kernels.freelist: vectorized pop/push per thread."""
    T, NC, CAP = stacks.shape
    t = jnp.arange(T)
    c = jnp.maximum(cls, 0)
    cnt = counts[t, c]
    is_pop = (op == 0) & (cnt > 0)
    is_push = (op == 1) & (cnt < CAP)

    pos_pop = jnp.maximum(cnt - 1, 0)
    ptr_out = jnp.where(is_pop, stacks[t, c, pos_pop], -1).astype(jnp.int32)

    pos_push = jnp.minimum(cnt, CAP - 1)
    new_stacks = stacks.at[t, c, pos_push].set(
        jnp.where(is_push, ptr_in, stacks[t, c, pos_push])
    )
    delta = jnp.where(is_pop, -1, jnp.where(is_push, 1, 0))
    new_counts = counts.at[t, c].add(delta)
    return ptr_out, new_counts, new_stacks


def paged_attention_ref(q, k_pages, v_pages, page_table, seq_lens):
    """Reference for kernels.paged_attention: dense gather + masked softmax."""
    B, H, D = q.shape
    N, page_size, KVH, _ = k_pages.shape
    P = page_table.shape[1]
    G = H // KVH
    scale = 1.0 / (D ** 0.5)

    pt = jnp.maximum(page_table, 0)
    k = k_pages[pt]                       # [B, P, page, KVH, D]
    v = v_pages[pt]
    S = P * page_size
    k = k.reshape(B, S, KVH, D).astype(jnp.float32)
    v = v.reshape(B, S, KVH, D).astype(jnp.float32)
    qh = q.reshape(B, KVH, G, D).astype(jnp.float32)

    s = jnp.einsum("bkgd,bskd->bkgs", qh, k) * scale
    pos = jnp.arange(S)[None, None, None, :]
    mask = pos < seq_lens[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v)
    return o.reshape(B, H, D).astype(q.dtype)
