"""Checkpointing: named-leaf npz shards + JSON manifest, async save,
restore-with-resharding (elastic: restore onto a different mesh/device
count — host round-trip re-places every leaf under the target sharding).

Single-host implementation; in a multi-host deployment each process writes
its addressable shards under `dir/proc-<k>/` with the same manifest format
(documented contract — the restore path already takes per-leaf shardings).
"""
from __future__ import annotations

import json
import os
import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np

import jax


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def save(tree, step: int, ckpt_dir: str) -> str:
    """Blocking save. Returns the checkpoint path."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    named = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in named.items()}
    np.savez(os.path.join(path, "leaves.npz"), **arrays)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                   for k, a in arrays.items()},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    # atomic completion marker (restart-safe: partial saves are ignored)
    with open(os.path.join(path, "COMMITTED"), "w") as f:
        f.write("ok")
    return path


class AsyncCheckpointer:
    """Fire-and-forget saves on a background thread; `wait()` to drain.

    The tree is snapshotted to host memory synchronously (cheap vs. training
    step), serialization happens off-thread — the paper-independent but
    deployment-required 'don't stall the TPUs on I/O' pattern."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._futures = []
        self._lock = threading.Lock()

    def save(self, tree, step: int):
        # np.array, not np.asarray: asarray is a no-copy view of host
        # arrays, and the caller may mutate them before the worker writes
        host_tree = jax.tree.map(lambda x: np.array(x), tree)
        with self._lock:
            self._futures.append(
                self._pool.submit(save, host_tree, step, self.ckpt_dir))

    def wait(self):
        with self._lock:
            futs, self._futures = self._futures, []
        return [f.result() for f in futs]


def latest_step(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, "COMMITTED")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(tree_like, step: int, ckpt_dir: str, shardings=None):
    """Restore into the structure of `tree_like` (pytree of arrays or
    ShapeDtypeStructs). `shardings`: optional matching pytree of
    jax.sharding.Sharding for elastic re-placement onto a new mesh."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "leaves.npz"))
    named = _flatten(tree_like)
    flat_sh = _flatten(shardings) if shardings is not None else None
    out = {}
    for key, leaf in named.items():
        arr = data[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        want = np.dtype(leaf.dtype)
        if arr.dtype != want:
            # dtype drift between writer and restorer: cast, but refuse a
            # lossy cast — a silently-truncated heap pointer is corruption
            cast = arr.astype(want)
            if not np.array_equal(cast.astype(arr.dtype), arr):
                raise ValueError(
                    f"lossy dtype cast restoring {key!r}: saved "
                    f"{arr.dtype} -> wanted {want}")
            arr = cast
        if flat_sh is not None:
            out[key] = jax.device_put(arr, flat_sh[key])
        else:
            out[key] = jax.numpy.asarray(arr)
    # rebuild tree
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path_, _ in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_)
        leaves.append(out[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)
