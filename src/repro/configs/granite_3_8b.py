"""granite-3-8b [dense] — GQA kv=8. [hf:ibm-granite/granite-3.0-8b-base]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=12800,
    vocab=49155, head_dim=128, mlp="swiglu",
    fsdp=True,
    # SSPerf-validated optimized defaults (baseline: override these False)
    attn_4d=True, gqa_expand=True, kv_seq_parallel=True,
)
