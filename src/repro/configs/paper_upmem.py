"""The paper's own system config: UPMEM-PIM allocator parameters (Table 3)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperConfig:
    heap_bytes: int = 32 * 1024 * 1024
    min_block: int = 32
    block_bytes: int = 4096
    size_classes: tuple = (16, 32, 64, 128, 256, 512, 1024, 2048)
    num_threads: int = 16          # evaluated at 1 and 16 tasklets
    n_cores: int = 512             # UPMEM system in Sec. 5
    buddy_cache_bytes: int = 64    # 16 entries x 4 B
    freq_hz: float = 350e6


CONFIG = PaperConfig()
