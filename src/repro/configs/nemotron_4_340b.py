"""nemotron-4-340b [dense] — GQA kv=8, squared-ReLU MLP. [arXiv:2402.16819]

Largest assigned arch: sequence-sharded residual (Megatron-SP) and bf16
optimizer moments are on by default so train_4k fits 256 x 16 GB HBM.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, d_ff=73728,
    vocab=256000, head_dim=192, mlp="squared_relu",
    seq_shard=True, opt_moment_dtype="bfloat16",
    fsdp=True,
    # SSPerf-validated optimized defaults (baseline: override these False)
    attn_4d=True, gqa_expand=True, kv_seq_parallel=True,
    train_microbatches=2,
)
