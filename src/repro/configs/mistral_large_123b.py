"""mistral-large-123b [dense] — GQA kv=8. [hf:mistralai/Mistral-Large-2407]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=28672,
    vocab=32768, head_dim=128, mlp="swiglu",
    seq_shard=True, opt_moment_dtype="bfloat16",
    fsdp=True,
    # SSPerf-validated optimized defaults (baseline: override these False)
    attn_4d=True, gqa_expand=True, kv_seq_parallel=True,
    train_microbatches=2,
)
