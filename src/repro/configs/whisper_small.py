"""whisper-small [audio] — enc-dec, conv frontend STUB. [arXiv:2212.04356]

enc_frames padded 1500 -> 1536 for block-divisible flash cross-attention.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=51865, head_dim=64, mlp="gelu", enc_layers=12, enc_frames=1536,
    tie_embeddings=True,
    # SSPerf-validated optimized defaults (baseline: override these False)
    kv_seq_parallel=True  # attn_4d off: H<16 heads cannot shard,
)
