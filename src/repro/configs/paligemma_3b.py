"""paligemma-3b [vlm] — SigLIP patch STUB + gemma decoder (MQA kv=1).
[arXiv:2407.07726]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
    vocab=257216, head_dim=256, mlp="geglu", n_patches=256,
    tie_embeddings=True,
    # SSPerf-validated optimized defaults (baseline: override these False)
    kv_seq_parallel=True  # attn_4d off: H<16 heads cannot shard,
)
