"""Assigned architecture configs (public-literature specs) + paper config.

Each module exposes CONFIG: ArchConfig with the exact assigned dimensions;
`get(name)` resolves by arch id (dashes or underscores).
"""
from __future__ import annotations

import importlib

ARCHS = (
    "mamba2_130m", "nemotron_4_340b", "stablelm_12b", "mistral_large_123b",
    "granite_3_8b", "recurrentgemma_9b", "whisper_small", "olmoe_1b_7b",
    "qwen2_moe_a2_7b", "paligemma_3b",
)


def get(name: str):
    mod = importlib.import_module(
        f"repro.configs.{name.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


def all_configs():
    return {a: get(a) for a in ARCHS}
