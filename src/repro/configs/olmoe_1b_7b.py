"""olmoe-1b-7b [moe] — 64 experts, top-8. [arXiv:2409.02060]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=0,
    vocab=50304, head_dim=128, n_experts=64, top_k=8, expert_d_ff=1024,
    fsdp=True,
    # SSPerf-validated optimized defaults (baseline: override these False)
    attn_4d=True,
)
