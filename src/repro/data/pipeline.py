"""Deterministic synthetic data pipeline, sharded placement included.

A real deployment would swap `TokenStream` for a tokenized corpus reader;
the contract (global-batch numpy arrays -> `shard_batch` device placement)
is what the trainer depends on. Streams are seeded and step-indexed, so a
restore-at-step-k resumes the exact byte stream (fault-tolerance invariant,
tested in tests/test_substrate.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    d_model: int = 0          # for frontend-stub streams
    enc_frames: int = 0
    n_patches: int = 0
    dtype: str = "bfloat16"


class TokenStream:
    """Stateless-per-step synthetic LM stream: batch(step) is pure."""

    def __init__(self, cfg: StreamConfig):
        self.cfg = cfg

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.Generator(np.random.Philox(key=cfg.seed, counter=step))
        toks = rng.integers(0, cfg.vocab, size=(cfg.global_batch, cfg.seq_len),
                            dtype=np.int32)
        out = {"tokens": toks, "labels": toks.copy()}
        if cfg.enc_frames:
            out["enc_embeds"] = rng.standard_normal(
                (cfg.global_batch, cfg.enc_frames, cfg.d_model),
                dtype=np.float32)
        if cfg.n_patches:
            out["patch_embeds"] = rng.standard_normal(
                (cfg.global_batch, cfg.n_patches, cfg.d_model),
                dtype=np.float32)
        return out


def batch_pspec(mesh, batch: dict) -> dict:
    """Shard the leading (global-batch) dim over all non-'model' axes."""
    dp = tuple(a for a in mesh.axis_names if a != "model")
    return {k: P(dp, *([None] * (v.ndim - 1))) for k, v in batch.items()}


def shard_batch(mesh, batch: dict) -> dict:
    specs = batch_pspec(mesh, batch)
    return {
        k: jax.device_put(v, NamedSharding(mesh, specs[k]))
        for k, v in batch.items()
    }
