"""Fault-tolerant training runtime: checkpoint/restart, straggler watchdog,
failure injection for tests, and elastic re-meshing hooks.

Posture for 1000+ nodes (documented contract, exercised single-host here):
  * every K steps -> async checkpoint (params, opt state, data-stream step);
  * a step watchdog flags stragglers (step > deadline x median) — on real
    fleets this feeds the scheduler's drain/replace signal;
  * on failure: restore latest committed checkpoint, rebuild the data
    stream at the restored step (byte-identical stream), continue;
  * elastic: restore accepts a NEW mesh; data axis may grow/shrink
    (global batch and model-axis layout are invariants).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.checkpoint import ckpt as ckpt_lib


class FailureInjector:
    """Deterministically raise at given steps (tests / chaos drills)."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)
        self.failed = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.failed:
            self.failed.add(step)
            raise RuntimeError(f"injected failure at step {step}")


@dataclasses.dataclass
class StepWatchdog:
    """Flags steps slower than `factor` x running median as stragglers."""

    factor: float = 3.0
    window: int = 32
    _times: list = dataclasses.field(default_factory=list)
    stragglers: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        times = self._times
        is_straggler = False
        if len(times) >= 5:
            med = sorted(times)[len(times) // 2]
            if seconds > self.factor * med:
                self.stragglers.append((step, seconds, med))
                is_straggler = True
        times.append(seconds)
        if len(times) > self.window:
            times.pop(0)
        return is_straggler


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    max_failures: int = 3


def run_with_recovery(cfg: TrainLoopConfig, *, init_state, step_fn: Callable,
                      make_batch: Callable, injector: Optional[FailureInjector]
                      = None, watchdog: Optional[StepWatchdog] = None):
    """Generic fault-tolerant loop.

    init_state: pytree (params, opt, ...) — the checkpointable unit
    step_fn(state, batch, step) -> (state, metrics)
    make_batch(step) -> batch
    Returns (state, history dict).
    """
    saver = ckpt_lib.AsyncCheckpointer(cfg.ckpt_dir)
    state = init_state
    start = 0
    restored = ckpt_lib.latest_step(cfg.ckpt_dir)
    if restored is not None:
        state = ckpt_lib.restore(state, restored, cfg.ckpt_dir)
        start = restored + 1

    failures = 0
    history = {"steps": [], "recoveries": 0, "stragglers": 0}
    step = start
    while step < cfg.total_steps:
        try:
            t0 = time.monotonic()
            if injector is not None:
                injector.maybe_fail(step)
            batch = make_batch(step)
            state, metrics = step_fn(state, batch, step)
            dt = time.monotonic() - t0
            if watchdog is not None and watchdog.observe(step, dt):
                history["stragglers"] += 1
            history["steps"].append(step)
            if step % cfg.ckpt_every == 0:
                saver.save(state, step)
            step += 1
        except Exception:
            failures += 1
            if failures > cfg.max_failures:
                raise
            saver.wait()
            restored = ckpt_lib.latest_step(cfg.ckpt_dir)
            if restored is not None:
                state = ckpt_lib.restore(state, restored, cfg.ckpt_dir)
                step = restored + 1
            else:
                state = init_state
                step = 0
            history["recoveries"] += 1
    saver.wait()
    return state, history
