"""AdamW with global-norm clipping, configurable moment dtype, and warmup+
cosine schedule. Pure-functional (optax-style) — opt state shards exactly
like the parameters (see parallel/sharding.py), so ZeRO-style partitioning
falls out of the param specs.

For the largest assigned archs (nemotron-340b, mistral-123b) moments default
to bf16 (`ArchConfig.opt_moment_dtype`) — with fp32 moments the optimizer
state alone would exceed 16 GB/device on a 256-chip pod.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: str = "float32"


class AdamWState(NamedTuple):
    count: jnp.ndarray
    m: object   # pytree like params
    v: object


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_frac * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init(cfg: AdamWConfig, params) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    def zeros(p):
        return jnp.zeros(p.shape, dt)
    return AdamWState(count=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    lr = schedule(cfg, count)
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mh = m32 / c1
        vh = v32 / c2
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (step + decay)
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(count=count, m=new_m, v=new_v), metrics
