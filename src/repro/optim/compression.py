"""Gradient compression: int8 block-quantized collectives with error feedback.

At 1000+ node scale, cross-pod (DCN) gradient all-reduces dominate step time
for data-parallel training. This module provides:

  * quantize/dequantize — int8 with per-block fp32 scales (block = trailing
    dim tiles of 256), ~3.5x wire-size reduction vs bf16.
  * compressed_psum    — shard_map-compatible psum of quantized grads:
    quantize -> psum(int32 accumulate) -> dequantize. Exact for <= 2^23
    summands per block (int32 head-room), deterministic.
  * ErrorFeedback      — residual accumulation so quantization error is
    re-injected next step (Seide et al.; keeps convergence).

Used by launch/train.py's `--compress-grads` path where the pod-axis
all-reduce is done explicitly under shard_map rather than left to GSPMD.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    flat = jnp.pad(x.reshape(-1), (0, pad))
    return flat.reshape(-1, BLOCK), n


def quantize(x):
    """x -> (int8 values [..., BLOCK], fp32 scales, orig_size)."""
    blocks, n = _pad_to_block(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0], n


def dequantize(q, scale, n, shape):
    x = q.astype(jnp.float32) * scale[:, None]
    return x.reshape(-1)[:n].reshape(shape)


def quantization_error(x):
    q, s, n = quantize(x)
    return x.astype(jnp.float32) - dequantize(q, s, n, x.shape)


def compressed_psum(x, axis_name: str):
    """int8-quantized psum along `axis_name` (call inside shard_map).

    Each participant quantizes locally; int8 payloads are summed in int32
    (exact), scales are gathered and applied: sum_i (q_i * s_i) done as
    psum over already-descaled fp... To keep wire traffic int8 we psum the
    int32 *accumulation* of q and all-gather the tiny per-block scales.
    """
    q, s, n = quantize(x)
    # tiny: [n_blocks] fp32 scales per participant
    scales = jax.lax.all_gather(s, axis_name)           # [P, n_blocks]
    qs = jax.lax.all_gather(q, axis_name)               # [P, n_blocks, BLOCK]
    total = jnp.einsum("pb,pbk->bk", scales, qs.astype(jnp.float32))
    return total.reshape(-1)[:n].reshape(x.shape)


class ErrorFeedback(NamedTuple):
    residual: object  # pytree like grads


def ef_init(grads_like):
    return ErrorFeedback(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def ef_compress(ef: ErrorFeedback, grads):
    """Add residual, quantize, store new residual. Returns (q_grads, ef)."""
    corrected = jax.tree.map(
        lambda g, r: g.astype(jnp.float32) + r, grads, ef.residual)
    err = jax.tree.map(quantization_error, corrected)
    sent = jax.tree.map(lambda c, e: c - e, corrected, err)
    return sent, ErrorFeedback(residual=err)
