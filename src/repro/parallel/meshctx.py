"""Version-portable mesh activation & discovery.

jax has renamed the "make this mesh ambient" entry point three times:

  * jax >= 0.8   : ``jax.set_mesh(mesh)`` (context manager)
  * jax ~ 0.5-0.7: ``jax.sharding.use_mesh(mesh)``
  * jax 0.4.x    : ``with mesh:`` (the Mesh resource-env context manager)

and likewise for reading it back (``jax.sharding.get_abstract_mesh`` vs the
0.4.x thread-resources physical mesh). Every call site in this repo that
activates or sniffs a mesh goes through this module so the whole tree runs
unmodified across those versions (the CI container pins 0.4.x).
"""
from __future__ import annotations

import contextlib

import jax


def activate_mesh(mesh):
    """Context manager making `mesh` the ambient mesh on any jax version."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    # jax 0.4.x: Mesh is itself the resource-env context manager
    return mesh


def ambient_mesh():
    """The currently active mesh, or None. Mirrors `activate_mesh`."""
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        try:
            mesh = get_abstract()
            if mesh is not None and not mesh.empty:
                return mesh
        except Exception:
            pass
    try:  # jax 0.4.x thread-local resource env
        from jax._src import mesh as _mesh_lib
        mesh = _mesh_lib.thread_resources.env.physical_mesh
        if not mesh.empty:
            return mesh
    except Exception:
        pass
    return None


@contextlib.contextmanager
def maybe_activate(mesh):
    """`activate_mesh` that tolerates mesh=None (no-op)."""
    if mesh is None:
        yield None
    else:
        with activate_mesh(mesh) as m:
            yield m


def make_rank_mesh(num_ranks: int, axis_name: str = "ranks"):
    """1-D device mesh for a `num_ranks`-rank fleet.

    Uses the largest device count that divides `num_ranks` so every device
    carries the same number of rank shards; on a single-device host (CPU CI)
    this degenerates to a 1-device mesh and the fleet runs fully local.
    """
    n_dev = max(jax.device_count(), 1)
    ranks = max(num_ranks, 1)
    d = max(k for k in range(1, min(ranks, n_dev) + 1) if ranks % k == 0)
    return jax.make_mesh((d,), (axis_name,))
