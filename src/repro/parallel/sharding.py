"""Sharding rules: param/optimizer/cache/batch PartitionSpecs per mesh.

Conventions (divisibility-aware — falls back per dimension):
  * batch/sequence data shard over all non-'model' axes ('pod','data').
  * Megatron TP: qkv/up projections shard their output dim over 'model';
    out/down projections shard their input dim.
  * FSDP (>= ~8B params): every 2D+ weight additionally shards its largest
    remaining dim over 'data' — optimizer state inherits param specs, so
    ZeRO-3 falls out for free.
  * MoE experts shard the expert dim over 'model' when divisible (olmoe:
    64 % 16 == 0), else the expert-FF dim (qwen2: 60 experts).
  * KV pools: batch dim over ('pod','data') — pools, page tables, and
    allocator state live with their sequences (PIM-Metadata/PIM-Executed);
    KV heads over 'model' when divisible, else head_dim.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh):
    return tuple(a for a in mesh.axis_names if a != "model")


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _maybe(mesh, dim: int, axes):
    """axes if dim divisible by their product else None."""
    return axes if dim % _axsize(mesh, axes) == 0 else None


# --------------------------------------------------------------------- params
_COL = ("wq", "wk", "wv", "w1", "w3", "m1", "m3", "ws1", "ws3", "in_proj",
        "wx", "wy", "wz", "wb", "wc", "wdt", "w_r", "w_i", "xwq", "xwk",
        "xwv")   # shard LAST dim (wxi matches "wx")
_ROW = ("wo", "w2", "m2", "ws2", "out_proj", "w_out", "xwo")  # shard dim -2
_REPL = ("ln", "scale", "norm", "a_param", "a_log", "dt_bias", "d_skip",
         "conv_w", "conv_b")


def _param_spec(mesh: Mesh, name: str, shape, fsdp: bool):
    nd = len(shape)
    spec = [None] * nd
    if name.startswith(_REPL) or nd <= 1:
        return P(*spec)
    if name.startswith("embed"):
        if shape[0] % _axsize(mesh, "model") == 0:
            spec[0] = "model"
        elif shape[1] % _axsize(mesh, "model") == 0:
            spec[1] = "model"
        if fsdp:
            free = 1 if spec[0] == "model" else 0
            if spec[free] is None and shape[free] % _axsize(mesh, "data") == 0:
                spec[free] = "data"
        return P(*spec)
    if name == "head":  # [D, V]
        spec[-1] = _maybe(mesh, shape[-1], "model")
        if fsdp and spec[-1] is not None:
            spec[0] = _maybe(mesh, shape[0], "data")
        return P(*spec)
    if name in ("we1", "we3"):       # [L, E, D, Fe]
        if shape[1] % _axsize(mesh, "model") == 0:
            spec[1] = "model"
        else:
            spec[3] = _maybe(mesh, shape[3], "model")
        if fsdp:
            spec[2] = _maybe(mesh, shape[2], "data")
        return P(*spec)
    if name == "we2":                # [L, E, Fe, D]
        if shape[1] % _axsize(mesh, "model") == 0:
            spec[1] = "model"
        else:
            spec[2] = _maybe(mesh, shape[2], "model")
        if fsdp:
            spec[3] = _maybe(mesh, shape[3], "data")
        return P(*spec)
    if name == "wr":                 # [L, D, E] router
        spec[1] = _maybe(mesh, shape[1], "data") if fsdp else None
        spec[2] = _maybe(mesh, shape[2], "model")
        return P(*spec)
    if name in ("wq", "wk", "wv", "xwq", "xwk", "xwv") and nd == 4:
        # attn_4d Megatron layout [L, D, H, hd]: shard the HEAD dim over
        # 'model' when divisible, else REPLICATE. Never shard head_dim:
        # sharding the attention contraction makes GSPMD emit partial-sum
        # all-reduces of S^2-sized scores (measured regression, SSPerf IT1).
        h_s = _maybe(mesh, shape[2], "model")
        if fsdp:
            spec[1] = _maybe(mesh, shape[1], "data")
        spec[2] = h_s
        return P(*spec)
    if name in ("wo", "xwo") and nd == 4:     # [L, H, hd, D]
        h_s = _maybe(mesh, shape[1], "model")
        if fsdp:
            spec[3] = _maybe(mesh, shape[3], "data")
        spec[1] = h_s
        return P(*spec)
    if name.startswith(_COL):
        spec[-1] = _maybe(mesh, shape[-1], "model")
        if fsdp:
            spec[-2] = _maybe(mesh, shape[-2], "data")
        return P(*spec)
    if name.startswith(_ROW):
        spec[-2] = _maybe(mesh, shape[-2], "model")
        if fsdp:
            spec[-1] = _maybe(mesh, shape[-1], "data")
        return P(*spec)
    # default: try model on last dim
    spec[-1] = _maybe(mesh, shape[-1], "model")
    return P(*spec)


def param_specs(mesh: Mesh, shapes_sds, fsdp: bool = False):
    """ShapeDtypeStruct pytree -> PartitionSpec pytree (by leaf name)."""

    def walk(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        return _param_spec(mesh, name, leaf.shape, fsdp)

    return jax.tree_util.tree_map_with_path(walk, shapes_sds)


# ------------------------------------------------------------- batch & cache
def _dp_if_div(mesh: Mesh, dim: int):
    """Largest prefix of the dp axes that divides `dim` (b=1 -> replicate)."""
    dp = dp_axes(mesh)
    while dp and dim % _axsize(mesh, dp) != 0:
        dp = dp[1:]
    return dp if dp else None


def batch_specs(mesh: Mesh, batch_sds):
    return jax.tree.map(
        lambda s: P(_dp_if_div(mesh, s.shape[0]),
                    *([None] * (len(s.shape) - 1))), batch_sds)


def _kv_tail_spec(mesh, kvh: int, seq: int):
    """(KVH, seq) preference: KV heads over 'model' when divisible (fully
    local attention per head), else the sequence/page dim (sequence-parallel
    decode: GSPMD reduces the sharded-softmax to tiny stat all-reduces
    instead of gathering KV — see EXPERIMENTS.md SSPerf). Never shard
    head_dim: contraction sharding made GSPMD gather whole KV tensors."""
    if kvh % _axsize(mesh, "model") == 0:
        return "model", None
    if seq % _axsize(mesh, "model") == 0:
        return None, "model"
    return None, None


def cache_specs(mesh: Mesh, cache_sds):
    out = {}
    for name, s in cache_sds.items():
        shape = s.shape
        if name in ("k_pages", "v_pages"):   # [L, B, P, page, KVH, hd]
            dp = _dp_if_div(mesh, shape[1])
            kvh_s, seq_s = _kv_tail_spec(mesh, shape[4], shape[2])
            out[name] = P(None, dp, seq_s, None, kvh_s, None)
        elif name in ("win_k", "win_v"):     # [G, B, win, KVH, hd]
            dp = _dp_if_div(mesh, shape[1])
            kvh_s, seq_s = _kv_tail_spec(mesh, shape[3], shape[2])
            out[name] = P(None, dp, seq_s, kvh_s, None)
        elif name in ("enc_k", "enc_v"):     # [L, B, T, KVH, hd]
            dp = _dp_if_div(mesh, shape[1])
            kvh_s, seq_s = _kv_tail_spec(mesh, shape[3], shape[2])
            out[name] = P(None, dp, seq_s, kvh_s, None)
        elif name == "ssm_state":            # [L, B, H, p, N]
            dp = _dp_if_div(mesh, shape[1])
            h_s = _maybe(mesh, shape[2], "model")
            out[name] = P(None, dp, h_s, None, None)
        elif name == "conv_state":           # [L, B, W-1, C]
            dp = _dp_if_div(mesh, shape[1])
            out[name] = P(None, dp, None, _maybe(mesh, shape[3], "model"))
        elif name == "rg_state":             # [n_rec, B, D]
            dp = _dp_if_div(mesh, shape[1])
            out[name] = P(None, dp, _maybe(mesh, shape[2], "model"))
        elif name in ("page_table", "seq_lens"):
            dp = _dp_if_div(mesh, shape[0])
            out[name] = P(*([dp] + [None] * (len(shape) - 1)))
        else:
            out[name] = P(*([None] * len(shape)))
    return out


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
