"""Allocation-trace schema + recorder: real `AllocRequest` tapes.

A *tape* (schema ``pim-malloc-trace/v1``) is a fixed-shape sequence of
protocol rounds captured from a real allocation-heavy workload. Pointer
operands are stored **symbolically**: each FREE/REALLOC round carries a
``ptr_ref`` per thread — the flat slot id ``round * T + thread`` of the
round that *produced* the pointer being operated on (-1 = use the raw
recorded value, e.g. a NULL or a deliberately bogus pointer). Replay
(`repro.workloads.replay`) resolves refs against the pointers the *target*
backend actually returned, so one tape drives every `heap.REGISTRY` kind
closed-loop: ``sw``/``hwsw``/``pallas`` reproduce the recorded pointer
stream bitwise, and ``strawman`` serves the same workload shape through its
own placements.

`RecordingAllocator` is a drop-in `repro.core.api.Allocator` that observes
every `request()` round and maintains the pointer->producing-slot map, so
existing workload drivers (`graphupd.DynamicGraph`, `kvcache.PagePool`, the
hash-table workload) record themselves without cooperation.

Tapes serialize to reviewable JSON; committed smoke tapes live in
``benchmarks/tapes/`` (regenerate with ``python -m repro.workloads.record``)
and carry per-backend ``expect`` digests that CI replays against
(`workload-smoke`).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json

import numpy as np

from repro.core import api, heap

TRACE_SCHEMA = "pim-malloc-trace/v1"

# canonical dtype per AllocResponse field, in field order — digests must be
# byte-stable across platforms
_RESP_DTYPES = {
    "ptr": np.int32, "ok": np.uint8, "path": np.int32, "moved": np.uint8,
    "latency_cyc": np.float32, "backend_cyc": np.float32,
    "meta_hits": np.int32, "meta_misses": np.int32, "dram_bytes": np.int32,
}
SEMANTIC_FIELDS = ("ptr", "ok", "path", "moved")


def _canon(resp_stack, fields) -> bytes:
    out = []
    for f in fields:
        arr = np.ascontiguousarray(
            np.asarray(getattr(resp_stack, f)), _RESP_DTYPES[f])
        out.append(arr.tobytes())
    return b"".join(out)


def response_digest(resp_stack, semantic_only: bool = False) -> str:
    """sha256 over the stacked [R, T] response fields in canonical dtypes.

    ``semantic_only`` restricts to (ptr, ok, path, moved) — the
    backend-semantics fields shared by ``sw`` and ``hwsw`` (whose latency /
    cache counters legitimately differ)."""
    fields = SEMANTIC_FIELDS if semantic_only else tuple(_RESP_DTYPES)
    return hashlib.sha256(_canon(resp_stack, fields)).hexdigest()


@dataclasses.dataclass
class Trace:
    """One recorded workload tape (all arrays int32[R, T])."""

    name: str
    heap_bytes: int
    num_threads: int
    recorded_kind: str
    description: str
    op: np.ndarray
    size: np.ndarray
    ptr_ref: np.ndarray   # producing slot id (round*T + thread), -1 = raw
    ptr_raw: np.ndarray   # concrete recorded pointer (debug / raw operand)
    expect: dict = dataclasses.field(default_factory=dict)  # per-kind digests
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def rounds(self) -> int:
        return int(self.op.shape[0])

    @property
    def ops(self) -> int:
        return int((self.op != heap.OP_NOOP).sum())

    def to_json(self) -> dict:
        return {
            "schema": TRACE_SCHEMA,
            "name": self.name,
            "description": self.description,
            "heap_bytes": int(self.heap_bytes),
            "num_threads": int(self.num_threads),
            "recorded_kind": self.recorded_kind,
            "rounds": {
                "op": self.op.tolist(),
                "size": self.size.tolist(),
                "ptr_ref": self.ptr_ref.tolist(),
                "ptr_raw": self.ptr_raw.tolist(),
            },
            "expect": self.expect,
            "meta": self.meta,
        }

    @classmethod
    def from_json(cls, doc: dict) -> "Trace":
        if doc.get("schema") != TRACE_SCHEMA:
            raise ValueError(f"not a {TRACE_SCHEMA} document: "
                             f"{doc.get('schema')!r}")
        r = doc["rounds"]
        arrs = {k: np.asarray(r[k], np.int32)
                for k in ("op", "size", "ptr_ref", "ptr_raw")}
        shapes = {a.shape for a in arrs.values()}
        if len(shapes) != 1 or arrs["op"].ndim != 2:
            raise ValueError(f"malformed rounds arrays: shapes {shapes}")
        if arrs["op"].shape[1] != doc["num_threads"]:
            raise ValueError("rounds thread axis != num_threads")
        return cls(name=doc["name"], heap_bytes=doc["heap_bytes"],
                   num_threads=doc["num_threads"],
                   recorded_kind=doc["recorded_kind"],
                   description=doc.get("description", ""),
                   expect=doc.get("expect", {}), meta=doc.get("meta", {}),
                   **arrs)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "Trace":
        with open(path) as f:
            return cls.from_json(json.load(f))


def trace_lint(trace: Trace) -> list:
    """Machine-checkable well-formedness rules for a tape.

    Exports the differential fuzzer's modeled-UB exclusions (previously
    prose inside `tests/test_differential_fuzz.py`) as a reusable
    predicate, so committed tapes can never encode the pattern silently.
    Returns a list of human-readable findings (empty == clean).

    Rules:
      ops        every op code is one of the five protocol ops.
      refs       a ``ptr_ref`` names a slot of a *strictly earlier* round
                 and lies inside the tape.
      race-A     within one round, two threads must not operate on the
                 same pointer chain (duplicate ``ptr_ref``): the protocol
                 round order (malloc phase, then free phase, one metadata
                 pass) makes the outcome of racing same-chain ops
                 round-order-defined UB across backends.
      race-B     a *suspect* free-class op (raw pointer operand with no
                 producing slot: garbage or dangling) must not share a
                 round with a metadata-creating op (MALLOC / CALLOC /
                 growing REALLOC) — the create can recycle the very block
                 the suspect free names, which is the same-round
                 pointer-race class the fuzzer excludes by construction.
      epoch      no *small* ref may survive an EPOCH_RESET round: a
                 pointer produced by a request within the size classes
                 (``meta.max_size_class``, default 2048) may be
                 arena-placed on the ``arena``/``tlregion`` kinds, and a
                 reset — which applies at round *start* — invalidates it
                 wholesale. A ref in or after a reset round to a small
                 producer at or before it is therefore only well-formed on
                 *some* backends, which breaks the one-tape-every-kind
                 replay contract. Big bypass blocks live outside the arena
                 on every kind and legitimately survive resets.
    """
    errs = []
    op, size, ref = trace.op, trace.size, trace.ptr_ref
    raw = trace.ptr_raw
    R, T = op.shape
    known = (heap.OP_NOOP, heap.OP_MALLOC, heap.OP_FREE, heap.OP_REALLOC,
             heap.OP_CALLOC, heap.OP_EPOCH_RESET)
    bad_op = ~np.isin(op, known)
    for r, t in zip(*np.nonzero(bad_op)):
        errs.append(f"[lint:ops] round {r} thread {t}: unknown op code "
                    f"{int(op[r, t])}")

    has_ref = ref >= 0
    this_round_base = (np.arange(R) * T)[:, None]
    bad_ref = has_ref & ((ref >= this_round_base) | (ref >= R * T))
    for r, t in zip(*np.nonzero(bad_ref)):
        errs.append(f"[lint:refs] round {r} thread {t}: ptr_ref "
                    f"{int(ref[r, t])} does not name an earlier round's slot")

    creator = (op == heap.OP_MALLOC) | (op == heap.OP_CALLOC) | \
        ((op == heap.OP_REALLOC) & (size > 0))
    free_class = (op == heap.OP_FREE) | ((op == heap.OP_REALLOC) &
                                         (size <= 0))
    suspect = free_class & ~has_ref & (raw >= 0)
    for r in range(R):
        refs_r = ref[r][has_ref[r]]
        uniq, counts = np.unique(refs_r, return_counts=True)
        for s in uniq[counts > 1]:
            ts = [int(t) for t in np.nonzero(ref[r] == s)[0]]
            errs.append(f"[lint:race-A] round {r}: threads {ts} both operate "
                        f"on the chain produced at slot {int(s)} — "
                        "same-round pointer race (modeled UB)")
        if suspect[r].any() and creator[r].any():
            ts = [int(t) for t in np.nonzero(suspect[r])[0]]
            cs = [int(t) for t in np.nonzero(creator[r])[0]]
            errs.append(f"[lint:race-B] round {r}: suspect free-class ops on "
                        f"threads {ts} (raw pointer, no producing slot) race "
                        f"metadata-creating ops on threads {cs} — "
                        "same-round pointer race (modeled UB)")

    any_reset = (op == heap.OP_EPOCH_RESET).any(axis=1)
    if any_reset.any():
        cum = np.cumsum(any_reset)   # resets in rounds [0..r]
        max_class = int(trace.meta.get("max_size_class", 2048))
        for r, t in zip(*np.nonzero(has_ref & ~bad_ref)):
            s = int(ref[r, t])
            rs, ts = divmod(s, T)
            psize = int(size[rs, ts])
            # resets in (rs, r]: the producer's own round does not count
            # (a reset applies at round start, before that round's allocs)
            if 0 < psize <= max_class and cum[r] - cum[rs] > 0:
                errs.append(
                    f"[lint:epoch] round {r} thread {t}: ref to slot {s} "
                    f"({psize} B, produced round {rs}) crosses an epoch "
                    "reset — arena-managed pointers do not survive a reset")
    return errs


class RecordingAllocator(api.Allocator):
    """An `api.Allocator` that captures every protocol round onto a tape.

    The pointer->slot map is maintained from the observed (request,
    response) stream alone: an alloc-producing op that succeeded registers
    its result pointer under slot ``round * T + thread``; a served free
    (and a relocating realloc) retires the old pointer. A FREE/REALLOC
    operand whose pointer is not currently mapped (NULL, double free,
    garbage) records ``ptr_ref = -1`` and keeps the raw value — misuse is
    replayed verbatim on every backend.
    """

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._rounds = []          # (op, size, ptr_ref, ptr_raw) np[T]
        self._ptr_slot = {}        # live concrete ptr -> producing slot id
        self._ptr_small = {}       # live concrete ptr -> within size classes
        self._max_class = max(self.cfg.pm.size_classes)

    @property
    def recorded_rounds(self) -> int:
        return len(self._rounds)

    def request(self, req: heap.AllocRequest) -> heap.AllocResponse:
        op = np.asarray(req.op, np.int32).copy()
        size = np.asarray(req.size, np.int32).copy()
        ptr = np.asarray(req.ptr, np.int32).copy()
        if op.ndim != 1:
            raise ValueError("RecordingAllocator records single-core [T] "
                             f"rounds, got shape {op.shape}")
        # an EPOCH_RESET applies at round start: every small (possibly
        # arena-placed) pointer is retired from the map NOW, so a later op
        # through one records ptr_ref = -1 (raw misuse, replayed verbatim)
        # instead of a lint:epoch-violating cross-reset ref
        if np.any(op == heap.OP_EPOCH_RESET):
            for p in [p for p, sm in self._ptr_small.items() if sm]:
                self._ptr_slot.pop(p, None)
                self._ptr_small.pop(p, None)

        ptr_ref = np.full_like(ptr, -1)
        for t in range(op.shape[0]):
            if op[t] in (heap.OP_FREE, heap.OP_REALLOC) and ptr[t] >= 0:
                ptr_ref[t] = self._ptr_slot.get(int(ptr[t]), -1)

        resp = super().request(req)

        r = len(self._rounds)
        T = op.shape[0]
        rptr = np.asarray(resp.ptr, np.int32)
        rok = np.asarray(resp.ok, bool)
        rmoved = np.asarray(resp.moved, bool)
        for t in range(T):
            small = 0 < size[t] <= self._max_class
            if op[t] == heap.OP_FREE and rok[t]:
                self._ptr_slot.pop(int(ptr[t]), None)
                self._ptr_small.pop(int(ptr[t]), None)
            elif op[t] in (heap.OP_MALLOC, heap.OP_CALLOC) and rptr[t] >= 0:
                self._ptr_slot[int(rptr[t])] = r * T + t
                self._ptr_small[int(rptr[t])] = small
            elif op[t] == heap.OP_REALLOC:
                if size[t] <= 0 and ptr[t] >= 0 and rok[t]:
                    self._ptr_slot.pop(int(ptr[t]), None)   # realloc(p, 0)
                    self._ptr_small.pop(int(ptr[t]), None)
                elif rptr[t] >= 0:
                    if rmoved[t]:
                        self._ptr_slot.pop(int(ptr[t]), None)
                        self._ptr_small.pop(int(ptr[t]), None)
                    self._ptr_slot[int(rptr[t])] = r * T + t
                    self._ptr_small[int(rptr[t])] = small
        self._rounds.append((op, size, ptr_ref, ptr))
        return resp

    def finish(self, name: str, description: str = "", meta: dict = None,
               lint: bool = True) -> Trace:
        """Freeze the recorded rounds into a Trace (no expect digests yet —
        `repro.workloads.replay.attach_expectations` fills those).

        Runs `trace_lint` by default so a recorder can never hand out a
        tape encoding the modeled-UB same-round race; pass ``lint=False``
        only to capture a deliberately broken tape for testing."""
        op, size, ptr_ref, ptr_raw = (np.stack(x) for x in
                                      zip(*self._rounds))
        meta = dict(meta or {})
        meta.setdefault("max_size_class", self._max_class)
        trace = Trace(name=name, heap_bytes=self.cfg.heap_bytes,
                      num_threads=self.cfg.num_threads,
                      recorded_kind=self.cfg.kind, description=description,
                      op=op, size=size, ptr_ref=ptr_ref, ptr_raw=ptr_raw,
                      meta=meta)
        if lint:
            errs = trace_lint(trace)
            if errs:
                raise ValueError("recorded tape fails trace_lint:\n  "
                                 + "\n  ".join(errs))
        return trace
