"""Allocation-trace workload engine: record/replay real AllocRequest tapes.

See `repro.workloads.trace` (schema + recorder), `repro.workloads.replay`
(closed-loop replay through every `heap.REGISTRY` backend, heap-health
reports, cross-backend parity checks) and `repro.workloads.scenarios`
(the three representative workloads: graph churn, paged-KV serving,
hash-table grow-rehash). CLIs: ``python -m repro.workloads.record`` /
``python -m repro.workloads.replay``.
"""
from .trace import (RecordingAllocator, Trace, TRACE_SCHEMA,  # noqa: F401
                    response_digest)

_LAZY = {
    "replay": "repro.workloads.replay",
    "replay_all_kinds": "repro.workloads.replay",
    "check_trace": "repro.workloads.replay",
    "attach_expectations": "repro.workloads.replay",
    "SCENARIOS": "repro.workloads.scenarios",
}


def __getattr__(name):
    # lazy so `python -m repro.workloads.replay` does not re-import the
    # submodule through the package (runpy double-import warning)
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(name)
