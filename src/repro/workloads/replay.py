"""Closed-loop tape replay through any `heap.REGISTRY` backend.

    PYTHONPATH=src python -m repro.workloads.replay benchmarks/tapes/*.json \
        [--kinds all|sw,hwsw,...] [--check] [--json PATH]

Replays a recorded `Trace` as one `lax.scan` of `heap.step` over the tape:
each round's pointer operands are resolved from a *slot file* of the
pointers THIS backend returned earlier in the replay (see
`repro.workloads.trace` for the ref encoding), so the tape is a real
workload on every design point, not a transplant of foreign pointers.

Every replay emits a heap-health report: op/ok/fail counts, dropped frees
(allocator misuse can no longer vanish silently), modeled latency stats,
and the fragmentation/utilization telemetry of `repro.core.telemetry`
(live bytes, high-water mark, per-buddy-level free-block histogram,
external fragmentation, conservation residual).

``--check`` verifies the committed cross-backend contract on each tape:

  * every kind's response stream matches its committed ``expect`` digest
    bitwise (determinism across machines/runs),
  * ``pallas`` == ``hwsw`` on the full response stream (kernel parity),
  * ``sw`` == ``hwsw`` on the semantic fields (ptr/ok/path/moved — the
    metadata cache may only change latencies/counters),
  * the conservation residual is zero for every kind.

Exit code 1 on any violation — this is the CI ``workload-smoke`` step.
"""
from __future__ import annotations

import argparse
import functools
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import heap, system as sysm, telemetry
from repro.core.heap import AllocRequest
from repro.workloads.trace import Trace, response_digest, trace_lint

PARITY_PAIRS = (("pallas", "hwsw", "full"), ("sw", "hwsw", "semantic"))


def _make_cfg(trace: Trace, kind: str) -> sysm.SystemConfig:
    return sysm.SystemConfig(kind=kind, heap_bytes=trace.heap_bytes,
                             num_threads=trace.num_threads)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _replay_scan(cfg, state, op, size, ptr_ref, ptr_raw):
    """scan heap.step over the tape, resolving refs from the slot file."""
    R, T = op.shape
    slots0 = jnp.full((R * T,), -1, jnp.int32)

    def body(carry, x):
        st, slots = carry
        r, op_r, size_r, ref_r, raw_r = x
        ptr = jnp.where(ref_r >= 0,
                        slots[jnp.clip(ref_r, 0, R * T - 1)], raw_r)
        st, resp = heap.step(cfg, st, AllocRequest(op=op_r, size=size_r,
                                                   ptr=ptr))
        # a slot records the op's SURVIVING pointer: a failed relocating
        # realloc leaves the old block intact (C contract), so later refs
        # to the realloc slot must resolve to the still-live old pointer,
        # not NULL. (Recorded tapes never ref failed-realloc slots — the
        # recorder keeps the old producing slot — so this only changes
        # resolution for planner-generated sessions, e.g. FleetServe.)
        survived = ((op_r == heap.OP_REALLOC) & (size_r > 0)
                    & (resp.ptr < 0) & (ptr >= 0))
        slots = lax.dynamic_update_slice(
            slots, jnp.where(survived, ptr, resp.ptr), (r * T,))
        return (st, slots), resp

    (state, _), resps = lax.scan(
        body, (state, slots0),
        (jnp.arange(R, dtype=jnp.int32), jnp.asarray(op), jnp.asarray(size),
         jnp.asarray(ptr_ref), jnp.asarray(ptr_raw)))
    return state, resps


def replay(trace: Trace, kind: str):
    """Replay one tape on one backend.

    Returns (resps, state, report): the stacked [R, T] AllocResponse, the
    final SystemState, and the heap-health report dict.
    """
    cfg = _make_cfg(trace, kind)
    state = heap.init(cfg)
    state, resps = _replay_scan(cfg, state, trace.op, trace.size,
                                trace.ptr_ref, trace.ptr_raw)

    op = trace.op
    path = np.asarray(resps.path)
    ok = np.asarray(resps.ok)
    lat = np.asarray(resps.latency_cyc)
    is_alloc = np.isin(op, (heap.OP_MALLOC, heap.OP_CALLOC))
    is_re = op == heap.OP_REALLOC
    re_free0 = is_re & (trace.size <= 0) & (trace.ptr_raw >= 0)
    freeish = (op == heap.OP_FREE) | re_free0
    active = op != heap.OP_NOOP
    freq = cfg.dpu.freq_hz
    round_max_cyc = lat.max(axis=1) if lat.size else np.zeros((0,))
    report = {
        "name": trace.name,
        "kind": kind,
        "rounds": trace.rounds,
        "ops": int(active.sum()),
        "ok_ops": int(ok.sum()),
        "malloc_ops": int((op == heap.OP_MALLOC).sum()),
        "calloc_ops": int((op == heap.OP_CALLOC).sum()),
        "realloc_ops": int(is_re.sum()),
        "free_ops": int((op == heap.OP_FREE).sum()),
        "failed_allocs": int(((is_alloc | is_re) & active & ~ok).sum()),
        "dropped_frees": int((freeish & (path == 2)).sum()),
        "moved_reallocs": int(np.asarray(resps.moved).sum()),
        "us_per_op": float(lat[active].mean() / freq * 1e6)
        if active.any() else 0.0,
        "max_us": float(lat.max() / freq * 1e6) if lat.size else 0.0,
        "modeled_wall_us": float(round_max_cyc.sum() / freq * 1e6),
        "meta_dram_bytes": int(np.asarray(resps.dram_bytes).sum()),
        "digest_full": response_digest(resps),
        "digest_sem": response_digest(resps, semantic_only=True),
        "telemetry": telemetry.snapshot(cfg, state),
    }
    if cfg.kind != "strawman":
        report["stats_dropped_frees"] = int(state.alloc.stats.dropped_frees)
    return resps, state, report


def replay_all_kinds(trace: Trace, kinds=None) -> dict:
    """{kind: (resps, report)} over the registry (or an explicit subset)."""
    out = {}
    for kind in (kinds or heap.kinds()):
        resps, _, report = replay(trace, kind)
        out[kind] = (resps, report)
    return out


def check_trace(trace: Trace, kinds=None, results=None) -> list:
    """Verify the cross-backend contract; returns error strings.

    ``results`` reuses a prior `replay_all_kinds` output (else replays)."""
    errs = list(trace_lint(trace))
    if results is None:
        results = replay_all_kinds(trace, kinds)
    for kind, (_, rep) in results.items():
        exp = trace.expect.get(kind)
        if exp is None:
            errs.append(f"{trace.name}/{kind}: no committed expectation "
                        "(regenerate the tape)")
        else:
            for key in ("digest_full", "digest_sem"):
                if exp.get(key) != rep[key]:
                    errs.append(f"{trace.name}/{kind}: {key} mismatch "
                                f"(expected {exp.get(key)!r:.20}..., "
                                f"got {rep[key]!r:.20}...)")
            for key in ("ok_ops", "dropped_frees"):
                if exp.get(key) != rep[key]:
                    errs.append(f"{trace.name}/{kind}: {key} "
                                f"{exp.get(key)} != {rep[key]}")
            for key in ("live_bytes", "hwm_bytes"):
                if exp.get(key) != rep["telemetry"][key]:
                    errs.append(f"{trace.name}/{kind}: telemetry {key} "
                                f"{exp.get(key)} != "
                                f"{rep['telemetry'][key]}")
        if rep["telemetry"]["conservation_residual"] != 0:
            errs.append(f"{trace.name}/{kind}: conservation residual "
                        f"{rep['telemetry']['conservation_residual']}")
    for a, b, level in PARITY_PAIRS:
        if a not in results or b not in results:
            continue
        ra, rb = results[a][1], results[b][1]
        key = "digest_full" if level == "full" else "digest_sem"
        if ra[key] != rb[key]:
            errs.append(f"{trace.name}: {a} != {b} on {level} response "
                        "stream")
    return errs


def attach_expectations(trace: Trace, kinds=None) -> dict:
    """Replay on all kinds and write the expect block; returns the reports."""
    reports = {}
    trace.expect = {}
    for kind, (_, rep) in replay_all_kinds(trace, kinds).items():
        trace.expect[kind] = {
            "digest_full": rep["digest_full"],
            "digest_sem": rep["digest_sem"],
            "ok_ops": rep["ok_ops"],
            "dropped_frees": rep["dropped_frees"],
            "live_bytes": rep["telemetry"]["live_bytes"],
            "hwm_bytes": rep["telemetry"]["hwm_bytes"],
        }
        reports[kind] = rep
    return reports


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("tapes", nargs="+", help="trace JSON files")
    ap.add_argument("--kinds", default="all",
                    help="comma-separated backend subset (default: all)")
    ap.add_argument("--check", action="store_true",
                    help="verify committed digests + cross-backend parity; "
                         "exit 1 on any mismatch")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write all reports as JSON")
    args = ap.parse_args(argv)
    kinds = None if args.kinds == "all" else tuple(args.kinds.split(","))

    all_reports, failures = {}, []
    for path in args.tapes:
        trace = Trace.load(path)
        results = replay_all_kinds(trace, kinds)
        if args.check:
            errs = check_trace(trace, kinds, results=results)
            failures.extend(errs)
            status = "OK" if not errs else f"{len(errs)} MISMATCH(ES)"
            print(f"[{status}] {path}: {trace.rounds} rounds, "
                  f"{trace.ops} ops")
            for e in errs:
                print(f"  !! {e}")
        reports = {k: rep for k, (_, rep) in results.items()}
        all_reports[trace.name] = reports
        for kind, rep in reports.items():
            tel = rep["telemetry"]
            print(f"  {trace.name}/{kind}: ok={rep['ok_ops']}/{rep['ops']} "
                  f"dropped={rep['dropped_frees']} "
                  f"us/op={rep['us_per_op']:.3f} "
                  f"live={tel['live_bytes']} hwm={tel['hwm_bytes']} "
                  f"frag={tel['external_frag']:.2f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(all_reports, f, indent=1)
    if failures:
        print(f"{len(failures)} workload-replay check failure(s)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
