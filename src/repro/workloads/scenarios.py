"""The three representative PIM workload scenarios, recorded as tapes.

Each scenario rebuilds an allocation-heavy application end-to-end over the
unified Heap API and records every protocol round through a
`RecordingAllocator` (see `repro.workloads.trace`):

  * ``graph_churn``  — dynamic graph insertion/deletion
    (`repro.graphupd.DynamicGraph`): streaming edge inserts (pimMalloc of
    16 B node cells) interleaved with edge deletions (unlink + pimFree).
  * ``kv_paged``     — paged-KV serving churn (`repro.kvcache.PagePool`):
    sequence prefills reserve page extents, decode steps grow single
    pages through the thread-cache frontend, context growth reallocs
    extents, and finished sequences free everything back.
  * ``hashtable``    — open-addressing KV store
    (`repro.workloads.hashtable`): per-thread tables with pimCalloc'd
    backing arrays, per-insert value cells, and grow-rehash
    `realloc` pressure across size classes into buddy bypass range.

Scenarios are deterministic (seeded) and sized for CI smoke replay; the
committed tapes live in ``benchmarks/tapes/`` and are regenerated with
``python -m repro.workloads.record``.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.workloads.hashtable import HashTableConfig, HashTableWorkload
from repro.workloads.trace import RecordingAllocator, Trace

RECORD_KIND = "hwsw"   # the paper's winning design point records the tapes


def record_graph_churn(smoke: bool = True, kind: str = RECORD_KIND) -> Trace:
    """Dynamic graph: build a partition, then stream insert/delete rounds."""
    from repro.graphupd.workload import GraphConfig, DynamicGraph, synth_edges

    # heap must cover the 16-thread x 8-class x 4 KB prepopulation (512 KB)
    gcfg = GraphConfig(n_nodes=64, n_edges_pre=160, n_edges_new=96,
                       num_threads=16, heap_bytes=1 << 20, seed=3)
    if not smoke:
        gcfg = GraphConfig(n_nodes=192, n_edges_pre=1200, n_edges_new=600,
                           num_threads=16, heap_bytes=1 << 21, seed=3)
    rec = RecordingAllocator(heap_bytes=gcfg.heap_bytes,
                             num_threads=gcfg.num_threads, kind=kind)
    g = DynamicGraph(gcfg, client=rec)
    pre_s, pre_d, new_s, new_d = synth_edges(gcfg)
    T = gcfg.num_threads
    rng = np.random.default_rng(gcfg.seed)
    inserted = list(zip(pre_s.tolist(), pre_d.tolist()))
    for i in range(0, len(pre_s), T):
        g.insert_round(pre_s[i:i + T], pre_d[i:i + T])
    # churn: each new-edge round is followed every other round by a
    # deletion round over randomly chosen existing edges
    for i in range(0, len(new_s), T):
        g.insert_round(new_s[i:i + T], new_d[i:i + T])
        inserted.extend(zip(new_s[i:i + T].tolist(),
                            new_d[i:i + T].tolist()))
        if (i // T) % 2 == 1 and inserted:
            take = [inserted.pop(rng.integers(len(inserted)))
                    for _ in range(min(T, len(inserted)))]
            g.delete_round([u for u, _ in take], [v for _, v in take])
    return rec.finish(
        "graph_churn",
        "dynamic graph insertion/deletion over the PIM-malloc heap "
        "(loc-gowalla-style partition, paper Section 6.2 + deletions)",
        meta={"n_nodes": gcfg.n_nodes, "edges_inserted":
              int(len(pre_s) + len(new_s)), "live_edges": len(inserted)})


def record_kv_paged(smoke: bool = True, kind: str = RECORD_KIND) -> Trace:
    """Paged-KV serving churn: prefill extents, decode growth, eviction."""
    from repro.kvcache.paged import PAGE_UNIT, PagePool

    T = 16
    n_pages = 1 << 16 if smoke else 1 << 18   # heap >= 512 KB prepopulation
    steps = 24 if smoke else 96
    rec = RecordingAllocator(heap_bytes=n_pages * PAGE_UNIT,
                             num_threads=T, kind=kind)
    # a RecordingAllocator IS a HeapClient (request() override taping every
    # round) — no adapter needed since the alloc= hook was retired
    pool = PagePool(n_pages=n_pages, num_threads=T, client=rec)
    rng = np.random.default_rng(11)

    # one serving slot per thread: each holds (extent_first, extent_pages,
    # decode_pages). Prefill lengths mix frontend classes and buddy bypass.
    extent_choices = (4, 8, 16, 64, 512)   # pages; 512 pages = 8 KB bypass
    slots = []
    for t in range(T):
        n = int(rng.choice(extent_choices))
        ext = pool.alloc_pages(n, thread=t)
        assert ext.shape[0] == n
        slots.append({"first": int(ext[0]), "pages": n, "decode": []})

    for step in range(steps):
        # decode growth: ~2/3 of the sequences gain one page this round
        growing = rng.random(T) < 0.66
        pages, _ = pool.alloc_page_batch(jnp.asarray(growing))
        for t in range(T):
            p = int(pages[t])
            if growing[t] and p >= 0:
                slots[t]["decode"].append(p)
        # occasional context growth: realloc one extent to twice the pages
        if step % 6 == 3:
            t = int(rng.integers(T))
            ids, moved = pool.grow_extent(slots[t]["first"],
                                          slots[t]["pages"] * 2, thread=t)
            if ids.shape[0]:
                slots[t].update(first=int(ids[0]),
                                pages=slots[t]["pages"] * 2)
        # eviction: finished sequences free ALL decode pages then the
        # extent through the protocol (PagePool.evict — the pre-PR-8
        # recorder truncated the drain at T and leaked the tail), and a
        # fresh sequence prefills into the vacated slot
        if step % 4 == 2:
            t = int(rng.integers(T))
            pool.evict(slots[t]["first"], slots[t]["decode"], thread=t)
            n = int(rng.choice(extent_choices))
            ext = pool.alloc_pages(n, thread=t)
            slots[t] = {"first": int(ext[0]) if ext.shape[0] else -1,
                        "pages": n if ext.shape[0] else 0, "decode": []}
    return rec.finish(
        "kv_paged",
        "paged-KV serving churn: prefill extents + single-page decode "
        "growth + extent realloc + sequence eviction (PagePool)",
        meta={"n_pages": n_pages, "steps": steps})


def record_hashtable(smoke: bool = True, kind: str = RECORD_KIND) -> Trace:
    """Open-addressing KV store with grow-rehash realloc pressure."""
    cfg = HashTableConfig(num_threads=16, heap_bytes=1 << 19,
                          n_inserts=40 if smoke else 256,
                          delete_every=5, seed=7)
    rec = RecordingAllocator(heap_bytes=cfg.heap_bytes,
                             num_threads=cfg.num_threads, kind=kind)
    wl = HashTableWorkload(cfg, rec)
    stats = wl.run()
    wl.verify()
    return rec.finish(
        "hashtable",
        "open-addressing hash-table/KV-store: calloc'd tables, per-insert "
        "value cells, grow-rehash realloc across size classes",
        meta=stats)


def record_decode_serve(smoke: bool = True, kind: str = RECORD_KIND) -> Trace:
    """The busiest core's slice of a DecodeServe session (paged-KV LLM
    decode: Zipf tenants, prefill bursts, page-per-token appends,
    eviction), exported through `ScanEngine.trace` — the serving engine's
    page traffic IS a standard tape (no separate recorder)."""
    from repro.core import system as sysm
    from repro.launch.serve_decode import DecodeServe, DecodeTraffic

    T = 4 if smoke else 16
    cfg = sysm.SystemConfig(kind=kind, heap_bytes=1 << 20, num_threads=T)
    tc = DecodeTraffic(seed=29, rounds=24 if smoke else 96,
                       session_rate=1.5 if smoke else 6.0, num_tenants=8,
                       queue_cap=16)
    eng = DecodeServe(cfg, 2, 2, traffic=tc, mesh=False)
    plan = eng.plan()
    # the Zipf head tenant's home is the hottest heap in the fleet
    rank, core = plan.tenant_home.get(0, (0, 0))
    return eng.trace(plan, rank, core, name="decode_serve")


SCENARIOS = {
    "graph_churn": record_graph_churn,
    "kv_paged": record_kv_paged,
    "hashtable": record_hashtable,
    "decode_serve": record_decode_serve,
}
