"""Regenerate the committed workload tapes (benchmarks/tapes/*.json).

    PYTHONPATH=src python -m repro.workloads.record \
        [--out benchmarks/tapes] [--scenarios all] [--full]

Records each scenario on the ``hwsw`` design point, replays it on every
registered backend to fill the per-kind ``expect`` digests, and writes the
JSON tapes. Commit the refreshed tapes together with whatever allocator
change moved the digests — the CI ``workload-smoke`` step replays them
bitwise on every PR.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.workloads.replay import attach_expectations
from repro.workloads.scenarios import SCENARIOS


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))),
        "benchmarks", "tapes"))
    ap.add_argument("--scenarios", default="all")
    ap.add_argument("--full", action="store_true",
                    help="record the full-scale (non-smoke) variants")
    args = ap.parse_args(argv)
    names = (list(SCENARIOS) if args.scenarios == "all"
             else args.scenarios.split(","))
    os.makedirs(args.out, exist_ok=True)
    for name in names:
        trace = SCENARIOS[name](smoke=not args.full)
        reports = attach_expectations(trace)
        path = os.path.join(args.out, f"{name}.json")
        trace.save(path)
        ops = trace.ops
        print(f"wrote {path}: {trace.rounds} rounds / {ops} ops; "
              + "; ".join(
                  f"{k}: ok={r['ok_ops']} dropped={r['dropped_frees']} "
                  f"live={r['telemetry']['live_bytes']}"
                  for k, r in sorted(reports.items())))
    return 0


if __name__ == "__main__":
    sys.exit(main())
