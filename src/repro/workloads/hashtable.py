"""Open-addressing hash-table / KV-store workload with grow-rehash
`realloc` pressure — the third representative PIM workload.

One independent table per hardware thread (the paper's tasklet model: T
concurrent data structures on one core's heap). Each table is a linear-
probing open-addressing array of (key -> value-cell pointer) entries whose
backing store is a heap block:

  * table arrays start with `pimCalloc(capacity, ENTRY_BYTES)` (zeroed
    metadata, overflow-guarded),
  * every insert `pimMalloc`s a small value cell (mixed size classes),
  * crossing the load factor triggers `pimRealloc(table, 2x)` — a
    grow-rehash that walks the size classes up into buddy bypass range,
    exactly the class-change realloc path the allocator must get right,
  * deletes `pimFree` the value cell and tombstone the slot.

The structure is functionally real: entries live in host-side mirrors keyed
by the allocator pointers, `lookup()` probes exactly like the insert path,
and `verify()` checks every surviving key resolves to its distinct value
cell (asserted in tests/test_workloads.py).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import heap

ENTRY_BYTES = 8           # one slot: key (4B) + value ptr (4B)
VALUE_SIZES = (16, 24, 48, 96)  # value-cell payloads (mixed size classes)


@dataclasses.dataclass(frozen=True)
class HashTableConfig:
    num_threads: int = 16
    heap_bytes: int = 1 << 20
    init_capacity: int = 8        # entries; 8 * 8 B = one 64 B class
    max_load: float = 0.7         # grow-rehash threshold
    n_inserts: int = 64           # per thread
    delete_every: int = 5         # delete one live key every k-th insert
    seed: int = 0


class _Table:
    """Host-side mirror of one thread's open-addressing table."""

    def __init__(self, capacity: int):
        self.ptr = -1                  # heap pointer of the backing array
        self.capacity = capacity
        self.keys = np.zeros(capacity, np.int64)      # 0 = empty
        self.vptr = np.full(capacity, -1, np.int64)
        self.live = 0

    def _probe(self, key: int) -> int:
        i = (key * 2654435761) % self.capacity
        for _ in range(self.capacity):
            if self.keys[i] == 0 or self.keys[i] == key:
                return i
            i = (i + 1) % self.capacity
        return -1

    def insert(self, key: int, vptr: int) -> bool:
        i = self._probe(key)
        if i < 0:
            return False
        self.keys[i] = key
        self.vptr[i] = vptr
        self.live = int((self.keys != 0).sum())
        return True

    def lookup(self, key: int) -> int:
        i = self._probe(key)
        return int(self.vptr[i]) if i >= 0 and self.keys[i] == key else -1

    def delete(self, key: int) -> int:
        i = self._probe(key)
        if i < 0 or self.keys[i] != key:
            return -1
        vp = int(self.vptr[i])
        # full rehash of the cluster keeps linear probing correct
        kept = [(int(k), int(v)) for k, v in zip(self.keys, self.vptr)
                if k != 0 and k != key]
        self.keys[:] = 0
        self.vptr[:] = -1
        for k, v in kept:
            self.keys[self._probe(k)] = k
            self.vptr[self._probe(k)] = v
        self.live = len(kept)
        return vp

    def rehash_into(self, new_capacity: int, new_ptr: int) -> None:
        kept = [(int(k), int(v)) for k, v in zip(self.keys, self.vptr)
                if k != 0]
        self.capacity = new_capacity
        self.ptr = new_ptr
        self.keys = np.zeros(new_capacity, np.int64)
        self.vptr = np.full(new_capacity, -1, np.int64)
        for k, v in kept:
            self.insert(k, v)


class HashTableWorkload:
    """Drive T per-thread tables through one Allocator-style handle."""

    def __init__(self, cfg: HashTableConfig, alloc):
        assert alloc.cfg.num_threads == cfg.num_threads
        self.cfg = cfg
        self.alloc = alloc
        self.tables = [_Table(cfg.init_capacity)
                       for _ in range(cfg.num_threads)]
        self.rng = np.random.default_rng(cfg.seed)
        self.grow_rounds = 0

    def _request(self, req):
        return self.alloc.request(req)

    def init_tables(self):
        T = self.cfg.num_threads
        resp = self._request(heap.calloc_request(
            jnp.full((T,), self.cfg.init_capacity, jnp.int32),
            jnp.full((T,), ENTRY_BYTES, jnp.int32)))
        for t, tab in enumerate(self.tables):
            assert int(resp.ptr[t]) >= 0, "table calloc failed"
            tab.ptr = int(resp.ptr[t])

    def _maybe_grow(self):
        """One realloc round growing every table past the load factor."""
        need = [tab.live / tab.capacity > self.cfg.max_load
                for tab in self.tables]
        if not any(need):
            return
        new_caps = [tab.capacity * 2 if n else 0
                    for tab, n in zip(self.tables, need)]
        resp = self._request(heap.realloc_request(
            jnp.array([tab.ptr if n else -1
                       for tab, n in zip(self.tables, need)], jnp.int32),
            jnp.array([c * ENTRY_BYTES for c in new_caps], jnp.int32),
            active=jnp.array(need)))
        self.grow_rounds += 1
        for t, (tab, n) in enumerate(zip(self.tables, need)):
            if n and int(resp.ptr[t]) >= 0:
                tab.rehash_into(new_caps[t], int(resp.ptr[t]))

    def run(self) -> dict:
        """The recorded op stream; returns workload stats."""
        cfg = self.cfg
        T = cfg.num_threads
        self.init_tables()
        next_key = np.ones(T, np.int64)
        for step in range(cfg.n_inserts):
            # one value cell per thread, mixed classes
            vsizes = self.rng.choice(VALUE_SIZES, size=T)
            resp = self._request(heap.malloc_request(
                jnp.asarray(vsizes, jnp.int32)))
            for t, tab in enumerate(self.tables):
                vp = int(resp.ptr[t])
                if vp >= 0:
                    tab.insert(int(next_key[t]), vp)
                    next_key[t] += 1
            self._maybe_grow()
            if cfg.delete_every and (step + 1) % cfg.delete_every == 0:
                drops = np.full(T, -1, np.int64)
                for t, tab in enumerate(self.tables):
                    livek = tab.keys[tab.keys != 0]
                    if livek.size:
                        drops[t] = tab.delete(
                            int(self.rng.choice(livek)))
                self._request(heap.free_request(
                    jnp.asarray(drops, jnp.int32)))
        return {
            "tables": T,
            "live_keys": int(sum(t.live for t in self.tables)),
            "capacities": [t.capacity for t in self.tables],
            "grow_rounds": self.grow_rounds,
        }

    def verify(self) -> None:
        """Every surviving key resolves to a distinct live value cell."""
        seen = set()
        for tab in self.tables:
            for k, v in zip(tab.keys, tab.vptr):
                if k == 0:
                    continue
                assert v >= 0, (k, v)
                assert tab.lookup(int(k)) == int(v)
                assert v not in seen, "value cells must be distinct"
                seen.add(int(v))
        # table arrays themselves are distinct live blocks
        ptrs = [t.ptr for t in self.tables]
        assert all(p >= 0 for p in ptrs)
        assert len(set(ptrs)) == len(ptrs)
