"""Whisper-style encoder-decoder (audio family).

The conv audio frontend is a STUB per the assignment: `input_specs()` feeds
precomputed frame embeddings [B, enc_frames, D]. Encoder layers are
bidirectional self-attention; decoder layers are causal self-attention +
cross-attention + MLP. Decode uses the paged KV cache for decoder
self-attention and caches the (static) encoder K/V densely.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.kvcache import paged
from . import layers
from .config import ArchConfig


def param_shapes(cfg: ArchConfig):
    L, Le = cfg.n_layers, cfg.enc_layers
    D, V, F = cfg.d_model, cfg.padded_vocab, cfg.d_ff
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.dtype

    def attn_mats(L):
        if cfg.attn_4d:
            return {
                "wq": ((L, D, H, hd), dt), "wk": ((L, D, KVH, hd), dt),
                "wv": ((L, D, KVH, hd), dt), "wo": ((L, H, hd, D), dt),
            }
        return {
            "wq": ((L, D, H * hd), dt), "wk": ((L, D, KVH * hd), dt),
            "wv": ((L, D, KVH * hd), dt), "wo": ((L, H * hd, D), dt),
        }

    enc = {"ln1": ((Le, D), dt), "ln2": ((Le, D), dt),
           "w1": ((Le, D, F), dt), "w2": ((Le, F, D), dt)}
    enc.update({k: ((Le,) + v[0][1:], dt) for k, v in attn_mats(Le).items()})
    dec = {"ln1": ((L, D), dt), "ln_x": ((L, D), dt), "ln2": ((L, D), dt),
           "w1": ((L, D, F), dt), "w2": ((L, F, D), dt)}
    dec.update(attn_mats(L))
    dec.update({f"x{k}": v for k, v in attn_mats(L).items()})
    return {"embed": ((V, D), dt), "enc": enc, "dec": dec,
            "ln_enc": ((D,), dt), "ln_f": ((D,), dt)}


def init(cfg: ArchConfig, key):
    return layers.init_params(param_shapes(cfg), key)


def encode(cfg: ArchConfig, params, enc_embeds):
    """enc_embeds [B, T, D] (stub frontend output) -> encoder hidden."""
    B, T, D = enc_embeds.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    x = enc_embeds.astype(cfg.dtype)

    def blk(x, lp):
        h = layers.rms_norm(x, lp["ln1"])
        q = layers.qk_proj(h, lp["wq"], H, hd)
        k = layers.qk_proj(h, lp["wk"], KVH, hd)
        v = layers.qk_proj(h, lp["wv"], KVH, hd)
        q = layers.rope(q, positions, cfg.rope_theta)
        k = layers.rope(k, positions, cfg.rope_theta)
        o = layers.attention(q, k, v, causal=False)
        x = x + layers.out_proj(o, lp["wo"]).astype(x.dtype)
        h2 = layers.rms_norm(x, lp["ln2"])
        return x + layers.mlp(h2, lp["w1"], lp["w2"], None, "gelu")

    if cfg.remat:
        blk = jax.checkpoint(blk)
    x, _ = lax.scan(lambda x, lp: (blk(x, lp), None), x, params["enc"])
    return layers.rms_norm(x, params["ln_enc"])


def _dec_block(cfg, x, positions, enc_out, lp):
    B, S, D = x.shape
    T = enc_out.shape[1]
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    # causal self-attention
    h = layers.rms_norm(x, lp["ln1"])
    q = layers.qk_proj(h, lp["wq"], H, hd)
    k = layers.qk_proj(h, lp["wk"], KVH, hd)
    v = layers.qk_proj(h, lp["wv"], KVH, hd)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)
    attn = layers.pick_attention(S, S, cfg.flash_min_seq)
    o = attn(q, k, v, causal=True)
    x = x + layers.out_proj(o, lp["wo"]).astype(x.dtype)
    # cross-attention
    hx = layers.rms_norm(x, lp["ln_x"])
    qx = layers.qk_proj(hx, lp["xwq"], H, hd)
    kx = layers.qk_proj(enc_out, lp["xwk"], KVH, hd)
    vx = layers.qk_proj(enc_out, lp["xwv"], KVH, hd)
    xattn = layers.pick_attention(S, T, cfg.flash_min_seq)
    ox = xattn(qx, kx, vx, causal=False)
    x = x + layers.out_proj(ox, lp["xwo"]).astype(x.dtype)
    h2 = layers.rms_norm(x, lp["ln2"])
    return x + layers.mlp(h2, lp["w1"], lp["w2"], None, "gelu")


def forward(cfg: ArchConfig, params, tokens, enc_embeds):
    B, S = tokens.shape
    enc_out = encode(cfg, params, enc_embeds)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = params["embed"][tokens].astype(cfg.dtype)
    blk = functools.partial(_dec_block, cfg)
    if cfg.remat:
        blk = jax.checkpoint(blk)
    x, _ = lax.scan(lambda x, lp: (blk(x, positions, enc_out, lp), None),
                    x, params["dec"])
    return layers.rms_norm(x, params["ln_f"])


def logits_fn(cfg, params, hidden):
    return layers.mask_padded_logits(
        hidden @ params["embed"].T.astype(hidden.dtype), cfg.vocab)  # tied


def loss(cfg: ArchConfig, params, batch):
    hidden = forward(cfg, params, batch["tokens"], batch["enc_embeds"])
    logits = logits_fn(cfg, params, hidden)
    l = layers.cross_entropy(logits, batch["labels"])
    return l, {"loss": l}


# ----------------------------------------------------------------- serving --
def cache_spec(cfg: ArchConfig, batch: int, max_seq: int):
    spec = paged.cache_spec(
        n_layers=cfg.n_layers, batch=batch, max_seq=max_seq,
        page_size=cfg.page_size, kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        dtype=cfg.dtype,
    )
    sds = jax.ShapeDtypeStruct
    dt = jnp.dtype(cfg.dtype)
    L, T = cfg.n_layers, cfg.enc_frames
    KVH, hd = cfg.n_kv_heads, cfg.head_dim
    spec["enc_k"] = sds((L, batch, T, KVH, hd), dt)
    spec["enc_v"] = sds((L, batch, T, KVH, hd), dt)
    return spec


def prefill(cfg: ArchConfig, params, batch, cache):
    """Encode audio, precompute per-layer cross K/V, prefill decoder."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    enc_out = encode(cfg, params, batch["enc_embeds"])
    T = enc_out.shape[1]
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = params["embed"][tokens].astype(cfg.dtype)

    def step(x, xs):
        lp, k_pages, v_pages = xs
        h = layers.rms_norm(x, lp["ln1"])
        q = layers.qk_proj(h, lp["wq"], H, hd)
        k = layers.qk_proj(h, lp["wk"], KVH, hd)
        v = layers.qk_proj(h, lp["wv"], KVH, hd)
        q = layers.rope(q, positions, cfg.rope_theta)
        k = layers.rope(k, positions, cfg.rope_theta)
        attn = layers.pick_attention(S, S, cfg.flash_min_seq)
        o = attn(q, k, v, causal=True)
        x = x + layers.out_proj(o, lp["wo"]).astype(x.dtype)
        hx = layers.rms_norm(x, lp["ln_x"])
        qx = layers.qk_proj(hx, lp["xwq"], H, hd)
        kx = layers.qk_proj(enc_out, lp["xwk"], KVH, hd)
        vx = layers.qk_proj(enc_out, lp["xwv"], KVH, hd)
        xattn = layers.pick_attention(S, T, cfg.flash_min_seq)
        ox = xattn(qx, kx, vx, causal=False)
        x = x + layers.out_proj(ox, lp["xwo"]).astype(x.dtype)
        h2 = layers.rms_norm(x, lp["ln2"])
        x = x + layers.mlp(h2, lp["w1"], lp["w2"], None, "gelu")
        k_pages = paged.write_prefill(k_pages, k, cache["page_table"])
        v_pages = paged.write_prefill(v_pages, v, cache["page_table"])
        return x, (k_pages, v_pages, kx, vx)

    x, (k_pages, v_pages, enc_k, enc_v) = lax.scan(
        step, x, (params["dec"], cache["k_pages"], cache["v_pages"]))
    x = layers.rms_norm(x, params["ln_f"])
    logits = logits_fn(cfg, params, x[:, -1])
    cache = dict(cache, k_pages=k_pages, v_pages=v_pages, enc_k=enc_k,
                 enc_v=enc_v, seq_lens=jnp.full((B,), S, jnp.int32))
    return cache, logits


def decode(cfg: ArchConfig, params, cache, batch):
    tokens = batch["tokens"]
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pos = cache["seq_lens"]
    x = params["embed"][tokens[:, 0]].astype(cfg.dtype)[:, None, :]

    def step(x, xs):
        lp, k_pages, v_pages, enc_k, enc_v = xs
        h = layers.rms_norm(x, lp["ln1"])
        q = layers.qk_proj(h, lp["wq"], H, hd)[:, 0]
        k = layers.qk_proj(h, lp["wk"], KVH, hd)[:, 0]
        v = layers.qk_proj(h, lp["wv"], KVH, hd)[:, 0]
        q = layers.rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        k = layers.rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        if cfg.kv_seq_parallel:
            o, k_pages, v_pages = paged.write_attend_seqpar(
                q, k, v, k_pages, v_pages, cache["page_table"], pos)
        else:
            k_pages = paged.write_token(k_pages, k, cache["page_table"], pos)
            v_pages = paged.write_token(v_pages, v, cache["page_table"], pos)
            o = paged.attend(q, k_pages, v_pages, cache["page_table"],
                             pos + 1, impl=cfg.attend_impl)
        x = x + layers.out_proj(o[:, None], lp["wo"]).astype(x.dtype)
        hx = layers.rms_norm(x, lp["ln_x"])
        qx = layers.qk_proj(hx, lp["xwq"], H, hd)
        ox = layers.attention(qx, enc_k, enc_v, causal=False)
        x = x + layers.out_proj(ox, lp["xwo"]).astype(x.dtype)
        h2 = layers.rms_norm(x, lp["ln2"])
        x = x + layers.mlp(h2, lp["w1"], lp["w2"], None, "gelu")
        return x, (k_pages, v_pages)

    x, (k_pages, v_pages) = lax.scan(
        step, x, (params["dec"], cache["k_pages"], cache["v_pages"],
                  cache["enc_k"], cache["enc_v"]))
    x = layers.rms_norm(x, params["ln_f"])
    logits = logits_fn(cfg, params, x[:, 0])
    cache = dict(cache, k_pages=k_pages, v_pages=v_pages, seq_lens=pos + 1)
    return cache, logits
