"""Family dispatch + per-(arch x shape) input specs for train/prefill/decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import encdec, hybrid, moe, ssm, transformer, vlm
from .config import ArchConfig, ShapeConfig

FAMILY_MODULES = {
    "dense": transformer,
    "ssm": ssm,
    "hybrid": hybrid,
    "audio": encdec,
    "moe": moe,
    "vlm": vlm,
}


def get_module(cfg: ArchConfig):
    return FAMILY_MODULES[cfg.family]


def init(cfg: ArchConfig, key):
    return get_module(cfg).init(cfg, key)


def param_sds(cfg: ArchConfig):
    """ShapeDtypeStruct pytree of the parameters (no allocation; dry-run)."""
    from . import layers
    return layers.param_specs_as_sds(get_module(cfg).param_shapes(cfg))


def loss_fn(cfg: ArchConfig):
    mod = get_module(cfg)
    return lambda params, batch: mod.loss(cfg, params, batch)


# --------------------------------------------------------------- input specs
def _text_len(cfg: ArchConfig, seq_len: int) -> int:
    """VLM text length excludes the patch prefix (total positions = seq_len)."""
    if cfg.family == "vlm":
        return seq_len - cfg.n_patches
    return seq_len


def train_specs(cfg: ArchConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for one global training batch."""
    B, S = shape.global_batch, _text_len(cfg, shape.seq_len)
    sds = jax.ShapeDtypeStruct
    spec = {"tokens": sds((B, S), jnp.int32), "labels": sds((B, S), jnp.int32)}
    if cfg.family == "audio":
        spec["enc_embeds"] = sds((B, cfg.enc_frames, cfg.d_model),
                                 jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        spec["patch_embeds"] = sds((B, cfg.n_patches, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
    return spec


def prefill_specs(cfg: ArchConfig, shape: ShapeConfig):
    """(batch_spec, cache_spec) for a prefill step over the full seq_len."""
    B, S = shape.global_batch, _text_len(cfg, shape.seq_len)
    sds = jax.ShapeDtypeStruct
    batch = {"tokens": sds((B, S), jnp.int32)}
    if cfg.family == "audio":
        batch["enc_embeds"] = sds((B, cfg.enc_frames, cfg.d_model),
                                  jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        batch["patch_embeds"] = sds((B, cfg.n_patches, cfg.d_model),
                                    jnp.dtype(cfg.dtype))
    cache = get_module(cfg).cache_spec(cfg, B, shape.seq_len)
    return batch, cache


def decode_specs(cfg: ArchConfig, shape: ShapeConfig):
    """(batch_spec, cache_spec) for one decode step with a seq_len-deep cache."""
    B = shape.global_batch
    sds = jax.ShapeDtypeStruct
    batch = {"tokens": sds((B, 1), jnp.int32)}
    cache = get_module(cfg).cache_spec(cfg, B, shape.seq_len)
    return batch, cache


def make_train_batch(cfg: ArchConfig, shape: ShapeConfig, key,
                     global_batch: int | None = None):
    """Materialized synthetic batch (smoke tests / examples)."""
    B = global_batch or shape.global_batch
    S = _text_len(cfg, shape.seq_len)
    ks = jax.random.split(key, 3)
    toks = jax.random.randint(ks[0], (B, S), 0, cfg.vocab, jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "audio":
        batch["enc_embeds"] = jax.random.normal(
            ks[1], (B, cfg.enc_frames, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            ks[1], (B, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch
