"""Mixture-of-Experts LM family (olmoe 64e/top-8, qwen2-moe 60e/top-4 + shared).

Token-choice top-k routing with capacity-bounded, *gather-based* dispatch:
tokens are scattered into per-expert slot tables (int32 indices), experts run
as one batched [E, C, D] x [E, D, F] einsum, and results gather back — no
[tokens, E, C] one-hot dispatch tensors, so dispatch costs memory bandwidth
rather than MXU flops. Dispatch runs in groups of `moe_group` tokens
(scan-bounded memory). Expert weights shard over 'model' on the expert dim
when divisible (olmoe: 64 % 16 == 0 -> true expert parallelism; qwen2's 60
experts are not divisible, so its expert FF dim shards instead — see
parallel/sharding.py), and GSPMD derives the token all-to-alls.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.kvcache import paged
from . import layers
from .config import ArchConfig


def capacity(cfg: ArchConfig) -> int:
    c = math.ceil(cfg.moe_group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8 * math.ceil(c / 8), 8)


def param_shapes(cfg: ArchConfig):
    L, D, V = cfg.n_layers, cfg.d_model, cfg.padded_vocab
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    E, Fe = cfg.padded_experts, cfg.expert_d_ff  # dummies never routed
    dt = cfg.dtype
    blocks = {
        "ln1": ((L, D), dt),
        "ln2": ((L, D), dt),
        "wq": ((L, D, H, hd) if cfg.attn_4d else (L, D, H * hd), dt),
        "wk": ((L, D, KVH, hd) if cfg.attn_4d else (L, D, KVH * hd), dt),
        "wv": ((L, D, KVH, hd) if cfg.attn_4d else (L, D, KVH * hd), dt),
        "wo": ((L, H, hd, D) if cfg.attn_4d else (L, H * hd, D), dt),
        "wr": ((L, D, E), "float32"),       # router in fp32
        "we1": ((L, E, D, Fe), dt),
        "we2": ((L, E, Fe, D), dt),
        "we3": ((L, E, D, Fe), dt),
    }
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * Fe
        blocks.update({
            "ws1": ((L, D, Fs), dt),
            "ws2": ((L, Fs, D), dt),
            "ws3": ((L, D, Fs), dt),
        })
    shapes = {"embed": ((V, D), dt), "blocks": blocks, "ln_f": ((D,), dt)}
    if not cfg.tie_embeddings:
        shapes["head"] = ((D, V), dt)
    return shapes


def init(cfg: ArchConfig, key):
    return layers.init_params(param_shapes(cfg), key)


def _moe_mlp(cfg: ArchConfig, h, lp):
    """h [B, S, D] -> [B, S, D] routed through capacity-bounded experts."""
    B, S, D = h.shape
    E, K = cfg.padded_experts, cfg.top_k
    N = B * S
    # adapt the dispatch group to the actual token count (decode steps have
    # ~B tokens; padding them to a full training group wastes memory 16x)
    Gs = min(cfg.moe_group, max(8 * ((N + 7) // 8), 8))
    C = max(8 * -(-int(Gs * K * cfg.capacity_factor / E) // 8), 8)
    x = h.reshape(N, D)
    # scan over a sharded dim serializes under GSPMD (measured: it
    # all-gathered every group, SSPerf IT-B3). Process `m` groups per scan
    # step with vmap so the group dim stays data-sharded; scan only the
    # (unsharded) outer iteration dim.
    m = max(min(cfg.moe_parallel_groups, -(-N // Gs)), 1)
    pad = (-N) % (Gs * m)
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    n_iter = x.shape[0] // (Gs * m)
    # m must be the OUTER (contiguous-major) dim so the data sharding of the
    # token stream lands on it; scan then runs over the unsharded n_iter
    xg = jnp.moveaxis(x.reshape(m, n_iter, Gs, D), 1, 0)

    def _one_group(xg1):
        logits = (xg1 @ lp["wr"].astype(xg1.dtype)).astype(jnp.float32)
        if E != cfg.n_experts:  # mask padded (dummy) experts off the router
            logits = jnp.where(jnp.arange(E) < cfg.n_experts, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)              # [Gs, E]
        gates, idx = lax.top_k(probs, K)                     # [Gs, K]
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        # position of each (token, k) inside its expert (token-major order)
        oh = jax.nn.one_hot(idx, E, dtype=jnp.int32)         # [Gs, K, E]
        ohf = oh.reshape(Gs * K, E)
        pos_excl = jnp.cumsum(ohf, axis=0) - ohf
        pos = jnp.sum(pos_excl * ohf, axis=-1)               # [Gs*K]
        keep = (pos < C) & (ohf.sum(-1) > 0)
        # slot tables: token id per (expert, slot); -1 = empty
        e_flat = idx.reshape(-1)
        tok_flat = jnp.repeat(jnp.arange(Gs, dtype=jnp.int32), K)
        slot_tok = jnp.full((E, C), -1, jnp.int32)
        slot_tok = slot_tok.at[
            jnp.where(keep, e_flat, E),   # out-of-bounds -> dropped
            jnp.where(keep, pos, C),
        ].set(tok_flat, mode="drop")
        # gather tokens -> [E, C, D], run experts, gather back
        x_e = xg1[jnp.maximum(slot_tok, 0)]
        x_e = jnp.where((slot_tok >= 0)[..., None], x_e, 0)
        h1 = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_e, lp["we1"],
                                    preferred_element_type=jnp.float32))
        h3 = jnp.einsum("ecd,edf->ecf", x_e, lp["we3"],
                        preferred_element_type=jnp.float32)
        y_e = jnp.einsum("ecf,efd->ecd", (h1 * h3).astype(x_e.dtype), lp["we2"],
                         preferred_element_type=jnp.float32).astype(x_e.dtype)
        # combine: y[g] = sum_k gate_k * y_e[idx_k, pos_k]
        pos_k = pos.reshape(Gs, K)
        keep_k = keep.reshape(Gs, K)
        picked = y_e[idx, jnp.minimum(pos_k, C - 1)]          # [Gs, K, D]
        w = jnp.where(keep_k, gates, 0.0).astype(picked.dtype)
        return jnp.einsum("gkd,gk->gd", picked, w)

    def per_iter(_, xgm):  # xgm [m, Gs, D], m groups in parallel (sharded)
        return None, jax.vmap(_one_group)(xgm)

    _, yg = lax.scan(per_iter, None, xg)
    y = yg.reshape(-1, D)[:N].reshape(B, S, D)
    if cfg.n_shared_experts:
        y = y + layers.mlp(h, lp["ws1"], lp["ws2"], lp["ws3"], "swiglu")
    return y.astype(h.dtype)


def _block(cfg: ArchConfig, x, positions, lp):
    B, S, D = x.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = layers.rms_norm(x, lp["ln1"])
    q = layers.qk_proj(h, lp["wq"], H, hd)
    k = layers.qk_proj(h, lp["wk"], KVH, hd)
    v = layers.qk_proj(h, lp["wv"], KVH, hd)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)
    if cfg.gqa_expand and KVH != H:
        k = jnp.repeat(k, H // KVH, axis=2)
        v = jnp.repeat(v, H // KVH, axis=2)
    attn = layers.pick_attention(S, S, cfg.flash_min_seq)
    o = attn(q, k, v, causal=True)
    x = x + layers.out_proj(o, lp["wo"]).astype(x.dtype)
    h2 = layers.rms_norm(x, lp["ln2"])
    return x + _moe_mlp(cfg, h2, lp)


def forward(cfg: ArchConfig, params, tokens, positions=None):
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = params["embed"][tokens].astype(cfg.dtype)
    blk = functools.partial(_block, cfg)
    if cfg.remat:
        blk = jax.checkpoint(blk)

    def step(x, lp):
        x = layers.activation_constraint(x, seq_over_model=cfg.seq_shard)
        return blk(x, positions, lp), None

    x, _ = lax.scan(step, x, params["blocks"])
    return layers.rms_norm(x, params["ln_f"])


def logits_fn(cfg, params, hidden):
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return layers.mask_padded_logits(hidden @ head.astype(hidden.dtype),
                                     cfg.vocab)


def loss(cfg: ArchConfig, params, batch):
    hidden = forward(cfg, params, batch["tokens"])
    logits = logits_fn(cfg, params, hidden)
    l = layers.cross_entropy(logits, batch["labels"])
    return l, {"loss": l}


# ----------------------------------------------------------------- serving --
def cache_spec(cfg: ArchConfig, batch: int, max_seq: int):
    return paged.cache_spec(
        n_layers=cfg.n_layers, batch=batch, max_seq=max_seq,
        page_size=cfg.page_size, kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        dtype=cfg.dtype,
    )


def prefill(cfg: ArchConfig, params, batch, cache):
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = params["embed"][tokens].astype(cfg.dtype)
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    def step(x, xs):
        lp, k_pages, v_pages = xs
        h = layers.rms_norm(x, lp["ln1"])
        q = layers.qk_proj(h, lp["wq"], H, hd)
        k = layers.qk_proj(h, lp["wk"], KVH, hd)
        v = layers.qk_proj(h, lp["wv"], KVH, hd)
        q = layers.rope(q, positions, cfg.rope_theta)
        k = layers.rope(k, positions, cfg.rope_theta)
        attn = layers.pick_attention(S, S, cfg.flash_min_seq)
        o = attn(q, k, v, causal=True)
        x = x + layers.out_proj(o, lp["wo"]).astype(x.dtype)
        h2 = layers.rms_norm(x, lp["ln2"])
        x = x + _moe_mlp(cfg, h2, lp)
        k_pages = paged.write_prefill(k_pages, k, cache["page_table"])
        v_pages = paged.write_prefill(v_pages, v, cache["page_table"])
        return x, (k_pages, v_pages)

    x, (k_pages, v_pages) = lax.scan(
        step, x, (params["blocks"], cache["k_pages"], cache["v_pages"]))
    x = layers.rms_norm(x, params["ln_f"])
    logits = logits_fn(cfg, params, x[:, -1])
    cache = dict(cache, k_pages=k_pages, v_pages=v_pages,
                 seq_lens=jnp.full((B,), S, jnp.int32))
    return cache, logits


def decode(cfg: ArchConfig, params, cache, batch):
    tokens = batch["tokens"]
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pos = cache["seq_lens"]
    x = params["embed"][tokens[:, 0]].astype(cfg.dtype)[:, None, :]

    def step(x, xs):
        lp, k_pages, v_pages = xs
        h = layers.rms_norm(x, lp["ln1"])
        q = layers.qk_proj(h, lp["wq"], H, hd)[:, 0]
        k = layers.qk_proj(h, lp["wk"], KVH, hd)[:, 0]
        v = layers.qk_proj(h, lp["wv"], KVH, hd)[:, 0]
        q = layers.rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        k = layers.rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        if cfg.kv_seq_parallel:
            o, k_pages, v_pages = paged.write_attend_seqpar(
                q, k, v, k_pages, v_pages, cache["page_table"], pos)
        else:
            k_pages = paged.write_token(k_pages, k, cache["page_table"], pos)
            v_pages = paged.write_token(v_pages, v, cache["page_table"], pos)
            o = paged.attend(q, k_pages, v_pages, cache["page_table"],
                             pos + 1, impl=cfg.attend_impl)
        x = x + layers.out_proj(o[:, None], lp["wo"]).astype(x.dtype)
        h2 = layers.rms_norm(x, lp["ln2"])
        x = x + _moe_mlp(cfg, h2, lp)
        return x, (k_pages, v_pages)

    x, (k_pages, v_pages) = lax.scan(
        step, x, (params["blocks"], cache["k_pages"], cache["v_pages"]))
    x = layers.rms_norm(x, params["ln_f"])
    logits = logits_fn(cfg, params, x[:, 0])
    cache = dict(cache, k_pages=k_pages, v_pages=v_pages, seq_lens=pos + 1)
    return cache, logits
