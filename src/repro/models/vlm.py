"""PaliGemma-style VLM: SigLIP patch-embedding STUB + gemma decoder (MQA).

Per the assignment the modality frontend is a stub: `input_specs()` supplies
precomputed patch embeddings [B, n_patches, D] which are prepended to the
text embeddings; the backbone is the dense transformer (kv=1 MQA, GeGLU).
Deviation noted in DESIGN.md: attention is fully causal (PaliGemma uses
bidirectional attention over the image+prompt prefix).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kvcache import paged
from . import layers, transformer
from .config import ArchConfig

param_shapes = transformer.param_shapes
init = transformer.init
logits_fn = transformer.logits_fn
cache_spec = transformer.cache_spec


def forward(cfg: ArchConfig, params, tokens, patch_embeds):
    """tokens [B, S_text]; patch_embeds [B, n_patches, D] -> hidden (full seq)."""
    B, S = tokens.shape
    P = patch_embeds.shape[1]
    x_txt = params["embed"][tokens].astype(cfg.dtype)
    x = jnp.concatenate([patch_embeds.astype(cfg.dtype), x_txt], axis=1)
    positions = jnp.broadcast_to(jnp.arange(P + S), (B, P + S))
    return transformer.forward_embeds(cfg, params, x, positions)


def loss(cfg: ArchConfig, params, batch):
    tokens, labels = batch["tokens"], batch["labels"]
    P = batch["patch_embeds"].shape[1]
    hidden = forward(cfg, params, tokens, batch["patch_embeds"])
    # text token s sits at position P + s; logits at P + s - 1 predict it
    S = tokens.shape[1]
    hs = hidden[:, P - 1: P + S - 1]
    logits = logits_fn(cfg, params, hs)
    l = layers.cross_entropy(logits, labels)
    return l, {"loss": l}


def prefill(cfg: ArchConfig, params, batch, cache):
    """Image + prompt prefill. The patch prefix occupies the first pages."""
    tokens = batch["tokens"]
    patch_embeds = batch["patch_embeds"]
    B, S = tokens.shape
    P = patch_embeds.shape[1]
    assert (P + S) % cfg.page_size == 0, (P, S, cfg.page_size)
    x_txt = params["embed"][tokens].astype(cfg.dtype)
    x = jnp.concatenate([patch_embeds.astype(cfg.dtype), x_txt], axis=1)
    positions = jnp.broadcast_to(jnp.arange(P + S), (B, P + S))

    import functools
    from jax import lax
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    Sfull = P + S

    def step(x, xs):
        lp, k_pages, v_pages = xs
        h = layers.rms_norm(x, lp["ln1"])
        q = layers.qk_proj(h, lp["wq"], H, hd)
        k = layers.qk_proj(h, lp["wk"], KVH, hd)
        v = layers.qk_proj(h, lp["wv"], KVH, hd)
        q = layers.rope(q, positions, cfg.rope_theta)
        k = layers.rope(k, positions, cfg.rope_theta)
        attn = layers.pick_attention(Sfull, Sfull, cfg.flash_min_seq)
        o = attn(q, k, v, causal=True)
        x = x + layers.out_proj(o, lp["wo"]).astype(x.dtype)
        h2 = layers.rms_norm(x, lp["ln2"])
        x = x + layers.mlp(h2, lp["w1"], lp["w2"], lp.get("w3"), cfg.mlp)
        k_pages = paged.write_prefill(k_pages, k, cache["page_table"])
        v_pages = paged.write_prefill(v_pages, v, cache["page_table"])
        return x, (k_pages, v_pages)

    x, (k_pages, v_pages) = lax.scan(
        step, x, (params["blocks"], cache["k_pages"], cache["v_pages"]))
    x = layers.rms_norm(x, params["ln_f"])
    logits = logits_fn(cfg, params, x[:, -1])
    cache = dict(cache, k_pages=k_pages, v_pages=v_pages,
                 seq_lens=jnp.full((B,), Sfull, jnp.int32))
    return cache, logits


decode = transformer.decode  # post-prefill decode is identical to dense
