"""Shared model layers: norms, RoPE, (flash/GQA/local) attention, MLPs.

All parameters are *stacked over layers* (leading L dim) so models scan over
layers — small HLO, fast multi-device compiles, and remat-friendly.
Matmuls run in the config dtype with fp32 accumulation.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta: float = 10_000.0):
    """x: [..., S, H, D]; positions: [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def _gqa_scores_einsum(q, k):
    """q: [B,S,KVH,G,D], k: [B,T,KVH,D] -> [B,KVH,G,S,T] fp32."""
    return jnp.einsum("bskgd,btkd->bkgst", q, k,
                      preferred_element_type=jnp.float32)


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              q_offset: int = 0):
    """Materializing GQA attention (use for short sequences).

    q: [B, S, H, D]; k, v: [B, T, KVH, D]. Returns [B, S, H, D].
    window > 0 -> local (sliding-window) attention.
    """
    B, S, H, D = q.shape
    T, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    qh = q.reshape(B, S, KVH, G, D)
    s = _gqa_scores_einsum(qh, k) / (D ** 0.5)
    qpos = jnp.arange(S) + q_offset
    kpos = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, S, H, D).astype(q.dtype)


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (prefers big blocks)."""
    d = min(n, target)
    while n % d:
        d -= 1
    return d


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 1024, block_kv: int = 1024):
    """Chunked (flash-style) attention in pure JAX: O(S*block) memory.

    Same signature/semantics as `attention`; used for long sequences where
    the S x T score matrix must never materialize. Online softmax over KV
    blocks via lax.scan; query blocks via lax.map.
    """
    B, S, H, D = q.shape
    T, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    block_q = _pick_block(S, block_q)
    block_kv = _pick_block(T, block_kv)
    nq, nk = S // block_q, T // block_kv
    qh = q.reshape(B, nq, block_q, KVH, G, D)
    kb = k.reshape(B, nk, block_kv, KVH, D)
    vb = v.reshape(B, nk, block_kv, KVH, D)
    scale = 1.0 / (D ** 0.5)

    def q_block(iq):
        qi = qh[:, iq]  # [B, bq, KVH, G, D]
        qpos = iq * block_q + jnp.arange(block_q)

        def kv_step(carry, ik):
            m, l, acc = carry
            ki, vi = kb[:, ik], vb[:, ik]
            s = jnp.einsum("bskgd,btkd->bkgst", qi, ki,
                           preferred_element_type=jnp.float32) * scale
            kpos = ik * block_kv + jnp.arange(block_kv)
            mask = jnp.ones((block_q, block_kv), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window:
                mask &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_cur = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m, m_cur)
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgst,btkd->bkgsd", p.astype(qi.dtype), vi,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KVH, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, KVH, G, block_q, D), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0),
                                  jnp.arange(nk, dtype=jnp.int32))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return o  # [B, KVH, G, bq, D]

    o = lax.map(q_block, jnp.arange(nq, dtype=jnp.int32))  # [nq, B, KVH, G, bq, D]
    o = jnp.moveaxis(o, 0, 1)  # [B, nq, KVH, G, bq, D]
    o = jnp.transpose(o, (0, 1, 4, 2, 3, 5)).reshape(B, S, H, D)
    return o.astype(q.dtype)


def pick_attention(S: int, T: int, min_seq: int = 8193):
    """Materializing attention below `min_seq` tokens, chunked flash above.

    Baseline keeps dense attention at train lengths (<= 8K); the §Perf
    hillclimb lowers `ArchConfig.flash_min_seq` to kill the S^2 buffers."""
    return attention if max(S, T) < min_seq else flash_attention


def qk_proj(h, w, H: int, hd: int):
    """Attention projection for both weight layouts.

    w 2D [D, H*hd] (flat baseline) or 3D [D, H, hd] (`attn_4d`: Megatron
    layout — head/head_dim sharding survives because there is no reshape
    across the shard boundary)."""
    if w.ndim == 2:
        return (h @ w).reshape(*h.shape[:-1], H, hd)
    return jnp.einsum("...d,dhk->...hk", h, w,
                      preferred_element_type=jnp.float32).astype(h.dtype)


def out_proj(o, w):
    """o [..., H, hd] x wo ([H*hd, D] flat | [H, hd, D] attn_4d) -> [..., D]."""
    if w.ndim == 2:
        return o.reshape(*o.shape[:-2], -1) @ w
    return jnp.einsum("...hk,hkd->...d", o, w,
                      preferred_element_type=jnp.float32).astype(o.dtype)


def mlp(x, w1, w2, w3, kind: str):
    """w1: [D,F] (gate/in), w2: [F,D] (out), w3: [D,F] (up; swiglu/geglu only)."""
    dt = x.dtype
    if kind == "swiglu":
        h = jax.nn.silu(x @ w1) * (x @ w3)
    elif kind == "geglu":
        h = jax.nn.gelu(x @ w1) * (x @ w3)
    elif kind == "squared_relu":
        h = jnp.square(jax.nn.relu(x @ w1))
    elif kind == "gelu":
        h = jax.nn.gelu(x @ w1)
    else:
        raise ValueError(kind)
    return (h.astype(dt) @ w2).astype(dt)


def mlp_n_mats(kind: str) -> int:
    return 3 if kind in ("swiglu", "geglu") else 2


def mask_padded_logits(logits, vocab: int):
    """Vocab is padded (Megatron-style) for clean TP; mask the pad columns."""
    vp = logits.shape[-1]
    if vp == vocab:
        return logits
    col = jnp.arange(vp) < vocab
    return jnp.where(col, logits, NEG_INF)


def cross_entropy(logits, labels, ignore: int = -100):
    """Mean token cross-entropy in fp32; `ignore` labels are masked."""
    logits = logits.astype(jnp.float32)
    valid = labels != ignore
    lbl = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lbl[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, logz - gold, 0.0)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)


def init_dense(key, shape, dtype, scale: Optional[float] = None):
    if any(s == 0 for s in shape):
        return jnp.zeros(shape, dtype)
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def init_params(shapes, key):
    """Materialize a {name: (shape, dtype)} pytree: norms ('ln*'/'scale*'/'a_param')
    -> zeros; embeddings ('embed*') -> N(0, 0.02); else fan-in normal."""

    def is_leaf(x):
        return (isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple))

    paths_leaves = jax.tree_util.tree_flatten_with_path(shapes, is_leaf=is_leaf)
    flat, treedef = paths_leaves
    keys = jax.random.split(key, max(len(flat), 1))
    leaves = []
    for k, (path, (shape, dt)) in zip(keys, flat):
        name = str(path[-1].key if hasattr(path[-1], "key") else path[-1])
        if name.startswith(("ln", "scale", "norm")):
            leaves.append(jnp.zeros(shape, dt))
        elif name.startswith("embed"):
            leaves.append(init_dense(k, shape, dt, scale=0.02))
        else:
            leaves.append(init_dense(k, shape, dt))
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])


def param_specs_as_sds(shapes):
    """{name: (shape, dtype)} -> ShapeDtypeStruct pytree (dry-run params)."""

    def is_leaf(x):
        return (isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple))

    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x[0], jnp.dtype(x[1])), shapes,
        is_leaf=is_leaf)


def activation_constraint(x, seq_over_model: bool = False):
    """Pin the residual stream's sharding inside the layer scan.

    Batch stays on the data axes (GSPMD otherwise trades batch sharding away
    to avoid FSDP param gathers — measured 16x activation blow-up, SSPerf),
    and optionally Megatron-SP shards the seq dim over 'model'.
    No-op when no mesh is ambient (single-device tests)."""
    try:
        from jax.sharding import PartitionSpec as P

        mesh = jax.sharding.get_abstract_mesh()
        if mesh.empty or "model" not in mesh.axis_names:
            return x
        batch = tuple(a for a in mesh.axis_names if a != "model")
        bsz = 1
        for a in batch:
            bsz *= mesh.shape[a]
        if x.shape[0] % max(bsz, 1) != 0:
            batch = ()
        seq = ("model" if seq_over_model
               and x.shape[1] % mesh.shape["model"] == 0 else None)
        spec = P(batch if batch else None, seq, None)
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def seq_shard_constraint(x):  # back-compat alias
    return activation_constraint(x, seq_over_model=True)
