"""Mamba-2 / SSD (state-space duality) family — attention-free LM.

Train/prefill use the *chunked* SSD algorithm (quadratic within chunks,
linear scan across chunks) so the MXU sees real matmuls; decode is the O(1)
recurrent update h' = exp(dt*A) h + dt * (B ⊗ x). The SSM state is constant
size, so `long_500k` decode is runnable (sub-quadratic); there is no KV
cache to page — see DESIGN.md §Arch-applicability for how the allocator is
(not) used here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from . import layers
from .config import ArchConfig


def dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * N  # x, B, C go through the causal conv
    return d_inner, H, N, conv_dim


def param_shapes(cfg: ArchConfig):
    L, D, V = cfg.n_layers, cfg.d_model, cfg.padded_vocab
    d_inner, H, N, conv_dim = dims(cfg)
    dt = cfg.dtype
    blocks = {
        "ln": ((L, D), dt),
        # separate projections (vs the fused in_proj) so every output dim is
        # TP-divisible: z/x are d_inner (pow2), B/C are N, dt stays replicated
        "wz": ((L, D, d_inner), dt),
        "wxi": ((L, D, d_inner), dt),
        "wb": ((L, D, N), dt),
        "wc": ((L, D, N), dt),
        "wdt": ((L, D, H), dt),
        "conv_w": ((L, cfg.conv_width, conv_dim), dt),
        "conv_b": ((L, conv_dim), dt),
        "a_log": ((L, H), "float32"),
        "d_skip": ((L, H), "float32"),
        "dt_bias": ((L, H), "float32"),
        "ln_y": ((L, d_inner), dt),
        "out_proj": ((L, d_inner, D), dt),
    }
    return {"embed": ((V, D), dt), "blocks": blocks, "ln_f": ((D,), dt)}


def init(cfg: ArchConfig, key):
    p = layers.init_params(param_shapes(cfg), key)
    # A in (-1, 0): a_log init ~ log(uniform[1,16]); dt_bias ~ softplus^-1(0.01)
    L = cfg.n_layers
    _, H, _, _ = dims(cfg)
    p["blocks"]["a_log"] = jnp.log(
        jnp.linspace(1.0, 16.0, H, dtype=jnp.float32))[None].repeat(L, 0)
    p["blocks"]["dt_bias"] = jnp.full((L, H), -4.6, jnp.float32)
    return p


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x [B,S,C]; w [W,C]; state [B,W-1,C] or None.

    Returns (y [B,S,C], new_state [B,W-1,C])."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i: i + x.shape[1], :] * w[i][None, None, :] for i in range(W))
    y = jax.nn.silu(y + b[None, None, :])
    return y.astype(x.dtype), xp[:, -(W - 1):, :]


def ssd_chunked(x, dt, A, B_, C_, chunk: int):
    """Chunked SSD. x [b,s,h,p]; dt [b,s,h] (>0); A [h] (<0); B_,C_ [b,s,n].

    Returns y [b,s,h,p] and the final state [b,h,p,n]."""
    b, s, h, p = x.shape
    n = B_.shape[-1]
    s_orig = s
    pad = (-s) % chunk
    if pad:
        # dt=0 steps contribute nothing to the state; outputs are sliced off
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = B_.reshape(b, nc, chunk, n)
    Cc = C_.reshape(b, nc, chunk, n)

    dA = dtc * A  # [b,nc,l,h], negative
    cum = jnp.cumsum(dA, axis=2)  # inclusive within-chunk cumsum

    # intra-chunk (quadratic in chunk length)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # [b,nc,i,j,h]
    idx = jnp.arange(chunk)
    causal = (idx[:, None] >= idx[None, :])[None, None, :, :, None]
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc,
                        preferred_element_type=jnp.float32)
    W = scores[..., None] * decay * dtc[:, :, None, :, :]
    W = jnp.where(causal, W, 0.0)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", W, xc.astype(jnp.float32))

    # chunk-final states
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)  # [b,nc,j,h]
    Sc = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", decay_end * dtc, Bc,
                    xc.astype(jnp.float32))

    # inter-chunk linear recurrence: H_c = exp(sum dA_c) H_{c-1} + S_c
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # [b,nc,h]

    def step(Hprev, xs):
        cd, Sc_ = xs  # [b,h], [b,h,p,n]
        Hnew = Hprev * cd[:, :, None, None] + Sc_
        return Hnew, Hprev

    H0 = jnp.zeros((b, h, p, n), jnp.float32)
    Hfin, Hprevs = lax.scan(step, H0, (jnp.moveaxis(chunk_decay, 1, 0),
                                       jnp.moveaxis(Sc, 1, 0)))
    Hprevs = jnp.moveaxis(Hprevs, 0, 1)  # [b,nc,h,p,n] state at chunk starts

    y_inter = jnp.einsum("bcin,bchpn->bcihp", Cc, Hprevs) * jnp.exp(
        cum)[..., None]
    y = (y_intra + y_inter).reshape(b, s, h, p)[:, :s_orig]
    return y.astype(x.dtype), Hfin


def ssd_recurrent_step(state, x, dt, A, B_, C_):
    """One-token SSD update. state [B,h,p,n]; x [B,h,p]; dt [B,h]; B_,C_ [B,n]."""
    dt = dt.astype(jnp.float32)
    dA = jnp.exp(dt * A)  # [B,h]
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt, B_, x.astype(jnp.float32))
    state = state * dA[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", C_, state)
    return state, y.astype(x.dtype)


def _proj(lp, h):
    return (h @ lp["wz"], h @ lp["wxi"], h @ lp["wb"], h @ lp["wc"],
            h @ lp["wdt"])


def _block_train(cfg: ArchConfig, x, lp):
    B, S, D = x.shape
    d_inner, H, N, conv_dim = dims(cfg)
    h = layers.rms_norm(x, lp["ln"])
    z, xs, B_, C_, dtp = _proj(lp, h)
    conv_in = jnp.concatenate([xs, B_, C_], axis=-1)
    conv_out, _ = _causal_conv(conv_in, lp["conv_w"], lp["conv_b"])
    xs, B_, C_ = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + lp["dt_bias"])
    A = -jnp.exp(lp["a_log"])
    xh = xs.reshape(B, S, H, cfg.ssm_head_dim)
    y, _ = ssd_chunked(xh, dt, A, B_, C_, cfg.ssm_chunk)
    y = y + lp["d_skip"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(B, S, d_inner)
    y = layers.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                        lp["ln_y"])
    return x + (y @ lp["out_proj"]).astype(x.dtype)


def forward(cfg: ArchConfig, params, tokens, positions=None):
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    blk = functools.partial(_block_train, cfg)
    if cfg.remat:
        blk = jax.checkpoint(blk)

    def step(x, lp):
        x = layers.activation_constraint(x, seq_over_model=cfg.seq_shard)
        return blk(x, lp), None

    x, _ = lax.scan(step, x, params["blocks"])
    return layers.rms_norm(x, params["ln_f"])


def logits_fn(cfg: ArchConfig, params, hidden):
    return layers.mask_padded_logits(
        hidden @ params["embed"].T.astype(hidden.dtype), cfg.vocab)  # tied


def loss(cfg: ArchConfig, params, batch):
    hidden = forward(cfg, params, batch["tokens"])
    logits = logits_fn(cfg, params, hidden)
    l = layers.cross_entropy(logits, batch["labels"])
    return l, {"loss": l}


# ----------------------------------------------------------------- serving --
def cache_spec(cfg: ArchConfig, batch: int, max_seq: int):
    d_inner, H, N, conv_dim = dims(cfg)
    L, W = cfg.n_layers, cfg.conv_width
    sds = jax.ShapeDtypeStruct
    return {
        "ssm_state": sds((L, batch, H, cfg.ssm_head_dim, N), jnp.float32),
        "conv_state": sds((L, batch, W - 1, conv_dim), jnp.dtype(cfg.dtype)),
        "seq_lens": sds((batch,), jnp.int32),
    }


def init_cache(cfg: ArchConfig, batch: int, max_seq: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, max_seq))


def prefill(cfg: ArchConfig, params, batch, cache):
    """Forward + capture final SSM/conv states for decode."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    d_inner, H, N, conv_dim = dims(cfg)
    x = params["embed"][tokens].astype(cfg.dtype)

    def step(x, xs):
        lp, _, _ = xs
        h = layers.rms_norm(x, lp["ln"])
        z, xs_, B_, C_, dtp = _proj(lp, h)
        conv_in = jnp.concatenate([xs_, B_, C_], axis=-1)
        conv_out, conv_state = _causal_conv(conv_in, lp["conv_w"], lp["conv_b"])
        xs_, B_, C_ = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
        dt = jax.nn.softplus(dtp.astype(jnp.float32) + lp["dt_bias"])
        A = -jnp.exp(lp["a_log"])
        xh = xs_.reshape(B, S, H, cfg.ssm_head_dim)
        y, ssm_state = ssd_chunked(xh, dt, A, B_, C_, cfg.ssm_chunk)
        y = y + lp["d_skip"][None, None, :, None].astype(y.dtype) * xh
        y = y.reshape(B, S, d_inner)
        y = layers.rms_norm(
            y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), lp["ln_y"])
        return x + (y @ lp["out_proj"]).astype(x.dtype), (ssm_state, conv_state)

    x, (ssm_state, conv_state) = lax.scan(
        step, x, (params["blocks"], cache["ssm_state"], cache["conv_state"]))
    x = layers.rms_norm(x, params["ln_f"])
    logits = logits_fn(cfg, params, x[:, -1])
    cache = dict(cache, ssm_state=ssm_state, conv_state=conv_state,
                 seq_lens=jnp.full((B,), S, jnp.int32))
    return cache, logits


def decode(cfg: ArchConfig, params, cache, batch):
    tokens = batch["tokens"]
    B = tokens.shape[0]
    d_inner, H, N, conv_dim = dims(cfg)
    x = params["embed"][tokens[:, 0]].astype(cfg.dtype)[:, None, :]

    def step(x, xs):
        lp, ssm_state, conv_state = xs
        h = layers.rms_norm(x, lp["ln"])
        z, xs_, B_, C_, dtp = _proj(lp, h)
        conv_in = jnp.concatenate([xs_, B_, C_], axis=-1)
        conv_out, conv_state = _causal_conv(conv_in, lp["conv_w"], lp["conv_b"],
                                            state=conv_state)
        xs_, B_, C_ = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
        dt = jax.nn.softplus(dtp[:, 0].astype(jnp.float32) + lp["dt_bias"])
        A = -jnp.exp(lp["a_log"])
        xh = xs_[:, 0].reshape(B, H, cfg.ssm_head_dim)
        ssm_state, y = ssd_recurrent_step(ssm_state, xh, dt, A, B_[:, 0], C_[:, 0])
        y = y + lp["d_skip"][None, :, None].astype(y.dtype) * xh
        y = y.reshape(B, 1, d_inner)
        y = layers.rms_norm(
            y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), lp["ln_y"])
        return x + (y @ lp["out_proj"]).astype(x.dtype), (ssm_state, conv_state)

    x, (ssm_state, conv_state) = lax.scan(
        step, x, (params["blocks"], cache["ssm_state"], cache["conv_state"]))
    x = layers.rms_norm(x, params["ln_f"])
    logits = logits_fn(cfg, params, x[:, 0])
    cache = dict(cache, ssm_state=ssm_state, conv_state=conv_state,
                 seq_lens=cache["seq_lens"] + 1)
    return cache, logits
