"""Architecture configuration — one frozen dataclass drives every family.

`reduced()` returns the smoke-test scale config of the same family (small
layers/width, few experts, tiny vocab) used by per-arch CPU smoke tests;
the FULL configs are exercised only via the dry-run (ShapeDtypeStruct).
"""
from __future__ import annotations

import dataclasses

FAMILIES = ("dense", "ssm", "hybrid", "audio", "moe", "vlm")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    mlp: str = "swiglu"              # swiglu | geglu | squared_relu | gelu
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_group: int = 2048            # tokens per dispatch group (memory bound)
    moe_parallel_groups: int = 16    # groups processed per scan step (vmapped;
                                     # keeps the group dim data-sharded)
    pad_experts_to: int = 16         # pad expert count to a TP-divisible
                                     # multiple (dummy experts never routed)
    train_microbatches: int = 0      # 0 = auto; SP archs use fewer, larger
                                     # microbatches (per-micro grad reduces
                                     # dominate otherwise — SSPerf)
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128             # SSD chunk length
    conv_width: int = 4
    # --- hybrid (recurrentgemma) ---
    attn_period: int = 0             # 3 -> every 3rd layer is local attention
    window: int = 2048               # local attention window
    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    enc_frames: int = 1500           # stub audio frontend frames
    # --- VLM (paligemma) ---
    n_patches: int = 0               # stub SigLIP patch embeddings
    # --- numerics & distribution ---
    dtype: str = "bfloat16"
    remat: bool = True
    fsdp: bool = False               # shard params+opt over 'data' too (ZeRO-3)
    seq_shard: bool = False          # Megatron-SP: shard residual seq over model
    page_size: int = 128             # paged-KV page tokens
    attend_impl: str = "ref"         # paged decode attention: 'ref' | 'kernel'
    opt_moment_dtype: str = "float32"
    pad_vocab_to: int = 256          # Megatron-style vocab padding (clean TP)
    attn_4d: bool = False            # [D,H,hd] attention weights (SSPerf iter)
    flash_min_seq: int = 8193        # flash attention above this many tokens
    kv_seq_parallel: bool = False    # shard_map flash-decoding (SSPerf iter)
    gqa_expand: bool = False         # expand KV to H heads pre-attention so
                                     # every S^2 tensor shards on 'model' (SSPerf)

    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def padded_vocab(self) -> int:
        p = self.pad_vocab_to
        return -(-self.vocab // p) * p

    @property
    def padded_experts(self) -> int:
        p = max(self.pad_experts_to, 1)
        return -(-self.n_experts // p) * p

    @property
    def sub_quadratic(self) -> bool:
        """Whether long_500k decode is runnable (constant-ish per-token state)."""
        return self.family in ("ssm", "hybrid")

    def reduced(self) -> "ArchConfig":
        """Smoke-test scale: same family/topology, tiny dims."""
        return dataclasses.replace(
            self,
            # hybrid keeps one full (rec, rec, attn) group
            n_layers=3 if self.family == "hybrid" else min(self.n_layers, 2),
            d_model=128,
            n_heads=max(min(self.n_heads, 4), 1),
            n_kv_heads=max(min(self.n_kv_heads, 2), 1) if self.n_kv_heads else 0,
            d_ff=256,
            vocab=512,
            head_dim=32 if self.n_heads else 0,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            expert_d_ff=64 if self.expert_d_ff else 0,
            moe_group=64,
            pad_experts_to=1,
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=16,
            window=32,
            enc_layers=min(self.enc_layers, 2),
            enc_frames=16 if self.enc_frames else 0,
            n_patches=min(self.n_patches, 8),
            dtype="float32",
            remat=False,
            seq_shard=False,
            page_size=16,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
