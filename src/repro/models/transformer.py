"""Dense GQA transformer LM (nemotron / stablelm / mistral / granite).

Parameters are stacked over layers and the forward pass is a `lax.scan` —
compact HLO, remat-friendly, fast SPMD compiles. Decode uses the paged KV
cache managed by PIM-malloc (`repro.kvcache`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.kvcache import paged
from . import layers
from .config import ArchConfig


def param_shapes(cfg: ArchConfig):
    L, D, V, F = cfg.n_layers, cfg.d_model, cfg.padded_vocab, cfg.d_ff
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.dtype
    blocks = {
        "ln1": ((L, D), dt),
        "ln2": ((L, D), dt),
        "wq": ((L, D, H, hd) if cfg.attn_4d else (L, D, H * hd), dt),
        "wk": ((L, D, KVH, hd) if cfg.attn_4d else (L, D, KVH * hd), dt),
        "wv": ((L, D, KVH, hd) if cfg.attn_4d else (L, D, KVH * hd), dt),
        "wo": ((L, H, hd, D) if cfg.attn_4d else (L, H * hd, D), dt),
        "w1": ((L, D, F), dt),
        "w2": ((L, F, D), dt),
    }
    if layers.mlp_n_mats(cfg.mlp) == 3:
        blocks["w3"] = ((L, D, F), dt)
    shapes = {"embed": ((V, D), dt), "blocks": blocks, "ln_f": ((D,), dt)}
    if not cfg.tie_embeddings:
        shapes["head"] = ((D, V), dt)
    return shapes


def init(cfg: ArchConfig, key):
    return layers.init_params(param_shapes(cfg), key)


def _block(cfg: ArchConfig, x, positions, lp, *, window: int = 0):
    B, S, D = x.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.seq_shard:
        # Megatron-SP: the residual is seq-sharded BETWEEN blocks (small
        # remat carries); gather the seq dim here so the TP matmuls see
        # whole sequences — otherwise GSPMD all-gathers the WEIGHTS every
        # layer x microbatch (measured: 1.4 GB x 704 on mistral, SSPerf).
        x = layers.activation_constraint(x, seq_over_model=False)
    h = layers.rms_norm(x, lp["ln1"])
    q = layers.qk_proj(h, lp["wq"], H, hd)
    k = layers.qk_proj(h, lp["wk"], KVH, hd)
    v = layers.qk_proj(h, lp["wv"], KVH, hd)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)
    if cfg.gqa_expand and KVH != H:
        k = jnp.repeat(k, H // KVH, axis=2)
        v = jnp.repeat(v, H // KVH, axis=2)
    attn = layers.pick_attention(S, S, cfg.flash_min_seq)
    o = attn(q, k, v, causal=True, window=window)
    x = x + layers.out_proj(o, lp["wo"]).astype(x.dtype)
    h2 = layers.rms_norm(x, lp["ln2"])
    x = x + layers.mlp(h2, lp["w1"], lp["w2"], lp.get("w3"), cfg.mlp)
    return x


def forward_embeds(cfg: ArchConfig, params, x, positions):
    """x [B, S, D] input embeddings -> final hidden [B, S, D]."""
    blk = functools.partial(_block, cfg)
    if cfg.remat:
        blk = jax.checkpoint(blk)

    def step(x, lp):
        x = layers.activation_constraint(x, seq_over_model=cfg.seq_shard)
        return blk(x, positions, lp), None

    x, _ = lax.scan(step, x, params["blocks"])
    return layers.rms_norm(x, params["ln_f"])


def forward(cfg: ArchConfig, params, tokens, positions=None):
    """tokens [B, S] -> final hidden [B, S, D]."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = params["embed"][tokens].astype(cfg.dtype)
    return forward_embeds(cfg, params, x, positions)


def logits_fn(cfg: ArchConfig, params, hidden):
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return layers.mask_padded_logits(hidden @ head.astype(hidden.dtype),
                                     cfg.vocab)


def loss(cfg: ArchConfig, params, batch):
    hidden = forward(cfg, params, batch["tokens"])
    logits = logits_fn(cfg, params, hidden)
    l = layers.cross_entropy(logits, batch["labels"])
    return l, {"loss": l}


# ----------------------------------------------------------------- serving --
def cache_spec(cfg: ArchConfig, batch: int, max_seq: int):
    return paged.cache_spec(
        n_layers=cfg.n_layers, batch=batch, max_seq=max_seq,
        page_size=cfg.page_size, kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        dtype=cfg.dtype,
    )


def prefill(cfg: ArchConfig, params, batch, cache):
    """Full-sequence forward that also writes the paged KV cache.

    Returns (cache, logits_last [B, V])."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = params["embed"][tokens].astype(cfg.dtype)
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    def step(x, xs):
        lp, k_pages, v_pages = xs
        h = layers.rms_norm(x, lp["ln1"])
        q = layers.qk_proj(h, lp["wq"], H, hd)
        k = layers.qk_proj(h, lp["wk"], KVH, hd)
        v = layers.qk_proj(h, lp["wv"], KVH, hd)
        q = layers.rope(q, positions, cfg.rope_theta)
        k = layers.rope(k, positions, cfg.rope_theta)
        attn = layers.pick_attention(S, S, cfg.flash_min_seq)
        o = attn(q, k, v, causal=True)
        x = x + layers.out_proj(o, lp["wo"]).astype(x.dtype)
        h2 = layers.rms_norm(x, lp["ln2"])
        x = x + layers.mlp(h2, lp["w1"], lp["w2"], lp.get("w3"), cfg.mlp)
        k_pages = paged.write_prefill(k_pages, k, cache["page_table"])
        v_pages = paged.write_prefill(v_pages, v, cache["page_table"])
        return x, (k_pages, v_pages)

    x, (k_pages, v_pages) = lax.scan(
        step, x, (params["blocks"], cache["k_pages"], cache["v_pages"])
    )
    x = layers.rms_norm(x, params["ln_f"])
    logits = logits_fn(cfg, params, x[:, -1])
    cache = dict(cache, k_pages=k_pages, v_pages=v_pages,
                 seq_lens=jnp.full((B,), S, jnp.int32))
    return cache, logits


def decode(cfg: ArchConfig, params, cache, batch):
    """One decode step: tokens [B, 1] -> (cache, logits [B, V])."""
    tokens = batch["tokens"]
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pos = cache["seq_lens"]  # [B] position of the new token
    x = params["embed"][tokens[:, 0]].astype(cfg.dtype)[:, None, :]  # [B,1,D]

    def step(x, xs):
        lp, k_pages, v_pages = xs
        h = layers.rms_norm(x, lp["ln1"])
        q = layers.qk_proj(h, lp["wq"], H, hd)[:, 0]
        k = layers.qk_proj(h, lp["wk"], KVH, hd)[:, 0]
        v = layers.qk_proj(h, lp["wv"], KVH, hd)[:, 0]
        q = layers.rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        k = layers.rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        if cfg.kv_seq_parallel:
            o, k_pages, v_pages = paged.write_attend_seqpar(
                q, k, v, k_pages, v_pages, cache["page_table"], pos)
        else:
            k_pages = paged.write_token(k_pages, k, cache["page_table"], pos)
            v_pages = paged.write_token(v_pages, v, cache["page_table"], pos)
            o = paged.attend(q, k_pages, v_pages, cache["page_table"],
                             pos + 1, impl=cfg.attend_impl)
        x = x + layers.out_proj(o[:, None], lp["wo"]).astype(x.dtype)
        h2 = layers.rms_norm(x, lp["ln2"])
        x = x + layers.mlp(h2, lp["w1"], lp["w2"], lp.get("w3"), cfg.mlp)
        return x, (k_pages, v_pages)

    x, (k_pages, v_pages) = lax.scan(
        step, x, (params["blocks"], cache["k_pages"], cache["v_pages"])
    )
    x = layers.rms_norm(x, params["ln_f"])
    logits = logits_fn(cfg, params, x[:, 0])
    cache = dict(cache, k_pages=k_pages, v_pages=v_pages, seq_lens=pos + 1)
    return cache, logits
