"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention.

Layer pattern 1:2 — repeating groups of (recurrent, recurrent, local-attn),
with any remainder layers recurrent. Every layer has its own GeGLU MLP.
RG-LRU trains via `lax.associative_scan` (parallel linear recurrence) and
decodes with an O(1) state update; local attention uses a rolling
`window`-token KV buffer, so `long_500k` decode has constant per-token state.

Deviation noted in DESIGN.md: RG-LRU input/recurrence gates use dense
projections (the paper uses block-diagonal).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from . import layers
from .config import ArchConfig

RG_C = 8.0  # Griffin's fixed scalar in a_t = exp(-c * softplus(lam) * r_t)


def _group_counts(cfg: ArchConfig):
    return cfg.n_layers // 3, cfg.n_layers % 3  # (groups of R,R,A; tail R's)


def _rec_shapes(cfg: ArchConfig, L: int):
    D = cfg.d_model
    dt = cfg.dtype
    return {
        "ln": ((L, D), dt),
        "wx": ((L, D, D), dt),
        "wy": ((L, D, D), dt),
        "conv_w": ((L, cfg.conv_width, D), dt),
        "conv_b": ((L, D), dt),
        "w_r": ((L, D, D), dt),
        "w_i": ((L, D, D), dt),
        "a_param": ((L, D), "float32"),
        "w_out": ((L, D, D), dt),
        "ln_mlp": ((L, D), dt),
        "m1": ((L, D, cfg.d_ff), dt),
        "m2": ((L, cfg.d_ff, D), dt),
        "m3": ((L, D, cfg.d_ff), dt),
    }


def _attn_shapes(cfg: ArchConfig, L: int):
    D, H, KVH, hd, F = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                        cfg.d_ff)
    dt = cfg.dtype
    return {
        "ln": ((L, D), dt),
        "wq": ((L, D, H, hd) if cfg.attn_4d else (L, D, H * hd), dt),
        "wk": ((L, D, KVH, hd) if cfg.attn_4d else (L, D, KVH * hd), dt),
        "wv": ((L, D, KVH, hd) if cfg.attn_4d else (L, D, KVH * hd), dt),
        "wo": ((L, H, hd, D) if cfg.attn_4d else (L, H * hd, D), dt),
        "ln_mlp": ((L, D), dt),
        "m1": ((L, D, F), dt),
        "m2": ((L, F, D), dt),
        "m3": ((L, D, F), dt),
    }


def param_shapes(cfg: ArchConfig):
    G, R = _group_counts(cfg)
    dt = cfg.dtype
    shapes = {
        "embed": ((cfg.padded_vocab, cfg.d_model), dt),
        "rec1": _rec_shapes(cfg, G),
        "rec2": _rec_shapes(cfg, G),
        "attn": _attn_shapes(cfg, G),
        "ln_f": ((cfg.d_model,), dt),
    }
    if R:
        shapes["tail"] = _rec_shapes(cfg, R)
    return shapes


def init(cfg: ArchConfig, key):
    p = layers.init_params(param_shapes(cfg), key)
    G, R = _group_counts(cfg)
    # a_param init so a^(1/c) ~ uniform(0.9, 0.999): softplus(a_param) ~ small
    for name, L in (("rec1", G), ("rec2", G), ("tail", R)):
        if L and name in p:
            p[name]["a_param"] = jnp.full((L, cfg.d_model), 0.65, jnp.float32)
    return p


def _rglru_scan(x, r, i, a_param):
    """Parallel RG-LRU. x/r/i: [B,S,D] (r,i post-sigmoid); returns [B,S,D]."""
    log_a = (-RG_C * jax.nn.softplus(a_param)[None, None, :]
             * r.astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    b = gated * (i.astype(jnp.float32) * x.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype)


def _rglru_step(state, x, r, i, a_param):
    """One-token RG-LRU. state/x/r/i: [B, D] -> (state, y)."""
    log_a = -RG_C * jax.nn.softplus(a_param)[None, :] * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    h = a * state + gated * (i.astype(jnp.float32) * x.astype(jnp.float32))
    return h, h.astype(x.dtype)


def _conv_shift(state, x):
    """Causal depthwise conv states. state [B,W-1,D]; x [B,S,D]."""
    return jnp.concatenate([state, x], axis=1)[:, -(state.shape[1]):, :]


def _rec_mixer_train(cfg, x, lp):
    h = layers.rms_norm(x, lp["ln"])
    xb = h @ lp["wx"]
    yb = h @ lp["wy"]
    W = cfg.conv_width
    conv_state = jnp.zeros((x.shape[0], W - 1, xb.shape[-1]), xb.dtype)
    xp = jnp.concatenate([conv_state, xb], axis=1)
    xc = sum(xp[:, i: i + xb.shape[1], :] * lp["conv_w"][i][None, None, :]
             for i in range(W)) + lp["conv_b"][None, None, :]
    r = jax.nn.sigmoid(xc @ lp["w_r"])
    i = jax.nn.sigmoid(xc @ lp["w_i"])
    y = _rglru_scan(xc, r, i, lp["a_param"])
    out = (y * jax.nn.gelu(yb)) @ lp["w_out"]
    x = x + out.astype(x.dtype)
    h2 = layers.rms_norm(x, lp["ln_mlp"])
    return x + layers.mlp(h2, lp["m1"], lp["m2"], lp["m3"], "geglu")


def _attn_mixer_train(cfg, x, positions, lp):
    B, S, D = x.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = layers.rms_norm(x, lp["ln"])
    q = layers.qk_proj(h, lp["wq"], H, hd)
    k = layers.qk_proj(h, lp["wk"], KVH, hd)
    v = layers.qk_proj(h, lp["wv"], KVH, hd)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)
    attn = layers.pick_attention(S, S, cfg.flash_min_seq)
    o = attn(q, k, v, causal=True, window=cfg.window)
    x = x + layers.out_proj(o, lp["wo"]).astype(x.dtype)
    h2 = layers.rms_norm(x, lp["ln_mlp"])
    return x + layers.mlp(h2, lp["m1"], lp["m2"], lp["m3"], "geglu")


def forward(cfg: ArchConfig, params, tokens, positions=None):
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = params["embed"][tokens].astype(cfg.dtype)

    rec = functools.partial(_rec_mixer_train, cfg)
    att = functools.partial(_attn_mixer_train, cfg)
    if cfg.remat:
        rec, att = jax.checkpoint(rec), jax.checkpoint(att)

    def group(x, gp):
        x = layers.activation_constraint(x, seq_over_model=cfg.seq_shard)
        x = rec(x, gp["rec1"])
        x = rec(x, gp["rec2"])
        x = att(x, positions, gp["attn"])
        return x, None

    G, R = _group_counts(cfg)
    if G:
        gp = {k: params[k] for k in ("rec1", "rec2", "attn")}
        x, _ = lax.scan(group, x, gp)
    if R:
        x, _ = lax.scan(lambda x, lp: (rec(x, lp), None), x, params["tail"])
    return layers.rms_norm(x, params["ln_f"])


def logits_fn(cfg, params, hidden):
    return layers.mask_padded_logits(
        hidden @ params["embed"].T.astype(hidden.dtype), cfg.vocab)


def loss(cfg: ArchConfig, params, batch):
    hidden = forward(cfg, params, batch["tokens"])
    logits = logits_fn(cfg, params, hidden)
    l = layers.cross_entropy(logits, batch["labels"])
    return l, {"loss": l}


# ----------------------------------------------------------------- serving --
def cache_spec(cfg: ArchConfig, batch: int, max_seq: int):
    G, R = _group_counts(cfg)
    D, W = cfg.d_model, cfg.conv_width
    KVH, hd = cfg.n_kv_heads, cfg.head_dim
    win = min(cfg.window, max_seq)
    sds = jax.ShapeDtypeStruct
    dt = jnp.dtype(cfg.dtype)
    n_rec = 2 * G + R
    return {
        "rg_state": sds((n_rec, batch, D), jnp.float32),
        "conv_state": sds((n_rec, batch, W - 1, D), dt),
        "win_k": sds((G, batch, win, KVH, hd), dt),
        "win_v": sds((G, batch, win, KVH, hd), dt),
        "seq_lens": sds((batch,), jnp.int32),
    }


def init_cache(cfg: ArchConfig, batch: int, max_seq: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, max_seq))


def _rec_mixer_decode(cfg, x, lp, rg_state, conv_state):
    """x [B,1,D]; rg_state [B,D]; conv_state [B,W-1,D]."""
    h = layers.rms_norm(x, lp["ln"])
    xb = h @ lp["wx"]
    yb = h @ lp["wy"]
    W = cfg.conv_width
    xp = jnp.concatenate([conv_state.astype(xb.dtype), xb], axis=1)  # [B,W,D]
    xc = sum(xp[:, i: i + 1, :] * lp["conv_w"][i][None, None, :]
             for i in range(W)) + lp["conv_b"][None, None, :]
    new_conv = xp[:, 1:, :]
    r = jax.nn.sigmoid(xc @ lp["w_r"])[:, 0]
    i = jax.nn.sigmoid(xc @ lp["w_i"])[:, 0]
    rg_state, y = _rglru_step(rg_state, xc[:, 0], r, i, lp["a_param"])
    out = (y[:, None, :] * jax.nn.gelu(yb)) @ lp["w_out"]
    x = x + out.astype(x.dtype)
    h2 = layers.rms_norm(x, lp["ln_mlp"])
    x = x + layers.mlp(h2, lp["m1"], lp["m2"], lp["m3"], "geglu")
    return x, rg_state, new_conv


def _attn_mixer_decode(cfg, x, lp, win_k, win_v, pos):
    """Rolling-window MQA decode. win_k/v [B,win,KVH,hd]; pos [B]."""
    B = x.shape[0]
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    win = win_k.shape[1]
    h = layers.rms_norm(x, lp["ln"])
    q = layers.qk_proj(h, lp["wq"], H, hd)[:, 0]
    k = layers.qk_proj(h, lp["wk"], KVH, hd)[:, 0]
    v = layers.qk_proj(h, lp["wv"], KVH, hd)[:, 0]
    q = layers.rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    k = layers.rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    slot = pos % win
    win_k = win_k.at[jnp.arange(B), slot].set(k.astype(win_k.dtype))
    win_v = win_v.at[jnp.arange(B), slot].set(v.astype(win_v.dtype))
    # slots valid if their stored position <= pos (always true after wrap)
    slots = jnp.arange(win)[None, :]
    valid = (slots <= pos[:, None]) | (pos[:, None] >= win)
    G = H // KVH
    qh = q.reshape(B, KVH, G, hd)
    s = jnp.einsum("bkgd,bwkd->bkgw", qh, win_k.astype(q.dtype),
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgw,bwkd->bkgd", p.astype(q.dtype),
                   win_v.astype(q.dtype), preferred_element_type=jnp.float32)
    o4 = o.reshape(B, 1, H, hd).astype(x.dtype)
    x = x + layers.out_proj(o4, lp["wo"]).astype(x.dtype)
    h2 = layers.rms_norm(x, lp["ln_mlp"])
    x = x + layers.mlp(h2, lp["m1"], lp["m2"], lp["m3"], "geglu")
    return x, win_k, win_v


def decode(cfg: ArchConfig, params, cache, batch):
    tokens = batch["tokens"]
    pos = cache["seq_lens"]
    x = params["embed"][tokens[:, 0]].astype(cfg.dtype)[:, None, :]
    G, R = _group_counts(cfg)

    def group(carry, xs):
        x = carry
        gp, rg1, cv1, rg2, cv2, wk, wv = xs
        x, rg1, cv1 = _rec_mixer_decode(cfg, x, gp["rec1"], rg1, cv1)
        x, rg2, cv2 = _rec_mixer_decode(cfg, x, gp["rec2"], rg2, cv2)
        x, wk, wv = _attn_mixer_decode(cfg, x, gp["attn"], wk, wv, pos)
        return x, (rg1, cv1, rg2, cv2, wk, wv)

    rg = cache["rg_state"]
    cv = cache["conv_state"]
    if G:
        gp = {k: params[k] for k in ("rec1", "rec2", "attn")}
        xs = (gp, rg[0:2 * G:2], cv[0:2 * G:2], rg[1:2 * G:2], cv[1:2 * G:2],
              cache["win_k"], cache["win_v"])
        x, (rg1, cv1, rg2, cv2, wk, wv) = lax.scan(group, x, xs)
        rg = rg.at[0:2 * G:2].set(rg1).at[1:2 * G:2].set(rg2)
        cv = cv.at[0:2 * G:2].set(cv1).at[1:2 * G:2].set(cv2)
    else:
        wk, wv = cache["win_k"], cache["win_v"]
    if R:
        def tail(carry, xs):
            x = carry
            lp, rgt, cvt = xs
            x, rgt, cvt = _rec_mixer_decode(cfg, x, lp, rgt, cvt)
            return x, (rgt, cvt)

        x, (rgt, cvt) = lax.scan(tail, x, (params["tail"], rg[2 * G:], cv[2 * G:]))
        rg = rg.at[2 * G:].set(rgt)
        cv = cv.at[2 * G:].set(cvt)

    x = layers.rms_norm(x, params["ln_f"])
    logits = logits_fn(cfg, params, x[:, 0])
    cache = dict(cache, rg_state=rg, conv_state=cv, win_k=wk, win_v=wv,
                 seq_lens=pos + 1)
    return cache, logits


def _rec_mixer_prefill(cfg, x, lp):
    """Train-path recurrent mixer that also returns (rg_state, conv_state)."""
    h = layers.rms_norm(x, lp["ln"])
    xb = h @ lp["wx"]
    yb = h @ lp["wy"]
    W = cfg.conv_width
    conv0 = jnp.zeros((x.shape[0], W - 1, xb.shape[-1]), xb.dtype)
    xp = jnp.concatenate([conv0, xb], axis=1)
    xc = sum(xp[:, i: i + xb.shape[1], :] * lp["conv_w"][i][None, None, :]
             for i in range(W)) + lp["conv_b"][None, None, :]
    conv_state = xp[:, -(W - 1):, :]
    r = jax.nn.sigmoid(xc @ lp["w_r"])
    i = jax.nn.sigmoid(xc @ lp["w_i"])
    log_a = (-RG_C * jax.nn.softplus(lp["a_param"])[None, None, :]
             * r.astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    b = gated * (i.astype(jnp.float32) * xc.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, hfull = lax.associative_scan(combine, (a, b), axis=1)
    rg_state = hfull[:, -1]  # [B, D] fp32
    y = hfull.astype(x.dtype)
    out = (y * jax.nn.gelu(yb)) @ lp["w_out"]
    x = x + out.astype(x.dtype)
    h2 = layers.rms_norm(x, lp["ln_mlp"])
    x = x + layers.mlp(h2, lp["m1"], lp["m2"], lp["m3"], "geglu")
    return x, rg_state, conv_state


def _attn_mixer_prefill(cfg, x, positions, lp, win):
    """Train-path local attention that also fills the rolling window buffer."""
    B, S, D = x.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = layers.rms_norm(x, lp["ln"])
    q = layers.qk_proj(h, lp["wq"], H, hd)
    k = layers.qk_proj(h, lp["wk"], KVH, hd)
    v = layers.qk_proj(h, lp["wv"], KVH, hd)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)
    attn = layers.pick_attention(S, S, cfg.flash_min_seq)
    o = attn(q, k, v, causal=True, window=cfg.window)
    xo = x + layers.out_proj(o, lp["wo"]).astype(x.dtype)
    h2 = layers.rms_norm(xo, lp["ln_mlp"])
    xo = xo + layers.mlp(h2, lp["m1"], lp["m2"], lp["m3"], "geglu")
    # rolling buffer: last `win` tokens at slots pos % win
    last_k = k[:, -win:] if S >= win else k
    last_v = v[:, -win:] if S >= win else v
    pos_last = positions[:, -last_k.shape[1]:]
    slots = pos_last % win
    win_k = jnp.zeros((B, win, KVH, hd), k.dtype)
    win_v = jnp.zeros((B, win, KVH, hd), v.dtype)
    bidx = jnp.arange(B)[:, None]
    win_k = win_k.at[bidx, slots].set(last_k)
    win_v = win_v.at[bidx, slots].set(last_v)
    return xo, win_k, win_v


def prefill(cfg: ArchConfig, params, batch, cache):
    """Parallel prefill: associative-scan RG-LRU + windowed attention, with
    state capture for decode."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x = params["embed"][tokens].astype(cfg.dtype)
    G, R = _group_counts(cfg)
    win = cache["win_k"].shape[2]

    def group(x, gp):
        x, rg1, cv1 = _rec_mixer_prefill(cfg, x, gp["rec1"])
        x, rg2, cv2 = _rec_mixer_prefill(cfg, x, gp["rec2"])
        x, wk, wv = _attn_mixer_prefill(cfg, x, positions, gp["attn"], win)
        return x, (rg1, cv1, rg2, cv2, wk, wv)

    rg = cache["rg_state"]
    cv = cache["conv_state"]
    wk, wv = cache["win_k"], cache["win_v"]
    if G:
        gp = {k: params[k] for k in ("rec1", "rec2", "attn")}
        x, (rg1, cv1, rg2, cv2, wk, wv) = lax.scan(group, x, gp)
        rg = rg.at[0:2 * G:2].set(rg1).at[1:2 * G:2].set(rg2)
        cv = cv.at[0:2 * G:2].set(cv1).at[1:2 * G:2].set(cv2)
    if R:
        def tail(x, lp):
            x, rgt, cvt = _rec_mixer_prefill(cfg, x, lp)
            return x, (rgt, cvt)

        x, (rgt, cvt) = lax.scan(tail, x, params["tail"])
        rg = rg.at[2 * G:].set(rgt)
        cv = cv.at[2 * G:].set(cvt)

    x = layers.rms_norm(x, params["ln_f"])
    logits = logits_fn(cfg, params, x[:, -1])
    cache = dict(cache, rg_state=rg, conv_state=cv, win_k=wk, win_v=wv,
                 seq_lens=jnp.full((B,), S, jnp.int32))
    return cache, logits
