"""Tensorized buddy allocator (the paper's backend / straw-man allocator).

The paper manages each PIM core's heap with a binary buddy tree whose nodes
carry 2-bit state (free / split / full).  For a fixed-shape, branch-free JAX
implementation we use the standard *array buddy* encoding instead: a
``longest[]`` array where ``longest[i]`` is the size in bytes of the largest
free block underneath tree node ``i`` (1-indexed, root = 1).  alloc/free are
O(depth) with *fixed* trip counts, which makes them `vmap`-able across PIM
cores and `scan`-able across a request stream.

Every op also emits a fixed-length *trace* of the tree-node indices it
touched.  The metadata-cache simulators (`buddy_cache.py`) and the DPU cost
model (`cost_model.py`) consume these traces; they charge 2 bits per node —
the paper's metadata encoding — so capacity/traffic arithmetic (e.g. Fig 15's
"64 B buddy cache = 256 nodes") is reproduced exactly even though the
functional state here is int32.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
from jax import lax

INVALID = jnp.int32(-1)


def next_pow2(x):
    """Smallest power of two >= x (exact integer bit-smear)."""
    x = jnp.maximum(x, 1).astype(jnp.int32) - 1
    x = x | (x >> 1)
    x = x | (x >> 2)
    x = x | (x >> 4)
    x = x | (x >> 8)
    x = x | (x >> 16)
    return x + 1


def ilog2(x):
    """log2 of a power-of-two int32 (exact, via popcount)."""
    return lax.population_count(jnp.asarray(x, jnp.int32) - 1)


@dataclasses.dataclass(frozen=True)
class BuddyConfig:
    """Static heap geometry. depth = log2(heap/min_block) tree levels below root."""

    heap_bytes: int
    min_block: int

    def __post_init__(self):
        assert self.heap_bytes & (self.heap_bytes - 1) == 0, "heap must be pow2"
        assert self.min_block & (self.min_block - 1) == 0, "min_block must be pow2"
        assert self.heap_bytes >= self.min_block

    @property
    def depth(self) -> int:
        return (self.heap_bytes // self.min_block).bit_length() - 1

    @property
    def n_leaf(self) -> int:
        return self.heap_bytes // self.min_block

    @property
    def n_nodes(self) -> int:  # 1-indexed array size (slot 0 unused)
        return 2 * self.n_leaf

    @property
    def trace_len(self) -> int:
        # descent records root + one node per level; up-walk one per level.
        return 2 * (self.depth + 1)

    @property
    def metadata_bytes(self) -> int:
        """Paper metadata footprint: 2 bits per tree node."""
        return (2 * self.n_nodes + 7) // 8


class BuddyState(NamedTuple):
    longest: jnp.ndarray  # int32[n_nodes], bytes of largest free block under node


class BuddyEvent(NamedTuple):
    """Per-op record consumed by cache sims + cost model."""

    ok: jnp.ndarray          # bool — op succeeded
    levels_down: jnp.ndarray  # int32 — descent length (nodes visited - 1)
    levels_up: jnp.ndarray    # int32 — ancestor updates
    trace: jnp.ndarray        # int32[trace_len] node indices, -1 padded


def init(cfg: BuddyConfig) -> BuddyState:
    n = cfg.n_nodes
    idx = jnp.arange(n, dtype=jnp.int32)
    # depth of node i = floor(log2(i)); longest = heap >> depth. Slot 0 unused.
    depth = jnp.where(idx > 0, 31 - lax.clz(jnp.maximum(idx, 1)), 0)
    longest = jnp.where(idx > 0, cfg.heap_bytes >> depth, 0).astype(jnp.int32)
    return BuddyState(longest=longest)


def _round_size(cfg: BuddyConfig, size):
    return jnp.maximum(next_pow2(size), cfg.min_block)


def alloc(cfg: BuddyConfig, st: BuddyState, size):
    """Allocate `size` bytes. Returns (state, offset, BuddyEvent); offset=-1 on failure.

    size may be a traced scalar. Fixed trip counts: cfg.depth for both the
    descent and the ancestor re-max walk.
    """
    size = _round_size(cfg, size)
    ok = (size <= cfg.heap_bytes) & (st.longest[1] >= size)
    longest = st.longest

    trace0 = jnp.full((cfg.trace_len,), INVALID, dtype=jnp.int32)
    trace0 = trace0.at[0].set(1)  # root visit

    def down(i, carry):
        node, node_size, trace, nsteps = carry
        descend = node_size > size
        left = 2 * node
        go_left = longest[left] >= size
        nxt = jnp.where(go_left, left, left + 1)
        node = jnp.where(descend, nxt, node)
        trace = trace.at[1 + i].set(jnp.where(descend, node, INVALID))
        node_size = jnp.where(descend, node_size >> 1, node_size)
        nsteps = nsteps + jnp.where(descend, 1, 0)
        return node, node_size, trace, nsteps

    node, node_size, trace, levels_down = lax.fori_loop(
        0, cfg.depth, down, (jnp.int32(1), jnp.int32(cfg.heap_bytes), trace0, jnp.int32(0))
    )

    offset = node * node_size - cfg.heap_bytes
    longest = longest.at[node].set(jnp.where(ok, 0, longest[node]))

    def up(i, carry):
        longest, n, trace, nsteps = carry
        parent = n >> 1
        active = ok & (parent >= 1)
        p = jnp.maximum(parent, 1)
        newval = jnp.maximum(longest[2 * p], longest[2 * p + 1])
        longest = longest.at[p].set(jnp.where(active, newval, longest[p]))
        trace = trace.at[cfg.depth + 1 + i].set(jnp.where(active, p, INVALID))
        nsteps = nsteps + jnp.where(active, 1, 0)
        return longest, jnp.where(active, p, jnp.int32(0)), trace, nsteps

    longest, _, trace, levels_up = lax.fori_loop(
        0, cfg.depth, up, (longest, node, trace, jnp.int32(0))
    )

    offset = jnp.where(ok, offset, INVALID)
    ev = BuddyEvent(ok=ok, levels_down=levels_down, levels_up=levels_up, trace=trace)
    return BuddyState(longest=longest), offset, ev


def free(cfg: BuddyConfig, st: BuddyState, offset, size):
    """Free a block previously allocated at `offset` with request `size`."""
    size = _round_size(cfg, size)
    node = (offset + cfg.heap_bytes) // size
    valid = (offset >= 0) & (offset < cfg.heap_bytes) & (st.longest[node] == 0)

    longest = st.longest.at[node].set(jnp.where(valid, size, st.longest[node]))
    trace0 = jnp.full((cfg.trace_len,), INVALID, dtype=jnp.int32)
    trace0 = trace0.at[0].set(node)

    def up(i, carry):
        longest, n, nsize, trace, nsteps = carry
        parent = n >> 1
        active = valid & (parent >= 1)
        p = jnp.maximum(parent, 1)
        psize = nsize << 1
        l, r = longest[2 * p], longest[2 * p + 1]
        both_free = (l == nsize) & (r == nsize)
        newval = jnp.where(both_free, psize, jnp.maximum(l, r))
        longest = longest.at[p].set(jnp.where(active, newval, longest[p]))
        trace = trace.at[1 + i].set(jnp.where(active, p, INVALID))
        nsteps = nsteps + jnp.where(active, 1, 0)
        return longest, jnp.where(active, p, jnp.int32(0)), psize, trace, nsteps

    longest, _, _, trace, levels_up = lax.fori_loop(
        0, cfg.depth, up, (longest, node, size, trace0, jnp.int32(0))
    )
    ev = BuddyEvent(
        ok=valid, levels_down=jnp.int32(0), levels_up=levels_up, trace=trace
    )
    return BuddyState(longest=longest), ev


def alloc_batch(cfg: BuddyConfig, st: BuddyState, sizes):
    """Serially service a [B] batch of allocs (models the shared-mutex backend)."""

    def step(st, size):
        st, off, ev = alloc(cfg, st, size)
        return st, (off, ev)

    st, (offs, evs) = lax.scan(step, st, sizes)
    return st, offs, evs


def free_batch(cfg: BuddyConfig, st: BuddyState, offsets, sizes):
    def step(st, x):
        off, size = x
        st, ev = free(cfg, st, off, size)
        return st, ev

    st, evs = lax.scan(step, st, (offsets, sizes))
    return st, evs


def free_bytes(cfg: BuddyConfig, st: BuddyState):
    """Total free bytes = heap - allocated bytes.

    In the ``longest[]`` encoding, allocating node X sets longest[X]=0 but
    leaves X's descendants *stale* at their full sizes (the subtree was
    wholly free when X was chosen). Hence X was allocated-as-a-block iff
    longest[X]==0 and (X is a leaf, or both children read stale-full).
    An inner node with longest==0 whose children were allocated individually
    has children with longest==0 (not full), so the test is exact.
    """
    n = cfg.n_nodes
    idx = jnp.arange(n, dtype=jnp.int32)
    depth = jnp.where(idx > 0, 31 - lax.clz(jnp.maximum(idx, 1)), 0)
    full = (cfg.heap_bytes >> depth).astype(jnp.int32)
    is_leaf = depth == cfg.depth
    lc = jnp.minimum(2 * idx, n - 1)
    rc = jnp.minimum(2 * idx + 1, n - 1)
    child_full = (full >> 1).astype(jnp.int32)
    stale = (st.longest[lc] == child_full) & (st.longest[rc] == child_full)
    is_blk = (idx > 0) & (st.longest == 0) & (is_leaf | stale)
    allocated = jnp.sum(jnp.where(is_blk, full, 0))
    return jnp.int32(cfg.heap_bytes) - allocated
