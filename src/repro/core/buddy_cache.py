"""Metadata-cache simulators for the buddy allocator's tree traversals.

Two designs from the paper, both consuming the same node-index traces the
allocator emits (`BuddyEvent.trace` / `MallocEvent.trace`):

* `SWBuffer`  — PIM-malloc-SW's *software-managed metadata buffer* (Fig 12a):
  a single contiguous window of metadata words staged in scratchpad. A miss
  flushes the whole buffer and refills it around the requested word
  (coarse-grained), charging one DMA setup + `buf_bytes` of DRAM traffic.

* `BuddyCache` — PIM-malloc-HW/SW's hardware *buddy cache* (Fig 11-13):
  an `n_entries`-way fully-associative CAM of 4-byte metadata words with true
  LRU replacement. A miss fetches ONLY the requested word (fine-grained):
  one DMA setup + `word_bytes` of traffic, evicting the LRU entry
  (`lookup_bc` / `read_bc` / `write_bc` semantics).

Metadata addressing follows the paper's 2-bit-per-node packing: 16 tree
nodes per 4-byte word, so `word = node // 16` and a 16-entry cache holds
64 B = 256 nodes — exactly Fig 15's saturation arithmetic.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
from jax import lax

NODES_PER_WORD = 16  # 2 bits/node, 4-byte words
WORD_BYTES = 4


@dataclasses.dataclass(frozen=True)
class SWBufferConfig:
    """Software-managed metadata buffer: a *direct-mapped* line cache.

    'Caching recently accessed metadata and its neighboring entries' (paper
    Sec 3.2): a miss flushes the mapped line and DMAs a contiguous
    `line_bytes` block around the requested word. Coarse-grained management
    (whole-line flush+refill, trivial index mapping) is what a wimpy DPU can
    afford in software; the paper's attempted SW LRU was a net loss
    (Sec 4.2), so no LRU here — that is the HW buddy cache's edge.

    Default: 512 B of the 64 KB WRAM (shared by up to 24 tasklets' stacks and
    application working set), 64 B lines -> 8 lines. Direct mapping makes the
    buddy's top-of-tree words conflict with deep-level words, reproducing the
    thrash the HW cache's associativity + LRU eliminates.
    """

    buf_bytes: int = 512
    line_bytes: int = 64

    @property
    def n_lines(self) -> int:
        return self.buf_bytes // self.line_bytes

    @property
    def line_words(self) -> int:
        return self.line_bytes // WORD_BYTES


class SWBufferState(NamedTuple):
    tags: jnp.ndarray  # int32[n_lines] resident line address, -1 = empty


def sw_buffer_init(cfg: SWBufferConfig) -> SWBufferState:
    return SWBufferState(tags=jnp.full((cfg.n_lines,), -1, jnp.int32))


def sw_buffer_access(cfg: SWBufferConfig, st: SWBufferState, node):
    """One metadata access. Returns (state, hit bool, dram_bytes int32)."""
    valid = node >= 0
    word = jnp.maximum(node, 0) // NODES_PER_WORD
    line = word // cfg.line_words
    idx = line % cfg.n_lines
    hit = valid & (st.tags[idx] == line)
    miss = valid & ~hit
    tags = st.tags.at[idx].set(jnp.where(miss, line, st.tags[idx]))
    dram = jnp.where(miss, cfg.line_bytes, 0).astype(jnp.int32)
    return SWBufferState(tags=tags), hit, dram


@dataclasses.dataclass(frozen=True)
class BuddyCacheConfig:
    n_entries: int = 16  # 16 x 4 B = 64 B (paper's design point)


class BuddyCacheState(NamedTuple):
    tags: jnp.ndarray       # int32[E] word addresses, -1 invalid
    last_used: jnp.ndarray  # int32[E] LRU timestamps (-1 invalid => first victim)
    clock: jnp.ndarray      # int32 global access counter


def buddy_cache_init(cfg: BuddyCacheConfig) -> BuddyCacheState:
    return BuddyCacheState(
        tags=jnp.full((cfg.n_entries,), -1, jnp.int32),
        last_used=jnp.full((cfg.n_entries,), -1, jnp.int32),
        clock=jnp.int32(0),
    )


def buddy_cache_access(cfg: BuddyCacheConfig, st: BuddyCacheState, node):
    """lookup_bc + (read_bc | evict + write_bc). Returns (state, hit, dram_bytes)."""
    del cfg
    valid = node >= 0
    word = jnp.maximum(node, 0) // NODES_PER_WORD
    match = st.tags == word
    hit = valid & jnp.any(match)
    hit_idx = jnp.argmax(match)
    victim = jnp.argmin(st.last_used)  # invalid entries (-1) chosen first
    idx = jnp.where(hit, hit_idx, victim)
    do = valid
    tags = st.tags.at[idx].set(jnp.where(do, word, st.tags[idx]))
    last = st.last_used.at[idx].set(jnp.where(do, st.clock, st.last_used[idx]))
    clock = st.clock + do.astype(jnp.int32)
    dram = jnp.where(valid & ~hit, WORD_BYTES, 0).astype(jnp.int32)
    return BuddyCacheState(tags=tags, last_used=last, clock=clock), hit, dram


class TraceStats(NamedTuple):
    hits: jnp.ndarray        # int32[...]: per-op metadata hits
    misses: jnp.ndarray      # int32[...]
    dram_bytes: jnp.ndarray  # int32[...]


def simulate_traces(access_fn, cache_state, traces):
    """Run a cache sim over [B, L] node traces (ops in serialization order).

    access_fn: (state, node) -> (state, hit, dram_bytes)
    Returns (final_state, TraceStats with [B] per-op aggregates).
    """

    def per_op(cache_state, trace):
        def per_access(carry, node):
            cs, h, m, d = carry
            cs, hit, dram = access_fn(cs, node)
            valid = node >= 0
            h = h + (valid & hit).astype(jnp.int32)
            m = m + (valid & ~hit).astype(jnp.int32)
            d = d + dram
            return (cs, h, m, d), None

        (cache_state, h, m, d), _ = lax.scan(
            per_access, (cache_state, jnp.int32(0), jnp.int32(0), jnp.int32(0)), trace
        )
        return cache_state, (h, m, d)

    cache_state, (h, m, d) = lax.scan(per_op, cache_state, traces)
    return cache_state, TraceStats(hits=h, misses=m, dram_bytes=d)
