"""repro.core — PIM-malloc: the paper's contribution as composable JAX modules.

Layers (bottom-up):
  buddy        tensorized array-buddy allocator (backend / straw-man)
  thread cache + hierarchy: pim_malloc (PIM-malloc-SW semantics)
  buddy_cache  metadata-cache simulators (SW buffer vs HW CAM+LRU)
  cost_model   DPU cycle model (UPMEM timing)
  system       composed design points: strawman / sw / hwsw
  design_space Table 1 / Fig 5 exploration
  api          Table 2 paper-facing API
"""
from . import (api, buddy, buddy_cache, cost_model, design_space, oracle,
               pim_malloc, system)
from .api import Allocator, initAllocator
from .buddy import BuddyConfig, BuddyState
from .pim_malloc import PimMallocConfig, PimMallocState
from .system import SystemConfig, SystemState, malloc_round, free_round, system_init

__all__ = [
    "api", "buddy", "buddy_cache", "cost_model", "design_space", "oracle",
    "pim_malloc", "system", "Allocator", "initAllocator", "BuddyConfig",
    "BuddyState", "PimMallocConfig", "PimMallocState", "SystemConfig",
    "SystemState", "malloc_round", "free_round", "system_init",
]
