"""repro.core — PIM-malloc: the paper's contribution as composable JAX modules.

Layers (bottom-up):
  buddy        tensorized array-buddy allocator (backend / straw-man)
  thread cache + hierarchy: pim_malloc (PIM-malloc-SW semantics, incl.
               realloc/calloc)
  buddy_cache  metadata-cache simulators (SW buffer vs HW CAM+LRU)
  cost_model   DPU cycle model (UPMEM timing)
  system       composed design points: strawman / sw / hwsw / pallas — each
               registers a cost-instrumented `heap.step` backend
  heap         THE public allocator surface: AllocRequest/AllocResponse
               protocol, `step`, `MultiCoreHeap` (vmap over cores)
  design_space Table 1 / Fig 5 exploration
  api          Table 2 paper-facing facade over heap.step
"""
from . import (api, buddy, buddy_cache, cost_model, design_space, heap,
               oracle, pim_malloc, system)
from .api import Allocator, initAllocator
from .buddy import BuddyConfig, BuddyState
from .heap import (AllocRequest, AllocResponse, MultiCoreHeap, OP_CALLOC,
                   OP_FREE, OP_MALLOC, OP_NOOP, OP_REALLOC)
from .pim_malloc import PimMallocConfig, PimMallocState
from .system import SystemConfig, SystemState, malloc_round, free_round, system_init

__all__ = [
    "api", "buddy", "buddy_cache", "cost_model", "design_space", "heap",
    "oracle", "pim_malloc", "system", "Allocator", "initAllocator",
    "AllocRequest", "AllocResponse", "MultiCoreHeap", "OP_NOOP", "OP_MALLOC",
    "OP_FREE", "OP_REALLOC", "OP_CALLOC", "BuddyConfig", "BuddyState",
    "PimMallocConfig", "PimMallocState", "SystemConfig", "SystemState",
    "malloc_round", "free_round", "system_init",
]
