"""Heap-health snapshots: fragmentation / utilization reporting.

`SystemState.telem` (see :class:`repro.core.system.HeapTelemetry`) carries
the round-by-round counters — live rounded bytes and their high-water mark
— advanced inside `system._price_round` identically for every backend.
This module derives the *snapshot* side of heap health from the metadata
state itself:

  * total buddy free bytes and the per-level histogram of maximal free
    blocks (external fragmentation: free capacity that exists only in
    pieces smaller than a request class),
  * bytes parked in the thread-cache frontend (carved but not handed out),
  * the conservation law the two sides must satisfy together:

        live_bytes + free_bytes + cached_frontend_bytes == heap_bytes

    for any well-formed request stream (pinned in tests/test_telemetry.py).

Everything here is host-side NumPy over a state snapshot — reporting code,
not part of the jitted step.
"""
from __future__ import annotations

import numpy as np

from .buddy import BuddyConfig


def _node_levels(bcfg: BuddyConfig):
    """(level[i], full_size[i]) for the 1-indexed longest[] array."""
    n = bcfg.n_nodes
    idx = np.arange(n)
    level = np.zeros(n, np.int64)
    level[1:] = np.floor(np.log2(idx[1:])).astype(np.int64)
    full = np.where(idx > 0, bcfg.heap_bytes >> level, 0).astype(np.int64)
    return level, full


def free_block_histogram(bcfg: BuddyConfig, longest) -> np.ndarray:
    """Count of *maximal* free blocks per buddy level.

    Index ``l`` counts free blocks of exactly ``heap_bytes >> l`` bytes
    (level 0 = the whole heap ... level ``depth`` = ``min_block``) that are
    not contained in a larger free block. The ``longest[]`` encoding leaves
    the descendants of an allocated node stale at their full sizes, so a
    node only counts as free when no ancestor is allocated-as-a-block
    (same subtlety as `buddy.free_bytes`).
    """
    longest = np.asarray(longest, np.int64)
    n = bcfg.n_nodes
    level, full = _node_levels(bcfg)
    is_leaf = level == bcfg.depth
    lc = np.minimum(2 * np.arange(n), n - 1)
    rc = np.minimum(2 * np.arange(n) + 1, n - 1)
    stale = (longest[lc] == full // 2) & (longest[rc] == full // 2)
    is_blk = (np.arange(n) > 0) & (longest == 0) & (is_leaf | stale)

    # covered[i]: some ancestor of i was allocated as a block (its stale
    # descendants must not read as free)
    covered = np.zeros(n, bool)
    for lvl in range(1, bcfg.depth + 1):
        idx = np.arange(1 << lvl, min(1 << (lvl + 1), n))
        covered[idx] = covered[idx >> 1] | is_blk[idx >> 1]

    truly_free = (np.arange(n) > 0) & (longest == full) & ~covered
    parent_free = np.zeros(n, bool)
    idx = np.arange(2, n)
    parent_free[idx] = truly_free[idx >> 1]
    maximal = truly_free & ~parent_free

    hist = np.zeros(bcfg.depth + 1, np.int64)
    np.add.at(hist, level[maximal], 1)
    return hist


def free_bytes_from_histogram(bcfg: BuddyConfig, hist) -> int:
    sizes = bcfg.heap_bytes >> np.arange(len(hist))
    return int((np.asarray(hist, np.int64) * sizes).sum())


def frontend_cached_bytes(cfg, state) -> int:
    """Bytes parked in the frontend layer: free sub-blocks in the per-thread
    LIFO freelists (0 for strawman), plus — for the ``arena``/``tlregion``
    kinds — every arena-region byte not currently placed (unbumped space AND
    retired holes: neither is live, neither is buddy-free, so conservation
    requires the frontend to own them until the next epoch reset)."""
    if cfg.kind == "strawman":
        return 0
    counts = np.asarray(state.alloc.counts, np.int64)
    class_sizes = np.asarray(cfg.pm.size_classes, np.int64)
    cached = int((counts * class_sizes[None, :]).sum())
    if cfg.kind in ("arena", "tlregion"):
        from . import arena
        cached += arena.arena_bytes(cfg) - int(
            np.asarray(arena.arena_live_bytes(cfg, state.cls_map)))
    return cached


def fleet_pressure(state) -> dict:
    """Per-rank heap-pressure signal from a fleet state's telemetry.

    ``state.telem`` carries per-core live/high-water counters with leading
    [R, C] axes (the fleet transform stack vmaps the per-core state).
    Returns host-side arrays: ``live`` / ``hwm`` as [R, C] int64 plus the
    per-rank maxima (the hottest core per rank is the signal that matters —
    one overloaded heap stalls its whole rank's round barrier).
    """
    live = np.asarray(state.telem.live_bytes, np.int64)
    hwm = np.asarray(state.telem.hwm_bytes, np.int64)
    if live.ndim != 2:
        raise ValueError(f"fleet_pressure wants [R, C] telemetry, "
                         f"got shape {live.shape}")
    return {
        "live": live,
        "hwm": hwm,
        "rank_live": live.max(axis=1),
        "rank_hwm": hwm.max(axis=1),
    }


def hwm_divergence(rank_hwm, ratio: float = 2.0, min_bytes: int = 1) -> dict:
    """Decide whether per-rank high-water marks have diverged.

    ``trigger`` is True when the hottest rank's HWM exceeds the coldest
    rank's by more than ``ratio`` AND the hottest HWM is at least
    ``min_bytes`` (a floor so an idle fleet, where the coldest rank may
    still be at 0, does not divide-by-zero its way into migrating nothing).
    The coldest rank is compared at ``max(coldest, min_bytes)``, so the
    threshold is exactly ``hottest > ratio * max(coldest, min_bytes)``.
    Pure and host-side — pinned by tests/test_elastic_fleet.py.
    """
    h = np.asarray(rank_hwm, np.int64).reshape(-1)
    if h.shape[0] == 0:
        raise ValueError("empty rank_hwm")
    hot = int(np.argmax(h))
    cold = int(np.argmin(h))
    floor = max(int(h[cold]), int(min_bytes))
    return {
        "hottest_rank": hot,
        "coldest_rank": cold,
        "hottest_hwm": int(h[hot]),
        "coldest_hwm": int(h[cold]),
        "ratio": float(h[hot]) / float(floor),
        "trigger": bool(h[hot] >= int(min_bytes)
                        and float(h[hot]) > ratio * floor),
    }


def snapshot(cfg, state) -> dict:
    """One heap-health report from a (SystemConfig, SystemState) pair.

    Plain Python numbers/lists — ready for the JSON bench schema. Keys:
    ``live_bytes``, ``hwm_bytes``, ``free_bytes``, ``cached_frontend_bytes``,
    ``heap_bytes``, ``utilization``, ``hwm_utilization``,
    ``largest_free_block``, ``external_frag``, ``free_blocks_per_level``,
    ``conservation_residual`` (0 for well-formed streams).
    """
    bcfg = cfg.straw.buddy_cfg if cfg.kind == "strawman" else cfg.pm.buddy_cfg
    longest = np.asarray(state.alloc.buddy.longest)
    hist = free_block_histogram(bcfg, longest)
    free_b = free_bytes_from_histogram(bcfg, hist)
    cached = frontend_cached_bytes(cfg, state)
    live = int(np.asarray(state.telem.live_bytes))
    hwm = int(np.asarray(state.telem.hwm_bytes))
    largest = int(longest[1]) if longest.shape[0] > 1 else 0
    heap = int(cfg.heap_bytes)
    return {
        "live_bytes": live,
        "hwm_bytes": hwm,
        "free_bytes": free_b,
        "cached_frontend_bytes": cached,
        "heap_bytes": heap,
        "utilization": live / heap,
        "hwm_utilization": hwm / heap,
        "largest_free_block": largest,
        # classic external-fragmentation metric: the share of free memory
        # not reachable by a single largest-block request
        "external_frag": (1.0 - largest / free_b) if free_b > 0 else 0.0,
        "free_blocks_per_level": hist.tolist(),
        "conservation_residual": heap - (live + free_b + cached),
    }
