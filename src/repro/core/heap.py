"""The transform-native allocator surface: one request/response protocol.

Every allocator design point in this repo (``strawman``, ``sw``, ``hwsw``,
``pallas`` — the fused-kernel fast path — and ``sanitizer``, the
shadow-heap misuse detector) serves the same typed protocol:

    state, response = heap.step(cfg, state, request)

``AllocRequest`` carries one op per hardware thread — MALLOC / FREE /
REALLOC / CALLOC / NOOP — as a fixed-shape pytree of int32[T] leaves, and
``AllocResponse`` returns pointers, result paths, and the DPU cost model's
per-thread latency / metadata-traffic accounting.  ``step`` is pure and
shape-stable, so the transforms compose the way the paper's scaling story
requires:

  * one PIM core      : ``jax.jit(partial(heap.step, cfg))``
  * C cores, one rank : ``jax.vmap`` — see :class:`MultiCoreHeap`
  * a mesh of ranks   : ``shard_map`` of the vmapped step — see
    :class:`ShardedHeap` (metadata never leaves a core OR a rank — the
    PIM-Metadata/PIM-Executed placement of Fig 5 at fleet scale)

Backends register through :func:`register`; the implementations live in
``repro.core.system`` (cost-model instrumented) on top of the functional
allocators in ``repro.core.pim_malloc`` / ``repro.core.buddy``.  The
paper-facing Table 2 names (``initAllocator`` / ``pimMalloc`` / ``pimFree``
/ ``pimRealloc`` / ``pimCalloc``) are a thin stateful facade over this
module — see ``repro.core.api``.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

# Per-thread op codes (int32). CALLOC is MALLOC + zero-fill cost; the request
# carries the total byte count (nmemb * size), see `calloc_request`.
# EPOCH_RESET is the arena frontend's bulk-free: every arena-resident block
# is retired in O(1) (non-arena backends treat it as an idle round).
OP_NOOP = 0
OP_MALLOC = 1
OP_FREE = 2
OP_REALLOC = 3
OP_CALLOC = 4
OP_EPOCH_RESET = 5

OP_NAMES = {OP_NOOP: "noop", OP_MALLOC: "malloc", OP_FREE: "free",
            OP_REALLOC: "realloc", OP_CALLOC: "calloc",
            OP_EPOCH_RESET: "epoch_reset"}

NULL_PTR = -1  # the protocol's NULL: free(-1) is benign, alloc failure returns it


class AllocRequest(NamedTuple):
    """One batched request round: one op per hardware thread.

    op   int32[T]  OP_* code
    size int32[T]  bytes (MALLOC/CALLOC/REALLOC); ignored for FREE/NOOP
    ptr  int32[T]  heap offset (FREE/REALLOC); ignored otherwise (-1)
    """

    op: jnp.ndarray
    size: jnp.ndarray
    ptr: jnp.ndarray


class AllocResponse(NamedTuple):
    """Per-thread results of one protocol round.

    ptr          int32[T]   resulting pointer: new block for MALLOC/CALLOC,
                            surviving block for REALLOC, -1 for FREE/NOOP/fail
    ok           bool[T]    op succeeded (NOOP -> False)
    path         int32[T]   legacy path code (0 hit / 1 refill / 2 bypass /
                            3 fail for allocs; 0 small / 1 big / 2 dropped
                            for frees; -1 idle)
    moved        bool[T]    REALLOC relocated the block (alloc+copy+free)
    latency_cyc  float32[T] DPU cycles incl. mutex queuing + copy/zero DMA
    backend_cyc  float32[T] buddy-backend service cycles (excl. queuing)
    meta_hits    int32[T]   metadata-cache hits charged to this thread
    meta_misses  int32[T]
    dram_bytes   int32[T]
    """

    ptr: jnp.ndarray
    ok: jnp.ndarray
    path: jnp.ndarray
    moved: jnp.ndarray
    latency_cyc: jnp.ndarray
    backend_cyc: jnp.ndarray
    meta_hits: jnp.ndarray
    meta_misses: jnp.ndarray
    dram_bytes: jnp.ndarray


# ---------------------------------------------------------------------------
# request builders
# ---------------------------------------------------------------------------
# Builders accept any leading batch shape — the thread axis is last, so a
# [T], [C, T] or [R, C, T] argument yields a same-shaped request (this is
# how FleetRouter / fig_fleet call them). An `active` mask broadcasts
# NumPy-style against the data (trailing axes align); pass it pre-shaped —
# the MultiCoreHeap/ShardedHeap wrappers instead vmap the builders so
# leading-axis ([C] / [R, C]) masks select cores/ranks.
def _mask(active, shape):
    if active is None:
        return jnp.ones(shape, bool)
    return jnp.broadcast_to(jnp.asarray(active, bool), shape)


def noop_request(num_threads: int) -> AllocRequest:
    z = jnp.zeros((num_threads,), jnp.int32)
    return AllocRequest(op=z, size=z, ptr=z - 1)


def malloc_request(sizes, active=None) -> AllocRequest:
    sizes = jnp.asarray(sizes, jnp.int32)
    on = _mask(active, sizes.shape) & (sizes > 0)
    return AllocRequest(op=jnp.where(on, OP_MALLOC, OP_NOOP).astype(jnp.int32),
                        size=jnp.where(on, sizes, 0),
                        ptr=jnp.full_like(sizes, -1))


def free_request(ptrs, active=None) -> AllocRequest:
    """free(ptr) with C semantics: NULL (== -1) frees are benign no-ops;
    every other pointer — including garbage negatives and out-of-heap
    offsets — is passed through so the backend can count it against
    `Stats.dropped_frees` (path 2) instead of silently vanishing."""
    ptrs = jnp.asarray(ptrs, jnp.int32)
    on = _mask(active, ptrs.shape) & (ptrs != NULL_PTR)
    return AllocRequest(op=jnp.where(on, OP_FREE, OP_NOOP).astype(jnp.int32),
                        size=jnp.zeros_like(ptrs),
                        ptr=jnp.where(on, ptrs, -1))


def realloc_request(ptrs, sizes, active=None) -> AllocRequest:
    """realloc(ptr, size) with C semantics, enforced for every backend:

      * ptr < 0, size > 0   -> plain malloc(size)   (realloc(NULL, n))
      * ptr >= 0, size == 0 -> free(ptr)            (realloc(p, 0))
      * ptr < 0, size == 0  -> NOOP                 (realloc(NULL, 0))
      * size < 0            -> failing request: size_t-negative means a
        huge allocation, so the op keeps REALLOC/MALLOC form with an
        unsatisfiable INT32_MAX size — it fails (path 3) and a live old
        block stays intact, exactly like C realloc on failure.
    """
    ptrs = jnp.asarray(ptrs, jnp.int32)
    sizes = jnp.asarray(sizes, jnp.int32)
    ptrs, sizes = jnp.broadcast_arrays(ptrs, sizes)
    on = _mask(active, ptrs.shape)
    eff = jnp.where(sizes < 0, jnp.int32(jnp.iinfo(jnp.int32).max), sizes)
    has_ptr = ptrs >= 0
    op = jnp.where(
        ~on, OP_NOOP,
        jnp.where(has_ptr & (eff > 0), OP_REALLOC,
                  jnp.where(has_ptr, OP_FREE,
                            jnp.where(eff > 0, OP_MALLOC, OP_NOOP))))
    keep_ptr = on & has_ptr
    return AllocRequest(op=op.astype(jnp.int32),
                        size=jnp.where(on & (eff > 0), eff, 0),
                        ptr=jnp.where(keep_ptr, ptrs, -1))


def epoch_reset_request(num_threads: int, active=None) -> AllocRequest:
    """EPOCH_RESET: bulk-retire the arena frontend's current epoch.

    On the shared ``arena`` kind one resetting thread suffices (the op is
    idempotent within a round); on ``tlregion`` each active thread resets its
    own region. Backends without an arena frontend serve it as an idle round
    (ok=False, path -1), so mixed-kind tapes replay everywhere.
    """
    z = jnp.zeros((num_threads,), jnp.int32)
    on = _mask(active, z.shape)
    return AllocRequest(
        op=jnp.where(on, OP_EPOCH_RESET, OP_NOOP).astype(jnp.int32),
        size=z, ptr=z - 1)


def calloc_request(nmemb, sizes, active=None) -> AllocRequest:
    """calloc(nmemb, size): total bytes with the C overflow guard — an
    overflowing product becomes a failing (INT32_MAX) request, never a small
    wrapped one."""
    from .pim_malloc import total_calloc_bytes
    sizes = jnp.asarray(sizes, jnp.int32)
    total = total_calloc_bytes(nmemb, sizes)
    on = _mask(active, total.shape) & (total > 0)
    return AllocRequest(op=jnp.where(on, OP_CALLOC, OP_NOOP).astype(jnp.int32),
                        size=jnp.where(on, total, 0),
                        ptr=jnp.full_like(total, -1))


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------
REGISTRY: dict[str, Callable] = {}
_BACKENDS = REGISTRY  # legacy alias


def register(kind: str):
    """Register a backend step: fn(cfg, state, AllocRequest) -> (state, AllocResponse)."""

    def deco(fn):
        REGISTRY[kind] = fn
        return fn

    return deco


def kinds() -> tuple:
    _ensure_backends()
    return tuple(sorted(REGISTRY))


def _ensure_backends():
    if not REGISTRY:
        from . import system  # noqa: F401  (registers strawman/sw/hwsw/pallas)


def init(cfg, prepopulate: bool = True):
    """Fresh heap state for `cfg` (a `system.SystemConfig`)."""
    from . import system
    return system.system_init(cfg, prepopulate=prepopulate)


def step(cfg, state, request: AllocRequest):
    """Serve one batched request round on the backend named by `cfg.kind`."""
    _ensure_backends()
    return _BACKENDS[cfg.kind](cfg, state, request)


# ---------------------------------------------------------------------------
# scan / multi-core drivers
# ---------------------------------------------------------------------------
def run_rounds(cfg, state, requests: AllocRequest):
    """scan `step` over an [R, T]-leaved request tape.

    Returns (state, AllocResponse with [R, T] leaves).
    """

    def body(st, req):
        st, resp = step(cfg, st, req)
        return st, resp

    return lax.scan(body, state, requests)


def run_alloc_free_rounds(cfg, state, sizes_rounds):
    """Fig 6's (de)allocation loop: each round mallocs sizes[r] then frees
    the pointers it just received. Returns (state, alloc resp, free resp)."""

    def body(st, sizes):
        st, ra = step(cfg, st, malloc_request(sizes))
        st, rf = step(cfg, st, free_request(ra.ptr))
        return st, (ra, rf)

    state, (ra, rf) = lax.scan(body, state, sizes_rounds)
    return state, ra, rf


def multicore_init(cfg, num_cores: int, prepopulate: bool = True):
    """Stacked per-core states: every leaf gains a leading [C] axis."""
    st = init(cfg, prepopulate=prepopulate)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (num_cores,) + x.shape), st)


def multicore_step(cfg, states, requests: AllocRequest):
    """vmap of `step` over the core axis: requests are [C, T]-leaved."""
    return jax.vmap(functools.partial(step, cfg))(states, requests)


class MultiCoreHeap:
    """C independent per-core heaps behind one `[C, T]` batched entry point.

    The whole PIM system is literally `jit(vmap(step))` — core i's requests
    can never perturb core j's state because the states are disjoint slices
    of one stacked pytree. A TPU-mesh deployment shard_maps this same step
    over a rank axis on top (see :class:`ShardedHeap` and
    `repro.launch.fleet`).
    """

    def __init__(self, cfg, num_cores: int, prepopulate: bool = True):
        self.cfg = cfg
        self.num_cores = num_cores
        self.state = multicore_init(cfg, num_cores, prepopulate=prepopulate)
        self._step = jax.jit(jax.vmap(functools.partial(step, cfg)))

    @property
    def num_threads(self) -> int:
        return self.cfg.num_threads

    def step(self, request: AllocRequest) -> AllocResponse:
        """Serve a [C, T] request batch; advances the stacked state."""
        self.state, resp = self._step(self.state, request)
        return resp

    # vmap (rather than relying on builder broadcasting) so a per-core
    # [C]-shaped active mask keeps masking whole cores, not thread slots —
    # the same contract for all four builders (pinned in tests/test_heap_api)
    def _core_mask(self, active):
        if active is None:
            return None
        return jnp.broadcast_to(jnp.asarray(active, bool), (self.num_cores,))

    def _v(self, build, *args, active=None):
        return self.step(jax.vmap(build)(*args, self._core_mask(active)))

    def malloc(self, sizes, active=None) -> AllocResponse:
        return self._v(malloc_request, jnp.asarray(sizes, jnp.int32),
                       active=active)

    def free(self, ptrs, active=None) -> AllocResponse:
        return self._v(free_request, jnp.asarray(ptrs, jnp.int32),
                       active=active)

    def realloc(self, ptrs, sizes, active=None) -> AllocResponse:
        return self._v(realloc_request, jnp.asarray(ptrs, jnp.int32),
                       jnp.asarray(sizes, jnp.int32), active=active)

    def calloc(self, nmemb, sizes, active=None) -> AllocResponse:
        return self._v(calloc_request, jnp.asarray(nmemb, jnp.int32),
                       jnp.asarray(sizes, jnp.int32), active=active)


# ---------------------------------------------------------------------------
# fleet tier: shard_map over a rank mesh
# ---------------------------------------------------------------------------
def sharded_init(cfg, num_ranks: int, num_cores: int, prepopulate: bool = True):
    """Stacked fleet state: every leaf gains leading [R, C] axes."""
    st = multicore_init(cfg, num_cores, prepopulate=prepopulate)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (num_ranks,) + x.shape), st)


def sharded_step(cfg, states, requests: AllocRequest):
    """vmap of `multicore_step` over the rank axis: requests are [R, C, T].

    This is the per-device body a ShardedHeap shard_maps over the rank axis;
    on its own it is the single-device fallback (identical results)."""
    return jax.vmap(functools.partial(multicore_step, cfg))(states, requests)


def sharded_inner(cfg, num_ranks: int, mesh=None, axis_name: str = "ranks"):
    """Build the fleet-round step fn([R,C]-state, [R,C,T]-request).

    The one place the mesh plumbing lives: returns ``(fn, mesh)`` where `fn`
    is :func:`sharded_step` wrapped in ``shard_map`` over a 1-D rank mesh
    (``mesh=None`` builds one over the local devices; ``mesh=False`` skips
    shard_map — the pure-vmap fallback, with ``mesh`` returned as None).
    Shared by :class:`ShardedHeap` (one round per call) and the FleetServe
    scan driver (`repro.launch.serve_fleet`, many rounds per call), so both
    tiers serve bitwise-identical results from the same transform stack.
    """
    inner = functools.partial(sharded_step, cfg)
    if mesh is None:
        from repro.parallel.meshctx import make_rank_mesh
        mesh = make_rank_mesh(num_ranks, axis_name)
    if mesh is False:
        return inner, None
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec
    axis_name = mesh.axis_names[0]
    if num_ranks % mesh.shape[axis_name]:
        raise ValueError(
            f"num_ranks={num_ranks} not divisible by mesh axis "
            f"{axis_name}={mesh.shape[axis_name]}")
    spec = PartitionSpec(axis_name)
    return shard_map(inner, mesh=mesh, in_specs=(spec, spec),
                     out_specs=(spec, spec), check_rep=False), mesh


class ShardedHeap:
    """R ranks x C cores of independent heaps behind one [R, C, T] entry point.

    The third tier of the transform stack: ``shard_map`` (over a 1-D
    ``jax.sharding.Mesh`` of ranks) of the vmapped :func:`step`. Rank shards
    hold disjoint slices of one stacked state pytree, so metadata never
    crosses a core OR a rank boundary — the paper's PIM-Metadata /
    PIM-Executed placement at fleet scale (2560-DPU claim, Fig 5). The heap
    state argument is donated to the jitted step, so per-round updates reuse
    the state buffers in place instead of an O(heap) copy per protocol round
    (backends without donation, e.g. CPU, silently fall back to copying).

    ``mesh=None`` builds a 1-D mesh over the local devices (1-device on CPU
    CI — the whole path still compiles through shard_map); ``mesh=False``
    skips shard_map entirely and runs the pure vmap fallback. Both must be
    bitwise-identical to :class:`MultiCoreHeap` per (rank, core) — pinned in
    tests/test_sharded_heap.py.
    """

    def __init__(self, cfg, num_ranks: int, num_cores: int, mesh=None,
                 axis_name: str = "ranks", prepopulate: bool = True,
                 donate: bool = True):
        self.cfg = cfg
        self.num_ranks = num_ranks
        self.num_cores = num_cores
        self.state = sharded_init(cfg, num_ranks, num_cores,
                                  prepopulate=prepopulate)
        inner, self.mesh = sharded_inner(cfg, num_ranks, mesh=mesh,
                                         axis_name=axis_name)
        self.donate = donate
        self._step = jax.jit(inner, donate_argnums=(0,) if donate else ())

    @property
    def num_threads(self) -> int:
        return self.cfg.num_threads

    @property
    def shape(self) -> tuple:
        """(R, C, T): one slot per hardware thread in the fleet."""
        return (self.num_ranks, self.num_cores, self.cfg.num_threads)

    def step(self, request: AllocRequest) -> AllocResponse:
        """Serve a [R, C, T] request batch; advances the sharded state."""
        self.state, resp = self._step(self.state, request)
        return resp

    # vmap twice (rather than relying on builder broadcasting) so [R]- or
    # [R, C]-shaped active masks keep masking ranks/cores, not thread slots
    # (an [R] mask broadcasts to [R, C] first — the double vmap needs the
    # mask pre-shaped to the grid)
    def _grid_mask(self, active):
        if active is None:
            return None
        m = jnp.asarray(active, bool)
        m = m.reshape(m.shape + (1,) * (2 - m.ndim))
        return jnp.broadcast_to(m, (self.num_ranks, self.num_cores))

    def _vv(self, build, *args, active=None):
        return self.step(jax.vmap(jax.vmap(build))(
            *args, self._grid_mask(active)))

    def malloc(self, sizes, active=None) -> AllocResponse:
        return self._vv(malloc_request, jnp.asarray(sizes, jnp.int32),
                        active=active)

    def free(self, ptrs, active=None) -> AllocResponse:
        return self._vv(free_request, jnp.asarray(ptrs, jnp.int32),
                        active=active)

    def realloc(self, ptrs, sizes, active=None) -> AllocResponse:
        return self._vv(realloc_request, jnp.asarray(ptrs, jnp.int32),
                        jnp.asarray(sizes, jnp.int32), active=active)

    def calloc(self, nmemb, sizes, active=None) -> AllocResponse:
        return self._vv(calloc_request, jnp.asarray(nmemb, jnp.int32),
                        jnp.asarray(sizes, jnp.int32), active=active)
