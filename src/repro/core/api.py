"""Paper-facing API (Table 2): initAllocator / pimMalloc / pimFree.

Thin, stateful-convenience wrapper over the pure-functional core so the
examples read like the paper's UPMEM programs. For performance-critical /
distributed use, call the pure functions in `repro.core.pim_malloc` (or the
batched `repro.core.system`) directly and manage state explicitly.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import pim_malloc
from .pim_malloc import PimMallocConfig, PimMallocState


class Allocator:
    """Per-PIM-core allocator handle (one heap, T hardware threads)."""

    def __init__(self, heap_bytes: int = 32 * 1024 * 1024,
                 size_classes=(16, 32, 64, 128, 256, 512, 1024, 2048),
                 num_threads: int = 16, prepopulate: bool = True):
        self.cfg = PimMallocConfig(
            heap_bytes=heap_bytes, size_classes=tuple(size_classes),
            num_threads=num_threads,
        )
        self.state: PimMallocState = pim_malloc.init(self.cfg, prepopulate)

    # -- Table 2 API ---------------------------------------------------------
    def pimMalloc(self, size: int, thread: int = 0) -> int:
        sizes = jnp.zeros((self.cfg.num_threads,), jnp.int32).at[thread].set(size)
        active = jnp.zeros((self.cfg.num_threads,), bool).at[thread].set(True)
        self.state, ptrs, _ = pim_malloc.malloc(self.cfg, self.state, sizes, active)
        return int(ptrs[thread])

    def pimFree(self, ptr: int, thread: int = 0) -> None:
        ptrs = jnp.full((self.cfg.num_threads,), -1, jnp.int32).at[thread].set(ptr)
        self.state, _ = pim_malloc.free(self.cfg, self.state, ptrs)

    # -- batched (one request per hardware thread) ----------------------------
    def pimMallocBatch(self, sizes) -> jnp.ndarray:
        sizes = jnp.asarray(sizes, jnp.int32)
        self.state, ptrs, _ = pim_malloc.malloc(self.cfg, self.state, sizes)
        return ptrs

    def pimFreeBatch(self, ptrs) -> None:
        self.state, _ = pim_malloc.free(self.cfg, self.state,
                                        jnp.asarray(ptrs, jnp.int32))

    def gc(self) -> None:
        self.state = pim_malloc.gc(self.cfg, self.state)

    @property
    def stats(self) -> dict:
        return {k: int(v) for k, v in self.state.stats._asdict().items()}


def initAllocator(heap_bytes: int, size_classes=None, **kw) -> Allocator:
    if size_classes is None:
        size_classes = (16, 32, 64, 128, 256, 512, 1024, 2048)
    return Allocator(heap_bytes=heap_bytes, size_classes=size_classes, **kw)
