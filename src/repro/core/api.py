"""Paper-facing API (Table 2): initAllocator / pimMalloc / pimFree /
pimRealloc / pimCalloc.

Thin, stateful-convenience facade over the transform-native protocol in
`repro.core.heap` so the examples read like the paper's UPMEM programs.
Every method builds one `AllocRequest` batching this call's per-thread ops
and runs a single jitted `heap.step` round — there is exactly one compiled
step per (kind, shape), shared by all methods, instead of one scan per
Python-level call. For performance-critical / distributed use, call
`heap.step` (or `heap.MultiCoreHeap`) directly and manage state explicitly.

Migration from the pre-protocol Allocator: constructor args and
`pimMalloc` / `pimFree` / `pimMallocBatch` / `pimFreeBatch` / `gc` /
`stats` are unchanged; the facade now also exposes `pimRealloc` /
`pimCalloc`, a `kind=` selector ("sw" default, "hwsw", "strawman",
"pallas" — the fused-kernel fast path, "sanitizer" — the shadow-heap
misuse detector, see docs/analysis.md), the
raw `request()` entry point, and `last_info` (per-thread DPU latencies of
the most recent round). See docs/api.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import heap, pim_malloc
from .heap import AllocRequest, AllocResponse
from .pim_malloc import PimMallocConfig
from .system import SystemConfig, SystemState


class Allocator:
    """Per-PIM-core allocator handle (one heap, T hardware threads)."""

    def __init__(self, heap_bytes: int = 32 * 1024 * 1024,
                 size_classes=(16, 32, 64, 128, 256, 512, 1024, 2048),
                 num_threads: int = 16, prepopulate: bool = True,
                 kind: str = "sw"):
        pm = PimMallocConfig(
            heap_bytes=heap_bytes, size_classes=tuple(size_classes),
            num_threads=num_threads,
        )
        self.cfg = SystemConfig(kind=kind, heap_bytes=heap_bytes,
                                num_threads=num_threads, pm=pm)
        self.state: SystemState = heap.init(self.cfg, prepopulate)
        self._step = jax.jit(functools.partial(heap.step, self.cfg))
        self.last_info: AllocResponse | None = None

    # -- protocol entry point -------------------------------------------------
    def request(self, req: AllocRequest) -> AllocResponse:
        """Serve one batched request round; advances the heap state."""
        self.state, resp = self._step(self.state, req)
        self.last_info = resp
        return resp

    def _one(self, build, thread: int):
        T = self.cfg.num_threads
        active = jnp.zeros((T,), bool).at[thread].set(True)
        return self.request(build(active))

    # -- Table 2 API ---------------------------------------------------------
    def pimMalloc(self, size: int, thread: int = 0) -> int:
        resp = self._one(lambda a: heap.malloc_request(
            jnp.full((self.cfg.num_threads,), size, jnp.int32), a), thread)
        return int(resp.ptr[thread])

    def pimFree(self, ptr: int, thread: int = 0) -> None:
        self._one(lambda a: heap.free_request(
            jnp.full((self.cfg.num_threads,), ptr, jnp.int32), a), thread)

    def pimRealloc(self, ptr: int, size: int, thread: int = 0) -> int:
        T = self.cfg.num_threads
        resp = self._one(lambda a: heap.realloc_request(
            jnp.full((T,), ptr, jnp.int32), jnp.full((T,), size, jnp.int32),
            a), thread)
        return int(resp.ptr[thread])

    def pimCalloc(self, nmemb: int, size: int, thread: int = 0) -> int:
        T = self.cfg.num_threads
        resp = self._one(lambda a: heap.calloc_request(
            jnp.full((T,), nmemb, jnp.int32), jnp.full((T,), size, jnp.int32),
            a), thread)
        return int(resp.ptr[thread])

    # -- batched (one request per hardware thread) ----------------------------
    def pimMallocBatch(self, sizes) -> jnp.ndarray:
        return self.request(heap.malloc_request(sizes)).ptr

    def pimFreeBatch(self, ptrs) -> None:
        self.request(heap.free_request(ptrs))

    def pimReallocBatch(self, ptrs, sizes) -> jnp.ndarray:
        return self.request(heap.realloc_request(ptrs, sizes)).ptr

    def pimCallocBatch(self, nmemb, sizes) -> jnp.ndarray:
        return self.request(heap.calloc_request(nmemb, sizes)).ptr

    def gc(self) -> None:
        """Merge fully-free thread-cache blocks back into the buddy.

        Works on every pim-style kind (sw/hwsw/pallas/sanitizer share the
        PimMallocState layout in `.alloc` — the sanitizer's shadow map and
        quarantine describe live allocations, which gc never moves);
        strawman has no thread caches to merge."""
        if self.cfg.kind == "strawman":
            return
        # gc moves fully-free cached blocks back to the buddy: live bytes
        # are unchanged, so the telemetry counters carry over as-is
        self.state = self.state._replace(
            alloc=pim_malloc.gc(self.cfg.pm, self.state.alloc))

    @property
    def stats(self) -> dict:
        if self.cfg.kind == "strawman":
            return {}
        return {k: int(v) for k, v in self.state.alloc.stats._asdict().items()}


def initAllocator(heap_bytes: int, size_classes=None, **kw) -> Allocator:
    if size_classes is None:
        size_classes = (16, 32, 64, 128, 256, 512, 1024, 2048)
    return Allocator(heap_bytes=heap_bytes, size_classes=size_classes, **kw)
