"""Allocator client surface: `HeapClient` + the paper-facing Table-2 facade.

`HeapClient` is the one stateful client object every consumer builds on —
`kvcache.PagePool`, the Table-2 facade below, and the serving engines in
`repro.launch` all drive a registered heap kind through the same surface:

  * ``malloc / calloc / realloc / free`` — single-op convenience (one
    hardware thread active per call),
  * ``malloc_batch / calloc_batch / realloc_batch / free_batch`` — one op
    per hardware thread, returning the full `AllocResponse`,
  * ``request()`` — the raw protocol entry point every method routes
    through (subclass hook: `repro.workloads.trace.RecordingAllocator`
    overrides it to tape every round),
  * ``stats`` / ``telemetry()`` / ``last_info`` — allocator counters, a
    heap-health snapshot (`repro.core.telemetry`), and the per-thread DPU
    latencies of the most recent round.

Every call builds one `AllocRequest` batching this call's per-thread ops
and runs a single jitted `heap.step` round — there is exactly one compiled
step per (kind, shape), shared by all methods, instead of one scan per
Python-level call. For performance-critical / distributed use, call
`heap.step` (or `heap.MultiCoreHeap` / `heap.ShardedHeap`) directly and
manage state explicitly.

`Allocator` is the paper-facing facade (Table 2): initAllocator /
pimMalloc / pimFree / pimRealloc / pimCalloc (+Batch variants) are aliases
over the client surface so the examples read like the paper's UPMEM
programs. `HeapClient.wrap` adapts legacy duck-typed handles (the
deprecated ``PagePool(alloc=)`` injection hook) onto this surface; see
docs/api.md for the migration note.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import heap, pim_malloc
from .heap import AllocRequest, AllocResponse
from .pim_malloc import PimMallocConfig
from .system import SystemConfig, SystemState


class HeapClient:
    """One registered heap kind behind malloc/free/realloc/calloc + telemetry.

    One client == one per-PIM-core heap serving T hardware threads. All
    methods route through `request()`, so a subclass that overrides it
    (e.g. to record a tape) sees every protocol round of every consumer.
    """

    def __init__(self, heap_bytes: int = 32 * 1024 * 1024,
                 size_classes=(16, 32, 64, 128, 256, 512, 1024, 2048),
                 num_threads: int = 16, prepopulate: bool = True,
                 kind: str = "sw"):
        pm = PimMallocConfig(
            heap_bytes=heap_bytes, size_classes=tuple(size_classes),
            num_threads=num_threads,
        )
        self.cfg = SystemConfig(kind=kind, heap_bytes=heap_bytes,
                                num_threads=num_threads, pm=pm)
        self.state: SystemState = heap.init(self.cfg, prepopulate)
        self._step = jax.jit(functools.partial(heap.step, self.cfg))
        self.last_info: AllocResponse | None = None

    @classmethod
    def wrap(cls, handle) -> "HeapClient":
        """Adapt a legacy allocator handle onto the client surface.

        Accepts a `HeapClient` (returned as-is), a zero-arg factory
        returning one, or any duck-typed object with ``cfg`` / ``request()``
        (the pre-PR-8 ``PagePool(alloc=)`` injection contract).
        """
        if isinstance(handle, HeapClient):
            return handle
        if callable(handle) and not hasattr(handle, "request"):
            return cls.wrap(handle())
        if not hasattr(handle, "request") or not hasattr(handle, "cfg"):
            raise TypeError(
                f"cannot adapt {type(handle).__name__!r} to HeapClient: "
                "need a HeapClient, a zero-arg factory returning one, or "
                "an object with .cfg and .request(AllocRequest)")
        return _HandleAdapter(handle)

    # -- protocol entry point ------------------------------------------------
    def request(self, req: AllocRequest) -> AllocResponse:
        """Serve one batched request round; advances the heap state."""
        self.state, resp = self._step(self.state, req)
        self.last_info = resp
        return resp

    def _one(self, build, thread: int) -> AllocResponse:
        T = self.cfg.num_threads
        active = jnp.zeros((T,), bool).at[thread].set(True)
        return self.request(build(active))

    # -- single-op convenience (one hardware thread active) ------------------
    def malloc(self, size: int, thread: int = 0) -> int:
        resp = self._one(lambda a: heap.malloc_request(
            jnp.full((self.cfg.num_threads,), size, jnp.int32), a), thread)
        return int(resp.ptr[thread])

    def free(self, ptr: int, thread: int = 0) -> None:
        self._one(lambda a: heap.free_request(
            jnp.full((self.cfg.num_threads,), ptr, jnp.int32), a), thread)

    def realloc(self, ptr: int, size: int, thread: int = 0) -> int:
        T = self.cfg.num_threads
        resp = self._one(lambda a: heap.realloc_request(
            jnp.full((T,), ptr, jnp.int32), jnp.full((T,), size, jnp.int32),
            a), thread)
        return int(resp.ptr[thread])

    def calloc(self, nmemb: int, size: int, thread: int = 0) -> int:
        T = self.cfg.num_threads
        resp = self._one(lambda a: heap.calloc_request(
            jnp.full((T,), nmemb, jnp.int32), jnp.full((T,), size, jnp.int32),
            a), thread)
        return int(resp.ptr[thread])

    # -- batched (one op per hardware thread, full response) -----------------
    def malloc_batch(self, sizes, active=None) -> AllocResponse:
        return self.request(heap.malloc_request(sizes, active))

    def free_batch(self, ptrs, active=None) -> AllocResponse:
        """Free one pointer per thread slot. NULL (-1) frees are benign
        no-ops; any other stale/garbage pointer reaches the backend so it
        counts against `Stats.dropped_frees` (and, on the ``sanitizer``
        kind, is tagged) instead of silently vanishing."""
        return self.request(heap.free_request(ptrs, active))

    def realloc_batch(self, ptrs, sizes, active=None) -> AllocResponse:
        return self.request(heap.realloc_request(ptrs, sizes, active))

    def calloc_batch(self, nmemb, sizes, active=None) -> AllocResponse:
        return self.request(heap.calloc_request(nmemb, sizes, active))

    def epoch_reset(self, active=None) -> AllocResponse:
        """Retire the current allocation epoch (``OP_EPOCH_RESET``).

        On the ``arena`` kind any active thread clears the whole shared
        bump region (idempotent across threads in one round); on
        ``tlregion`` each active thread clears only its own region. Every
        pointer the arena handed out this epoch is invalid afterwards —
        the caller must drop its references (the ``trace_lint`` rule).
        Backends without an arena frontend answer the round as idle, and
        the ``sanitizer`` retires every LIVE shadow start to STALE and
        tags later uses as ``epoch_stale``."""
        return self.request(heap.epoch_reset_request(
            self.cfg.num_threads, active))

    # -- maintenance / introspection -----------------------------------------
    def gc(self) -> None:
        """Merge fully-free thread-cache blocks back into the buddy.

        Works on every pim-style kind (sw/hwsw/pallas/sanitizer/arena/
        tlregion share the PimMallocState layout in `.alloc` — the
        sanitizer's shadow map and quarantine describe live allocations,
        which gc never moves, and the arena kinds' bump region lives
        outside the backend's thread caches entirely); strawman has no
        thread caches to merge."""
        if self.cfg.kind == "strawman":
            return
        # gc moves fully-free cached blocks back to the buddy: live bytes
        # are unchanged, so the telemetry counters carry over as-is
        self.state = self.state._replace(
            alloc=pim_malloc.gc(self.cfg.pm, self.state.alloc))

    @property
    def kind(self) -> str:
        return self.cfg.kind

    @property
    def num_threads(self) -> int:
        return self.cfg.num_threads

    @property
    def heap_bytes(self) -> int:
        return self.cfg.heap_bytes

    @property
    def stats(self) -> dict:
        if self.cfg.kind == "strawman":
            return {}
        return {k: int(v) for k, v in self.state.alloc.stats._asdict().items()}

    def telemetry(self) -> dict:
        """Heap-health snapshot: live/hwm/free bytes, external_frag, the
        conservation residual (see `repro.core.telemetry.snapshot`)."""
        from . import telemetry
        return telemetry.snapshot(self.cfg, self.state)


class _HandleAdapter(HeapClient):
    """`HeapClient.wrap` shim: forwards the protocol to a duck-typed handle
    while exposing the full client surface (deprecation path for the old
    ``PagePool(alloc=)`` hook)."""

    def __init__(self, handle):  # noqa: D401 — no heap of its own
        self._handle = handle
        self.cfg = handle.cfg
        self.last_info = getattr(handle, "last_info", None)

    def request(self, req: AllocRequest) -> AllocResponse:
        resp = self._handle.request(req)
        self.last_info = resp
        return resp

    @property
    def state(self):
        return self._handle.state

    def gc(self) -> None:
        if hasattr(self._handle, "gc"):
            self._handle.gc()


class Allocator(HeapClient):
    """Per-PIM-core allocator handle — the paper-facing Table 2 names
    (pimMalloc / pimFree / pimRealloc / pimCalloc and the Batch variants)
    as thin aliases over the `HeapClient` surface."""

    # -- Table 2 API ---------------------------------------------------------
    def pimMalloc(self, size: int, thread: int = 0) -> int:
        return self.malloc(size, thread=thread)

    def pimFree(self, ptr: int, thread: int = 0) -> None:
        self.free(ptr, thread=thread)

    def pimRealloc(self, ptr: int, size: int, thread: int = 0) -> int:
        return self.realloc(ptr, size, thread=thread)

    def pimCalloc(self, nmemb: int, size: int, thread: int = 0) -> int:
        return self.calloc(nmemb, size, thread=thread)

    # -- batched (one request per hardware thread) ----------------------------
    def pimMallocBatch(self, sizes) -> jnp.ndarray:
        return self.malloc_batch(sizes).ptr

    def pimFreeBatch(self, ptrs) -> None:
        self.free_batch(ptrs)

    def pimReallocBatch(self, ptrs, sizes) -> jnp.ndarray:
        return self.realloc_batch(ptrs, sizes).ptr

    def pimCallocBatch(self, nmemb, sizes) -> jnp.ndarray:
        return self.calloc_batch(nmemb, sizes).ptr


def initAllocator(heap_bytes: int, size_classes=None, **kw) -> Allocator:
    if size_classes is None:
        size_classes = (16, 32, 64, 128, 256, 512, 1024, 2048)
    return Allocator(heap_bytes=heap_bytes, size_classes=size_classes, **kw)
