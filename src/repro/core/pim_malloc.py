"""PIM-malloc-SW: the paper's hierarchical per-core allocator (Section 4.1).

Two levels, exactly as in Fig 8:
  frontend  — per-thread *thread caches*: NC size classes (16 B … 2 KB),
              LIFO freelists of sub-blocks carved from `block_bytes` (4 KB)
              blocks. O(1) pop/push, no mutex (vectorized across threads).
  backend   — shared buddy allocator over the per-core heap with minimum
              grain `block_bytes` (tree depth 20 → 13 for 32 MB), protected
              by a mutex (modeled: `lax.scan` serializes backend users and
              the cost model charges queuing/busy-wait).

The state is a fixed-shape pytree so a whole PIM system is just
`vmap(malloc)` across cores, and a mesh of devices is `shard_map` of that —
the paper's winning *PIM-Metadata/PIM-Executed* design point: allocator
metadata lives in (and never leaves) each core's local memory.

Workflow cases of Fig 9:
  case 1  thread-cache hit     path=0
  case 2  thread-cache miss    path=1 (refill 4 KB from buddy, carve, pop)
  case 3  bypass (> 2 KB)      path=2 (buddy alloc, rounded pow2 >= 4 KB)
  fail    heap exhausted       path=3
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
from jax import lax

from . import buddy
from .buddy import BuddyConfig, BuddyState, ilog2, next_pow2

INVALID = jnp.int32(-1)


@dataclasses.dataclass(frozen=True)
class PimMallocConfig:
    heap_bytes: int = 32 * 1024 * 1024
    num_threads: int = 16          # paper: up to 24 tasklets per DPU
    size_classes: tuple = (16, 32, 64, 128, 256, 512, 1024, 2048)
    block_bytes: int = 4096        # thread-cache refill unit == buddy min grain
    cap: int = 1024                # freelist capacity per (thread, class)
    max_gc: int = 8                # full blocks merged back per gc() pass

    def __post_init__(self):
        assert all(s & (s - 1) == 0 for s in self.size_classes)
        assert tuple(sorted(self.size_classes)) == tuple(self.size_classes)
        assert self.block_bytes > max(self.size_classes)
        assert self.cap >= self.block_bytes // min(self.size_classes)

    @property
    def nc(self) -> int:
        return len(self.size_classes)

    @property
    def nb(self) -> int:  # number of 4 KB blocks in the heap
        return self.heap_bytes // self.block_bytes

    @property
    def max_sub(self) -> int:  # sub-blocks per block for the smallest class
        return self.block_bytes // min(self.size_classes)

    @property
    def buddy_cfg(self) -> BuddyConfig:
        return BuddyConfig(heap_bytes=self.heap_bytes, min_block=self.block_bytes)

    @property
    def log2_min_class(self) -> int:
        return min(self.size_classes).bit_length() - 1

    @property
    def max_class(self) -> int:
        return max(self.size_classes)


class Stats(NamedTuple):
    front_hits: jnp.ndarray
    front_misses: jnp.ndarray
    bypass: jnp.ndarray
    fails: jnp.ndarray
    frees_small: jnp.ndarray
    frees_big: jnp.ndarray
    dropped_frees: jnp.ndarray
    gc_blocks: jnp.ndarray


def _zero_stats() -> Stats:
    z = jnp.int32(0)
    return Stats(z, z, z, z, z, z, z, z)


class PimMallocState(NamedTuple):
    buddy: BuddyState
    counts: jnp.ndarray      # int32[T, NC] free sub-blocks per freelist
    stacks: jnp.ndarray      # int32[T, NC, CAP] LIFO freelists (byte offsets)
    block_cls: jnp.ndarray   # int32[NB] owning size class, -1 if not cache-owned
    block_free: jnp.ndarray  # int32[NB] free sub-blocks currently cached, per block
    big_log2: jnp.ndarray    # int32[NB] log2(size) for bypass allocs at base block, -1
    stats: Stats


class MallocEvent(NamedTuple):
    """Per-thread record for the cost model / cache sims."""

    path: jnp.ndarray         # int32[T]: 0 hit / 1 refill / 2 bypass / 3 fail / -1 idle
    backend_pos: jnp.ndarray  # int32[T]: serialization order at backend, -1 if none
    levels_down: jnp.ndarray  # int32[T]
    levels_up: jnp.ndarray    # int32[T]
    trace: jnp.ndarray        # int32[T, trace_len] buddy-tree nodes touched


class FreeEvent(NamedTuple):
    path: jnp.ndarray         # int32[T]: 0 small / 1 big / 2 dropped / -1 idle
    backend_pos: jnp.ndarray
    levels_up: jnp.ndarray
    trace: jnp.ndarray


class ReallocMeta(NamedTuple):
    """Size-class analysis of live pointers for pim_realloc (all [T])."""

    valid_old: jnp.ndarray  # bool — ptr maps to tracked metadata
    in_place: jnp.ndarray   # bool — rounded size class unchanged
    old_bytes: jnp.ndarray  # int32 rounded bytes of the live block (0 if invalid)
    new_bytes: jnp.ndarray  # int32 rounded bytes of the requested size


class ReallocEvent(NamedTuple):
    malloc: "MallocEvent"     # alloc phase of moved reallocs
    free: "FreeEvent"         # release phase of moved reallocs
    in_place: jnp.ndarray     # bool[T] served without touching the heap
    moved: jnp.ndarray        # bool[T] relocated (new ptr, old freed)
    copy_bytes: jnp.ndarray   # int32[T] payload DMA'd old -> new block


def _class_of(cfg: PimMallocConfig, sizes):
    rounded = next_pow2(jnp.maximum(sizes, min(cfg.size_classes)))
    return jnp.clip(ilog2(rounded) - cfg.log2_min_class, 0, cfg.nc - 1)


def init(cfg: PimMallocConfig, prepopulate: bool = True) -> PimMallocState:
    """initAllocator(): reset metadata; optionally pre-carve one 4 KB block per
    freelist (paper: done once by thread 0)."""
    st = PimMallocState(
        buddy=buddy.init(cfg.buddy_cfg),
        counts=jnp.zeros((cfg.num_threads, cfg.nc), jnp.int32),
        stacks=jnp.full((cfg.num_threads, cfg.nc, cfg.cap), INVALID, jnp.int32),
        block_cls=jnp.full((cfg.nb,), INVALID, jnp.int32),
        block_free=jnp.zeros((cfg.nb,), jnp.int32),
        big_log2=jnp.full((cfg.nb,), INVALID, jnp.int32),
        stats=_zero_stats(),
    )
    if not prepopulate:
        return st

    class_sizes = jnp.array(cfg.size_classes, jnp.int32)

    def carve(st: PimMallocState, tc):
        t, c = tc
        bstate, off, _ = buddy.alloc(cfg.buddy_cfg, st.buddy, jnp.int32(cfg.block_bytes))
        ok = off >= 0
        csize = class_sizes[c]
        sub = cfg.block_bytes // csize
        offs = off + jnp.arange(cfg.max_sub, dtype=jnp.int32) * csize
        row = jnp.where(jnp.arange(cfg.max_sub) < sub, offs, INVALID)
        stacks = st.stacks.at[t, c, : cfg.max_sub].set(
            jnp.where(ok, row, st.stacks[t, c, : cfg.max_sub])
        )
        counts = st.counts.at[t, c].set(jnp.where(ok, sub, st.counts[t, c]))
        b = off // cfg.block_bytes
        bsafe = jnp.where(ok, b, 0)
        block_cls = st.block_cls.at[bsafe].set(jnp.where(ok, c, st.block_cls[bsafe]))
        block_free = st.block_free.at[bsafe].set(jnp.where(ok, sub, st.block_free[bsafe]))
        return (
            st._replace(buddy=bstate, stacks=stacks, counts=counts,
                        block_cls=block_cls, block_free=block_free),
            None,
        )

    t_idx, c_idx = jnp.meshgrid(
        jnp.arange(cfg.num_threads, dtype=jnp.int32),
        jnp.arange(cfg.nc, dtype=jnp.int32),
        indexing="ij",
    )
    st, _ = lax.scan(carve, st, (t_idx.ravel(), c_idx.ravel()))
    return st


def malloc(cfg: PimMallocConfig, st: PimMallocState, sizes, active=None):
    """Service one batched request round: sizes int32[T] per thread.

    Returns (state, ptrs int32[T], MallocEvent). ptr = -1 for failed/idle.
    """
    T = cfg.num_threads
    assert sizes.shape == (T,)
    if active is None:
        active = jnp.ones((T,), bool)
    class_sizes = jnp.array(cfg.size_classes, jnp.int32)
    t_idx = jnp.arange(T, dtype=jnp.int32)
    tlen = cfg.buddy_cfg.trace_len

    # ---------------- Phase A: vectorized thread-cache pops (case 1) --------
    # sizes beyond the heap fail outright (and must not reach next_pow2,
    # which wraps int32 for sizes > 2^30 — e.g. calloc overflow sentinels).
    too_big = active & (sizes > cfg.heap_bytes)
    small = active & (sizes <= cfg.max_class) & (sizes > 0)
    c = _class_of(cfg, sizes)
    cnt = st.counts[t_idx, c]
    hit = small & (cnt > 0)
    pos = jnp.maximum(cnt - 1, 0)
    ptr_a = st.stacks[t_idx, c, pos]
    counts = st.counts.at[t_idx, c].add(jnp.where(hit, -1, 0))
    blk_a = jnp.where(hit, ptr_a // cfg.block_bytes, cfg.nb)  # nb -> dropped
    block_free = st.block_free.at[blk_a].add(-1, mode="drop")

    # ---------------- Phase B: serialized backend (cases 2 & 3, mutex) ------
    refill = small & ~hit
    bypass = active & (sizes > cfg.max_class) & ~too_big
    need = refill | bypass

    def step(carry, x):
        bstate, counts, stacks, block_cls, block_free, big_log2, border = carry
        t, need_t, refill_t, bypass_t, size_t, c_t = x
        alloc_size = jnp.where(
            bypass_t, next_pow2(jnp.maximum(size_t, cfg.block_bytes)),
            jnp.int32(cfg.block_bytes),
        )
        bstate2, off, bev = buddy.alloc(cfg.buddy_cfg, bstate, alloc_size)
        ok = need_t & (off >= 0)
        # commit buddy mutation only if this thread actually used the backend
        bstate = BuddyState(
            longest=jnp.where(need_t, bstate2.longest, bstate.longest)
        )
        b = jnp.where(off >= 0, off // cfg.block_bytes, 0)

        # -- refill: carve block into sub-blocks, push all, pop top ----------
        csize = class_sizes[c_t]
        sub = cfg.block_bytes // csize
        offs = off + jnp.arange(cfg.max_sub, dtype=jnp.int32) * csize
        row = jnp.where(jnp.arange(cfg.max_sub) < sub, offs, INVALID)
        do_refill = refill_t & ok
        stacks = stacks.at[t, c_t, : cfg.max_sub].set(
            jnp.where(do_refill, row, stacks[t, c_t, : cfg.max_sub])
        )
        counts = counts.at[t, c_t].set(
            jnp.where(do_refill, sub - 1, counts[t, c_t])
        )
        block_cls = block_cls.at[b].set(jnp.where(do_refill, c_t, block_cls[b]))
        block_free = block_free.at[b].set(jnp.where(do_refill, sub - 1, block_free[b]))
        ptr_refill = off + (sub - 1) * csize

        # -- bypass: record size for ptr-only pimFree -------------------------
        do_bypass = bypass_t & ok
        big_log2 = big_log2.at[b].set(
            jnp.where(do_bypass, ilog2(alloc_size), big_log2[b])
        )

        ptr = jnp.where(do_refill, ptr_refill, jnp.where(do_bypass, off, INVALID))
        bpos = jnp.where(need_t, border, INVALID)
        border = border + need_t.astype(jnp.int32)
        ev = (
            jnp.where(need_t, bev.levels_down, 0),
            jnp.where(need_t, bev.levels_up, 0),
            jnp.where(need_t, bev.trace, jnp.full((tlen,), INVALID, jnp.int32)),
            bpos,
            ok,
        )
        return (bstate, counts, stacks, block_cls, block_free, big_log2, border), (ptr, ev)

    carry = (st.buddy, counts, st.stacks, st.block_cls, block_free, st.big_log2,
             jnp.int32(0))
    xs = (t_idx, need, refill, bypass, sizes, c)
    carry, (ptr_b, (lv_down, lv_up, trace, bpos, ok_b)) = lax.scan(step, carry, xs)
    bstate, counts, stacks, block_cls, block_free, big_log2, _ = carry

    ptrs = jnp.where(hit, ptr_a, ptr_b)
    path = jnp.where(
        hit, 0,
        jnp.where(refill & ok_b, 1,
                  jnp.where(bypass & ok_b, 2,
                            jnp.where(need | too_big, 3, INVALID))),
    ).astype(jnp.int32)

    stats = st.stats._replace(
        front_hits=st.stats.front_hits + jnp.sum(hit),
        front_misses=st.stats.front_misses + jnp.sum(refill),
        bypass=st.stats.bypass + jnp.sum(bypass),
        fails=st.stats.fails + jnp.sum((need & ~ok_b) | too_big),
    )
    new_st = PimMallocState(
        buddy=bstate, counts=counts, stacks=stacks, block_cls=block_cls,
        block_free=block_free, big_log2=big_log2, stats=stats,
    )
    ev = MallocEvent(path=path, backend_pos=bpos, levels_down=lv_down,
                     levels_up=lv_up, trace=trace)
    return new_st, ptrs, ev


def free(cfg: PimMallocConfig, st: PimMallocState, ptrs, active=None):
    """pimFree(ptr) batched over threads: size recovered from block metadata.

    C-like misuse accounting: a NULL free (ptr == -1) is a benign no-op
    (path -1); any other requested free that cannot be served — negative
    garbage, out-of-heap offsets, pointers in untracked blocks, double
    frees of bypass blocks, or a freelist at capacity — is *dropped*
    (path 2) and counted in `Stats.dropped_frees` so workload replays
    surface allocator misuse. (Detection is block-granularity: a double
    free of a sub-block whose 4 KB block is still cache-owned cannot be
    distinguished from a legitimate free and is served as a push.)
    """
    T = cfg.num_threads
    assert ptrs.shape == (T,)
    if active is None:
        active = jnp.ones((T,), bool)
    requested = active & (ptrs != -1)
    active = requested & (ptrs >= 0) & (ptrs < cfg.heap_bytes)
    t_idx = jnp.arange(T, dtype=jnp.int32)
    tlen = cfg.buddy_cfg.trace_len

    b = jnp.where(active, ptrs // cfg.block_bytes, 0)
    cls = st.block_cls[b]
    small = active & (cls >= 0)
    big = active & (cls < 0) & (st.big_log2[b] >= 0) & (ptrs % cfg.block_bytes == 0)

    # -------- small frees: vectorized push to the calling thread's list -----
    csel = jnp.maximum(cls, 0)
    pos = st.counts[t_idx, csel]
    overflow = small & (pos >= cfg.cap)
    push = small & ~overflow
    possafe = jnp.minimum(pos, cfg.cap - 1)
    stacks = st.stacks.at[t_idx, csel, possafe].set(
        jnp.where(push, ptrs, st.stacks[t_idx, csel, possafe])
    )
    counts = st.counts.at[t_idx, csel].add(jnp.where(push, 1, 0))
    block_free = st.block_free.at[jnp.where(push, b, cfg.nb)].add(1, mode="drop")

    # -------- big frees: serialized buddy frees (mutex) ---------------------
    def step(carry, x):
        bstate, big_log2, border = carry
        big_t, ptr_t, b_t = x
        size = jnp.int32(1) << jnp.maximum(big_log2[b_t], 0)
        bstate2, bev = buddy.free(cfg.buddy_cfg, bstate, ptr_t, size)
        bstate = BuddyState(
            longest=jnp.where(big_t, bstate2.longest, bstate.longest)
        )
        big_log2 = big_log2.at[b_t].set(jnp.where(big_t, INVALID, big_log2[b_t]))
        bpos = jnp.where(big_t, border, INVALID)
        border = border + big_t.astype(jnp.int32)
        ev = (
            jnp.where(big_t, bev.levels_up, 0),
            jnp.where(big_t, bev.trace, jnp.full((tlen,), INVALID, jnp.int32)),
            bpos,
        )
        return (bstate, big_log2, border), ev

    carry = (st.buddy, st.big_log2, jnp.int32(0))
    carry, (lv_up, trace, bpos) = lax.scan(step, carry, (big, ptrs, b))
    bstate, big_log2, _ = carry

    dropped = requested & ~push & ~big
    path = jnp.where(push, 0, jnp.where(big, 1, jnp.where(dropped, 2, INVALID)))
    stats = st.stats._replace(
        frees_small=st.stats.frees_small + jnp.sum(push),
        frees_big=st.stats.frees_big + jnp.sum(big),
        dropped_frees=st.stats.dropped_frees + jnp.sum(dropped),
    )
    new_st = PimMallocState(
        buddy=bstate, counts=counts, stacks=stacks, block_cls=st.block_cls,
        block_free=block_free, big_log2=big_log2, stats=stats,
    )
    ev = FreeEvent(path=path.astype(jnp.int32), backend_pos=bpos,
                   levels_up=lv_up, trace=trace)
    return new_st, ev


def realloc_meta(cfg: PimMallocConfig, st: PimMallocState, ptrs, sizes) -> ReallocMeta:
    """Classify live pointers against requested sizes (no state change).

    A pointer is small iff its block is thread-cache-owned (block_cls >= 0),
    big iff it is the base of a recorded bypass allocation. Grow/shrink stays
    in place iff the rounded size class (small) or rounded pow2 (big) is
    unchanged — exactly when the paper's allocator can return the same block.
    """
    valid = (ptrs >= 0) & (ptrs < cfg.heap_bytes)
    b = jnp.where(valid, ptrs // cfg.block_bytes, 0)
    cls = st.block_cls[b]
    small_old = valid & (cls >= 0)
    big_old = (valid & (cls < 0) & (st.big_log2[b] >= 0)
               & (ptrs % cfg.block_bytes == 0))
    class_sizes = jnp.array(cfg.size_classes, jnp.int32)
    old_bytes = jnp.where(
        small_old, class_sizes[jnp.maximum(cls, 0)],
        jnp.where(big_old, jnp.int32(1) << jnp.maximum(st.big_log2[b], 0), 0),
    )
    new_small = sizes <= cfg.max_class
    new_bytes = jnp.where(
        new_small, class_sizes[_class_of(cfg, sizes)],
        next_pow2(jnp.maximum(sizes, cfg.block_bytes)),
    )
    in_place = ((small_old & new_small) | (big_old & ~new_small)) & (
        new_bytes == old_bytes)
    return ReallocMeta(valid_old=small_old | big_old, in_place=in_place,
                       old_bytes=old_bytes, new_bytes=new_bytes)


def realloc(cfg: PimMallocConfig, st: PimMallocState, ptrs, sizes, active=None):
    """pimRealloc(ptr, size) batched over threads.

    Semantics mirror C realloc on the PIM heap:
      * same rounded size class      -> grow/shrink in place (ptr unchanged)
      * class changed                -> malloc new + copy payload + free old
      * ptr invalid/untracked        -> plain malloc(size)
      * size <= 0 with live ptr      -> free(ptr), returns -1
      * relocation malloc fails      -> -1, old block left intact

    Returns (state, new_ptrs int32[T], ReallocEvent).
    """
    T = cfg.num_threads
    assert ptrs.shape == (T,)
    if active is None:
        active = jnp.ones((T,), bool)
    sizes = jnp.asarray(sizes, jnp.int32)

    meta = realloc_meta(cfg, st, ptrs, sizes)
    live = active & (sizes > 0)
    in_place = live & meta.in_place
    moved = live & ~meta.in_place
    free_as_zero = active & (sizes <= 0) & (ptrs >= 0)

    st, mptrs, mev = malloc(cfg, st, jnp.where(moved, sizes, 0), moved)
    ok_new = mptrs >= 0
    f_active = (moved & meta.valid_old & ok_new) | free_as_zero
    st, fev = free(cfg, st, jnp.where(f_active, ptrs, INVALID), f_active)

    new_ptrs = jnp.where(in_place, ptrs,
                         jnp.where(moved & ok_new, mptrs, INVALID))
    copy_bytes = jnp.where(moved & ok_new & meta.valid_old,
                           jnp.minimum(meta.old_bytes, meta.new_bytes), 0)
    ev = ReallocEvent(malloc=mev, free=fev, in_place=in_place,
                      moved=moved & ok_new, copy_bytes=copy_bytes)
    return st, new_ptrs, ev


def calloc(cfg: PimMallocConfig, st: PimMallocState, nmemb, elem_sizes,
           active=None):
    """pimCalloc(nmemb, size): malloc(nmemb * size) rounded to a size class.

    The returned block is zero-initialized by construction here (the heap is
    functional metadata; payload zero-fill is charged by the system cost
    model). An nmemb * size product that overflows int32 becomes a failing
    (heap-sized) request instead of wrapping small.
    """
    T = cfg.num_threads
    nmemb = jnp.asarray(nmemb, jnp.int32)
    elem_sizes = jnp.asarray(elem_sizes, jnp.int32)
    assert nmemb.shape == (T,)
    if active is None:
        active = jnp.ones((T,), bool)
    total = total_calloc_bytes(nmemb, elem_sizes)
    return malloc(cfg, st, total, active & (total > 0))


def total_calloc_bytes(nmemb, elem_sizes):
    """nmemb * size in int32 with the C-calloc overflow guard: a wrapping
    product maps to INT32_MAX (which no heap can satisfy), never to a small
    positive size."""
    nmemb = jnp.asarray(nmemb, jnp.int32)
    elem_sizes = jnp.asarray(elem_sizes, jnp.int32)
    prod = nmemb * elem_sizes
    exact = (prod > 0) & (prod // jnp.maximum(elem_sizes, 1) == nmemb)
    requested = (nmemb > 0) & (elem_sizes > 0)
    return jnp.where(requested,
                     jnp.where(exact, prod, jnp.int32(jnp.iinfo(jnp.int32).max)),
                     0)


def gc(cfg: PimMallocConfig, st: PimMallocState):
    """Merge fully-free 4 KB blocks back into the buddy (paper Fig 8(b)).

    Processes up to cfg.max_gc blocks per call; leftover full blocks are
    handled by later calls (bounded work per step keeps shapes static).
    """
    class_sizes = jnp.array(cfg.size_classes, jnp.int32)
    sub_of = cfg.block_bytes // jnp.maximum(class_sizes[jnp.maximum(st.block_cls, 0)], 1)
    full = (st.block_cls >= 0) & (st.block_free == sub_of)
    score = jnp.where(full, 1, 0)
    _, cand = lax.top_k(score, cfg.max_gc)
    cand_ok = full[cand]

    def step(carry, x):
        bstate, counts, stacks, block_cls, block_free = carry
        b, ok = x
        c = jnp.maximum(block_cls[b], 0)
        # remove this block's sub-blocks from every thread's class-c freelist
        T, NC, CAP = stacks.shape
        pos = jnp.arange(CAP)
        valid = pos[None, :] < counts[:, c][:, None]          # [T, CAP]
        rows = stacks[:, c, :]                                 # [T, CAP]
        is_b = valid & (rows // cfg.block_bytes == b) & ok
        keep = ~is_b
        # stable-compact kept valid entries to the front (False sorts first)
        key = ~(keep & valid)
        order = jnp.argsort(key, axis=1, stable=True)
        compacted = jnp.take_along_axis(rows, order, axis=1)
        newcnt = jnp.sum(keep & valid, axis=1).astype(jnp.int32)
        compacted = jnp.where(pos[None, :] < newcnt[:, None], compacted, INVALID)
        apply = ok
        stacks = stacks.at[:, c, :].set(jnp.where(apply, compacted, rows))
        counts = counts.at[:, c].set(jnp.where(apply, newcnt, counts[:, c]))
        bstate2, _ = buddy.free(
            cfg.buddy_cfg, bstate, b * cfg.block_bytes, jnp.int32(cfg.block_bytes)
        )
        bstate = BuddyState(longest=jnp.where(apply, bstate2.longest, bstate.longest))
        block_cls = block_cls.at[b].set(jnp.where(apply, INVALID, block_cls[b]))
        block_free = block_free.at[b].set(jnp.where(apply, 0, block_free[b]))
        return (bstate, counts, stacks, block_cls, block_free), apply

    carry = (st.buddy, st.counts, st.stacks, st.block_cls, st.block_free)
    carry, applied = lax.scan(step, carry, (cand, cand_ok))
    bstate, counts, stacks, block_cls, block_free = carry
    stats = st.stats._replace(gc_blocks=st.stats.gc_blocks + jnp.sum(applied))
    return st._replace(
        buddy=bstate, counts=counts, stacks=stacks, block_cls=block_cls,
        block_free=block_free, stats=stats,
    )
