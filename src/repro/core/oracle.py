"""Plain-Python reference allocators — oracles for property tests.

These mirror the JAX implementations semantically (same placement decisions:
leftmost-descent buddy, LIFO size-class freelists) so tests can assert exact
pointer-for-pointer equality, not just invariant preservation.
"""
from __future__ import annotations


def _next_pow2(x: int) -> int:
    return 1 << max(x - 1, 0).bit_length() if x > 1 else 1


class PyBuddy:
    """Array-buddy ('longest') reference, identical placement to core.buddy."""

    def __init__(self, heap_bytes: int, min_block: int):
        assert heap_bytes & (heap_bytes - 1) == 0
        assert min_block & (min_block - 1) == 0
        self.heap = heap_bytes
        self.min_block = min_block
        self.n_leaf = heap_bytes // min_block
        self.longest = [0] * (2 * self.n_leaf)
        for i in range(1, 2 * self.n_leaf):
            self.longest[i] = heap_bytes >> (i.bit_length() - 1)

    def _round(self, size: int) -> int:
        return max(_next_pow2(size), self.min_block)

    def alloc(self, size: int) -> int:
        size = self._round(size)
        if size > self.heap or self.longest[1] < size:
            return -1
        node, node_size = 1, self.heap
        while node_size > size:
            left = 2 * node
            node = left if self.longest[left] >= size else left + 1
            node_size >>= 1
        offset = node * node_size - self.heap
        self.longest[node] = 0
        while node > 1:
            node >>= 1
            self.longest[node] = max(self.longest[2 * node], self.longest[2 * node + 1])
        return offset

    def free(self, offset: int, size: int) -> bool:
        size = self._round(size)
        node = (offset + self.heap) // size
        if offset < 0 or offset >= self.heap or self.longest[node] != 0:
            return False
        self.longest[node] = size
        node_size = size
        while node > 1:
            node >>= 1
            node_size <<= 1
            l, r = self.longest[2 * node], self.longest[2 * node + 1]
            if l == node_size >> 1 and r == node_size >> 1:
                self.longest[node] = node_size
            else:
                self.longest[node] = max(l, r)
        return True

    def free_bytes(self) -> int:
        """heap - allocated bytes; see core.buddy.free_bytes for the stale-
        descendant subtlety of the longest[] encoding."""

        def allocated(node: int, size: int) -> int:
            if self.longest[node] == size:
                return 0
            if size == self.min_block:
                return size if self.longest[node] == 0 else 0
            l, r = 2 * node, 2 * node + 1
            if (self.longest[node] == 0 and self.longest[l] == size >> 1
                    and self.longest[r] == size >> 1):
                return size
            return allocated(l, size >> 1) + allocated(r, size >> 1)

        return self.heap - allocated(1, self.heap)


class PyPimMalloc:
    """Reference for core.pim_malloc — identical placement decisions."""

    def __init__(self, heap_bytes=1 << 20, num_threads=4,
                 size_classes=(16, 32, 64, 128, 256, 512, 1024, 2048),
                 block_bytes=4096, cap=1024, prepopulate=True):
        self.cfg = dict(heap=heap_bytes, T=num_threads, classes=list(size_classes),
                        block=block_bytes, cap=cap)
        self.buddy = PyBuddy(heap_bytes, block_bytes)
        self.nc = len(size_classes)
        self.counts = [[0] * self.nc for _ in range(num_threads)]
        self.stacks = [[[] for _ in range(self.nc)] for _ in range(num_threads)]
        self.block_cls = {}
        self.block_free = {}
        self.big_log2 = {}
        self.stats = dict(front_hits=0, front_misses=0, bypass=0, fails=0,
                          frees_small=0, frees_big=0, dropped=0, gc_blocks=0)
        if prepopulate:
            for t in range(num_threads):
                for c in range(self.nc):
                    off = self.buddy.alloc(block_bytes)
                    if off < 0:
                        continue
                    csize = size_classes[c]
                    sub = block_bytes // csize
                    self.stacks[t][c] = [off + i * csize for i in range(sub)]
                    self.counts[t][c] = sub
                    b = off // block_bytes
                    self.block_cls[b] = c
                    self.block_free[b] = sub

    def _class_of(self, size):
        classes = self.cfg["classes"]
        for c, s in enumerate(classes):
            if size <= s:
                return c
        return self.nc - 1

    def malloc(self, sizes, active=None):
        T, block = self.cfg["T"], self.cfg["block"]
        classes = self.cfg["classes"]
        if active is None:
            active = [True] * T
        ptrs = [-1] * T
        paths = [-1] * T
        # phase A: hits
        backend = []
        for t in range(T):
            if not active[t] or sizes[t] <= 0:
                continue
            size = sizes[t]
            if size <= classes[-1]:
                c = self._class_of(size)
                if self.counts[t][c] > 0:
                    ptr = self.stacks[t][c][self.counts[t][c] - 1]
                    self.stacks[t][c].pop()
                    self.counts[t][c] -= 1
                    self.block_free[ptr // block] -= 1
                    ptrs[t] = ptr
                    paths[t] = 0
                    self.stats["front_hits"] += 1
                else:
                    backend.append((t, "refill", c, size))
            else:
                backend.append((t, "bypass", None, size))
        # phase B: serialized in thread order
        for t, kind, c, size in backend:
            if kind == "refill":
                off = self.buddy.alloc(block)
                self.stats["front_misses"] += 1
                if off < 0:
                    self.stats["fails"] += 1
                    paths[t] = 3
                    continue
                csize = classes[c]
                sub = block // csize
                self.stacks[t][c] = [off + i * csize for i in range(sub - 1)]
                self.counts[t][c] = sub - 1
                b = off // block
                self.block_cls[b] = c
                self.block_free[b] = sub - 1
                ptrs[t] = off + (sub - 1) * csize
                paths[t] = 1
            else:
                asize = max(_next_pow2(size), block)
                off = self.buddy.alloc(asize)
                self.stats["bypass"] += 1
                if off < 0:
                    self.stats["fails"] += 1
                    paths[t] = 3
                    continue
                self.big_log2[off // block] = asize.bit_length() - 1
                ptrs[t] = off
                paths[t] = 2
        return ptrs, paths

    def free(self, ptrs, active=None):
        """One batched free round; returns per-thread paths mirroring
        `core.pim_malloc.free`: 0 push / 1 big / 2 dropped / -1 idle (NULL
        frees are benign no-ops)."""
        T, block, cap = self.cfg["T"], self.cfg["block"], self.cfg["cap"]
        if active is None:
            active = [True] * T
        paths = [-1] * T
        for t in range(T):
            ptr = ptrs[t]
            if not active[t] or ptr == -1:   # NULL free: benign no-op
                continue
            if ptr < 0 or ptr >= self.cfg["heap"]:
                self.stats["dropped"] += 1   # garbage pointer
                paths[t] = 2
                continue
            b = ptr // block
            c = self.block_cls.get(b, -1)
            if c >= 0:
                if self.counts[t][c] >= cap:
                    self.stats["dropped"] += 1
                    paths[t] = 2
                    continue
                self.stacks[t][c].append(ptr)
                self.counts[t][c] += 1
                self.block_free[b] = self.block_free.get(b, 0) + 1
                self.stats["frees_small"] += 1
                paths[t] = 0
            elif self.big_log2.get(b, -1) >= 0 and ptr % block == 0:
                self.buddy.free(ptr, 1 << self.big_log2[b])
                del self.big_log2[b]
                self.stats["frees_big"] += 1
                paths[t] = 1
            else:
                self.stats["dropped"] += 1   # untracked / double free
                paths[t] = 2
        return paths

    # ------------------------------------------------------------------
    # full protocol rounds (the differential-fuzzing oracle surface)
    # ------------------------------------------------------------------
    def _realloc_meta(self, ptr: int, size: int):
        """(valid_old, in_place, old_bytes, new_bytes) for one pointer —
        mirrors `core.pim_malloc.realloc_meta`."""
        heap, block = self.cfg["heap"], self.cfg["block"]
        classes = self.cfg["classes"]
        valid = 0 <= ptr < heap
        b = ptr // block if valid else 0
        cls = self.block_cls.get(b, -1) if valid else -1
        small_old = valid and cls >= 0
        big_old = (valid and cls < 0 and self.big_log2.get(b, -1) >= 0
                   and ptr % block == 0)
        old = (classes[cls] if small_old
               else (1 << self.big_log2[b]) if big_old else 0)
        new_small = size <= classes[-1]
        new = (classes[self._class_of(size)] if new_small
               else max(_next_pow2(size), block))
        in_place = (((small_old and new_small) or (big_old and not new_small))
                    and new == old)
        return small_old or big_old, in_place, old, new

    def request(self, op, size, ptr):
        """Serve one mixed-op protocol round (the semantic half of
        `system._protocol_round`): per-thread MALLOC / FREE / REALLOC /
        CALLOC / NOOP with the same two-phase order — batched malloc for
        new blocks (incl. relocating reallocs), then batched free (explicit
        frees, realloc(p, 0), vacated realloc blocks).

        Returns {"ptr", "ok", "path", "moved"} per-thread lists — the
        semantic AllocResponse fields every backend must agree on
        (tests/test_differential_fuzz.py pins hwsw == this oracle).
        """
        T = self.cfg["T"]
        OP_MALLOC, OP_FREE, OP_REALLOC, OP_CALLOC = 1, 2, 3, 4
        is_alloc = [o in (OP_MALLOC, OP_CALLOC) for o in op]
        is_re = [o == OP_REALLOC for o in op]
        is_free = [o == OP_FREE for o in op]

        meta = [self._realloc_meta(ptr[t], size[t]) for t in range(T)]
        valid_old = [m[0] for m in meta]
        re_live = [is_re[t] and size[t] > 0 for t in range(T)]
        in_place = [re_live[t] and meta[t][1] for t in range(T)]
        moved = [re_live[t] and not meta[t][1] for t in range(T)]
        re_free0 = [is_re[t] and size[t] <= 0 and ptr[t] >= 0
                    for t in range(T)]

        m_active = [(is_alloc[t] and size[t] > 0) or moved[t]
                    for t in range(T)]
        mptrs, mpaths = self.malloc(
            [size[t] if m_active[t] else 0 for t in range(T)], m_active)
        mok = [m_active[t] and mptrs[t] >= 0 for t in range(T)]

        f_active = [is_free[t] or (moved[t] and valid_old[t] and mok[t])
                    or re_free0[t] for t in range(T)]
        fpaths = self.free(
            [ptr[t] if f_active[t] else -1 for t in range(T)], f_active)

        out_ptr, ok, path, moved_out = [], [], [], []
        for t in range(T):
            if is_alloc[t] and mok[t]:
                p = mptrs[t]
            elif in_place[t]:
                p = ptr[t]
            elif moved[t] and mok[t]:
                p = mptrs[t]
            else:
                p = -1
            out_ptr.append(p)
            ok.append((is_alloc[t] and mok[t]) or in_place[t]
                      or (moved[t] and mok[t])
                      or ((is_free[t] or re_free0[t])
                          and fpaths[t] in (0, 1)))
            if m_active[t]:
                path.append(mpaths[t])
            elif is_free[t] or re_free0[t]:
                path.append(fpaths[t])
            elif in_place[t]:
                path.append(0)
            else:
                path.append(-1)
            moved_out.append(moved[t] and mok[t])
        return {"ptr": out_ptr, "ok": ok, "path": path, "moved": moved_out}

    def gc(self, max_gc=8):
        block = self.cfg["block"]
        classes = self.cfg["classes"]
        full = sorted(
            b for b, c in self.block_cls.items()
            if c >= 0 and self.block_free.get(b, 0) == block // classes[c]
        )
        for b in full[:max_gc]:
            c = self.block_cls[b]
            for t in range(self.cfg["T"]):
                row = self.stacks[t][c]
                kept = [p for p in row if p // block != b]
                self.stacks[t][c] = kept
                self.counts[t][c] = len(kept)
            self.buddy.free(b * block, block)
            del self.block_cls[b]
            del self.block_free[b]
            self.stats["gc_blocks"] += 1


class PyArena:
    """Reference for core.arena — the layered bump frontend over the backend.

    Mirrors `arena.step` phase for phase (reset at round start, ownership
    classification against the post-reset map, bump allocation in thread
    order, forwarded backend round, merge), wrapping a `PyPimMalloc` the way
    the JAX arena wraps hwsw. ``tlregion=True`` gives each thread a private
    region (the ``tlregion`` design point); otherwise one shared bump.
    tests/test_differential_fuzz.py pins arena/tlregion == this oracle
    pointer-for-pointer on the semantic response fields.
    """

    GRANULE = 16
    OP_RESET = 5

    def __init__(self, heap_bytes=1 << 20, num_threads=4,
                 size_classes=(16, 32, 64, 128, 256, 512, 1024, 2048),
                 block_bytes=4096, cap=1024, tlregion=False):
        self.inner = PyPimMalloc(
            heap_bytes=heap_bytes, num_threads=num_threads,
            size_classes=size_classes, block_bytes=block_bytes, cap=cap,
            prepopulate=False)
        self.ab = heap_bytes // 2
        assert self.ab % block_bytes == 0
        off = self.inner.buddy.alloc(self.ab)
        assert off == 0, "pristine leftmost-descent carve must land at 0"
        self.T = num_threads
        self.tl = tlregion
        self.n_gran = self.ab // self.GRANULE
        if tlregion:
            assert self.n_gran % num_threads == 0
            self.region_gran = self.n_gran // num_threads
        else:
            self.region_gran = self.n_gran
        self.cls_map = {}              # start granule -> size-class index
        self.bump = [0] * (num_threads if tlregion else 1)
        self.epoch = 0

    def request(self, op, size, ptr):
        """One layered protocol round; returns {"ptr","ok","path","moved"}."""
        T = self.T
        classes = self.inner.cfg["classes"]
        max_class = classes[-1]
        OP_MALLOC, OP_FREE, OP_REALLOC, OP_CALLOC = 1, 2, 3, 4
        is_reset = [op[t] == self.OP_RESET for t in range(T)]

        # phase 0: epoch reset at round start (tl: own region; shared: all)
        if self.tl:
            for t in range(T):
                if is_reset[t]:
                    lo = t * self.region_gran
                    hi = lo + self.region_gran
                    for g in [g for g in self.cls_map if lo <= g < hi]:
                        del self.cls_map[g]
                    self.bump[t] = 0
        elif any(is_reset):
            self.cls_map.clear()
            self.bump[0] = 0
        self.epoch += int(any(is_reset))

        # ownership classification against the post-reset, pre-bump map
        plan = []
        for t in range(T):
            o, z, p = op[t], size[t], ptr[t]
            in_arena = 0 <= p < self.ab and p % self.GRANULE == 0
            g_old = p // self.GRANULE if in_arena else -1
            owned = in_arena and g_old in self.cls_map
            old_cls = self.cls_map[g_old] if owned else -1
            small = 0 < z <= max_class
            cls = self.inner._class_of(z) if small else -1
            is_alloc = o in (OP_MALLOC, OP_CALLOC)
            is_re = o == OP_REALLOC
            re_free0 = is_re and z <= 0 and p >= 0
            arena_free = (o == OP_FREE or re_free0) and owned
            re_arena = is_re and z > 0 and owned
            re_inplace = re_arena and small and cls == old_cls
            re_move = re_arena and not (small and cls == old_cls)
            plan.append(dict(
                g_old=g_old, cls=cls, small=small, arena_free=arena_free,
                re_inplace=re_inplace, re_move=re_move,
                plain_small=is_alloc and small, reset=is_reset[t]))

        # phase 1: bump allocation (shared arena serializes in thread order;
        # a failed fit does NOT consume space)
        for t, pl in enumerate(plan):
            cand = pl["plain_small"] or (pl["re_move"] and pl["small"])
            pl["g_new"], pl["served"] = -1, False
            if not cand:
                continue
            gneed = classes[pl["cls"]] // self.GRANULE
            slot = t if self.tl else 0
            limit = self.region_gran
            if self.bump[slot] + gneed <= limit:
                base = t * self.region_gran if self.tl else 0
                pl["g_new"] = base + self.bump[slot]
                pl["served"] = True
                self.bump[slot] += gneed
            pl["re_move_bump"] = pl["re_move"] and pl["small"] and pl["served"]
        for pl in plan:
            pl.setdefault("re_move_bump", False)
            pl["arena_alloc"] = pl["plain_small"] and pl["served"]
            pl["move_to_inner"] = pl["re_move"] and not pl["re_move_bump"]
            pl["consumed"] = (pl["arena_alloc"] or pl["arena_free"]
                              or pl["re_inplace"] or pl["re_move_bump"]
                              or pl["reset"])

        # phase 2: forwarded backend round
        in_op = [OP_MALLOC if pl["move_to_inner"]
                 else 0 if pl["consumed"] else op[t]
                 for t, pl in enumerate(plan)]
        in_size = [size[t] if pl["move_to_inner"]
                   else 0 if pl["consumed"] else size[t]
                   for t, pl in enumerate(plan)]
        in_ptr = [-1 if pl["consumed"] or pl["move_to_inner"] else ptr[t]
                  for t, pl in enumerate(plan)]
        r = self.inner.request(in_op, in_size, in_ptr)

        # phase 3: merge
        out = {"ptr": [], "ok": [], "path": [], "moved": []}
        for t, pl in enumerate(plan):
            move_ok = pl["re_move_bump"] or (pl["move_to_inner"]
                                             and r["ok"][t])
            if pl["arena_alloc"] or pl["re_move_bump"]:
                self.cls_map[pl["g_new"]] = pl["cls"]
            if pl["arena_free"] or move_ok:
                self.cls_map.pop(pl["g_old"], None)
            fwd = not pl["consumed"]       # passthrough or move_to_inner
            arena_ok = pl["consumed"]      # == the arena-served cases
            if pl["arena_alloc"] or pl["re_move_bump"]:
                p_out = pl["g_new"] * self.GRANULE
            elif pl["re_inplace"]:
                p_out = ptr[t]
            elif fwd:
                p_out = r["ptr"][t]
            else:
                p_out = -1
            out["ptr"].append(p_out)
            out["ok"].append(r["ok"][t] if fwd else arena_ok)
            out["path"].append(0 if arena_ok
                               else (r["path"][t] if fwd else -1))
            out["moved"].append(pl["re_move_bump"]
                                or (pl["move_to_inner"] and r["ok"][t])
                                or (not pl["consumed"]
                                    and not pl["move_to_inner"]
                                    and r["moved"][t]))
        return out
