"""Design-space exploration of PIM memory allocators (Table 1 / Fig 5).

Four strategies = {metadata on host | metadata in PIM banks}
              x {allocator executed by host CPU | by PIM cores}
evaluated on the paper's Fig 5 scenario: N PIM cores each requesting 128
identical 32 B allocations concurrently, over the straw-man
buddy_alloc_PIM_DRAM (32 MB heap, min 32 B, 20-level tree).

The *functional* result of all four is identical (same buddy algorithm);
what differs is where metadata lives and who traverses it, i.e. the cost:

  Host-Meta/Host-Exec  : host runs allocs for all N cores with P pthreads;
                         returned ptrs copied HOST2PIM.
  Host-Meta/PIM-Exec   : per-core metadata (512 KB at 2 b/node) shipped
                         HOST2PIM before PIM cores execute locally.
  PIM-Meta/Host-Exec   : metadata shipped PIM2HOST, host executes, metadata
                         + ptrs shipped back HOST2PIM.
  PIM-Meta/PIM-Exec    : fully local + parallel (the paper's winner; flat
                         latency in N) — the design PIM-malloc builds on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from .buddy import BuddyConfig
from .cost_model import DPUCost, HostCost, XferCost

STRATEGIES = (
    "host_meta_host_exec",
    "host_meta_pim_exec",
    "pim_meta_host_exec",
    "pim_meta_pim_exec",
)


@dataclasses.dataclass(frozen=True)
class Fig5Scenario:
    n_allocs: int = 128
    alloc_bytes: int = 32
    heap_bytes: int = 32 * 1024 * 1024
    min_block: int = 32

    @property
    def buddy_cfg(self) -> BuddyConfig:
        return BuddyConfig(heap_bytes=self.heap_bytes, min_block=self.min_block)

    @property
    def metadata_bytes_per_core(self) -> int:
        # paper: 2 bits x 2^21 nodes = 512 KB per core for the 32 MB heap
        return self.buddy_cfg.metadata_bytes


def pim_alloc_latency_s(scn: Fig5Scenario, dpu: DPUCost, sw_buf_bytes: int = 512,
                        avg_meta_miss_frac: float = None) -> float:
    """Single straw-man alloc on a DPU (no contention), analytic form.

    Traversal: depth+1 node visits down + depth up. Metadata accesses beyond
    the SW buffer's reach miss and cost a full coarse refill each.
    """
    depth = scn.buddy_cfg.depth
    import math

    # levels whose metadata fits in the staging buffer (top of tree is hot)
    nodes_in_buf = sw_buf_bytes * 4  # 2 bits/node -> 4 nodes per byte
    hot_levels = max(int(math.log2(max(nodes_in_buf, 1))), 0)
    visits_down = depth + 1
    visits_up = depth
    total_visits = visits_down + visits_up
    cold = max(total_visits - 2 * hot_levels, 0)
    hot = total_visits - cold
    dma_cyc = dpu.mram_setup_cyc + sw_buf_bytes / dpu.mram_bytes_per_cyc
    cyc = (
        dpu.cyc_mutex
        + total_visits * dpu.cyc_node
        + hot * dpu.cyc_meta_hit
        + cold * dma_cyc
    )
    return cyc / dpu.freq_hz


def host_alloc_latency_s(scn: Fig5Scenario, host: HostCost, n_cores: int) -> float:
    """One alloc executed on the host over N cores' metadata.

    Working set = N x 512 KB >> LLC, so each tree-node visit is DRAM-latency
    bound (pointer-chase); compute overlaps.
    """
    depth = scn.buddy_cfg.depth
    visits = 2 * depth + 1
    per_visit = max(host.dram_latency_s, host.cyc_node / host.freq_hz)
    # small working sets (few cores) partially fit in LLC: scale latency in
    llc_bytes = 32 * 1024 * 1024
    ws = n_cores * scn.metadata_bytes_per_core
    cached_frac = min(llc_bytes / max(ws, 1), 1.0)
    eff = per_visit * (1.0 - 0.9 * cached_frac)
    return visits * max(eff, host.cyc_node / host.freq_hz)


def strategy_latency_s(strategy: str, n_cores: int,
                       scn: Fig5Scenario = Fig5Scenario(),
                       dpu: DPUCost = DPUCost(),
                       host: HostCost = HostCost(),
                       xfer: XferCost = XferCost()) -> Dict[str, float]:
    """End-to-end Fig 5 latency (seconds) + breakdown for one design point."""
    meta_total = n_cores * scn.metadata_bytes_per_core
    ptr_bytes = n_cores * scn.n_allocs * 8

    t_pim_one = pim_alloc_latency_s(scn, dpu)
    t_host_one = host_alloc_latency_s(scn, host, n_cores)

    if strategy == "pim_meta_pim_exec":
        exec_s = scn.n_allocs * t_pim_one  # all cores in parallel
        return {"exec": exec_s, "xfer": 0.0, "total": exec_s}
    if strategy == "host_meta_host_exec":
        exec_s = n_cores * scn.n_allocs * t_host_one / host.threads
        x = xfer.h2p_s(ptr_bytes, n_cores)  # ship returned ptrs to cores
        return {"exec": exec_s, "xfer": x, "total": exec_s + x}
    if strategy == "host_meta_pim_exec":
        x = xfer.h2p_s(meta_total, n_cores)  # ship metadata to cores
        exec_s = scn.n_allocs * t_pim_one
        return {"exec": exec_s, "xfer": x, "total": exec_s + x}
    if strategy == "pim_meta_host_exec":
        x1 = xfer.p2h_s(meta_total, n_cores)   # metadata to host
        exec_s = n_cores * scn.n_allocs * t_host_one / host.threads
        x2 = xfer.h2p_s(meta_total + ptr_bytes, n_cores)  # metadata + ptrs back
        return {"exec": exec_s, "xfer": x1 + x2, "total": exec_s + x1 + x2}
    raise ValueError(strategy)


def sweep(n_cores_list=(1, 2, 4, 8, 16, 32, 64, 128, 256, 512), **kw):
    """Fig 5(a): avg per-alloc latency (us) per strategy vs #cores."""
    scn = kw.pop("scn", Fig5Scenario())
    out = {}
    for s in STRATEGIES:
        out[s] = {}
        for n in n_cores_list:
            r = strategy_latency_s(s, n, scn=scn, **kw)
            out[s][n] = {k: v / scn.n_allocs * 1e6 for k, v in r.items()}
    return out
