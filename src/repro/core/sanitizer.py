"""ASan-style shadow-heap sanitizer: a wrapper design point over hwsw.

The paper's allocators model heap *misuse* as benign dropped paths: a double
free or a free through a stale post-realloc pointer is either dropped
(path 2) or — when block-granularity metadata cannot tell — silently served.
The ``sanitizer`` kind turns that misuse into **deterministic tagged
reports** while still serving the full `repro.core.heap` protocol, so it
enrolls automatically in every KINDS-parametrized test, the differential
fuzzer, and tape replays:

  shadow map   one int8 cell per 16 B heap granule, tracking the *start
               granule* of every allocation: LIVE after a successful
               malloc/calloc/realloc, QUARANTINED after an explicit free,
               MOVED after a relocating realloc retires the old pointer,
               STALE after an EPOCH_RESET round retires every live start
               wholesale (the arena design points' bulk-invalidation op).
  poisoning    an op through a non-LIVE start granule never reaches the
               wrapped allocator; it is tagged (double_free /
               use_after_free / realloc_after_free / wild) and answered
               with a deterministic failing response.
  quarantine   legitimately freed blocks are parked in a FIFO ring instead
               of being released; the *oldest* entry is only handed to the
               wrapped allocator's free path when the ring overflows. This
               delays pointer reuse so cross-round double frees keep
               hitting poisoned shadow instead of a recycled block.

The wrapped allocator is the hwsw design point (`system._step_pim` with the
HW buddy-cache metadata path); quarantined bytes therefore stay *live* in
the heap telemetry and the conservation law

    live_bytes + buddy free bytes + cached frontend bytes == heap_bytes

keeps holding after every round (pinned by tests/test_telemetry.py, which
auto-parametrizes over this kind). Reports are cumulative int32 counters in
the state (`SanReports`) plus the per-thread tag vector of the last round;
`report()` renders them as the documented dict schema (docs/analysis.md).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
from jax import lax

from .heap import OP_CALLOC, OP_EPOCH_RESET, OP_FREE, OP_MALLOC, \
    OP_REALLOC, AllocRequest, AllocResponse
from .pim_malloc import INVALID

# Shadow is tracked at allocation *start granules*: every pointer the
# allocator hands out is GRANULE-aligned (the smallest size class is 16 B),
# so one int8 per granule distinguishes live starts from poisoned ones.
GRANULE = 16

# shadow cell states
SHADOW_FREE = 0    # no allocation starts here
SHADOW_LIVE = 1    # start of a live allocation
SHADOW_QUAR = 2    # start of an explicitly freed block, parked in quarantine
SHADOW_MOVED = 3   # start retired by a relocating realloc (or evicted misuse)
SHADOW_STALE = 4   # start invalidated wholesale by an EPOCH_RESET round

# per-op misuse tags (state.tags / report schema)
TAG_NONE = 0
TAG_DOUBLE_FREE = 1         # free-class op on a QUARANTINED start
TAG_USE_AFTER_FREE = 2      # free-class op on a MOVED (realloc-retired) start
TAG_REALLOC_AFTER_FREE = 3  # realloc(size>0) on a QUARANTINED/MOVED start
TAG_WILD = 4                # op on unmapped / misaligned / out-of-heap ptr
TAG_EPOCH_STALE = 5         # op on a start retired by an epoch reset

TAG_NAMES = {TAG_NONE: "none", TAG_DOUBLE_FREE: "double_free",
             TAG_USE_AFTER_FREE: "use_after_free",
             TAG_REALLOC_AFTER_FREE: "realloc_after_free", TAG_WILD: "wild",
             TAG_EPOCH_STALE: "epoch_stale"}

# quarantine capacity: enough slots that every thread can retire several
# blocks before the oldest one is released back to the wrapped allocator
QUARANTINE_FACTOR = 4


def quarantine_slots(num_threads: int) -> int:
    return max(8, QUARANTINE_FACTOR * num_threads)


class SanReports(NamedTuple):
    """Cumulative misuse counters (int32 scalars)."""

    double_free: jnp.ndarray
    use_after_free: jnp.ndarray
    realloc_after_free: jnp.ndarray
    wild_ops: jnp.ndarray
    quarantined: jnp.ndarray   # legit frees parked in the ring
    evicted: jnp.ndarray       # ring evictions released to the real free path
    epoch_resets: jnp.ndarray  # EPOCH_RESET rounds observed
    epoch_stale: jnp.ndarray   # ops tagged for touching a reset-retired start


def _zero_reports() -> SanReports:
    z = jnp.int32(0)
    return SanReports(z, z, z, z, z, z, z, z)


class SanitizerState(NamedTuple):
    """hwsw state + shadow map + quarantine ring + misuse reports.

    The leading (alloc, cache, telem) triple mirrors `system.SystemState`,
    so `repro.core.telemetry.snapshot` and the replay reports read this
    state unchanged.
    """

    alloc: object            # PimMallocState (the wrapped allocator)
    cache: object            # BuddyCacheState (hwsw metadata path)
    telem: object            # system.HeapTelemetry
    shadow: jnp.ndarray      # int8[heap_bytes // GRANULE]
    q_ptr: jnp.ndarray       # int32[Q] quarantined pointers (-1 empty)
    q_head: jnp.ndarray      # int32 index of the oldest entry
    q_len: jnp.ndarray       # int32 occupancy
    tags: jnp.ndarray        # int32[T] per-thread tag of the last round
    reports: SanReports


def init_state(cfg, inner_state) -> SanitizerState:
    """Wrap a freshly initialized hwsw-layout SystemState."""
    q = quarantine_slots(cfg.num_threads)
    return SanitizerState(
        alloc=inner_state.alloc, cache=inner_state.cache,
        telem=inner_state.telem,
        shadow=jnp.zeros((cfg.heap_bytes // GRANULE,), jnp.int8),
        q_ptr=jnp.full((q,), -1, jnp.int32),
        q_head=jnp.int32(0), q_len=jnp.int32(0),
        tags=jnp.zeros((cfg.num_threads,), jnp.int32),
        reports=_zero_reports(),
    )


def _quarantine_pass(q_ptr, q_head, q_len, enq, ptrs):
    """FIFO ring update for one round (scan over threads, mutex order).

    Each enqueueing thread parks its pointer; when the ring is full the
    oldest entry is evicted into that same thread's slot of the wrapped
    request — a thread whose own free is being delayed always has its
    request slot available to carry the released free.
    """
    Q = q_ptr.shape[0]

    def step(carry, x):
        q_ptr, q_head, q_len = carry
        enq_t, ptr_t = x
        # evict BEFORE enqueueing: at capacity the write position wraps
        # onto q_head, so enqueue-first would overwrite the oldest entry
        # and then "evict" the brand-new pointer with zero delay
        evict = enq_t & (q_len >= Q)
        ev_ptr = q_ptr[q_head]
        q_head = jnp.where(evict, (q_head + 1) % Q, q_head)
        q_len = q_len - evict.astype(jnp.int32)
        wpos = (q_head + q_len) % Q
        q_ptr = q_ptr.at[wpos].set(jnp.where(enq_t, ptr_t, q_ptr[wpos]))
        q_len = q_len + enq_t.astype(jnp.int32)
        return (q_ptr, q_head, q_len), jnp.where(evict, ev_ptr, INVALID)

    (q_ptr, q_head, q_len), evicted = lax.scan(step, (q_ptr, q_head, q_len),
                                               (enq, ptrs))
    return q_ptr, q_head, q_len, evicted


def step(cfg, st: SanitizerState, req: AllocRequest, inner_step):
    """One sanitized protocol round.

    ``inner_step`` is the wrapped backend step (`system._step_pim`); the
    sanitizer classifies every FREE/REALLOC operand against the pre-round
    shadow, forwards only clean work, and synthesizes deterministic tagged
    responses for poisoned operands.
    """
    from .system import SystemState  # late import: system registers us

    op, size, ptr = req.op, req.size, req.ptr
    n_gran = st.shadow.shape[0]

    # ---- epoch reset applies at round start (arena semantics): every LIVE
    # start is retired to STALE wholesale; later ops through such a start
    # are tagged epoch_stale. The wrapped hwsw heap has no arena region, so
    # the blocks deliberately stay live there (conservation holds) — the
    # sanitizer models the *pointer-invalidation* side of the reset.
    is_reset = op == OP_EPOCH_RESET
    any_reset = jnp.any(is_reset)
    shadow0 = jnp.where(any_reset & (st.shadow == SHADOW_LIVE),
                        jnp.int8(SHADOW_STALE), st.shadow)

    in_range = (ptr >= 0) & (ptr < cfg.heap_bytes)
    aligned = in_range & (ptr % GRANULE == 0)
    g = jnp.clip(jnp.where(in_range, ptr // GRANULE, 0), 0, n_gran - 1)
    sh = shadow0[g]
    live = aligned & (sh == SHADOW_LIVE)
    quar = aligned & (sh == SHADOW_QUAR)
    moved_sh = aligned & (sh == SHADOW_MOVED)
    stale = aligned & (sh == SHADOW_STALE)

    # free-class: explicit FREE, or realloc(p, size<=0) == free(p). NULL
    # (ptr == -1) stays a benign pass-through no-op, as in every backend.
    free_class = ((op == OP_FREE) | ((op == OP_REALLOC) & (size <= 0))) \
        & (ptr >= 0)
    realloc_live = (op == OP_REALLOC) & (size > 0) & (ptr >= 0)

    tag = jnp.zeros_like(op)
    tag = jnp.where(free_class & quar, TAG_DOUBLE_FREE, tag)
    tag = jnp.where(free_class & moved_sh, TAG_USE_AFTER_FREE, tag)
    tag = jnp.where(free_class & stale, TAG_EPOCH_STALE, tag)
    tag = jnp.where(free_class & ~live & ~quar & ~moved_sh & ~stale,
                    TAG_WILD, tag)
    tag = jnp.where(realloc_live & (quar | moved_sh),
                    TAG_REALLOC_AFTER_FREE, tag)
    tag = jnp.where(realloc_live & stale, TAG_EPOCH_STALE, tag)
    tag = jnp.where(realloc_live & ~live & ~quar & ~moved_sh & ~stale,
                    TAG_WILD, tag)
    tagged = tag > 0

    quar_free = free_class & live          # legit retire -> quarantine
    # NOOP/MALLOC/CALLOC/live REALLOC (resets are answered locally)
    passthrough = ~free_class & ~tagged & ~is_reset

    # ---- quarantine ring: park legit frees, maybe release the oldest ------
    q_ptr, q_head, q_len, evicted = _quarantine_pass(
        st.q_ptr, st.q_head, st.q_len, quar_free, ptr)
    evict = evicted >= 0

    # ---- pre-step shadow poisoning (on the post-reset shadow) -------------
    shadow = shadow0.at[jnp.where(quar_free, g, n_gran)].set(
        jnp.int8(SHADOW_QUAR), mode="drop")
    g_ev = jnp.clip(jnp.where(evict, evicted // GRANULE, 0), 0, n_gran - 1)
    shadow = shadow.at[jnp.where(evict, g_ev, n_gran)].set(
        jnp.int8(SHADOW_FREE), mode="drop")

    # ---- wrapped hwsw round on the filtered request -----------------------
    inner_req = AllocRequest(
        op=jnp.where(passthrough, op,
                     jnp.where(evict, OP_FREE, jnp.int32(0))),
        size=jnp.where(passthrough, size, 0),
        ptr=jnp.where(passthrough, ptr, jnp.where(evict, evicted, INVALID)),
    )
    inner_st = SystemState(alloc=st.alloc, cache=st.cache, telem=st.telem)
    inner_st, r = inner_step(cfg, inner_st, inner_req)

    # ---- post-step shadow updates from the wrapped responses --------------
    # a relocating realloc retires the old start; new allocations go LIVE
    re_moved = passthrough & (op == OP_REALLOC) & r.moved
    shadow = shadow.at[jnp.where(re_moved, g, n_gran)].set(
        jnp.int8(SHADOW_MOVED), mode="drop")
    new_live = passthrough & (r.ptr >= 0) & (
        (op == OP_MALLOC) | (op == OP_CALLOC) | ((op == OP_REALLOC) & r.moved))
    g_new = jnp.clip(jnp.where(new_live, r.ptr // GRANULE, 0), 0, n_gran - 1)
    shadow = shadow.at[jnp.where(new_live, g_new, n_gran)].set(
        jnp.int8(SHADOW_LIVE), mode="drop")

    # ---- response synthesis ------------------------------------------------
    dpu = cfg.dpu
    # quarantined frees are priced like a freelist push plus whatever the
    # released (evicted) free costs in this thread's wrapped slot; tagged
    # ops cost one shadow peek
    lat = jnp.where(passthrough, r.latency_cyc,
                    jnp.where(quar_free,
                              dpu.cyc_front_push + r.latency_cyc,
                              jnp.where(is_reset,
                                        jnp.float32(dpu.cyc_epoch_reset),
                                        jnp.where(tagged,
                                                  jnp.float32(
                                                      dpu.cyc_front_hit),
                                                  0.0))))
    path = jnp.where(
        passthrough, r.path,
        jnp.where(quar_free | is_reset, 0,
                  jnp.where(tagged & free_class, 2,
                            jnp.where(tagged & realloc_live, 3, INVALID))))
    resp = AllocResponse(
        ptr=jnp.where(passthrough, r.ptr, INVALID),
        ok=jnp.where(passthrough, r.ok, quar_free | is_reset),
        path=path.astype(jnp.int32),
        moved=passthrough & r.moved,
        latency_cyc=lat,
        backend_cyc=jnp.where(passthrough | quar_free, r.backend_cyc, 0.0),
        meta_hits=jnp.where(passthrough | quar_free, r.meta_hits, 0),
        meta_misses=jnp.where(passthrough | quar_free, r.meta_misses, 0),
        dram_bytes=jnp.where(passthrough | quar_free, r.dram_bytes, 0),
    )

    # tagged misuse folds into the wrapped allocator's misuse accounting so
    # replay reports (stats_dropped_frees) see it like any other backend
    stats = inner_st.alloc.stats
    stats = stats._replace(
        dropped_frees=stats.dropped_frees + jnp.sum(tagged & free_class),
        fails=stats.fails + jnp.sum(tagged & realloc_live),
    )
    rep = st.reports
    rep = SanReports(
        double_free=rep.double_free + jnp.sum(tag == TAG_DOUBLE_FREE),
        use_after_free=rep.use_after_free + jnp.sum(tag == TAG_USE_AFTER_FREE),
        realloc_after_free=(rep.realloc_after_free
                            + jnp.sum(tag == TAG_REALLOC_AFTER_FREE)),
        wild_ops=rep.wild_ops + jnp.sum(tag == TAG_WILD),
        quarantined=rep.quarantined + jnp.sum(quar_free),
        evicted=rep.evicted + jnp.sum(evict),
        epoch_resets=rep.epoch_resets + any_reset.astype(jnp.int32),
        epoch_stale=rep.epoch_stale + jnp.sum(tag == TAG_EPOCH_STALE),
    )
    new_st = SanitizerState(
        alloc=inner_st.alloc._replace(stats=stats), cache=inner_st.cache,
        telem=inner_st.telem, shadow=shadow, q_ptr=q_ptr, q_head=q_head,
        q_len=q_len, tags=tag, reports=rep,
    )
    return new_st, resp


def report(state: SanitizerState) -> dict:
    """Render the cumulative misuse report (docs/analysis.md schema)."""
    import numpy as np
    rep = {k: int(v) for k, v in state.reports._asdict().items()}
    rep["last_round_tags"] = [TAG_NAMES[int(t)]
                              for t in np.asarray(state.tags)]
    rep["quarantine_backlog"] = int(state.q_len)
    return rep
