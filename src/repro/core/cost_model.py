"""DPU cycle cost model (UPMEM-PIM timing, Table 3 of the paper).

This container has no PIM (or TPU) hardware, so — like the paper's
uPIMulator-based evaluation — latency numbers come from a cycle model driven
by the *functional* allocator's event traces. The same events feed the
metadata-cache simulators (`buddy_cache.py`), whose per-op hit/miss/DRAM
counts this module converts into cycles and seconds.

Constants are calibrated against published UPMEM characterization
(350 MHz in-order DPU, WRAM 1-2 cyc, MRAM DMA ~ 250 ns setup + ~2 B/cyc
streaming, host Xeon ~3.8 GHz with DRAM-latency-bound pointer chasing) and
validated in `benchmarks/` against the paper's own ratios (66x, 31%, 12x,
~80x frontend/backend gap, 28x graph update throughput).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DPUCost:
    freq_hz: float = 350e6
    # frontend (thread cache)
    cyc_front_hit: int = 30      # size-class calc + LIFO pop + counters
    cyc_front_push: int = 26     # free-path push
    cyc_refill: int = 190        # carve a 4 KB block into sub-blocks (WRAM writes)
    # backend (buddy)
    # NOTE: the DPU's revolving 14-stage pipeline gives a *single* tasklet an
    # effective issue rate of ~1 instr / 11 cycles; ~30-40 instructions of
    # address arithmetic + 2-bit field extraction per tree level therefore
    # cost O(40) effective cycles at the modeled operating point.
    cyc_node: int = 40           # per-level compare/branch/address arithmetic
    cyc_meta_hit: int = 2        # metadata access served from scratchpad/buddy cache
    cyc_mutex: int = 44          # mutex acquire/release (WRAM atomic rmw)
    # arena frontend (bump pointer): the O(1) fast path of the layered split.
    # A bump alloc is a class calc + one WRAM add; on the shared arena the
    # add must be atomic, so concurrent bumpers serialize for ~2 cyc each
    # (far below cyc_mutex — the point of the design). Epoch reset is a
    # constant-cost pointer rewind + epoch bump, amortized over every block.
    cyc_bump: int = 6            # size-class calc + bump-pointer add
    cyc_bump_atomic: int = 2     # per-contender serialization on the shared add
    cyc_epoch_reset: int = 64    # rewind + epoch counter + lg-map clear kickoff
    # MRAM (per-bank DRAM) DMA
    mram_setup_cyc: int = 88     # ~250 ns engine setup
    mram_bytes_per_cyc: float = 2.0   # ~700 MB/s per-DPU streaming


@dataclasses.dataclass(frozen=True)
class HostCost:
    freq_hz: float = 3.8e9
    threads: int = 16            # pthreads parallelism for host-executed allocs
    dram_latency_s: float = 80e-9  # random-access latency; buddy traversal over
    # N cores' metadata (N x 512 KB >> LLC) is latency-bound per node visit
    cyc_node: int = 8            # OoO core per-level compute overlapped w/ DRAM


@dataclasses.dataclass(frozen=True)
class XferCost:
    """host <-> PIM transfers (dpu_push_xfer): PrIM-style bandwidth curves."""

    setup_s: float = 20e-6
    h2p_per_core_gbs: float = 0.33
    h2p_cap_gbs: float = 6.7
    p2h_per_core_gbs: float = 0.25
    p2h_cap_gbs: float = 4.7

    def h2p_s(self, bytes_total: float, n_cores: int) -> float:
        bw = min(self.h2p_per_core_gbs * n_cores, self.h2p_cap_gbs) * 1e9
        return self.setup_s + bytes_total / bw

    def p2h_s(self, bytes_total: float, n_cores: int) -> float:
        bw = min(self.p2h_per_core_gbs * n_cores, self.p2h_cap_gbs) * 1e9
        return self.setup_s + bytes_total / bw


def mram_access_cyc(cost: DPUCost, bytes_moved) -> jnp.ndarray:
    """Cycles for one DMA moving `bytes_moved` (0 -> 0 cycles)."""
    b = jnp.asarray(bytes_moved, jnp.float32)
    return jnp.where(b > 0, cost.mram_setup_cyc + b / cost.mram_bytes_per_cyc, 0.0)


def backend_op_cyc(cost: DPUCost, levels_down, levels_up, meta_hits, meta_misses,
                   dram_bytes, n_dmas=None) -> jnp.ndarray:
    """Cycles for one buddy-allocator operation (excluding queuing).

    meta accesses: hits cost cyc_meta_hit; misses cost one DMA each. For the
    coarse SW buffer each miss is one DMA of buf_bytes; for the HW buddy
    cache each miss is one DMA of 4 B. `dram_bytes` is total traffic;
    `n_dmas` defaults to `meta_misses` (one DMA per miss).
    """
    levels = jnp.asarray(levels_down + levels_up, jnp.float32)
    if n_dmas is None:
        n_dmas = meta_misses
    n_dmas = jnp.asarray(n_dmas, jnp.float32)
    dma_cyc = n_dmas * cost.mram_setup_cyc + (
        jnp.asarray(dram_bytes, jnp.float32) / cost.mram_bytes_per_cyc
    )
    meta_cyc = jnp.asarray(meta_hits, jnp.float32) * cost.cyc_meta_hit
    return cost.cyc_mutex + (levels + 1.0) * cost.cyc_node + meta_cyc + dma_cyc


def round_latency_cyc(cost: DPUCost, path, backend_pos, backend_cyc):
    """Per-thread latency for one request round, including mutex busy-wait.

    path: int32[T] (0 hit / 1 refill / 2 bypass / 3 fail / -1 idle)
    backend_pos: serialization order among backend users (-1 = frontend only)
    backend_cyc: float32[T] own backend service cycles (0 for frontend hits)

    A backend user at position k busy-waits for the sum of service times of
    positions < k (the paper's Fig 7 'lock' time).
    """
    used_backend = backend_pos >= 0
    # queue[k] = sum of service cycles of backend users before position k
    order_key = jnp.where(used_backend, backend_pos, jnp.int32(1 << 30))
    order = jnp.argsort(order_key)
    svc_sorted = backend_cyc[order]
    wait_sorted = jnp.cumsum(svc_sorted) - svc_sorted
    wait = jnp.zeros_like(backend_cyc).at[order].set(wait_sorted)
    wait = jnp.where(used_backend, wait, 0.0)

    own = jnp.where(path == 0, cost.cyc_front_hit, 0.0)
    own = own + jnp.where(path == 1, cost.cyc_front_hit + cost.cyc_refill, 0.0)
    own = own + backend_cyc
    lat = own + wait
    return jnp.where(path >= 0, lat, 0.0)


def cyc_to_us(cost: DPUCost, cyc) -> jnp.ndarray:
    return jnp.asarray(cyc, jnp.float32) / cost.freq_hz * 1e6
