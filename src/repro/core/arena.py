"""Layered frontend/backend allocator: bump-pointer arena over the pim stack.

The paper's §2 design space is about *where allocator metadata lives and who
manages it*; this module adds the two missing frontend points as a thin,
composable layer over the existing backend instead of a fifth fork of the
step function:

  arena     one shared bump-pointer region (half the heap, carved out of
            the buddy at init). Small allocs (<= max size class) are served
            by bumping a pointer — O(1), no freelist, no buddy mutex; the
            shared bump add is an atomic, so same-round contenders
            serialize for ``cyc_bump_atomic`` cycles each. Frees hole-mark
            (space is NOT reclaimed); the new ``OP_EPOCH_RESET`` protocol
            op retires the whole epoch in O(1) — the EAlloc Temp /
            round-scoped allocation pattern.
  tlregion  the same frontend with the region pre-split per thread: each
            thread bumps its own private region and resets its own region,
            so the fast path has no cross-thread atomic at all (the TLS
            allocator-class point).

Everything the arena does not own — big allocs, arena exhaustion
(spill-to-buddy), non-arena pointers — is forwarded verbatim to the full
hwsw stack (freelists + buddy + metadata cache), or to the fused Pallas
kernel when ``SystemConfig.arena_inner == "pallas"``; the two inner
backends are bitwise-identical, so the kernel parity guarantee composes
through this layer unchanged.

Layout and conservation: the region occupies ``[0, arena_bytes)`` (the
leftmost-descent buddy hands a pristine heap's first ``heap_bytes // 2``
request offset 0 deterministically) and is never visible to the backend's
metadata, so the conservation law holds with the arena's unallocated +
holed bytes counted as *cached frontend* bytes (see
`repro.core.telemetry.frontend_cached_bytes`).

Epoch-reset semantics (mirrored by the PyArena oracle, the sanitizer's
shadow epochs, and the ``trace_lint`` rule): a reset applies at *round
start* — same-round frees of arena pointers see the cleared map and drop,
and no recorded pointer may be referenced across a reset round.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.kernels import freelist

from . import buddy, cost_model, pim_malloc
from .heap import (OP_CALLOC, OP_EPOCH_RESET, OP_FREE, OP_MALLOC, OP_NOOP,
                   OP_REALLOC, AllocRequest, AllocResponse)
from .pim_malloc import INVALID

# Arena placements are tracked at allocation start granules, like the
# sanitizer's shadow map: every size class is a multiple of 16 B.
GRANULE = 16


def arena_bytes(cfg) -> int:
    """Static size of the region carved for the bump frontend: half the
    heap, which keeps the backend's buddy tree usable for big/spill work."""
    ab = cfg.heap_bytes // 2
    assert ab % cfg.pm.block_bytes == 0, \
        f"arena region {ab} must be block-aligned ({cfg.pm.block_bytes})"
    return ab


def n_granules(cfg) -> int:
    return arena_bytes(cfg) // GRANULE


def region_granules(cfg) -> int:
    """Granules per thread region (``tlregion``) or the whole arena."""
    n = n_granules(cfg)
    if cfg.kind != "tlregion":
        return n
    assert n % cfg.num_threads == 0, \
        f"{n} granules not splittable across {cfg.num_threads} threads"
    return n // cfg.num_threads


class ArenaSystemState(NamedTuple):
    """Backend state + the arena frontend's placement map.

    The leading (alloc, cache, telem) triple mirrors `system.SystemState`,
    so telemetry snapshots, replay reports, and `api.HeapClient.stats`
    read this state unchanged (same contract as `SanitizerState`).
    """

    alloc: object            # PimMallocState (the spill backend)
    cache: object            # BuddyCacheState (hwsw metadata path)
    telem: object            # system.HeapTelemetry
    cls_map: jnp.ndarray     # int32[n_gran] size-class index at start granule, -1
    bump: jnp.ndarray        # int32[1] (arena) | int32[T] (tlregion) granules used
    epoch: jnp.ndarray       # int32[] completed-reset counter


def init_state(cfg) -> ArenaSystemState:
    """Carve the arena region out of a pristine backend heap.

    The freelists start empty (spills refill them on demand) and the region
    is deliberately NOT recorded in the backend's block metadata: a
    forwarded free of an arena-range pointer is untracked there and drops,
    which is exactly the misuse accounting the other kinds apply.
    """
    from .system import telemetry_init

    pmc = cfg.pm
    ab = arena_bytes(cfg)
    inner = pim_malloc.init(pmc, prepopulate=False)
    bst, _off, _ev = buddy.alloc(pmc.buddy_cfg, inner.buddy, jnp.int32(ab))
    inner = inner._replace(buddy=bst)
    n_bump = cfg.num_threads if cfg.kind == "tlregion" else 1
    region_granules(cfg)  # validate the per-thread split early
    return ArenaSystemState(
        alloc=inner, cache=cfg.cache_init(), telem=telemetry_init(),
        cls_map=jnp.full((n_granules(cfg),), -1, jnp.int32),
        bump=jnp.zeros((n_bump,), jnp.int32),
        epoch=jnp.int32(0),
    )


def arena_live_bytes(cfg, cls_map) -> jnp.ndarray:
    """Rounded bytes currently placed in the arena (start granules only)."""
    class_sizes = jnp.array(cfg.pm.size_classes, jnp.int32)
    nc = cfg.pm.nc
    return jnp.sum(jnp.where(
        cls_map >= 0, class_sizes[jnp.clip(cls_map, 0, nc - 1)], 0))


def step(cfg, st: ArenaSystemState, req: AllocRequest, inner_step):
    """One layered protocol round: arena pass, then the forwarded backend
    round, then the merge.

    ``inner_step`` is the spill backend (`system._step_pim` or
    `system._step_pallas`). Phases:

      0. EPOCH_RESET applies at round start (shared: any resetting thread
         clears the whole arena, idempotently; tlregion: each resetting
         thread clears only its own region).
      1. Ownership classification against the post-reset map; bump
         allocation for small MALLOC/CALLOC and small relocation targets
         (failed fits do not consume space).
      2. Forward everything unowned/unserved to the backend.
      3. Merge: hole-mark retired arena blocks, fold arena counters into
         the shared Stats, price arena-served ops with the bump-path
         cycles, and advance telemetry with the arena's byte deltas.
    """
    from .system import SystemState, _advance_telemetry

    pmc = cfg.pm
    dpu = cfg.dpu
    tl = cfg.kind == "tlregion"
    ab = arena_bytes(cfg)
    n_gran = n_granules(cfg)
    region_gran = region_granules(cfg)
    class_sizes = jnp.array(pmc.size_classes, jnp.int32)

    op, size, ptr = req.op, req.size, req.ptr
    is_alloc = (op == OP_MALLOC) | (op == OP_CALLOC)
    is_re = op == OP_REALLOC
    is_free = op == OP_FREE
    is_reset = op == OP_EPOCH_RESET

    # ---- phase 0: epoch reset at round start ------------------------------
    if tl:
        gran_owner = jnp.arange(n_gran, dtype=jnp.int32) // region_gran
        reset_gran = is_reset[jnp.clip(gran_owner, 0, cfg.num_threads - 1)]
        bump = jnp.where(is_reset, 0, st.bump)
    else:
        any_reset = jnp.any(is_reset)
        reset_gran = jnp.broadcast_to(any_reset, (n_gran,))
        bump = jnp.where(any_reset, 0, st.bump)
    cls_map, reset_freed = freelist.arena_region_reset(
        st.cls_map, class_sizes, reset_gran)
    epoch = st.epoch + jnp.any(is_reset).astype(jnp.int32)

    # ---- ownership classification (post-reset map) ------------------------
    in_arena = (ptr >= 0) & (ptr < ab) & (ptr % GRANULE == 0)
    g_old = jnp.clip(jnp.where(in_arena, ptr // GRANULE, 0), 0, n_gran - 1)
    owned = in_arena & (cls_map[g_old] >= 0)
    old_cls = jnp.where(owned, cls_map[g_old], -1)
    old_bytes = jnp.where(
        owned, class_sizes[jnp.clip(old_cls, 0, pmc.nc - 1)], 0)

    small = (size > 0) & (size <= pmc.max_class)
    cls = pim_malloc._class_of(pmc, size)
    cls_bytes = class_sizes[cls]
    gneed = cls_bytes // GRANULE

    re_free0 = is_re & (size <= 0) & (ptr >= 0)
    arena_free = (is_free | re_free0) & owned
    re_live = is_re & (size > 0)
    re_arena = re_live & owned
    re_inplace = re_arena & small & (cls == old_cls)
    re_move = re_arena & ~(small & (cls == old_cls))

    # ---- phase 1: bump allocation -----------------------------------------
    plain_small = is_alloc & small
    bump_cand = plain_small | (re_move & small)
    if tl:
        bump, g_new, served = freelist.arena_bump_tl(
            bump, bump_cand, gneed, region_gran)
        bump_wait = jnp.zeros_like(size, jnp.float32)
    else:
        b, g_new, served = freelist.arena_bump_shared(
            bump[0], bump_cand, gneed, n_gran)
        bump = jnp.reshape(b, (1,))
        # every attempter serializes on the shared atomic add, served or not
        rank = jnp.cumsum(bump_cand.astype(jnp.int32)) - bump_cand
        bump_wait = jnp.where(
            bump_cand, rank.astype(jnp.float32) * dpu.cyc_bump_atomic, 0.0)

    arena_alloc = plain_small & served
    re_move_bump = re_move & small & served
    move_to_inner = re_move & ~re_move_bump   # big new size, or arena full

    # ---- phase 2: forwarded backend round ---------------------------------
    consumed = arena_alloc | arena_free | re_inplace | re_move_bump | is_reset
    inner_req = AllocRequest(
        op=jnp.where(move_to_inner, OP_MALLOC,
                     jnp.where(consumed, OP_NOOP, op)).astype(jnp.int32),
        size=jnp.where(consumed & ~move_to_inner, 0, size),
        ptr=jnp.where(consumed | move_to_inner, INVALID, ptr),
    )
    inner_st = SystemState(alloc=st.alloc, cache=st.cache, telem=st.telem)
    inner_st, r = inner_step(cfg, inner_st, inner_req)

    # ---- phase 3: merge ----------------------------------------------------
    move_ok = re_move_bump | (move_to_inner & r.ok)
    cls_map = freelist.arena_mark(cls_map, g_new, cls,
                                  arena_alloc | re_move_bump)
    cls_map = freelist.arena_hole(cls_map, g_old, arena_free | move_ok)

    new_ptr = g_new * GRANULE
    passthrough = ~consumed & ~move_to_inner

    # pricing: bump-path cycles for arena-served ops, the same DMA pricing
    # as the backend for calloc zero-fill and relocation copies
    new_rounded = jnp.where(
        small, cls_bytes,
        buddy.next_pow2(jnp.maximum(size, pmc.block_bytes)))
    copy_bytes = jnp.minimum(old_bytes, new_rounded)
    zero_cyc = jnp.where((op == OP_CALLOC) & arena_alloc,
                         cost_model.mram_access_cyc(dpu, size), 0.0)
    lat = jnp.where(passthrough, r.latency_cyc, 0.0)
    lat = lat + jnp.where(arena_alloc, dpu.cyc_bump + bump_wait + zero_cyc,
                          0.0)
    lat = lat + jnp.where(
        re_move_bump,
        dpu.cyc_bump + bump_wait + cost_model.mram_access_cyc(dpu, copy_bytes),
        0.0)
    lat = lat + jnp.where(
        move_to_inner,
        r.latency_cyc + jnp.where(
            r.ok, cost_model.mram_access_cyc(dpu, copy_bytes), 0.0),
        0.0)
    lat = lat + jnp.where(re_inplace, jnp.float32(dpu.cyc_front_hit), 0.0)
    lat = lat + jnp.where(arena_free, jnp.float32(dpu.cyc_front_push), 0.0)
    lat = lat + jnp.where(is_reset, jnp.float32(dpu.cyc_epoch_reset), 0.0)

    arena_ok = arena_alloc | re_move_bump | re_inplace | arena_free | is_reset
    fwd = passthrough | move_to_inner
    resp = AllocResponse(
        ptr=jnp.where(arena_alloc | re_move_bump, new_ptr,
                      jnp.where(re_inplace, ptr,
                                jnp.where(fwd, r.ptr, INVALID))),
        ok=jnp.where(fwd, r.ok, arena_ok),
        path=jnp.where(arena_ok, 0, jnp.where(fwd, r.path, INVALID))
            .astype(jnp.int32),
        moved=re_move_bump | (move_to_inner & r.ok) | (passthrough & r.moved),
        latency_cyc=lat,
        backend_cyc=jnp.where(fwd, r.backend_cyc, 0.0),
        meta_hits=jnp.where(fwd, r.meta_hits, 0),
        meta_misses=jnp.where(fwd, r.meta_misses, 0),
        dram_bytes=jnp.where(fwd, r.dram_bytes, 0),
    )

    # arena-served work folds into the shared Stats so replay reports and
    # the Table-2 facade see one coherent counter set across the layers
    stats = inner_st.alloc.stats
    stats = stats._replace(
        front_hits=stats.front_hits + jnp.sum(arena_alloc | re_move_bump),
        frees_small=stats.frees_small + jnp.sum(arena_free | move_ok),
    )
    arena_alloc_bytes = jnp.sum(
        jnp.where(arena_alloc | re_move_bump, cls_bytes, 0))
    arena_freed_bytes = reset_freed + jnp.sum(
        jnp.where(arena_free | move_ok, old_bytes, 0))
    telem = _advance_telemetry(inner_st.telem, arena_alloc_bytes,
                               arena_freed_bytes)
    new_st = ArenaSystemState(
        alloc=inner_st.alloc._replace(stats=stats), cache=inner_st.cache,
        telem=telem, cls_map=cls_map, bump=bump, epoch=epoch,
    )
    return new_st, resp
