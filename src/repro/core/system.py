"""End-to-end allocator system simulation: the paper's design points.

  strawman : buddy_alloc_PIM_DRAM — single-level buddy over the whole heap,
             min block 32 B (20-level tree for 32 MB), shared mutex, coarse
             SW metadata buffer. (Section 3.2/3.3.)
  sw       : PIM-malloc-SW — per-thread caches + 13-level buddy backend +
             coarse SW metadata buffer. (Section 4.1.)
  hwsw     : PIM-malloc-HW/SW — same frontend/backend, but backend metadata
             served by the 16-entry LRU hardware buddy cache. (Section 4.2.)
  pallas   : hwsw semantics served by ONE fused Pallas kernel per core
             (`repro.kernels.heap_step`): VMEM-resident freelist cache +
             in-kernel buddy traversal + in-kernel LRU buddy cache.
             Bitwise-equal to hwsw in interpret mode; the device fast path.
  sanitizer: hwsw wrapped in a shadow map + quarantine ring
             (`repro.core.sanitizer`) — turns double-free /
             use-after-free / realloc-after-free / wild pointers into
             deterministic tagged reports. The debugging design point.
  arena    : layered frontend/backend split (`repro.core.arena`): a shared
             bump-pointer arena serves small allocs in O(1) and retires
             whole epochs with one EPOCH_RESET op; everything else spills
             to the full hwsw stack (freelists + buddy). The churn-workload
             design point.
  tlregion : the arena frontend with per-thread regions — no cross-thread
             atomic on the bump fast path (and per-thread epoch resets).

All these kinds serve the `repro.core.heap` request/response protocol: this
module registers one cost-model-instrumented `heap.step` implementation per
kind. A step services one mixed-op round (per-thread MALLOC / FREE /
REALLOC / CALLOC / NOOP), persists metadata-cache state across rounds, and
returns per-thread latencies — including mutex busy-wait for backend users
(Fig 7), payload-copy DMA for relocating reallocs, and zero-fill DMA for
callocs. A whole multi-core PIM system is `vmap` over cores of `heap.step`
(see `heap.MultiCoreHeap` / benchmarks/fig5) and a TPU mesh deployment is
`shard_map` of that (`repro.launch`).

`malloc_round` / `free_round` remain as single-op conveniences; they build
the corresponding protocol request and run the same step.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from . import buddy, buddy_cache, cost_model, heap, pim_malloc
from .buddy import BuddyConfig, BuddyState, ilog2, next_pow2
from .buddy_cache import (BuddyCacheConfig, SWBufferConfig, buddy_cache_access,
                          buddy_cache_init, sw_buffer_access, sw_buffer_init)
from .cost_model import DPUCost
from .heap import (OP_CALLOC, OP_FREE, OP_MALLOC, OP_NOOP, OP_REALLOC,
                   AllocRequest, AllocResponse)
from .pim_malloc import INVALID, PimMallocConfig

# Backend enumeration has ONE source of truth: the protocol registry
# (`heap.REGISTRY`, populated by the `@heap.register` decorators below).
# `KINDS` is derived from it on attribute access (PEP 562), so registering
# a backend — from this module or anywhere else — auto-enrolls it in every
# KINDS-parametrized suite (pinned in tests/test_heap_api.py).
def __getattr__(name: str):
    if name == "KINDS":
        heap._ensure_backends()
        return tuple(heap.REGISTRY)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# --------------------------------------------------------------------------
# Straw-man allocator: buddy-only over the full heap, min 32 B
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StrawmanConfig:
    heap_bytes: int = 32 * 1024 * 1024
    num_threads: int = 16
    min_block: int = 32

    @property
    def buddy_cfg(self) -> BuddyConfig:
        return BuddyConfig(heap_bytes=self.heap_bytes, min_block=self.min_block)


class StrawmanState(NamedTuple):
    buddy: BuddyState
    leaf_log2: jnp.ndarray  # int8[n_leaf] alloc size exponent at base leaf, -1


def strawman_init(cfg: StrawmanConfig) -> StrawmanState:
    return StrawmanState(
        buddy=buddy.init(cfg.buddy_cfg),
        leaf_log2=jnp.full((cfg.buddy_cfg.n_leaf,), -1, jnp.int8),
    )


def strawman_malloc(cfg: StrawmanConfig, st: StrawmanState, sizes, active=None):
    T = cfg.num_threads
    if active is None:
        active = jnp.ones((T,), bool)
    requested = active & (sizes > 0)
    # heap-exceeding sizes fail without reaching next_pow2 (int32 wrap > 2^30)
    active = requested & (sizes <= cfg.heap_bytes)
    tlen = cfg.buddy_cfg.trace_len

    def step(carry, x):
        bstate, leaf_log2, border = carry
        need, size = x
        bstate2, off, bev = buddy.alloc(cfg.buddy_cfg, bstate, size)
        ok = need & (off >= 0)
        bstate = BuddyState(longest=jnp.where(need, bstate2.longest, bstate.longest))
        leaf = jnp.where(ok, off // cfg.min_block, 0)
        lg = ilog2(next_pow2(jnp.maximum(size, cfg.min_block)))
        leaf_log2 = leaf_log2.at[leaf].set(
            jnp.where(ok, lg.astype(jnp.int8), leaf_log2[leaf])
        )
        ptr = jnp.where(ok, off, INVALID)
        bpos = jnp.where(need, border, INVALID)
        border = border + need.astype(jnp.int32)
        ev = (
            jnp.where(need, bev.levels_down, 0),
            jnp.where(need, bev.levels_up, 0),
            jnp.where(need, bev.trace, jnp.full((tlen,), INVALID, jnp.int32)),
            bpos, ok,
        )
        return (bstate, leaf_log2, border), (ptr, ev)

    carry = (st.buddy, st.leaf_log2, jnp.int32(0))
    carry, (ptrs, (lv_down, lv_up, trace, bpos, ok)) = lax.scan(
        step, carry, (active, sizes)
    )
    bstate, leaf_log2, _ = carry
    path = jnp.where(active & ok, 2,
                     jnp.where(requested, 3, INVALID)).astype(jnp.int32)
    ev = pim_malloc.MallocEvent(path=path, backend_pos=bpos, levels_down=lv_down,
                                levels_up=lv_up, trace=trace)
    return StrawmanState(buddy=bstate, leaf_log2=leaf_log2), ptrs, ev


def strawman_free(cfg: StrawmanConfig, st: StrawmanState, ptrs, active=None):
    """Strawman free round. Same misuse accounting as `pim_malloc.free`:
    NULL (-1) frees are benign no-ops (path -1); any other requested free
    that is out of range or untracked is dropped (path 2)."""
    T = cfg.num_threads
    if active is None:
        active = jnp.ones((T,), bool)
    requested = active & (ptrs != INVALID)
    active = requested & (ptrs >= 0) & (ptrs < cfg.heap_bytes)
    tlen = cfg.buddy_cfg.trace_len

    def step(carry, x):
        bstate, leaf_log2, border = carry
        need, ptr = x
        leaf = jnp.where(need, ptr // cfg.min_block, 0)
        lg = leaf_log2[leaf].astype(jnp.int32)
        need = need & (lg >= 0)
        size = jnp.int32(1) << jnp.maximum(lg, 0)
        bstate2, bev = buddy.free(cfg.buddy_cfg, bstate, ptr, size)
        bstate = BuddyState(longest=jnp.where(need, bstate2.longest, bstate.longest))
        leaf_log2 = leaf_log2.at[leaf].set(
            jnp.where(need, jnp.int8(-1), leaf_log2[leaf])
        )
        bpos = jnp.where(need, border, INVALID)
        border = border + need.astype(jnp.int32)
        ev = (
            jnp.where(need, bev.levels_up, 0),
            jnp.where(need, bev.trace, jnp.full((tlen,), INVALID, jnp.int32)),
            bpos,
        )
        return (bstate, leaf_log2, border), ev

    carry = (st.buddy, st.leaf_log2, jnp.int32(0))
    carry, (lv_up, trace, bpos) = lax.scan(step, carry, (active, ptrs))
    bstate, leaf_log2, _ = carry
    dropped = requested & (bpos < 0)
    path = jnp.where(bpos >= 0, 1, jnp.where(dropped, 2, INVALID)).astype(jnp.int32)
    ev = pim_malloc.FreeEvent(path=path, backend_pos=bpos, levels_up=lv_up,
                              trace=trace)
    return StrawmanState(buddy=bstate, leaf_log2=leaf_log2), ev


# --------------------------------------------------------------------------
# Composite simulator
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SystemConfig:
    kind: str = "sw"
    heap_bytes: int = 32 * 1024 * 1024
    num_threads: int = 16
    pm: PimMallocConfig = None
    straw: StrawmanConfig = None
    sw_buf: SWBufferConfig = SWBufferConfig()
    bc: BuddyCacheConfig = BuddyCacheConfig()
    dpu: DPUCost = DPUCost()
    # ``pallas`` kind only: batched same-class backend refill inside the
    # fused kernel. None defers to PIM_MALLOC_BATCH_REFILL (default on);
    # False forces the pre-batching serial walk. Bitwise-identical either
    # way — this is a wall-clock knob, not a semantic one.
    kernel_batch_refill: bool = None
    # ``arena``/``tlregion`` kinds only: which backend serves arena spills —
    # "hwsw" (scan-based reference) or "pallas" (the fused kernel under the
    # existing 3-way refill switch). Bitwise-identical either way (the
    # kernel parity guarantee composes through the arena layer; pinned in
    # tests/test_kind_conformance.py).
    arena_inner: str = "hwsw"

    def __post_init__(self):
        heap._ensure_backends()
        assert self.kind in heap.REGISTRY, \
            f"unknown kind {self.kind!r} (registered: {tuple(heap.REGISTRY)})"
        if self.pm is None:
            object.__setattr__(self, "pm", PimMallocConfig(
                heap_bytes=self.heap_bytes, num_threads=self.num_threads))
        if self.straw is None:
            object.__setattr__(self, "straw", StrawmanConfig(
                heap_bytes=self.heap_bytes, num_threads=self.num_threads))

    @property
    def trace_len(self) -> int:
        cfg = self.straw.buddy_cfg if self.kind == "strawman" else self.pm.buddy_cfg
        return cfg.trace_len

    @property
    def access_fn(self):
        if self.kind in ("hwsw", "pallas", "sanitizer", "arena", "tlregion"):
            return functools.partial(buddy_cache_access, self.bc)
        return functools.partial(sw_buffer_access, self.sw_buf)

    def cache_init(self):
        if self.kind in ("hwsw", "pallas", "sanitizer", "arena", "tlregion"):
            return buddy_cache_init(self.bc)
        return sw_buffer_init(self.sw_buf)

    @property
    def dma_bytes_per_miss(self) -> int:
        if self.kind in ("hwsw", "pallas", "sanitizer", "arena", "tlregion"):
            return buddy_cache.WORD_BYTES
        return self.sw_buf.line_bytes


class HeapTelemetry(NamedTuple):
    """Per-core heap-health counters, advanced on every protocol round.

    Rounded (size-class / pow2) bytes, i.e. allocator-side occupancy, not
    user-requested bytes. For any well-formed request stream the
    conservation law

        live_bytes + buddy free bytes + cached thread-cache bytes
            == heap_bytes

    holds after every round (pinned in tests/test_telemetry.py); the two
    snapshot terms come from `repro.core.telemetry`. Both counters are
    identical across backends — the deltas are computed in `_price_round`,
    which every kind (including ``pallas``) goes through.
    """

    live_bytes: jnp.ndarray  # int32[] rounded bytes currently handed out
    hwm_bytes: jnp.ndarray   # int32[] high-water mark of live_bytes


def telemetry_init() -> HeapTelemetry:
    z = jnp.int32(0)
    return HeapTelemetry(live_bytes=z, hwm_bytes=z)


def _advance_telemetry(t: HeapTelemetry, alloc_bytes, freed_bytes):
    live = t.live_bytes + alloc_bytes - freed_bytes
    return HeapTelemetry(live_bytes=live,
                         hwm_bytes=jnp.maximum(t.hwm_bytes, live))


class SystemState(NamedTuple):
    alloc: object            # PimMallocState | StrawmanState
    cache: object            # BuddyCacheState | SWBufferState
    telem: HeapTelemetry     # fragmentation/utilization counters


class RoundInfo(NamedTuple):
    latency_cyc: jnp.ndarray   # float32[T]
    path: jnp.ndarray          # int32[T]
    meta_hits: jnp.ndarray     # int32[T]
    meta_misses: jnp.ndarray   # int32[T]
    dram_bytes: jnp.ndarray    # int32[T]
    backend_cyc: jnp.ndarray   # float32[T] service time excl. queuing


def system_init(cfg: SystemConfig, prepopulate: bool = True):
    if cfg.kind in ("arena", "tlregion"):
        # the layered frontend owns its region carve — freelists start empty
        # and spill-refill on demand (see repro.core.arena.init_state)
        from . import arena
        return arena.init_state(cfg)
    if cfg.kind == "strawman":
        alloc = strawman_init(cfg.straw)
    else:
        alloc = pim_malloc.init(cfg.pm, prepopulate=prepopulate)
    base = SystemState(alloc=alloc, cache=cfg.cache_init(),
                       telem=telemetry_init())
    if cfg.kind == "sanitizer":
        from . import sanitizer
        return sanitizer.init_state(cfg, base)
    return base


def _cache_pass(cfg: SystemConfig, cache_st, backend_pos, traces):
    """Run the metadata cache over this round's backend ops in mutex order."""
    T = traces.shape[0]
    key = jnp.where(backend_pos >= 0, backend_pos, jnp.int32(1 << 30))
    order = jnp.argsort(key)
    traces_sorted = traces[order]
    cache_st, stats = buddy_cache.simulate_traces(cfg.access_fn, cache_st,
                                                  traces_sorted)
    inv = jnp.zeros((T,), jnp.int32).at[order].set(jnp.arange(T, dtype=jnp.int32))
    return cache_st, buddy_cache.TraceStats(
        hits=stats.hits[inv], misses=stats.misses[inv],
        dram_bytes=stats.dram_bytes[inv],
    )


def _strawman_realloc_meta(cfg: StrawmanConfig, st: StrawmanState, ptrs, sizes):
    """Strawman counterpart of pim_malloc.realloc_meta over leaf_log2."""
    valid = (ptrs >= 0) & (ptrs < cfg.heap_bytes)
    leaf = jnp.where(valid, ptrs // cfg.min_block, 0)
    lg = st.leaf_log2[leaf].astype(jnp.int32)
    valid_old = valid & (lg >= 0)
    old_bytes = jnp.where(valid_old, jnp.int32(1) << jnp.maximum(lg, 0), 0)
    new_bytes = next_pow2(jnp.maximum(sizes, cfg.min_block))
    return pim_malloc.ReallocMeta(
        valid_old=valid_old, in_place=valid_old & (new_bytes == old_bytes),
        old_bytes=old_bytes, new_bytes=new_bytes)


def _protocol_round(cfg: SystemConfig, st: SystemState, req: AllocRequest,
                    malloc_fn, free_fn, meta_fn, free_path_fn):
    """One mixed-op protocol round over kind-specific allocator primitives.

    Phases: (1) realloc size-class analysis on the pre-round metadata,
    (2) one batched malloc round (MALLOC/CALLOC + relocating REALLOCs),
    (3) one batched free round (FREE + released old realloc blocks), then a
    single metadata-cache pass + mutex queue over both phases' backend ops
    in serialization order (malloc phase drains first — mutex FIFO).
    """
    op, size, ptr = req.op, req.size, req.ptr
    is_alloc = (op == OP_MALLOC) | (op == OP_CALLOC)
    is_re = op == OP_REALLOC
    is_free = op == OP_FREE

    meta = meta_fn(st.alloc, ptr, size)
    re_live = is_re & (size > 0)
    in_place = re_live & meta.in_place
    moved = re_live & ~meta.in_place
    re_free0 = is_re & (size <= 0) & (ptr >= 0)

    # ---- phase 1: batched malloc (new blocks) ------------------------------
    m_active = (is_alloc & (size > 0)) | moved
    alloc_st, mptrs, mev = malloc_fn(st.alloc, jnp.where(m_active, size, 0),
                                     m_active)
    mok = m_active & (mptrs >= 0)

    # ---- phase 2: batched free (explicit frees + vacated realloc blocks) ---
    f_active = is_free | (moved & meta.valid_old & mok) | re_free0
    alloc_st, fev = free_fn(alloc_st, jnp.where(f_active, ptr, INVALID),
                            f_active)
    fpath = free_path_fn(fev)

    # ---- one cache pass + shared pricing over both phases ------------------
    n_back_m = jnp.sum(mev.backend_pos >= 0)
    bpos = jnp.concatenate([
        mev.backend_pos,
        jnp.where(fev.backend_pos >= 0, fev.backend_pos + n_back_m, INVALID),
    ])
    traces = jnp.concatenate([mev.trace, fev.trace], axis=0)
    cache_st, tstats = _cache_pass(cfg, st.cache, bpos, traces)
    T = op.shape[0]
    resp, alloc_bytes, freed_bytes = _price_round(
        cfg, req, mptrs=mptrs, m_path=mev.path, m_bpos=mev.backend_pos,
        m_lvdown=mev.levels_down, m_lvup=mev.levels_up, fpath=fpath,
        f_bpos=fev.backend_pos, f_lvup=fev.levels_up,
        hits_m=tstats.hits[:T], miss_m=tstats.misses[:T],
        dram_m=tstats.dram_bytes[:T], hits_f=tstats.hits[T:],
        miss_f=tstats.misses[T:], dram_f=tstats.dram_bytes[T:],
        in_place=in_place, moved=moved, mok=mok, valid_old=meta.valid_old,
        old_bytes=meta.old_bytes, new_bytes=meta.new_bytes,
        re_free0=re_free0)
    telem = _advance_telemetry(st.telem, alloc_bytes, freed_bytes)
    return SystemState(alloc=alloc_st, cache=cache_st, telem=telem), resp


def _price_round(cfg: SystemConfig, req: AllocRequest, *, mptrs, m_path,
                 m_bpos, m_lvdown, m_lvup, fpath, f_bpos, f_lvup, hits_m,
                 miss_m, dram_m, hits_f, miss_f, dram_f, in_place, moved,
                 mok, valid_old, old_bytes, new_bytes, re_free0):
    """Price one protocol round; returns (AllocResponse, alloc_bytes,
    freed_bytes) — the heap-telemetry deltas of the round in rounded
    allocator bytes (see :class:`HeapTelemetry`).

    Shared by every backend: the scan-based rounds feed it the metadata
    cache sim's per-op stats, the ``pallas`` backend feeds it the fused
    kernel's in-kernel counters. Identical counters => identical latencies
    and telemetry, which is what pins the kernel path bitwise to the
    ``hwsw`` reference.
    """
    op, size, ptr = req.op, req.size, req.ptr
    is_alloc = (op == OP_MALLOC) | (op == OP_CALLOC)
    is_free = op == OP_FREE

    n_back_m = jnp.sum(m_bpos >= 0)
    bpos = jnp.concatenate(
        [m_bpos, jnp.where(f_bpos >= 0, f_bpos + n_back_m, INVALID)])
    cyc_m = cost_model.backend_op_cyc(cfg.dpu, m_lvdown, m_lvup,
                                      hits_m, miss_m, dram_m)
    cyc_m = jnp.where(m_bpos >= 0, cyc_m, 0.0)
    cyc_f = cost_model.backend_op_cyc(cfg.dpu, jnp.zeros_like(f_lvup),
                                      f_lvup, hits_f, miss_f, dram_f)
    cyc_f = jnp.where(f_bpos >= 0, cyc_f, 0.0)

    # mutex busy-wait: position k waits for the service of positions < k
    svc = jnp.concatenate([cyc_m, cyc_f])
    key = jnp.where(bpos >= 0, bpos, jnp.int32(1 << 30))
    order = jnp.argsort(key)
    wait_sorted = jnp.cumsum(svc[order]) - svc[order]
    wait = jnp.zeros_like(svc).at[order].set(wait_sorted)
    wait = jnp.where(bpos >= 0, wait, 0.0)
    T = op.shape[0]
    wait_m, wait_f = wait[:T], wait[T:]

    dpu = cfg.dpu
    own_m = (jnp.where(m_path == 0, dpu.cyc_front_hit, 0.0)
             + jnp.where(m_path == 1, dpu.cyc_front_hit + dpu.cyc_refill, 0.0)
             + cyc_m)
    lat_m = jnp.where(m_path >= 0, own_m + wait_m, 0.0)
    own_f = jnp.where(fpath == 0, dpu.cyc_front_push, 0.0) + cyc_f
    lat_f = jnp.where(fpath >= 0, own_f + wait_f, 0.0)
    # relocating realloc DMAs the surviving payload; calloc zero-fills.
    copy_cyc = jnp.where(
        moved & mok & valid_old,
        cost_model.mram_access_cyc(dpu, jnp.minimum(old_bytes, new_bytes)),
        0.0)
    zero_cyc = jnp.where((op == OP_CALLOC) & mok,
                         cost_model.mram_access_cyc(dpu, size), 0.0)
    # in-place realloc: O(1) metadata peek, no heap traffic.
    inplace_cyc = jnp.where(in_place, jnp.float32(dpu.cyc_front_hit), 0.0)
    latency = lat_m + lat_f + copy_cyc + zero_cyc + inplace_cyc

    m_active = (is_alloc & (size > 0)) | moved
    out_ptr = jnp.where(is_alloc & mok, mptrs,
                        jnp.where(in_place, ptr,
                                  jnp.where(moved & mok, mptrs, INVALID)))
    ok = (is_alloc & mok) | in_place | (moved & mok) | (
        (is_free | re_free0) & ((fpath == 0) | (fpath == 1)))
    path = jnp.where(m_active, m_path,
                     jnp.where(is_free | re_free0, fpath,
                               jnp.where(in_place, 0, INVALID)))
    # heap-telemetry deltas: rounded bytes handed out / returned this round
    # (new_bytes/old_bytes come from the kind's realloc-meta rounding, which
    # matches the malloc/free paths' actual placement sizes)
    new_alloc = (is_alloc & mok) | (moved & mok)
    alloc_bytes = jnp.sum(jnp.where(new_alloc, new_bytes, 0))
    # every free-phase participant — explicit frees, realloc(p, 0), and a
    # moved realloc's vacated old block — only returns bytes when the free
    # actually served (fpath 0/1): a capacity-dropped push (fpath 2) leaks
    # the block, which must stay in live_bytes for conservation to hold
    freed_served = ((is_free | re_free0 | (moved & mok & valid_old))
                    & ((fpath == 0) | (fpath == 1)))
    freed_bytes = jnp.sum(jnp.where(freed_served, old_bytes, 0))
    resp = AllocResponse(
        ptr=out_ptr, ok=ok, path=path.astype(jnp.int32), moved=moved & mok,
        latency_cyc=latency, backend_cyc=cyc_m + cyc_f,
        meta_hits=hits_m + hits_f, meta_misses=miss_m + miss_f,
        dram_bytes=dram_m + dram_f,
    )
    return resp, alloc_bytes, freed_bytes


@heap.register("strawman")
def _step_strawman(cfg: SystemConfig, st: SystemState, req: AllocRequest):
    return _protocol_round(
        cfg, st, req,
        malloc_fn=lambda s, z, a: strawman_malloc(cfg.straw, s, z, a),
        free_fn=lambda s, p, a: strawman_free(cfg.straw, s, p, a),
        meta_fn=lambda s, p, z: _strawman_realloc_meta(cfg.straw, s, p, z),
        free_path_fn=lambda ev: ev.path,
    )


@heap.register("sw")
@heap.register("hwsw")
def _step_pim(cfg: SystemConfig, st: SystemState, req: AllocRequest):
    return _protocol_round(
        cfg, st, req,
        malloc_fn=lambda s, z, a: pim_malloc.malloc(cfg.pm, s, z, a),
        free_fn=lambda s, p, a: pim_malloc.free(cfg.pm, s, p, a),
        meta_fn=lambda s, p, z: pim_malloc.realloc_meta(cfg.pm, s, p, z),
        free_path_fn=lambda ev: ev.path,
    )


@functools.partial(jax.jit, static_argnums=0)
def _sanitizer_step_compiled(cfg: SystemConfig, st, req: AllocRequest):
    from . import sanitizer

    return sanitizer.step(cfg, st, req, _step_pim)


@heap.register("sanitizer")
def _step_sanitizer(cfg: SystemConfig, st, req: AllocRequest):
    """ASan-style shadow-heap wrapper over the hwsw design point.

    Classifies every FREE/REALLOC operand against a 16 B-granule shadow
    map, quarantines legitimate frees in a FIFO ring, and forwards only
    clean work to `_step_pim`; poisoned operands are answered with
    deterministic tagged reports. See `repro.core.sanitizer`.

    The step is jit-compiled as a single unit (cfg static): the shadow
    classification + forwarded hwsw round otherwise execute as dozens of
    separately compiled primitives per eager call, which both slows the
    KINDS-parametrized suites down and bloats XLA's per-process
    compilation footprint.
    """
    return _sanitizer_step_compiled(cfg, st, req)


@functools.partial(jax.jit, static_argnums=0)
def _arena_step_compiled(cfg: SystemConfig, st, req: AllocRequest):
    from . import arena

    inner = _step_pallas if cfg.arena_inner == "pallas" else _step_pim
    return arena.step(cfg, st, req, inner)


@heap.register("arena")
@heap.register("tlregion")
def _step_arena(cfg: SystemConfig, st, req: AllocRequest):
    """The layered design points: bump-pointer frontend over the pim stack.

    A pure-jnp arena pass (`repro.core.arena`) serves small allocs by
    bumping into a region carved out of the buddy heap at init, retires
    whole epochs with OP_EPOCH_RESET, and forwards everything else — big
    allocs, non-arena pointers, and spill-on-exhaustion — to the full
    hwsw stack (`_step_pim`, or the fused kernel when
    ``cfg.arena_inner == "pallas"``). ``arena`` shares one region (bump
    adds serialize for cyc_bump_atomic each); ``tlregion`` gives each
    thread its own region and per-thread resets — no cross-thread atomic
    on the fast path. Jit-compiled as one unit for the same reason as the
    sanitizer step.
    """
    return _arena_step_compiled(cfg, st, req)


@heap.register("pallas")
def _step_pallas(cfg: SystemConfig, st: SystemState, req: AllocRequest):
    """The fused-kernel design point: hwsw semantics, one Pallas call.

    The whole round (dispatch + thread-cache frontend + serial buddy backend
    + LRU buddy cache) runs inside `repro.kernels.heap_step`; this wrapper
    only rebuilds the state pytree, folds the kernel's per-thread records
    into the allocator stats, and prices the round through the same
    `_price_round` as the scan-based backends. State layout is identical to
    ``hwsw`` (PimMallocState + BuddyCacheState), and results are bitwise
    equal to it — pinned in tests/test_pallas_heap.py.
    """
    from repro.kernels import heap_step

    pmc = cfg.pm
    al, ca = st.alloc, st.cache
    out = heap_step.fused_heap_step(
        req.op, req.size, req.ptr, al.buddy.longest, al.counts, al.stacks,
        al.block_cls, al.block_free, al.big_log2, ca.tags, ca.last_used,
        jnp.reshape(ca.clock, (1,)), heap_bytes=pmc.heap_bytes,
        block_bytes=pmc.block_bytes, size_classes=pmc.size_classes,
        batch_refill=cfg.kernel_batch_refill)

    m_hit = out.m_hit.astype(bool)
    m_refill = out.m_refill.astype(bool)
    m_bypass = out.m_bypass.astype(bool)
    m_okb = out.m_okb.astype(bool)
    f_push = out.f_push.astype(bool)
    f_big = out.f_big.astype(bool)
    in_place = out.in_place.astype(bool)
    moved = out.moved_raw.astype(bool)
    valid_old = out.valid_old.astype(bool)

    need = m_refill | m_bypass
    is_alloc = (req.op == OP_MALLOC) | (req.op == OP_CALLOC)
    m_active = (is_alloc & (req.size > 0)) | moved
    too_big = m_active & (req.size > pmc.heap_bytes)
    m_path = jnp.where(
        m_hit, 0,
        jnp.where(m_refill & m_okb, 1,
                  jnp.where(m_bypass & m_okb, 2,
                            jnp.where(need | too_big, 3, INVALID)))
    ).astype(jnp.int32)
    mok = m_active & (out.m_ptr >= 0)
    re_free0 = (req.op == OP_REALLOC) & (req.size <= 0) & (req.ptr >= 0)
    # same misuse accounting as pim_malloc.free: every requested free that
    # neither pushed nor reached the buddy is dropped (NULL == -1 exempt)
    f_active = (req.op == OP_FREE) | (moved & valid_old & mok) | re_free0
    f_drop = f_active & (req.ptr != -1) & ~f_push & ~f_big
    fpath = jnp.where(f_push, 0,
                      jnp.where(f_big, 1,
                                jnp.where(f_drop, 2, INVALID))).astype(jnp.int32)

    stats = al.stats._replace(
        front_hits=al.stats.front_hits + jnp.sum(m_hit),
        front_misses=al.stats.front_misses + jnp.sum(m_refill),
        bypass=al.stats.bypass + jnp.sum(m_bypass),
        fails=al.stats.fails + jnp.sum((need & ~m_okb) | too_big),
        frees_small=al.stats.frees_small + jnp.sum(f_push),
        frees_big=al.stats.frees_big + jnp.sum(f_big),
        dropped_frees=al.stats.dropped_frees + jnp.sum(f_drop),
    )
    new_alloc = pim_malloc.PimMallocState(
        buddy=BuddyState(longest=out.longest), counts=out.counts,
        stacks=out.stacks, block_cls=out.block_cls,
        block_free=out.block_free, big_log2=out.big_log2, stats=stats)
    new_cache = buddy_cache.BuddyCacheState(
        tags=out.tags, last_used=out.last_used,
        clock=jnp.reshape(out.clock, ()))

    dma = cfg.dma_bytes_per_miss
    resp, alloc_bytes, freed_bytes = _price_round(
        cfg, req, mptrs=out.m_ptr, m_path=m_path, m_bpos=out.m_bpos,
        m_lvdown=out.m_lvdown, m_lvup=out.m_lvup, fpath=fpath,
        f_bpos=out.f_bpos, f_lvup=out.f_lvup,
        hits_m=out.m_hits, miss_m=out.m_miss, dram_m=out.m_miss * dma,
        hits_f=out.f_hits, miss_f=out.f_miss, dram_f=out.f_miss * dma,
        in_place=in_place, moved=moved, mok=mok, valid_old=valid_old,
        old_bytes=out.old_bytes, new_bytes=out.new_bytes, re_free0=re_free0)
    telem = _advance_telemetry(st.telem, alloc_bytes, freed_bytes)
    return SystemState(alloc=new_alloc, cache=new_cache, telem=telem), resp


def _round_info(resp: AllocResponse) -> RoundInfo:
    return RoundInfo(latency_cyc=resp.latency_cyc, path=resp.path,
                     meta_hits=resp.meta_hits, meta_misses=resp.meta_misses,
                     dram_bytes=resp.dram_bytes, backend_cyc=resp.backend_cyc)


def fleet_accounting(req: AllocRequest, resp: AllocResponse) -> dict:
    """Cost-model accounting of one batched protocol round.

    Works on any leading batch shape; with [R, C, T] leaves (a ShardedHeap
    round) the `per_rank` lists break totals down by rank — the fleet-level
    numbers a router reports per round. Fleet totals are exact sums of the
    per-rank entries (pinned in tests/test_sharded_heap.py).
    """
    import numpy as np
    op = np.asarray(req.op)
    active = op != OP_NOOP
    lat = np.asarray(resp.latency_cyc)
    out = {
        "ops": int(active.sum()),
        "ok": int(np.asarray(resp.ok).sum()),
        "latency_cyc": float(lat.sum()),
        "max_latency_cyc": float(lat.max()) if lat.size else 0.0,
        "backend_cyc": float(np.asarray(resp.backend_cyc).sum()),
        "meta_hits": int(np.asarray(resp.meta_hits).sum()),
        "meta_misses": int(np.asarray(resp.meta_misses).sum()),
        "dram_bytes": int(np.asarray(resp.dram_bytes).sum()),
    }
    if op.ndim >= 3:  # [R, ...]: per-rank breakdown over the leading axis
        rest = tuple(range(1, op.ndim))
        out["per_rank"] = {
            "ops": active.sum(axis=rest).tolist(),
            "latency_cyc": lat.sum(axis=rest).tolist(),
            "dram_bytes": np.asarray(resp.dram_bytes).sum(axis=rest).tolist(),
        }
    return out


def malloc_round(cfg: SystemConfig, st: SystemState, sizes, active=None):
    """One all-MALLOC round: sizes int32[T]. Returns (state, ptrs, RoundInfo)."""
    st, resp = heap.step(cfg, st, heap.malloc_request(sizes, active))
    return st, resp.ptr, _round_info(resp)


def free_round(cfg: SystemConfig, st: SystemState, ptrs, active=None):
    """One all-FREE round: ptrs int32[T]. Returns (state, RoundInfo)."""
    st, resp = heap.step(cfg, st, heap.free_request(ptrs, active))
    return st, _round_info(resp)


def run_alloc_rounds(cfg: SystemConfig, st: SystemState, sizes_rounds):
    """scan over [R, T] request rounds; returns (state, ptrs [R,T], infos [R,...])."""

    def step(st, sizes):
        st, ptrs, info = malloc_round(cfg, st, sizes)
        return st, (ptrs, info)

    st, (ptrs, infos) = lax.scan(step, st, sizes_rounds)
    return st, ptrs, infos


def run_alloc_free_rounds(cfg: SystemConfig, st: SystemState, sizes_rounds):
    """Each round: alloc then immediately free (Fig 6's (de)allocation loop)."""

    def step(st, sizes):
        st, ptrs, info_a = malloc_round(cfg, st, sizes)
        st, info_f = free_round(cfg, st, ptrs)
        return st, (info_a, info_f)

    st, (infos_a, infos_f) = lax.scan(step, st, sizes_rounds)
    return st, infos_a, infos_f
