"""End-to-end allocator system simulation: the paper's three design points.

  strawman : buddy_alloc_PIM_DRAM — single-level buddy over the whole heap,
             min block 32 B (20-level tree for 32 MB), shared mutex, coarse
             SW metadata buffer. (Section 3.2/3.3.)
  sw       : PIM-malloc-SW — per-thread caches + 13-level buddy backend +
             coarse SW metadata buffer. (Section 4.1.)
  hwsw     : PIM-malloc-HW/SW — same frontend/backend, but backend metadata
             served by the 16-entry LRU hardware buddy cache. (Section 4.2.)

`malloc_round` / `free_round` service one batched request round (one request
per thread), persist metadata-cache state across rounds, and return
per-thread latencies from the DPU cost model — including mutex busy-wait for
backend users (Fig 7). A whole multi-core PIM system is `vmap` over cores of
these functions (see benchmarks/fig5) and a TPU mesh deployment is
`shard_map` of that (`repro.launch`).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax.numpy as jnp
from jax import lax

from . import buddy, buddy_cache, cost_model, pim_malloc
from .buddy import BuddyConfig, BuddyState, ilog2, next_pow2
from .buddy_cache import (BuddyCacheConfig, SWBufferConfig, buddy_cache_access,
                          buddy_cache_init, sw_buffer_access, sw_buffer_init)
from .cost_model import DPUCost
from .pim_malloc import INVALID, PimMallocConfig

KINDS = ("strawman", "sw", "hwsw")


# --------------------------------------------------------------------------
# Straw-man allocator: buddy-only over the full heap, min 32 B
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StrawmanConfig:
    heap_bytes: int = 32 * 1024 * 1024
    num_threads: int = 16
    min_block: int = 32

    @property
    def buddy_cfg(self) -> BuddyConfig:
        return BuddyConfig(heap_bytes=self.heap_bytes, min_block=self.min_block)


class StrawmanState(NamedTuple):
    buddy: BuddyState
    leaf_log2: jnp.ndarray  # int8[n_leaf] alloc size exponent at base leaf, -1


def strawman_init(cfg: StrawmanConfig) -> StrawmanState:
    return StrawmanState(
        buddy=buddy.init(cfg.buddy_cfg),
        leaf_log2=jnp.full((cfg.buddy_cfg.n_leaf,), -1, jnp.int8),
    )


def strawman_malloc(cfg: StrawmanConfig, st: StrawmanState, sizes, active=None):
    T = cfg.num_threads
    if active is None:
        active = jnp.ones((T,), bool)
    active = active & (sizes > 0)
    tlen = cfg.buddy_cfg.trace_len

    def step(carry, x):
        bstate, leaf_log2, border = carry
        need, size = x
        bstate2, off, bev = buddy.alloc(cfg.buddy_cfg, bstate, size)
        ok = need & (off >= 0)
        bstate = BuddyState(longest=jnp.where(need, bstate2.longest, bstate.longest))
        leaf = jnp.where(ok, off // cfg.min_block, 0)
        lg = ilog2(next_pow2(jnp.maximum(size, cfg.min_block)))
        leaf_log2 = leaf_log2.at[leaf].set(
            jnp.where(ok, lg.astype(jnp.int8), leaf_log2[leaf])
        )
        ptr = jnp.where(ok, off, INVALID)
        bpos = jnp.where(need, border, INVALID)
        border = border + need.astype(jnp.int32)
        ev = (
            jnp.where(need, bev.levels_down, 0),
            jnp.where(need, bev.levels_up, 0),
            jnp.where(need, bev.trace, jnp.full((tlen,), INVALID, jnp.int32)),
            bpos, ok,
        )
        return (bstate, leaf_log2, border), (ptr, ev)

    carry = (st.buddy, st.leaf_log2, jnp.int32(0))
    carry, (ptrs, (lv_down, lv_up, trace, bpos, ok)) = lax.scan(
        step, carry, (active, sizes)
    )
    bstate, leaf_log2, _ = carry
    path = jnp.where(active & ok, 2, jnp.where(active, 3, INVALID)).astype(jnp.int32)
    ev = pim_malloc.MallocEvent(path=path, backend_pos=bpos, levels_down=lv_down,
                                levels_up=lv_up, trace=trace)
    return StrawmanState(buddy=bstate, leaf_log2=leaf_log2), ptrs, ev


def strawman_free(cfg: StrawmanConfig, st: StrawmanState, ptrs, active=None):
    T = cfg.num_threads
    if active is None:
        active = jnp.ones((T,), bool)
    active = active & (ptrs >= 0) & (ptrs < cfg.heap_bytes)
    tlen = cfg.buddy_cfg.trace_len

    def step(carry, x):
        bstate, leaf_log2, border = carry
        need, ptr = x
        leaf = jnp.where(need, ptr // cfg.min_block, 0)
        lg = leaf_log2[leaf].astype(jnp.int32)
        need = need & (lg >= 0)
        size = jnp.int32(1) << jnp.maximum(lg, 0)
        bstate2, bev = buddy.free(cfg.buddy_cfg, bstate, ptr, size)
        bstate = BuddyState(longest=jnp.where(need, bstate2.longest, bstate.longest))
        leaf_log2 = leaf_log2.at[leaf].set(
            jnp.where(need, jnp.int8(-1), leaf_log2[leaf])
        )
        bpos = jnp.where(need, border, INVALID)
        border = border + need.astype(jnp.int32)
        ev = (
            jnp.where(need, bev.levels_up, 0),
            jnp.where(need, bev.trace, jnp.full((tlen,), INVALID, jnp.int32)),
            bpos,
        )
        return (bstate, leaf_log2, border), ev

    carry = (st.buddy, st.leaf_log2, jnp.int32(0))
    carry, (lv_up, trace, bpos) = lax.scan(step, carry, (active, ptrs))
    bstate, leaf_log2, _ = carry
    path = jnp.where(bpos >= 0, 1, INVALID).astype(jnp.int32)
    ev = pim_malloc.FreeEvent(path=path, backend_pos=bpos, levels_up=lv_up,
                              trace=trace)
    return StrawmanState(buddy=bstate, leaf_log2=leaf_log2), ev


# --------------------------------------------------------------------------
# Composite simulator
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SystemConfig:
    kind: str = "sw"
    heap_bytes: int = 32 * 1024 * 1024
    num_threads: int = 16
    pm: PimMallocConfig = None
    straw: StrawmanConfig = None
    sw_buf: SWBufferConfig = SWBufferConfig()
    bc: BuddyCacheConfig = BuddyCacheConfig()
    dpu: DPUCost = DPUCost()

    def __post_init__(self):
        assert self.kind in KINDS
        if self.pm is None:
            object.__setattr__(self, "pm", PimMallocConfig(
                heap_bytes=self.heap_bytes, num_threads=self.num_threads))
        if self.straw is None:
            object.__setattr__(self, "straw", StrawmanConfig(
                heap_bytes=self.heap_bytes, num_threads=self.num_threads))

    @property
    def trace_len(self) -> int:
        cfg = self.straw.buddy_cfg if self.kind == "strawman" else self.pm.buddy_cfg
        return cfg.trace_len

    @property
    def access_fn(self):
        if self.kind == "hwsw":
            return functools.partial(buddy_cache_access, self.bc)
        return functools.partial(sw_buffer_access, self.sw_buf)

    def cache_init(self):
        if self.kind == "hwsw":
            return buddy_cache_init(self.bc)
        return sw_buffer_init(self.sw_buf)

    @property
    def dma_bytes_per_miss(self) -> int:
        return buddy_cache.WORD_BYTES if self.kind == "hwsw" else self.sw_buf.line_bytes


class SystemState(NamedTuple):
    alloc: object            # PimMallocState | StrawmanState
    cache: object            # BuddyCacheState | SWBufferState


class RoundInfo(NamedTuple):
    latency_cyc: jnp.ndarray   # float32[T]
    path: jnp.ndarray          # int32[T]
    meta_hits: jnp.ndarray     # int32[T]
    meta_misses: jnp.ndarray   # int32[T]
    dram_bytes: jnp.ndarray    # int32[T]
    backend_cyc: jnp.ndarray   # float32[T] service time excl. queuing


def system_init(cfg: SystemConfig, prepopulate: bool = True) -> SystemState:
    if cfg.kind == "strawman":
        alloc = strawman_init(cfg.straw)
    else:
        alloc = pim_malloc.init(cfg.pm, prepopulate=prepopulate)
    return SystemState(alloc=alloc, cache=cfg.cache_init())


def _cache_pass(cfg: SystemConfig, cache_st, backend_pos, traces):
    """Run the metadata cache over this round's backend ops in mutex order."""
    T = traces.shape[0]
    key = jnp.where(backend_pos >= 0, backend_pos, jnp.int32(1 << 30))
    order = jnp.argsort(key)
    traces_sorted = traces[order]
    cache_st, stats = buddy_cache.simulate_traces(cfg.access_fn, cache_st,
                                                  traces_sorted)
    inv = jnp.zeros((T,), jnp.int32).at[order].set(jnp.arange(T, dtype=jnp.int32))
    return cache_st, buddy_cache.TraceStats(
        hits=stats.hits[inv], misses=stats.misses[inv],
        dram_bytes=stats.dram_bytes[inv],
    )


def malloc_round(cfg: SystemConfig, st: SystemState, sizes, active=None):
    """One batched round: sizes int32[T]. Returns (state, ptrs, RoundInfo)."""
    if cfg.kind == "strawman":
        alloc_st, ptrs, ev = strawman_malloc(cfg.straw, st.alloc, sizes, active)
    else:
        alloc_st, ptrs, ev = pim_malloc.malloc(cfg.pm, st.alloc, sizes, active)

    cache_st, tstats = _cache_pass(cfg, st.cache, ev.backend_pos, ev.trace)
    backend_cyc = cost_model.backend_op_cyc(
        cfg.dpu, ev.levels_down, ev.levels_up, tstats.hits, tstats.misses,
        tstats.dram_bytes,
    )
    backend_cyc = jnp.where(ev.backend_pos >= 0, backend_cyc, 0.0)
    lat = cost_model.round_latency_cyc(cfg.dpu, ev.path, ev.backend_pos, backend_cyc)
    info = RoundInfo(latency_cyc=lat, path=ev.path, meta_hits=tstats.hits,
                     meta_misses=tstats.misses, dram_bytes=tstats.dram_bytes,
                     backend_cyc=backend_cyc)
    return SystemState(alloc=alloc_st, cache=cache_st), ptrs, info


def free_round(cfg: SystemConfig, st: SystemState, ptrs, active=None):
    if cfg.kind == "strawman":
        alloc_st, ev = strawman_free(cfg.straw, st.alloc, ptrs, active)
        path = jnp.where(ev.backend_pos >= 0, 1, INVALID)
    else:
        alloc_st, ev = pim_malloc.free(cfg.pm, st.alloc, ptrs, active)
        path = ev.path
    cache_st, tstats = _cache_pass(cfg, st.cache, ev.backend_pos, ev.trace)
    backend_cyc = cost_model.backend_op_cyc(
        cfg.dpu, jnp.zeros_like(ev.levels_up), ev.levels_up, tstats.hits,
        tstats.misses, tstats.dram_bytes,
    )
    backend_cyc = jnp.where(ev.backend_pos >= 0, backend_cyc, 0.0)
    # frees: small -> push cost; big -> backend cost (+ queue)
    lat_path = jnp.where(path == 0, 0, jnp.where(path >= 1, 1, INVALID))
    own = jnp.where(path == 0, cfg.dpu.cyc_front_push, 0.0) + backend_cyc
    key = jnp.where(ev.backend_pos >= 0, ev.backend_pos, jnp.int32(1 << 30))
    order = jnp.argsort(key)
    svc = backend_cyc[order]
    wait_sorted = jnp.cumsum(svc) - svc
    wait = jnp.zeros_like(backend_cyc).at[order].set(wait_sorted)
    wait = jnp.where(ev.backend_pos >= 0, wait, 0.0)
    lat = jnp.where(path >= 0, own + wait, 0.0)
    info = RoundInfo(latency_cyc=lat, path=path, meta_hits=tstats.hits,
                     meta_misses=tstats.misses, dram_bytes=tstats.dram_bytes,
                     backend_cyc=backend_cyc)
    return SystemState(alloc=alloc_st, cache=cache_st), info


def run_alloc_rounds(cfg: SystemConfig, st: SystemState, sizes_rounds):
    """scan over [R, T] request rounds; returns (state, ptrs [R,T], infos [R,...])."""

    def step(st, sizes):
        st, ptrs, info = malloc_round(cfg, st, sizes)
        return st, (ptrs, info)

    st, (ptrs, infos) = lax.scan(step, st, sizes_rounds)
    return st, ptrs, infos


def run_alloc_free_rounds(cfg: SystemConfig, st: SystemState, sizes_rounds):
    """Each round: alloc then immediately free (Fig 6's (de)allocation loop)."""

    def step(st, sizes):
        st, ptrs, info_a = malloc_round(cfg, st, sizes)
        st, info_f = free_round(cfg, st, ptrs)
        return st, (info_a, info_f)

    st, (infos_a, infos_f) = lax.scan(step, st, sizes_rounds)
    return st, infos_a, infos_f
