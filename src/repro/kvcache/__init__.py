from . import paged
from .paged import PagePool

__all__ = ["paged", "PagePool"]
