"""Paged KV cache backed by PIM-malloc — the paper's technique as a
first-class serving feature.

Layout (distributed path): **per-sequence page pools**
    k_pages [L, B, P, page, KVH, hd]
Each sequence owns a reserved extent of P physical pages (exactly what the
buddy backend hands out at prefill); the page table indirects logical ->
physical *within* that extent, and single-page decode growth is served by
the thread-cache frontend. Sharding: B over ('pod','data') — every device
owns the pools AND page tables AND allocator metadata of its own sequences,
i.e. the paper's winning PIM-Metadata/PIM-Executed placement, with zero
cross-device metadata. KV heads / head_dim shard over 'model'.

The single-device serving path flattens the per-seq pools into the shared
pool the Pallas paged-attention kernel expects ([B*P, page, KVH, hd] with
global page ids), so the TPU kernel and the allocator-shared-pool story are
exercised end-to-end in examples/serve_paged.py.

`attend` implementations (explicit `impl=` argument; models thread
`ArchConfig.attend_impl` through — there is no module-global switch):
  * 'ref'    — pure-jnp batched gather + masked softmax; GSPMD-partitionable
               (used in pjit'd serve steps / the dry run).
  * 'kernel' — Pallas TPU kernel (scalar-prefetched page indices, online
               softmax in VMEM scratch).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.core.heap import AllocResponse

PAGE_UNIT = 16  # allocator bytes per page (smallest size class)


def pages_per_seq(max_seq: int, page_size: int) -> int:
    return math.ceil(max_seq / page_size)


def cache_spec(*, n_layers: int, batch: int, max_seq: int, page_size: int,
               kv_heads: int, head_dim: int, dtype):
    """ShapeDtypeStruct pytree for the paged cache (dry-run friendly)."""
    P = pages_per_seq(max_seq, page_size)
    sds = jax.ShapeDtypeStruct
    return {
        "k_pages": sds((n_layers, batch, P, page_size, kv_heads, head_dim), dtype),
        "v_pages": sds((n_layers, batch, P, page_size, kv_heads, head_dim), dtype),
        "page_table": sds((batch, P), jnp.int32),
        "seq_lens": sds((batch,), jnp.int32),
    }


def init_cache(*, n_layers: int, batch: int, max_seq: int, page_size: int,
               kv_heads: int, head_dim: int, dtype):
    """Zero cache with the identity page table (contiguous buddy extent)."""
    spec = cache_spec(n_layers=n_layers, batch=batch, max_seq=max_seq,
                      page_size=page_size, kv_heads=kv_heads,
                      head_dim=head_dim, dtype=dtype)
    P = spec["page_table"].shape[1]
    return {
        "k_pages": jnp.zeros(spec["k_pages"].shape, dtype),
        "v_pages": jnp.zeros(spec["v_pages"].shape, dtype),
        "page_table": jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32),
                                       (batch, P)).copy(),
        "seq_lens": jnp.zeros((batch,), jnp.int32),
    }


def write_prefill(pages, kv, page_table):
    """pages [B,P,page,KVH,hd]; kv [B,S,KVH,hd]; S % page_size == 0.

    put_along_axis (NOT .at[bidx, idx]) so the scatter carries batching
    dims — GSPMD keeps the batch axis sharded instead of involuntarily
    replicating the pool across the data axis."""
    B, P, page_size, KVH, hd = pages.shape
    S = kv.shape[1]
    assert S % page_size == 0, (S, page_size)
    sp = S // page_size
    kv4 = kv.reshape(B, sp, page_size, KVH, hd).astype(pages.dtype)
    idx = jnp.clip(page_table[:, :sp], 0, P - 1)
    return jax.vmap(lambda p, i, v: p.at[i].set(v))(pages, idx, kv4)


def write_token(pages, kv, page_table, pos):
    """pages [B,P,page,KVH,hd]; kv [B,KVH,hd]; pos int32[B] (0-based slot).

    Flattens (P, page) so the write is one batched put_along_axis."""
    B, P, page_size, KVH, hd = pages.shape
    pidx = jnp.take_along_axis(page_table, (pos // page_size)[:, None], axis=1)[:, 0]
    pidx = jnp.clip(pidx, 0, P - 1)
    slot = pos % page_size
    return jax.vmap(lambda p, i, s, v: p.at[i, s].set(v))(
        pages, pidx, slot, kv.astype(pages.dtype))


def _attend_ref(q, k_pages, v_pages, page_table, seq_lens):
    """Batched-gather reference: per-seq pools stay local on the data axis.

    take_along_axis (batching dims!) + bf16 gathers; fp32 only inside the
    einsum accumulators."""
    B, H, D = q.shape
    _, P, page_size, KVH, _ = k_pages.shape
    G = H // KVH
    scale = 1.0 / (D ** 0.5)
    pt = jnp.clip(page_table, 0, P - 1)
    k = jax.vmap(lambda p, i: p[i])(k_pages, pt).reshape(B, P * page_size,
                                                         KVH, D)
    v = jax.vmap(lambda p, i: p[i])(v_pages, pt).reshape(B, P * page_size,
                                                         KVH, D)
    qh = q.reshape(B, KVH, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qh.astype(k.dtype), k,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(P * page_size)[None, None, None, :]
    mask = pos < seq_lens[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(k.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, H, D).astype(q.dtype)


def attend(q, k_pages, v_pages, page_table, seq_lens, impl: str = "ref"):
    """Decode attention over per-seq paged KV. q [B,H,hd] -> [B,H,hd]."""
    if impl == "kernel":
        from repro.kernels import ops
        B, P, page_size, KVH, hd = k_pages.shape
        kp = k_pages.reshape(B * P, page_size, KVH, hd)
        vp = v_pages.reshape(B * P, page_size, KVH, hd)
        pt_global = (jnp.arange(B, dtype=jnp.int32)[:, None] * P
                     + jnp.clip(page_table, 0, P - 1))
        return ops.paged_attention_op(q, kp, vp, pt_global, seq_lens)
    return _attend_ref(q, k_pages, v_pages, page_table, seq_lens)


def _ambient_mesh():
    """The active mesh when it has a 'model' axis; None otherwise."""
    from repro.parallel.meshctx import ambient_mesh
    mesh = ambient_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        return mesh
    return None


def write_attend_seqpar(q, k_new, v_new, k_pages, v_pages, page_table, pos):
    """Flash-decoding under shard_map: pools shard their PHYSICAL page dim
    over 'model' (sequence parallelism). Each shard writes the new token iff
    it owns the target page (no cross-shard scatter), attends over its local
    pages with an online-softmax partial, and the partials combine with
    pmax/psum of [B, KVH, G(, hd)] stats — O(KB) collectives per layer
    instead of the GSPMD fallback's full-pool gathers/reduces.

    q [B,H,hd]; k_new/v_new [B,KVH,hd]; pools [B,P,page,KVH,hd]; pos [B].
    Returns (o [B,H,hd], k_pages, v_pages). Falls back to the write_token +
    attend pair when no 'model' mesh is ambient (single-device tests).
    """
    mesh = _ambient_mesh()
    if mesh is None:
        kp = write_token(k_pages, k_new, page_table, pos)
        vp = write_token(v_pages, v_new, page_table, pos)
        return attend(q, kp, vp, page_table, pos + 1), kp, vp

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    B, H, hd = q.shape
    _, Pn, page_size, KVH, _ = k_pages.shape
    G = H // KVH
    dp = tuple(a for a in mesh.axis_names if a != "model")
    dpb = dp if B % max(
        1, int(np.prod([mesh.shape[a] for a in dp]))) == 0 else None

    def local_fn(q, kn, vn, kp, vp, pt, pos):
        from jax import lax
        Bl = q.shape[0]
        Pl = kp.shape[1]
        midx = lax.axis_index("model")
        base = midx * Pl
        # ---- local write of the new token --------------------------------
        pidx = jnp.take_along_axis(pt, (pos // page_size)[:, None], axis=1)[:, 0]
        mine = (pidx >= base) & (pidx < base + Pl)
        li = jnp.clip(pidx - base, 0, Pl - 1)
        slot = pos % page_size

        def wr(p, i, s, v, w):
            return p.at[i, s].set(jnp.where(w, v.astype(p.dtype), p[i, s]))

        kp = jax.vmap(wr)(kp, li, slot, kn, mine)
        vp = jax.vmap(wr)(vp, li, slot, vn, mine)
        # ---- logical positions of local physical pages -------------------
        inv = jax.vmap(lambda row: jnp.full((Pn,), -1, jnp.int32).at[
            jnp.clip(row, 0, Pn - 1)].set(
                jnp.arange(Pn, dtype=jnp.int32)))(pt)
        inv_local = lax.dynamic_slice(inv, (jnp.int32(0), base), (Bl, Pl))
        grid = (inv_local[:, :, None] * page_size
                + jnp.arange(page_size)[None, None, :])
        valid = (inv_local[:, :, None] >= 0) & (grid <= pos[:, None, None])
        valid = valid.reshape(Bl, 1, 1, Pl * page_size)
        # ---- local flash partial ------------------------------------------
        k2 = kp.reshape(Bl, Pl * page_size, KVH, hd)
        v2 = vp.reshape(Bl, Pl * page_size, KVH, hd)
        qh = q.reshape(Bl, KVH, G, hd)
        s = jnp.einsum("bkgd,btkd->bkgt", qh.astype(k2.dtype), k2,
                       preferred_element_type=jnp.float32) / (hd ** 0.5)
        s = jnp.where(valid, s, -1e30)
        m = jnp.max(s, axis=-1)
        m_g = lax.pmax(m, "model")
        p = jnp.exp(s - m_g[..., None])
        p = jnp.where(valid, p, 0.0)
        l = lax.psum(jnp.sum(p, axis=-1), "model")
        o_p = jnp.einsum("bkgt,btkd->bkgd", p.astype(k2.dtype), v2,
                         preferred_element_type=jnp.float32)
        o = lax.psum(o_p, "model") / jnp.maximum(l, 1e-30)[..., None]
        return o.reshape(Bl, H, hd).astype(q.dtype), kp, vp

    pool_spec = P(dpb, "model", None, None, None)
    o, kp, vp = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dpb, None, None), P(dpb, None, None), P(dpb, None, None),
                  pool_spec, pool_spec, P(dpb, None), P(dpb,)),
        out_specs=(P(dpb, None, None), pool_spec, pool_spec),
        check_rep=False,
    )(q, k_new, v_new, k_pages, v_pages, page_table, pos)
    return o, kp, vp


class PagePool:
    """Host-side page allocator for serving: PIM-malloc manages page ids.

    Pages are allocator 'bytes' at PAGE_UNIT per page; ptr -> page_id =
    ptr // PAGE_UNIT. Built on a `repro.core.api.HeapClient`, so serving
    shares one allocator surface (and one jitted step) with the simulators
    and the serving engines, and every call also yields the DPU cost
    model's per-thread latencies (`pool.client.last_info`). One pool per
    device shard — a multi-device pool is `heap.MultiCoreHeap` / shard_map
    over the data axis (see examples/serve_paged.py).

    Every page free routes through the protocol's free path — a stale or
    repeated page id reaches the backend and shows up in
    `Stats.dropped_frees` (and as a deterministic ``double_free`` /
    ``use_after_free`` tag on the ``sanitizer`` kind) instead of being
    silently absorbed host-side (pinned in tests/test_serve_decode.py).
    """

    def __init__(self, n_pages: int, num_threads: int = 16, kind: str = "sw",
                 client: api.HeapClient = None, alloc=None):
        """``client`` injects a `HeapClient` whose heap spans
        n_pages * PAGE_UNIT bytes — e.g. a
        `repro.workloads.trace.RecordingAllocator`, so serving churn can be
        captured as an AllocRequest tape and replayed on every backend.

        ``alloc`` is the deprecated PR-4 injection hook: an
        Allocator-compatible handle (or zero-arg factory returning one).
        Still accepted, but warns and is adapted via `HeapClient.wrap`.
        """
        assert n_pages & (n_pages - 1) == 0, "n_pages must be pow2"
        self.n_pages = n_pages
        if alloc is not None:
            import warnings
            warnings.warn(
                "PagePool(alloc=...) is deprecated: pass client=HeapClient "
                "(or any HeapClient subclass); bare handles/factories are "
                "adapted via HeapClient.wrap for now",
                DeprecationWarning, stacklevel=2)
            if client is not None:
                raise TypeError("pass either client= or (deprecated) alloc=")
            client = api.HeapClient.wrap(alloc)
        if client is None:
            client = api.HeapClient(
                heap_bytes=n_pages * PAGE_UNIT, num_threads=num_threads,
                kind=kind,
            )
        elif not isinstance(client, api.HeapClient):
            raise TypeError(
                f"client must be a HeapClient, got {type(client).__name__!r}"
                " (legacy handles go through the deprecated alloc= hook)")
        assert client.cfg.heap_bytes == n_pages * PAGE_UNIT, \
            (client.cfg.heap_bytes, n_pages * PAGE_UNIT)
        self.client = client
        # back-compat alias: pre-PR-8 callers read `pool.alloc.last_info`
        self.alloc = client
        self.cfg = self.client.cfg.pm  # block_bytes=4096: 256-page refills

    def alloc_pages(self, n: int, thread: int = 0) -> jnp.ndarray:
        """Contiguous extent of `n` pages; returns page ids [n] (empty on OOM)."""
        ptr = self.client.malloc(n * PAGE_UNIT, thread=thread)
        if ptr < 0:
            return jnp.zeros((0,), jnp.int32)
        return ptr // PAGE_UNIT + jnp.arange(n, dtype=jnp.int32)

    def alloc_page_batch(self, threads) -> tuple[jnp.ndarray, AllocResponse]:
        """One single-page allocation per requesting thread (decode growth).
        threads: bool[T] mask. Returns (int32[T] page ids (-1 = none), resp)."""
        threads = jnp.asarray(threads)
        sizes = jnp.where(threads, PAGE_UNIT, 0).astype(jnp.int32)
        resp = self.client.malloc_batch(sizes, threads)
        return jnp.where(resp.ptr >= 0, resp.ptr // PAGE_UNIT, -1), resp

    def grow_extent(self, first_page: int, n_pages: int,
                    thread: int = 0) -> tuple[jnp.ndarray, bool]:
        """realloc an extent to `n_pages` pages.

        Returns (page ids [n], moved). ids is empty on OOM (the old extent
        then remains live). When `moved` is True the allocator relocated the
        extent and freed the old pages: the caller MUST copy the old pages'
        KV contents into the returned ids before its next allocation, or the
        old pages may be handed to another sequence.
        """
        new_ptr = self.client.realloc(int(first_page) * PAGE_UNIT,
                                      n_pages * PAGE_UNIT, thread=thread)
        if new_ptr < 0:
            return jnp.zeros((0,), jnp.int32), False
        moved = bool(self.client.last_info.moved[thread])
        return new_ptr // PAGE_UNIT + jnp.arange(n_pages, dtype=jnp.int32), moved

    def free_page_batch(self, pages) -> AllocResponse:
        """Free one page per thread slot (decode-page reclaim): pages
        int32[T] page ids, -1 = nothing to free on that slot."""
        pages = jnp.asarray(pages, jnp.int32)
        ptrs = jnp.where(pages >= 0, pages * PAGE_UNIT, -1)
        return self.client.free_batch(ptrs)

    def free_extent(self, first_page: int, thread: int = 0) -> None:
        self.client.free(int(first_page) * PAGE_UNIT, thread=thread)

    def evict(self, first_page: int, decode_pages, thread: int = 0) -> dict:
        """Session-end eviction: free ALL decode pages, then the extent,
        every free through the protocol.

        ``decode_pages`` (any length — chunked into T-wide free rounds; the
        pre-PR-8 recorder truncated at T and silently leaked the tail) and
        the extent at ``first_page`` (skipped when < 0, e.g. a session that
        died before its prefill extent was allocated). Returns
        ``{"freed_pages", "dropped_frees"}`` — a nonzero ``dropped_frees``
        means a stale/double page id reached the backend's dropped-free
        path (deterministically tagged on the ``sanitizer`` kind).
        """
        T = self.client.cfg.num_threads
        ids = [int(p) for p in np.asarray(decode_pages, np.int64).reshape(-1)
               if int(p) >= 0]
        freed = dropped = 0
        for i in range(0, len(ids), T):
            chunk = np.full((T,), -1, np.int32)
            chunk[:len(ids[i:i + T])] = ids[i:i + T]
            resp = self.free_page_batch(chunk)
            freed += len(ids[i:i + T])
            dropped += int(np.asarray((resp.path == 2)
                                      & (chunk >= 0)).sum())
        if int(first_page) >= 0:
            self.free_extent(first_page, thread=thread)
            info = self.client.last_info
            dropped += int(np.asarray(info.path[thread] == 2))
        return {"freed_pages": freed, "dropped_frees": dropped}

    def gc(self) -> None:
        self.client.gc()

    @property
    def stats(self) -> dict:
        return self.client.stats
