"""Elastic FleetServe: pressure-driven migration, fault injection,
snapshot/restore.

Million-user traffic is not stationary and hardware is not immortal; this
module wraps :class:`repro.launch.serve_fleet.FleetServe` into the serving
tier that survives both, without giving up the repo's core currency —
bitwise determinism:

  * **Live tenant migration.** At drain points (epoch boundaries by
    default — Temp blocks die at the reset for free, so a moving tenant
    drags no epoch state along) the engine reads the fleet's
    `HeapTelemetry` high-water marks (`telemetry.fleet_pressure`). When
    per-rank HWMs diverge past `MigrationConfig.ratio`
    (`telemetry.hwm_divergence`), a migration policy
    (`fleet.MIGRATIONS`) picks tenants and destinations, and the planner
    drains each block with a FREE on its source core and replays a MALLOC
    of it on the destination — re-binding the block's producing slot so
    every later op follows it. Each core's session slice stays a closed
    tape: the migrated tenant's destination slice replays bitwise through
    `repro.workloads.replay`.

  * **Fault injection.** A :class:`FaultPlan` is a deterministic,
    seed-generated schedule of core kills (the heap state slice is
    re-initialized mid-session and every block that lived there is
    re-placed through the migration path), transient stalls (a core
    accepts no dispatch for one round; its queued work waits a barrier)
    and dropped rounds (nothing dispatches fleet-wide). The expiry-free
    lane is never droppable: frees whose block died with a core wait for
    the replay MALLOC to re-bind the slot, then dispatch — the chaos
    harness pins `dropped_frees == 0` under every schedule.

  * **Snapshot / restore.** `snapshot()` checkpoints a mid-session engine
    through `repro.checkpoint.ckpt` — heap state, slot file, planned
    grids and responses-so-far in the npz/manifest format, the host-side
    planner (rng mid-stream state, queues, ledgers) in a JSON sidecar.
    `ElasticFleetServe.restore` rebuilds an engine that finishes the
    session **bitwise-identically** to the uninterrupted run — including
    restoring onto a different mesh wiring (vmap ⇄ shard_map: the
    restore path re-places every leaf under the target sharding,
    exercising `ckpt.restore(shardings=)`).

Execution model: the session's single `lax.scan` becomes a handful of
`ScanEngine.run_segment` scans split exactly at decision rounds (kills +
drain points). The round body is shared with the one-shot scan, and the
slot file + round offset are carried across segments, so with no faults
and no migrations the segmented session is bitwise-identical to
`FleetServe.serve()` — pinned in tests/test_elastic_fleet.py.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np
import jax

from repro.core import heap as heap_api
from repro.core import telemetry
from repro.checkpoint import ckpt
from repro.launch import fleet
from repro.launch.serve_fleet import (FleetServe, SessionPlanner,
                                      TrafficConfig)
from repro.launch.serving import AllocResponse, SessionPlan

KILL, STALL, DROP = "kill", "stall", "drop"


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: kill/stall a (rank, core) or drop a round."""

    round: int
    kind: str                      # "kill" | "stall" | "drop"
    rank: int = -1                 # unused for "drop"
    core: int = -1

    def __post_init__(self):
        assert self.kind in (KILL, STALL, DROP), self.kind
        assert self.round >= 0


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic fault schedule (a tuple of :class:`FaultEvent`).

    Schedules are data: `generate` derives one from a seed, `to_json` /
    `from_json` round-trip it exactly, and the same plan + the same
    traffic seed always produces the same report and tapes (pinned in
    tests/test_elastic_fleet.py).
    """

    events: tuple = ()

    def validate(self, shape: tuple, rounds: int):
        R, C, _ = shape
        for ev in self.events:
            if ev.round >= rounds:
                raise ValueError(f"fault at round {ev.round} >= {rounds}")
            if ev.kind != DROP and not (0 <= ev.rank < R
                                        and 0 <= ev.core < C):
                raise ValueError(f"fault core {(ev.rank, ev.core)} outside "
                                 f"[{R}, {C}]")
        kills = [(ev.rank, ev.core) for ev in self.events if ev.kind == KILL]
        if len(set(kills)) != len(kills):
            raise ValueError("a core can only be killed once")
        return self

    def at(self, r: int, kind: str):
        return [ev for ev in self.events
                if ev.round == r and ev.kind == kind]

    def stalled_at(self, r: int):
        return [(ev.rank, ev.core) for ev in self.at(r, STALL)]

    def is_dropped(self, r: int) -> bool:
        return bool(self.at(r, DROP))

    def kill_rounds(self):
        return sorted({ev.round for ev in self.events if ev.kind == KILL})

    def to_json(self) -> str:
        return json.dumps([dataclasses.asdict(ev) for ev in self.events])

    @classmethod
    def from_json(cls, s: str) -> "FaultPlan":
        return cls(tuple(FaultEvent(**d) for d in json.loads(s)))

    @classmethod
    def generate(cls, seed: int, rounds: int, shape: tuple, kills: int = 1,
                 stalls: int = 1, drops: int = 1,
                 min_round: int = 2) -> "FaultPlan":
        """Seed-derived schedule: distinct fault rounds in
        [min_round, rounds), kill cores drawn without replacement."""
        R, C, _ = shape
        n = kills + stalls + drops
        if n == 0:
            return cls()
        rng = np.random.default_rng(seed)
        span = rounds - min_round
        if span < n:
            raise ValueError(f"not enough rounds for {n} faults")
        rnds = min_round + rng.choice(span, size=n, replace=False)
        cores = rng.choice(R * C, size=max(kills, 1), replace=False)
        events = []
        for i in range(kills):
            events.append(FaultEvent(int(rnds[i]), KILL,
                                     int(cores[i]) // C, int(cores[i]) % C))
        for i in range(stalls):
            rc = int(rng.integers(R * C))
            events.append(FaultEvent(int(rnds[kills + i]), STALL,
                                     rc // C, rc % C))
        for i in range(drops):
            events.append(FaultEvent(int(rnds[kills + stalls + i]), DROP))
        return cls(tuple(sorted(events, key=lambda e: (e.round, e.kind))))


@dataclasses.dataclass(frozen=True)
class MigrationConfig:
    """When and how the elastic tier moves tenants.

    ``ratio``/``min_bytes`` feed `telemetry.hwm_divergence`; ``policy`` /
    ``drain`` name entries in `fleet.MIGRATIONS` / `fleet.DRAINS`
    (registering a new policy there is the whole integration);
    ``check_rounds`` paces the ``interval`` drain policy; ``max_moves``
    bounds tenants moved per decision.
    """

    ratio: float = 2.0
    min_bytes: int = 4096
    policy: str = "hottest_tenant"
    drain: str = "epoch"
    check_rounds: int = 8
    max_moves: int = 1

    def __post_init__(self):
        if self.policy not in fleet.MIGRATIONS:
            raise ValueError(f"unknown migration policy {self.policy!r} "
                             f"(have {tuple(fleet.MIGRATIONS)})")
        if self.drain not in fleet.DRAINS:
            raise ValueError(f"unknown drain policy {self.drain!r} "
                             f"(have {tuple(fleet.DRAINS)})")


class ElasticFleetServe(FleetServe):
    """FleetServe that migrates under pressure, survives injected faults,
    and checkpoints/resumes mid-session (see module docstring).

    Incremental API (``serve()`` wraps it for one-shot use)::

        eng = ElasticFleetServe(cfg, 2, 2, traffic=tc, faults=fp,
                                migration=MigrationConfig())
        eng.start()
        eng.run_until(32)                  # rounds [0, 32)
        path = eng.snapshot(ckpt_dir)      # mid-session checkpoint
        eng.run_until(tc.rounds)
        plan, report = eng.finish()

        eng2 = ElasticFleetServe(...same identity...)
        eng2.restore(ckpt_dir)             # back at round 32
        eng2.run_until(tc.rounds)          # finishes bitwise-identically
    """

    def __init__(self, cfg, num_ranks: int, num_cores: int,
                 traffic: TrafficConfig = None,
                 placement: str = "round_robin", mesh=False,
                 faults: FaultPlan = None,
                 migration: MigrationConfig = None):
        super().__init__(cfg, num_ranks, num_cores, traffic=traffic,
                         placement=placement, mesh=mesh)
        self.faults = (faults or FaultPlan()).validate(self.shape,
                                                       self.traffic.rounds)
        self.migration = migration
        self._planner = None

    # ------------------------------------------------------------------
    # incremental session driver
    # ------------------------------------------------------------------
    def start(self):
        """Begin a session at round 0 with a fresh fleet."""
        self._planner = self.planner()
        self.state = heap_api.sharded_init(self.cfg, self.num_ranks,
                                           self.num_cores)
        self.slots = np.full((self.traffic.rounds * self.capacity,), -1,
                             np.int32)
        self.r = 0
        self._resps = []
        self.pressure_log = []
        return self

    def _decision_rounds(self):
        """Rounds where the fleet pauses between segments: every kill plus
        every drain point of the configured drain policy."""
        decide = set(self.faults.kill_rounds())
        if self.migration is not None:
            decide.update(fleet.DRAINS[self.migration.drain](
                self.traffic, self.migration.check_rounds))
        return decide

    def _kill(self, rk: int, ck: int, r: int):
        """Core (rk, ck) dies at round r: its heap state slice is
        re-initialized (the fleet keeps its grid shape — a dead core just
        never gets work again) and the planner re-places its blocks."""
        fresh = jax.tree.map(lambda x: x[0, 0],
                             heap_api.sharded_init(self.cfg, 1, 1))
        self.state = jax.tree.map(
            lambda full, f: full.at[rk, ck].set(f), self.state, fresh)
        self._planner.kill_core(rk, ck, r)

    def _check_migration(self, r: int):
        pres = telemetry.fleet_pressure(self.state)
        div = telemetry.hwm_divergence(pres["rank_hwm"],
                                       ratio=self.migration.ratio,
                                       min_bytes=self.migration.min_bytes)
        self.pressure_log.append({"round": int(r), **div})
        if not div["trigger"]:
            return
        moves = fleet.MIGRATIONS[self.migration.policy](
            div, self._planner.homes, self._planner.tenant_bytes(),
            self._planner.loads, self.shape, dead=self._planner.dead,
            max_moves=self.migration.max_moves)
        for k, dst in moves:
            self._planner.migrate(k, dst, r)

    def run_until(self, stop: int):
        """Plan + execute rounds [current, stop) in decision-bounded
        segments."""
        if self._planner is None:
            self.start()
        stop = min(int(stop), self.traffic.rounds)
        decide = self._decision_rounds()
        drains = (set(fleet.DRAINS[self.migration.drain](
            self.traffic, self.migration.check_rounds))
            if self.migration is not None else set())
        p = self._planner
        while self.r < stop:
            for rk, ck in ((ev.rank, ev.core)
                           for ev in self.faults.at(self.r, KILL)):
                self._kill(rk, ck, self.r)
            if self.r in drains:
                self._check_migration(self.r)
            nxt = min([stop] + [d for d in decide if self.r < d < stop])
            for r in range(self.r, nxt):
                p.plan_round(r, stalled=self.faults.stalled_at(r),
                             drop_round=self.faults.is_dropped(r))
            sl = slice(self.r, nxt)
            self.state, self.slots, resps = self.run_segment(
                self.state, self.slots, self.r,
                (p.op[sl], p.size[sl], p.ref[sl], p.raw[sl]))
            self._resps.append(jax.tree.map(np.asarray, resps))
            self.r = nxt
        return self

    def finish(self):
        """Complete the session; returns (plan, report) like ``serve``."""
        self.run_until(self.traffic.rounds)
        plan = self._planner.finish()
        resps = AllocResponse(*[
            np.concatenate([np.asarray(getattr(seg, f))
                            for seg in self._resps], axis=0)
            for f in AllocResponse._fields])
        report = self.report(plan, resps, self.state)
        report.update(self._elastic_extras())
        return plan, report

    def _elastic_extras(self) -> dict:
        p = self._planner
        return {
            "migrations": [ev for ev in p.migration_log
                           if ev["kind"] == "migrate"],
            "kills": [ev for ev in p.migration_log if ev["kind"] == "kill"],
            "migration_ops_dispatched": p.mig_dispatched,
            "killed_cores": sorted([list(d) for d in p.dead]),
            "faults": json.loads(self.faults.to_json()),
            "pressure": self.pressure_log,
        }

    def serve(self, plan: SessionPlan = None):
        """One-shot elastic session (plan= is meaningless here: planning is
        interleaved with execution)."""
        if plan is not None:
            raise ValueError("ElasticFleetServe plans its own session; "
                             "use FleetServe for pre-planned tapes")
        self.start()
        return self.finish()

    # ------------------------------------------------------------------
    # snapshot / restore
    # ------------------------------------------------------------------
    def _identity(self) -> dict:
        # normalized through a JSON round-trip so tuples (size_choices)
        # compare equal against a loaded sidecar
        return json.loads(json.dumps({
            "kind": self.cfg.kind,
            "shape": list(self.shape),
            "placement": self.placement,
            "traffic": dataclasses.asdict(self.traffic),
        }))

    def snapshot(self, ckpt_dir: str, step: int = None) -> str:
        """Checkpoint the mid-session engine; returns the checkpoint path.

        Device half (heap state, slot file, planned grids, responses so
        far) goes through `repro.checkpoint.ckpt.save`; host half (the
        planner) into a ``host.json`` sidecar inside the step directory.
        """
        step = self.r if step is None else step
        p = self._planner
        tree = {
            "heap": self.state,
            "slots": np.asarray(self.slots),
            "plan": {"op": p.op, "size": p.size, "ref": p.ref, "raw": p.raw},
            "resps": {
                f: (np.concatenate(
                    [np.asarray(getattr(seg, f)) for seg in self._resps],
                    axis=0) if self._resps
                    else np.zeros((0,) + self.shape, np.int32))
                for f in AllocResponse._fields},
        }
        path = ckpt.save(tree, step, ckpt_dir)
        host = {
            "format": "pim-malloc-elastic-ckpt/v1",
            "identity": self._identity(),
            "round": int(self.r),
            "faults": self.faults.to_json(),
            "migration": (dataclasses.asdict(self.migration)
                          if self.migration else None),
            "planner": p.pack_host(),
            "pressure_log": self.pressure_log,
        }
        with open(os.path.join(path, "host.json"), "w") as f:
            json.dump(host, f)
        return path

    def restore(self, ckpt_dir: str, step: int = None):
        """Rebuild this engine's mid-session state from a snapshot.

        The engine must be constructed with the same identity (cfg kind,
        shape, placement, traffic); ``mesh`` may differ — when this engine
        is shard_mapped the heap leaves are re-placed under the rank
        sharding (the `ckpt.restore(shardings=)` elastic path), and the
        resumed session is bitwise-identical either way.
        """
        if step is None:
            step = ckpt.latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no committed checkpoint under "
                                        f"{ckpt_dir}")
        path = os.path.join(ckpt_dir, f"step_{step:08d}")
        with open(os.path.join(path, "host.json")) as f:
            host = json.load(f)
        if host["identity"] != self._identity():
            raise ValueError(f"checkpoint identity mismatch:\n"
                             f"  saved   {host['identity']}\n"
                             f"  engine  {self._identity()}")
        tc = self.traffic
        rounds, (R, C, T) = tc.rounds, self.shape
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)["leaves"]

        def resp_like(field):
            m = manifest[f"resps/{field}"]
            return np.zeros(m["shape"], m["dtype"])

        grid = np.zeros((rounds, R, C, T), np.int32)
        tree_like = {
            "heap": heap_api.sharded_init(self.cfg, R, C),
            "slots": np.zeros((rounds * self.capacity,), np.int32),
            "plan": {k: grid for k in ("op", "size", "ref", "raw")},
            "resps": {f: resp_like(f) for f in AllocResponse._fields},
        }
        shardings = None
        if self.mesh is not None:
            # elastic re-placement: heap leaves shard over the rank axis,
            # everything else is replicated
            from jax.sharding import NamedSharding, PartitionSpec
            ranked = NamedSharding(self.mesh,
                                   PartitionSpec(self.mesh.axis_names[0]))
            repl = NamedSharding(self.mesh, PartitionSpec())
            shardings = jax.tree.map(lambda _: repl, tree_like)
            shardings["heap"] = jax.tree.map(lambda _: ranked,
                                             tree_like["heap"])
        tree = ckpt.restore(tree_like, step, ckpt_dir, shardings=shardings)

        self.r = int(host["round"])
        self.state = tree["heap"]
        self.slots = tree["slots"]
        self._resps = ([AllocResponse(**{
            f: np.asarray(tree["resps"][f])
            for f in AllocResponse._fields})] if self.r else [])
        self.faults = FaultPlan.from_json(host["faults"]).validate(
            self.shape, rounds)
        if host["migration"] is not None:
            self.migration = MigrationConfig(**host["migration"])
        self._planner = SessionPlanner.unpack(
            tc, self.shape, self.placement, host["planner"],
            (np.asarray(tree["plan"][k]) for k in ("op", "size", "ref",
                                                   "raw")))
        self.pressure_log = list(host["pressure_log"])
        return self


def serve_elastic(cfg, num_ranks: int, num_cores: int,
                  traffic: TrafficConfig = None,
                  placement: str = "round_robin", mesh=False,
                  faults: FaultPlan = None,
                  migration: MigrationConfig = None) -> dict:
    """One-call convenience mirroring `serve_fleet.serve_session`."""
    eng = ElasticFleetServe(cfg, num_ranks, num_cores, traffic=traffic,
                            placement=placement, mesh=mesh, faults=faults,
                            migration=migration)
    _, report = eng.serve()
    return report
