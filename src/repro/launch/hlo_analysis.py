"""Loop-aware HLO accounting for the roofline analysis.

XLA's `compiled.cost_analysis()` visits every while body ONCE (known
HloCostAnalysis behavior), so a scan-over-layers model under-reports FLOPs
by ~n_layers x n_microbatches. This module re-derives loop-scaled totals
from `compiled.as_text()`:

  1. parse computations + per-instruction result shapes;
  2. read `known_trip_count` from every while's backend_config (present in
     optimized HLO) and propagate multipliers through the call graph
     (while bodies x trip count; fusions/calls x 1);
  3. FLOPs: 2 * prod(result_shape) * prod(contracted lhs dims) per dot /
     convolution, scaled by the enclosing computation's multiplier;
  4. collective bytes: result-buffer sizes of all-gather / all-reduce /
     reduce-scatter / all-to-all / collective-permute / ragged-all-to-all
     (including async -start forms; -done skipped), scaled likewise;
  5. memory bytes: 2x the result-buffer bytes of every *producing* op
     (dot, fusion, copy, gather/scatter, dynamic slice/update, reduce,
     concatenate, custom-call, collectives) — each produced buffer is
     written once and read ~once by its consumer. broadcast/iota/transpose
     are EXCLUDED: they materialize on the CPU backend used for the
     dry-run but fuse into consumers on TPU.

Used by launch/dryrun.py; validated against cost_analysis() on unrolled
single-layer probes in tests/test_dryrun_small.py.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "s4": 1,
    "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
_MEM_OPS = ("dot", "fusion", "copy", "gather", "scatter", "dynamic-slice",
            "dynamic-update-slice", "reduce", "concatenate", "custom-call",
            "convolution", "reverse", "pad", "slice",
            "select-and-scatter") + _COLLECTIVES


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes in a (possibly tuple) shape string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return None
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


def _shape_elems(shape_str: str) -> int:
    dims = _shape_dims(shape_str)
    if not dims:
        return 1
    n = 1
    for d in dims:
        n *= d
    return n


def cost_analysis_dict(compiled) -> dict:
    """`compiled.cost_analysis()` as one flat dict across jax versions.

    jax <= 0.4.x returns a one-element list of per-program dicts; newer
    versions return the dict directly."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


class Instruction:
    __slots__ = ("name", "shape_str", "op", "line")

    def __init__(self, name, shape_str, op, line):
        self.name, self.shape_str, self.op, self.line = name, shape_str, op, line


def _parse_instr(line: str):
    """'%name = SHAPE op(...)' -> (name, shape_str, op) or None.

    Handles tuple shapes with nested parens and /*index=N*/ comments via
    bracket counting (regexes break on those)."""
    ls = line.strip()
    if ls.startswith("ROOT "):
        ls = ls[5:]
    if not ls.startswith("%"):
        return None
    eq = ls.find(" = ")
    if eq < 0:
        return None
    name = ls[:eq]
    rest = ls[eq + 3:]
    if rest.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        shape, rest2 = rest[:end + 1], rest[end + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape, rest2 = rest[:sp], rest[sp + 1:].lstrip()
    m = re.match(r"([\w\-]+)\(", rest2)
    if not m:
        return None
    return name, shape, m.group(1)


def parse_hlo(text: str):
    """-> {comp_name: [Instruction]}, {comp_name: trip_multiplier}."""
    comps = {}
    cur = None
    for line in text.splitlines():
        ls = line.strip()
        # computation headers: "[ENTRY] %name (args...) -> result {"
        if ls.endswith("{") and "->" in ls and not line.startswith("    "):
            tok = ls.split()[0]
            if tok == "ENTRY":
                tok = ls.split()[1]
            cur = tok.lstrip("%")
            comps[cur] = []
            continue
        if ls == "}":
            cur = None
            continue
        if cur is None:
            continue
        im = _parse_instr(line)
        if im:
            comps[cur].append(Instruction(im[0], im[1], im[2], line))

    # while call sites: body computation -> trip count
    calls = defaultdict(list)  # callee -> [(caller, factor)]
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.op == "while":
                body = re.search(r"body=(%?[\w.\-]+)", ins.line)
                trip = re.search(r'known_trip_count.{0,6}?"n":"(\d+)"', ins.line)
                n = int(trip.group(1)) if trip else 1
                if body:
                    calls[body.group(1).lstrip("%")].append((cname, n))
            else:
                for attr in ("calls", "to_apply", "condition",
                             "true_computation", "false_computation",
                             "branch_computations"):
                    for m in re.finditer(attr + r"=\{?(%?[\w.\-]+)", ins.line):
                        calls[m.group(1).lstrip("%")].append((cname, 1))

    # propagate multipliers (call graph is a DAG in HLO)
    mult = {}

    def resolve(comp):
        if comp in mult:
            return mult[comp]
        sites = calls.get(comp)
        if not sites:
            mult[comp] = 1  # entry or unreferenced
            return 1
        mult[comp] = 0  # cycle guard
        total = sum(resolve(caller) * n for caller, n in sites)
        mult[comp] = max(total, 1)
        return mult[comp]

    for comp in comps:
        resolve(comp)
    return comps, mult


def _dot_flops(ins: Instruction, symtab) -> float:
    out_dims = _shape_dims(ins.shape_str)
    if out_dims is None:
        return 0.0
    # lhs operand: either typed ('dot(f32[32,64]{1,0} %x, ...)' — read dims
    # straight off the annotation) or bare ('dot(%x, ...)' — symtab lookup).
    # Split at the op's own paren: the result layout may contain parens too
    # (TPU tiling, 'f32[64,128]{1,0:T(8,128)}').
    parts = ins.line.split(ins.op + "(", 1)
    if len(parts) < 2:
        return 0.0
    args = parts[1]
    m = re.match(r"\s*(?:(\w+\[[\d,]*\])\S*\s+)?(%[\w.\-]+)", args)
    lhs_dims = None
    if m:
        lhs_dims = (_shape_dims(m.group(1)) if m.group(1)
                    else symtab.get(m.group(2)))
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    contracted = 1
    if lhs_dims and cm and cm.group(1):
        for d in cm.group(1).split(","):
            di = int(d)
            if di < len(lhs_dims):
                contracted *= lhs_dims[di]
    out = 1
    for d in out_dims:
        out *= d
    return 2.0 * out * contracted


def analyze(text: str) -> dict:
    """Loop-scaled totals from optimized HLO text."""
    comps, mult = parse_hlo(text)
    flops = 0.0
    coll_bytes = 0.0
    coll_ops = defaultdict(float)
    mem_bytes = 0.0
    for cname, instrs in comps.items():
        k = mult.get(cname, 1)
        symtab = {ins.name: _shape_dims(ins.shape_str) for ins in instrs}
        for ins in instrs:
            op = ins.op
            if op in ("dot", "convolution"):
                flops += k * _dot_flops(ins, symtab)
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES and not op.endswith("-done"):
                b = _shape_bytes(ins.shape_str)
                coll_bytes += k * b
                coll_ops[base] += k * b
            if base in _MEM_OPS and not op.endswith("-done"):
                # produced buffer: one write + ~one consumer read.
                # In-place patterns are aliased by XLA, not re-materialized:
                #  * dynamic-update-slice: traffic = the UPDATE slice, not the
                #    full result (scan stacking / grad accumulation);
                #  * large fusions whose result dims equal an operand's dims
                #    (whole-carry converts/copies) alias on TPU -> skip.
                b = _shape_bytes(ins.shape_str)
                operands = re.findall(r"(%[\w.\-]+)",
                                      ins.line.split("(", 1)[1])
                if base == "dynamic-update-slice" and len(operands) >= 2:
                    upd = symtab.get(operands[1])
                    if upd is not None:
                        ub = 1
                        for d in upd:
                            ub *= d
                        width = max(_shape_bytes(ins.shape_str)
                                    // max(_shape_elems(ins.shape_str), 1), 1)
                        b = ub * width  # traffic = the update slice only
                elif (base == "fusion" and b > 1e8
                      and not ins.shape_str.startswith("(")):
                    rdims = _shape_dims(ins.shape_str)
                    if rdims is not None and any(
                            symtab.get(o) == rdims for o in operands):
                        b = 0
                mem_bytes += 2 * k * b
    return {
        "flops_scaled": flops,
        "collective_bytes_scaled": coll_bytes,
        "collective_bytes_by_op": dict(coll_ops),
        "memory_bytes_scaled": mem_bytes,
        "n_computations": len(comps),
    }


def collective_schedule(text: str, limit: int = 40):
    """Human-readable (op, result shape, multiplier) list for EXPERIMENTS.md."""
    comps, mult = parse_hlo(text)
    out = []
    for cname, instrs in comps.items():
        for ins in instrs:
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base in _COLLECTIVES and not ins.op.endswith("-done"):
                out.append({
                    "op": base, "shape": ins.shape_str.strip(),
                    "times": mult.get(cname, 1),
                    "bytes": _shape_bytes(ins.shape_str),
                })
    out.sort(key=lambda d: -d["bytes"] * d["times"])
    return out[:limit]
