"""End-to-end fault-tolerant trainer.

    PYTHONPATH=src python -m repro.launch.train --arch granite_3_8b --reduced \
        --steps 50 --batch 8 --seq 128 [--ckpt-dir /tmp/ck] [--fail-at 20]

On this CPU container use --reduced (smoke-scale config); on a real pod the
full config + production mesh apply unchanged. Integrates: synthetic data
pipeline, AdamW + schedule, grad accumulation, async checkpointing,
watchdog, and checkpoint/restart recovery (optionally chaos-tested via
--fail-at).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.checkpoint import ckpt as ckpt_lib
from repro.data.pipeline import StreamConfig, TokenStream, shard_batch
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import registry
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.runtime import fault


def build(arch: str, reduced: bool, batch: int, seq: int, n_micro: int,
          total_steps: int):
    cfg = configs.get(arch)
    if reduced:
        cfg = cfg.reduced()
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=total_steps,
                          moment_dtype=cfg.opt_moment_dtype)
    key = jax.random.PRNGKey(0)
    params = registry.init(cfg, key)
    opt_state = adamw.init(opt_cfg, params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, n_micro=n_micro))
    stream = TokenStream(StreamConfig(
        vocab=cfg.vocab, seq_len=seq, global_batch=batch,
        d_model=cfg.d_model, enc_frames=cfg.enc_frames
        if cfg.family == "audio" else 0,
        n_patches=cfg.n_patches if cfg.family == "vlm" else 0))
    return cfg, params, opt_state, step_fn, stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (recovery drill)")
    args = ap.parse_args()

    mesh = make_host_mesh()
    cfg, params, opt_state, step_fn, stream = build(
        args.arch, args.reduced, args.batch, args.seq, args.n_micro,
        args.steps)
    print(f"arch={cfg.name} params="
          f"{sum(np.prod(p.shape) for p in jax.tree.leaves(params)):,}")

    def step(state, batch, step_idx):
        params, opt_state = state
        batch = shard_batch(mesh, batch)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step_idx % 5 == 0:
            print(f"step {step_idx}: loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e}", flush=True)
        return (params, opt_state), metrics

    injector = fault.FailureInjector([args.fail_at] if args.fail_at else [])
    watchdog = fault.StepWatchdog()
    loop_cfg = fault.TrainLoopConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir)
    state, history = fault.run_with_recovery(
        loop_cfg, init_state=(params, opt_state), step_fn=step,
        make_batch=stream.batch, injector=injector, watchdog=watchdog)
    print(f"done: {len(history['steps'])} steps, "
          f"{history['recoveries']} recoveries, "
          f"{history['stragglers']} straggler events")
    print(f"latest checkpoint: step {ckpt_lib.latest_step(args.ckpt_dir)}")


if __name__ == "__main__":
    main()
