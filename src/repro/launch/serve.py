"""Paged-KV serving driver: PIM-malloc page allocation + batched decode.

    PYTHONPATH=src python -m repro.launch.serve --arch granite_3_8b --reduced \
        --batch 4 --prompt-len 32 --decode-steps 48

Demonstrates the paper's technique as the serving substrate:
  * prefill allocates each request's page extent via the BUDDY BACKEND
    (bypass path — large contiguous allocation),
  * per-token page growth is served by the THREAD-CACHE FRONTEND (O(1)),
  * attention consumes the resulting page tables (Pallas kernel on the
    single-device path, GSPMD 'ref' path inside pjit),
  * with --fleet-ranks R, decode-time page growth routes through a
    ShardedHeap fleet (shard_map tier): sequence b lands on rank b % R,
    and the run reports the FleetRouter's per-rank cost accounting.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import heap as heap_api
from repro.core import system as sysm
from repro.kvcache import paged
from repro.launch.fleet import FleetRouter
from repro.models import registry


def make_fleet_pool(num_ranks: int, n_pages: int, num_threads: int = 16,
                    kind: str = "sw") -> FleetRouter:
    """A FleetRouter over R single-core page-heap ranks (serving fleet).

    Each rank owns an independent page heap of `n_pages`; page ids are
    rank-local, mirroring one PagePool per device shard.
    """
    cfg = sysm.SystemConfig(kind=kind, heap_bytes=n_pages * paged.PAGE_UNIT,
                            num_threads=num_threads)
    return FleetRouter(heap_api.ShardedHeap(cfg, num_ranks=num_ranks,
                                            num_cores=1))


def fleet_page_request(router: FleetRouter, need) -> heap_api.AllocRequest:
    """One fleet round allocating a page for every sequence with need[b]."""
    R, C, T = router.shape
    size = np.zeros((R, C, T), np.int32)
    for b in np.nonzero(np.asarray(need))[0]:
        rank, slot = int(b) % R, int(b) // R
        if slot >= C * T:
            raise ValueError(f"sequence {b} exceeds fleet thread capacity "
                             f"{router.capacity} ({R}x{C}x{T})")
        size[rank, slot // T, slot % T] = paged.PAGE_UNIT
    return heap_api.malloc_request(jnp.asarray(size))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=48)
    ap.add_argument("--impl", default="kernel", choices=["kernel", "ref"])
    ap.add_argument("--fleet-ranks", type=int, default=0,
                    help="route decode page growth through a ShardedHeap "
                         "fleet of this many ranks (0 = single PagePool)")
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family in ("ssm",):
        raise SystemExit("ssm decode has no paged KV; use examples/quickstart")
    # the attention impl rides the (frozen) arch config into every
    # paged.attend call site — no module-global mutation
    cfg = dataclasses.replace(cfg, attend_impl=args.impl)
    mod = registry.get_module(cfg)

    B, S = args.batch, args.prompt_len
    max_seq = S + args.decode_steps + cfg.page_size
    P = paged.pages_per_seq(max_seq, cfg.page_size)

    # ---- PIM-malloc page pool: one extent per request (buddy/bypass path) --
    # floor: the hierarchy needs headroom beyond thread-cache prepopulation
    n_pages = max(1 << (B * P - 1).bit_length(), 1 << 16)
    pool = paged.PagePool(n_pages=n_pages)
    router = (make_fleet_pool(args.fleet_ranks, n_pages,
                              num_threads=pool.cfg.num_threads)
              if args.fleet_ranks else None)
    if router is None and B > pool.cfg.num_threads:
        raise SystemExit(
            f"--batch {B} exceeds the single pool's {pool.cfg.num_threads} "
            "hardware threads; use --fleet-ranks to scale page allocation")
    if router is not None and B > router.capacity:
        raise SystemExit(
            f"--batch {B} exceeds the fleet's {router.capacity} hardware "
            "threads; raise --fleet-ranks")
    page_rows = []
    for b in range(B):
        pages = pool.alloc_pages(P, thread=b % pool.cfg.num_threads)
        assert pages.shape[0] == P, "pool exhausted"
        page_rows.append(pages)
    print("allocator stats after prefill extents:", pool.stats)

    key = jax.random.PRNGKey(0)
    params = registry.init(cfg, key)
    spec = mod.cache_spec(cfg, B, max_seq)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
    if "page_table" in cache:
        # local (per-seq-pool) page tables are slot indices; the shared-pool
        # ids from PIM-malloc map through modulo the per-seq extent
        cache["page_table"] = jnp.stack(page_rows) % P

    batch = registry.make_train_batch(
        cfg, type("S", (), {"seq_len": S + (cfg.n_patches if cfg.family ==
                                            "vlm" else 0),
                            "global_batch": B})(), key, global_batch=B)
    batch.pop("labels", None)
    # page-align prompt for prefill
    pad = (-(S + (cfg.n_patches if cfg.family == "vlm" else 0))) % cfg.page_size
    if pad:
        batch["tokens"] = jnp.pad(batch["tokens"], ((0, 0), (0, pad)))
        S += pad

    prefill = jax.jit(lambda p, b, c: mod.prefill(cfg, p, b, c))
    decode = jax.jit(lambda p, c, b: mod.decode(cfg, p, c, b))

    t0 = time.time()
    cache, logits = prefill(params, batch, cache)
    print(f"prefill {B}x{S}: {time.time()-t0:.2f}s")

    toks = jnp.argmax(logits, axis=-1)[:, None]
    t0 = time.time()
    n_page_allocs = 0
    alloc_cyc = 0.0
    for i in range(args.decode_steps):
        # allocate a fresh page via the frontend when any sequence crosses
        # a page boundary (the paper's fast path, Fig 9 case 1)
        pos = np.asarray(cache["seq_lens"])
        need = (pos % cfg.page_size) == 0
        if need.any():
            if router is not None:
                resp = router.route(fleet_page_request(router, need))
            else:
                ids, resp = pool.alloc_page_batch(
                    np.pad(need, (0, pool.cfg.num_threads - B)))
            n_page_allocs += int(need.sum())
            alloc_cyc += float(np.asarray(resp.latency_cyc).max())
        cache, logits = decode(params, cache, {"tokens": toks})
        toks = jnp.argmax(logits, axis=-1)[:, None]
    dt = time.time() - t0
    total = args.decode_steps * B
    print(f"decode: {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s CPU-{args.impl})")
    alloc_us = alloc_cyc / pool.client.cfg.dpu.freq_hz * 1e6
    print(f"frontend page allocations during decode: {n_page_allocs} "
          f"({alloc_us:.2f} us modeled DPU time)")
    print("final allocator stats:", pool.stats)
    if router is not None:
        st = router.stats
        print(f"fleet ({args.fleet_ranks} ranks): {st['rounds']} rounds, "
              f"{st['ops']} page allocs, {st['us_per_op']:.3f} us/op, "
              f"per-rank ops={st['per_rank']['ops']}")


if __name__ == "__main__":
    main()
