"""Paged-KV serving driver: PIM-malloc page allocation + batched decode.

    PYTHONPATH=src python -m repro.launch.serve --arch granite_3_8b --reduced \
        --batch 4 --prompt-len 32 --decode-steps 48

Demonstrates the paper's technique as the serving substrate:
  * prefill allocates each request's page extent via the BUDDY BACKEND
    (bypass path — large contiguous allocation),
  * per-token page growth is served by the THREAD-CACHE FRONTEND (O(1)),
  * attention consumes the resulting page tables (Pallas kernel on the
    single-device path, GSPMD 'ref' path inside pjit).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.kvcache import paged
from repro.models import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=48)
    ap.add_argument("--impl", default="kernel", choices=["kernel", "ref"])
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family in ("ssm",):
        raise SystemExit("ssm decode has no paged KV; use examples/quickstart")
    # the attention impl rides the (frozen) arch config into every
    # paged.attend call site — no module-global mutation
    cfg = dataclasses.replace(cfg, attend_impl=args.impl)
    mod = registry.get_module(cfg)

    B, S = args.batch, args.prompt_len
    max_seq = S + args.decode_steps + cfg.page_size
    P = paged.pages_per_seq(max_seq, cfg.page_size)

    # ---- PIM-malloc page pool: one extent per request (buddy/bypass path) --
    # floor: the hierarchy needs headroom beyond thread-cache prepopulation
    n_pages = max(1 << (B * P - 1).bit_length(), 1 << 16)
    pool = paged.PagePool(n_pages=n_pages)
    page_rows = []
    for b in range(B):
        pages = pool.alloc_pages(P, thread=b % pool.cfg.num_threads)
        assert pages.shape[0] == P, "pool exhausted"
        page_rows.append(pages)
    print("allocator stats after prefill extents:", pool.stats)

    key = jax.random.PRNGKey(0)
    params = registry.init(cfg, key)
    spec = mod.cache_spec(cfg, B, max_seq)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
    if "page_table" in cache:
        # local (per-seq-pool) page tables are slot indices; the shared-pool
        # ids from PIM-malloc map through modulo the per-seq extent
        cache["page_table"] = jnp.stack(page_rows) % P

    batch = registry.make_train_batch(
        cfg, type("S", (), {"seq_len": S + (cfg.n_patches if cfg.family ==
                                            "vlm" else 0),
                            "global_batch": B})(), key, global_batch=B)
    batch.pop("labels", None)
    # page-align prompt for prefill
    pad = (-(S + (cfg.n_patches if cfg.family == "vlm" else 0))) % cfg.page_size
    if pad:
        batch["tokens"] = jnp.pad(batch["tokens"], ((0, 0), (0, pad)))
        S += pad

    prefill = jax.jit(lambda p, b, c: mod.prefill(cfg, p, b, c))
    decode = jax.jit(lambda p, c, b: mod.decode(cfg, p, c, b))

    t0 = time.time()
    cache, logits = prefill(params, batch, cache)
    print(f"prefill {B}x{S}: {time.time()-t0:.2f}s")

    toks = jnp.argmax(logits, axis=-1)[:, None]
    t0 = time.time()
    n_page_allocs = 0
    alloc_cyc = 0.0
    for i in range(args.decode_steps):
        # allocate a fresh page via the frontend when any sequence crosses
        # a page boundary (the paper's fast path, Fig 9 case 1)
        pos = np.asarray(cache["seq_lens"])
        need = (pos % cfg.page_size) == 0
        if need.any():
            ids, resp = pool.alloc_page_batch(
                np.pad(need, (0, pool.cfg.num_threads - B)))
            n_page_allocs += int(need.sum())
            alloc_cyc += float(np.asarray(resp.latency_cyc).max())
        cache, logits = decode(params, cache, {"tokens": toks})
        toks = jnp.argmax(logits, axis=-1)[:, None]
    dt = time.time() - t0
    total = args.decode_steps * B
    print(f"decode: {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s CPU-{args.impl})")
    alloc_us = alloc_cyc / pool.alloc.cfg.dpu.freq_hz * 1e6
    print(f"frontend page allocations during decode: {n_page_allocs} "
          f"({alloc_us:.2f} us modeled DPU time)")
    print("final allocator stats:", pool.stats)


if __name__ == "__main__":
    main()
