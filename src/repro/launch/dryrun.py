import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, capture memory/cost analysis + the loop-scaled collective schedule.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite_3_8b \
        --shape train_4k [--multi-pod] [--out results/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all

The XLA_FLAGS line above MUST run before any other jax-importing module:
this container has one CPU device; the dry-run fakes 512 host devices so
`jax.make_mesh((2,16,16))` can build the production mesh. Smoke tests and
benchmarks do NOT import this module and keep seeing 1 device.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step, opt_state_sds)
from repro.models import registry
from repro.models.config import SHAPES
from repro.optim.adamw import AdamWConfig
from repro.parallel import sharding
from repro.parallel.meshctx import activate_mesh

RESULTS_DIR = "results/dryrun"

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return {"batch": registry.train_specs(cfg, shape)}
    if shape.kind == "prefill":
        batch, cache = registry.prefill_specs(cfg, shape)
        return {"batch": batch, "cache": cache}
    batch, cache = registry.decode_specs(cfg, shape)
    return {"batch": batch, "cache": cache}


def _sharded_bytes(sds_tree, spec_tree, mesh) -> int:
    """Per-device bytes of a sharded pytree (analytic)."""
    import numpy as np
    total = 0
    for s, p in zip(jax.tree.leaves(sds_tree),
                    jax.tree.leaves(spec_tree,
                                    is_leaf=lambda x: isinstance(
                                        x, jax.sharding.PartitionSpec))):
        n = int(np.prod(s.shape)) if s.shape else 1
        div = 1
        for axes in p:
            if axes is None:
                continue
            for a in (axes if isinstance(axes, tuple) else (axes,)):
                div *= mesh.shape[a]
        total += n * jnp.dtype(s.dtype).itemsize // max(div, 1)
    return total


def dryrun_cell(arch: str, shape_name: str, multi_pod: bool = False,
                n_micro: int | None = None, overrides: dict | None = None,
                verbose: bool = True) -> dict:
    cfg = configs.get(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "status": "ok",
    }

    if shape_name == "long_500k" and not cfg.sub_quadratic:
        result["status"] = "skipped"
        result["reason"] = ("pure full-attention arch: O(L^2) at 512K is out "
                            "of assigned scope (DESIGN.md)")
        return result

    p_sds = registry.param_sds(cfg)
    # serving (prefill/decode) has no optimizer state: params place TP-only
    # (replicated over data); FSDP gathers per step would be pure overhead
    fsdp = cfg.fsdp and shape.kind == "train"
    p_spec = sharding.param_specs(mesh, p_sds, fsdp=fsdp)
    dp = 1
    for a in sharding.dp_axes(mesh):
        dp *= mesh.shape[a]

    t0 = time.time()
    def nm_(spec):
        return sharding.named(mesh, spec)
    with activate_mesh(mesh):
        if shape.kind == "train":
            opt_cfg = AdamWConfig(moment_dtype=cfg.opt_moment_dtype)
            nm = (n_micro or cfg.train_microbatches
                  or max(1, min(8, shape.global_batch // dp)))
            step = make_train_step(cfg, opt_cfg, n_micro=nm,
                                    grad_pspec=p_spec)
            o_sds = opt_state_sds(cfg, opt_cfg)
            from repro.optim.adamw import AdamWState
            o_spec = AdamWState(count=jax.sharding.PartitionSpec(),
                                m=p_spec, v=p_spec)  # moments shard like params
            b_sds = input_specs(arch, shape_name)["batch"]
            b_spec = sharding.batch_specs(mesh, b_sds)
            result["n_micro"] = nm
            jitted = jax.jit(
                step, in_shardings=(nm_(p_spec), nm_(o_spec), nm_(b_spec)),
                out_shardings=(nm_(p_spec), nm_(o_spec), None))
            lowered = jitted.lower(p_sds, o_sds, b_sds)
            state_parts = {"params": (p_sds, p_spec), "opt_m": (o_sds.m, p_spec),
                           "opt_v": (o_sds.v, p_spec)}
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg)
            sp = input_specs(arch, shape_name)
            b_spec = sharding.batch_specs(mesh, sp["batch"])
            c_spec = sharding.cache_specs(mesh, sp["cache"])
            jitted = jax.jit(
                step, in_shardings=(nm_(p_spec), nm_(b_spec), nm_(c_spec)),
                out_shardings=(nm_(c_spec), None))
            lowered = jitted.lower(p_sds, sp["batch"], sp["cache"])
            state_parts = {"params": (p_sds, p_spec),
                           "cache": (sp["cache"], c_spec)}
        else:  # decode
            step = make_decode_step(cfg)
            sp = input_specs(arch, shape_name)
            b_spec = sharding.batch_specs(mesh, sp["batch"])
            c_spec = sharding.cache_specs(mesh, sp["cache"])
            jitted = jax.jit(
                step, in_shardings=(nm_(p_spec), nm_(c_spec), nm_(b_spec)),
                out_shardings=(nm_(c_spec), None))
            lowered = jitted.lower(p_sds, sp["cache"], sp["batch"])
            state_parts = {"params": (p_sds, p_spec),
                           "cache": (sp["cache"], c_spec)}

        result["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t1, 1)

    # ----- memory analysis --------------------------------------------------
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                mem[attr] = int(v)
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = str(e)
    result["memory_analysis"] = mem
    result["state_bytes_per_device"] = {
        k: _sharded_bytes(sds, spec, mesh) for k, (sds, spec) in
        state_parts.items()
    }

    # ----- cost analysis (raw; while bodies counted once) -------------------
    try:
        ca = hlo_analysis.cost_analysis_dict(compiled)
        result["cost_analysis_raw"] = {
            k: float(v) for k, v in ca.items()
            if k in ("flops", "bytes accessed", "transcendentals")
        }
    except Exception as e:
        result["cost_analysis_raw"] = {"error": str(e)}

    # ----- loop-scaled HLO accounting ---------------------------------------
    txt = compiled.as_text()
    result["hlo"] = hlo_analysis.analyze(txt)
    result["collective_schedule"] = hlo_analysis.collective_schedule(txt, 25)
    if os.environ.get("DRYRUN_SAVE_HLO"):
        os.makedirs(RESULTS_DIR, exist_ok=True)
        hp = os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__"
                          f"{result['mesh'].replace('x', '_')}.hlo.txt")
        with open(hp, "w") as f:
            f.write(txt)
        result["hlo_path"] = hp

    # ----- roofline terms (the SPMD HLO is already the per-device program) --
    n_dev = mesh.devices.size
    terms = {
        "compute_s": result["hlo"]["flops_scaled"] / PEAK_FLOPS,
        "memory_s": result["hlo"]["memory_bytes_scaled"] / HBM_BW,
        "collective_s": result["hlo"]["collective_bytes_scaled"] / ICI_BW,
    }
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]
                              if k.endswith("_s") else -1)
    result["roofline"] = terms
    result["devices"] = int(n_dev)

    if verbose:
        print(json.dumps({k: result[k] for k in
                          ("arch", "shape", "mesh", "status", "compile_s")},
                         indent=None))
    return result


def save_result(res: dict, out_dir: str = RESULTS_DIR):
    os.makedirs(out_dir, exist_ok=True)
    name = f"{res['arch']}__{res['shape']}__{res['mesh'].replace('x', '_')}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(res, f, indent=1)
    return os.path.join(out_dir, name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--overrides", default=None,
                    help="JSON dict of ArchConfig overrides (SSPerf iters)")
    args = ap.parse_args()
    overrides = json.loads(args.overrides) if args.overrides else None

    cells = []
    if args.all:
        for arch in configs.ARCHS:
            for shape in SHAPES:
                meshes = (False, True) if args.both_meshes else (args.multi_pod,)
                for mp in meshes:
                    cells.append((arch, shape, mp))
    else:
        meshes = (False, True) if args.both_meshes else (args.multi_pod,)
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    failures = 0
    for arch, shape, mp in cells:
        key = f"{arch}/{shape}/{'2x16x16' if mp else '16x16'}"
        try:
            res = dryrun_cell(arch, shape, multi_pod=mp, overrides=overrides)
        except Exception as e:
            failures += 1
            res = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "error": str(e)[-2000:],
                   "traceback": traceback.format_exc()[-4000:]}
            print(f"FAIL {key}: {e}")
        path = save_result(res, args.out)
        print(f"{key}: {res['status']} -> {path}", flush=True)
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
