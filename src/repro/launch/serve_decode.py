"""DecodeServe: closed-loop continuous-batching LLM decode on the fleet heap.

Where `repro.launch.serve_fleet.FleetServe` drives *raw* alloc traffic,
this engine drives the paper's flagship application shape: multi-tenant
LLM serving whose KV cache is paged through PIM-malloc. Every allocator op
in the session is a KV-page event of a real serving schedule:

  1. **Sessions (host side).** `DecodeTraffic` draws Poisson session
     arrivals; each session belongs to a tenant whose popularity is
     Zipf-distributed, carries a prompt length and a decode budget, and
     passes a bounded admission queue (arrivals beyond it are dropped and
     accounted). Placement is tenant-sticky via `fleet.tenant_core`, so a
     session's whole page chain lives on one (rank, core) heap.
  2. **Continuous batching (host side).** Each protocol round the
     scheduler dispatches, in priority order, into the home core's T
     thread slots: (a) eviction frees — non-droppable, they release
     capacity; (b) one decode token per running session, which allocates
     ONE page (`PAGE_UNIT`, the thread-cache frontend path) whenever the
     token crosses a page boundary — no slot free means the token
     **stalls**; (c) queued prefills — one burst malloc of the whole
     prompt extent (`prompt_pages * PAGE_UNIT`, the buddy/bypass path for
     long prompts). A session ends when its decode budget is spent or its
     context hits ``max_context`` (overflow ⇒ eviction); eviction frees
     every decode page and the prefill extent back through the protocol.
  3. **The scanned round driver (device side).** The whole session — op /
     size / pointer-ref grids of shape [rounds, R, C, T] — runs as ONE
     donated-state ``lax.scan`` over `heap.sharded_inner`
     (`repro.launch.serving.ScanEngine`, shared with FleetServe), with
     pointer operands resolved in-scan against the pointers the fleet
     actually returned: eviction frees free the real pages of this run.

The report couples serving and allocator metrics: ``tokens_per_sec`` and
TTFT percentiles (arrival → prefill dispatch through round barriers + the
prefill op's own modeled latency) alongside alloc p50/p95/p99 service
latencies, per-rank heap high-water marks, external fragmentation, and the
per-core conservation residual. `trace(rank, core)` (inherited) exports
any core's page traffic as a ``pim-malloc-trace/v1`` tape that replays
bitwise on every backend (pinned in tests/test_serve_decode.py; the
committed tape lives in benchmarks/tapes/decode_serve.json).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools

import numpy as np

from repro.core.heap import (OP_FREE, OP_MALLOC, OP_NOOP, AllocRequest,
                             AllocResponse)
from repro.kvcache.paged import PAGE_UNIT
from repro.launch import fleet
from repro.launch.serving import (ScanEngine, fleet_health, pct,
                                  resolve_pointers, response_host,
                                  round_barrier_cum)
from repro.workloads.trace import Trace

# ledger op kinds (DecodePlan.opkind)
PREFILL, DECODE_PAGE, EVICT_PAGE, EVICT_EXTENT = 0, 1, 2, 3

# session phases (host-side planner state machine)
_QUEUED, _DECODE, _EVICTED = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class DecodeTraffic:
    """Multi-tenant LLM decode traffic: Poisson sessions, Zipf tenants.

    ``session_rate`` is the mean number of new sessions per protocol round
    (Poisson). A session draws its prompt from ``prompt_choices`` (tokens;
    short prompts prefill through the thread-cache frontend, long ones
    through the buddy bypass) and its decode budget from
    ``decode_choices`` (0 = the tenant dies right after prefill). Context
    is capped at ``max_context`` tokens — a session that would decode past
    it is evicted on **overflow**. ``max_context`` must be page-aligned so
    the overflow edge lands exactly on a page boundary (no page is ever
    allocated for a token that cannot be written). ``queue_cap`` bounds
    the session admission queue (backpressure: drops are accounted).
    """

    seed: int = 0
    rounds: int = 96
    session_rate: float = 1.5
    num_tenants: int = 8
    zipf_a: float = 1.4
    page_size: int = 16                       # tokens per KV page
    prompt_choices: tuple = (24, 48, 120, 512, 3000)
    decode_choices: tuple = (0, 8, 24, 56, 120)
    max_context: int = 576
    queue_cap: int = 16

    def __post_init__(self):
        assert self.rounds >= 1 and self.zipf_a > 1.0
        assert self.queue_cap >= 1 and self.session_rate >= 0
        assert self.max_context % self.page_size == 0, \
            "max_context must be page-aligned (overflow = page boundary)"


@dataclasses.dataclass
class DecodePlan:
    """One planned decode session: the device tape + serving ledger."""

    shape: tuple                 # (R, C, T)
    placement: str
    page_size: int
    op: np.ndarray               # int32[rounds, R, C, T]
    size: np.ndarray
    ptr_ref: np.ndarray          # global slot id round*(R*C*T) + grid slot, -1
    ptr_raw: np.ndarray
    # per dispatched allocator op, in dispatch order:
    enq_round: np.ndarray        # int32[n] (prefill: session arrival round)
    disp_round: np.ndarray       # int32[n]
    slot: np.ndarray             # int32[n] flat in-round grid slot id
    session: np.ndarray          # int32[n]
    opkind: np.ndarray           # int32[n] PREFILL/DECODE_PAGE/EVICT_*
    # per admitted session:
    s_tenant: np.ndarray         # int32[S]
    s_arrive: np.ndarray         # int32[S]
    s_prefill_round: np.ndarray  # int32[S] (-1 = never prefilled)
    s_prompt: np.ndarray         # int32[S] tokens
    s_decode_target: np.ndarray  # int32[S] tokens
    s_tokens: np.ndarray         # int32[S] decode tokens actually generated
    s_end_round: np.ndarray      # int32[S] (-1 = still running at end)
    s_overflow: np.ndarray       # bool[S] evicted on context overflow
    s_stalls: np.ndarray         # int32[S] tokens delayed by a full core
    # admission / series:
    offered: int
    dropped: int
    backlog_end: int             # queued sessions + pending frees at end
    queue_depth: np.ndarray      # int32[rounds] admission queue after dispatch
    drops_per_round: np.ndarray
    decode_tokens_per_round: np.ndarray
    tenant_home: dict

    @property
    def rounds(self) -> int:
        return int(self.op.shape[0])

    @property
    def dispatched(self) -> int:
        return int(self.slot.shape[0])


class DecodeServe(ScanEngine):
    """Closed-loop paged-KV decode engine over one [R, C, T] fleet.

    Same driver contract as FleetServe (`ScanEngine`): ``mesh=False``
    scans the pure-vmap fleet step, ``None`` builds a 1-D rank mesh and
    shard_maps it — bitwise-identical either way (pinned in
    tests/test_serve_decode.py).
    """

    def __init__(self, cfg, num_ranks: int, num_cores: int,
                 traffic: DecodeTraffic = None,
                 placement: str = "least_loaded", mesh=False):
        if placement not in fleet.PLACEMENTS:
            raise ValueError(f"unknown placement {placement!r} "
                             f"(have {tuple(fleet.PLACEMENTS)})")
        super().__init__(cfg, num_ranks, num_cores, mesh=mesh)
        self.traffic = traffic or DecodeTraffic()
        self.placement = placement

    # ------------------------------------------------------------------
    # host-side planning: the continuous-batching scheduler
    # ------------------------------------------------------------------
    def plan(self) -> DecodePlan:
        tc = self.traffic
        R, C, T = self.shape
        cap = R * C * T
        ps = tc.page_size
        rng = np.random.default_rng(tc.seed)

        w = np.arange(1, tc.num_tenants + 1, dtype=np.float64) ** -tc.zipf_a
        pop = w / w.sum()

        op = np.zeros((tc.rounds, R, C, T), np.int32)
        size = np.zeros_like(op)
        ref = np.full_like(op, -1)
        raw = np.full_like(op, -1)

        sessions = []                       # planner state machines
        admit_q = collections.deque()       # sessions awaiting prefill
        evict_q = collections.deque()       # (session, aid, opkind) frees
        homes = {}                          # tenant -> (rank, core)
        loads = np.zeros((R, C))            # live bytes per core
        alloc_slot = {}                     # aid -> (global slot id, round)
        alloc_bytes = {}
        aid_counter = itertools.count()

        enq_l, disp_l, slot_l, sess_l, kind_l = [], [], [], [], []
        depth_series = np.zeros(tc.rounds, np.int32)
        drops_series = np.zeros(tc.rounds, np.int32)
        tokens_series = np.zeros(tc.rounds, np.int32)
        offered = dropped = 0

        def home_of(s):
            k = s["tenant"]
            if k not in homes:
                homes[k] = fleet.tenant_core(
                    self.placement, len(homes), self.shape, loads=loads,
                    expected_tenants=tc.num_tenants)
            return homes[k]

        for r in range(tc.rounds):
            # -- session arrivals through the bounded admission queue ------
            for _ in range(int(rng.poisson(tc.session_rate))):
                offered += 1
                k = int(rng.choice(tc.num_tenants, p=pop))
                prompt = int(rng.choice(tc.prompt_choices))
                decode = int(rng.choice(tc.decode_choices))
                if len(admit_q) >= tc.queue_cap:
                    dropped += 1
                    drops_series[r] += 1
                    continue
                s = {"idx": len(sessions), "tenant": k, "arrive": r,
                     "prompt": prompt, "decode_target": decode, "pos": 0,
                     "prefill_round": -1, "pages": [], "tokens": 0,
                     "phase": _QUEUED, "stalls": 0, "end": -1,
                     "overflow": False}
                sessions.append(s)
                admit_q.append(s)

            used = np.zeros((R, C), np.int32)

            def emit(s, o, sz, aid=None, new=False, kind=0, enq=None):
                """Place one op on s's home core this round; returns the
                (possibly fresh) aid, or None when the core is full or the
                free targets a pointer produced this very round."""
                rk, ck = home_of(s)
                if used[rk, ck] >= T:
                    return None
                if aid is not None and alloc_slot[aid][1] >= r:
                    return None
                t = int(used[rk, ck])
                used[rk, ck] += 1
                gslot = (rk * C + ck) * T + t
                op[r, rk, ck, t] = o
                size[r, rk, ck, t] = sz
                if aid is not None:
                    ref[r, rk, ck, t] = alloc_slot[aid][0]
                if new:
                    aid = next(aid_counter)
                    alloc_slot[aid] = (r * cap + gslot, r)
                    alloc_bytes[aid] = sz
                    loads[rk, ck] += sz
                elif o == OP_FREE:
                    loads[rk, ck] -= alloc_bytes.pop(aid)
                    del alloc_slot[aid]
                enq_l.append(r if enq is None else enq)
                disp_l.append(r)
                slot_l.append(gslot)
                sess_l.append(s["idx"])
                kind_l.append(kind)
                return aid

            # (a) eviction frees first: non-droppable, they release pages
            for _ in range(len(evict_q)):
                s, aid, kind = evict_q.popleft()
                if emit(s, OP_FREE, 0, aid=aid, kind=kind) is None:
                    evict_q.append((s, aid, kind))   # retry next round

            # (b) one decode token per running session (continuous batch)
            for s in sessions:
                if s["phase"] != _DECODE:
                    continue
                target = s["prompt"] + s["decode_target"]
                horizon = min(target, tc.max_context)
                if s["pos"] >= horizon:
                    # done (budget spent) or overflow (context full):
                    # evict — free decode pages, then the prefill extent
                    s["phase"] = _EVICTED
                    s["end"] = r
                    s["overflow"] = s["pos"] < target
                    for aid in s["pages"][1:]:
                        evict_q.append((s, aid, EVICT_PAGE))
                    evict_q.append((s, s["pages"][0], EVICT_EXTENT))
                    continue
                p = s["pos"]
                prompt_pages = -(-s["prompt"] // ps)
                if p % ps == 0 and p // ps >= prompt_pages:
                    # token crosses a page boundary: frontend single-page
                    # malloc; a full home core stalls the token
                    aid = emit(s, OP_MALLOC, PAGE_UNIT, new=True,
                               kind=DECODE_PAGE)
                    if aid is None:
                        s["stalls"] += 1
                        continue
                    s["pages"].append(aid)
                s["pos"] += 1
                s["tokens"] += 1
                tokens_series[r] += 1

            # (c) queued prefills fill the remaining slots (FIFO)
            for _ in range(len(admit_q)):
                s = admit_q.popleft()
                prompt_pages = -(-s["prompt"] // ps)
                aid = emit(s, OP_MALLOC, prompt_pages * PAGE_UNIT, new=True,
                           kind=PREFILL, enq=s["arrive"])
                if aid is None:
                    admit_q.appendleft(s)   # head-of-line: stay FIFO
                    break
                s["pages"] = [aid]
                s["pos"] = s["prompt"]
                s["prefill_round"] = r
                s["phase"] = _DECODE

            depth_series[r] = len(admit_q)

        return DecodePlan(
            shape=self.shape, placement=self.placement, page_size=ps,
            op=op, size=size, ptr_ref=ref, ptr_raw=raw,
            enq_round=np.asarray(enq_l, np.int32),
            disp_round=np.asarray(disp_l, np.int32),
            slot=np.asarray(slot_l, np.int32),
            session=np.asarray(sess_l, np.int32),
            opkind=np.asarray(kind_l, np.int32),
            s_tenant=np.asarray([s["tenant"] for s in sessions], np.int32),
            s_arrive=np.asarray([s["arrive"] for s in sessions], np.int32),
            s_prefill_round=np.asarray(
                [s["prefill_round"] for s in sessions], np.int32),
            s_prompt=np.asarray([s["prompt"] for s in sessions], np.int32),
            s_decode_target=np.asarray(
                [s["decode_target"] for s in sessions], np.int32),
            s_tokens=np.asarray([s["tokens"] for s in sessions], np.int32),
            s_end_round=np.asarray([s["end"] for s in sessions], np.int32),
            s_overflow=np.asarray([s["overflow"] for s in sessions], bool),
            s_stalls=np.asarray([s["stalls"] for s in sessions], np.int32),
            offered=offered, dropped=dropped,
            backlog_end=len(admit_q) + len(evict_q)
            + sum(1 for s in sessions if s["phase"] == _DECODE),
            queue_depth=depth_series, drops_per_round=drops_series,
            decode_tokens_per_round=tokens_series,
            tenant_home=dict(homes))

    def serve(self, plan: DecodePlan = None):
        """Plan (unless given) and run one session; returns (plan, report)."""
        plan = plan or self.plan()
        state, resps = self.run(plan)
        return plan, self.report(plan, resps, state)

    # ------------------------------------------------------------------
    # reporting: serving metrics + allocator metrics, one place
    # ------------------------------------------------------------------
    def report(self, plan: DecodePlan, resps: AllocResponse, state) -> dict:
        R, C, T = plan.shape
        rounds = plan.rounds
        freq = self.cfg.dpu.freq_hz
        host = response_host(resps)
        lat = host["latency_cyc"]
        opf = plan.op.reshape(rounds, -1)
        pathf = host["path"].reshape(rounds, -1)
        okf = host["ok"].reshape(rounds, -1)

        round_cyc, cum = round_barrier_cum(lat)
        own = lat.reshape(rounds, -1)[plan.disp_round, plan.slot]

        # TTFT: session arrival -> prefill dispatch (round barriers) + the
        # prefill op's own modeled latency — prefill emits the first token
        is_prefill = plan.opkind == PREFILL
        ttft = (cum[plan.disp_round[is_prefill]]
                - cum[plan.enq_round[is_prefill]] + own[is_prefill])
        # allocator service latency over every page-alloc op
        is_alloc_op = (plan.opkind == PREFILL) | (plan.opkind == DECODE_PAGE)
        alloc_lat = own[is_alloc_op]

        resolved = resolve_pointers(plan, host["ptr"])
        acct = fleet.FleetAccounting(R)
        for r in range(rounds):
            req = AllocRequest(op=plan.op[r], size=plan.size[r],
                               ptr=resolved[r])
            acct.add_round(req, AllocResponse(
                *[host[f][r] for f in AllocResponse._fields]))

        health = fleet_health(self.cfg, state, R, C)

        active = opf != OP_NOOP
        is_alloc = opf == OP_MALLOC
        modeled_wall_us = float(round_cyc.sum() / freq * 1e6)
        decode_tokens = int(plan.s_tokens.sum())
        prefill_tokens = int(plan.s_prompt[plan.s_prefill_round >= 0].sum())
        n_disp = plan.dispatched
        prefilled = int((plan.s_prefill_round >= 0).sum())
        report = {
            "shape": list(plan.shape), "rounds": rounds,
            "placement": plan.placement, "seed": self.traffic.seed,
            "page_size": plan.page_size,
            "capacity_per_round": self.capacity,
            # sessions / admission
            "sessions_offered": plan.offered,
            "sessions_dropped": plan.dropped,
            "session_drop_rate": plan.dropped / max(plan.offered, 1),
            "sessions_prefilled": prefilled,
            "sessions_completed": int(((plan.s_end_round >= 0)
                                       & ~plan.s_overflow).sum()),
            "sessions_evicted_overflow": int(plan.s_overflow.sum()),
            "sessions_active_end": int(((plan.s_prefill_round >= 0)
                                        & (plan.s_end_round < 0)).sum()),
            "backlog_end": plan.backlog_end,
            "queue_depth_mean": float(plan.queue_depth.mean()),
            "queue_depth_max": int(plan.queue_depth.max()),
            "drops_per_round": plan.drops_per_round.tolist(),
            "decode_tokens_per_round": plan.decode_tokens_per_round.tolist(),
            # tokens (the serving side of the coupled report)
            "prefill_tokens": prefill_tokens,
            "decode_tokens": decode_tokens,
            "tokens_total": prefill_tokens + decode_tokens,
            "tokens_per_sec": (decode_tokens
                               / max(modeled_wall_us, 1e-9) * 1e6),
            "decode_stalls": int(plan.s_stalls.sum()),
            **{f"ttft_{k}": v for k, v in pct(ttft).items()},
            # allocator latency (the allocator side)
            **{f"alloc_{k}": v for k, v in pct(alloc_lat).items()},
            # op mix / outcome counters
            "prefill_allocs": int(is_prefill.sum()),
            "decode_page_allocs": int((plan.opkind == DECODE_PAGE).sum()),
            "evict_frees": int((plan.opkind >= EVICT_PAGE).sum()),
            "ops": int(active.sum()), "ok_ops": int(okf.sum()),
            "failed_allocs": int((is_alloc & active & ~okf).sum()),
            "dropped_frees": int(((opf == OP_FREE) & (pathf == 2)).sum()),
            # heap health (per-core conservation + per-rank high-water)
            **health,
            "modeled_wall_us": modeled_wall_us,
            "ops_per_sec": (n_disp / max(modeled_wall_us, 1e-9) * 1e6),
            "accounting": acct.summary(freq),
        }
        report["us_per_op"] = report["accounting"]["us_per_op"]
        return report

    def trace(self, plan: DecodePlan, rank: int, core: int,
              name: str = None) -> Trace:
        """Export (rank, core)'s page traffic as a ``pim-malloc-trace/v1``
        tape (see `ScanEngine.trace` — closed by tenant stickiness)."""
        return super().trace(
            plan, rank, core, name=name,
            description=(f"DecodeServe paged-KV session slice rank={rank} "
                         f"core={core} placement={plan.placement}"),
            meta={"placement": plan.placement, "rank": rank, "core": core,
                  "seed": self.traffic.seed, "page_size": plan.page_size,
                  "workload": "llm-decode-paged-kv"})


def serve_decode_session(cfg, num_ranks: int, num_cores: int,
                         traffic: DecodeTraffic = None,
                         placement: str = "least_loaded", mesh=False) -> dict:
    """One-call convenience: build a DecodeServe, run one session, return
    the report (benchmarks and the example CLI use this)."""
    engine = DecodeServe(cfg, num_ranks, num_cores, traffic=traffic,
                         placement=placement, mesh=mesh)
    _, report = engine.serve()
    return report
