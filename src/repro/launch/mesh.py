"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax device
state. Single pod: 16 x 16 = 256 chips (data x model). Multi-pod: 2 pods x
256 = 512 chips with a leading 'pod' axis (DP across pods over DCN/ICI).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Degenerate mesh over the locally available devices (tests/examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
