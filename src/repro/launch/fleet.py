"""Fleet-level request router over a ShardedHeap.

The deployment story of the scaling claim: a service front-end holds a flat
stream of allocation requests; the router scatters them onto the fleet's
fixed [R ranks, C cores, T threads] protocol grid (NOOP-padding the empty
slots), drives one donated `ShardedHeap.step` per round, gathers the
responses back into request order, and accumulates the DPU cost model's
accounting fleet-wide and per rank.

    heap = ShardedHeap(cfg, num_ranks=R, num_cores=C)
    router = FleetRouter(heap)
    resp = router.route(request_RCT)          # pre-batched [R, C, T] round
    out = router.route_flat(op, size, ptr)    # flat stream, any N <= R*C*T
    router.stats                              # totals + per-rank breakdown

Placement is slot-order (row-major over ranks, then cores, then threads):
request i lands on rank i // (C*T) — contiguous chunks per rank, matching
how a rank-of-ranks management layer (SimplePIM-style) hands work to DPUs.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import heap as heap_api
from repro.core import system as sysm
from repro.core.heap import AllocRequest, AllocResponse


def scatter_flat(op, size, ptr, shape: tuple) -> AllocRequest:
    """Flat per-request arrays (length N <= R*C*T) -> one [R, C, T] round.

    Unfilled slots become NOOPs; slot order is row-major, so `gather_flat`
    with the same N is the exact inverse.
    """
    R, C, T = shape
    total = R * C * T
    op = np.asarray(op, np.int32)
    n = op.shape[0]
    if n > total:
        raise ValueError(f"{n} requests > fleet capacity {total} ({shape})")

    def pad(x, fill):
        x = np.asarray(x, np.int32)
        out = np.full((total,), fill, np.int32)
        out[:n] = x
        return jnp.asarray(out.reshape(R, C, T))

    return AllocRequest(op=pad(op, heap_api.OP_NOOP), size=pad(size, 0),
                        ptr=pad(ptr, -1))


def gather_flat(resp: AllocResponse, n: int) -> dict:
    """[R, C, T] response -> flat arrays in the original request order."""
    return {f: np.asarray(getattr(resp, f)).reshape(-1)[:n]
            for f in AllocResponse._fields}


class FleetRouter:
    """Scatter/step/gather driver + cost accounting for one ShardedHeap."""

    def __init__(self, heap: heap_api.ShardedHeap):
        self.heap = heap
        self.rounds = 0
        self.totals = {k: 0.0 for k in
                       ("ops", "ok", "latency_cyc", "backend_cyc",
                        "meta_hits", "meta_misses", "dram_bytes")}
        self.per_rank_latency_cyc = np.zeros(heap.num_ranks)
        self.per_rank_ops = np.zeros(heap.num_ranks, np.int64)
        self.per_rank_dram_bytes = np.zeros(heap.num_ranks, np.int64)

    @property
    def shape(self) -> tuple:
        return self.heap.shape

    @property
    def capacity(self) -> int:
        """Requests servable per round: one per fleet hardware thread."""
        R, C, T = self.shape
        return R * C * T

    def route(self, request: AllocRequest) -> AllocResponse:
        """Serve one pre-batched [R, C, T] round and account for it."""
        resp = self.heap.step(request)
        acct = sysm.fleet_accounting(request, resp)
        self.rounds += 1
        for k in self.totals:
            self.totals[k] += acct[k]
        pr = acct.get("per_rank")
        if pr:
            self.per_rank_latency_cyc += np.asarray(pr["latency_cyc"])
            self.per_rank_ops += np.asarray(pr["ops"], np.int64)
            self.per_rank_dram_bytes += np.asarray(pr["dram_bytes"], np.int64)
        return resp

    def route_flat(self, op, size, ptr) -> dict:
        """Serve a flat request stream; returns flat response arrays + the
        full AllocResponse under 'resp'."""
        n = np.asarray(op).shape[0]
        resp = self.route(scatter_flat(op, size, ptr, self.shape))
        out = gather_flat(resp, n)
        out["resp"] = resp
        return out

    @property
    def stats(self) -> dict:
        """Accumulated fleet accounting across all routed rounds."""
        freq = self.heap.cfg.dpu.freq_hz
        ops = max(self.totals["ops"], 1)
        return {
            "rounds": self.rounds,
            **{k: (int(v) if k not in ("latency_cyc", "backend_cyc")
                   else float(v)) for k, v in self.totals.items()},
            "us_per_op": self.totals["latency_cyc"] / ops / freq * 1e6,
            "dram_bytes_per_op": self.totals["dram_bytes"] / ops,
            "per_rank": {
                "ops": self.per_rank_ops.tolist(),
                "latency_cyc": self.per_rank_latency_cyc.tolist(),
                "dram_bytes": self.per_rank_dram_bytes.tolist(),
            },
        }
