"""Fleet-level request routing over a ShardedHeap: placement + accounting.

The deployment story of the scaling claim: a service front-end holds a flat
stream of allocation requests; the router scatters them onto the fleet's
fixed [R ranks, C cores, T threads] protocol grid (NOOP-padding the empty
slots), drives one donated `ShardedHeap.step` per round, gathers the
responses back into request order, and accumulates the DPU cost model's
accounting fleet-wide and per rank.

    heap = ShardedHeap(cfg, num_ranks=R, num_cores=C)
    router = FleetRouter(heap)
    resp = router.route(request_RCT)          # pre-batched [R, C, T] round
    out = router.route_flat(op, size, ptr)    # flat stream, any N <= R*C*T
    out = router.route_flat(op, size, ptr, placement="least_loaded")
    router.stats                              # totals + per-rank breakdown

Three pieces are deliberately standalone so the closed-loop serving tier
(`repro.launch.serve_fleet`) shares them instead of reimplementing:

  * **placement** — the :data:`PLACEMENTS` registry maps a policy name to a
    slot policy ``fn(n, shape, loads=None, start=0) -> int array [n]`` of
    flat grid slot ids (slot ``(r, c, t)`` has id ``(r*C + c)*T + t``), and
    :func:`tenant_core` derives a sticky (rank, core) homing for the i-th
    admitted tenant under the same policy names (a tenant's frees/reallocs
    must hit the heap that served its mallocs — cores are independent);
  * **scatter/gather** — :func:`scatter_slots` / :func:`gather_slots` place
    a flat stream onto arbitrary slots and invert it exactly
    (:func:`scatter_flat` / :func:`gather_flat` are the contiguous
    chunked special case, pinned as exact inverses in
    tests/test_fleet_serve.py);
  * **accounting** — :class:`FleetAccounting` accumulates
    `system.fleet_accounting` rounds into fleet totals + per-rank series.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import heap as heap_api
from repro.core import system as sysm
from repro.core.heap import AllocRequest, AllocResponse


# ---------------------------------------------------------------------------
# placement policies
# ---------------------------------------------------------------------------
def place_chunked(n: int, shape: tuple, loads=None, start: int = 0):
    """Contiguous row-major slots: request i -> slot start + i (mod cap).

    The original FleetRouter behavior — rank 0's cores fill first, matching
    a SimplePIM-style management layer handing contiguous work chunks to
    DPUs."""
    R, C, T = shape
    return (start + np.arange(n, dtype=np.int64)) % (R * C * T)


def place_round_robin(n: int, shape: tuple, loads=None, start: int = 0):
    """Stripe across ranks first, then cores, then thread slots: consecutive
    requests land on different ranks, spreading a small burst fleet-wide."""
    R, C, T = shape
    i = start + np.arange(n, dtype=np.int64)
    rank = i % R
    core = (i // R) % C
    th = (i // (R * C)) % T
    return (rank * C + core) * T + th


def place_least_loaded(n: int, shape: tuple, loads=None, start: int = 0):
    """Fill the thread slots of the least-loaded (rank, core) first.

    ``loads`` is a [R, C] (or flat [R*C]) per-core load signal — live bytes,
    outstanding ops, whatever the caller tracks; ties break row-major. With
    no loads this degrades to chunked."""
    R, C, T = shape
    if loads is None:
        return place_chunked(n, shape, start=start)
    order = np.argsort(np.asarray(loads, np.float64).reshape(-1),
                       kind="stable")
    slots = (order[:, None] * T + np.arange(T)[None, :]).reshape(-1)
    if n > slots.shape[0]:
        raise ValueError(f"{n} requests > fleet capacity {R * C * T}")
    return slots[:n].astype(np.int64)


PLACEMENTS = {
    "chunked": place_chunked,
    "round_robin": place_round_robin,
    "least_loaded": place_least_loaded,
}


def tenant_core(policy: str, i: int, shape: tuple, loads=None,
                expected_tenants: int = None) -> tuple:
    """Sticky (rank, core) homing for the i-th admitted tenant.

    All of a tenant's ops must reach the SAME per-core heap (pointers are
    core-local), so the serving tier places tenants, not single requests:

      * ``chunked``      — contiguous tenant blocks per core in row-major
        order (block size ``ceil(expected_tenants / (R*C))``, default 1);
      * ``round_robin``  — tenant i -> rank i % R, core (i // R) % C;
      * ``least_loaded`` — the core with the smallest ``loads`` entry
        (falls back to chunked blocks when no loads are tracked yet).

    A policy registered in :data:`PLACEMENTS` without a homing rule here is
    an error — it must not silently degrade to chunked homing.
    """
    R, C, T = shape
    if policy not in PLACEMENTS:
        raise ValueError(f"unknown placement {policy!r} "
                         f"(have {tuple(PLACEMENTS)})")
    if policy == "round_robin":
        return int(i % R), int((i // R) % C)
    if policy == "least_loaded" and loads is not None:
        flat = int(np.argmin(np.asarray(loads, np.float64).reshape(-1)))
        return flat // C, flat % C
    if policy not in ("chunked", "least_loaded"):
        raise ValueError(f"no tenant-homing rule for placement {policy!r}")
    chunk = max(1, -(-int(expected_tenants or R * C) // (R * C)))
    j = (i // chunk) % (R * C)
    return j // C, j % C


# ---------------------------------------------------------------------------
# migration + drain policies (the elastic tier's declarative hooks)
# ---------------------------------------------------------------------------
# A migration policy answers "pressure diverged — which tenants move where":
#     fn(pressure, homes, tenant_bytes, loads, shape, dead=frozenset(),
#        max_moves=1) -> [(tenant, (rank, core)), ...]
# with ``pressure`` a `repro.core.telemetry.hwm_divergence` dict, ``homes``
# the planner's {tenant: (rank, core)}, ``tenant_bytes`` {tenant: tracked
# live bytes}, ``loads`` the [R, C] live-bytes signal, and ``dead`` the
# killed cores. A drain policy answers "at which rounds may the fleet pause
# to decide": fn(traffic, check_rounds) -> sorted round list. Registering a
# new entry in MIGRATIONS / DRAINS is the whole integration — the elastic
# engine (`repro.launch.elastic`) looks policies up by name, mirroring
# PLACEMENTS.


def migrate_hottest_tenant(pressure, homes, tenant_bytes, loads, shape,
                           dead=frozenset(), max_moves: int = 1):
    """Move the biggest tenant(s) homed on the hottest rank to the
    least-loaded live core off that rank; ties break by tenant id."""
    R, C, T = shape
    hot = pressure["hottest_rank"]
    victims = sorted((k for k, (rk, _) in homes.items() if rk == hot),
                     key=lambda k: (-tenant_bytes.get(k, 0), k))
    masked = np.asarray(loads, np.float64).copy()
    masked[hot, :] = np.inf
    for d in dead:
        masked[d] = np.inf
    moves = []
    for k in victims[:max_moves]:
        if not np.isfinite(masked).any():
            break
        flat = int(np.argmin(masked.reshape(-1)))
        dst = (flat // C, flat % C)
        masked[dst] += tenant_bytes.get(k, 0)
        moves.append((k, dst))
    return moves


def migrate_none(pressure, homes, tenant_bytes, loads, shape,
                 dead=frozenset(), max_moves: int = 1):
    """Baseline: never move anything (the migration-off bench arm)."""
    return []


MIGRATIONS = {
    "hottest_tenant": migrate_hottest_tenant,
    "none": migrate_none,
}


def drain_epoch(traffic, check_rounds: int):
    """Decide only at epoch boundaries — the free drain point: Temp blocks
    die at the reset, so a migrating tenant drags no Temp state along.
    Falls back to no drain points when the traffic has no epoch mode."""
    E = traffic.epoch_rounds
    if not E:
        return []
    return list(range(E, traffic.rounds, E))


def drain_interval(traffic, check_rounds: int):
    """Decide every ``check_rounds`` rounds regardless of epoch mode."""
    step = max(1, int(check_rounds))
    return list(range(step, traffic.rounds, step))


def drain_never(traffic, check_rounds: int):
    return []


DRAINS = {
    "epoch": drain_epoch,
    "interval": drain_interval,
    "none": drain_never,
}


# ---------------------------------------------------------------------------
# scatter / gather
# ---------------------------------------------------------------------------
def scatter_slots(op, size, ptr, shape: tuple, slots) -> AllocRequest:
    """Flat per-request arrays -> one [R, C, T] round at explicit grid slots.

    ``slots`` are distinct flat slot ids (see module docstring); unfilled
    slots become NOOPs. ``gather_slots`` with the same slots is the exact
    inverse."""
    R, C, T = shape
    total = R * C * T
    op = np.asarray(op, np.int32)
    slots = np.asarray(slots, np.int64)
    n = op.shape[0]
    if slots.shape[0] != n:
        raise ValueError(f"{n} requests but {slots.shape[0]} slots")
    if n > total:
        raise ValueError(f"{n} requests > fleet capacity {total} ({shape})")
    if n and (slots.min() < 0 or slots.max() >= total):
        raise ValueError(f"slot ids out of range [0, {total})")
    if np.unique(slots).shape[0] != n:
        raise ValueError("duplicate slot ids in one round")

    def pad(x, fill):
        out = np.full((total,), fill, np.int32)
        out[slots] = np.asarray(x, np.int32)
        return jnp.asarray(out.reshape(R, C, T))

    return AllocRequest(op=pad(op, heap_api.OP_NOOP), size=pad(size, 0),
                        ptr=pad(ptr, -1))


def gather_slots(resp: AllocResponse, slots) -> dict:
    """[R, C, T] response -> flat arrays in the original request order."""
    slots = np.asarray(slots, np.int64)
    return {f: np.asarray(getattr(resp, f)).reshape(-1)[slots]
            for f in AllocResponse._fields}


def scatter_flat(op, size, ptr, shape: tuple) -> AllocRequest:
    """Flat per-request arrays (length N <= R*C*T) -> one [R, C, T] round.

    Unfilled slots become NOOPs; slot order is row-major (chunked), so
    `gather_flat` with the same N is the exact inverse.
    """
    n = np.asarray(op, np.int32).shape[0]
    return scatter_slots(op, size, ptr, shape, place_chunked(n, shape))


def gather_flat(resp: AllocResponse, n: int) -> dict:
    """[R, C, T] response -> flat arrays in the original request order."""
    return {f: np.asarray(getattr(resp, f)).reshape(-1)[:n]
            for f in AllocResponse._fields}


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------
class FleetAccounting:
    """Accumulates `system.fleet_accounting` rounds: totals + per-rank."""

    TOTALS = ("ops", "ok", "latency_cyc", "backend_cyc", "meta_hits",
              "meta_misses", "dram_bytes")

    def __init__(self, num_ranks: int):
        self.rounds = 0
        self.totals = {k: 0.0 for k in self.TOTALS}
        self.per_rank_latency_cyc = np.zeros(num_ranks)
        self.per_rank_ops = np.zeros(num_ranks, np.int64)
        self.per_rank_dram_bytes = np.zeros(num_ranks, np.int64)

    def add_round(self, request: AllocRequest, resp: AllocResponse) -> dict:
        """Account one [R, C, T] round; returns the round's accounting."""
        acct = sysm.fleet_accounting(request, resp)
        self.rounds += 1
        for k in self.totals:
            self.totals[k] += acct[k]
        pr = acct.get("per_rank")
        if pr:
            self.per_rank_latency_cyc += np.asarray(pr["latency_cyc"])
            self.per_rank_ops += np.asarray(pr["ops"], np.int64)
            self.per_rank_dram_bytes += np.asarray(pr["dram_bytes"],
                                                   np.int64)
        return acct

    def summary(self, freq_hz: float) -> dict:
        """Accumulated fleet accounting across all added rounds."""
        ops = max(self.totals["ops"], 1)
        return {
            "rounds": self.rounds,
            **{k: (int(v) if k not in ("latency_cyc", "backend_cyc")
                   else float(v)) for k, v in self.totals.items()},
            "us_per_op": self.totals["latency_cyc"] / ops / freq_hz * 1e6,
            "dram_bytes_per_op": self.totals["dram_bytes"] / ops,
            "per_rank": {
                "ops": self.per_rank_ops.tolist(),
                "latency_cyc": self.per_rank_latency_cyc.tolist(),
                "dram_bytes": self.per_rank_dram_bytes.tolist(),
            },
        }


class FleetRouter:
    """Scatter/step/gather driver + cost accounting for one ShardedHeap."""

    def __init__(self, heap: heap_api.ShardedHeap):
        self.heap = heap
        self.acct = FleetAccounting(heap.num_ranks)
        self._core_ops = np.zeros((heap.num_ranks, heap.num_cores), np.int64)

    @property
    def shape(self) -> tuple:
        return self.heap.shape

    @property
    def rounds(self) -> int:
        return self.acct.rounds

    @property
    def capacity(self) -> int:
        """Requests servable per round: one per fleet hardware thread."""
        R, C, T = self.shape
        return R * C * T

    @property
    def core_loads(self) -> np.ndarray:
        """[R, C] cumulative routed-op counts — the default load signal for
        ``least_loaded`` placement (activity, not residency: the router has
        no pointer lifetime knowledge; the serving tier tracks live bytes)."""
        return self._core_ops

    def route(self, request: AllocRequest) -> AllocResponse:
        """Serve one pre-batched [R, C, T] round and account for it."""
        resp = self.heap.step(request)
        self.acct.add_round(request, resp)
        self._core_ops += (np.asarray(request.op)
                           != heap_api.OP_NOOP).sum(axis=2)
        return resp

    def route_flat(self, op, size, ptr, placement: str = "chunked",
                   slots=None) -> dict:
        """Serve a flat request stream; returns flat response arrays + the
        full AllocResponse under 'resp' and the grid slots used under
        'slots'. ``placement`` picks the slot policy (:data:`PLACEMENTS`)
        used to spread the stream over the grid.

        Pointer locality: a FREE/REALLOC must reach the core that produced
        its pointer. ``chunked``/``round_robin`` are pure functions of the
        request index, so a free stream in the same order as its alloc
        stream lands on the same cores; ``least_loaded`` is *stateful*
        (loads change between rounds), so pointer-carrying streams must pin
        their placement by passing the alloc round's returned ``slots``
        back via ``slots=`` — the tenant-sticky serving tier
        (`repro.launch.serve_fleet`) exists for exactly this reason."""
        n = np.asarray(op).shape[0]
        if slots is None:
            if placement == "least_loaded" and np.any(np.asarray(ptr) >= 0):
                raise ValueError(
                    "least_loaded placement is stateful: pointer-carrying "
                    "streams (FREE/REALLOC) must pin the producing round's "
                    "slots via slots= or they may land on the wrong core")
            slots = PLACEMENTS[placement](n, self.shape,
                                          loads=self.core_loads)
        resp = self.route(scatter_slots(op, size, ptr, self.shape, slots))
        out = gather_slots(resp, slots)
        out["resp"] = resp
        out["slots"] = slots
        return out

    @property
    def stats(self) -> dict:
        """Accumulated fleet accounting across all routed rounds."""
        return self.acct.summary(self.heap.cfg.dpu.freq_hz)
