"""Step builders: train_step (grad-accum + AdamW), prefill_step, decode_step.

These are the functions the dry-run lowers and the trainer jits. All are
pure: state in, state out.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.models import registry
from repro.models.config import ArchConfig
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig, AdamWState


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig, n_micro: int = 1,
                    grad_pspec=None):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    Gradient accumulation over `n_micro` microbatches via lax.scan: the
    leading global-batch dim must be divisible by n_micro. `grad_pspec`
    (a PartitionSpec pytree matching params) pins the accumulator's
    sharding — without it GSPMD replicates the accumulator and emits
    full-weight all-reduces every layer x micro (measured: 5.4 GB x 704
    on mistral-123b, EXPERIMENTS.md SSPerf).
    """
    lf = registry.loss_fn(cfg)
    grad_fn = jax.value_and_grad(lf, has_aux=True)

    def _pin(g):
        if grad_pspec is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g, grad_pspec)

    def train_step(params, opt_state: AdamWState, batch):
        if n_micro == 1:
            (l, metrics), grads = grad_fn(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro,
                                    *x.shape[1:]), batch)

            def acc(carry, micro):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, micro)
                g_acc = _pin(jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g))
                return (g_acc, l_acc + l), None

            g0 = _pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, lsum), _ = lax.scan(acc, (g0, jnp.float32(0.0)), mb)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            l = lsum / n_micro
            metrics = {"loss": l}
        params, opt_state, opt_metrics = adamw.update(
            opt_cfg, grads, opt_state, params)
        return params, opt_state, {**metrics, **opt_metrics}

    return train_step


def make_prefill_step(cfg: ArchConfig):
    mod = registry.get_module(cfg)

    def prefill_step(params, batch, cache):
        return mod.prefill(cfg, params, batch, cache)

    return prefill_step


def make_decode_step(cfg: ArchConfig):
    mod = registry.get_module(cfg)

    def decode_step(params, cache, batch):
        return mod.decode(cfg, params, cache, batch)

    return decode_step


def opt_state_sds(cfg: ArchConfig, opt_cfg: AdamWConfig):
    """ShapeDtypeStructs of the optimizer state (dry run, no allocation)."""
    p_sds = registry.param_sds(cfg)
    mdt = jnp.dtype(opt_cfg.moment_dtype)
    mom = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, mdt), p_sds)
    return AdamWState(count=jax.ShapeDtypeStruct((), jnp.int32), m=mom, v=mom)
