"""Shared serving-engine machinery for the closed-loop engines.

`repro.launch.serve_fleet.FleetServe` (raw multi-tenant alloc traffic) and
`repro.launch.serve_decode.DecodeServe` (paged-KV LLM decode) plan very
different host-side workloads, but they execute and report them the same
way. This module holds that common substance — extracted, not copied:

  * :class:`SessionPlan` — the planned device tape (op / size / pointer-ref
    grids of shape [rounds, R, C, T]) plus the host-side dispatch ledger
    and admission/backpressure series.
  * :class:`ScanEngine` — the round driver: the whole planned session runs
    as ONE ``lax.scan`` of the fleet step (`heap.sharded_inner`: vmap over
    cores and ranks, optionally shard_mapped over a rank mesh) with the
    heap state **donated**. Pointer operands are symbolic slot references
    resolved in-scan against the pointers the fleet actually returned
    (exactly the `repro.workloads` tape mechanism lifted to the grid), so
    sessions are closed-loop: frees free the real pointers of this run.
    `ScanEngine.trace` exports any (rank, core)'s slice of a session as a
    standard ``pim-malloc-trace/v1`` tape.
  * report helpers — latency percentiles over round barriers
    (:func:`pct`, :func:`round_barrier_cum`), in-scan pointer resolution
    for accounting (:func:`resolve_pointers`), and the per-core heap-health
    sweep (:func:`fleet_health` — |residual| summed so signed residuals of
    two broken cores never cancel into a clean-looking fleet).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core import heap as heap_api
from repro.core import telemetry
from repro.core.heap import OP_REALLOC, AllocRequest, AllocResponse
from repro.workloads.trace import Trace

PERCENTILES = (50, 95, 99)


@dataclasses.dataclass
class SessionPlan:
    """One planned serve session: the device tape + the host-side ledger."""

    shape: tuple                 # (R, C, T)
    placement: str
    op: np.ndarray               # int32[rounds, R, C, T]
    size: np.ndarray
    ptr_ref: np.ndarray          # global slot id round*(R*C*T) + grid slot, -1
    ptr_raw: np.ndarray
    # per dispatched request, in dispatch order:
    enq_round: np.ndarray        # int32[n]
    disp_round: np.ndarray       # int32[n]
    slot: np.ndarray             # int32[n] flat in-round grid slot id
    tenant: np.ndarray           # int32[n]
    external: np.ndarray         # bool[n] (False = expiry free)
    # admission/backpressure ledger:
    offered: int                 # external arrivals
    dropped: int                 # rejected at the full admission queue
    backlog_end: int             # still queued when the session ended
    queue_depth: np.ndarray      # int32[rounds] backlog after each dispatch
    external_queue_depth: np.ndarray  # int32[rounds] admission queue only
    drops_per_round: np.ndarray  # int32[rounds]
    dispatched_per_round: np.ndarray
    tenant_home: dict            # tenant -> (rank, core)

    @property
    def rounds(self) -> int:
        return int(self.op.shape[0])

    @property
    def dispatched(self) -> int:
        return int(self.slot.shape[0])


def epoch_boundaries(rounds: int, epoch_rounds: int) -> np.ndarray:
    """bool[rounds] mask of epoch-boundary rounds.

    With ``epoch_rounds = E > 0`` every E-th round (r = E-1, 2E-1, ...) is
    dedicated to ``OP_EPOCH_RESET``: the planner dispatches no traffic into
    it and every epoch-managed (small) allocation made since the previous
    boundary is invalid afterwards — the arena frontend reclaims them in
    one bulk reset instead of one FREE per block. ``epoch_rounds <= 0``
    disables epochs (all-False mask).
    """
    mask = np.zeros(rounds, bool)
    if epoch_rounds > 0:
        mask[epoch_rounds - 1::epoch_rounds] = True
    return mask


def pct(x, percentiles=PERCENTILES) -> dict:
    """{'p50_cyc': ..., ...} percentile dict (zeros for an empty sample)."""
    x = np.asarray(x)
    if x.size == 0:
        return {f"p{p}_cyc": 0.0 for p in percentiles}
    return {f"p{p}_cyc": float(np.percentile(x, p)) for p in percentiles}


def response_host(resps: AllocResponse) -> dict:
    """One device->host conversion per response field, reused throughout."""
    return {f: np.asarray(getattr(resps, f)) for f in AllocResponse._fields}


def round_barrier_cum(lat: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(per-round barrier cycles, cumulative barrier prefix [rounds+1]).

    Threads within a round run concurrently; rounds serialize, so one
    round's barrier is its slowest thread and a queued request waits
    through the barriers between enqueue and dispatch.
    """
    rounds = lat.shape[0]
    flat = lat.reshape(rounds, -1)
    round_cyc = flat.max(axis=1) if flat.size else np.zeros(rounds)
    return round_cyc, np.concatenate([[0.0], np.cumsum(round_cyc)])


def resolve_pointers(plan, host_ptr: np.ndarray) -> np.ndarray:
    """Pointer operands as the scan actually resolved them (slot refs
    against this run's returned pointers), not the raw placeholders —
    accounting must see the served request."""
    flat_ptr = host_ptr.reshape(-1)
    return np.where(
        plan.ptr_ref >= 0,
        flat_ptr[np.clip(plan.ptr_ref, 0, flat_ptr.shape[0] - 1)],
        plan.ptr_raw).astype(np.int32)


def fleet_health(cfg, state, R: int, C: int) -> dict:
    """Per-core telemetry sweep over the final sharded state.

    ``conservation_residual`` sums |per-core residuals| (signed residuals
    of two broken cores must not cancel into a clean-looking fleet);
    ``hwm_bytes_per_rank`` is each rank's busiest core (heaps are per-core,
    so a rank's high-water footprint is bounded by its hottest heap).
    """
    residual = live_b = 0
    hwm_rank = [0] * R
    frags = []
    for rk in range(R):
        for ck in range(C):
            snap = telemetry.snapshot(
                cfg, jax.tree.map(lambda x: x[rk, ck], state))
            residual += abs(snap["conservation_residual"])
            live_b += snap["live_bytes"]
            hwm_rank[rk] = max(hwm_rank[rk], snap["hwm_bytes"])
            frags.append(snap["external_frag"])
    return {
        "live_bytes": int(live_b),
        "conservation_residual": int(residual),
        "hwm_bytes_per_rank": [int(h) for h in hwm_rank],
        "hwm_bytes_max": int(max(hwm_rank)),
        "external_frag_mean": float(np.mean(frags)) if frags else 0.0,
    }


class ScanEngine:
    """The scanned round driver every serving engine shares.

    ``mesh`` follows :class:`repro.core.heap.ShardedHeap`: ``False``
    scans the pure-vmap fleet step, ``None`` builds a 1-D rank mesh and
    shard_maps it, or pass an explicit mesh. The scanned step is
    bitwise-identical either way (pinned for the one-round path in
    tests/test_sharded_heap.py, for whole sessions in
    tests/test_fleet_serve.py and tests/test_serve_decode.py).
    """

    def __init__(self, cfg, num_ranks: int, num_cores: int, mesh=False):
        self.cfg = cfg
        self.num_ranks = num_ranks
        self.num_cores = num_cores
        inner, self.mesh = heap_api.sharded_inner(cfg, num_ranks, mesh=mesh)
        self._inner = inner
        self._scan = jax.jit(self._scan_fn, donate_argnums=(0,))
        # segmented driver (elastic tier): same round body, but the slot
        # file and the round offset are carried across calls so a session
        # can be executed in pieces with host-side decisions in between —
        # bitwise-identical to one uninterrupted scan (same per-round math)
        self._segment = jax.jit(self._segment_fn, donate_argnums=(0, 1))

    @property
    def shape(self) -> tuple:
        return (self.num_ranks, self.num_cores, self.cfg.num_threads)

    @property
    def capacity(self) -> int:
        R, C, T = self.shape
        return R * C * T

    def _round_body(self, n_slots: int, cap: int):
        def body(carry, x):
            st, slots = carry
            r, op_r, size_r, ref_r, raw_r = x
            ptr = jnp.where(ref_r >= 0,
                            slots[jnp.clip(ref_r, 0, n_slots - 1)], raw_r)
            st, resp = self._inner(st, AllocRequest(op=op_r, size=size_r,
                                                    ptr=ptr))
            # slot = the op's surviving pointer (same rule as the workloads
            # replayer): a failed relocating realloc keeps the old block,
            # so the tenant's scheduled expiry FREE must still reach it
            survived = ((op_r == OP_REALLOC) & (size_r > 0)
                        & (resp.ptr < 0) & (ptr >= 0))
            slots = lax.dynamic_update_slice(
                slots, jnp.where(survived, ptr, resp.ptr).reshape(-1),
                (r * cap,))
            return (st, slots), resp

        return body

    def _scan_fn(self, state, op, size, ptr_ref, ptr_raw):
        rounds = op.shape[0]
        cap = self.capacity
        n_slots = rounds * cap
        slots0 = jnp.full((n_slots,), -1, jnp.int32)
        (state, _), resps = lax.scan(
            self._round_body(n_slots, cap), (state, slots0),
            (jnp.arange(rounds, dtype=jnp.int32), op, size, ptr_ref,
             ptr_raw))
        return state, resps

    def _segment_fn(self, state, slots, r0, op, size, ptr_ref, ptr_raw):
        """Scan a contiguous slice [r0, r0+len) of a session.

        ``slots`` is the full-session slot file (rounds * capacity), carried
        across segments; ``r0`` the slice's first global round index. The
        round body is exactly :meth:`_scan_fn`'s, so running a session as N
        segments is bitwise-identical to one scan — the elastic tier's
        snapshot/resume and fault-surgery points rely on this.
        """
        seg = op.shape[0]
        cap = self.capacity
        (state, slots), resps = lax.scan(
            self._round_body(slots.shape[0], cap), (state, slots),
            (r0 + jnp.arange(seg, dtype=jnp.int32), op, size, ptr_ref,
             ptr_raw))
        return state, slots, resps

    def run_segment(self, state, slots, r0: int, plan):
        """Execute rounds [r0, r1) of a planned session (r1 = r0 + segment
        length implied by the sliced grids passed via ``plan`` tuple
        ``(op, size, ptr_ref, ptr_raw)``); returns (state, slots, resps)."""
        op, size, ptr_ref, ptr_raw = plan
        return self._segment(
            state, slots, jnp.int32(r0), jnp.asarray(op), jnp.asarray(size),
            jnp.asarray(ptr_ref), jnp.asarray(ptr_raw))

    def run(self, plan):
        """Execute a planned session on a fresh fleet; returns the final
        sharded state and the stacked [rounds, R, C, T] responses."""
        state = heap_api.sharded_init(self.cfg, self.num_ranks,
                                      self.num_cores)
        return self._scan(
            state, jnp.asarray(plan.op), jnp.asarray(plan.size),
            jnp.asarray(plan.ptr_ref), jnp.asarray(plan.ptr_raw))

    # ------------------------------------------------------------------
    # tape export: one core's slice of a session is a standard trace
    # ------------------------------------------------------------------
    def trace(self, plan, rank: int, core: int, name: str = None,
              description: str = None, meta: dict = None) -> Trace:
        """Export (rank, core)'s slice as a ``pim-malloc-trace/v1`` tape.

        Tenant stickiness guarantees every pointer ref in a core's slice
        points at a slot of the same core, so the slice is a closed,
        self-contained workload: replaying it through
        `repro.workloads.replay` reproduces this core's serve responses
        bitwise (pinned in tests/test_fleet_serve.py and
        tests/test_serve_decode.py).
        """
        R, C, T = plan.shape
        cap = R * C * T
        base = (rank * C + core) * T
        refs = plan.ptr_ref[:, rank, core, :]
        m = refs >= 0
        in_round = refs % cap
        if m.any() and not ((in_round[m] >= base)
                            & (in_round[m] < base + T)).all():
            raise ValueError("cross-core pointer ref: slice is not closed")
        new_ref = np.where(m, (refs // cap) * T + (in_round - base), -1)
        return Trace(
            name=name or f"serve_{plan.placement}_r{rank}c{core}",
            heap_bytes=self.cfg.heap_bytes, num_threads=T,
            recorded_kind=self.cfg.kind,
            description=description or
            f"serve session slice rank={rank} core={core} "
            f"placement={plan.placement}",
            op=plan.op[:, rank, core, :].astype(np.int32),
            size=plan.size[:, rank, core, :].astype(np.int32),
            ptr_ref=new_ref.astype(np.int32),
            ptr_raw=plan.ptr_raw[:, rank, core, :].astype(np.int32),
            meta=meta or {"placement": plan.placement, "rank": rank,
                          "core": core})
