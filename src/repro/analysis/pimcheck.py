"""pimcheck: static verifier for the allocator backends + tape linter.

Traces every registered backend step (`heap.REGISTRY`) with
`jax.make_jaxpr` — at the single-core tier, the vmapped multi-core tier,
and the shard_map-body fleet tier — and runs the checker passes from
`repro.analysis.passes` over the closed jaxprs. Also lints trace tapes
(`workloads.trace.trace_lint`) and self-tests the passes against the
seeded-bug fixtures.

CLI (the CI `analysis` lane):

    python -m repro.analysis.pimcheck --all-kinds --tapes
    python -m repro.analysis.pimcheck --fixtures
    python -m repro.analysis.pimcheck --kinds hwsw,pallas --tiers single

Exit code is non-zero on any unsuppressed finding, tape-lint error, or
fixture the passes fail to flag. Findings are printed per target and,
when `$GITHUB_STEP_SUMMARY` is set, appended there as a markdown table
(same convention as `benchmarks/perf_gate.py`).
"""
from __future__ import annotations

import argparse
import functools
import glob
import json
import os
import sys

import jax
import jax.numpy as jnp

from repro.core import heap, system as sysm
from repro.workloads.trace import Trace, trace_lint

from .fixtures import FIXTURES, fix_init, fix_request
from .passes import PASS_NAMES, TracedStep, run_passes

TIERS = ("single", "vmap", "sharded")
DEFAULT_TAPES = os.path.join(
    os.path.dirname(__file__), os.pardir, os.pardir, os.pardir,
    "benchmarks", "tapes", "*.json")


def _mixed_request(num_threads: int) -> heap.AllocRequest:
    """A representative round exercising every op class, so tracing
    covers the malloc, free, realloc and calloc paths at once."""
    ops = [heap.OP_MALLOC, heap.OP_FREE, heap.OP_REALLOC, heap.OP_CALLOC,
           heap.OP_NOOP]
    mk = [64, 0, 256, 16, 0]
    pt = [-1, 4096, 8192, -1, -1]
    reps = (num_threads + len(ops) - 1) // len(ops)
    return heap.AllocRequest(
        op=jnp.array((ops * reps)[:num_threads], jnp.int32),
        size=jnp.array((mk * reps)[:num_threads], jnp.int32),
        ptr=jnp.array((pt * reps)[:num_threads], jnp.int32))


def _traced(fn, args, target, tier) -> TracedStep:
    out_shape = jax.eval_shape(fn, *args)
    closed = jax.make_jaxpr(fn)(*args)
    return TracedStep(
        target=target, tier=tier, closed_jaxpr=closed,
        n_state_in=len(jax.tree.leaves(args[0])),
        n_state_out=len(jax.tree.leaves(out_shape[0])))


def trace_kind(kind: str, tier: str = "single", heap_bytes: int = 1 << 18,
               num_threads: int = 4) -> TracedStep:
    """Trace one backend step at one deployment tier."""
    cfg = sysm.SystemConfig(kind=kind, heap_bytes=heap_bytes,
                            num_threads=num_threads)
    req = _mixed_request(num_threads)
    if tier == "single":
        fn = functools.partial(heap.step, cfg)
        args = (heap.init(cfg), req)
    elif tier == "vmap":
        fn = functools.partial(heap.multicore_step, cfg)
        args = (heap.multicore_init(cfg, 2),
                jax.tree.map(lambda x: jnp.stack([x, x]), req))
    elif tier == "sharded":
        # the shard_map body of a fleet round: vmap over ranks of the
        # multi-core step (heap.sharded_step)
        fn = functools.partial(heap.sharded_step, cfg)
        args = (heap.sharded_init(cfg, 2, 2),
                jax.tree.map(lambda x: jnp.stack([jnp.stack([x, x])] * 2),
                             req))
    else:
        raise ValueError(f"unknown tier {tier!r} (want one of {TIERS})")
    return _traced(fn, args, kind, tier)


def trace_fixture(name: str) -> TracedStep:
    fn, _expect = FIXTURES[name]
    return _traced(fn, (fix_init(), fix_request()), f"fixture:{name}",
                   "single")


def check_kinds(kinds, tiers, passes=None, heap_bytes=1 << 18,
                num_threads=4):
    """Run the passes over (kind, tier) pairs; returns (rows, active,
    suppressed) where rows summarize per-target results."""
    rows, active, suppressed = [], [], []
    for kind in kinds:
        for tier in tiers:
            tr = trace_kind(kind, tier, heap_bytes, num_threads)
            act, sup = run_passes(tr, passes)
            active.extend(act)
            suppressed.extend(sup)
            rows.append({
                "target": kind, "tier": tier,
                "eqns": len(tr.jaxpr.eqns),
                "findings": len(act), "suppressed": len(sup),
            })
    return rows, active, suppressed


def check_fixtures(passes=None):
    """Self-test: every seeded-bug fixture must be flagged by its pass.

    Returns (rows, failures) — a failure is a fixture the passes missed.
    """
    rows, failures = [], []
    for name, (_fn, expect_pass) in FIXTURES.items():
        tr = trace_fixture(name)
        act, _sup = run_passes(tr, passes)
        hit = [f for f in act if f.pass_name == expect_pass]
        if not hit:
            failures.append(f"fixture {name}: expected a {expect_pass} "
                            "finding, got "
                            f"{[f.pass_name for f in act] or 'none'}")
        rows.append({"target": f"fixture:{name}", "tier": "single",
                     "eqns": len(tr.jaxpr.eqns),
                     "findings": len(act),
                     "flagged_by_expected": bool(hit)})
    return rows, failures


def lint_tapes(paths):
    """trace_lint every tape; returns (rows, errors)."""
    rows, errors = [], []
    for path in paths:
        try:
            trace = Trace.load(path)
            errs = trace_lint(trace)
        except (ValueError, KeyError, OSError) as e:
            errs = [f"unreadable tape: {e}"]
            trace = None
        errors.extend(f"{os.path.basename(path)}: {e}" for e in errs)
        rows.append({"target": f"tape:{os.path.basename(path)}",
                     "tier": "-",
                     "rounds": trace.rounds if trace else 0,
                     "findings": len(errs)})
    return rows, errors


def _step_summary(rows, active, suppressed, tape_errors, fixture_failures):
    lines = ["## pimcheck", "",
             "| target | tier | findings | suppressed |",
             "|---|---|---:|---:|"]
    for r in rows:
        lines.append(f"| {r['target']} | {r['tier']} | {r['findings']} | "
                     f"{r.get('suppressed', 0)} |")
    lines.append("")
    for f in active:
        lines.append(f"- ❌ {f.fmt()}")
    for f, reason in suppressed:
        lines.append(f"- ⚠️ suppressed: {f.fmt()} — {reason}")
    for e in tape_errors:
        lines.append(f"- ❌ tape lint: {e}")
    for e in fixture_failures:
        lines.append(f"- ❌ {e}")
    if not (active or tape_errors or fixture_failures):
        lines.append("- ✅ all passes green")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="pimcheck", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--all-kinds", action="store_true",
                    help="verify every kind in heap.REGISTRY")
    ap.add_argument("--kinds", default=None,
                    help="comma-separated backend subset")
    ap.add_argument("--tiers", default=",".join(TIERS),
                    help=f"comma-separated tiers (default {','.join(TIERS)})")
    ap.add_argument("--passes", default=None,
                    help=f"comma-separated pass subset of {PASS_NAMES}")
    ap.add_argument("--tapes", nargs="*", default=None, metavar="PATH",
                    help="lint trace tapes (no paths: benchmarks/tapes/*)")
    ap.add_argument("--fixtures", action="store_true",
                    help="self-test the passes on the seeded-bug fixtures")
    ap.add_argument("--heap-bytes", type=int, default=1 << 18)
    ap.add_argument("--threads", type=int, default=4)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the full report as JSON")
    args = ap.parse_args(argv)

    kinds = ()
    if args.all_kinds:
        kinds = heap.kinds()
    elif args.kinds:
        kinds = tuple(args.kinds.split(","))
    tiers = tuple(args.tiers.split(","))
    passes = tuple(args.passes.split(",")) if args.passes else None

    rows, active, suppressed = check_kinds(
        kinds, tiers, passes, args.heap_bytes, args.threads)
    for f in active:
        print(f"FINDING {f.fmt()}")
    for f, reason in suppressed:
        print(f"suppressed {f.fmt()}\n  reason: {reason}")

    tape_rows, tape_errors = [], []
    if args.tapes is not None:
        paths = args.tapes or sorted(glob.glob(DEFAULT_TAPES))
        tape_rows, tape_errors = lint_tapes(paths)
        for e in tape_errors:
            print(f"TAPE LINT {e}")
    rows += tape_rows

    fixture_failures = []
    if args.fixtures:
        fx_rows, fixture_failures = check_fixtures(passes)
        rows += fx_rows
        for e in fixture_failures:
            print(f"FIXTURE MISS {e}")

    for r in rows:
        print(f"  {r['target']:<28} {r['tier']:<8} "
              f"findings={r['findings']} suppressed={r.get('suppressed', 0)}")

    report = {
        "rows": rows,
        "findings": [f.fmt() for f in active],
        "suppressed": [{"finding": f.fmt(), "reason": r}
                       for f, r in suppressed],
        "tape_errors": tape_errors,
        "fixture_failures": fixture_failures,
    }
    if args.json:
        with open(args.json, "w") as fp:
            json.dump(report, fp, indent=1)
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a") as fp:
            fp.write(_step_summary(rows, active, suppressed, tape_errors,
                                   fixture_failures))

    bad = len(active) + len(tape_errors) + len(fixture_failures)
    print(f"pimcheck: {len(rows)} target(s), {bad} failure(s), "
          f"{len(suppressed)} suppressed")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
