"""Jaxpr-walking utilities shared by the `pimcheck` passes.

Everything here operates on the closed jaxprs produced by
`jax.make_jaxpr` over a backend step: recursive equation iteration
through every higher-order primitive (scan / while / cond / pjit /
custom-derivative calls / pallas_call), producer maps, and small
provenance / taint dataflow helpers. The passes in
`repro.analysis.passes` are thin rule sets over these.
"""
from __future__ import annotations

from jax import core as jcore
try:  # jax >= 0.4.30 moved the jaxpr types
    from jax.extend import core as jexcore
    Jaxpr = jexcore.Jaxpr
    ClosedJaxpr = jexcore.ClosedJaxpr
    Var = jexcore.Var
    Literal = jexcore.Literal
except Exception:  # pragma: no cover - older jax layouts
    Jaxpr = jcore.Jaxpr
    ClosedJaxpr = jcore.ClosedJaxpr
    Var = jcore.Var
    Literal = jcore.Literal

# higher-order primitives whose sub-jaxprs are *serialized* per element —
# the scan carry makes iterations a mutex region, so intra-round
# cross-thread race analysis must not descend into them
SERIAL_PRIMS = ("scan", "while")


def _as_jaxpr(obj):
    if isinstance(obj, ClosedJaxpr):
        return obj.jaxpr
    if isinstance(obj, Jaxpr):
        return obj
    return None


def sub_jaxprs(eqn):
    """All sub-jaxprs of one equation, regardless of the primitive.

    Scans `eqn.params` generically: any value that is a (Closed)Jaxpr, or
    a tuple/list containing them (cond branches, custom-vjp pairs), is a
    sub-program. This stays correct as primitives evolve, instead of
    keying on a hard-coded param-name table.
    """
    subs = []
    for val in eqn.params.values():
        j = _as_jaxpr(val)
        if j is not None:
            subs.append(j)
        elif isinstance(val, (tuple, list)):
            for item in val:
                j = _as_jaxpr(item)
                if j is not None:
                    subs.append(j)
    return subs


def iter_eqns(jaxpr, path=(), descend=True, skip_prims=()):
    """Yield ``(eqn, path)`` for every equation, recursively.

    ``path`` is the tuple of enclosing primitive names (e.g.
    ``("scan", "cond")``); ``skip_prims`` prunes descent into the named
    higher-order primitives (their eqns are not yielded either).
    """
    j = _as_jaxpr(jaxpr)
    for eqn in j.eqns:
        name = eqn.primitive.name
        yield eqn, path
        if descend and name not in skip_prims:
            for sub in sub_jaxprs(eqn):
                yield from iter_eqns(sub, path + (name,),
                                     descend=descend, skip_prims=skip_prims)


def producers(jaxpr):
    """Map every output `Var` to the equation that produces it (one level,
    no descent — sub-jaxpr vars live in their own scope)."""
    out = {}
    for eqn in _as_jaxpr(jaxpr).eqns:
        for v in eqn.outvars:
            if isinstance(v, Var):
                out[v] = eqn
    return out


def forward_taint(jaxpr, seed_vars, kill_prims=(), kill_fn=None):
    """Forward may-taint dataflow at one jaxpr level.

    Starts from ``seed_vars`` and marks every value data-dependent on
    them. An equation whose primitive is in ``kill_prims`` (or for which
    ``kill_fn(eqn, tainted)`` is true) *bounds* its result — taint does
    not propagate through it (e.g. a gather from a constant size-class
    table yields a bounded value however wild the index was; the
    ``kill_fn`` receives the current tainted set so guards like
    ``where(valid, idx, 0)`` — a select with an untainted fallback
    branch — can be recognized).

    Higher-order equations propagate conservatively: any tainted input
    taints every output. Returns the set of tainted Vars.
    """
    tainted = set(v for v in seed_vars if isinstance(v, Var))
    for eqn in _as_jaxpr(jaxpr).eqns:
        if eqn.primitive.name in kill_prims:
            continue
        if kill_fn is not None and kill_fn(eqn, tainted):
            continue
        if any(isinstance(v, Var) and v in tainted for v in eqn.invars):
            tainted.update(v for v in eqn.outvars if isinstance(v, Var))
    return tainted


def derives_from(jaxpr, var, pred, prods=None, _seen=None):
    """True iff any equation in ``var``'s producer chain satisfies
    ``pred(eqn)`` (backward DFS at one jaxpr level; literals/invars end
    the walk)."""
    if prods is None:
        prods = producers(jaxpr)
    if _seen is None:
        _seen = set()
    if not isinstance(var, Var) or var in _seen:
        return False
    _seen.add(var)
    eqn = prods.get(var)
    if eqn is None:
        return False
    if pred(eqn):
        return True
    return any(derives_from(jaxpr, v, pred, prods, _seen)
               for v in eqn.invars)


def aval_sig(v):
    """(shape, dtype) signature of a var/aval, for donation matching."""
    aval = v.aval if hasattr(v, "aval") else v
    return (tuple(getattr(aval, "shape", ())),
            str(getattr(aval, "dtype", "abstract")))
