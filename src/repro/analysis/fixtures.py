"""Seeded-bug mini-backends: one deliberately broken step per pass.

Each fixture serves the (state, AllocRequest) -> (state, out) calling
convention of a real backend step, small enough to read in one screen,
and plants exactly the defect its pass exists to catch. `pimcheck
--fixtures` (and tests/test_analysis.py) asserts every fixture is
flagged by its `expect_pass` — the checker passes are themselves under
test, in both directions: real kinds green, planted bugs red.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.heap import AllocRequest

T = 4  # fixture thread count


class FixState(NamedTuple):
    table: jnp.ndarray   # int32[128] — a "size-class table"
    counts: jnp.ndarray  # int32[64]  — a "freelist occupancy" row


def fix_init() -> FixState:
    return FixState(table=jnp.arange(128, dtype=jnp.int32),
                    counts=jnp.zeros((64,), jnp.int32))


def fix_request() -> AllocRequest:
    return AllocRequest(op=jnp.ones((T,), jnp.int32),
                        size=jnp.array([16, 64, 256, 8192], jnp.int32),
                        ptr=jnp.array([-1, 32, 64, 4096], jnp.int32))


# --- int-width: pointer computed through float -----------------------------
def step_float_leak(st: FixState, req: AllocRequest):
    """BUG: scales the request size in float32 and converts the result
    back to an int32 pointer — bits above 2^24 are silently lost."""
    ptr = (req.size.astype(jnp.float32) * 1.5).astype(jnp.int32)
    return st, ptr


# --- index-bounds: raw request value used as a table index -----------------
def step_unclamped_index(st: FixState, req: AllocRequest):
    """BUG: indexes the class table directly with the request size (a
    PROMISE_IN_BOUNDS gather) — no clip/mod, so size=8192 reads past the
    128-entry table."""
    csize = st.table[req.size]
    return st, csize


# --- write-race: per-thread scatter keyed on the request pointer -----------
def step_aliased_scatter(st: FixState, req: AllocRequest):
    """BUG: every thread scatters its size into `counts[ptr]`: two
    threads carrying the same pointer write the same cell in one round,
    and the survivor is scatter-order-defined."""
    counts = st.counts.at[req.ptr].set(req.size)
    return FixState(table=st.table, counts=counts), counts[:T]


# --- donation: state buffer re-materialized from a constant ----------------
def step_dropped_donation(st: FixState, req: AllocRequest):
    """BUG: returns a freshly zeroed table instead of the (possibly
    updated) input buffer — the donated input is dropped and a new
    allocation is made every round."""
    counts = st.counts + jnp.sum(req.size)
    return FixState(table=jnp.zeros((128,), jnp.int32), counts=counts), counts[:T]


# name -> (step_fn, expected pass that must flag it)
FIXTURES = {
    "float_leak": (step_float_leak, "int-width"),
    "unclamped_index": (step_unclamped_index, "index-bounds"),
    "aliased_scatter": (step_aliased_scatter, "write-race"),
    "dropped_donation": (step_dropped_donation, "donation"),
}
