"""Static analysis for the allocator backends: `pimcheck` + tape lint.

Two pillars (see docs/analysis.md):

* `repro.analysis.pimcheck` — trace every registered backend step with
  `jax.make_jaxpr` (single / vmapped / sharded tiers) and run the
  checker passes in `repro.analysis.passes` over the closed jaxpr:
  donated-state discipline, integer-width safety, index-bound
  provability, and intra-round write-race detection. CLI:
  ``python -m repro.analysis.pimcheck --all-kinds --tapes``.

* the ``sanitizer`` backend (`repro.core.sanitizer`, registered in
  `heap.REGISTRY`) — an ASan-style shadow-heap design point that turns
  double-free / use-after-free / realloc-after-free into deterministic
  tagged reports; `sanitizer_report` re-exports its report renderer.

The same-round pointer-race tape rule lives in
`repro.workloads.trace.trace_lint` (shared with the recorder and the
replay checker); pimcheck's `--tapes` mode applies it to committed
tapes.
"""
from repro.core.sanitizer import report as sanitizer_report  # noqa: F401
from .passes import (ALL_PASSES, Finding, PASS_NAMES,  # noqa: F401
                     SUPPRESSIONS, TracedStep, run_passes)
from .pimcheck import (check_fixtures, check_kinds, lint_tapes,  # noqa: F401
                       trace_fixture, trace_kind)
