"""The `pimcheck` checker passes: rule sets over traced allocator jaxprs.

Each pass is a function ``(traced, ctx) -> [Finding]`` over a
`TracedStep` (the closed jaxpr of one backend step plus the state/request
calling convention). The rules are *calibrated against the real
backends*: every registered kind must trace green (or carry an explicit
entry in `SUPPRESSIONS` with a written justification), while the seeded
broken mini-backends in `repro.analysis.fixtures` must be flagged — both
directions are pinned by tests/test_analysis.py.

Passes
------
  donation     donated-state discipline: every state buffer threads
               in -> out with an unchanged (shape, dtype) multiset, and
               no large state leaf is silently re-materialized from a
               constant (a dropped donation turns an in-place update
               into a fresh allocation every round).
  int-width    pointer/size arithmetic stays 32-bit: no 64-bit values
               on the allocator path, no pointer/size routed through
               float and back (lossy above 2^24), and any product of two
               request-derived int32 values must be overflow-guarded by
               a division check (the `total_calloc_bytes` idiom).
  index-bounds every gather/scatter lowered with PROMISE_IN_BOUNDS must
               have index provenance passing through a bounding op
               (clip/min/max/mod/mask/bool-count...); `dynamic_slice`
               is hardware-clamped and always fine.
  write-race   intra-round thread-axis races: a top-level (outside the
               serialized scan mutex region) non-commutative scatter
               whose per-thread indices are request-derived and carry no
               structural disjointness witness (iota over the thread
               axis, or an argsort permutation) lets two threads write
               the same metadata address in one round — the UB class the
               trace linter excludes by construction.
"""
from __future__ import annotations

import dataclasses
import fnmatch

from . import jaxpr_utils as ju
from .jaxpr_utils import (Literal, Var, aval_sig, derives_from,
                          forward_taint, iter_eqns, producers)

PASS_NAMES = ("donation", "int-width", "index-bounds", "write-race")


@dataclasses.dataclass(frozen=True)
class Finding:
    pass_name: str
    target: str      # backend kind or fixture name
    tier: str        # single | vmap | sharded
    severity: str    # error | warn
    message: str

    def fmt(self) -> str:
        return (f"[{self.pass_name}] {self.target}/{self.tier} "
                f"{self.severity}: {self.message}")


# --------------------------------------------------------------------------
# suppressions: (pass, target glob, message substring, justification).
# A suppressed finding is reported but does not fail pimcheck. Every entry
# must say WHY the hazard is acceptable; docs/analysis.md documents the
# policy (prefer fixing the code or sharpening the pass — the calibration
# sweep for this file turned its one candidate entry, the masked
# `where(valid, idx, fallback)` scatter idiom, into a pass rule instead).
# --------------------------------------------------------------------------
SUPPRESSIONS = ()


def suppression_for(f: Finding):
    for pass_name, target_glob, substr, reason in SUPPRESSIONS:
        if (f.pass_name == pass_name
                and fnmatch.fnmatch(f.target, target_glob)
                and substr in f.message):
            return reason
    return None


@dataclasses.dataclass
class TracedStep:
    """One traced backend step + its calling convention, fed to passes."""

    target: str          # kind / fixture name
    tier: str            # single | vmap | sharded
    closed_jaxpr: object
    n_state_in: int      # leading invars that are donated state leaves
    n_state_out: int     # leading outvars that are next-round state leaves

    @property
    def jaxpr(self):
        return self.closed_jaxpr.jaxpr

    @property
    def state_invars(self):
        return self.jaxpr.invars[:self.n_state_in]

    @property
    def req_invars(self):
        return self.jaxpr.invars[self.n_state_in:]

    @property
    def state_outvars(self):
        return self.jaxpr.outvars[:self.n_state_out]


# --------------------------------------------------------------------------
# taint / guard vocabulary (calibrated on the real backends' jaxprs)
# --------------------------------------------------------------------------
# a result of these is bounded regardless of operand wildness
_BOUND_PRIMS = frozenset({
    "clamp", "min", "max", "rem", "and", "iota", "population_count",
    "shift_right_logical", "shift_right_arithmetic",
    "reduce_min", "reduce_max", "argmin", "argmax", "sort",
})
# jnp helpers that lower to pjit-wrapped sub-jaxprs; identified by name
_BOUND_PJIT_NAMES = frozenset({
    "clip", "_clip", "remainder", "mod", "argsort", "searchsorted",
})
_DISJOINT_PRIMS = frozenset({"iota"})
_DISJOINT_PJIT_NAMES = frozenset({"argsort"})  # permutations never collide


def _is_bounding(eqn, tainted) -> bool:
    name = eqn.primitive.name
    if name in _BOUND_PRIMS:
        return True
    if name == "pjit" and eqn.params.get("name") in _BOUND_PJIT_NAMES:
        return True
    if name == "convert_element_type":
        src = getattr(eqn.invars[0].aval, "dtype", None)
        if str(src) == "bool":   # {0, 1} however wild the inputs
            return True
    # the codebase's guard idiom: `where(valid, expr, fallback)` with an
    # untainted fallback bounds the result (a masked write / parked
    # index). JAX's negative-index normalization select —
    # select_n(idx < 0, idx, idx + N) — has BOTH branches tainted and is
    # deliberately NOT a guard.
    if (name == "select_n"
            or (name == "pjit" and eqn.params.get("name") == "_where")):
        data_ops = eqn.invars[1:]   # operand 0 is the predicate
        if any(isinstance(v, Literal) or v not in tainted
               for v in data_ops):
            return True
    # comparisons produce bools
    return name in ("lt", "le", "gt", "ge", "eq", "ne")


def _request_taint(tr: TracedStep):
    """Vars data-derived from the request operands with no bounding op in
    between (top jaxpr level; higher-order eqns propagate in -> out)."""
    return forward_taint(tr.jaxpr, tr.req_invars, kill_fn=_is_bounding)


def _disjoint_witness(jaxpr, var, prods) -> bool:
    return derives_from(
        jaxpr, var,
        lambda e: (e.primitive.name in _DISJOINT_PRIMS
                   or (e.primitive.name == "pjit"
                       and e.params.get("name") in _DISJOINT_PJIT_NAMES)),
        prods)


# --------------------------------------------------------------------------
# pass: donation
# --------------------------------------------------------------------------
_BIG_LEAF = 64  # elements; below this a copy is noise, not a donation bug


def check_donation(tr: TracedStep, _ctx=None):
    finds = []

    def f(sev, msg):
        finds.append(Finding("donation", tr.target, tr.tier, sev, msg))

    in_sigs = sorted(aval_sig(v) for v in tr.state_invars)
    out_sigs = sorted(aval_sig(v) for v in tr.state_outvars)
    if in_sigs != out_sigs:
        gone = [s for s in in_sigs if s not in out_sigs]
        new = [s for s in out_sigs if s not in in_sigs]
        f("error", "state buffer multiset changed across the round: "
          f"dropped {gone}, introduced {new} — donated buffers cannot be "
          "reused in place")

    prods = producers(tr.jaxpr)
    used = set()
    for eqn in tr.jaxpr.eqns:
        used.update(v for v in eqn.invars if isinstance(v, Var))
    out_set = set(v for v in tr.jaxpr.outvars if isinstance(v, Var))

    for i, v in enumerate(tr.state_outvars):
        if isinstance(v, Literal):
            f("error", f"state output leaf #{i} is a literal constant — "
              "the round discards this buffer entirely")
            continue
        if v in set(tr.jaxpr.invars):
            continue  # threaded through untouched: ideal donation
        eqn = prods.get(v)
        if eqn is None:
            continue
        size = 1
        for d in aval_sig(v)[0]:
            size *= d
        if size < _BIG_LEAF:
            continue
        if eqn.primitive.name == "broadcast_in_dim" and all(
                isinstance(iv, Literal) or prods.get(iv) is None
                for iv in eqn.invars):
            f("error", f"state output leaf #{i} {aval_sig(v)} is "
              "re-materialized from a constant broadcast — the donated "
              "input buffer is silently dropped and a fresh allocation "
              "is made every round")

    for i, v in enumerate(tr.state_invars):
        size = 1
        for d in aval_sig(v)[0]:
            size *= d
        if size >= _BIG_LEAF and v not in used and v not in out_set:
            f("warn", f"state input leaf #{i} {aval_sig(v)} is never read "
              "and never returned — dead donated buffer")
    return finds


# --------------------------------------------------------------------------
# pass: int-width
# --------------------------------------------------------------------------
def check_int_width(tr: TracedStep, _ctx=None):
    finds = []

    def f(sev, msg):
        finds.append(Finding("int-width", tr.target, tr.tier, sev, msg))

    for eqn, path in iter_eqns(tr.jaxpr):
        for v in list(eqn.invars) + list(eqn.outvars):
            dt = str(getattr(getattr(v, "aval", None), "dtype", ""))
            if dt in ("int64", "uint64", "float64"):
                f("error", f"64-bit value ({dt}) at `{eqn.primitive.name}` "
                  f"in {'/'.join(path) or 'top level'} — allocator "
                  "arithmetic must stay 32-bit")
                break

    # int -> float -> int roundtrip: pointers/sizes above 2^24 lose bits
    floaty = set()
    for eqn in tr.jaxpr.eqns:
        name = eqn.primitive.name
        if name == "convert_element_type":
            src = str(eqn.invars[0].aval.dtype)
            dst = str(eqn.params["new_dtype"])
            if src.startswith("int") and dst.startswith("float"):
                floaty.update(v for v in eqn.outvars if isinstance(v, Var))
                continue
            if (dst.startswith(("int", "uint"))
                    and src.startswith("float")
                    and any(isinstance(v, Var) and v in floaty
                            for v in eqn.invars)):
                f("error", "integer value routed through float and back "
                  "(int -> float -> int convert chain) — pointer/size "
                  "bits above 2^24 are lost")
                continue
        if any(isinstance(v, Var) and v in floaty for v in eqn.invars):
            floaty.update(v for v in eqn.outvars if isinstance(v, Var))

    # unguarded products of two request-derived int32s (calloc overflow
    # class): the result must feed a division check, as in
    # `pim_malloc.total_calloc_bytes` (wide = a*b; ok = wide // b == a)
    tainted = _request_taint(tr)
    div_guarded = set()
    for eqn in tr.jaxpr.eqns:
        name = eqn.primitive.name
        if name == "div" or (name == "pjit"
                             and eqn.params.get("name") == "floor_divide"):
            div_guarded.update(v for v in eqn.invars if isinstance(v, Var))
    for eqn in tr.jaxpr.eqns:
        if eqn.primitive.name != "mul":
            continue
        ins = [v for v in eqn.invars if isinstance(v, Var)]
        if len(ins) < 2 or not all(v in tainted for v in ins):
            continue
        if not str(eqn.outvars[0].aval.dtype).startswith("int"):
            continue
        if any(v in div_guarded for v in eqn.outvars):
            continue
        f("error", "int32 product of two request-derived values with no "
          "overflow guard — a division check on the product "
          "(total_calloc_bytes idiom) or a pre-clamp is required")
    return finds


# --------------------------------------------------------------------------
# pass: index-bounds
# --------------------------------------------------------------------------
def check_index_bounds(tr: TracedStep, _ctx=None):
    finds = []
    tainted = _request_taint(tr)
    unsafe = "PROMISE_IN_BOUNDS"
    for eqn in tr.jaxpr.eqns:  # top level: where request-driven indexing is
        name = eqn.primitive.name
        if not name.startswith(("gather", "scatter")):
            continue
        mode = str(eqn.params.get("mode"))
        if unsafe not in mode:
            continue  # FILL_OR_DROP / CLIP are safe by construction
        idx = eqn.invars[1]
        if not isinstance(idx, Var) or idx not in tainted:
            continue  # constant or bounded provenance
        finds.append(Finding(
            "index-bounds", tr.target, tr.tier, "error",
            f"`{name}` with mode PROMISE_IN_BOUNDS indexes "
            f"{aval_sig(eqn.invars[0])} with a request-derived index that "
            "has no bounding op (clip/min/max/mod/mask) in its provenance "
            "— out-of-bounds requests reach unchecked memory"))
    return finds


# --------------------------------------------------------------------------
# pass: write-race
# --------------------------------------------------------------------------
def check_write_race(tr: TracedStep, _ctx=None):
    finds = []
    tainted = _request_taint(tr)
    prods = producers(tr.jaxpr)
    # only the top level: eqns inside scan/while run in the serialized
    # mutex region (one thread per iteration) and cannot race
    for eqn, path in iter_eqns(tr.jaxpr, skip_prims=ju.SERIAL_PRIMS):
        if path:  # nested in pjit etc.: vars are scoped, skip
            continue
        if eqn.primitive.name != "scatter":   # scatter-add is commutative
            continue
        upd = eqn.invars[2]
        shape = aval_sig(upd)[0]
        if not shape or shape[0] < 2:
            continue  # a single update cannot self-race
        idx = eqn.invars[1]
        if not isinstance(idx, Var) or idx not in tainted:
            continue  # indices not request-controlled
        if _disjoint_witness(tr.jaxpr, idx, prods):
            continue  # iota / argsort permutation: provably distinct slots
        finds.append(Finding(
            "write-race", tr.target, tr.tier, "error",
            f"non-commutative `scatter` of {shape[0]} per-thread updates "
            f"into {aval_sig(eqn.invars[0])} with request-derived indices "
            "and no disjointness witness (iota/argsort) — two threads can "
            "write the same address in one round, and the winner is "
            "scatter-order-defined"))
    return finds


ALL_PASSES = {
    "donation": check_donation,
    "int-width": check_int_width,
    "index-bounds": check_index_bounds,
    "write-race": check_write_race,
}


def run_passes(tr: TracedStep, passes=None):
    """Run the selected passes; returns (active, suppressed) finding
    lists, where suppressed entries are (finding, justification)."""
    active, suppressed = [], []
    for name in (passes or PASS_NAMES):
        for f in ALL_PASSES[name](tr):
            reason = suppression_for(f)
            if reason is None:
                active.append(f)
            else:
                suppressed.append((f, reason))
    return active, suppressed
