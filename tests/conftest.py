"""Shared test plumbing: degrade hypothesis property tests to skips when
hypothesis is not installed, instead of failing collection of the whole file
(the non-property tests in the same modules still run).

Also pins the XLA CPU runtime for the whole suite: jaxlib 0.4.37's new
thunk-based CPU runtime leaks per-compilation state, and a full tier-1 run
eagerly compiles enough distinct programs (~300 tests x several backends)
that the process segfaults inside ``backend_compile`` around the 75% mark
— deterministically, but at whichever compile happens to cross the
threshold. The legacy runtime is stable at this volume. Must be set before
jax initializes its backends, hence conftest import time."""
import os

_xla_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_cpu_use_thunk_runtime" not in _xla_flags:
    os.environ["XLA_FLAGS"] = (
        _xla_flags + " --xla_cpu_use_thunk_runtime=false").strip()

import pytest  # noqa: E402


def hypothesis_or_skip():
    """Return (given, settings, strategies). Without hypothesis, `given`
    replaces the test with a skip and the strategy stubs accept any args."""
    try:
        from hypothesis import given, settings, strategies
        return given, settings, strategies
    except ImportError:
        class _AnyStrategy:
            def __getattr__(self, name):
                return lambda *a, **k: None

        def given(*a, **k):
            def deco(fn):
                @pytest.mark.skip(reason="hypothesis not installed")
                def skipped():
                    pass
                skipped.__name__ = fn.__name__
                return skipped
            return deco

        def settings(*a, **k):
            return lambda fn: fn

        return given, settings, _AnyStrategy()
