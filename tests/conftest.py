"""Shared test plumbing: degrade hypothesis property tests to skips when
hypothesis is not installed, instead of failing collection of the whole file
(the non-property tests in the same modules still run)."""
import pytest


def hypothesis_or_skip():
    """Return (given, settings, strategies). Without hypothesis, `given`
    replaces the test with a skip and the strategy stubs accept any args."""
    try:
        from hypothesis import given, settings, strategies
        return given, settings, strategies
    except ImportError:
        class _AnyStrategy:
            def __getattr__(self, name):
                return lambda *a, **k: None

        def given(*a, **k):
            def deco(fn):
                @pytest.mark.skip(reason="hypothesis not installed")
                def skipped():
                    pass
                skipped.__name__ = fn.__name__
                return skipped
            return deco

        def settings(*a, **k):
            return lambda fn: fn

        return given, settings, _AnyStrategy()
