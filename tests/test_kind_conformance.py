"""New-kind conformance: every `heap.REGISTRY` entry is pinned by
construction, not by copy-pasted per-kind tests.

Each test parametrizes over `heap.kinds()`, so registering a design point
(PR 9: ``arena`` / ``tlregion``; any future kind) automatically enrolls it
in the core contracts:

  * telemetry conservation after a mixed malloc/realloc/reset/free stream,
  * C-semantics edge cases (realloc(NULL, n) / realloc(p, 0) /
    realloc(NULL, 0) / negative sizes) served through the live heap,
  * tape-replay digest stability (same tape -> same digest, including
    through a JSON round-trip).

The arena kinds additionally pin their composability axis: the forwarded
backend is interchangeable (``arena_inner="hwsw"`` vs ``"pallas"``)
bitwise, reset rounds included.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import heap, system as sysm
from repro.core.api import HeapClient
from repro.core.heap import AllocRequest
from repro.workloads.replay import replay
from repro.workloads.trace import RecordingAllocator, Trace

T = 4
HEAP = 1 << 19
KINDS = tuple(heap.kinds())


def test_registry_and_kinds_agree():
    # system.KINDS orders for presentation; the membership must match the
    # registry exactly so nothing escapes the parametrized contracts
    assert set(sysm.KINDS) == set(KINDS)
    assert {"strawman", "sw", "hwsw", "pallas", "sanitizer", "arena",
            "tlregion"} <= set(KINDS)


# --------------------------------------------------------------------------
# telemetry conservation through a mixed stream (reset round included)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("kind", KINDS)
def test_conservation_through_mixed_rounds(kind):
    """live + buddy-free + frontend-cached == heap_bytes after every round
    of a stream that crosses size classes, the bypass range, a realloc
    round, and an epoch reset."""
    cl = HeapClient(heap_bytes=HEAP, num_threads=T, kind=kind)

    def residual():
        return cl.telemetry()["conservation_residual"]

    r0 = cl.malloc_batch(jnp.array([16, 100, 2048, 8192], jnp.int32))
    assert all(bool(x) for x in r0.ok)
    assert residual() == 0
    r1 = cl.realloc_batch(r0.ptr, jnp.array([300, 100, 0, 16384], jnp.int32))
    assert residual() == 0
    cl.epoch_reset()
    assert residual() == 0
    # post-reset traffic: only pointers produced after the reset (plus the
    # big bypass block, which survives it on every kind) are referenced —
    # the same well-formedness rule trace_lint enforces on tapes
    r3 = cl.malloc_batch(jnp.full((T,), 64, jnp.int32))
    assert all(bool(x) for x in r3.ok)
    assert residual() == 0
    cl.free_batch(r3.ptr)
    assert residual() == 0
    cl.free(int(r1.ptr[3]), thread=3)
    assert residual() == 0


# --------------------------------------------------------------------------
# C-semantics edges, served through the live heap
# --------------------------------------------------------------------------
@pytest.mark.parametrize("kind", KINDS)
def test_c_semantics_edges(kind):
    """One round exercising every realloc edge the builder normalizes:
    realloc(NULL, n) allocates, realloc(p, 0) frees, realloc(NULL, 0)
    idles, and a negative size fails while the old block stays live."""
    cl = HeapClient(heap_bytes=HEAP, num_threads=T, kind=kind)
    r0 = cl.malloc_batch(jnp.array([100, 100, 100, 8192], jnp.int32))
    assert all(bool(x) for x in r0.ok)
    ptrs = jnp.array([-1, int(r0.ptr[1]), -1, int(r0.ptr[3])], jnp.int32)
    sizes = jnp.array([64, 0, 0, -5], jnp.int32)
    r1 = cl.realloc_batch(ptrs, sizes)
    assert int(r1.ptr[0]) >= 0 and bool(r1.ok[0])     # realloc(NULL, n)
    assert int(r1.ptr[1]) == -1                        # realloc(p, 0) == free
    assert int(r1.path[2]) == -1                       # realloc(NULL, 0) idle
    assert int(r1.ptr[3]) == -1 and not bool(r1.ok[3])  # negative size fails
    assert int(r1.path[3]) == 3
    # the failed realloc kept thread 3's block live: freeing it succeeds
    r2 = cl.free_batch(jnp.array([-1, -1, -1, int(r0.ptr[3])], jnp.int32))
    assert bool(r2.ok[3])
    assert cl.telemetry()["conservation_residual"] == 0


# --------------------------------------------------------------------------
# tape replay digest stability
# --------------------------------------------------------------------------
def _small_tape() -> Trace:
    rec = RecordingAllocator(heap_bytes=HEAP, num_threads=T, kind="hwsw")
    r0 = rec.request(heap.malloc_request(
        jnp.array([16, 100, 2048, 8192], jnp.int32)))
    rec.request(heap.realloc_request(
        r0.ptr, jnp.array([300, 0, 64, 16384], jnp.int32)))
    rec.request(heap.epoch_reset_request(T))
    r3 = rec.request(heap.malloc_request(jnp.full((T,), 64, jnp.int32)))
    rec.request(heap.free_request(r3.ptr))
    return rec.finish("conformance", "unit")


@pytest.mark.parametrize("kind", KINDS)
def test_tape_replay_digest_stable(kind, tmp_path):
    """Replaying the same tape (reset round included) is deterministic per
    kind, and a JSON round-trip replays to the identical digest."""
    tr = _small_tape()
    _, _, a = replay(tr, kind)
    _, _, b = replay(tr, kind)
    assert a["digest_full"] == b["digest_full"]
    assert a["digest_sem"] == b["digest_sem"]
    p = str(tmp_path / "t.json")
    tr.save(p)
    _, _, c = replay(Trace.load(p), kind)
    assert c["digest_full"] == a["digest_full"]


# --------------------------------------------------------------------------
# arena composability: the forwarded backend is interchangeable bitwise
# --------------------------------------------------------------------------
def _closed_loop_stream(kind: str, inner: str, rounds: int = 24,
                        seed: int = 3):
    cfg = sysm.SystemConfig(kind=kind, heap_bytes=HEAP, num_threads=T,
                            arena_inner=inner)
    st = heap.init(cfg)
    rng = np.random.default_rng(seed)
    live = []
    resps = []
    for r in range(rounds):
        if r % 8 == 7:
            req = heap.epoch_reset_request(T)
            live.clear()          # reference nothing from before the reset
        else:
            op = rng.choice([1, 1, 2, 3, 4], size=T).astype(np.int32)
            size = rng.choice([16, 48, 200, 2048, 4096, 8192],
                              size=T).astype(np.int32)
            ptr = np.full(T, -1, np.int32)
            for t in range(T):
                if op[t] in (2, 3) and live:
                    ptr[t] = live.pop(int(rng.integers(len(live))))
                elif op[t] == 2:
                    op[t] = 0     # nothing to free: idle slot
            req = AllocRequest(op=jnp.asarray(op), size=jnp.asarray(size),
                               ptr=jnp.asarray(ptr))
        st, resp = heap.step(cfg, st, req)
        resps.append(resp)
        rp = np.asarray(resp.ptr)
        rok = np.asarray(resp.ok)
        ro = np.asarray(req.op)
        for t in range(T):
            if rok[t] and ro[t] in (1, 3, 4) and rp[t] >= 0:
                live.append(int(rp[t]))
    return resps


@pytest.mark.parametrize("kind", ("arena", "tlregion"))
def test_arena_inner_backend_parity(kind):
    """arena_inner='pallas' == arena_inner='hwsw' bitwise on a closed-loop
    mixed stream with reset rounds — the frontend/backend layering is a
    real seam, not a pair of entangled implementations."""
    a = _closed_loop_stream(kind, "hwsw")
    b = _closed_loop_stream(kind, "pallas")
    for r, (ra, rb) in enumerate(zip(a, b)):
        for f in ra._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(ra, f)), np.asarray(getattr(rb, f)),
                err_msg=f"round {r} field {f}")
