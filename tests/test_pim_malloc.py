"""Tests for the hierarchical PIM-malloc-SW allocator (thread cache + buddy)."""
import random

import jax
import jax.numpy as jnp
import pytest
from conftest import hypothesis_or_skip

given, settings, st = hypothesis_or_skip()

from repro.core import pim_malloc as pm
from repro.core.oracle import PyPimMalloc

CFG = pm.PimMallocConfig(heap_bytes=1 << 20, num_threads=4)


@pytest.fixture(scope="module")
def ops():
    return (
        jax.jit(lambda s, z: pm.malloc(CFG, s, z)),
        jax.jit(lambda s, p: pm.free(CFG, s, p)),
        jax.jit(lambda s: pm.gc(CFG, s)),
    )


def _assert_state_equal(st_, py, where=""):
    assert py.buddy.longest == [int(x) for x in st_.buddy.longest], where
    for t in range(CFG.num_threads):
        for c in range(CFG.nc):
            n = int(st_.counts[t][c])
            assert py.counts[t][c] == n, (where, t, c)
            assert py.stacks[t][c] == [int(x) for x in st_.stacks[t][c][:n]], (where, t, c)


def test_prepopulate_matches_paper():
    """init pre-carves one 4 KB block per freelist (paper Sec 4.1)."""
    st_ = pm.init(CFG)
    for t in range(CFG.num_threads):
        for c, csize in enumerate(CFG.size_classes):
            assert int(st_.counts[t][c]) == CFG.block_bytes // csize


def test_hit_is_frontend_path(ops):
    malloc, _, _ = ops
    st_ = pm.init(CFG)
    st_, ptrs, ev = malloc(st_, jnp.full((4,), 128, jnp.int32))
    assert all(int(p) == 0 for p in ev.path)  # all thread-cache hits
    assert all(int(p) >= 0 for p in ptrs)
    assert int(st_.stats.front_hits) == 4


def test_bypass_path(ops):
    malloc, free, _ = ops
    st_ = pm.init(CFG)
    st_, ptrs, ev = malloc(st_, jnp.full((4,), 8192, jnp.int32))
    assert all(int(p) == 2 for p in ev.path)  # all bypass
    assert all(int(x) % 8192 == 0 for x in ptrs)
    # ptr-only free works for bypass blocks
    st_, fev = free(st_, ptrs)
    assert all(int(p) == 1 for p in fev.path)


def test_miss_refills_from_buddy(ops):
    malloc, _, _ = ops
    st_ = pm.init(CFG)
    # 2048-class prepopulated with 2 sub-blocks; third alloc misses
    sizes = jnp.full((4,), 2048, jnp.int32)
    st_, _, ev0 = malloc(st_, sizes)
    st_, _, ev1 = malloc(st_, sizes)
    st_, ptrs, ev2 = malloc(st_, sizes)
    assert all(int(p) == 0 for p in ev1.path)
    assert all(int(p) == 1 for p in ev2.path)  # refill
    assert all(int(x) >= 0 for x in ptrs)


def test_backend_serialization_order(ops):
    malloc, _, _ = ops
    st_ = pm.init(CFG)
    st_, _, ev = malloc(st_, jnp.array([8192, 64, 16384, 4096], jnp.int32))
    # threads 0, 2, 3 bypass -> backend positions 0, 1, 2 in thread order
    assert [int(x) for x in ev.backend_pos] == [0, -1, 1, 2]


def test_gc_merges_full_blocks(ops):
    malloc, free, gc = ops
    st_ = pm.init(CFG)
    # exhaust + free the 1024-class, then gc twice
    st_, p1, _ = malloc(st_, jnp.full((4,), 1024, jnp.int32))
    st_, p2, _ = malloc(st_, jnp.full((4,), 1024, jnp.int32))
    st_, p3, _ = malloc(st_, jnp.full((4,), 1024, jnp.int32))
    for p in (p1, p2, p3):
        st_, _ = free(st_, p)
    st_ = gc(st_)
    st_ = gc(st_)
    assert int(st_.stats.gc_blocks) >= 4


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_property_matches_oracle(seed):
    cfg = pm.PimMallocConfig(heap_bytes=1 << 18, num_threads=4)
    st_ = pm.init(cfg)
    py = PyPimMalloc(heap_bytes=1 << 18, num_threads=4)
    jm = jax.jit(lambda s, z: pm.malloc(cfg, s, z))
    jf = jax.jit(lambda s, p: pm.free(cfg, s, p))
    jg = jax.jit(lambda s: pm.gc(cfg, s))
    rng = random.Random(seed)
    live = [[] for _ in range(4)]
    for i in range(30):
        op = rng.random()
        if op < 0.55:
            sizes = [rng.choice([16, 100, 256, 2048, 3000, 8192]) for _ in range(4)]
            st_, ptrs, ev = jm(st_, jnp.array(sizes, jnp.int32))
            pptrs, ppaths = py.malloc(sizes)
            assert [int(x) for x in ptrs] == pptrs, (seed, i)
            assert [int(x) for x in ev.path] == ppaths, (seed, i)
            for t in range(4):
                if pptrs[t] >= 0:
                    live[t].append(pptrs[t])
        elif op < 0.9:
            ptrs = [live[t].pop(rng.randrange(len(live[t])))
                    if live[t] and rng.random() < 0.8 else -1 for t in range(4)]
            st_, _ = jf(st_, jnp.array(ptrs, jnp.int32))
            py.free(ptrs)
        else:
            st_ = jg(st_)
            py.gc()
    _assert_state_equal(st_, py, f"seed={seed}")
    sd = {k: int(v) for k, v in st_.stats._asdict().items()}
    assert sd["dropped_frees"] == py.stats["dropped"]
    assert sd["gc_blocks"] == py.stats["gc_blocks"]


def test_no_overlap_across_threads(ops):
    """Live pointers from different threads never overlap (heap safety)."""
    malloc, free, _ = ops
    st_ = pm.init(CFG)
    rng = random.Random(3)
    live = []  # (ptr, rounded_size)
    for _ in range(25):
        sizes = [rng.choice([16, 64, 256, 2048, 8192]) for _ in range(4)]
        st_, ptrs, _ = malloc(st_, jnp.array(sizes, jnp.int32))
        for t in range(4):
            p = int(ptrs[t])
            if p >= 0:
                rs = max(1 << (sizes[t] - 1).bit_length(), 16)
                live.append((p, rs))
        ivs = sorted((p, p + s) for p, s in live)
        for (a0, a1), (b0, b1) in zip(ivs, ivs[1:]):
            assert a1 <= b0


def test_api_allocator_roundtrip():
    from repro.core.api import initAllocator

    a = initAllocator(1 << 18, num_threads=4)
    p1 = a.pimMalloc(100)
    p2 = a.pimMalloc(100)
    assert p1 >= 0 and p2 >= 0 and p1 != p2
    a.pimFree(p1)
    a.pimFree(p2)
    assert a.stats["front_hits"] == 2
    assert a.stats["frees_small"] == 2
