"""Dry-run machinery test on a small faked-device mesh (subprocess so the
XLA device-count flag never leaks into other tests)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec
from repro import configs
from repro.launch.steps import make_train_step, opt_state_sds
from repro.launch import hlo_analysis
from repro.models import registry
from repro.models.config import ShapeConfig
from repro.optim.adamw import AdamWConfig, AdamWState
from repro.parallel import sharding

cfg = configs.get("granite_3_8b").reduced()
import dataclasses
cfg = dataclasses.replace(cfg, dtype="bfloat16")
mesh = jax.make_mesh((4, 2), ("data", "model"))
p_sds = registry.param_sds(cfg)
p_spec = sharding.param_specs(mesh, p_sds, fsdp=True)
opt_cfg = AdamWConfig()
o_sds = opt_state_sds(cfg, opt_cfg)
o_spec = AdamWState(count=PartitionSpec(), m=p_spec, v=p_spec)
shape = ShapeConfig("t", 64, 8, "train")
b_sds = registry.train_specs(cfg, shape)
b_spec = sharding.batch_specs(mesh, b_sds)
step = make_train_step(cfg, opt_cfg, n_micro=2)
nm = lambda s: sharding.named(mesh, s)
with mesh:
    lowered = jax.jit(step, in_shardings=(nm(p_spec), nm(o_spec), nm(b_spec)),
                      out_shardings=(nm(p_spec), nm(o_spec), None)
                      ).lower(p_sds, o_sds, b_sds)
    compiled = lowered.compile()
res = hlo_analysis.analyze(compiled.as_text())
ca = hlo_analysis.cost_analysis_dict(compiled)
print(json.dumps({
    "flops_scaled": res["flops_scaled"],
    "flops_raw": float(ca["flops"]),
    "coll": res["collective_bytes_scaled"],
    "mem": res["memory_bytes_scaled"],
}))
"""


@pytest.mark.slow
def test_dryrun_small_mesh_compiles_and_analyzes():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # loop-scaled flops must exceed raw (while-once) flops: 2 layers x 2 micro
    assert res["flops_scaled"] > res["flops_raw"] * 1.5
    assert res["coll"] > 0           # grads reduce across the data axis
    assert res["mem"] > 0


def test_production_mesh_shapes():
    """make_production_mesh geometry (validated on fake devices)."""
    env = dict(os.environ, PYTHONPATH="src")
    script = (
        "import os; os.environ['XLA_FLAGS']="
        "'--xla_force_host_platform_device_count=512'\n"
        "from repro.launch.mesh import make_production_mesh\n"
        "m1 = make_production_mesh(); m2 = make_production_mesh(multi_pod=True)\n"
        "assert dict(m1.shape) == {'data': 16, 'model': 16}, m1.shape\n"
        "assert dict(m2.shape) == {'pod': 2, 'data': 16, 'model': 16}\n"
        "print('ok')\n")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ok" in out.stdout
