"""The CI perf-regression gate must catch injected regressions and tolerate
noise-level drift, missing rows, and new rows (see benchmarks/perf_gate.py)."""
import copy
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))
from benchmarks import perf_gate  # noqa: E402


def _doc(rows):
    return {
        "schema": "pim-malloc-bench/v1",
        "env": {"python": "3", "jax": "0", "backend": "cpu",
                "device_count": 1, "commit": "x", "smoke": True},
        "figs": {"fig14": {"status": "ok", "wall_s": 1.0, "records": [
            {"name": n, "us_per_call": v, "derived": ""}
            for n, v in rows.items()]}},
    }


def _write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


BASE = {"fig14/sw/size=32": 0.10, "fig14/hwsw/size=32": 0.08,
        "fig14/pallas/size=32": 0.08, "fig14/claim": 0.0}


def test_gate_passes_on_identical_doc(tmp_path):
    b = _write(tmp_path, "base.json", _doc(BASE))
    c = _write(tmp_path, "cur.json", _doc(BASE))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 0


def test_gate_fails_on_injected_regression(tmp_path):
    """Acceptance: an injected >20% us_per_call regression exits non-zero."""
    cur = dict(BASE)
    cur["fig14/hwsw/size=32"] = BASE["fig14/hwsw/size=32"] * 1.5  # +50%
    b = _write(tmp_path, "base.json", _doc(BASE))
    c = _write(tmp_path, "cur.json", _doc(cur))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 1


def test_gate_warns_but_passes_between_thresholds(tmp_path, capsys):
    cur = dict(BASE)
    cur["fig14/sw/size=32"] = BASE["fig14/sw/size=32"] * 1.10  # +10%
    b = _write(tmp_path, "base.json", _doc(BASE))
    c = _write(tmp_path, "cur.json", _doc(cur))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 0
    out = capsys.readouterr().out
    assert "Warnings" in out and "+10.0%" in out


def test_gate_fails_on_missing_tracked_row(tmp_path, capsys):
    """A tracked baseline row that disappears is a hard failure — silent
    coverage loss must refresh the committed baseline explicitly."""
    cur = dict(BASE)
    del cur["fig14/pallas/size=32"]                 # tracked row vanished
    b = _write(tmp_path, "base.json", _doc(BASE))
    c = _write(tmp_path, "cur.json", _doc(cur))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 1
    out = capsys.readouterr().out
    assert "disappeared" in out and "MISSING" in out


def test_gate_tolerates_new_rows(tmp_path, capsys):
    cur = dict(BASE)
    cur["fig14/newrow"] = 0.5                       # new row appeared
    b = _write(tmp_path, "base.json", _doc(BASE))
    c = _write(tmp_path, "cur.json", _doc(cur))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 0
    out = capsys.readouterr().out
    assert "newrow" in out


def test_gate_fails_when_current_figure_errored(tmp_path, capsys):
    """A figure that crashed in the current run must FAIL the gate — its
    tracked rows would otherwise degrade into 'missing' warnings."""
    cur_doc = _doc({})  # fig14 rows gone...
    cur_doc["figs"]["fig14"] = {"status": "error", "wall_s": 0.1,
                                "records": [], "error": "AssertionError: x"}
    b = _write(tmp_path, "base.json", _doc(BASE))
    c = _write(tmp_path, "cur.json", cur_doc)
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 1
    out = capsys.readouterr().out
    assert "errored in the current run" in out


def test_gate_ignores_zero_and_error_rows(tmp_path):
    """us_per_call == 0 rows (claims/summaries) and error figs are untracked."""
    base_doc = _doc(BASE)
    base_doc["figs"]["broken"] = {"status": "error", "wall_s": 0.0,
                                  "records": [{"name": "broken/r",
                                               "us_per_call": 1.0}]}
    cur = dict(BASE)
    cur["fig14/claim"] = 99.0  # zero-baseline row may change freely
    b = _write(tmp_path, "base.json", base_doc)
    c = _write(tmp_path, "cur.json", _doc(cur))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 0


def test_gate_writes_github_step_summary(tmp_path):
    cur = dict(BASE)
    cur["fig14/hwsw/size=32"] = 1.0
    b = _write(tmp_path, "base.json", _doc(BASE))
    c = _write(tmp_path, "cur.json", _doc(cur))
    summary = tmp_path / "summary.md"
    assert perf_gate.run_gate(c, b, 0.20, 0.05,
                              summary_path=str(summary)) == 1
    text = summary.read_text()
    assert "Perf gate FAILED" in text and "| row |" in text


def test_gate_new_untracked_rows_pass_with_notice(tmp_path, capsys):
    """Rows that exist only in the current run (e.g. a freshly added
    fleet_serve figure) must pass the gate and surface as a 'new' notice,
    never as failures."""
    cur = dict(BASE)
    cur["fleet_serve/sw/placement=round_robin"] = 1.23
    cur["fleet_serve/sw/placement=least_loaded"] = 1.11
    b = _write(tmp_path, "base.json", _doc(BASE))
    c = _write(tmp_path, "cur.json", _doc(cur))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 0
    out = capsys.readouterr().out
    assert "fleet_serve/sw/placement=round_robin" in out
    assert "new" in out and "FAIL" not in out


def test_gate_zero_metric_baseline_row_no_divide_by_zero(tmp_path, capsys):
    """A baseline row whose us_per_call is exactly 0.0 is untracked: the
    gate must neither divide by zero nor fail when the current value moves
    (summary/claim rows are free to change)."""
    base = dict(BASE)
    base["fig14/zero_row"] = 0.0
    cur = dict(base)
    cur["fig14/zero_row"] = 7.5            # any movement is fine
    b = _write(tmp_path, "base.json", _doc(base))
    c = _write(tmp_path, "cur.json", _doc(cur))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 0
    out = capsys.readouterr().out
    assert "ZeroDivisionError" not in out
    # zero-baseline rows are not in the tracked count
    assert "4 tracked rows" not in out.split("\n")[0]


def test_gate_zero_metric_current_row_is_improvement(tmp_path):
    """A tracked row dropping TO 0.0 (e.g. a path became free) is a -100%
    improvement, not an error."""
    cur = dict(BASE)
    cur["fig14/sw/size=32"] = 0.0
    b = _write(tmp_path, "base.json", _doc(BASE))
    c = _write(tmp_path, "cur.json", _doc(cur))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 0


def test_gate_rejects_wrong_schema(tmp_path):
    doc = _doc(BASE)
    bad = copy.deepcopy(doc)
    bad["schema"] = "other/v0"
    b = _write(tmp_path, "base.json", bad)
    c = _write(tmp_path, "cur.json", doc)
    with pytest.raises(SystemExit):
        perf_gate.run_gate(c, b, 0.20, 0.05)


def test_repo_baseline_is_schema_valid():
    """The committed BENCH_BASELINE.json must load and contain tracked rows."""
    root = os.path.join(os.path.dirname(__file__), os.pardir)
    path = os.path.join(root, "BENCH_BASELINE.json")
    rows = perf_gate.load_rows(path)
    tracked = [n for n, r in rows.items() if r.get("us_per_call", 0) > 0]
    assert len(tracked) >= 10
    # the baseline must cover the new backend axis
    assert any("pallas" in n for n in rows)


# ---------------------------------------------------------------------------
# wall-clock row family
# ---------------------------------------------------------------------------

ENV = "linux-x86_64-cpu-interpret"


def _wall_doc(modeled, wall, env_key=ENV):
    doc = _doc(modeled)
    doc["figs"]["fig14_wall"] = {"status": "ok", "wall_s": 1.0, "records": [
        {"name": n, "us_per_call": v, "derived": "", "lane": "wall",
         "env_key": env_key} for n, v in wall.items()]}
    return doc


WALL = {"fig14_wall/pallas/size=32/threads=16": 10.0,
        "fig14_wall/kernel_batch_speedup": 200.0}


def test_wall_rows_use_wall_thresholds_not_modeled(tmp_path):
    """A +40% wall drift passes (generous wall threshold) while the same
    +40% on a modeled row fails — the two families never share thresholds."""
    wall_cur = {n: v * 1.4 for n, v in WALL.items()}
    b = _write(tmp_path, "base.json", _wall_doc(BASE, WALL))
    c = _write(tmp_path, "cur.json", _wall_doc(BASE, wall_cur))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 0
    mod_cur = dict(BASE)
    mod_cur["fig14/hwsw/size=32"] = BASE["fig14/hwsw/size=32"] * 1.4
    c2 = _write(tmp_path, "cur2.json", _wall_doc(mod_cur, WALL))
    assert perf_gate.run_gate(c2, b, 0.20, 0.05) == 1


def test_injected_wall_regression_fails(tmp_path, capsys):
    """Acceptance: a wall regression past --fail-over-wall exits non-zero."""
    wall_cur = {n: v * 3.0 for n, v in WALL.items()}  # +200% > +150%
    b = _write(tmp_path, "base.json", _wall_doc(BASE, WALL))
    c = _write(tmp_path, "cur.json", _wall_doc(BASE, wall_cur))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 1
    out = capsys.readouterr().out
    assert "wall" in out and "FAIL" in out


def test_wall_rows_only_gated_against_same_env(tmp_path, capsys):
    """A wall baseline from a different runner class (env_key mismatch) is
    skipped informationally — compiled-device and CPU-interpret numbers
    must never cross-gate."""
    wall_cur = {n: v * 10.0 for n, v in WALL.items()}  # huge, but other env
    b = _write(tmp_path, "base.json", _wall_doc(BASE, WALL))
    c = _write(tmp_path, "cur.json",
               _wall_doc(BASE, wall_cur, env_key="linux-x86_64-tpu-compiled"))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 0
    assert "env-skip" in capsys.readouterr().out


def test_missing_wall_row_warns_not_fails(tmp_path, capsys):
    """A wall row absent from the current run is a warning — wall coverage
    loss must not hard-fail the way modeled coverage loss does."""
    b = _write(tmp_path, "base.json", _wall_doc(BASE, WALL))
    c = _write(tmp_path, "cur.json", _doc(BASE))  # no wall rows at all
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 0
    out = capsys.readouterr().out
    assert "wall row missing" in out and "no-wall" in out


def test_lane_filter_restricts_gate(tmp_path):
    """--lane wall ignores modeled rows entirely (a wall-only artifact must
    not trip 'tracked row disappeared'), and --lane modeled ignores wall."""
    wall_only = _wall_doc({}, WALL)
    del wall_only["figs"]["fig14"]
    b = _write(tmp_path, "base.json", _wall_doc(BASE, WALL))
    c = _write(tmp_path, "wall_only.json", wall_only)
    assert perf_gate.run_gate(c, b, 0.20, 0.05, lane="wall") == 0
    assert perf_gate.run_gate(c, b, 0.20, 0.05, lane="all") == 1
    mod_only = _write(tmp_path, "mod_only.json", _doc(BASE))
    assert perf_gate.run_gate(mod_only, b, 0.20, 0.05, lane="modeled") == 0


def test_delta_table_groups_by_lane_with_subtotals(tmp_path, capsys):
    """The delta table renders the modeled group first, then wall, each
    closed by a subtotal row (summed us, aggregate delta, verdict counts)."""
    b = _write(tmp_path, "base.json", _wall_doc(BASE, WALL))
    c = _write(tmp_path, "cur.json", _wall_doc(BASE, WALL))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 0
    out = capsys.readouterr().out
    assert "**modeled lane**" in out and "**wall lane**" in out
    assert "_modeled subtotal" in out and "_wall subtotal" in out
    assert out.index("**modeled lane**") < out.index("**wall lane**")
    assert "ok=3" in out          # three tracked modeled rows all ok


def test_custom_wall_threshold_cli(tmp_path):
    """--fail-over-wall from the CLI overrides the default wall threshold."""
    wall_cur = {n: v * 1.4 for n, v in WALL.items()}
    b = _write(tmp_path, "base.json", _wall_doc(BASE, WALL))
    c = _write(tmp_path, "cur.json", _wall_doc(BASE, wall_cur))
    assert perf_gate.main([c, "--baseline", b,
                           "--fail-over-wall", "0.30"]) == 1
    assert perf_gate.main([c, "--baseline", b,
                           "--fail-over-wall", "3.0"]) == 0


def test_repo_baseline_has_wall_speedup_row():
    """Acceptance: the committed baseline carries the >=2x batched-refill
    wall speedup row, env-keyed for the gate."""
    root = os.path.join(os.path.dirname(__file__), os.pardir)
    rows = perf_gate.load_rows(os.path.join(root, "BENCH_BASELINE.json"))
    rec = rows["fig14_wall/kernel_batch_speedup"]
    assert rec.get("lane") == "wall" and rec.get("env_key")
    assert float(rec["speedup_vs_serial"]) >= 2.0


# ---------------------------------------------------------------------------
# env_stamp dirty-check (benchmarks/run.py)
# ---------------------------------------------------------------------------

def _git(tmp, *args):
    import subprocess
    return subprocess.run(
        ["git", "-c", "user.email=t@t", "-c", "user.name=t", *args],
        cwd=tmp, capture_output=True, text=True, check=True)


def test_env_stamp_ignores_untracked_pycache(tmp_path):
    """A clean checkout with stray __pycache__ dirs must NOT stamp -dirty:
    the committed revision fully reproduces the rows."""
    from benchmarks import run as bench_run
    _git(tmp_path, "init", "-q")
    (tmp_path / "f.py").write_text("x = 1\n")
    _git(tmp_path, "add", "f.py")
    _git(tmp_path, "commit", "-qm", "init")
    cache = tmp_path / "__pycache__"
    cache.mkdir()
    (cache / "f.cpython-311.pyc").write_bytes(b"\x00")
    stamp = bench_run.env_stamp(True, root=str(tmp_path))
    assert not stamp["commit"].endswith("-dirty")
    # ... but a modified *tracked* file still must
    (tmp_path / "f.py").write_text("x = 2\n")
    stamp = bench_run.env_stamp(True, root=str(tmp_path))
    assert stamp["commit"].endswith("-dirty")
