"""The CI perf-regression gate must catch injected regressions and tolerate
noise-level drift, missing rows, and new rows (see benchmarks/perf_gate.py)."""
import copy
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))
from benchmarks import perf_gate  # noqa: E402


def _doc(rows):
    return {
        "schema": "pim-malloc-bench/v1",
        "env": {"python": "3", "jax": "0", "backend": "cpu",
                "device_count": 1, "commit": "x", "smoke": True},
        "figs": {"fig14": {"status": "ok", "wall_s": 1.0, "records": [
            {"name": n, "us_per_call": v, "derived": ""}
            for n, v in rows.items()]}},
    }


def _write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


BASE = {"fig14/sw/size=32": 0.10, "fig14/hwsw/size=32": 0.08,
        "fig14/pallas/size=32": 0.08, "fig14/claim": 0.0}


def test_gate_passes_on_identical_doc(tmp_path):
    b = _write(tmp_path, "base.json", _doc(BASE))
    c = _write(tmp_path, "cur.json", _doc(BASE))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 0


def test_gate_fails_on_injected_regression(tmp_path):
    """Acceptance: an injected >20% us_per_call regression exits non-zero."""
    cur = dict(BASE)
    cur["fig14/hwsw/size=32"] = BASE["fig14/hwsw/size=32"] * 1.5  # +50%
    b = _write(tmp_path, "base.json", _doc(BASE))
    c = _write(tmp_path, "cur.json", _doc(cur))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 1


def test_gate_warns_but_passes_between_thresholds(tmp_path, capsys):
    cur = dict(BASE)
    cur["fig14/sw/size=32"] = BASE["fig14/sw/size=32"] * 1.10  # +10%
    b = _write(tmp_path, "base.json", _doc(BASE))
    c = _write(tmp_path, "cur.json", _doc(cur))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 0
    out = capsys.readouterr().out
    assert "Warnings" in out and "+10.0%" in out


def test_gate_fails_on_missing_tracked_row(tmp_path, capsys):
    """A tracked baseline row that disappears is a hard failure — silent
    coverage loss must refresh the committed baseline explicitly."""
    cur = dict(BASE)
    del cur["fig14/pallas/size=32"]                 # tracked row vanished
    b = _write(tmp_path, "base.json", _doc(BASE))
    c = _write(tmp_path, "cur.json", _doc(cur))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 1
    out = capsys.readouterr().out
    assert "disappeared" in out and "MISSING" in out


def test_gate_tolerates_new_rows(tmp_path, capsys):
    cur = dict(BASE)
    cur["fig14/newrow"] = 0.5                       # new row appeared
    b = _write(tmp_path, "base.json", _doc(BASE))
    c = _write(tmp_path, "cur.json", _doc(cur))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 0
    out = capsys.readouterr().out
    assert "newrow" in out


def test_gate_fails_when_current_figure_errored(tmp_path, capsys):
    """A figure that crashed in the current run must FAIL the gate — its
    tracked rows would otherwise degrade into 'missing' warnings."""
    cur_doc = _doc({})  # fig14 rows gone...
    cur_doc["figs"]["fig14"] = {"status": "error", "wall_s": 0.1,
                                "records": [], "error": "AssertionError: x"}
    b = _write(tmp_path, "base.json", _doc(BASE))
    c = _write(tmp_path, "cur.json", cur_doc)
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 1
    out = capsys.readouterr().out
    assert "errored in the current run" in out


def test_gate_ignores_zero_and_error_rows(tmp_path):
    """us_per_call == 0 rows (claims/summaries) and error figs are untracked."""
    base_doc = _doc(BASE)
    base_doc["figs"]["broken"] = {"status": "error", "wall_s": 0.0,
                                  "records": [{"name": "broken/r",
                                               "us_per_call": 1.0}]}
    cur = dict(BASE)
    cur["fig14/claim"] = 99.0  # zero-baseline row may change freely
    b = _write(tmp_path, "base.json", base_doc)
    c = _write(tmp_path, "cur.json", _doc(cur))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 0


def test_gate_writes_github_step_summary(tmp_path):
    cur = dict(BASE)
    cur["fig14/hwsw/size=32"] = 1.0
    b = _write(tmp_path, "base.json", _doc(BASE))
    c = _write(tmp_path, "cur.json", _doc(cur))
    summary = tmp_path / "summary.md"
    assert perf_gate.run_gate(c, b, 0.20, 0.05,
                              summary_path=str(summary)) == 1
    text = summary.read_text()
    assert "Perf gate FAILED" in text and "| row |" in text


def test_gate_new_untracked_rows_pass_with_notice(tmp_path, capsys):
    """Rows that exist only in the current run (e.g. a freshly added
    fleet_serve figure) must pass the gate and surface as a 'new' notice,
    never as failures."""
    cur = dict(BASE)
    cur["fleet_serve/sw/placement=round_robin"] = 1.23
    cur["fleet_serve/sw/placement=least_loaded"] = 1.11
    b = _write(tmp_path, "base.json", _doc(BASE))
    c = _write(tmp_path, "cur.json", _doc(cur))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 0
    out = capsys.readouterr().out
    assert "fleet_serve/sw/placement=round_robin" in out
    assert "new" in out and "FAIL" not in out


def test_gate_zero_metric_baseline_row_no_divide_by_zero(tmp_path, capsys):
    """A baseline row whose us_per_call is exactly 0.0 is untracked: the
    gate must neither divide by zero nor fail when the current value moves
    (summary/claim rows are free to change)."""
    base = dict(BASE)
    base["fig14/zero_row"] = 0.0
    cur = dict(base)
    cur["fig14/zero_row"] = 7.5            # any movement is fine
    b = _write(tmp_path, "base.json", _doc(base))
    c = _write(tmp_path, "cur.json", _doc(cur))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 0
    out = capsys.readouterr().out
    assert "ZeroDivisionError" not in out
    # zero-baseline rows are not in the tracked count
    assert "4 tracked rows" not in out.split("\n")[0]


def test_gate_zero_metric_current_row_is_improvement(tmp_path):
    """A tracked row dropping TO 0.0 (e.g. a path became free) is a -100%
    improvement, not an error."""
    cur = dict(BASE)
    cur["fig14/sw/size=32"] = 0.0
    b = _write(tmp_path, "base.json", _doc(BASE))
    c = _write(tmp_path, "cur.json", _doc(cur))
    assert perf_gate.run_gate(c, b, 0.20, 0.05) == 0


def test_gate_rejects_wrong_schema(tmp_path):
    doc = _doc(BASE)
    bad = copy.deepcopy(doc)
    bad["schema"] = "other/v0"
    b = _write(tmp_path, "base.json", bad)
    c = _write(tmp_path, "cur.json", doc)
    with pytest.raises(SystemExit):
        perf_gate.run_gate(c, b, 0.20, 0.05)


def test_repo_baseline_is_schema_valid():
    """The committed BENCH_BASELINE.json must load and contain tracked rows."""
    root = os.path.join(os.path.dirname(__file__), os.pardir)
    path = os.path.join(root, "BENCH_BASELINE.json")
    rows = perf_gate.load_rows(path)
    tracked = [n for n, r in rows.items() if r.get("us_per_call", 0) > 0]
    assert len(tracked) >= 10
    # the baseline must cover the new backend axis
    assert any("pallas" in n for n in rows)
