"""Tests for metadata-cache simulators, cost model, design space, and the
composed system (strawman / PIM-malloc-SW / PIM-malloc-HW/SW)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import buddy_cache as bc
from repro.core import cost_model as cm
from repro.core import design_space as ds
from repro.core import system as sysm


# ---------------------------------------------------------------- buddy cache
def test_cam_lru_behavior():
    cfg = bc.BuddyCacheConfig(n_entries=2)
    st = bc.buddy_cache_init(cfg)
    acc = jax.jit(functools.partial(bc.buddy_cache_access, cfg))
    # words: nodes 0-15 -> word 0, 16-31 -> word 1, 32-47 -> word 2
    st, h, d = acc(st, jnp.int32(0))
    assert not bool(h) and int(d) == bc.WORD_BYTES
    st, h, _ = acc(st, jnp.int32(5))   # same word -> hit
    assert bool(h)
    st, h, _ = acc(st, jnp.int32(16))  # second entry
    assert not bool(h)
    st, h, _ = acc(st, jnp.int32(32))  # evicts LRU (word 0)
    assert not bool(h)
    st, h, _ = acc(st, jnp.int32(17))  # word 1 still resident
    assert bool(h)
    st, h, _ = acc(st, jnp.int32(1))   # word 0 was evicted
    assert not bool(h)


def test_cam_vs_python_lru():
    """Random trace: CAM sim matches a dict-based LRU reference."""
    import random

    cfg = bc.BuddyCacheConfig(n_entries=4)
    st = bc.buddy_cache_init(cfg)
    acc = jax.jit(functools.partial(bc.buddy_cache_access, cfg))
    lru, clock = {}, 0
    rng = random.Random(0)
    for _ in range(200):
        node = rng.randrange(0, 512)
        word = node // bc.NODES_PER_WORD
        st, h, _ = acc(st, jnp.int32(node))
        py_hit = word in lru
        assert bool(h) == py_hit, (node, word, lru)
        if not py_hit and len(lru) == 4:
            del lru[min(lru, key=lru.get)]
        lru[word] = clock
        clock += 1


def test_sw_buffer_direct_mapped():
    cfg = bc.SWBufferConfig(buf_bytes=128, line_bytes=64)  # 2 lines
    st = bc.sw_buffer_init(cfg)
    acc = jax.jit(functools.partial(bc.sw_buffer_access, cfg))
    st, h, d = acc(st, jnp.int32(0))       # line 0
    assert not bool(h) and int(d) == 64
    st, h, _ = acc(st, jnp.int32(100))     # word 6, line 0 -> hit
    assert bool(h)
    st, h, _ = acc(st, jnp.int32(300))     # word 18, line 1
    assert not bool(h)
    st, h, _ = acc(st, jnp.int32(1026))    # word 64, line 4 -> maps to slot 0, evict
    assert not bool(h)
    st, h, _ = acc(st, jnp.int32(0))       # line 0 was evicted
    assert not bool(h)


def test_invalid_nodes_skipped():
    cfg = bc.BuddyCacheConfig()
    st = bc.buddy_cache_init(cfg)
    traces = jnp.array([[-1, -1, 3, -1]], jnp.int32)
    st, stats = bc.simulate_traces(
        functools.partial(bc.buddy_cache_access, cfg), st, traces
    )
    assert int(stats.hits[0]) == 0 and int(stats.misses[0]) == 1


# ------------------------------------------------------------------ cost model
def test_queuing_latency():
    cost = cm.DPUCost()
    path = jnp.array([2, 0, 2, -1], jnp.int32)
    pos = jnp.array([0, -1, 1, -1], jnp.int32)
    svc = jnp.array([100.0, 0.0, 200.0, 0.0], jnp.float32)
    lat = cm.round_latency_cyc(cost, path, pos, svc)
    assert float(lat[0]) == 100.0            # first backend user: no wait
    assert float(lat[1]) == cost.cyc_front_hit
    assert float(lat[2]) == 100.0 + 200.0    # waits for user 0
    assert float(lat[3]) == 0.0


# ---------------------------------------------------------------- design space
def test_fig5_qualitative_shape():
    sweep = ds.sweep(n_cores_list=(1, 64, 512))
    red = sweep["pim_meta_pim_exec"]
    # winner: flat in N
    assert abs(red[512]["total"] - red[1]["total"]) / red[1]["total"] < 1e-6
    # all others grow with N and are worse at 512 cores
    for s in ds.STRATEGIES:
        if s == "pim_meta_pim_exec":
            continue
        assert sweep[s][512]["total"] > sweep[s][1]["total"]
        assert sweep[s][512]["total"] > red[512]["total"], s
    # metadata movers are transfer-dominated at 512 cores (Fig 5b)
    for s in ("host_meta_pim_exec", "pim_meta_host_exec"):
        assert sweep[s][512]["xfer"] > sweep[s][512]["exec"] * 0.5, s


# --------------------------------------------------------------------- system
@pytest.mark.parametrize("kind", sysm.KINDS)
def test_system_round_runs(kind):
    cfg = sysm.SystemConfig(kind=kind, heap_bytes=1 << 18, num_threads=4)
    st = sysm.system_init(cfg)
    st, ptrs, info = jax.jit(lambda s, z: sysm.malloc_round(cfg, s, z))(
        st, jnp.array([32, 256, 2048, 8192], jnp.int32)
    )
    assert all(int(p) >= 0 for p in ptrs)
    assert np.all(np.asarray(info.latency_cyc) >= 0)
    st, info_f = jax.jit(lambda s, p: sysm.free_round(cfg, s, p))(st, ptrs)
    assert np.all(np.asarray(info_f.latency_cyc) >= 0)


def test_hierarchy_beats_strawman_small_sizes():
    lat = {}
    for kind in ("strawman", "sw"):
        cfg = sysm.SystemConfig(kind=kind, heap_bytes=1 << 20, num_threads=4)
        st = sysm.system_init(cfg)
        sz = jnp.full((16, 4), 32, jnp.int32)
        st, ptrs, infos = jax.jit(
            lambda s, z: sysm.run_alloc_rounds(cfg, s, z)
        )(st, sz)
        lat[kind] = float(np.mean(np.asarray(infos.latency_cyc)))
    assert lat["strawman"] > 10 * lat["sw"]


def test_hwsw_reduces_dram_traffic():
    """Fig 16(c): fine-grained buddy cache moves fewer DRAM bytes than SW."""
    traffic = {}
    for kind in ("sw", "hwsw"):
        cfg = sysm.SystemConfig(kind=kind, heap_bytes=1 << 20, num_threads=4)
        st = sysm.system_init(cfg)
        sz = jnp.full((32, 4), 4096, jnp.int32)  # all backend ops
        st, ptrs, infos = jax.jit(
            lambda s, z: sysm.run_alloc_rounds(cfg, s, z)
        )(st, sz)
        traffic[kind] = int(np.sum(np.asarray(infos.dram_bytes)))
    assert traffic["hwsw"] < traffic["sw"]


def test_contention_fluctuation():
    """Fig 7: multi-thread straw-man latency fluctuates via busy-wait."""
    cfg = sysm.SystemConfig(kind="strawman", heap_bytes=1 << 20, num_threads=8)
    st = sysm.system_init(cfg)
    sz = jnp.full((8, 8), 256, jnp.int32)
    st, ptrs, infos = jax.jit(lambda s, z: sysm.run_alloc_rounds(cfg, s, z))(st, sz)
    lat = np.asarray(infos.latency_cyc)
    spread = lat.max(axis=1) / np.maximum(lat.min(axis=1), 1)
    assert spread.max() > 3  # later mutex waiters see multiples of the service time
