"""Protocol conformance for the unified heap API (repro.core.heap).

One protocol, three backends: `heap.step` must produce exactly the pointer
sequences of the legacy call paths (`pim_malloc.malloc/free`, the strawman
allocator, `system.malloc_round/free_round`) on a shared random op tape,
plus realloc/calloc semantics and multi-core vmap independence.
"""
import functools
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import heap
from repro.core import pim_malloc as pm
from repro.core import system as sysm

T = 4
HEAP = 1 << 18


def _cfg(kind):
    return sysm.SystemConfig(kind=kind, heap_bytes=HEAP, num_threads=T)


def _random_tape(seed, rounds=12):
    """Alternating malloc/free rounds with per-thread live-pointer tracking.

    Yields ("malloc", sizes) / ("free", idx) where idx picks from the live
    list; the driver substitutes actual pointers so all paths share the tape.
    """
    rng = random.Random(seed)
    tape = []
    for _ in range(rounds):
        if rng.random() < 0.6:
            tape.append(("malloc", [rng.choice([16, 100, 256, 2048, 3000, 8192])
                                    for _ in range(T)]))
        else:
            tape.append(("free", [rng.random() for _ in range(T)]))
    return tape


def _drive(tape, malloc_fn, free_fn):
    """Run a tape against (malloc_fn, free_fn); returns the ptr sequence."""
    live = [[] for _ in range(T)]
    seq = []
    for kind, arg in tape:
        if kind == "malloc":
            ptrs = malloc_fn(jnp.array(arg, jnp.int32))
            for t in range(T):
                if int(ptrs[t]) >= 0:
                    live[t].append(int(ptrs[t]))
            seq.extend(int(p) for p in ptrs)
        else:
            ptrs = [live[t].pop(int(r * len(live[t])))
                    if live[t] and r < 0.8 else -1 for t, r in zip(range(T), arg)]
            free_fn(jnp.array(ptrs, jnp.int32))
    return seq


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_step_matches_legacy_pim_malloc(seed):
    """sw protocol path == raw pim_malloc.malloc/free, pointer for pointer."""
    cfg = _cfg("sw")
    tape = _random_tape(seed)

    st_h = heap.init(cfg)
    step = jax.jit(functools.partial(heap.step, cfg))

    def h_malloc(sizes):
        nonlocal st_h
        st_h, resp = step(st_h, heap.malloc_request(sizes))
        return resp.ptr

    def h_free(ptrs):
        nonlocal st_h
        st_h, _ = step(st_h, heap.free_request(ptrs))

    st_l = pm.init(cfg.pm)

    def l_malloc(sizes):
        nonlocal st_l
        st_l, ptrs, _ = pm.malloc(cfg.pm, st_l, sizes)
        return ptrs

    def l_free(ptrs):
        nonlocal st_l
        st_l, _ = pm.free(cfg.pm, st_l, ptrs)

    assert _drive(tape, h_malloc, h_free) == _drive(tape, l_malloc, l_free)
    np.testing.assert_array_equal(np.asarray(st_h.alloc.buddy.longest),
                                  np.asarray(st_l.buddy.longest))


@pytest.mark.parametrize("seed", [0, 1])
def test_step_matches_legacy_strawman(seed):
    cfg = _cfg("strawman")
    tape = _random_tape(seed)

    st_h = heap.init(cfg)
    step = jax.jit(functools.partial(heap.step, cfg))

    def h_malloc(sizes):
        nonlocal st_h
        st_h, resp = step(st_h, heap.malloc_request(sizes))
        return resp.ptr

    def h_free(ptrs):
        nonlocal st_h
        st_h, _ = step(st_h, heap.free_request(ptrs))

    st_l = sysm.strawman_init(cfg.straw)

    def l_malloc(sizes):
        nonlocal st_l
        st_l, ptrs, _ = sysm.strawman_malloc(cfg.straw, st_l, sizes)
        return ptrs

    def l_free(ptrs):
        nonlocal st_l
        st_l, _ = sysm.strawman_free(cfg.straw, st_l, ptrs)

    assert _drive(tape, h_malloc, h_free) == _drive(tape, l_malloc, l_free)


@pytest.mark.parametrize("kind", sysm.KINDS)
def test_round_wrappers_are_the_protocol(kind):
    """malloc_round/free_round return the same ptrs+latency as raw heap.step."""
    cfg = _cfg(kind)
    sizes = jnp.array([32, 256, 2048, 8192], jnp.int32)
    st_a = heap.init(cfg)
    st_b = heap.init(cfg)
    st_a, ptrs_a, info = sysm.malloc_round(cfg, st_a, sizes)
    st_b, resp = heap.step(cfg, st_b, heap.malloc_request(sizes))
    np.testing.assert_array_equal(np.asarray(ptrs_a), np.asarray(resp.ptr))
    np.testing.assert_allclose(np.asarray(info.latency_cyc),
                               np.asarray(resp.latency_cyc))
    st_a, info_f = sysm.free_round(cfg, st_a, ptrs_a)
    st_b, resp_f = heap.step(cfg, st_b, heap.free_request(resp.ptr))
    np.testing.assert_allclose(np.asarray(info_f.latency_cyc),
                               np.asarray(resp_f.latency_cyc))


# ------------------------------------------------------------------- realloc
def test_realloc_in_place_same_class():
    cfg = _cfg("sw")
    st = heap.init(cfg)
    st, r0 = heap.step(cfg, st, heap.malloc_request(
        jnp.full((T,), 100, jnp.int32)))  # 128 B class
    st, r1 = heap.step(cfg, st, heap.realloc_request(
        r0.ptr, jnp.array([128, 65, 16, 1], jnp.int32)))  # grow/shrink in class
    # 128 and 65 round to the same 128 B class -> in place; 16 moves to the
    # 16 B class; 1 rounds up to the min class (16) -> also moves
    np.testing.assert_array_equal(np.asarray(r1.ptr[:2]), np.asarray(r0.ptr[:2]))
    assert not bool(r1.moved[0]) and not bool(r1.moved[1])
    assert bool(r1.moved[2]) and int(r1.ptr[2]) != int(r0.ptr[2])
    assert bool(r1.moved[3])
    assert all(bool(x) for x in r1.ok)


def test_realloc_move_frees_old_block():
    cfg = _cfg("sw")
    st = heap.init(cfg)
    st, r0 = heap.step(cfg, st, heap.malloc_request(
        jnp.full((T,), 100, jnp.int32)))
    st, r1 = heap.step(cfg, st, heap.realloc_request(
        r0.ptr, jnp.full((T,), 300, jnp.int32)))  # -> 512 B class, relocated
    assert all(bool(m) for m in r1.moved)
    # the vacated 128 B sub-blocks went back to each thread's freelist (LIFO):
    # the next 128 B malloc must hand the old pointers straight back
    st, r2 = heap.step(cfg, st, heap.malloc_request(
        jnp.full((T,), 128, jnp.int32)))
    np.testing.assert_array_equal(np.asarray(r2.ptr), np.asarray(r0.ptr))


def test_realloc_null_ptr_is_malloc_and_zero_size_is_free():
    cfg = _cfg("sw")
    st = heap.init(cfg)
    st, r0 = heap.step(cfg, st, heap.realloc_request(
        jnp.full((T,), -1, jnp.int32), jnp.full((T,), 64, jnp.int32)))
    assert all(int(p) >= 0 for p in r0.ptr)          # realloc(NULL, n) == malloc
    st, r1 = heap.step(cfg, st, heap.realloc_request(
        r0.ptr, jnp.zeros((T,), jnp.int32)))
    assert all(int(p) == -1 for p in r1.ptr)         # realloc(p, 0) == free
    st, r2 = heap.step(cfg, st, heap.malloc_request(
        jnp.full((T,), 64, jnp.int32)))
    np.testing.assert_array_equal(np.asarray(r2.ptr), np.asarray(r0.ptr))


def test_realloc_failure_keeps_old_block():
    cfg = _cfg("sw")
    st = heap.init(cfg)
    st, r0 = heap.step(cfg, st, heap.malloc_request(
        jnp.full((T,), 100, jnp.int32)))
    st, r1 = heap.step(cfg, st, heap.realloc_request(
        r0.ptr, jnp.full((T,), 2 * HEAP, jnp.int32)))  # cannot be satisfied
    assert all(int(p) == -1 for p in r1.ptr)
    assert not any(bool(x) for x in r1.ok)
    # old blocks still live: freeing them must succeed as small frees (path 0)
    st, r2 = heap.step(cfg, st, heap.free_request(r0.ptr))
    assert all(int(p) == 0 for p in r2.path)


def test_pim_malloc_realloc_pure_function():
    """The pim_malloc-level realloc mirrors the protocol semantics."""
    cfg = pm.PimMallocConfig(heap_bytes=HEAP, num_threads=T)
    st = pm.init(cfg)
    st, p0, _ = pm.malloc(cfg, st, jnp.full((T,), 100, jnp.int32))
    st, p1, ev = pm.realloc(cfg, st, p0, jnp.array([120, 300, 0, -1], jnp.int32))
    assert int(p1[0]) == int(p0[0]) and bool(ev.in_place[0])
    assert bool(ev.moved[1]) and int(p1[1]) != int(p0[1])
    assert int(ev.copy_bytes[1]) == 128                  # min(old 128, new 512)
    assert int(p1[2]) == -1 and int(p1[3]) == -1         # freed / no-op


@pytest.mark.parametrize("seed", [0, 1])
def test_pure_realloc_calloc_match_protocol(seed):
    """pim_malloc.realloc/calloc and the protocol REALLOC/CALLOC path are
    dual implementations of the same semantics — pin them pointer-equal."""
    rng = random.Random(seed)
    cfg = _cfg("sw")
    st_h = heap.init(cfg)
    st_p = pm.init(cfg.pm)
    st_h, r0 = heap.step(cfg, st_h, heap.malloc_request(
        jnp.full((T,), 100, jnp.int32)))
    st_p, p0, _ = pm.malloc(cfg.pm, st_p, jnp.full((T,), 100, jnp.int32))
    np.testing.assert_array_equal(np.asarray(r0.ptr), np.asarray(p0))
    live_h, live_p = r0.ptr, p0
    for _ in range(8):
        if rng.random() < 0.5:
            sizes = jnp.array([rng.choice([0, 16, 100, 300, 3000, 8192])
                               for _ in range(T)], jnp.int32)
            st_h, rh = heap.step(cfg, st_h,
                                 heap.realloc_request(live_h, sizes))
            st_p, pp, _ = pm.realloc(cfg.pm, st_p, live_p, sizes)
            np.testing.assert_array_equal(np.asarray(rh.ptr), np.asarray(pp))
            live_h, live_p = rh.ptr, pp
        else:
            n = jnp.array([rng.randint(0, 64) for _ in range(T)], jnp.int32)
            e = jnp.array([rng.choice([0, 16, 40]) for _ in range(T)], jnp.int32)
            st_h, rh = heap.step(cfg, st_h, heap.calloc_request(n, e))
            st_p, pp, _ = pm.calloc(cfg.pm, st_p, n, e)
            np.testing.assert_array_equal(np.asarray(rh.ptr), np.asarray(pp))
            st_h, _ = heap.step(cfg, st_h, heap.free_request(rh.ptr))
            st_p, _ = pm.free(cfg.pm, st_p, jnp.where(pp >= 0, pp, -1))
    np.testing.assert_array_equal(np.asarray(st_h.alloc.buddy.longest),
                                  np.asarray(st_p.buddy.longest))


# -------------------------------------------------------------------- calloc
def test_calloc_size_class_rounding():
    cfg = _cfg("sw")
    st = heap.init(cfg)
    st, r0 = heap.step(cfg, st, heap.calloc_request(
        jnp.array([3, 64, 1, 100], jnp.int32),
        jnp.array([40, 16, 100, 0], jnp.int32)))
    # 3*40=120 -> 128 class; 64*16=1024 -> 1024 class; 100 -> 128; n*0 -> noop
    assert [int(p) >= 0 for p in r0.ptr] == [True, True, True, False]
    # prove the classes via in-place realloc up to the rounded size
    st, r1 = heap.step(cfg, st, heap.realloc_request(
        r0.ptr, jnp.array([128, 1024, 128, 0], jnp.int32),
        active=jnp.array([True, True, True, False])))
    assert not any(bool(m) for m in r1.moved)
    np.testing.assert_array_equal(np.asarray(r1.ptr[:3]), np.asarray(r0.ptr[:3]))


def test_calloc_overflow_fails():
    cfg = _cfg("sw")
    st = heap.init(cfg)
    st, r = heap.step(cfg, st, heap.calloc_request(
        jnp.full((T,), 1 << 20, jnp.int32), jnp.full((T,), 1 << 20, jnp.int32)))
    assert all(int(p) == -1 for p in r.ptr)
    assert not any(bool(x) for x in r.ok)


# ------------------------------------------------------------ mixed-op rounds
@pytest.mark.parametrize("kind", sysm.KINDS)
def test_mixed_op_round(kind):
    cfg = _cfg(kind)
    st = heap.init(cfg)
    st, r0 = heap.step(cfg, st, heap.malloc_request(
        jnp.array([64, 256, 64, 0], jnp.int32),
        active=jnp.array([True, True, True, False])))
    req = heap.AllocRequest(
        op=jnp.array([heap.OP_REALLOC, heap.OP_FREE, heap.OP_NOOP,
                      heap.OP_MALLOC], jnp.int32),
        size=jnp.array([8192, 0, 0, 32], jnp.int32),
        ptr=jnp.array([int(r0.ptr[0]), int(r0.ptr[1]), -1, -1], jnp.int32))
    st, r1 = heap.step(cfg, st, req)
    assert bool(r1.moved[0]) and int(r1.ptr[0]) != int(r0.ptr[0])
    assert bool(r1.ok[1]) and int(r1.ptr[1]) == -1     # freed
    assert int(r1.path[2]) == -1                       # noop untouched
    assert int(r1.ptr[3]) >= 0                         # malloc served
    assert float(jnp.sum(r1.latency_cyc)) > 0


# ------------------------------------------------------- multi-core vmap/jit
def test_jit_vmap_step_with_realloc_compiles():
    """Acceptance: jit(vmap(step)) for 8 cores x 16 threads incl. reallocs."""
    C = 8
    cfg = sysm.SystemConfig(kind="sw", heap_bytes=1 << 20, num_threads=16)
    states = heap.multicore_init(cfg, C)
    vstep = jax.jit(jax.vmap(functools.partial(heap.step, cfg)))
    sizes = jnp.tile(jnp.array([16, 100, 256, 2048, 3000, 8192, 64, 64,
                                16, 100, 256, 2048, 3000, 8192, 64, 64],
                               jnp.int32)[None], (C, 1))
    states, r0 = vstep(states, jax.vmap(heap.malloc_request)(sizes))
    assert bool((r0.ptr >= 0).all())
    states, r1 = vstep(states, jax.vmap(heap.realloc_request)(
        r0.ptr, jnp.roll(sizes, 1, axis=1)))
    assert r1.ptr.shape == (C, 16)
    assert bool((r1.latency_cyc >= 0).all())


def test_multicore_independence():
    """Core i's requests never perturb core j's state."""
    C = 4
    cfg = sysm.SystemConfig(kind="sw", heap_bytes=1 << 18, num_threads=T)
    mch = heap.MultiCoreHeap(cfg, num_cores=C)
    baseline = jax.tree.map(lambda x: np.asarray(x), mch.state)

    # only core 0 allocates; cores 1..3 are all-NOOP
    sizes = jnp.zeros((C, T), jnp.int32).at[0].set(
        jnp.array([64, 8192, 2048, 16], jnp.int32))
    resp = mch.malloc(sizes)
    assert bool((resp.ptr[0] >= 0).all())
    assert bool((resp.ptr[1:] == -1).all())
    changed = jax.tree.map(
        lambda a, b: np.asarray([not np.array_equal(a[c], b[c])
                                 for c in range(C)]),
        baseline, mch.state)
    flags = np.stack(jax.tree.leaves(changed))       # [n_leaves, C]
    assert flags[:, 0].any()                         # core 0 state advanced
    assert not flags[:, 1:].any()                    # cores 1..3 untouched

    # symmetric tapes on all cores -> identical per-core pointer sequences
    mch2 = heap.MultiCoreHeap(cfg, num_cores=C)
    same = jnp.tile(jnp.array([16, 256, 2048, 8192], jnp.int32)[None], (C, 1))
    r = mch2.malloc(same)
    for c in range(1, C):
        np.testing.assert_array_equal(np.asarray(r.ptr[0]), np.asarray(r.ptr[c]))


# ------------------------------------------------------------------- facade
def test_table2_facade_roundtrip():
    from repro.core.api import initAllocator

    a = initAllocator(1 << 18, num_threads=T)
    p1 = a.pimMalloc(100)
    p2 = a.pimCalloc(16, 16)                # 256 B class
    assert p1 >= 0 and p2 >= 0 and p1 != p2
    p3 = a.pimRealloc(p1, 90)               # same class: in place
    assert p3 == p1
    p4 = a.pimRealloc(p1, 2048)             # bigger class: moves
    assert p4 >= 0 and p4 != p1
    a.pimFree(p2), a.pimFree(p4)
    st = a.stats
    assert st["front_hits"] >= 2 and st["frees_small"] >= 3
    assert a.last_info is not None and a.last_info.ptr.shape == (T,)


def test_registry_covers_all_kinds():
    assert set(heap.kinds()) == set(sysm.KINDS)


def test_multicore_per_core_active_mask():
    """A [C]-shaped active mask masks whole cores (not thread slots)."""
    C = 3
    cfg = sysm.SystemConfig(kind="sw", heap_bytes=1 << 18, num_threads=T)
    mch = heap.MultiCoreHeap(cfg, num_cores=C)
    sizes = jnp.full((C, T), 64, jnp.int32)
    resp = mch.malloc(sizes, active=jnp.array([True, False, False]))
    assert bool((resp.ptr[0] >= 0).all())
    assert bool((resp.ptr[1:] == -1).all())


# ----------------------------------------- C-semantics guards (bugfix pins)
def test_realloc_request_builder_normalizes_c_semantics():
    """realloc(NULL, n) -> MALLOC; realloc(p, 0) -> FREE;
    realloc(NULL, 0) -> NOOP; negative size -> failing INT32_MAX request."""
    req = heap.realloc_request(jnp.array([-1, 10, -1, 10], jnp.int32),
                               jnp.array([64, 0, 0, -5], jnp.int32))
    assert req.op.tolist() == [heap.OP_MALLOC, heap.OP_FREE, heap.OP_NOOP,
                               heap.OP_REALLOC]
    assert req.size.tolist()[3] == np.iinfo(np.int32).max
    assert req.ptr.tolist() == [-1, 10, -1, 10]


@pytest.mark.parametrize("kind", sysm.KINDS)
def test_realloc_negative_size_fails_and_keeps_old_block(kind):
    """A negative realloc size must FAIL (C size_t semantics), never free
    or shrink the live block — identical across all four KINDS."""
    cfg = _cfg(kind)
    st = heap.init(cfg)
    st, r0 = heap.step(cfg, st, heap.malloc_request(
        jnp.full((T,), 100, jnp.int32)))
    st, r1 = heap.step(cfg, st, heap.realloc_request(
        r0.ptr, jnp.full((T,), -3, jnp.int32)))
    assert all(int(p) == -1 for p in r1.ptr)
    assert not any(bool(x) for x in r1.ok)
    assert all(int(p) == 3 for p in r1.path)          # failing alloc path
    # old blocks stayed live: freeing them succeeds on every thread
    st, r2 = heap.step(cfg, st, heap.free_request(r0.ptr))
    assert all(bool(x) for x in r2.ok)


@pytest.mark.parametrize("kind", sysm.KINDS)
def test_invalid_frees_are_counted_dropped(kind):
    """free(-1) is benign (NULL); any other unserviceable free is path 2
    and (on pim kinds) lands in Stats.dropped_frees."""
    cfg = _cfg(kind)
    st = heap.init(cfg)
    st, r = heap.step(cfg, st, heap.free_request(
        jnp.array([-1, -9, 2 * HEAP, HEAP - 32], jnp.int32)))
    # NULL -> idle; garbage negative / out-of-heap / untracked -> dropped
    assert int(r.path[0]) == -1 and not bool(r.ok[0])
    assert [int(p) for p in r.path[1:]] == [2, 2, 2]
    assert not any(bool(x) for x in r.ok[1:])
    if kind != "strawman":
        assert int(st.alloc.stats.dropped_frees) == 3


def test_multicore_realloc_calloc_per_core_active_mask():
    """The realloc/calloc wrappers honor the same [C]-mask contract as
    malloc/free: a [C]-shaped mask selects whole cores, not thread slots."""
    C = 3
    cfg = sysm.SystemConfig(kind="sw", heap_bytes=1 << 18, num_threads=T)
    mch = heap.MultiCoreHeap(cfg, num_cores=C)
    r0 = mch.malloc(jnp.full((C, T), 100, jnp.int32))
    mask = jnp.array([True, False, False])
    r1 = mch.realloc(r0.ptr, jnp.full((C, T), 300, jnp.int32), active=mask)
    assert bool(r1.moved[0].all()) and bool((r1.ptr[0] >= 0).all())
    assert bool((r1.ptr[1:] == -1).all())
    r2 = mch.calloc(jnp.full((C, T), 4, jnp.int32),
                    jnp.full((C, T), 16, jnp.int32),
                    active=jnp.array([False, True, False]))
    assert bool((r2.ptr[1] >= 0).all())
    assert bool((r2.ptr[0] == -1).all()) and bool((r2.ptr[2] == -1).all())
    # masked cores kept their original blocks live
    r3 = mch.free(r0.ptr, active=~mask)
    assert bool(r3.ok[1:].all())


def test_sharded_realloc_calloc_rank_and_grid_masks():
    """ShardedHeap realloc/calloc accept [R]- and [R, C]-shaped masks
    (rank-level masks broadcast across the core axis)."""
    R, C = 2, 2
    cfg = sysm.SystemConfig(kind="sw", heap_bytes=1 << 18, num_threads=T)
    sh = heap.ShardedHeap(cfg, num_ranks=R, num_cores=C, mesh=False)
    r0 = sh.malloc(jnp.full((R, C, T), 64, jnp.int32))
    r1 = sh.realloc(r0.ptr, jnp.full((R, C, T), 2048, jnp.int32),
                    active=jnp.array([True, False]))          # [R] mask
    assert bool(r1.moved[0].all()) and bool((r1.ptr[1] == -1).all())
    r2 = sh.calloc(jnp.full((R, C, T), 8, jnp.int32),
                   jnp.full((R, C, T), 16, jnp.int32),
                   active=jnp.array([[True, False],
                                     [False, True]]))         # [R, C] mask
    ok = np.asarray(r2.ptr >= 0).all(axis=-1)
    np.testing.assert_array_equal(ok, [[True, False], [False, True]])


def test_request_builders_accept_batched_and_scalar_shapes():
    """Builders produce consistent pytree leaves on [R, C, T] batches and
    on broadcast scalar arguments (all leaves share one shape)."""
    sizes = jnp.full((2, 3, T), 64, jnp.int32)
    for req in (heap.malloc_request(sizes),
                heap.free_request(sizes),
                heap.realloc_request(sizes, sizes),
                heap.calloc_request(sizes, jnp.int32(16))):
        assert req.op.shape == req.size.shape == req.ptr.shape == (2, 3, T)
    req = heap.calloc_request(jnp.array([4] * T, jnp.int32), jnp.int32(16))
    assert req.op.shape == req.size.shape == req.ptr.shape == (T,)
