"""The allocation-trace workload engine: recorder, tapes, replay, parity.

Acceptance for the workloads subsystem: the three committed tapes replay
bitwise-deterministically on every registered backend, with sw/hwsw/pallas
agreeing on the semantic response stream and heap-telemetry conservation
holding on every kind; misuse (invalid frees) surfaces in the replayer's
report instead of vanishing.
"""
import glob
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import heap
from repro.workloads.hashtable import HashTableConfig, HashTableWorkload
from repro.workloads.replay import (check_trace, replay, replay_all_kinds)
from repro.workloads.trace import RecordingAllocator, Trace

TAPES_DIR = os.path.join(os.path.dirname(__file__), os.pardir,
                         "benchmarks", "tapes")
TAPES = sorted(glob.glob(os.path.join(TAPES_DIR, "*.json")))


# ------------------------------------------------------------- the recorder
def _tiny_recording(kind="hwsw"):
    rec = RecordingAllocator(heap_bytes=1 << 19, num_threads=4, kind=kind)
    r0 = rec.request(heap.malloc_request(
        jnp.array([16, 100, 2048, 8192], jnp.int32)))
    rec.request(heap.realloc_request(
        r0.ptr, jnp.array([300, 100, 0, 16384], jnp.int32)))
    rec.request(heap.free_request(
        jnp.array([-1, int(r0.ptr[1]), -1, -1], jnp.int32)))
    return rec, r0


def test_recorder_slot_refs_point_at_producers():
    rec, r0 = _tiny_recording()
    trace = rec.finish("tiny", "unit")
    T = 4
    # round 1 realloc'd round-0 pointers: refs name slot 0*T + t
    assert trace.ptr_ref[1, 0] == 0        # thread 0 realloc(ptr from r0)
    assert trace.ptr_ref[1, 2] == 2        # realloc(p, 0) == free ref
    assert trace.op[1, 2] == heap.OP_FREE  # builder normalized it
    # round 2 freed thread 1's ORIGINAL pointer (realloc was in-place for
    # t=1: same class) -> ref points at the round-1 realloc slot (latest
    # producer of that pointer value)
    assert trace.ptr_ref[2, 1] == 1 * T + 1
    # NULL frees carry no ref and stay NOOP
    assert trace.ptr_ref[2, 0] == -1 and trace.op[2, 0] == heap.OP_NOOP


def test_trace_json_roundtrip(tmp_path):
    rec, _ = _tiny_recording()
    trace = rec.finish("tiny", "unit", meta={"x": 1})
    p = str(tmp_path / "t.json")
    trace.save(p)
    back = Trace.load(p)
    # finish() stamps max_size_class so trace_lint's epoch rule knows the
    # small/big boundary without the recording config
    assert back.name == trace.name
    assert back.meta == {"x": 1, "max_size_class": 2048}
    for f in ("op", "size", "ptr_ref", "ptr_raw"):
        np.testing.assert_array_equal(getattr(back, f), getattr(trace, f))


def test_replay_reproduces_recording_bitwise():
    """Closed-loop replay on the recorded kind returns the recorded
    pointers (slot refs resolve to the same stream)."""
    rec, r0 = _tiny_recording()
    trace = rec.finish("tiny", "unit")
    resps, _, report = replay(trace, "hwsw")
    np.testing.assert_array_equal(np.asarray(resps.ptr[0]),
                                  np.asarray(r0.ptr))
    assert report["ops"] == trace.ops
    # determinism: an identical second replay gives an identical stream
    _, _, report2 = replay(trace, "hwsw")
    assert report2["digest_full"] == report["digest_full"]


# ------------------------------------------------- committed-tape acceptance
def test_committed_tapes_exist():
    assert len(TAPES) >= 4, TAPES
    names = {os.path.basename(p) for p in TAPES}
    assert {"graph_churn.json", "kv_paged.json", "hashtable.json",
            "decode_serve.json"} <= names


@pytest.mark.parametrize("path", TAPES, ids=os.path.basename)
def test_committed_tape_cross_backend_contract(path):
    """Acceptance: every backend replays the tape to its committed digest,
    pallas == hwsw bitwise, sw == hwsw on semantics, conservation holds."""
    trace = Trace.load(path)
    assert set(trace.expect) == set(heap.kinds())
    errs = check_trace(trace)
    assert errs == []


@pytest.mark.parametrize("path", TAPES, ids=os.path.basename)
def test_replay_reports_carry_telemetry(path):
    trace = Trace.load(path)
    _, _, rep = replay(trace, "sw")
    tel = rep["telemetry"]
    assert tel["conservation_residual"] == 0
    assert tel["hwm_bytes"] >= tel["live_bytes"] > 0
    assert 0.0 <= tel["utilization"] <= 1.0
    assert len(tel["free_blocks_per_level"]) >= 1
    assert rep["us_per_op"] > 0 and rep["dropped_frees"] == 0


# ------------------------------------------------------- misuse visibility
def test_replay_surfaces_invalid_frees():
    """A tape carrying garbage frees reports them as dropped on every kind
    (the free_request/-Stats.dropped_frees bugfix, end to end)."""
    rec = RecordingAllocator(heap_bytes=1 << 19, num_threads=4, kind="hwsw")
    r0 = rec.request(heap.malloc_request(jnp.full((4,), 64, jnp.int32)))
    rec.request(heap.free_request(r0.ptr))
    # garbage negative, out-of-heap, and an in-range pointer in a block no
    # allocator structure tracks (past the 32 prepopulated blocks); NULL (-1)
    # stays benign
    rec.request(heap.free_request(
        jnp.array([-7, 1 << 20, 500000, -1], jnp.int32)))
    trace = rec.finish("misuse", "unit")
    for kind in heap.kinds():
        _, _, rep = replay(trace, kind)
        assert rep["dropped_frees"] == 3, kind
        if kind != "strawman":
            assert rep["stats_dropped_frees"] == 3, kind


# ------------------------------------------------------ workload functional
def test_hashtable_workload_is_functionally_real():
    cfg = HashTableConfig(num_threads=8, heap_bytes=1 << 19, n_inserts=48,
                          delete_every=4, seed=5)
    rec = RecordingAllocator(heap_bytes=cfg.heap_bytes,
                             num_threads=cfg.num_threads, kind="sw")
    wl = HashTableWorkload(cfg, rec)
    stats = wl.run()
    wl.verify()
    assert stats["grow_rounds"] >= 1          # realloc pressure happened
    assert all(c > cfg.init_capacity for c in stats["capacities"])
    assert rec.recorded_rounds > 10
    # and the recorded tape replays with full parity
    trace = rec.finish("ht_unit", "unit")
    from repro.workloads.replay import attach_expectations
    attach_expectations(trace)
    assert check_trace(trace) == []


def test_kv_paged_pool_records_through_injection():
    from repro.kvcache.paged import PAGE_UNIT, PagePool

    rec = RecordingAllocator(heap_bytes=(1 << 16) * PAGE_UNIT,
                             num_threads=8, kind="hwsw")
    pool = PagePool(n_pages=1 << 16, num_threads=8, client=rec)
    ext = pool.alloc_pages(512)
    singles, _ = pool.alloc_page_batch([True] * 4 + [False] * 4)
    pool.free_page_batch(jnp.where(jnp.asarray(singles) >= 0,
                                   jnp.asarray(singles), -1))
    pool.free_extent(int(ext[0]))
    assert rec.recorded_rounds == 4
    trace = rec.finish("kv_unit", "unit")
    results = replay_all_kinds(trace, kinds=("hwsw", "pallas"))
    assert (results["hwsw"][1]["digest_full"]
            == results["pallas"][1]["digest_full"])


def test_kv_paged_pool_deprecated_alloc_hook_warns_but_works():
    """The PR-4 bare-handle hook keeps working through HeapClient.wrap,
    but only behind a DeprecationWarning; a handle that satisfies neither
    contract is rejected outright."""
    import pytest

    from repro.core.api import HeapClient
    from repro.kvcache.paged import PAGE_UNIT, PagePool

    rec = RecordingAllocator(heap_bytes=(1 << 16) * PAGE_UNIT,
                             num_threads=8, kind="hwsw")
    with pytest.warns(DeprecationWarning, match="client=HeapClient"):
        pool = PagePool(n_pages=1 << 16, num_threads=8, alloc=rec)
    assert pool.client is rec                 # a HeapClient passes through
    assert pool.alloc_pages(4).shape == (4,)  # and still serves pages

    # a zero-arg factory (the truly bare callable) adapts with the warning
    with pytest.warns(DeprecationWarning):
        pool2 = PagePool(
            n_pages=1 << 16, num_threads=8,
            alloc=lambda: HeapClient(heap_bytes=(1 << 16) * PAGE_UNIT,
                                     num_threads=8, kind="sw"))
    assert pool2.alloc_pages(2).shape == (2,)

    with pytest.raises(TypeError):
        HeapClient.wrap(object())


def test_deprecated_alloc_hooks_warn_exactly_once():
    """One deprecated ``alloc=`` construction emits exactly ONE
    DeprecationWarning — no duplicates from the wrap/adapter layers — for
    both remaining carriers of the hook (PagePool and DynamicGraph)."""
    import warnings

    from repro.core.api import HeapClient
    from repro.graphupd.workload import DynamicGraph, GraphConfig
    from repro.kvcache.paged import PAGE_UNIT, PagePool

    client = HeapClient(heap_bytes=(1 << 16) * PAGE_UNIT, num_threads=8,
                        kind="sw")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        PagePool(n_pages=1 << 16, num_threads=8, alloc=client)
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1, [str(x.message) for x in dep]

    gcfg = GraphConfig(n_nodes=8, n_edges_pre=0, n_edges_new=0,
                       num_threads=4, heap_bytes=1 << 19)
    gclient = HeapClient(heap_bytes=gcfg.heap_bytes, num_threads=4,
                         kind="sw")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        g = DynamicGraph(gcfg, alloc=gclient)
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1, [str(x.message) for x in dep]
    assert g.client is gclient

    # the supported client= path is warning-free
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        DynamicGraph(gcfg, client=gclient)
        PagePool(n_pages=1 << 16, num_threads=8, client=client)
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]


def test_graph_insert_delete_matches_reference():
    from repro.graphupd.workload import DynamicGraph, GraphConfig

    cfg = GraphConfig(n_nodes=24, n_edges_pre=0, n_edges_new=0,
                      num_threads=4, heap_bytes=1 << 19)
    g = DynamicGraph(cfg, kind="sw")
    edges = [(1, 2), (1, 3), (2, 3), (1, 4), (3, 1), (1, 2)]
    for i in range(0, len(edges), 4):
        batch = edges[i:i + 4]
        g.insert_round([u for u, _ in batch], [v for _, v in batch])
    assert g.neighbors(1) == [2, 4, 3, 2]     # LIFO adjacency
    resp = g.delete_round([1, 2], [3, 3])     # remove (1,3) and (2,3)
    assert int(resp.path[0]) == 0 and int(resp.path[1]) == 0  # small frees
    assert g.neighbors(1) == [2, 4, 2]
    assert g.neighbors(2) == []
    # deleting a non-existent edge frees nothing (NULL round slot)
    resp = g.delete_round([5], [9])
    assert int(resp.path[0]) == -1
    # the freed cells return LIFO on the next inserts
    before = int(g.state.alloc.stats.frees_small)
    assert before >= 2
