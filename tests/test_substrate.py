"""Substrate tests: optimizer, compression, data pipeline, checkpointing,
fault-tolerant runtime, sharding rules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import hypothesis_or_skip

given, settings, st = hypothesis_or_skip()

from repro.checkpoint import ckpt as ckpt_lib
from repro.data.pipeline import StreamConfig, TokenStream
from repro.optim import adamw, compression
from repro.optim.adamw import AdamWConfig
from repro.runtime import fault


# ------------------------------------------------------------------- optimizer
def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init(cfg, params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, state, m = adamw.update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adamw_clipping_and_schedule():
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=10, total_steps=100)
    params = {"w": jnp.zeros(4)}
    state = adamw.init(cfg, params)
    big = {"w": jnp.full(4, 1e6)}
    params, state, m = adamw.update(cfg, big, state, params)
    assert float(m["grad_norm"]) > 1e5
    assert float(m["lr"]) == pytest.approx(0.1, rel=1e-3)  # warmup step 1/10
    assert np.isfinite(np.asarray(params["w"])).all()


def test_adamw_bf16_moments():
    cfg = AdamWConfig(moment_dtype="bfloat16")
    params = {"w": jnp.ones((8, 8))}
    state = adamw.init(cfg, params)
    assert state.m["w"].dtype == jnp.bfloat16
    params, state, _ = adamw.update(cfg, {"w": jnp.ones((8, 8))}, state, params)
    assert state.v["w"].dtype == jnp.bfloat16


# ----------------------------------------------------------------- compression
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_quantize_roundtrip_error_bound(seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(300) * 10 ** rng.uniform(-3, 3))
    q, s, n = compression.quantize(x)
    y = compression.dequantize(q, s, n, x.shape)
    err = np.abs(np.asarray(x) - np.asarray(y))
    per_block_max = np.abs(np.asarray(x)).max()
    assert err.max() <= per_block_max / 127.0 + 1e-6


def test_error_feedback_accumulates():
    ef = compression.ef_init({"g": jnp.zeros(4)})
    g = {"g": jnp.array([1e-9, 1.0, -1.0, 0.5])}
    sent, ef = compression.ef_compress(ef, g)
    # residual carries the quantization error; next round re-injects it
    total_sent = np.asarray(sent["g"]) + np.asarray(ef.residual["g"])
    np.testing.assert_allclose(total_sent, np.asarray(g["g"]), rtol=1e-6)


def test_compressed_psum_matches_fp32():
    from jax.sharding import Mesh
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.asarray(np.random.RandomState(0).randn(1, 256), jnp.float32)

    def f(xs):
        return compression.compressed_psum(xs[0], "data")[None]

    y = shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P("data"))(x)
    np.testing.assert_allclose(np.asarray(y)[0], np.asarray(x)[0], atol=0.1,
                               rtol=0.02)


# ------------------------------------------------------------------------ data
def test_stream_deterministic_and_step_indexed():
    cfg = StreamConfig(vocab=100, seq_len=16, global_batch=4, seed=7)
    s1, s2 = TokenStream(cfg), TokenStream(cfg)
    b5a, b5b = s1.batch(5), s2.batch(5)
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    assert not np.array_equal(s1.batch(5)["tokens"], s1.batch(6)["tokens"])


# ------------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    ckpt_lib.save(tree, 3, str(tmp_path))
    assert ckpt_lib.latest_step(str(tmp_path)) == 3
    out = ckpt_lib.restore(tree, 3, str(tmp_path))
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_async_checkpointer(tmp_path):
    tree = {"w": jnp.ones((16, 16))}
    saver = ckpt_lib.AsyncCheckpointer(str(tmp_path))
    saver.save(tree, 1)
    saver.save(tree, 2)
    paths = saver.wait()
    assert len(paths) == 2
    assert ckpt_lib.latest_step(str(tmp_path)) == 2


def test_restore_with_resharding(tmp_path):
    """Elastic restore: same data re-placed under a new sharding/mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {"w": jnp.arange(8.0)}
    ckpt_lib.save(tree, 0, str(tmp_path))
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P(None))}
    out = ckpt_lib.restore(tree, 0, str(tmp_path), shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8.0))


# ----------------------------------------------------------------- fault loop
def test_recovery_resumes_from_checkpoint(tmp_path):
    calls = []

    def step_fn(state, batch, step):
        calls.append(step)
        return {"x": state["x"] + 1}, {}

    injector = fault.FailureInjector([7])
    cfg = fault.TrainLoopConfig(total_steps=12, ckpt_every=3,
                                ckpt_dir=str(tmp_path))
    state, hist = fault.run_with_recovery(
        cfg, init_state={"x": jnp.zeros(())}, step_fn=step_fn,
        make_batch=lambda s: None, injector=injector)
    assert hist["recoveries"] == 1
    # restored at step 6+1: steps 7..12 re-run; final x == completed steps
    assert float(state["x"]) == len(set(calls))
    assert sorted(set(calls)) == list(range(12))


def test_watchdog_flags_stragglers():
    wd = fault.StepWatchdog(factor=3.0)
    for _ in range(6):
        wd.observe(0, 0.1)
    assert wd.observe(6, 1.0)
    assert not wd.observe(7, 0.12)


# --------------------------------------------------------------------- sharding
def test_param_specs_divisibility():
    from jax.sharding import PartitionSpec as P

    from repro import configs
    from repro.models import registry
    from repro.parallel import sharding


    # qwen2: 60 experts not divisible by model axis in production; verify the
    # rule logic directly against a fake 16-way mesh via _maybe
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    fm = FakeMesh()
    assert sharding._maybe(fm, 64, "model") == "model"   # olmoe experts
    assert sharding._maybe(fm, 60, "model") is None      # qwen2 experts
    assert sharding._maybe(fm, 49408, "model") == "model"  # padded vocab

    spec = sharding._param_spec(fm, "we1", (24, 60, 2048, 1408), False)
    assert spec == P(None, None, None, "model")  # falls to expert-FF dim
    spec = sharding._param_spec(fm, "we1", (16, 64, 2048, 1024), False)
    assert spec == P(None, "model", None, None)  # true EP
    # 4D attention weights: heads shard when divisible, else REPLICATE
    # (never head_dim — contraction sharding regression, EXPERIMENTS SSPerf)
    spec = sharding._param_spec(fm, "wq", (40, 4096, 32, 128), False)
    assert spec == P(None, None, "model", None)
    spec = sharding._param_spec(fm, "wq", (12, 768, 12, 64), False)
    assert spec == P(None, None, None, None)


def test_batch_specs_b1_replicates():
    from repro.parallel import sharding

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    assert sharding._dp_if_div(FakeMesh(), 1) is None
    assert sharding._dp_if_div(FakeMesh(), 128) == ("data",)
