"""Heap-telemetry invariants: conservation, high-water mark, fragmentation.

The core property (ISSUE acceptance): after ANY request stream, on every
backend,

    live_bytes + buddy free bytes + cached thread-cache bytes == heap_bytes

with live_bytes/hwm advanced incrementally in `system._price_round` and the
other two terms recomputed independently from the metadata snapshot
(`repro.core.telemetry` / `buddy.free_bytes`).
"""
import random

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import hypothesis_or_skip

from repro.core import buddy, heap, system as sysm, telemetry

given, settings, st_ = hypothesis_or_skip()

T = 4
HEAP = 1 << 18


def _cfg(kind):
    return sysm.SystemConfig(kind=kind, heap_bytes=HEAP, num_threads=T)


def _drive_random_stream(kind, seed, rounds=10):
    """Random mixed-op rounds (incl. misuse-free streams); asserts the
    conservation law and hwm monotonicity after every round."""
    rng = random.Random(seed)
    cfg = _cfg(kind)
    st = heap.init(cfg)
    live = [[] for _ in range(T)]
    hwm_prev = 0
    for _ in range(rounds):
        roll = rng.random()
        if roll < 0.45:
            req = heap.malloc_request(jnp.array(
                [rng.choice([16, 100, 256, 2048, 3000, 8192])
                 for _ in range(T)], jnp.int32))
        elif roll < 0.7:
            ptrs = [live[t].pop(rng.randrange(len(live[t])))
                    if live[t] and rng.random() < 0.85 else -1
                    for t in range(T)]
            req = heap.free_request(jnp.array(ptrs, jnp.int32))
        elif roll < 0.9:
            ptrs = [live[t].pop(rng.randrange(len(live[t])))
                    if live[t] and rng.random() < 0.85 else -1
                    for t in range(T)]
            req = heap.realloc_request(
                jnp.array(ptrs, jnp.int32),
                jnp.array([rng.choice([0, 16, 100, 300, 3000, 8192])
                           for _ in range(T)], jnp.int32))
        else:
            req = heap.calloc_request(
                jnp.array([rng.randint(0, 64) for _ in range(T)], jnp.int32),
                jnp.array([rng.choice([0, 16, 40]) for _ in range(T)],
                          jnp.int32))
        st, resp = heap.step(cfg, st, req)
        for t in range(T):
            if int(resp.ptr[t]) >= 0:
                live[t].append(int(resp.ptr[t]))
        snap = telemetry.snapshot(cfg, st)
        assert snap["conservation_residual"] == 0, (kind, seed, snap)
        assert snap["hwm_bytes"] >= snap["live_bytes"]
        assert snap["hwm_bytes"] >= hwm_prev          # monotone
        hwm_prev = snap["hwm_bytes"]
        assert snap["free_bytes"] >= 0 and snap["cached_frontend_bytes"] >= 0
    return st, cfg


@pytest.mark.parametrize("kind", sysm.KINDS)
@pytest.mark.parametrize("seed", [0, 1])
def test_conservation_on_random_streams(kind, seed):
    _drive_random_stream(kind, seed)


@given(st_.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_prop_conservation_any_stream(seed):
    """Property: the telemetry invariant holds on arbitrary streams for the
    reference (sw) and kernel (pallas) backends alike."""
    _drive_random_stream("sw", seed, rounds=6)
    _drive_random_stream("pallas", seed, rounds=6)


def test_histogram_matches_buddy_free_bytes():
    """The per-level maximal-free histogram sums exactly to the buddy's
    independent free-bytes accounting, as fragmentation develops."""
    cfg = _cfg("sw")
    st = heap.init(cfg)
    bcfg = cfg.pm.buddy_cfg
    for sizes in ([8192] * T, [16384, 0, 8192, 0], [65536, 0, 0, 0]):
        st, resp = heap.step(cfg, st, heap.malloc_request(
            jnp.array(sizes, jnp.int32)))
        hist = telemetry.free_block_histogram(bcfg, st.alloc.buddy.longest)
        got = telemetry.free_bytes_from_histogram(bcfg, hist)
        want = int(buddy.free_bytes(bcfg, st.alloc.buddy))
        assert got == want
        # free half of what we just got -> holes -> histogram must follow
        st, _ = heap.step(cfg, st, heap.free_request(
            jnp.where(jnp.arange(T) % 2 == 0, resp.ptr, -1)))
        hist = telemetry.free_block_histogram(bcfg, st.alloc.buddy.longest)
        assert (telemetry.free_bytes_from_histogram(bcfg, hist)
                == int(buddy.free_bytes(bcfg, st.alloc.buddy)))


def test_pallas_telemetry_bitwise_equals_hwsw():
    cfg_p, cfg_h = _cfg("pallas"), _cfg("hwsw")
    sp, sh = heap.init(cfg_p), heap.init(cfg_h)
    reqs = [heap.malloc_request(jnp.array([16, 100, 3000, 8192], jnp.int32))]
    for req in reqs:
        sp, rp = heap.step(cfg_p, sp, req)
        sh, rh = heap.step(cfg_h, sh, req)
    sp, rp = heap.step(cfg_p, sp, heap.realloc_request(
        rp.ptr, jnp.array([300, 0, -4, 16384], jnp.int32)))
    sh, rh = heap.step(cfg_h, sh, heap.realloc_request(
        rh.ptr, jnp.array([300, 0, -4, 16384], jnp.int32)))
    assert int(sp.telem.live_bytes) == int(sh.telem.live_bytes)
    assert int(sp.telem.hwm_bytes) == int(sh.telem.hwm_bytes)


@pytest.mark.parametrize("kind", ["sw", "hwsw", "pallas"])
def test_conservation_when_moved_realloc_free_is_dropped(kind):
    """A moved realloc whose old-block free overflows a full freelist
    (dropped, path 2) leaks the block: live_bytes must keep it, or the
    conservation law breaks."""
    import repro.core.pim_malloc as pm
    pmc = pm.PimMallocConfig(heap_bytes=HEAP, num_threads=T,
                             size_classes=(512, 1024, 2048), cap=8)
    cfg = sysm.SystemConfig(kind=kind, heap_bytes=HEAP, num_threads=T,
                            pm=pmc)
    st = heap.init(cfg)
    # t0 and t1 each pop a 512 B sub-block (counts 7), then t0 pushes t1's
    # block back onto ITS OWN list -> t0's 512-class stack is full (cap=8)
    st, r0 = heap.step(cfg, st, heap.malloc_request(
        jnp.array([512, 512, 0, 0], jnp.int32)))
    st, _ = heap.step(cfg, st, heap.free_request(
        jnp.array([int(r0.ptr[1]), -1, -1, -1], jnp.int32)))
    # moved realloc of t0's block: the vacated 512 B free overflows -> drop
    dropped0 = int(st.alloc.stats.dropped_frees)
    st, r1 = heap.step(cfg, st, heap.realloc_request(
        r0.ptr, jnp.array([8192, 0, 0, 0], jnp.int32),
        active=jnp.array([True, False, False, False])))
    assert bool(r1.moved[0]) and int(r1.ptr[0]) >= 0
    assert int(st.alloc.stats.dropped_frees) == dropped0 + 1
    snap = telemetry.snapshot(cfg, st)
    assert snap["conservation_residual"] == 0, snap
    # the leaked 512 B stays live alongside the new 8 KB block
    assert snap["live_bytes"] >= 8192 + 512


def test_hwm_tracks_peak_not_current():
    cfg = _cfg("sw")
    st = heap.init(cfg)
    st, r = heap.step(cfg, st, heap.malloc_request(
        jnp.full((T,), 8192, jnp.int32)))
    peak = int(st.telem.live_bytes)
    st, _ = heap.step(cfg, st, heap.free_request(r.ptr))
    assert int(st.telem.live_bytes) == 0
    assert int(st.telem.hwm_bytes) == peak == 4 * 8192


def test_multicore_states_carry_independent_telemetry():
    cfg = _cfg("sw")
    mch = heap.MultiCoreHeap(cfg, num_cores=3)
    sizes = jnp.zeros((3, T), jnp.int32).at[0].set(
        jnp.full((T,), 2048, jnp.int32))
    mch.malloc(sizes)
    live = np.asarray(mch.state.telem.live_bytes)
    assert live.shape == (3,)
    assert live[0] == 4 * 2048 and (live[1:] == 0).all()
