"""DecodeServe (paged-KV LLM decode tier) + the PR-8 API-redesign pins.

The decode engine must couple serving truth with allocator truth: prefill
bursts take the buddy/bypass path while steady-state decode appends stay
on the PAGE_UNIT frontend, eviction edges (context exactly at a page
boundary, tenants that die mid-prefill) never allocate a page no token can
use, mesh and vmap drivers agree bitwise, and any core's slice exports as
a ``pim-malloc-trace/v1`` tape that replays bitwise on hwsw and pallas.
Alongside it, the redesign's single-source-of-truth pins: `system.KINDS`
derives from `heap.REGISTRY` (a freshly registered kind auto-enrolls), and
PagePool eviction routes every free through the protocol so a double evict
is a deterministic sanitizer ``double_free`` tag, not a silent success.
"""
import numpy as np
import pytest

from repro.core import heap, sanitizer, system as sysm
from repro.kvcache.paged import PAGE_UNIT, PagePool
from repro.launch.serve_decode import (DECODE_PAGE, EVICT_EXTENT, EVICT_PAGE,
                                       PREFILL, DecodeServe, DecodeTraffic,
                                       serve_decode_session)
from repro.workloads.replay import replay

T = 4
HEAP = 1 << 20
BYPASS_MIN = 2048 + 1   # smallest size that skips the frontend classes


def _cfg(kind="sw"):
    return sysm.SystemConfig(kind=kind, heap_bytes=HEAP, num_threads=T)


def _tc(**kw):
    base = dict(seed=0, rounds=32, session_rate=1.0, num_tenants=4,
                prompt_choices=(24, 48, 120, 3000),
                decode_choices=(0, 8, 24, 120), max_context=144,
                queue_cap=8)
    base.update(kw)
    return DecodeTraffic(**base)


def _own_size(plan):
    """Per dispatched op: its size cell in the grid."""
    rounds = plan.rounds
    return plan.size.reshape(rounds, -1)[plan.disp_round, plan.slot]


# --------------------------------------------------------------------------
# report schema + accounting balance
# --------------------------------------------------------------------------
def test_report_schema_and_balance():
    rep = serve_decode_session(_cfg(), 2, 2, traffic=_tc())
    required = {
        "shape", "rounds", "placement", "seed", "page_size",
        "capacity_per_round", "sessions_offered", "sessions_dropped",
        "session_drop_rate", "sessions_prefilled", "sessions_completed",
        "sessions_evicted_overflow", "sessions_active_end", "backlog_end",
        "queue_depth_mean", "queue_depth_max", "drops_per_round",
        "decode_tokens_per_round", "prefill_tokens", "decode_tokens",
        "tokens_total", "tokens_per_sec", "decode_stalls",
        "ttft_p50_cyc", "ttft_p95_cyc", "ttft_p99_cyc",
        "alloc_p50_cyc", "alloc_p95_cyc", "alloc_p99_cyc",
        "prefill_allocs", "decode_page_allocs", "evict_frees",
        "ops", "ok_ops", "failed_allocs", "dropped_frees",
        "live_bytes", "conservation_residual", "hwm_bytes_per_rank",
        "hwm_bytes_max", "external_frag_mean", "modeled_wall_us",
        "us_per_op", "ops_per_sec", "accounting",
    }
    missing = required - set(rep)
    assert not missing, missing
    # the allocator side must be healthy and the serving side consistent
    assert rep["conservation_residual"] == 0
    assert rep["failed_allocs"] == 0 and rep["dropped_frees"] == 0
    assert rep["tokens_total"] == rep["prefill_tokens"] + rep["decode_tokens"]
    assert rep["tokens_per_sec"] > 0 and rep["ttft_p50_cyc"] > 0
    assert rep["alloc_p99_cyc"] >= rep["alloc_p50_cyc"] > 0
    # session conservation: every ended session ran through prefill, and
    # prefilled <= admitted = offered - dropped
    ended = (rep["sessions_completed"] + rep["sessions_evicted_overflow"])
    assert ended + rep["sessions_active_end"] == rep["sessions_prefilled"]
    assert rep["sessions_prefilled"] <= rep["sessions_offered"] - \
        rep["sessions_dropped"]
    assert len(rep["hwm_bytes_per_rank"]) == 2
    assert rep["hwm_bytes_max"] == max(rep["hwm_bytes_per_rank"])
    assert sum(rep["decode_tokens_per_round"]) == rep["decode_tokens"]


def test_plan_is_seed_deterministic():
    eng = DecodeServe(_cfg(), 2, 2, traffic=_tc(seed=11))
    a, b = eng.plan(), eng.plan()
    for f in ("op", "size", "ptr_ref", "disp_round", "opkind", "session"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
    assert a.offered == b.offered and a.tenant_home == b.tenant_home


# --------------------------------------------------------------------------
# prefill bursts vs steady-state decode: op sizes AND backend paths differ
# --------------------------------------------------------------------------
def test_prefill_burst_vs_steady_state_paths():
    """Prefills malloc the whole prompt extent in one burst (long prompts
    through the buddy bypass), decode appends are single PAGE_UNIT pages
    that must stay on the thread-cache frontend (path hit/refill, never
    bypass)."""
    eng = DecodeServe(_cfg(), 2, 2, traffic=_tc())
    plan = eng.plan()
    _, resps = eng.run(plan)
    own_size = _own_size(plan)
    rounds = plan.rounds
    path = np.asarray(resps.path).reshape(rounds, -1)[plan.disp_round,
                                                      plan.slot]
    pre, dec = plan.opkind == PREFILL, plan.opkind == DECODE_PAGE
    assert pre.any() and dec.any()
    # prefill extent = ceil(prompt/page_size) pages in ONE op
    prompts = plan.s_prompt[plan.session[pre]]
    pages = -(-prompts // plan.page_size)
    np.testing.assert_array_equal(own_size[pre], pages * PAGE_UNIT)
    assert (own_size[pre] > PAGE_UNIT).all()          # bursts, not pages
    assert (own_size[pre] >= BYPASS_MIN).any()        # long prompts bypass
    assert (path[pre][own_size[pre] >= BYPASS_MIN] == 2).all()
    # steady state: every decode append is exactly one frontend page
    assert (own_size[dec] == PAGE_UNIT).all()
    assert np.isin(path[dec], (0, 1)).all()           # hit/refill only


def test_eviction_frees_everything_the_session_allocated():
    """For every ended session the planner schedules exactly its decode
    pages + its one extent as protocol frees (closed-loop: nothing is
    reclaimed host-side)."""
    eng = DecodeServe(_cfg(), 2, 2, traffic=_tc(rounds=48))
    plan = eng.plan()
    ended = np.flatnonzero(plan.s_end_round >= 0)
    for s in ended:
        mine = plan.session == s
        n_pages = int((mine & (plan.opkind == DECODE_PAGE)).sum())
        # frees enqueued at end may still be draining in the last rounds;
        # every *dispatched* free belongs to something this session alloced
        n_free_pages = int((mine & (plan.opkind == EVICT_PAGE)).sum())
        n_free_ext = int((mine & (plan.opkind == EVICT_EXTENT)).sum())
        assert n_free_pages <= n_pages and n_free_ext <= 1
        if plan.s_end_round[s] <= plan.rounds - 3:    # had time to drain
            assert n_free_pages == n_pages and n_free_ext == 1, s
    assert (plan.opkind >= EVICT_PAGE).sum() > 0


# --------------------------------------------------------------------------
# eviction edges
# --------------------------------------------------------------------------
def test_context_exactly_at_page_boundary_completes_without_extra_page():
    """prompt 32 + decode 16 = 48 = max_context: the session fills its
    last page exactly and completes — no overflow, and no page is ever
    allocated for the boundary position it can never write."""
    tc = _tc(prompt_choices=(32,), decode_choices=(16,), max_context=48,
             session_rate=0.5, rounds=40)
    eng = DecodeServe(_cfg(), 2, 2, traffic=tc)
    plan = eng.plan()
    done = plan.s_end_round >= 0
    assert done.any()
    assert not plan.s_overflow[done].any()
    np.testing.assert_array_equal(plan.s_tokens[done], 16)
    for s in np.flatnonzero(done):
        mine = plan.session == s
        # tokens 32..47 live in ONE decode page (the 48-boundary page is
        # never allocated)
        assert int((mine & (plan.opkind == DECODE_PAGE)).sum()) == 1, s


def test_overflow_evicts_at_boundary_without_allocating_dead_page():
    """decode budget 17 > the 16 tokens max_context leaves room for: the
    session is evicted on overflow at pos==max_context with exactly one
    decode page — the page for the un-writable position is never
    allocated."""
    tc = _tc(prompt_choices=(32,), decode_choices=(17,), max_context=48,
             session_rate=0.5, rounds=40)
    plan = DecodeServe(_cfg(), 2, 2, traffic=tc).plan()
    done = plan.s_end_round >= 0
    assert done.any() and plan.s_overflow[done].all()
    np.testing.assert_array_equal(plan.s_tokens[done], 16)
    for s in np.flatnonzero(done):
        mine = plan.session == s
        assert int((mine & (plan.opkind == DECODE_PAGE)).sum()) == 1, s


def test_tenant_dies_mid_prefill_frees_extent_only():
    """A prompt longer than max_context overflows during prefill: zero
    decode tokens, zero decode pages, and eviction frees exactly the
    prefill extent."""
    tc = _tc(prompt_choices=(3000,), decode_choices=(120,), max_context=64,
             session_rate=0.5, rounds=40)
    eng = DecodeServe(_cfg(), 2, 2, traffic=tc)
    plan, rep = eng.serve()
    done = plan.s_end_round >= 0
    assert done.any() and plan.s_overflow[done].all()
    assert (plan.s_tokens == 0).all()
    assert (plan.opkind != DECODE_PAGE).all()
    assert rep["decode_tokens"] == 0 and rep["evict_frees"] > 0
    assert rep["conservation_residual"] == 0 and rep["dropped_frees"] == 0


def test_decode_zero_budget_dies_after_prefill():
    """decode budget 0: the tenant prefills, emits nothing, and is evicted
    cleanly (no overflow) — extent freed, no decode pages."""
    tc = _tc(prompt_choices=(48,), decode_choices=(0,), session_rate=0.5,
             rounds=32)
    plan = DecodeServe(_cfg(), 2, 2, traffic=tc).plan()
    done = plan.s_end_round >= 0
    assert done.any() and not plan.s_overflow[done].any()
    assert (plan.s_tokens == 0).all()
    assert (plan.opkind != DECODE_PAGE).all()
    assert (plan.opkind == EVICT_EXTENT).sum() >= done.sum() - 1


# --------------------------------------------------------------------------
# drivers + export
# --------------------------------------------------------------------------
def test_decode_mesh_and_vmap_paths_agree():
    """mesh=None (shard_map over the rank mesh) == mesh=False (pure vmap)
    on the same plan, response for response."""
    tc = _tc(rounds=16)
    a = DecodeServe(_cfg(), 2, 2, traffic=tc, mesh=False)
    b = DecodeServe(_cfg(), 2, 2, traffic=tc, mesh=None)
    plan = a.plan()
    _, ra = a.run(plan)
    _, rb = b.run(plan)
    for f in ("ptr", "ok", "path", "latency_cyc", "backend_cyc"):
        np.testing.assert_array_equal(np.asarray(getattr(ra, f)),
                                      np.asarray(getattr(rb, f)), err_msg=f)


@pytest.mark.parametrize("kind", ["hwsw", "pallas"])
def test_decode_trace_export_replays_bitwise(kind):
    """Any core's slice of the decode session exports as a
    pim-malloc-trace/v1 tape that replays bitwise through the workloads
    engine — on the hwsw reference and the fused pallas kernel."""
    eng = DecodeServe(_cfg(kind), 2, 2, traffic=_tc(rounds=20))
    plan = eng.plan()
    _, resps = eng.run(plan)
    checked = 0
    for rk in range(2):
        for ck in range(2):
            tr = eng.trace(plan, rk, ck)
            if tr.ops == 0:
                continue
            assert tr.meta["workload"] == "llm-decode-paged-kv"
            r2, _, _ = replay(tr, kind)
            for f in ("ptr", "ok", "path", "moved", "latency_cyc"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(resps, f))[:, rk, ck, :],
                    np.asarray(getattr(r2, f)), err_msg=f"{rk},{ck}:{f}")
            checked += 1
    assert checked >= 2


# --------------------------------------------------------------------------
# PR-8 satellite pins: KINDS single source of truth
# --------------------------------------------------------------------------
def test_kinds_derives_from_registry():
    assert tuple(sysm.KINDS) == tuple(heap.REGISTRY)
    assert set(sysm.KINDS) == set(heap.kinds())
    assert {"sw", "hwsw", "strawman", "sanitizer", "pallas"} <= \
        set(sysm.KINDS)


def test_fresh_kind_auto_enrolls_in_kinds():
    """Registering a backend is the ONLY enrollment step: it must appear
    in system.KINDS and heap.kinds() without touching system.py."""
    assert "dummy_pr8" not in sysm.KINDS

    @heap.register("dummy_pr8")
    def _dummy_step(cfg, state, req):   # pragma: no cover - never stepped
        raise NotImplementedError

    try:
        assert "dummy_pr8" in sysm.KINDS
        assert "dummy_pr8" in heap.kinds()
        # and SystemConfig accepts it (validation reads the registry)
        sysm.SystemConfig(kind="dummy_pr8", heap_bytes=HEAP, num_threads=T)
    finally:
        del heap.REGISTRY["dummy_pr8"]
    assert "dummy_pr8" not in sysm.KINDS


def test_unknown_kind_rejected_with_registry_listing():
    with pytest.raises(AssertionError, match="registered"):
        sysm.SystemConfig(kind="nope", heap_bytes=HEAP, num_threads=T)


# --------------------------------------------------------------------------
# PR-8 satellite pins: PagePool eviction through the protocol
# --------------------------------------------------------------------------
def test_pagepool_evict_drains_all_pages_past_thread_width():
    """evict() chunks ANY number of decode pages into T-wide protocol
    frees (the pre-PR-8 recorder truncated at T and leaked the tail)."""
    pool = PagePool(n_pages=1 << 14, num_threads=T, kind="sw")
    ext = pool.alloc_pages(4)
    pages = []
    for _ in range(3):          # 3*T single pages > one T-wide batch
        ids, resp = pool.alloc_page_batch(np.ones(T, bool))
        assert bool(np.asarray(resp.ok).all())
        pages.extend(int(p) for p in np.asarray(ids))
    live0 = pool.client.telemetry()["live_bytes"]
    out = pool.evict(int(ext[0]), pages)
    assert out == {"freed_pages": 3 * T, "dropped_frees": 0}
    assert pool.client.telemetry()["live_bytes"] < live0
    assert pool.client.telemetry()["conservation_residual"] == 0


def test_pagepool_double_evict_is_deterministic_sanitizer_tag():
    """Evicting the same session twice must NOT be a silent success: the
    stale page ids reach the backend's dropped-free path and the sanitizer
    tags them as deterministic double frees."""
    pool = PagePool(n_pages=1 << 14, num_threads=T, kind="sanitizer")
    ext = pool.alloc_pages(4)
    ids, resp = pool.alloc_page_batch(np.ones(T, bool))
    assert bool(np.asarray(resp.ok).all())
    pages = [int(p) for p in np.asarray(ids)]

    first = pool.evict(int(ext[0]), pages)
    assert first["dropped_frees"] == 0
    second = pool.evict(int(ext[0]), pages)
    # every repeated free is dropped, deterministically — twice gives the
    # same verdict
    assert second["dropped_frees"] == second["freed_pages"] + 1  # + extent
    rep = sanitizer.report(pool.client.state)
    assert rep["double_free"] >= T + 1
    assert pool.client.stats["dropped_frees"] >= T + 1
    third = pool.evict(int(ext[0]), pages)
    assert third == second
