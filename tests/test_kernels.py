"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp ref oracles,
with shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import buddy
from repro.core.buddy import BuddyConfig
from repro.kernels import ops


# ------------------------------------------------------------- buddy_traverse
@pytest.mark.parametrize("heap,min_block", [(1 << 14, 32), (1 << 16, 64),
                                            (1 << 18, 4096)])
@pytest.mark.parametrize("cores,batch", [(1, 8), (4, 16)])
def test_buddy_traverse_matches_ref(heap, min_block, cores, batch):
    cfg = BuddyConfig(heap_bytes=heap, min_block=min_block)
    tree = jax.vmap(lambda _: buddy.init(cfg).longest)(jnp.arange(cores))
    rng = np.random.RandomState(0)
    sizes = jnp.asarray(
        rng.choice([min_block, min_block * 2, min_block * 7, heap // 8],
                   size=(cores, batch)), jnp.int32)
    offs_k, tree_k = ops.buddy_alloc_batch(
        tree, sizes, heap_bytes=heap, min_block=min_block, interpret=True)
    offs_r, tree_r = ops.buddy_alloc_batch_ref(
        tree, sizes, heap_bytes=heap, min_block=min_block)
    np.testing.assert_array_equal(np.asarray(offs_k), np.asarray(offs_r))
    np.testing.assert_array_equal(np.asarray(tree_k), np.asarray(tree_r))


def test_buddy_traverse_exhaustion():
    heap, mb = 1 << 12, 32
    cfg = BuddyConfig(heap_bytes=heap, min_block=mb)
    tree = buddy.init(cfg).longest[None]
    sizes = jnp.full((1, 40), 128, jnp.int32)  # 40*128 > 4096 -> some fail
    offs, _ = ops.buddy_alloc_batch(tree, sizes, heap_bytes=heap, min_block=mb,
                                    interpret=True)
    offs = np.asarray(offs)[0]
    assert (offs >= 0).sum() == heap // 128
    assert (offs[heap // 128:] == -1).all()


# ------------------------------------------------------------------ freelist
@pytest.mark.parametrize("T,NC,CAP", [(4, 8, 64), (8, 4, 128)])
def test_freelist_matches_ref(T, NC, CAP):
    rng = np.random.RandomState(1)
    counts = jnp.asarray(rng.randint(0, CAP, size=(T, NC)), jnp.int32)
    stacks = jnp.asarray(rng.randint(0, 1 << 20, size=(T, NC, CAP)), jnp.int32)
    for trial in range(3):
        op = jnp.asarray(rng.randint(-1, 2, size=(T,)), jnp.int32)
        cls = jnp.asarray(rng.randint(0, NC, size=(T,)), jnp.int32)
        ptr = jnp.asarray(rng.randint(0, 1 << 20, size=(T,)), jnp.int32)
        pk, ck, sk = ops.freelist_op(stacks, counts, op, cls, ptr, interpret=True)
        pr, cr, sr = ops.freelist_op_ref(stacks, counts, op, cls, ptr)
        np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
        np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))
        stacks, counts = sk, ck


def test_freelist_pop_empty_and_push_full():
    T, NC, CAP = 2, 2, 4
    counts = jnp.array([[0, 4], [1, 4]], jnp.int32)
    stacks = jnp.arange(T * NC * CAP, dtype=jnp.int32).reshape(T, NC, CAP)
    op = jnp.array([0, 1], jnp.int32)      # pop empty class, push full class
    cls = jnp.array([0, 1], jnp.int32)
    ptr = jnp.array([111, 222], jnp.int32)
    pk, ck, sk = ops.freelist_op(stacks, counts, op, cls, ptr, interpret=True)
    assert int(pk[0]) == -1                    # pop from empty -> -1
    assert int(ck[0, 0]) == 0
    np.testing.assert_array_equal(np.asarray(sk[1, 1]), np.asarray(stacks[1, 1]))


# ------------------------------------------------------------ paged attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KVH,D,pages,page_size", [
    (2, 4, 2, 128, 4, 128),
    (1, 8, 1, 128, 2, 128),   # MQA
    (3, 6, 6, 128, 3, 128),   # MHA
])
def test_paged_attention_matches_ref(B, H, KVH, D, pages, page_size, dtype):
    rng = np.random.RandomState(2)
    N = pages * B + 2
    q = jnp.asarray(rng.randn(B, H, D), dtype) * 0.1
    k_pages = jnp.asarray(rng.randn(N, page_size, KVH, D), dtype) * 0.1
    v_pages = jnp.asarray(rng.randn(N, page_size, KVH, D), dtype) * 0.1
    # each sequence gets distinct pages (as the allocator would hand out)
    pt = jnp.asarray(
        rng.permutation(N)[: B * pages].reshape(B, pages), jnp.int32)
    seq_lens = jnp.asarray(rng.randint(1, pages * page_size, size=(B,)), jnp.int32)
    out_k = ops.paged_attention_op(q, k_pages, v_pages, pt, seq_lens,
                                   interpret=True)
    out_r = ops.paged_attention_ref(q, k_pages, v_pages, pt, seq_lens)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=atol, rtol=atol)


def test_paged_attention_respects_page_table():
    """Swapping page table rows permutes outputs accordingly."""
    B, H, KVH, D, pages, page_size = 2, 2, 2, 128, 2, 128
    rng = np.random.RandomState(3)
    q = jnp.asarray(rng.randn(1, H, D), jnp.float32)
    q2 = jnp.concatenate([q, q], axis=0)
    k_pages = jnp.asarray(rng.randn(6, page_size, KVH, D), jnp.float32)
    v_pages = jnp.asarray(rng.randn(6, page_size, KVH, D), jnp.float32)
    pt = jnp.array([[0, 1], [2, 3]], jnp.int32)
    sl = jnp.array([page_size * 2, page_size * 2], jnp.int32)
    out = ops.paged_attention_op(q2, k_pages, v_pages, pt, sl, interpret=True)
    out_sw = ops.paged_attention_op(q2, k_pages, v_pages, pt[::-1], sl,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out_sw[1]),
                               atol=1e-6)
    assert not np.allclose(np.asarray(out[0]), np.asarray(out[1]))


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,T,H,KVH,hd,causal,window", [
    (2, 256, 256, 4, 2, 128, True, 0),
    (1, 512, 512, 4, 1, 128, True, 128),   # MQA + sliding window
    (2, 128, 384, 6, 6, 128, False, 0),    # MHA, cross-shaped (S != T)
])
def test_flash_kernel_matches_dense(B, S, T, H, KVH, hd, causal, window, dtype):
    from repro.models import layers

    rng = np.random.RandomState(7)
    q = jnp.asarray(rng.randn(B, S, H, hd), dtype) * 0.2
    k = jnp.asarray(rng.randn(B, T, KVH, hd), dtype) * 0.2
    v = jnp.asarray(rng.randn(B, T, KVH, hd), dtype) * 0.2
    out_k = ops.flash_attention_op(q, k, v, causal=causal, window=window,
                                   block_q=128, block_kv=128, interpret=True)
    out_r = layers.attention(q, k, v, causal=causal, window=window)
    atol = 2.5e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               atol=atol, rtol=atol)


def test_flash_kernel_odd_blocks():
    """Block sizes auto-fit non-multiple sequence lengths."""
    from repro.models import layers

    rng = np.random.RandomState(8)
    q = jnp.asarray(rng.randn(1, 192, 2, 64), jnp.float32) * 0.3
    k = jnp.asarray(rng.randn(1, 192, 2, 64), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(1, 192, 2, 64), jnp.float32) * 0.3
    out_k = ops.flash_attention_op(q, k, v, causal=True, block_q=128,
                                   block_kv=128, interpret=True)
    out_r = layers.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=3e-5, rtol=3e-5)
