"""The `sanitizer` backend: shadow map, quarantine, deterministic tags.

Contract (ISSUE 6 acceptance): the sanitizer kind serves the full heap
protocol (it auto-enrolls in every KINDS-parametrized suite) and turns
heap misuse — double free, use-after-free through a stale pre-realloc
pointer, realloc-after-free, wild pointers — from modeled-benign dropped
paths into deterministic tagged reports, while the conservation law keeps
holding because quarantined blocks stay live in the wrapped hwsw heap.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import heap, sanitizer, system as sysm, telemetry
from test_differential_fuzz import SMOKE_SEEDS, fuzz_trace

T = 4
HEAP = 1 << 18


def _cfg(**kw):
    return sysm.SystemConfig(kind="sanitizer", heap_bytes=HEAP,
                             num_threads=T, **kw)


def _malloc(cfg, st, sizes):
    return heap.step(cfg, st, heap.malloc_request(
        jnp.array(sizes, jnp.int32)))


def _free(cfg, st, ptrs):
    return heap.step(cfg, st, heap.free_request(jnp.array(ptrs, jnp.int32)))


def _realloc(cfg, st, ptrs, sizes):
    return heap.step(cfg, st, heap.realloc_request(
        jnp.array(ptrs, jnp.int32), jnp.array(sizes, jnp.int32)))


# ------------------------------------------------------------ enrollment
def test_sanitizer_is_registered():
    assert "sanitizer" in heap.kinds()
    assert "sanitizer" in sysm.KINDS


def test_state_mirrors_system_state_layout():
    """telemetry.snapshot and the replay reports read (alloc, cache,
    telem) straight off the state — the sanitizer state must lead with
    the same triple."""
    cfg = _cfg()
    st = heap.init(cfg)
    assert isinstance(st, sanitizer.SanitizerState)
    snap = telemetry.snapshot(cfg, st)
    assert snap["conservation_residual"] == 0
    assert st.shadow.shape == (HEAP // sanitizer.GRANULE,)
    assert st.q_ptr.shape == (sanitizer.quarantine_slots(T),)


# ------------------------------------------------------- the three tags
def test_double_free_is_tagged_deterministically():
    cfg = _cfg()
    st = heap.init(cfg)
    st, r = _malloc(cfg, st, [32, 256, 2048, 64])
    st, rf = _free(cfg, st, r.ptr)
    assert bool(rf.ok.all()) and (np.asarray(rf.path) == 0).all()
    st, rd = _free(cfg, st, r.ptr)          # every thread frees again
    assert not bool(rd.ok.any())
    assert (np.asarray(rd.path) == 2).all()  # reported like a dropped free
    assert (np.asarray(rd.ptr) == -1).all()
    assert (np.asarray(st.tags) == sanitizer.TAG_DOUBLE_FREE).all()
    assert int(st.reports.double_free) == T
    assert int(st.alloc.stats.dropped_frees) == T  # folds into stats
    # deterministic: a fresh identical run produces identical everything
    st2 = heap.init(cfg)
    st2, r2 = _malloc(cfg, st2, [32, 256, 2048, 64])
    st2, _ = _free(cfg, st2, r2.ptr)
    st2, rd2 = _free(cfg, st2, r2.ptr)
    np.testing.assert_array_equal(np.asarray(rd.latency_cyc),
                                  np.asarray(rd2.latency_cyc))
    np.testing.assert_array_equal(np.asarray(st.tags), np.asarray(st2.tags))


def test_use_after_free_via_stale_realloc_pointer():
    cfg = _cfg()
    st = heap.init(cfg)
    st, r = _malloc(cfg, st, [64, 0, 0, 0])
    p0 = int(r.ptr[0])
    st, rr = _realloc(cfg, st, [p0, -1, -1, -1], [8192, 0, 0, 0])
    assert bool(rr.moved[0]) and int(rr.ptr[0]) != p0
    st, rf = _free(cfg, st, [p0, -1, -1, -1])   # stale pre-realloc pointer
    assert not bool(rf.ok[0]) and int(rf.path[0]) == 2
    assert int(st.tags[0]) == sanitizer.TAG_USE_AFTER_FREE
    assert int(st.reports.use_after_free) == 1
    # the relocated block is still perfectly freeable
    st, rf2 = _free(cfg, st, [int(rr.ptr[0]), -1, -1, -1])
    assert bool(rf2.ok[0])


def test_realloc_after_free_is_tagged():
    cfg = _cfg()
    st = heap.init(cfg)
    st, r = _malloc(cfg, st, [64, 128, 0, 0])
    st, _ = _free(cfg, st, [int(r.ptr[0]), -1, -1, -1])
    st, rr = _realloc(cfg, st, [int(r.ptr[0]), -1, -1, -1], [128, 0, 0, 0])
    assert not bool(rr.ok[0]) and int(rr.path[0]) == 3  # fails like realloc
    assert int(rr.ptr[0]) == -1
    assert int(st.tags[0]) == sanitizer.TAG_REALLOC_AFTER_FREE
    assert int(st.reports.realloc_after_free) == 1
    assert int(st.alloc.stats.fails) >= 1
    # the untouched thread-1 block is unaffected
    st, rf = _free(cfg, st, [-1, int(r.ptr[1]), -1, -1])
    assert bool(rf.ok[1])


def test_wild_and_misaligned_pointers_are_tagged():
    cfg = _cfg()
    st = heap.init(cfg)
    st, r = _malloc(cfg, st, [64, 0, 0, 0])
    p0 = int(r.ptr[0])
    # out-of-range, unmapped-in-range, interior (misaligned), NULL
    st, rf = _free(cfg, st, [HEAP + 8, 131072 + 16, p0 + 4, -1])
    assert (np.asarray(rf.path)[:3] == 2).all()
    assert int(rf.path[3]) == -1                       # NULL stays benign
    assert (np.asarray(st.tags)[:3] == sanitizer.TAG_WILD).all()
    assert int(st.reports.wild_ops) == 3
    assert int(st.alloc.stats.dropped_frees) == 3


# ------------------------------------------------------------ quarantine
def test_quarantine_delays_pointer_reuse():
    """hwsw recycles a freed small block LIFO on the very next malloc;
    the sanitizer parks it in the quarantine ring instead."""
    cfg = _cfg()
    hw = sysm.SystemConfig(kind="hwsw", heap_bytes=HEAP, num_threads=T)
    st, sh = heap.init(cfg), heap.init(hw)
    st, r = _malloc(cfg, st, [64, 0, 0, 0])
    sh, rh = heap.step(hw, sh, heap.malloc_request(
        jnp.array([64, 0, 0, 0], jnp.int32)))
    assert int(r.ptr[0]) == int(rh.ptr[0])  # same inner allocator
    st, _ = _free(cfg, st, [int(r.ptr[0]), -1, -1, -1])
    sh, _ = heap.step(hw, sh, heap.free_request(
        jnp.array([int(rh.ptr[0]), -1, -1, -1], jnp.int32)))
    st, r2 = _malloc(cfg, st, [64, 0, 0, 0])
    sh, rh2 = heap.step(hw, sh, heap.malloc_request(
        jnp.array([64, 0, 0, 0], jnp.int32)))
    assert int(rh2.ptr[0]) == int(rh.ptr[0])   # hwsw: immediate LIFO reuse
    assert int(r2.ptr[0]) != int(r.ptr[0])     # sanitizer: still parked
    assert int(st.q_len) == 1
    assert int(st.reports.quarantined) == 1


def test_quarantine_overflow_evicts_fifo_and_conserves():
    """Past capacity the OLDEST entry is released to the real free path;
    conservation holds throughout, and a released granule returns to
    unmapped shadow (a later free of it is wild, not double-free)."""
    cfg = _cfg()
    st = heap.init(cfg)
    Q = sanitizer.quarantine_slots(T)
    rounds = Q // T + 2
    ptrs = []
    for _ in range(rounds):
        st, r = _malloc(cfg, st, [2048] * T)
        assert (np.asarray(r.ptr) >= 0).all()
        ptrs.append(np.asarray(r.ptr).copy())
    first = int(ptrs[0][0])
    for p in ptrs:
        st, rf = _free(cfg, st, p)
        assert bool(rf.ok.all())
        snap = telemetry.snapshot(cfg, st)
        assert snap["conservation_residual"] == 0
    assert int(st.reports.quarantined) == rounds * T
    assert int(st.reports.evicted) == rounds * T - Q
    assert int(st.q_len) == Q
    # the first-freed pointer was evicted (FIFO): shadow is unmapped again
    assert int(st.shadow[first // sanitizer.GRANULE]) == sanitizer.SHADOW_FREE
    st, rf = _free(cfg, st, [first, -1, -1, -1])
    assert int(st.tags[0]) == sanitizer.TAG_WILD  # released, not double-free


# ------------------------------------------- fuzzer misuse-stream contract
@pytest.mark.parametrize("seed", SMOKE_SEEDS)
def test_fuzz_misuse_streams_replay_deterministically(seed):
    from repro.workloads.replay import replay

    trace = fuzz_trace(seed)
    _, s1, rep1 = replay(trace, "sanitizer")
    _, s2, rep2 = replay(trace, "sanitizer")
    assert rep1["digest_full"] == rep2["digest_full"]
    assert sanitizer.report(s1) == sanitizer.report(s2)
    assert rep1["telemetry"]["conservation_residual"] == 0


def test_fuzz_misuse_streams_are_tagged():
    """Across the CI smoke seeds the sanitizer must tag all misuse
    classes the fuzzer plants: cross-round double frees (incl.
    realloc(dead, 0)), stale pre-realloc frees, and garbage pointers."""
    from repro.workloads.replay import replay

    totals = {"double_free": 0, "use_after_free": 0, "wild_ops": 0}
    for seed in SMOKE_SEEDS:
        _, state, _ = replay(fuzz_trace(seed), "sanitizer")
        rep = sanitizer.report(state)
        for k in totals:
            totals[k] += rep[k]
    assert totals["double_free"] > 0, totals
    assert totals["use_after_free"] > 0, totals
    assert totals["wild_ops"] > 0, totals


def test_report_schema():
    cfg = _cfg()
    st = heap.init(cfg)
    st, r = _malloc(cfg, st, [64, 0, 0, 0])
    st, _ = _free(cfg, st, r.ptr)
    rep = sanitizer.report(st)
    assert set(rep) == {"double_free", "use_after_free",
                        "realloc_after_free", "wild_ops", "quarantined",
                        "evicted", "epoch_resets", "epoch_stale",
                        "last_round_tags", "quarantine_backlog"}
    assert rep["last_round_tags"] == ["none"] * T
    assert rep["quarantine_backlog"] == 1
