"""ShardedHeap (shard_map tier) + FleetRouter conformance.

The fleet tier must be a pure transform of the same `heap.step` every other
tier serves: a 1-device-mesh ShardedHeap reproduces MultiCoreHeap pointer
sequences bitwise, donation/fallback change nothing, the router round-trips
flat request streams through the [R, C, T] grid, and the cost accounting is
an exact per-rank decomposition.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import heap
from repro.core import system as sysm
from repro.launch import fleet

T = 4
HEAP = 1 << 18
R, C = 3, 2


def _cfg(kind="sw"):
    return sysm.SystemConfig(kind=kind, heap_bytes=HEAP, num_threads=T)


def _tape(rounds=4):
    """[rounds, R, C, T] malloc sizes, distinct per (rank, core, thread)."""
    rng = np.random.RandomState(7)
    return jnp.asarray(
        rng.choice([16, 100, 256, 2048, 3000, 8192], (rounds, R, C, T))
        .astype(np.int32))


@pytest.mark.parametrize("kind", sysm.KINDS)
def test_sharded_matches_multicore_bitwise(kind):
    """Acceptance: ShardedHeap on a 1-device mesh == MultiCoreHeap, pointer
    for pointer, across malloc/free/realloc rounds on every backend kind.
    Each rank sees a DISTINCT request stream and must match a MultiCoreHeap
    replaying exactly that rank's stream."""
    cfg = _cfg(kind)
    sh = heap.ShardedHeap(cfg, num_ranks=R, num_cores=C)
    assert sh.mesh is not None and sh.mesh.devices.size >= 1
    replays = [heap.MultiCoreHeap(cfg, num_cores=C) for _ in range(R)]
    for sizes in _tape():
        ra = sh.malloc(sizes)
        rr = sh.realloc(ra.ptr, jnp.roll(sizes, 1, axis=-1))
        live = jnp.where(rr.ptr >= 0, rr.ptr, ra.ptr)
        sh.free(live)
        for rk, mch in enumerate(replays):
            rm = mch.malloc(sizes[rk])
            np.testing.assert_array_equal(np.asarray(ra.ptr[rk]),
                                          np.asarray(rm.ptr))
            np.testing.assert_allclose(np.asarray(ra.latency_cyc[rk]),
                                       np.asarray(rm.latency_cyc))
            rrm = mch.step(jax.vmap(heap.realloc_request)(
                rm.ptr, jnp.roll(sizes[rk], 1, axis=-1)))
            np.testing.assert_array_equal(np.asarray(rr.ptr[rk]),
                                          np.asarray(rrm.ptr))
            mch.free(jnp.where(rrm.ptr >= 0, rrm.ptr, rm.ptr))


def test_donation_and_fallback_do_not_change_results():
    """donate=True (in-place state buffers), donate=False, and the pure-vmap
    fallback (mesh=False) produce identical pointer streams."""
    cfg = _cfg()
    variants = [heap.ShardedHeap(cfg, R, C, donate=True),
                heap.ShardedHeap(cfg, R, C, donate=False),
                heap.ShardedHeap(cfg, R, C, mesh=False, donate=True)]
    assert variants[2].mesh is None
    for sizes in _tape():
        resps = [v.malloc(sizes) for v in variants]
        for other in resps[1:]:
            np.testing.assert_array_equal(np.asarray(resps[0].ptr),
                                          np.asarray(other.ptr))
        for v, r in zip(variants, resps):
            v.free(r.ptr)
    # states converged identically too
    for leaf_a, leaf_b in zip(jax.tree.leaves(variants[0].state),
                              jax.tree.leaves(variants[1].state)):
        np.testing.assert_array_equal(np.asarray(leaf_a), np.asarray(leaf_b))


def test_rank_independence():
    """Rank 0's requests never perturb rank 1's heap."""
    cfg = _cfg()
    sh = heap.ShardedHeap(cfg, num_ranks=2, num_cores=C)
    baseline = jax.tree.map(np.asarray, sh.state)
    sizes = jnp.zeros((2, C, T), jnp.int32).at[0].set(
        jnp.full((C, T), 256, jnp.int32))
    resp = sh.malloc(sizes)
    assert bool((resp.ptr[0] >= 0).all()) and bool((resp.ptr[1] == -1).all())
    for a, b in zip(jax.tree.leaves(baseline), jax.tree.leaves(sh.state)):
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_router_round_trips_flat_batches():
    """scatter -> route -> gather preserves request order exactly, including
    a partially filled final rank, and matches a direct [R, C, T] round."""
    cfg = _cfg()
    router = fleet.FleetRouter(heap.ShardedHeap(cfg, R, C))
    n = R * C * T - 5                      # ragged tail: NOOP padding
    sizes = (np.arange(n, dtype=np.int32) % 7 + 1) * 32
    out = router.route_flat(np.full(n, heap.OP_MALLOC, np.int32), sizes,
                            np.full(n, -1, np.int32))
    assert out["ptr"].shape == (n,) and (out["ptr"] >= 0).all()

    # same sizes served directly as a full grid on a fresh fleet
    direct = heap.ShardedHeap(cfg, R, C)
    grid = np.zeros((R * C * T,), np.int32)
    grid[:n] = sizes
    rd = direct.malloc(jnp.asarray(grid.reshape(R, C, T)))
    np.testing.assert_array_equal(out["ptr"],
                                  np.asarray(rd.ptr).reshape(-1)[:n])

    # frees round-trip through the same slots
    out2 = router.route_flat(np.full(n, heap.OP_FREE, np.int32),
                             np.zeros(n, np.int32), out["ptr"])
    assert out2["ok"].all()

    with pytest.raises(ValueError):
        fleet.scatter_flat(np.zeros(R * C * T + 1, np.int32),
                           np.zeros(R * C * T + 1, np.int32),
                           np.zeros(R * C * T + 1, np.int32), router.shape)


def test_accounting_sums_across_ranks():
    cfg = _cfg()
    router = fleet.FleetRouter(heap.ShardedHeap(cfg, R, C))
    for sizes in _tape(3):
        ra = router.route(heap.malloc_request(sizes))
        router.route(heap.free_request(ra.ptr))
    st = router.stats
    assert st["rounds"] == 6
    assert st["ops"] == 6 * R * C * T == sum(st["per_rank"]["ops"])
    assert st["latency_cyc"] == pytest.approx(
        sum(st["per_rank"]["latency_cyc"]))
    assert st["dram_bytes"] == sum(st["per_rank"]["dram_bytes"])
    assert st["us_per_op"] > 0

    # per-rank latencies match an independent single-rank replay
    solo = fleet.FleetRouter(heap.ShardedHeap(cfg, 1, C))
    for sizes in _tape(3):
        ra = solo.route(heap.malloc_request(sizes[:1]))
        solo.route(heap.free_request(ra.ptr))
    assert solo.stats["per_rank"]["latency_cyc"][0] == pytest.approx(
        st["per_rank"]["latency_cyc"][0])


def test_fleet_accounting_shapes():
    """system.fleet_accounting: totals on [T] rounds, per_rank on [R,C,T]."""
    cfg = _cfg()
    st = heap.init(cfg)
    req = heap.malloc_request(jnp.full((T,), 64, jnp.int32))
    st, resp = heap.step(cfg, st, req)
    acct = sysm.fleet_accounting(req, resp)
    assert acct["ops"] == T and "per_rank" not in acct

    sh = heap.ShardedHeap(cfg, R, C)
    req3 = heap.malloc_request(jnp.full((R, C, T), 64, jnp.int32))
    acct3 = sysm.fleet_accounting(req3, sh.step(req3))
    assert len(acct3["per_rank"]["ops"]) == R
    assert acct3["ops"] == sum(acct3["per_rank"]["ops"])


def test_serve_fleet_page_requests():
    """The serving driver's fleet page-growth round: one MALLOC per needy
    sequence, landed on rank b % R, gathered accounting balanced."""
    from repro.launch import serve as serve_mod
    router = serve_mod.make_fleet_pool(num_ranks=2, n_pages=1 << 16,
                                       num_threads=T)
    need = np.array([True, False, True, True])
    req = serve_mod.fleet_page_request(router, need)
    assert int((np.asarray(req.op) == heap.OP_MALLOC).sum()) == 3
    resp = router.route(req)
    ptr = np.asarray(resp.ptr)
    assert int((ptr >= 0).sum()) == 3
    assert router.stats["per_rank"]["ops"] == [2, 1]