"""Integration tests: dynamic graph workload + paged KV cache + PagePool."""
import numpy as np

import jax.numpy as jnp

from repro.graphupd.workload import (DynamicGraph, GraphConfig, compare_all,
                                     synth_edges)
from repro.kvcache import paged


# ----------------------------------------------------------------- graph upd
def test_dynamic_graph_matches_reference():
    cfg = GraphConfig(n_nodes=48, n_edges_pre=80, n_edges_new=40,
                      heap_bytes=1 << 20)
    g = DynamicGraph(cfg, kind="sw")
    pre_s, pre_d, new_s, new_d = synth_edges(cfg)
    ref = {u: [] for u in range(cfg.n_nodes)}
    T = cfg.num_threads
    src = np.concatenate([pre_s, new_s])
    dst = np.concatenate([pre_d, new_d])
    for i in range(0, len(src), T):
        g.insert_round(src[i:i + T], dst[i:i + T])
        for u, v in zip(src[i:i + T], dst[i:i + T]):
            ref[int(u)].insert(0, int(v))
    for u in range(cfg.n_nodes):
        assert g.neighbors(u) == ref[u], u
    assert int(g.state.alloc.stats.fails) == 0


def test_graph_comparison_structure():
    """Paper Fig 16 qualitative ordering on a small instance."""
    cfg = GraphConfig(n_nodes=96, n_edges_pre=800, n_edges_new=400,
                      heap_bytes=1 << 20)
    res = compare_all(cfg)
    st = res["static_csr"]["us_per_edge"]
    assert res["sw"]["us_per_edge"] < st / 5          # dynamic >> static
    assert res["hwsw"]["us_per_edge"] < st / 5
    assert res["strawman"]["us_per_edge"] > st / 3    # straw-man ~ static


# ------------------------------------------------------------------ paged KV
def test_write_prefill_and_token_roundtrip():
    B, P, page, KVH, hd = 2, 4, 8, 2, 16
    pages = jnp.zeros((B, P, page, KVH, hd))
    kv = jnp.asarray(np.random.RandomState(0).randn(B, 16, KVH, hd))
    pt = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32), (B, P))
    pages = paged.write_prefill(pages, kv, pt)
    np.testing.assert_allclose(np.asarray(pages[:, 0, :, :, :]),
                               np.asarray(kv[:, :8]))
    tok = jnp.ones((B, KVH, hd))
    pages = paged.write_token(pages, tok, pt, jnp.array([16, 17]))
    assert float(pages[0, 2, 0, 0, 0]) == 1.0   # pos 16 -> page 2 slot 0
    assert float(pages[1, 2, 1, 0, 0]) == 1.0   # pos 17 -> page 2 slot 1


def test_attend_kernel_equals_ref_paths():
    B, P, page, KVH, hd, H = 2, 3, 128, 2, 128, 4
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(B, H, hd), jnp.float32) * 0.2
    kp = jnp.asarray(rng.randn(B, P, page, KVH, hd), jnp.float32) * 0.2
    vp = jnp.asarray(rng.randn(B, P, page, KVH, hd), jnp.float32) * 0.2
    pt = jnp.asarray(rng.permutation(P * B).reshape(B, P) % P, jnp.int32)
    sl = jnp.array([200, 300], jnp.int32)
    o_ref = paged.attend(q, kp, vp, pt, sl, impl="ref")
    o_k = paged.attend(q, kp, vp, pt, sl, impl="kernel")
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_k),
                               atol=2e-5, rtol=2e-5)


def test_page_pool_hierarchy_paths():
    pool = paged.PagePool(n_pages=1 << 16)
    # large extent -> bypass/buddy; small singles -> thread-cache frontend
    ext = pool.alloc_pages(512)           # 512 pages = 8 KB alloc -> bypass
    assert ext.shape[0] == 512
    assert pool.stats["bypass"] == 1
    singles, ev = pool.alloc_page_batch([True] * 4 + [False] * 12)
    assert int((np.asarray(singles) >= 0).sum()) == 4
    assert pool.stats["front_hits"] >= 4
    # extents and singles never overlap
    s = set(np.asarray(ext).tolist())
    for p in np.asarray(singles)[:4]:
        assert int(p) not in s
    pool.free_extent(int(ext[0]))
    assert pool.stats["frees_big"] == 1


from conftest import hypothesis_or_skip

given, settings, hst = hypothesis_or_skip()


@settings(max_examples=15, deadline=None)
@given(hst.integers(0, 10_000))
def test_property_paged_cache_equals_dense(seed):
    """Random page tables + interleaved prefill/token writes: attention over
    the paged cache == dense attention over the chronological KV stream."""
    from repro.models import layers

    rng = np.random.RandomState(seed)
    B, P, page, KVH, hd, H = 2, 4, 8, 2, 32, 4
    S0 = page * rng.randint(1, 3)          # page-aligned prefill length
    extra = rng.randint(1, page)           # decode steps
    pt = jnp.asarray([rng.permutation(P) for _ in range(B)], jnp.int32)

    kd = rng.randn(B, S0 + extra, KVH, hd).astype(np.float32) * 0.3
    vd = rng.randn(B, S0 + extra, KVH, hd).astype(np.float32) * 0.3
    kp = jnp.zeros((B, P, page, KVH, hd))
    vp = jnp.zeros((B, P, page, KVH, hd))
    kp = paged.write_prefill(kp, jnp.asarray(kd[:, :S0]), pt)
    vp = paged.write_prefill(vp, jnp.asarray(vd[:, :S0]), pt)
    for t in range(S0, S0 + extra):
        pos = jnp.full((B,), t, jnp.int32)
        kp = paged.write_token(kp, jnp.asarray(kd[:, t]), pt, pos)
        vp = paged.write_token(vp, jnp.asarray(vd[:, t]), pt, pos)

    q = jnp.asarray(rng.randn(B, H, hd).astype(np.float32) * 0.3)
    sl = jnp.full((B,), S0 + extra, jnp.int32)
    o_paged = paged.attend(q, kp, vp, pt, sl, impl="ref")
    o_kernel = paged.attend(q, kp, vp, pt, sl, impl="kernel")
    o_dense = layers.attention(q[:, None], jnp.asarray(kd), jnp.asarray(vd),
                               causal=False)[:, 0]
    np.testing.assert_allclose(np.asarray(o_paged), np.asarray(o_dense),
                               atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_dense),
                               atol=3e-5, rtol=3e-5)
