"""Chaos harness for the elastic serving tier (repro.launch.elastic).

Seeded kill/stall/drop/migrate/snapshot schedules across backends, pinning
the guarantees the tier sells:

  * per-core conservation holds after every chaos session (residual 0),
  * the expiry/eviction free lane records zero drops under every schedule
    (kills requeue it through the replay path — never drop it),
  * a migrated tenant's destination-core tape is a closed trace that
    replays bitwise through `repro.workloads.replay`,
  * the same traffic seed + the same FaultPlan reproduce the report and
    tapes exactly,
  * `snapshot()` mid-session → `restore()` (same mesh wiring AND onto a
    shard_mapped mesh) finishes the session with a report bitwise-equal
    to the uninterrupted run (crash-vs-clean equivalence),
  * with no faults and no migration the elastic engine is bitwise-equal
    to plain FleetServe (the segmented scan is the same session).

`CHAOS_SEEDS` (env) widens the seeded sweep — CI smoke runs 2, the
nightly lane more.
"""
import os

import numpy as np
import pytest

from repro.core import system as sysm
from repro.core import telemetry
from repro.core.heap import OP_NOOP
from repro.launch import fleet
from repro.launch.elastic import (DROP, KILL, STALL, ElasticFleetServe,
                                  FaultEvent, FaultPlan, MigrationConfig)
from repro.launch.serve_fleet import FleetServe, TrafficConfig
from repro.workloads.replay import replay

T = 4
SHAPE = (2, 2, T)
HEAP = 1 << 17
N_SEEDS = int(os.environ.get("CHAOS_SEEDS", "2"))
KINDS = ("sw", "hwsw")
CELLS = [(kind, seed) for kind in KINDS for seed in range(N_SEEDS)]


def _cfg(kind="sw"):
    return sysm.SystemConfig(kind=kind, heap_bytes=HEAP, num_threads=T)


def _tc(**kw):
    base = dict(seed=3, rounds=24, arrival_rate=6.0, num_tenants=8,
                queue_cap=32)
    base.update(kw)
    return TrafficConfig(**base)


def _mig(**kw):
    base = dict(ratio=1.2, min_bytes=256, drain="interval", check_rounds=6)
    base.update(kw)
    return MigrationConfig(**base)


def _chaos_engine(kind, seed, mesh=False):
    tc = _tc(seed=3 + seed)
    return ElasticFleetServe(
        _cfg(kind), 2, 2, traffic=tc, placement="chunked", mesh=mesh,
        faults=FaultPlan.generate(seed=100 + seed, rounds=tc.rounds,
                                  shape=SHAPE),
        migration=_mig())


_CACHE = {}


def _chaos_run(kind, seed):
    """One chaos session per (kind, seed), cached with its engine so the
    tape tests can reach the per-segment responses."""
    if (kind, seed) not in _CACHE:
        eng = _chaos_engine(kind, seed)
        plan, report = eng.serve()
        _CACHE[(kind, seed)] = (eng, plan, report)
    return _CACHE[(kind, seed)]


# --------------------------------------------------------------------------
# the chaos matrix
# --------------------------------------------------------------------------
@pytest.mark.parametrize("kind,seed", CELLS)
def test_chaos_conservation_holds(kind, seed):
    _, _, report = _chaos_run(kind, seed)
    assert report["conservation_residual"] == 0


@pytest.mark.parametrize("kind,seed", CELLS)
def test_chaos_expiry_lane_never_drops(kind, seed):
    """Kills re-place dead blocks through the replay lane and queued expiry
    frees wait for the re-bound slot — the never-droppable lane must end
    the session with zero dropped frees and an empty backlog."""
    _, plan, report = _chaos_run(kind, seed)
    assert report["dropped_frees"] == 0
    assert report["expiry_frees_dispatched"] > 0


@pytest.mark.parametrize("kind,seed", CELLS)
def test_chaos_killed_core_goes_dark(kind, seed):
    """After its kill round a dead core receives no further dispatch."""
    eng, plan, report = _chaos_run(kind, seed)
    for ev in report["faults"]:
        if ev["kind"] != KILL:
            continue
        after = plan.op[ev["round"]:, ev["rank"], ev["core"], :]
        assert (after == OP_NOOP).all()


def test_chaos_migrations_occur_somewhere():
    """The sweep must actually exercise migration — a chaos matrix whose
    pressure never diverges is vacuous."""
    assert any(_chaos_run(kind, seed)[2]["migrations"]
               for kind, seed in CELLS)


@pytest.mark.parametrize("kind,seed", CELLS)
def test_chaos_migration_lane_accounted(kind, seed):
    """Every queued migration op is either dispatched or still pending at
    session end; dispatched ledger entries are internal (non-external)."""
    eng, plan, report = _chaos_run(kind, seed)
    n_mig_ops = sum(2 * ev["blocks"] for ev in report["migrations"])
    n_kill_ops = sum(ev["blocks_replayed"] for ev in report["kills"])
    assert report["migration_ops_dispatched"] <= n_mig_ops + n_kill_ops
    assert (report["migration_ops_dispatched"] + report["backlog_end"]
            >= n_kill_ops)


def test_chaos_same_seed_same_faultplan_is_deterministic():
    """Same traffic seed + same FaultPlan ⇒ identical report and tapes."""
    kind, seed = KINDS[0], 0
    _, plan_a, rep_a = _chaos_run(kind, seed)
    eng_b = _chaos_engine(kind, seed)
    plan_b, rep_b = eng_b.serve()
    np.testing.assert_array_equal(plan_a.op, plan_b.op)
    np.testing.assert_array_equal(plan_a.size, plan_b.size)
    np.testing.assert_array_equal(plan_a.ptr_ref, plan_b.ptr_ref)
    assert rep_a == rep_b
    for rk in range(SHAPE[0]):
        for ck in range(SHAPE[1]):
            ta = eng_b.trace(plan_a, rk, ck)
            tb = eng_b.trace(plan_b, rk, ck)
            for f in ("op", "size", "ptr_ref", "ptr_raw"):
                np.testing.assert_array_equal(getattr(ta, f),
                                              getattr(tb, f))


@pytest.mark.parametrize("kind", KINDS)
def test_migrated_tenant_tape_replays_bitwise(kind):
    """The migration destination core's session slice is a closed tape:
    replaying it standalone reproduces the serve responses bitwise."""
    eng, plan, report = next(
        (_chaos_run(kind, s) for s in range(N_SEEDS)
         if _chaos_run(kind, s)[2]["migrations"]), (None, None, None))
    if eng is None:
        pytest.skip(f"no migration triggered for {kind} in {N_SEEDS} seeds")
    rk, ck = report["migrations"][0]["dst"]
    tape = eng.trace(plan, rk, ck)          # raises if not closed
    resps, _, _ = replay(tape, kind)
    got = np.concatenate([np.asarray(seg.ptr) for seg in eng._resps],
                         axis=0)[:, rk, ck, :]
    np.testing.assert_array_equal(np.asarray(resps.ptr), got)


# --------------------------------------------------------------------------
# crash-vs-clean: snapshot / restore
# --------------------------------------------------------------------------
def test_snapshot_resume_matches_clean_run(tmp_path):
    """Mid-session snapshot → restore (same mesh wiring AND onto a
    shard_mapped mesh) finishes bitwise-equal to the uninterrupted run."""
    kind, seed = KINDS[0], 0
    _, plan_c, rep_c = _chaos_run(kind, seed)

    a = _chaos_engine(kind, seed).start()
    a.run_until(13)
    path = a.snapshot(str(tmp_path))
    assert os.path.exists(os.path.join(path, "COMMITTED"))
    assert os.path.exists(os.path.join(path, "host.json"))

    for mesh in (False, None):              # same wiring, then shard_mapped
        b = _chaos_engine(kind, seed, mesh=mesh)
        b.restore(str(tmp_path))
        assert b.r == 13
        plan_b, rep_b = b.finish()
        np.testing.assert_array_equal(plan_c.op, plan_b.op)
        np.testing.assert_array_equal(plan_c.ptr_ref, plan_b.ptr_ref)
        assert rep_c == rep_b, f"mesh={mesh}"


def test_restore_rejects_identity_mismatch(tmp_path):
    a = _chaos_engine(KINDS[0], 0).start()
    a.run_until(7)
    a.snapshot(str(tmp_path))
    wrong = ElasticFleetServe(_cfg(KINDS[0]), 2, 2,
                              traffic=_tc(seed=999), placement="chunked")
    with pytest.raises(ValueError, match="identity"):
        wrong.restore(str(tmp_path))


def test_restore_without_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        _chaos_engine(KINDS[0], 0).restore(str(tmp_path))


# --------------------------------------------------------------------------
# elastic == plain FleetServe when nothing elastic happens
# --------------------------------------------------------------------------
@pytest.mark.parametrize("placement", ["chunked", "least_loaded"])
def test_no_faults_no_migration_equals_fleetserve(placement):
    """The segmented driver is the same session as the one-shot scan."""
    cfg, tc = _cfg(), _tc()
    plan0, rep0 = FleetServe(cfg, 2, 2, traffic=tc,
                             placement=placement).serve()
    plan1, rep1 = ElasticFleetServe(cfg, 2, 2, traffic=tc,
                                    placement=placement).serve()
    np.testing.assert_array_equal(plan0.op, plan1.op)
    np.testing.assert_array_equal(plan0.size, plan1.size)
    np.testing.assert_array_equal(plan0.ptr_ref, plan1.ptr_ref)
    for k in rep0:                          # rep1 adds elastic extras
        assert rep0[k] == rep1[k], k


def test_epoch_drain_arena_session():
    """Epoch-mode chaos on an arena frontend: decisions at the boundaries
    (Temp blocks die at the reset — the free drain point), conservation
    and the no-drop guarantee intact."""
    tc = _tc(epoch_rounds=6, rounds=24)
    eng = ElasticFleetServe(
        _cfg("arena"), 2, 2, traffic=tc, placement="chunked",
        faults=FaultPlan.generate(seed=11, rounds=tc.rounds, shape=SHAPE,
                                  kills=1, stalls=1, drops=0),
        migration=_mig(drain="epoch"))
    plan, report = eng.serve()
    assert report["conservation_residual"] == 0
    assert report["dropped_frees"] == 0
    assert report["epoch_resets"] > 0
    # decisions happened exactly at epoch boundaries
    assert {p["round"] for p in report["pressure"]} <= set(
        fleet.drain_epoch(tc, 0))


# --------------------------------------------------------------------------
# fault-plan semantics (cheap targeted sessions)
# --------------------------------------------------------------------------
def test_stall_blocks_one_round_then_recovers():
    tc = _tc()
    stall_r = 9
    fp = FaultPlan((FaultEvent(stall_r, STALL, 0, 0),))
    plan, rep = ElasticFleetServe(_cfg(), 2, 2, traffic=tc,
                                  placement="chunked", faults=fp).serve()
    assert (plan.op[stall_r, 0, 0, :] == OP_NOOP).all()
    assert (plan.op[stall_r + 1:, 0, 0, :] != OP_NOOP).any()
    assert rep["dropped_frees"] == 0 and rep["conservation_residual"] == 0


def test_dropped_round_dispatches_nothing_fleetwide():
    tc = _tc()
    drop_r = 9
    fp = FaultPlan((FaultEvent(drop_r, DROP),))
    plan, rep = ElasticFleetServe(_cfg(), 2, 2, traffic=tc,
                                  placement="chunked", faults=fp).serve()
    assert (plan.op[drop_r] == OP_NOOP).all()
    assert plan.dispatched_per_round[drop_r] == 0
    assert rep["dropped_frees"] == 0 and rep["conservation_residual"] == 0


def test_kill_rehomes_tenants_and_replays_blocks():
    tc = _tc()
    fp = FaultPlan((FaultEvent(10, KILL, 0, 0),))
    plan, rep = ElasticFleetServe(_cfg(), 2, 2, traffic=tc,
                                  placement="chunked", faults=fp).serve()
    (kill,) = rep["kills"]
    assert kill["core"] == [0, 0]
    assert rep["killed_cores"] == [[0, 0]]
    # nothing dispatched to the dead core from the kill round on
    assert (plan.op[10:, 0, 0, :] == OP_NOOP).all()
    # re-homed tenants now home elsewhere
    for k in kill["tenants_rehomed"]:
        assert tuple(plan.tenant_home[k]) != (0, 0)
    assert rep["dropped_frees"] == 0 and rep["conservation_residual"] == 0


# --------------------------------------------------------------------------
# FaultPlan: generation, serialization, validation
# --------------------------------------------------------------------------
def test_faultplan_generate_deterministic_and_json_roundtrip():
    a = FaultPlan.generate(seed=5, rounds=32, shape=SHAPE, kills=2,
                           stalls=2, drops=1)
    b = FaultPlan.generate(seed=5, rounds=32, shape=SHAPE, kills=2,
                           stalls=2, drops=1)
    assert a == b
    assert FaultPlan.from_json(a.to_json()) == a
    assert len(a.events) == 5
    a.validate(SHAPE, 32)
    assert len(a.kill_rounds()) == len({e.round for e in a.events
                                        if e.kind == KILL})


def test_faultplan_validate_rejects_bad_plans():
    with pytest.raises(ValueError, match="round"):
        FaultPlan((FaultEvent(40, DROP),)).validate(SHAPE, 32)
    with pytest.raises(ValueError, match="core"):
        FaultPlan((FaultEvent(3, KILL, 7, 0),)).validate(SHAPE, 32)
    with pytest.raises(ValueError, match="once"):
        FaultPlan((FaultEvent(3, KILL, 0, 0),
                   FaultEvent(5, KILL, 0, 0))).validate(SHAPE, 32)
    with pytest.raises(AssertionError):
        FaultEvent(3, "melt")


# --------------------------------------------------------------------------
# divergence detection (pure host-side units, pinned thresholds)
# --------------------------------------------------------------------------
def test_hwm_divergence_triggers_past_ratio():
    div = telemetry.hwm_divergence([10_000, 2_000], ratio=2.0, min_bytes=1)
    assert div["trigger"] and div["hottest_rank"] == 0
    assert div["coldest_rank"] == 1 and div["ratio"] == 5.0


def test_hwm_divergence_quiet_inside_ratio():
    # 1.5x apart under a 2x threshold: must NOT trigger
    assert not telemetry.hwm_divergence([3_000, 2_000], ratio=2.0)["trigger"]
    # exactly at the threshold is not past it
    assert not telemetry.hwm_divergence([4_000, 2_000], ratio=2.0)["trigger"]
    assert telemetry.hwm_divergence([4_001, 2_000], ratio=2.0)["trigger"]


def test_hwm_divergence_min_bytes_floor():
    """An idle fleet (cold rank at 0) must not trigger on noise below the
    byte floor, and must not divide by zero."""
    quiet = telemetry.hwm_divergence([100, 0], ratio=2.0, min_bytes=4096)
    assert not quiet["trigger"]
    hot = telemetry.hwm_divergence([10_000, 0], ratio=2.0, min_bytes=4096)
    assert hot["trigger"] and hot["ratio"] == 10_000 / 4096
    with pytest.raises(ValueError):
        telemetry.hwm_divergence([])


def test_fleet_pressure_reads_fleet_telemetry():
    from repro.core import heap as heap_api
    state = heap_api.sharded_init(_cfg(), 2, 2)
    pres = telemetry.fleet_pressure(state)
    assert pres["live"].shape == (2, 2) and pres["rank_hwm"].shape == (2,)
    with pytest.raises(ValueError):
        telemetry.fleet_pressure(
            __import__("jax").tree.map(lambda x: x[0], state))


# --------------------------------------------------------------------------
# policy registries
# --------------------------------------------------------------------------
def test_migrate_hottest_tenant_moves_biggest_off_hot_rank():
    homes = {0: (0, 0), 1: (0, 1), 2: (1, 0)}
    tb = {0: 100, 1: 900, 2: 500}
    loads = np.array([[600.0, 400.0], [500.0, 10.0]])
    div = {"hottest_rank": 0, "coldest_rank": 1}
    moves = fleet.MIGRATIONS["hottest_tenant"](div, homes, tb, loads,
                                               SHAPE, max_moves=2)
    assert moves[0] == (1, (1, 1))          # biggest tenant, emptiest core
    assert moves[1][0] == 0                 # next-biggest on the hot rank
    assert all(dst[0] != 0 for _, dst in moves)


def test_migrate_hottest_tenant_avoids_dead_cores():
    homes = {0: (0, 0)}
    loads = np.array([[900.0, 900.0], [0.0, 50.0]])
    div = {"hottest_rank": 0, "coldest_rank": 1}
    moves = fleet.MIGRATIONS["hottest_tenant"](
        div, homes, {0: 10}, loads, SHAPE, dead={(1, 0)})
    assert moves == [(0, (1, 1))]


def test_migrate_none_is_inert():
    assert fleet.MIGRATIONS["none"]({"hottest_rank": 0}, {0: (0, 0)},
                                    {0: 1}, np.zeros((2, 2)), SHAPE) == []


def test_drain_policies():
    tc = _tc(epoch_rounds=6, rounds=24)
    assert fleet.DRAINS["epoch"](tc, 0) == [6, 12, 18]
    assert fleet.DRAINS["epoch"](_tc(rounds=24), 0) == []
    assert fleet.DRAINS["interval"](_tc(rounds=20), 8) == [8, 16]
    assert fleet.DRAINS["none"](tc, 8) == []


def test_migration_config_validates_policy_names():
    with pytest.raises(ValueError, match="migration policy"):
        MigrationConfig(policy="teleport")
    with pytest.raises(ValueError, match="drain"):
        MigrationConfig(drain="sometimes")
