"""Tight decode-vs-full-forward equality for every family with a decode path
(the dense check lives in test_arch_smoke; these cover moe/hybrid/encdec),
plus a vmapped multi-core allocator test (PIM-Metadata/PIM-Executed,
functionally)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import registry


def _roundtrip(arch, S=32, B=2, extra=None, rtol=7e-3):
    cfg = configs.get(arch).reduced()
    mod = registry.get_module(cfg)
    key = jax.random.PRNGKey(0)
    params = registry.init(cfg, key)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks}
    if extra:
        batch.update(extra(cfg, B, key))

    spec = mod.cache_spec(cfg, B, S + 32)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
    if "page_table" in cache:
        P = spec["page_table"].shape[1]
        cache["page_table"] = jnp.broadcast_to(
            jnp.arange(P, dtype=jnp.int32), (B, P)).copy()

    cache, _ = jax.jit(lambda p, b, c: mod.prefill(cfg, p, b, c))(
        params, batch, cache)
    nt = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0, cfg.vocab)
    cache, logits_dec = jax.jit(lambda p, c, b: mod.decode(cfg, p, c, b))(
        params, cache, {"tokens": nt})

    toks2 = jnp.concatenate([toks, nt], axis=1)
    if cfg.family == "audio":
        hidden = mod.forward(cfg, params, toks2, batch["enc_embeds"])
    else:
        hidden = mod.forward(cfg, params, toks2)
    logits_full = mod.logits_fn(cfg, params, hidden)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, -1]),
                               rtol=rtol, atol=rtol)


def test_moe_decode_matches_forward():
    _roundtrip("olmoe_1b_7b")


def test_qwen2_shared_experts_decode_matches_forward():
    _roundtrip("qwen2_moe_a2_7b")


def test_hybrid_decode_matches_forward():
    _roundtrip("recurrentgemma_9b")


def test_encdec_decode_matches_forward():
    def extra(cfg, B, key):
        return {"enc_embeds": jax.random.normal(
            key, (B, cfg.enc_frames, cfg.d_model), jnp.float32)}

    _roundtrip("whisper_small", extra=extra)


def test_vmapped_multicore_allocators():
    """One allocator per PIM core, vmapped: fully independent states/heaps —
    the paper's PIM-Metadata/PIM-Executed point, functionally."""
    from repro.core import pim_malloc as pm

    cfg = pm.PimMallocConfig(heap_bytes=1 << 18, num_threads=4)
    n_cores = 8
    states = jax.vmap(lambda _: pm.init(cfg))(jnp.arange(n_cores))
    # different request patterns per core
    sizes = jnp.asarray(
        np.random.RandomState(0).choice([16, 64, 256, 2048, 8192],
                                        size=(n_cores, 4)), jnp.int32)
    states, ptrs, ev = jax.vmap(lambda s, z: pm.malloc(cfg, s, z))(states, sizes)
    assert bool(jnp.all(ptrs >= 0))
    # core 0's state must equal a solo run with the same requests (isolation)
    solo = pm.init(cfg)
    solo, solo_ptrs, _ = pm.malloc(cfg, solo, sizes[0])
    np.testing.assert_array_equal(np.asarray(ptrs[0]), np.asarray(solo_ptrs))
    np.testing.assert_array_equal(np.asarray(states.buddy.longest[0]),
                                  np.asarray(solo.buddy.longest))
    # frees stay core-local too
    states, fev = jax.vmap(lambda s, p: pm.free(cfg, s, p))(states, ptrs)
    assert int(jnp.sum(states.stats.dropped_frees)) == 0
