"""Conformance + property tests for the fused ``pallas`` heap backend.

Three independent oracles pin the kernel:

1. the ``hwsw`` reference round (`system._protocol_round`) — bitwise
   equality of every response field (incl. modeled latency and buddy-cache
   hit/miss counters) and of the full state pytree, on the same legacy
   pointer-sequence tapes as tests/test_heap_api.py;
2. a plain-Python/NumPy heap model (`NpHeapModel`, below) — an
   implementation with ordinary control flow, no JAX — via seeded random
   op streams and hypothesis property tests (push/pop/refill
   interleavings, realloc class changes, exactly-full freelists);
3. the transform stack — MultiCoreHeap/ShardedHeap over the pallas step
   (vmap/shard_map of a `pallas_call`) must match the per-core step.

Everything runs in interpret mode on CPU (the CI `kernels` matrix entry
sets JAX_PLATFORMS=cpu explicitly).
"""
import functools
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import heap
from repro.core import pim_malloc as pm
from repro.core import system as sysm

from conftest import hypothesis_or_skip

given, settings, st_ = hypothesis_or_skip()

T = 4
HEAP = 1 << 18


def _cfg(kind, heap_bytes=HEAP, **pm_kw):
    pmc = pm.PimMallocConfig(heap_bytes=heap_bytes, num_threads=T, **pm_kw)
    return sysm.SystemConfig(kind=kind, heap_bytes=heap_bytes,
                             num_threads=T, pm=pmc)


def _stepper(cfg):
    state = {"st": heap.init(cfg)}
    step = jax.jit(functools.partial(heap.step, cfg))

    def run(req):
        state["st"], resp = step(state["st"], req)
        return resp

    return state, run


# ---------------------------------------------------------------- vs hwsw
def _assert_resp_equal(rp, rh, msg=""):
    for f in rp._fields:
        np.testing.assert_array_equal(np.asarray(getattr(rp, f)),
                                      np.asarray(getattr(rh, f)),
                                      err_msg=f"{msg} field={f}")


def _assert_state_equal(sp, sh, msg=""):
    for lp, lh in zip(jax.tree.leaves(sp), jax.tree.leaves(sh)):
        np.testing.assert_array_equal(np.asarray(lp), np.asarray(lh),
                                      err_msg=msg)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_pallas_matches_hwsw_on_legacy_tapes(seed):
    """Acceptance: the fused kernel is bitwise-conformant with hwsw on the
    legacy pointer-sequence suite — pointers, paths, latencies, cache
    hit/miss counters, and the complete state pytree after every round."""
    rng = random.Random(seed)
    cfg_p, cfg_h = _cfg("pallas"), _cfg("hwsw")
    sp, run_p = _stepper(cfg_p)
    sh, run_h = _stepper(cfg_h)
    live = [[] for _ in range(T)]
    for r in range(14):
        roll = rng.random()
        if roll < 0.5:
            sizes = jnp.array([rng.choice([16, 100, 256, 2048, 3000, 8192])
                               for _ in range(T)], jnp.int32)
            req = heap.malloc_request(sizes)
        elif roll < 0.75:
            ptrs = [live[t].pop(rng.randrange(len(live[t])))
                    if live[t] and rng.random() < 0.8 else -1
                    for t in range(T)]
            req = heap.free_request(jnp.array(ptrs, jnp.int32))
        else:
            ptrs = [live[t].pop(rng.randrange(len(live[t])))
                    if live[t] and rng.random() < 0.8 else -1
                    for t in range(T)]
            sizes = [rng.choice([0, 16, 100, 300, 3000, 8192])
                     for _ in range(T)]
            req = heap.realloc_request(jnp.array(ptrs, jnp.int32),
                                       jnp.array(sizes, jnp.int32))
        rp, rh = run_p(req), run_h(req)
        _assert_resp_equal(rp, rh, f"seed={seed} round={r}")
        _assert_state_equal(sp["st"], sh["st"], f"seed={seed} round={r}")
        for t in range(T):
            if int(rp.ptr[t]) >= 0:
                live[t].append(int(rp.ptr[t]))


def test_pallas_matches_hwsw_mixed_op_round():
    """One round mixing all five op codes, thread-per-op."""
    cfg_p, cfg_h = _cfg("pallas"), _cfg("hwsw")
    sp, run_p = _stepper(cfg_p)
    sh, run_h = _stepper(cfg_h)
    r0p = run_p(heap.malloc_request(jnp.array([64, 256, 64, 8192], jnp.int32)))
    r0h = run_h(heap.malloc_request(jnp.array([64, 256, 64, 8192], jnp.int32)))
    _assert_resp_equal(r0p, r0h)
    req = heap.AllocRequest(
        op=jnp.array([heap.OP_REALLOC, heap.OP_FREE, heap.OP_CALLOC,
                      heap.OP_NOOP], jnp.int32),
        size=jnp.array([8192, 0, 48, 0], jnp.int32),
        ptr=jnp.array([int(r0p.ptr[0]), int(r0p.ptr[1]), -1, -1], jnp.int32))
    _assert_resp_equal(run_p(req), run_h(req))
    _assert_state_equal(sp["st"], sh["st"])


def test_pallas_cache_size_sweep_matches_hwsw():
    """fig15-style sweeps work on the kernel path: the in-kernel LRU honors
    BuddyCacheConfig.n_entries and reproduces hwsw's hit/miss counters."""
    from repro.core.buddy_cache import BuddyCacheConfig

    for entries in (4, 16, 64):
        cfg_p = sysm.SystemConfig(kind="pallas", heap_bytes=HEAP,
                                  num_threads=T,
                                  bc=BuddyCacheConfig(n_entries=entries))
        cfg_h = sysm.SystemConfig(kind="hwsw", heap_bytes=HEAP,
                                  num_threads=T,
                                  bc=BuddyCacheConfig(n_entries=entries))
        _, run_p = _stepper(cfg_p)
        _, run_h = _stepper(cfg_h)
        tot_p = tot_h = 0
        for _ in range(4):
            sizes = jnp.array([4096, 8192, 4096, 16384], jnp.int32)
            rp, rh = run_p(heap.malloc_request(sizes)), \
                run_h(heap.malloc_request(sizes))
            _assert_resp_equal(rp, rh, f"entries={entries}")
            tot_p += int(jnp.sum(rp.meta_hits))
            tot_h += int(jnp.sum(rh.meta_hits))
        assert tot_p == tot_h
        if entries >= 16:
            assert tot_p > 0  # a warm cache must actually hit


def test_pallas_multicore_and_sharded_match_single_core():
    """vmap/shard_map over the fused kernel == per-core steps, bitwise."""
    C = 3
    cfg = _cfg("pallas", heap_bytes=1 << 18)
    mch = heap.MultiCoreHeap(cfg, num_cores=C)
    singles = [_stepper(cfg) for _ in range(C)]
    rng = np.random.RandomState(0)
    for _ in range(3):
        sizes = rng.choice([16, 100, 2048, 8192], size=(C, T)).astype(np.int32)
        resp = mch.malloc(jnp.asarray(sizes))
        for c, (stc, runc) in enumerate(singles):
            rc = runc(heap.malloc_request(jnp.asarray(sizes[c])))
            np.testing.assert_array_equal(np.asarray(resp.ptr[c]),
                                          np.asarray(rc.ptr))
            np.testing.assert_allclose(np.asarray(resp.latency_cyc[c]),
                                       np.asarray(rc.latency_cyc))
    sh = heap.ShardedHeap(cfg, num_ranks=1, num_cores=C, mesh=False)
    sizes = jnp.asarray(rng.choice([16, 256], size=(1, C, T)).astype(np.int32))
    r = sh.malloc(sizes)
    assert r.ptr.shape == (1, C, T)
    assert bool((r.ptr >= 0).all())


# ------------------------------------------------- NumPy reference model
def _np_next_pow2(x):
    return 1 if x <= 1 else 1 << (int(x) - 1).bit_length()


class NpBuddy:
    """Array-buddy (`longest[]`) with plain Python control flow."""

    def __init__(self, heap_bytes, min_block):
        self.heap, self.minb = heap_bytes, min_block
        n = 2 * (heap_bytes // min_block)
        self.longest = np.zeros(n, np.int64)
        for i in range(1, n):
            self.longest[i] = heap_bytes >> (i.bit_length() - 1)

    def alloc(self, size):
        size = max(_np_next_pow2(size), self.minb)
        if size > self.heap or self.longest[1] < size:
            return -1
        node, node_size = 1, self.heap
        while node_size > size:
            node = 2 * node if self.longest[2 * node] >= size else 2 * node + 1
            node_size >>= 1
        off = node * node_size - self.heap
        self.longest[node] = 0
        while node > 1:
            node >>= 1
            self.longest[node] = max(self.longest[2 * node],
                                     self.longest[2 * node + 1])
        return off

    def free(self, off, size):
        size = max(_np_next_pow2(size), self.minb)
        node = (off + self.heap) // size
        if not (0 <= off < self.heap and self.longest[node] == 0):
            return
        self.longest[node] = size
        nsize = size
        while node > 1:
            node >>= 1
            psize = nsize << 1
            l, r = self.longest[2 * node], self.longest[2 * node + 1]
            self.longest[node] = psize if (l == nsize and r == nsize) \
                else max(l, r)
            nsize = psize


class NpHeapModel:
    """Pointer-semantics model of one protocol round (no cost model)."""

    def __init__(self, cfg: pm.PimMallocConfig, prepopulate=True):
        self.cfg = cfg
        self.buddy = NpBuddy(cfg.heap_bytes, cfg.block_bytes)
        self.stacks = [[[] for _ in cfg.size_classes]
                       for _ in range(cfg.num_threads)]
        self.block_cls = {}
        self.big = {}
        if prepopulate:
            for t in range(cfg.num_threads):
                for c, csize in enumerate(cfg.size_classes):
                    off = self.buddy.alloc(cfg.block_bytes)
                    if off < 0:
                        continue
                    sub = cfg.block_bytes // csize
                    self.stacks[t][c] = [off + i * csize for i in range(sub)]
                    self.block_cls[off // cfg.block_bytes] = c

    def _class_of(self, z):
        cfg = self.cfg
        rounded = _np_next_pow2(max(z, min(cfg.size_classes)))
        lg = rounded.bit_length() - 1
        return min(max(lg - cfg.log2_min_class, 0), cfg.nc - 1)

    def _meta(self, ptr, size):
        cfg = self.cfg
        valid = 0 <= ptr < cfg.heap_bytes
        b = ptr // cfg.block_bytes if valid else 0
        small_old = valid and b in self.block_cls
        big_old = (valid and not small_old and b in self.big
                   and ptr % cfg.block_bytes == 0)
        old_bytes = (cfg.size_classes[self.block_cls[b]] if small_old
                     else (1 << self.big[b]) if big_old else 0)
        new_small = size <= cfg.max_class
        new_bytes = (cfg.size_classes[self._class_of(size)] if new_small
                     else _np_next_pow2(max(size, cfg.block_bytes)))
        in_place = ((small_old and new_small) or (big_old and not new_small)) \
            and new_bytes == old_bytes
        return small_old or big_old, in_place

    def _malloc_phase(self, sizes, active):
        cfg = self.cfg
        ptrs = [-1] * cfg.num_threads
        backend = []
        for t in range(cfg.num_threads):
            size = sizes[t]
            if not active[t] or size <= 0:
                continue
            if size > cfg.heap_bytes:
                continue  # too big: fails without touching the backend
            if size <= cfg.max_class:
                c = self._class_of(size)
                if self.stacks[t][c]:
                    ptrs[t] = self.stacks[t][c].pop()  # case 1: LIFO hit
                else:
                    backend.append((t, c, "refill"))
            else:
                backend.append((t, size, "bypass"))
        for t, arg, kind in backend:  # serial backend, thread order
            if kind == "refill":
                c = arg
                off = self.buddy.alloc(cfg.block_bytes)
                if off < 0:
                    continue
                csize = cfg.size_classes[c]
                sub = cfg.block_bytes // csize
                self.stacks[t][c] = [off + i * csize for i in range(sub)]
                ptrs[t] = self.stacks[t][c].pop()
                self.block_cls[off // cfg.block_bytes] = c
            else:
                alloc_size = _np_next_pow2(max(arg, cfg.block_bytes))
                off = self.buddy.alloc(alloc_size)
                if off < 0:
                    continue
                self.big[off // cfg.block_bytes] = \
                    alloc_size.bit_length() - 1
                ptrs[t] = off
        return ptrs

    def _free_phase(self, ptrs, active):
        cfg = self.cfg
        bigs = []
        for t in range(cfg.num_threads):
            ptr = ptrs[t]
            if not active[t] or not 0 <= ptr < cfg.heap_bytes:
                continue
            b = ptr // cfg.block_bytes
            if b in self.block_cls:
                c = self.block_cls[b]
                if len(self.stacks[t][c]) < cfg.cap:
                    self.stacks[t][c].append(ptr)  # else: dropped free
            elif b in self.big and ptr % cfg.block_bytes == 0:
                bigs.append((t, ptr, b))
        for _, ptr, b in bigs:  # serial backend, thread order
            self.buddy.free(ptr, 1 << self.big[b])
            del self.big[b]

    def round(self, op, size, ptr):
        cfg = self.cfg
        Tn = cfg.num_threads
        metas = [self._meta(ptr[t], size[t]) for t in range(Tn)]
        re_live = [op[t] == heap.OP_REALLOC and size[t] > 0 for t in range(Tn)]
        in_place = [re_live[t] and metas[t][1] for t in range(Tn)]
        moved = [re_live[t] and not metas[t][1] for t in range(Tn)]
        re_free0 = [op[t] == heap.OP_REALLOC and size[t] <= 0 and ptr[t] >= 0
                    for t in range(Tn)]
        is_alloc = [op[t] in (heap.OP_MALLOC, heap.OP_CALLOC)
                    for t in range(Tn)]
        m_active = [(is_alloc[t] and size[t] > 0) or moved[t]
                    for t in range(Tn)]
        mptrs = self._malloc_phase(
            [size[t] if m_active[t] else 0 for t in range(Tn)], m_active)
        mok = [m_active[t] and mptrs[t] >= 0 for t in range(Tn)]
        f_active = [op[t] == heap.OP_FREE
                    or (moved[t] and metas[t][0] and mok[t]) or re_free0[t]
                    for t in range(Tn)]
        self._free_phase([ptr[t] if f_active[t] else -1 for t in range(Tn)],
                         f_active)
        return [mptrs[t] if (is_alloc[t] and mok[t]) or (moved[t] and mok[t])
                else ptr[t] if in_place[t] else -1 for t in range(Tn)]

    def assert_freelists_match(self, state):
        """Counts + live stack prefixes must equal the kernel state."""
        counts = np.asarray(state.alloc.counts)
        stacks = np.asarray(state.alloc.stacks)
        for t in range(self.cfg.num_threads):
            for c in range(self.cfg.nc):
                model = self.stacks[t][c]
                assert counts[t, c] == len(model), (t, c)
                np.testing.assert_array_equal(stacks[t, c, :len(model)],
                                              np.array(model, np.int32),
                                              err_msg=f"t={t} c={c}")


def _drive_model_vs_kernel(cfg, rounds, seed, sizes_pool):
    """Shared driver: random op rounds, kernel vs NumPy model, live-ptr
    tracked per thread; asserts pointer equality + freelist state."""
    rng = random.Random(seed)
    model = NpHeapModel(cfg.pm)
    sp, run = _stepper(cfg)
    live = [[] for _ in range(T)]
    for _ in range(rounds):
        roll = rng.random()
        ops, sizes, ptrs = [], [], []
        for t in range(T):
            if roll < 0.45:
                ops.append(heap.OP_MALLOC)
                sizes.append(rng.choice(sizes_pool))
                ptrs.append(-1)
            elif roll < 0.75:
                p = live[t].pop(rng.randrange(len(live[t]))) \
                    if live[t] and rng.random() < 0.85 else -1
                ops.append(heap.OP_FREE)
                sizes.append(0)
                ptrs.append(p)
            else:
                p = live[t].pop(rng.randrange(len(live[t]))) \
                    if live[t] and rng.random() < 0.85 else -1
                ops.append(heap.OP_REALLOC)
                sizes.append(rng.choice([0] + list(sizes_pool)))
                ptrs.append(p)
        req = heap.AllocRequest(op=jnp.array(ops, jnp.int32),
                                size=jnp.array(sizes, jnp.int32),
                                ptr=jnp.array(ptrs, jnp.int32))
        resp = run(req)
        want = model.round(ops, sizes, ptrs)
        assert [int(p) for p in resp.ptr] == want
        model.assert_freelists_match(sp["st"])
        for t in range(T):
            if int(resp.ptr[t]) >= 0:
                live[t].append(int(resp.ptr[t]))


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_kernel_matches_numpy_model(seed):
    _drive_model_vs_kernel(_cfg("pallas"), rounds=12, seed=seed,
                           sizes_pool=(16, 100, 256, 2048, 3000, 8192))


def test_kernel_matches_numpy_model_tiny_cap():
    """Exactly-full freelists: a small-cap config makes push-at-capacity (dropped
    frees) and refill-after-drain reachable within a few rounds."""
    cfg = _cfg("pallas", size_classes=(512, 1024, 2048), cap=8)
    _drive_model_vs_kernel(cfg, rounds=14, seed=7,
                           sizes_pool=(512, 700, 1024, 2048, 8192))


def test_exactly_full_stack_drops_free():
    """Deterministic capacity edge: the 9th push to a cap-8 freelist must be
    dropped (path 2) and leave the stack untouched — on kernel and model."""
    cfg = _cfg("pallas", size_classes=(512, 1024, 2048), cap=8)
    sp, run = _stepper(cfg)
    model = NpHeapModel(cfg.pm)
    # drain thread 0's 512 B list (8 sub-blocks) then give them all back
    got = []
    for _ in range(8):
        resp = run(heap.malloc_request(
            jnp.array([512, 0, 0, 0], jnp.int32)))
        model.round([heap.OP_MALLOC, 0, 0, 0], [512, 0, 0, 0], [-1] * 4)
        assert int(resp.path[0]) in (0, 1)
        got.append(int(resp.ptr[0]))
    for p in got:
        run(heap.free_request(jnp.array([p, -1, -1, -1], jnp.int32)))
        model.round([heap.OP_FREE, 0, 0, 0], [0] * 4, [p, -1, -1, -1])
    model.assert_freelists_match(sp["st"])
    assert int(sp["st"].alloc.counts[0, 0]) == cfg.pm.cap  # exactly full
    # one more free of a foreign 512 B sub-block: overflow -> dropped
    resp = run(heap.malloc_request(jnp.array([0, 512, 0, 0], jnp.int32)))
    model.round([0, heap.OP_MALLOC, 0, 0], [0, 512, 0, 0], [-1] * 4)
    foreign = int(resp.ptr[1])
    resp = run(heap.free_request(jnp.array([foreign, -1, -1, -1], jnp.int32)))
    model.round([heap.OP_FREE, 0, 0, 0], [0] * 4, [foreign, -1, -1, -1])
    assert int(resp.path[0]) == 2 and not bool(resp.ok[0])
    assert int(sp["st"].alloc.counts[0, 0]) == cfg.pm.cap
    model.assert_freelists_match(sp["st"])


def test_realloc_class_changes_on_kernel():
    """Realloc across size classes: in-place, grow-move, bypass promotion."""
    cfg = _cfg("pallas")
    sp, run = _stepper(cfg)
    r0 = run(heap.malloc_request(jnp.full((T,), 100, jnp.int32)))
    r1 = run(heap.realloc_request(
        r0.ptr, jnp.array([128, 65, 300, 8192], jnp.int32)))
    assert int(r1.ptr[0]) == int(r0.ptr[0]) and not bool(r1.moved[0])
    assert int(r1.ptr[1]) == int(r0.ptr[1]) and not bool(r1.moved[1])
    assert bool(r1.moved[2]) and int(r1.ptr[2]) != int(r0.ptr[2])
    assert bool(r1.moved[3]) and int(r1.ptr[3]) % cfg.pm.block_bytes == 0
    # the vacated 128 B sub-blocks return LIFO to threads 2/3's freelists
    r2 = run(heap.malloc_request(jnp.full((T,), 128, jnp.int32)))
    assert int(r2.ptr[2]) == int(r0.ptr[2])
    assert int(r2.ptr[3]) == int(r0.ptr[3])


def test_table2_facade_on_pallas_kind():
    """The paper-facing facade selects the fused kernel via kind="pallas"."""
    from repro.core.api import initAllocator

    a = initAllocator(1 << 18, num_threads=T, kind="pallas")
    p1 = a.pimMalloc(100)
    p2 = a.pimCalloc(16, 16)
    assert p1 >= 0 and p2 >= 0 and p1 != p2
    assert a.pimRealloc(p1, 90) == p1          # same class: in place
    p3 = a.pimRealloc(p1, 2048)                # bigger class: moves
    assert p3 >= 0 and p3 != p1
    a.pimFree(p2), a.pimFree(p3)
    st = a.stats
    assert st["front_hits"] >= 2 and st["frees_small"] >= 2
    a.gc()                                     # shared PimMallocState layout


# --------------------------------------------------- hypothesis properties
@given(st_.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=12, deadline=None)
def test_prop_random_streams_match_numpy_model(seed):
    """Property: on arbitrary mixed op streams the fused kernel and the
    NumPy model agree on every pointer and on the freelist state."""
    _drive_model_vs_kernel(_cfg("pallas"), rounds=8, seed=seed,
                           sizes_pool=(16, 100, 256, 2048, 3000, 8192))


@given(st_.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_prop_tiny_cap_streams_match_numpy_model(seed):
    """Property: same agreement at the cache-capacity edge (cap=8 stacks
    hit exactly-full on real streams)."""
    cfg = _cfg("pallas", size_classes=(512, 1024, 2048), cap=8)
    _drive_model_vs_kernel(cfg, rounds=10, seed=seed,
                           sizes_pool=(512, 700, 1024, 2048, 8192))


# --------------------------------------------- batched refill fast path
def _cfg_batch(batch):
    pmc = pm.PimMallocConfig(heap_bytes=HEAP, num_threads=T)
    return sysm.SystemConfig(kind="pallas", heap_bytes=HEAP, num_threads=T,
                             pm=pmc, kernel_batch_refill=batch)


@pytest.mark.parametrize("seed", [0, 3, 7])
def test_batched_refill_bitwise_equals_serial_walk(seed):
    """Acceptance: `kernel_batch_refill` is a pure wall-clock knob — on
    arbitrary mixed streams the batched kernel and the forced-serial kernel
    (and hwsw) agree bitwise on every response field and state leaf."""
    rng = random.Random(seed)
    steppers = [(_stepper(_cfg_batch(True))), (_stepper(_cfg_batch(False))),
                (_stepper(_cfg("hwsw")))]
    live = [[] for _ in range(T)]
    for r in range(12):
        roll = rng.random()
        if roll < 0.6:
            sizes = jnp.array([rng.choice([16, 64, 256, 2048, 4096, 8192])
                               for _ in range(T)], jnp.int32)
            req = heap.malloc_request(sizes)
        else:
            ptrs = [live[t].pop(rng.randrange(len(live[t])))
                    if live[t] and rng.random() < 0.8 else -1
                    for t in range(T)]
            req = heap.free_request(jnp.array(ptrs, jnp.int32))
        resps = [run(req) for _, run in steppers]
        _assert_resp_equal(resps[0], resps[1], f"batch-vs-serial round={r}")
        _assert_resp_equal(resps[0], resps[2], f"batch-vs-hwsw round={r}")
        _assert_state_equal(steppers[0][0]["st"], steppers[1][0]["st"],
                            f"state batch-vs-serial round={r}")
        _assert_state_equal(steppers[0][0]["st"], steppers[2][0]["st"],
                            f"state batch-vs-hwsw round={r}")
        for t in range(T):
            if int(resps[0].ptr[t]) >= 0:
                live[t].append(int(resps[0].ptr[t]))


def test_batched_refill_covers_all_backend_branches():
    """Crafted rounds drive each lax.switch branch — empty-skip (all-hit),
    vectorized run-carve (block-granularity refills AND 4096-byte
    bypasses), and the serial fallback (odd >block bypass class) — plus
    the backend-free coalescing round; every one stays bitwise-equal."""
    sp, run_p = _stepper(_cfg_batch(True))
    ss, run_s = _stepper(_cfg_batch(False))
    sh, run_h = _stepper(_cfg("hwsw"))

    def check(req, msg):
        rp, rs, rh = run_p(req), run_s(req), run_h(req)
        _assert_resp_equal(rp, rs, msg + " (vs serial)")
        _assert_resp_equal(rp, rh, msg + " (vs hwsw)")
        _assert_state_equal(sp["st"], ss["st"], msg + " state (vs serial)")
        _assert_state_equal(sp["st"], sh["st"], msg + " state (vs hwsw)")
        return rp

    # branch 0: prepopulated freelists -> all-hit round, no backend op
    check(heap.malloc_request(jnp.array([32] * T, jnp.int32)), "all-hit")
    # branch 1 (bypass flavor): 4096 == block_bytes -> run-carve
    r_b = check(heap.malloc_request(jnp.array([4096] * T, jnp.int32)),
                "block bypass")
    # branch 1 (refill flavor): drain one class then re-alloc it
    for _ in range(pm.PimMallocConfig(heap_bytes=HEAP, num_threads=T
                                      ).block_bytes // 256 + 2):
        req = heap.malloc_request(jnp.array([256] * T, jnp.int32))
        last = check(req, "drain 256B class")
        if int(np.asarray(last.path)[0]) == 1:  # refill round reached
            break
    # mixed refill + block bypass in one round still takes the fast path
    check(heap.malloc_request(jnp.array([256, 4096, 256, 4096], jnp.int32)),
          "mixed refill+bypass")
    # branch 2: odd class (8192 > block_bytes) falls back to the serial walk
    check(heap.malloc_request(jnp.array([8192, 256, 8192, 16], jnp.int32)),
          "odd-class fallback")
    # backend free (fbig): the free-phase skip cond must take the loop
    check(heap.free_request(r_b.ptr), "buddy coalescing frees")


def test_batch_refill_env_default(monkeypatch):
    """PIM_MALLOC_BATCH_REFILL gates the default; explicit config wins."""
    from repro.kernels import heap_step
    monkeypatch.delenv("PIM_MALLOC_BATCH_REFILL", raising=False)
    assert heap_step._batch_refill_default() is True
    monkeypatch.setenv("PIM_MALLOC_BATCH_REFILL", "0")
    assert heap_step._batch_refill_default() is False
    monkeypatch.setenv("PIM_MALLOC_BATCH_REFILL", "off")
    assert heap_step._batch_refill_default() is False
    monkeypatch.setenv("PIM_MALLOC_BATCH_REFILL", "1")
    assert heap_step._batch_refill_default() is True
