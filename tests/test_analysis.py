"""pimcheck: jaxpr-level verifier passes, fixtures, tape lint, CLI.

Three contracts from ISSUE 6:

* every seeded-bug fixture is flagged by exactly the pass it was planted
  for (`check_fixtures` is pimcheck's own self-test);
* every *real* registered backend is green — zero active findings across
  all deployment tiers, with no suppressions doing the work;
* the same-round pointer-race rule the differential fuzzer enforces by
  construction is exported as `trace_lint` and gates both the recorder
  (`RecordingAllocator.finish`) and tape replay (`check_trace`).
"""
import json

import numpy as np
import pytest

from repro.core import heap
from repro.analysis import passes as ap
from repro.analysis import pimcheck
from repro.workloads.trace import Trace, trace_lint

# ---------------------------------------------------------------- fixtures


def test_every_fixture_is_flagged_by_its_pass():
    rows, failures = pimcheck.check_fixtures()
    assert failures == []
    assert {r["target"] for r in rows} == {
        "fixture:float_leak", "fixture:unclamped_index",
        "fixture:aliased_scatter", "fixture:dropped_donation"}
    assert all(r["flagged_by_expected"] for r in rows)


def test_fixture_findings_name_the_right_pass():
    from repro.analysis.fixtures import FIXTURES
    for name, (_fn, expect_pass) in FIXTURES.items():
        tr = pimcheck.trace_fixture(name)
        active, _sup = ap.run_passes(tr)
        assert any(f.pass_name == expect_pass for f in active), \
            f"{name}: {[f.fmt() for f in active]}"
        assert all(f.severity in ("error", "warn") for f in active)


# ----------------------------------------------------- real kinds are green


@pytest.mark.parametrize("tier", pimcheck.TIERS)
def test_all_registered_kinds_are_clean(tier):
    rows, active, suppressed = pimcheck.check_kinds(heap.kinds(), (tier,))
    assert active == [], [f.fmt() for f in active]
    # green must come from sound passes, not suppression entries
    assert suppressed == []
    assert len(rows) == len(heap.kinds())
    assert all(r["eqns"] > 0 for r in rows)


def test_trace_kind_exposes_calling_convention():
    tr = pimcheck.trace_kind("hwsw", "single")
    assert tr.target == "hwsw" and tr.tier == "single"
    assert tr.n_state_in == tr.n_state_out  # donated-state discipline
    assert len(tr.state_invars) == tr.n_state_in
    assert len(tr.req_invars) == 3  # (op, size, ptr)


# ------------------------------------------------------------- suppressions


def test_suppression_mechanism(monkeypatch):
    f = ap.Finding("int-width", "hwsw", "single", "error",
                   "synthetic 64-bit dtype for the mechanism test")
    assert ap.suppression_for(f) is None
    monkeypatch.setattr(ap, "SUPPRESSIONS", (
        ("int-width", "hw*", "64-bit", "mechanism test entry"),))
    assert ap.suppression_for(f) == "mechanism test entry"
    # non-matching pass / target / substring all miss
    import dataclasses
    assert ap.suppression_for(
        dataclasses.replace(f, pass_name="donation")) is None
    assert ap.suppression_for(
        dataclasses.replace(f, target="sw")) is None
    assert ap.suppression_for(
        dataclasses.replace(f, message="no match here")) is None


def test_shipped_suppression_list_is_empty():
    """The calibration sweep turned every candidate suppression into a
    sharper pass rule; keep it that way unless a justified entry lands."""
    assert ap.SUPPRESSIONS == ()


# ---------------------------------------------------------------- tape lint


def _tape(op, size, ptr_ref, ptr_raw, T=4):
    op = np.asarray(op, np.int32)
    return Trace(name="synthetic", heap_bytes=1 << 18, num_threads=T,
                 recorded_kind="hwsw", description="lint unit tape",
                 op=op, size=np.asarray(size, np.int32),
                 ptr_ref=np.asarray(ptr_ref, np.int32),
                 ptr_raw=np.asarray(ptr_raw, np.int32))


def test_trace_lint_clean_tape():
    tape = _tape(op=[[1, 1, 0, 0], [2, 2, 0, 0]],
                 size=[[64, 64, 0, 0], [0, 0, 0, 0]],
                 ptr_ref=[[-1] * 4, [0, 1, -1, -1]],
                 ptr_raw=[[-1] * 4, [0, 64, -1, -1]])
    assert trace_lint(tape) == []


def test_trace_lint_flags_unknown_op():
    tape = _tape(op=[[9, 0, 0, 0]], size=[[0] * 4],
                 ptr_ref=[[-1] * 4], ptr_raw=[[-1] * 4])
    errs = trace_lint(tape)
    assert len(errs) == 1 and "[lint:ops]" in errs[0]


def test_trace_lint_flags_forward_and_same_round_refs():
    # slot 4 belongs to round 1 itself (same-round), slot 99 is out of tape
    tape = _tape(op=[[1, 1, 0, 0], [2, 2, 0, 0]],
                 size=[[64, 64, 0, 0], [0] * 4],
                 ptr_ref=[[-1] * 4, [4, 99, -1, -1]],
                 ptr_raw=[[-1] * 4, [0, 0, -1, -1]])
    errs = trace_lint(tape)
    assert len(errs) == 2 and all("[lint:refs]" in e for e in errs)


def test_trace_lint_flags_duplicate_chain_race():
    tape = _tape(op=[[1, 0, 0, 0], [2, 3, 0, 0]],
                 size=[[64, 0, 0, 0], [0, 128, 0, 0]],
                 ptr_ref=[[-1] * 4, [0, 0, -1, -1]],
                 ptr_raw=[[-1] * 4, [0, 0, -1, -1]])
    errs = trace_lint(tape)
    assert any("[lint:race-A]" in e for e in errs)


def test_trace_lint_flags_suspect_free_racing_creator():
    # thread 0 frees a garbage raw pointer while thread 1 mallocs
    tape = _tape(op=[[2, 1, 0, 0]], size=[[0, 64, 0, 0]],
                 ptr_ref=[[-1] * 4], ptr_raw=[[12345, -1, -1, -1]])
    errs = trace_lint(tape)
    assert len(errs) == 1 and "[lint:race-B]" in errs[0]
    # the same suspect free alone (no creator in-round) is legal misuse
    solo = _tape(op=[[2, 0, 0, 0]], size=[[0] * 4],
                 ptr_ref=[[-1] * 4], ptr_raw=[[12345, -1, -1, -1]])
    assert trace_lint(solo) == []


def test_recorder_finish_refuses_racy_rounds():
    import jax.numpy as jnp
    from repro.workloads.trace import RecordingAllocator

    rec = RecordingAllocator(heap_bytes=1 << 18, num_threads=4, kind="hwsw")
    r = rec.request(heap.malloc_request(jnp.array([64, 0, 0, 0], jnp.int32)))
    # same round: free thread-0's live block by raw pointer (unmapped ref
    # would be fine) while thread 1 mallocs -> race-B
    rec.request(heap.AllocRequest(
        op=jnp.array([heap.OP_FREE, heap.OP_MALLOC, 0, 0], jnp.int32),
        size=jnp.array([0, 64, 0, 0], jnp.int32),
        ptr=jnp.array([999_984, -1, -1, -1], jnp.int32)))
    with pytest.raises(ValueError, match="race-B"):
        rec.finish("racy")
    assert rec.finish("racy", lint=False).rounds == 2
    assert int(r.ptr[0]) >= 0


def test_committed_tapes_pass_lint():
    import glob
    paths = sorted(glob.glob(pimcheck.DEFAULT_TAPES))
    assert len(paths) >= 3
    rows, errors = pimcheck.lint_tapes(paths)
    assert errors == []
    assert all(r["findings"] == 0 for r in rows)


# ----------------------------------------------------------------- the CLI


def test_cli_green_on_real_kinds(tmp_path, capsys):
    out = tmp_path / "report.json"
    rc = pimcheck.main(["--kinds", "strawman,sw", "--tiers", "single",
                        "--json", str(out)])
    assert rc == 0
    report = json.loads(out.read_text())
    assert report["findings"] == []
    assert len(report["rows"]) == 2
    assert "pimcheck" in capsys.readouterr().out


def test_cli_red_on_bad_tape(tmp_path):
    bad = _tape(op=[[2, 1, 0, 0]], size=[[0, 64, 0, 0]],
                ptr_ref=[[-1] * 4], ptr_raw=[[777, -1, -1, -1]])
    path = tmp_path / "bad.json"
    bad.save(str(path))
    rc = pimcheck.main(["--tiers", "single", "--tapes", str(path)])
    assert rc == 1


def test_cli_red_when_a_pass_is_disabled_for_its_fixture():
    """Running --fixtures with only the donation pass must report the
    three fixtures whose planted bug needs a different pass."""
    rc = pimcheck.main(["--tiers", "single", "--fixtures",
                        "--passes", "donation"])
    assert rc == 1


def test_cli_step_summary_written(tmp_path, monkeypatch):
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    rc = pimcheck.main(["--kinds", "strawman", "--tiers", "single"])
    assert rc == 0
    text = summary.read_text()
    assert "## pimcheck" in text and "✅" in text
