"""Tests for the loop-aware HLO analyzer (roofline tooling).

Validated against XLA's own cost_analysis on UNROLLED programs (where
cost_analysis is exact), and against hand-computed trip scaling."""
import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.launch import hlo_analysis as H


def _compile(f, *sds):
    return jax.jit(f).lower(*sds).compile()


def test_dot_flops_match_cost_analysis_unrolled():
    def f(x, w):
        for _ in range(3):
            x = jnp.tanh(x @ w)
        return x

    c = _compile(f, jax.ShapeDtypeStruct((64, 128), jnp.float32),
                 jax.ShapeDtypeStruct((128, 128), jnp.float32))
    res = H.analyze(c.as_text())
    ca = H.cost_analysis_dict(c)
    assert res["flops_scaled"] == pytest.approx(ca["flops"], rel=0.01)


def test_scan_trip_scaling():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scan_f(x, ws):
        return lax.scan(body, x, ws)[0]

    def unroll_f(x, ws):
        for i in range(5):
            x, _ = body(x, ws[i])
        return x

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    r_scan = H.analyze(_compile(scan_f, x, ws).as_text())
    r_unroll = H.analyze(_compile(unroll_f, x, ws).as_text())
    # loop-scaled scan flops == unrolled flops (xla cost_analysis gets 1/5)
    assert r_scan["flops_scaled"] == pytest.approx(r_unroll["flops_scaled"],
                                                   rel=0.01)


def test_nested_scan_multipliers():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def f(x, ws):
        def outer(c, _):
            y, _ = lax.scan(body, c, ws)
            return y, None

        return lax.scan(outer, x, None, length=3)[0]

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    res = H.analyze(_compile(f, x, ws).as_text())
    one = 2 * 32 * 64 * 64
    assert res["flops_scaled"] == pytest.approx(12 * one, rel=0.01)


def test_collective_detection_and_bytes():
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((len(jax.devices()),), ("d",))

    def f(x):
        return x * 2.0

    sh = NamedSharding(mesh, P("d"))
    rep = NamedSharding(mesh, P(None))
    c = jax.jit(f, in_shardings=sh, out_shardings=rep).lower(
        jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile()
    res = H.analyze(c.as_text())
    if len(jax.devices()) > 1:
        assert res["collective_bytes_scaled"] > 0
    sched = H.collective_schedule(c.as_text())
    assert isinstance(sched, list)


def test_tuple_shape_instruction_parsing():
    """while ops with long tuple shapes + /*index=N*/ comments parse."""
    line = ("  %while.1 = (s32[], f32[16,2]{1,0}, /*index=2*/pred[]) "
            "while(%tuple), condition=%c, body=%b, "
            'backend_config={"known_trip_count":{"n":"7"}}')
    parsed = H._parse_instr(line)
    assert parsed is not None
    name, shape, op = parsed
    assert op == "while" and "f32[16,2]" in shape
    assert H._shape_bytes(shape) == 4 + 16 * 2 * 4 + 1
