"""Direct coverage for repro.checkpoint.ckpt (the elastic tier's substrate).

Save/restore round-trips over real heap-state pytrees for every registered
backend, the dtype-drift regression (the shardings path used to device_put
raw npz arrays with only shape checked — a drifted dtype restored silently
wrong), AsyncCheckpointer exception propagation, the COMMITTED-marker
contract, and restore-onto-a-different-mesh parity.
"""
import os
import threading

import numpy as np
import pytest

import jax

from repro.checkpoint import ckpt
from repro.core import heap as heap_api
from repro.core import system as sysm
from repro.core.heap import (OP_FREE, OP_MALLOC, OP_REALLOC, AllocRequest,
                             MultiCoreHeap)

from conftest import hypothesis_or_skip

given, settings, st = hypothesis_or_skip()

T = 4
HEAP = 1 << 16


def _cfg(kind):
    return sysm.SystemConfig(kind=kind, heap_bytes=HEAP, num_threads=T)


def _churned_state(kind, seed=0, rounds=6):
    """A heap state that has actually worked: malloc/free/realloc churn."""
    heap = MultiCoreHeap(_cfg(kind), num_cores=2)
    rng = np.random.default_rng(seed)
    ptrs = np.full((2, T), -1, np.int64)
    for _ in range(rounds):
        op = rng.choice([OP_MALLOC, OP_FREE, OP_REALLOC], (2, T))
        has = ptrs >= 0
        op = np.where((op != OP_MALLOC) & ~has, OP_MALLOC, op).astype(np.int32)
        size = rng.choice([32, 128, 2048], (2, T)).astype(np.int32)
        resp = heap.step(AllocRequest(op=jax.numpy.asarray(op),
                                      size=jax.numpy.asarray(size),
                                      ptr=jax.numpy.asarray(
                                          ptrs.astype(np.int32))))
        rp = np.asarray(resp.ptr)
        ptrs = np.where(op == OP_FREE, -1, np.where(rp >= 0, rp, ptrs))
    return heap.state


def _assert_tree_equal(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        assert np.asarray(x).dtype == np.asarray(y).dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --------------------------------------------------------------------------
# round-trips over every backend's real state pytree
# --------------------------------------------------------------------------
@pytest.mark.parametrize("kind", heap_api.kinds())
def test_save_restore_roundtrip_every_backend(kind, tmp_path):
    state = _churned_state(kind)
    path = ckpt.save(state, 3, str(tmp_path))
    assert os.path.exists(os.path.join(path, "COMMITTED"))
    assert ckpt.latest_step(str(tmp_path)) == 3
    back = ckpt.restore(state, 3, str(tmp_path))
    _assert_tree_equal(state, back)


@pytest.mark.parametrize("kind", ("sw", "hwsw"))
def test_restore_into_shapedtypestruct_templates(kind, tmp_path):
    """Restore needs only shapes/dtypes, not live arrays — the elastic
    resume path restores into eval_shape templates."""
    state = _churned_state(kind, seed=1)
    ckpt.save(state, 0, str(tmp_path))
    templates = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype),
        state)
    back = ckpt.restore(templates, 0, str(tmp_path))
    _assert_tree_equal(state, back)


@given(seed=st.integers(min_value=0, max_value=1 << 30))
@settings(max_examples=12, deadline=None)
def test_property_roundtrip_random_pytrees(seed):
    """Property: irregular pytrees (nested dicts/lists, mixed dtypes,
    0-d scalars) round-trip exactly through the flatten-key naming."""
    import tempfile
    rng = np.random.default_rng(seed)
    tree = {
        "a": rng.integers(-100, 100, int(rng.integers(1, 5)),
                          dtype=np.int32),
        "b": [rng.random(3).astype(np.float32),
              {"c": rng.integers(0, 2, (2, 2)).astype(bool)}],
        "d": np.int64(rng.integers(1 << 40)),
    }
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(tree, 0, d)
        back = ckpt.restore(tree, 0, d)
        _assert_tree_equal(tree, back)


def test_seeded_roundtrip_many_steps(tmp_path):
    """latest_step tracks the newest committed step across many saves."""
    rng = np.random.default_rng(7)
    for step in range(8):
        tree = {"x": rng.integers(-5, 5, 4, dtype=np.int32)}
        ckpt.save(tree, step, str(tmp_path))
        _assert_tree_equal(tree, ckpt.restore(tree, step, str(tmp_path)))
    assert ckpt.latest_step(str(tmp_path)) == 7


# --------------------------------------------------------------------------
# the dtype-drift regression (satellite fix)
# --------------------------------------------------------------------------
def test_restore_casts_drifted_dtype_losslessly(tmp_path):
    """A writer/restorer dtype drift must cast (when lossless) instead of
    restoring bits under the wrong type — on BOTH restore paths."""
    saved = {"x": np.arange(8, dtype=np.int64)}
    ckpt.save(saved, 0, str(tmp_path))
    want = {"x": np.zeros(8, np.int32)}
    back = ckpt.restore(want, 0, str(tmp_path))
    assert np.asarray(back["x"]).dtype == np.int32
    np.testing.assert_array_equal(np.asarray(back["x"]), saved["x"])

    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    back_sh = ckpt.restore(want, 0, str(tmp_path),
                           shardings={"x": sharding})
    assert np.asarray(back_sh["x"]).dtype == np.int32
    np.testing.assert_array_equal(np.asarray(back_sh["x"]), saved["x"])


def test_restore_refuses_lossy_dtype_cast(tmp_path):
    """Values that do not survive the cast (an int64 pointer truncated to
    int32) must raise, not silently corrupt — with and without shardings."""
    ckpt.save({"x": np.array([1 << 40], np.int64)}, 0, str(tmp_path))
    want = {"x": np.zeros(1, np.int32)}
    with pytest.raises(ValueError, match="lossy"):
        ckpt.restore(want, 0, str(tmp_path))
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    with pytest.raises(ValueError, match="lossy"):
        ckpt.restore(want, 0, str(tmp_path), shardings={"x": sharding})


def test_restore_rejects_shape_mismatch(tmp_path):
    ckpt.save({"x": np.zeros((4,), np.int32)}, 0, str(tmp_path))
    with pytest.raises(AssertionError):
        ckpt.restore({"x": np.zeros((5,), np.int32)}, 0, str(tmp_path))


# --------------------------------------------------------------------------
# AsyncCheckpointer
# --------------------------------------------------------------------------
def test_async_checkpointer_saves_and_waits(tmp_path):
    acp = ckpt.AsyncCheckpointer(str(tmp_path))
    tree = {"x": np.arange(10, dtype=np.int32)}
    acp.save(tree, 1)
    acp.save(tree, 2)
    paths = acp.wait()
    assert len(paths) == 2
    assert ckpt.latest_step(str(tmp_path)) == 2
    _assert_tree_equal(tree, ckpt.restore(tree, 2, str(tmp_path)))


def test_async_checkpointer_exception_propagates_through_wait(tmp_path):
    """A failed background save must surface at wait(), not vanish on the
    worker thread."""
    blocker = os.path.join(str(tmp_path), "step_00000005")
    with open(blocker, "w") as f:        # step dir path is a FILE:
        f.write("in the way")            # os.makedirs must fail
    acp = ckpt.AsyncCheckpointer(str(tmp_path))
    acp.save({"x": np.zeros(2)}, 5)
    with pytest.raises(OSError):
        acp.wait()
    assert ckpt.latest_step(str(tmp_path)) is None


def test_async_checkpointer_snapshots_before_mutation(tmp_path):
    """The tree is host-snapshotted synchronously: mutating the source
    array after save() must not corrupt the checkpoint."""
    gate = threading.Event()
    orig = ckpt.save

    def slow_save(tree, step, ckpt_dir):
        gate.wait(5)
        return orig(tree, step, ckpt_dir)

    x = np.arange(6, dtype=np.int32)
    acp = ckpt.AsyncCheckpointer(str(tmp_path))
    ckpt.save, saved_fn = slow_save, ckpt.save
    try:
        acp.save({"x": x}, 0)
    finally:
        ckpt.save = saved_fn
    x[:] = -1                            # mutate after the enqueue
    gate.set()
    acp.wait()
    back = ckpt.restore({"x": np.zeros(6, np.int32)}, 0, str(tmp_path))
    np.testing.assert_array_equal(np.asarray(back["x"]), np.arange(6))


# --------------------------------------------------------------------------
# COMMITTED-marker contract
# --------------------------------------------------------------------------
def test_partial_save_without_committed_is_ignored(tmp_path):
    ckpt.save({"x": np.zeros(2)}, 1, str(tmp_path))
    ckpt.save({"x": np.zeros(2)}, 4, str(tmp_path))
    os.remove(os.path.join(str(tmp_path), "step_00000004", "COMMITTED"))
    assert ckpt.latest_step(str(tmp_path)) == 1
    assert ckpt.latest_step(os.path.join(str(tmp_path), "nope")) is None


# --------------------------------------------------------------------------
# restore onto a different mesh: re-placed leaves, identical values
# --------------------------------------------------------------------------
def test_restore_onto_mesh_parity(tmp_path):
    """A fleet state saved from plain (vmap) arrays restores under a rank
    mesh's NamedSharding with identical values — the elastic re-placement
    path (`ElasticFleetServe.restore(mesh=None)` builds on this)."""
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.parallel.meshctx import make_rank_mesh
    cfg = _cfg("sw")
    state = heap_api.sharded_init(cfg, 1, 2)
    ckpt.save(state, 0, str(tmp_path))
    mesh = make_rank_mesh(1, "ranks")
    sh = jax.tree.map(
        lambda _: NamedSharding(mesh, PartitionSpec("ranks")), state)
    back = ckpt.restore(state, 0, str(tmp_path), shardings=sh)
    for leaf in jax.tree_util.tree_leaves(back):
        assert leaf.sharding.mesh.axis_names == ("ranks",)
    _assert_tree_equal(state, back)
