"""shard_map flash-decoding == single-device reference (run in a subprocess
with 8 faked devices so the XLA flag never leaks)."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.kvcache import paged
from repro.parallel.meshctx import activate_mesh

mesh = jax.make_mesh((2, 4), ("data", "model"))
B, Pn, page, KVH, hd, H = 4, 8, 16, 2, 32, 4
rng = np.random.RandomState(0)
q = jnp.asarray(rng.randn(B, H, hd), jnp.float32) * 0.3
kn = jnp.asarray(rng.randn(B, KVH, hd), jnp.float32) * 0.3
vn = jnp.asarray(rng.randn(B, KVH, hd), jnp.float32) * 0.3
kp = jnp.asarray(rng.randn(B, Pn, page, KVH, hd), jnp.float32) * 0.3
vp = jnp.asarray(rng.randn(B, Pn, page, KVH, hd), jnp.float32) * 0.3
# non-identity page tables (as the allocator would hand out under churn)
pt = jnp.asarray([rng.permutation(Pn) for _ in range(B)], jnp.int32)
pos = jnp.asarray(rng.randint(10, Pn * page - 2, B), jnp.int32)

kp_r = paged.write_token(kp, kn, pt, pos)
vp_r = paged.write_token(vp, vn, pt, pos)
o_r = paged.attend(q, kp_r, vp_r, pt, pos + 1)

with activate_mesh(mesh):
    o_s, kp_s, vp_s = jax.jit(lambda *a: paged.write_attend_seqpar(*a))(
        q, kn, vn, kp, vp, pt, pos)
np.testing.assert_allclose(np.asarray(o_s), np.asarray(o_r), atol=3e-5,
                           rtol=3e-5)
np.testing.assert_array_equal(np.asarray(kp_s), np.asarray(kp_r))
np.testing.assert_array_equal(np.asarray(vp_s), np.asarray(vp_r))
# no-mesh fallback path agrees too
o_f, kp_f, vp_f = paged.write_attend_seqpar(q, kn, vn, kp, vp, pt, pos)
np.testing.assert_allclose(np.asarray(o_f), np.asarray(o_r), atol=3e-5,
                           rtol=3e-5)
print("seqpar-ok")
"""


def test_seqpar_flash_decoding_matches_reference():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "seqpar-ok" in out.stdout
