"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (full configs
are exercised only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import registry
from repro.models.config import ShapeConfig

SMOKE_SHAPE = ShapeConfig("smoke", 64, 2, "train")


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_train_step_smoke(arch):
    cfg = configs.get(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = registry.init(cfg, key)
    batch = registry.make_train_batch(cfg, SMOKE_SHAPE, key)

    lf = registry.loss_fn(cfg)
    (l, metrics), grads = jax.jit(jax.value_and_grad(lf, has_aux=True))(
        params, batch)
    assert np.isfinite(float(l)), (arch, float(l))
    # all grads finite and shaped like params
    for p, g in zip(jax.tree.leaves(params), jax.tree.leaves(grads)):
        assert p.shape == g.shape
        assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", ["granite_3_8b", "mamba2_130m",
                                  "recurrentgemma_9b", "whisper_small",
                                  "olmoe_1b_7b", "paligemma_3b"])
def test_prefill_decode_smoke(arch):
    """One representative arch per family: prefill + 2 decode steps."""
    cfg = configs.get(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = registry.init(cfg, key)
    mod = registry.get_module(cfg)

    B, S = 2, 32
    total = S + (cfg.n_patches if cfg.family == "vlm" else 0)
    total = -(-total // cfg.page_size) * cfg.page_size  # page-align prefill
    batch = registry.make_train_batch(cfg, ShapeConfig("s", total, B, "train"),
                                      key, global_batch=B)
    batch.pop("labels")

    spec = mod.cache_spec(cfg, B, total + 32)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
    if "page_table" in cache:
        P = spec["page_table"].shape[1]
        cache["page_table"] = (jnp.arange(B)[:, None] * P
                               + jnp.arange(P)[None, :]).astype(jnp.int32)

    cache, logits = jax.jit(lambda p, b, c: mod.prefill(cfg, p, b, c))(
        params, batch, cache)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch

    dec = jax.jit(lambda p, c, b: mod.decode(cfg, p, c, b))
    for i in range(2):
        nt = jax.random.randint(jax.random.PRNGKey(i), (B, 1), 0, cfg.vocab)
        cache, logits = dec(params, cache, {"tokens": nt})
        assert logits.shape == (B, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch


def test_decode_matches_forward_dense_family():
    """Paged decode == full forward for the dense template (tight check)."""
    cfg = configs.get("granite_3_8b").reduced()
    key = jax.random.PRNGKey(2)
    params = registry.init(cfg, key)
    from repro.models import transformer as tf
    from repro.kvcache import paged

    B, S = 2, 31
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    cache = paged.init_cache(n_layers=cfg.n_layers, batch=B, max_seq=48,
                             page_size=cfg.page_size, kv_heads=cfg.n_kv_heads,
                             head_dim=cfg.head_dim, dtype=cfg.dtype)
    # S=31 not page-aligned -> pad to 32 for prefill, then drop one
    toks_p = jnp.pad(toks, ((0, 0), (0, 1)))
    cache, _ = jax.jit(lambda p, b, c: tf.prefill(cfg, p, b, c))(
        params, {"tokens": toks_p}, cache)
    cache["seq_lens"] = jnp.full((B,), S, jnp.int32)  # logical length 31

    nt = jax.random.randint(jax.random.PRNGKey(3), (B, 1), 0, cfg.vocab)
    cache, logits_dec = jax.jit(lambda p, c, b: tf.decode(cfg, p, c, b))(
        params, cache, {"tokens": nt})
    full = tf.logits_fn(cfg, params, tf.forward(
        cfg, params, jnp.concatenate([toks, nt], axis=1)))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(full[:, -1]), rtol=5e-3, atol=5e-3)


def test_flash_equals_dense_attention():
    from repro.models import layers
    key = jax.random.PRNGKey(4)
    B, S, H, KVH, D = 2, 256, 4, 2, 32
    q = jax.random.normal(key, (B, S, H, D)) * 0.3
    k = jax.random.normal(jax.random.PRNGKey(5), (B, S, KVH, D)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(6), (B, S, KVH, D)) * 0.3
    for causal, window in [(True, 0), (True, 64), (False, 0)]:
        a = layers.attention(q, k, v, causal=causal, window=window)
        f = layers.flash_attention(q, k, v, causal=causal, window=window,
                                   block_q=64, block_kv=64)
        np.testing.assert_allclose(np.asarray(a), np.asarray(f),
                                   rtol=2e-4, atol=2e-4), (causal, window)
