"""The CI workflows are config-as-code: pin their syntax and the invariants
this repo's lanes rely on (bench-wall step, nightly dispatchability, pip
caching) so a stray YAML edit fails tier-1 instead of the first push."""
import os

import pytest

yaml = pytest.importorskip("yaml")

ROOT = os.path.join(os.path.dirname(__file__), os.pardir)
WF = os.path.join(ROOT, ".github", "workflows")


def _load(name):
    with open(os.path.join(WF, name)) as f:
        return yaml.safe_load(f)


def _steps(job):
    return job.get("steps", [])


def _run_text(job):
    return "\n".join(s.get("run", "") for s in _steps(job))


def test_ci_workflow_is_valid_yaml_with_expected_jobs():
    doc = _load("ci.yml")
    assert set(doc["jobs"]) >= {"lint", "analysis", "tier1", "bench-smoke"}


def test_tier1_matrix_has_decode_smoke_lane():
    """Acceptance: the decode serving tier rides tier-1 — the suite plus
    the example CLI as a closed-loop smoke."""
    job = _load("ci.yml")["jobs"]["tier1"]
    lanes = {e["suite"]: e["run"]
             for e in job["strategy"]["matrix"]["include"]}
    assert "decode-smoke" in lanes
    assert "tests/test_serve_decode.py" in lanes["decode-smoke"]
    assert "examples/serve_decode.py --smoke" in lanes["decode-smoke"]


def test_tier1_matrix_has_chaos_smoke_lane():
    """Acceptance: the elastic-fleet chaos harness (fault injection,
    heap-pressure migration, snapshot/restore) and the checkpoint
    substrate suite ride tier-1 with a bounded seed sweep."""
    job = _load("ci.yml")["jobs"]["tier1"]
    lanes = {e["suite"]: e["run"]
             for e in job["strategy"]["matrix"]["include"]}
    assert "chaos-smoke" in lanes
    assert "tests/test_elastic_fleet.py" in lanes["chaos-smoke"]
    assert "tests/test_checkpoint.py" in lanes["chaos-smoke"]
    assert "CHAOS_SEEDS=" in lanes["chaos-smoke"]


def test_tier1_fuzz_smoke_lane_runs_kind_conformance():
    """Acceptance: the registry-generic conformance suite (which enrolls
    arena/tlregion in conservation, C-edges, digest-stability, and
    arena-inner parity) rides the fuzz-smoke lane on every PR."""
    job = _load("ci.yml")["jobs"]["tier1"]
    lanes = {e["suite"]: e["run"]
             for e in job["strategy"]["matrix"]["include"]}
    assert "tests/test_kind_conformance.py" in lanes["fuzz-smoke"]


def test_analysis_lane_has_region_frontend_pimcheck_cell():
    """Acceptance: arena+tlregion are pimcheck-traced at every deployment
    tier as an explicit CI cell (and with zero suppressions — the
    SUPPRESSIONS list ships empty, pinned by tests/test_analysis.py)."""
    text = _run_text(_load("ci.yml")["jobs"]["analysis"])
    assert "--kinds arena,tlregion" in text
    assert "--tiers single,vmap,sharded" in text


def test_bench_smoke_job_runs_wall_lane_and_both_gates():
    """Acceptance: the bench-wall step runs the wall-clock lane, the wall
    gate is exercised (not skipped) with --lane wall, and the JSON rides
    the uploaded artifact."""
    job = _load("ci.yml")["jobs"]["bench-smoke"]
    text = _run_text(job)
    assert "fig14_wall" in text and "bench_wall.json" in text
    assert "--lane wall" in text and "--fail-over-wall" in text
    assert "--lane modeled" in text
    assert "wall_report.py" in text
    upload = [s for s in _steps(job)
              if "upload-artifact" in str(s.get("uses", ""))]
    assert upload and "bench_wall.json" in upload[0]["with"]["path"]


def test_nightly_workflow_scheduled_and_dispatchable():
    """The nightly lane must be cron-scheduled AND workflow_dispatch-able
    (the acceptance path for syntax validation), run the non-smoke sweep,
    and raise the fuzzer budget above the PR smoke lane's 15."""
    doc = _load("nightly.yml")
    trig = doc.get("on") or doc.get(True)  # yaml 1.1 parses bare `on:` as True
    assert "schedule" in trig and "workflow_dispatch" in trig
    jobs = doc["jobs"]
    bench = _run_text(jobs["bench-full"])
    assert "benchmarks.run" in bench and "--smoke" not in bench
    fuzz = _run_text(jobs["fuzz-deep"])
    assert "FUZZ_MAX_EXAMPLES=" in fuzz
    budget = int(fuzz.split("FUZZ_MAX_EXAMPLES=")[1].split()[0])
    assert budget > 15


def test_nightly_chaos_sweep_deepens_the_smoke_lane():
    """The nightly chaos sweep must rerun the elastic harness with a
    strictly wider seed sweep than the per-PR chaos-smoke lane."""
    tier1 = _load("ci.yml")["jobs"]["tier1"]
    lanes = {e["suite"]: e["run"]
             for e in tier1["strategy"]["matrix"]["include"]}
    smoke = int(lanes["chaos-smoke"].split("CHAOS_SEEDS=")[1].split()[0])
    sweep_text = _run_text(_load("nightly.yml")["jobs"]["chaos-sweep"])
    assert "tests/test_elastic_fleet.py" in sweep_text
    deep = int(sweep_text.split("CHAOS_SEEDS=")[1].split()[0])
    assert deep > smoke


def test_all_setup_python_steps_cache_pip():
    """Every job in every workflow must enable actions/setup-python pip
    caching — cold dependency installs dominate lane latency."""
    for wf in ("ci.yml", "nightly.yml"):
        for jname, job in _load(wf)["jobs"].items():
            for s in _steps(job):
                if "setup-python" in str(s.get("uses", "")):
                    cfg = s.get("with", {})
                    assert cfg.get("cache") == "pip", f"{wf}:{jname}"
                    assert cfg.get("cache-dependency-path"), f"{wf}:{jname}"


def test_pytest_timeout_session_default_configured():
    """pyproject pins a session-wide pytest-timeout default and the plugin
    is in requirements.txt, so CI hangs fail fast."""
    with open(os.path.join(ROOT, "pyproject.toml")) as f:
        py = f.read()
    assert "timeout = " in py.split("[tool.pytest.ini_options]")[1]
    with open(os.path.join(ROOT, "requirements.txt")) as f:
        assert "pytest-timeout" in f.read()
