"""Cross-backend differential fuzzing: randomized `AllocRequest` streams
replayed through every `heap.REGISTRY` kind plus the `PyPimMalloc` oracle.

The generator emits symbolic tapes (the ``pim-malloc-trace/v1`` ref
encoding, so one stream drives every backend closed-loop against its OWN
pointers) full of allocator abuse: interleaved malloc/free/realloc/calloc,
NULL and garbage pointers, cross-round double frees, realloc-after-free,
zero/negative/overflowing sizes, and capacity-exhausting bursts. Every
stream must satisfy the repo's established contract:

  * ``pallas`` == ``hwsw`` bitwise on the full response stream,
  * ``sw`` == ``hwsw`` on the semantic fields (ptr/ok/path/moved),
  * heap-telemetry conservation holds for every kind (strawman included),
  * ``hwsw`` == the plain-Python `PyPimMalloc.request` oracle
    pointer-for-pointer, with conservation checked after every round.

Two deliberate generator constraints, both excluding C-level data races no
backend promises to price consistently (all four kinds still agree with
each other on them — only the *conservation accounting* is off, because a
round is priced against its pre-round metadata):

  * at most one op per *pointer chain* (a malloc and the reallocs
    descending from it) per round — two same-round frees of one pointer
    race on the backend mutex;
  * frees whose target metadata may be absent pre-round (cross-round
    double frees, stale pre-realloc pointers, garbage raws) only appear in
    dedicated *misuse rounds* containing no metadata-creating ops.
    Otherwise the malloc phase can recycle the freed offset in the same
    round and the free phase — which reads live metadata — frees the
    brand-new block: free(p) racing a malloc that just returned p, a
    use-after-free by construction.

Cross-round misuse IS generated and must be dropped (path 2) or served
deterministically-identically by every backend.

The seeded deterministic subset below replays >= 200 randomized rounds per
backend; CI runs it in the tier1 ``fuzz-smoke`` lane. With hypothesis
installed, property variants widen the stream space under a bounded,
derandomized example budget (FUZZ_MAX_EXAMPLES).
"""
import functools
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import hypothesis_or_skip
from repro.core import heap, system as sysm, telemetry
from repro.core.oracle import PyArena, PyPimMalloc
from repro.workloads.replay import replay, replay_all_kinds
from repro.workloads.trace import Trace

given, settings, st = hypothesis_or_skip()

T = 4
HEAP = 1 << 19
INT32_MAX = np.iinfo(np.int32).max
SMOKE_SEEDS = (0, 1, 2)
SMOKE_ROUNDS = int(os.environ.get("FUZZ_ROUNDS", "80"))
MAX_EXAMPLES = int(os.environ.get("FUZZ_MAX_EXAMPLES", "15"))

GARBAGE_PTRS = (-7, 3, 17, 4096, HEAP - 16, HEAP + 104, 1 << 21)
# negative sizes are raw-protocol territory: a MALLOC/CALLOC with size <= 0
# is idle (path -1), a REALLOC with size <= 0 and a live ptr is free(p)
ALLOC_SIZES = (-5, 0, 1, 16, 48, 100, 256, 1024, 2047, 2048, 2049, 4096,
               12000, HEAP, HEAP * 2)
REALLOC_SIZES = (1, 16, 48, 100, 256, 1024, 2047, 2048)
BURST_SIZES = (4096, 8192, 1 << 14, 1 << 15, HEAP // 4)
CALLOC_SIZES = (-3, 16, 64, 1024, 4096, INT32_MAX)


def fuzz_trace(seed: int, rounds: int = SMOKE_ROUNDS, num_threads: int = T,
               heap_bytes: int = HEAP, clean: bool = False) -> Trace:
    """One randomized symbolic tape (deterministic in `seed`).

    The generator is *oracle-guided*: it steps a `PyPimMalloc` alongside
    generation, so it knows the concrete pointer value behind every slot and
    the exact set of live values. That knowledge enforces the two UB
    exclusions from the module docstring — misuse targets are verified
    dead-by-value at selection time, and misuse rounds carry no
    metadata-creating ops. ``clean=True`` drops the misuse rounds and
    garbage pointers entirely: every alloc freed at most once through its
    latest producer slot — well-formed under ANY correct allocator, which is
    what lets one clean tape check conservation on ``strawman`` too (its
    placements differ from the oracle's, so value-guided misuse does not
    transfer).
    """
    rng = np.random.default_rng(seed)
    op = np.zeros((rounds, num_threads), np.int32)
    size = np.zeros_like(op)
    ref = np.full_like(op, -1)
    raw = np.full_like(op, -1)

    py = PyPimMalloc(heap_bytes=heap_bytes, num_threads=num_threads)
    n_slots = rounds * num_threads
    vals = np.full((n_slots,), -1, np.int64)  # oracle value per slot
    live_vals = set()
    # chain = one malloc + the reallocs descending from it: {"slot": latest
    # producing slot, "stale": earlier slots, "live": not yet retired}
    chains = []

    def pick(pool, used):
        pool = [c for c in pool if id(c) not in used]
        return pool[int(rng.integers(len(pool)))] if pool else None

    for r in range(rounds):
        u0 = rng.random()
        misuse = (not clean) and u0 < 0.18
        burst = not misuse and u0 > 0.88
        used = set()                       # chains touched this round
        actions = [None] * num_threads     # (kind, chain) to reconcile
        for t in range(num_threads):
            slot = r * num_threads + t
            live = [c for c in chains if c["live"]]
            dead_safe = [c for c in chains if not c["live"]
                         and vals[c["slot"]] not in live_vals]
            if misuse:
                v = rng.random()
                op[r, t] = heap.OP_FREE
                if v < 0.30:               # cross-round double free
                    c = pick(dead_safe, used)
                    if c is not None:
                        used.add(id(c))
                        ref[r, t] = c["slot"]
                        continue
                if v < 0.45:               # free a stale pre-realloc slot
                    pool = [c for c in chains if any(
                        vals[s] not in live_vals for s in c["stale"])]
                    c = pick(pool, used)
                    if c is not None:
                        used.add(id(c))
                        cand = [s for s in c["stale"]
                                if vals[s] not in live_vals]
                        ref[r, t] = cand[int(rng.integers(len(cand)))]
                        continue
                if v < 0.62:               # raw garbage pointer
                    g = [g for g in GARBAGE_PTRS if g not in live_vals]
                    if g:
                        raw[r, t] = int(rng.choice(g))
                    continue
                if v < 0.72:               # NULL free: benign by contract
                    continue
                if v < 0.85:               # realloc(dead_ptr, 0)
                    c = pick(dead_safe, used)
                    if c is not None:
                        used.add(id(c))
                        op[r, t] = heap.OP_REALLOC
                        ref[r, t] = c["slot"]
                        continue
                c = pick(live, used)       # plain retire (safe anywhere)
                if c is not None:
                    used.add(id(c))
                    ref[r, t] = c["slot"]
                    actions[t] = ("free", c)
                continue
            u = rng.random()
            if burst or u < 0.40 or not live:
                op[r, t] = heap.OP_MALLOC
                size[r, t] = int(rng.choice(BURST_SIZES if burst
                                            else ALLOC_SIZES))
                if size[r, t] > 0:
                    actions[t] = ("alloc", None)
            elif u < 0.50:
                op[r, t] = heap.OP_CALLOC
                size[r, t] = int(rng.choice(CALLOC_SIZES))
                if size[r, t] > 0:
                    actions[t] = ("alloc", None)
            elif u < 0.72:                 # retire a live chain
                c = pick(live, used)
                op[r, t] = heap.OP_FREE
                if c is not None:
                    used.add(id(c))
                    ref[r, t] = c["slot"]
                    actions[t] = ("free", c)
            else:                          # REALLOC
                w = rng.random()
                op[r, t] = heap.OP_REALLOC
                size[r, t] = int(rng.choice(REALLOC_SIZES))
                c = pick(live, used)
                if w < 0.80 and c is not None:
                    used.add(id(c))
                    ref[r, t] = c["slot"]
                    if rng.random() < 0.15:
                        # realloc(p, <=0) == free(p) at the raw protocol
                        size[r, t] = int(rng.choice((0, -5)))
                        actions[t] = ("free", c)
                    else:
                        actions[t] = ("realloc", c)
                elif not clean and w < 0.90:   # raw garbage ptr realloc
                    g = [g for g in GARBAGE_PTRS if g not in live_vals]
                    if g:
                        raw[r, t] = int(rng.choice(g))
                else:                      # realloc(NULL, n) == malloc
                    actions[t] = ("alloc", None)

        # -- advance the oracle guide and reconcile chain/value state -----
        resolved = np.where(ref[r] >= 0,
                            vals[np.clip(ref[r], 0, n_slots - 1)],
                            raw[r]).astype(np.int64)
        out = py.request(op[r].tolist(), size[r].tolist(), resolved.tolist())
        for t in range(num_threads):
            slot = r * num_threads + t
            p_new = int(out["ptr"][t])
            vals[slot] = p_new
            if actions[t] is None:
                continue
            kind, c = actions[t]
            if kind == "alloc":
                if p_new >= 0:
                    live_vals.add(p_new)
                    chains.append({"slot": slot, "stale": [], "live": True})
            elif kind == "free":
                if out["path"][t] in (0, 1):
                    live_vals.discard(int(resolved[t]))
                    c["live"] = False
            elif kind == "realloc":
                if out["ok"][t]:
                    if out["moved"][t]:
                        live_vals.discard(int(resolved[t]))
                    live_vals.add(p_new)
                    c["stale"].append(c["slot"])
                    c["slot"] = slot
                # on failure the old block stays intact: chain unchanged
    return Trace(name=f"fuzz_{seed}", heap_bytes=heap_bytes,
                 num_threads=num_threads, recorded_kind="hwsw",
                 description=f"differential fuzz stream seed={seed}",
                 op=op, size=size, ptr_ref=ref, ptr_raw=raw)


def assert_stream_contract(trace: Trace, kinds=None):
    """The cross-backend contract every fuzz stream must satisfy."""
    results = replay_all_kinds(trace, kinds)
    reps = {k: rep for k, (_, rep) in results.items()}
    for kind, rep in reps.items():
        assert rep["telemetry"]["conservation_residual"] == 0, \
            f"{trace.name}/{kind}: conservation violated"
    if "pallas" in reps and "hwsw" in reps:
        assert reps["pallas"]["digest_full"] == reps["hwsw"]["digest_full"], \
            f"{trace.name}: pallas != hwsw bitwise"
    if "sw" in reps and "hwsw" in reps:
        assert reps["sw"]["digest_sem"] == reps["hwsw"]["digest_sem"], \
            f"{trace.name}: sw != hwsw on semantic fields"
    return reps


# --------------------------------------------------------------------------
# deterministic smoke subset (the CI fuzz-smoke lane): >= 200 rounds/backend
# --------------------------------------------------------------------------
@pytest.mark.parametrize("seed", SMOKE_SEEDS)
def test_fuzz_misuse_stream_contract(seed):
    """Misuse streams (double frees, garbage pointers, realloc-after-free)
    through the pim family: sw/hwsw/pallas parity + conservation."""
    trace = fuzz_trace(seed)
    reps = assert_stream_contract(trace, kinds=("sw", "hwsw", "pallas"))
    # the streams genuinely exercise the nasty paths
    assert reps["hwsw"]["dropped_frees"] > 0, "no misuse generated?"
    assert reps["hwsw"]["ops"] > SMOKE_ROUNDS  # multi-op rounds


@pytest.mark.parametrize("seed", SMOKE_SEEDS)
def test_fuzz_clean_stream_contract_all_kinds(seed):
    """Well-formed streams through ALL four kinds (strawman included):
    parity + conservation on every backend."""
    trace = fuzz_trace(seed + 100, clean=True)
    reps = assert_stream_contract(trace)
    assert set(reps) == set(heap.kinds())


def test_fuzz_total_rounds_meet_acceptance():
    """>= 200 randomized rounds per backend in the CI smoke configuration
    (strawman sees the clean streams; the pim family sees both)."""
    assert len(SMOKE_SEEDS) * SMOKE_ROUNDS >= 200


def test_fuzz_exhaustion_bursts_fail_cleanly():
    """Capacity-exhausting bursts must produce path-3 failures (not crashes,
    not pointer reuse) and keep conservation intact."""
    trace = fuzz_trace(seed=7, rounds=60, clean=True)
    resps, _, rep = replay(trace, "hwsw")
    assert rep["failed_allocs"] > 0, "bursts never exhausted the heap?"
    assert rep["telemetry"]["conservation_residual"] == 0
    # every successful alloc in one round returns distinct pointers
    ptr = np.asarray(resps.ptr)
    ok = np.asarray(resps.ok)
    isal = np.isin(trace.op, (heap.OP_MALLOC, heap.OP_CALLOC))
    for r in range(trace.rounds):
        got = ptr[r][isal[r] & ok[r]]
        assert len(set(got.tolist())) == got.shape[0]


def test_fuzz_replay_is_deterministic():
    """Same tape, two replays: bitwise-identical response streams."""
    trace = fuzz_trace(seed=1, rounds=24)
    r1, _, rep1 = replay(trace, "hwsw")
    r2, _, rep2 = replay(trace, "hwsw")
    assert rep1["digest_full"] == rep2["digest_full"]


# --------------------------------------------------------------------------
# differential oracle: hwsw vs plain-Python PyPimMalloc, round by round
# --------------------------------------------------------------------------
def _resolve(trace: Trace, slots: np.ndarray, r: int) -> np.ndarray:
    ref = trace.ptr_ref[r]
    return np.where(ref >= 0, slots[np.clip(ref, 0, slots.shape[0] - 1)],
                    trace.ptr_raw[r]).astype(np.int32)


def run_oracle_differential(seed: int, rounds: int = 36):
    """Step hwsw eagerly against the oracle; verify semantics + conservation
    after EVERY round (the scan-based tests only snapshot the end state)."""
    trace = fuzz_trace(seed, rounds=rounds)
    cfg = sysm.SystemConfig(kind="hwsw", heap_bytes=HEAP, num_threads=T)
    state = heap.init(cfg)
    py = PyPimMalloc(heap_bytes=HEAP, num_threads=T)
    step = jax.jit(functools.partial(heap.step, cfg))
    slots = np.full((rounds * T,), -1, np.int32)
    for r in range(rounds):
        ptr = _resolve(trace, slots, r)
        req = heap.AllocRequest(op=jnp.asarray(trace.op[r]),
                                size=jnp.asarray(trace.size[r]),
                                ptr=jnp.asarray(ptr))
        state, resp = step(state, req)
        want = py.request(trace.op[r].tolist(), trace.size[r].tolist(),
                          ptr.tolist())
        got_ptr = np.asarray(resp.ptr)
        np.testing.assert_array_equal(got_ptr, want["ptr"],
                                      err_msg=f"round {r}: ptr")
        np.testing.assert_array_equal(np.asarray(resp.ok), want["ok"],
                                      err_msg=f"round {r}: ok")
        np.testing.assert_array_equal(np.asarray(resp.path), want["path"],
                                      err_msg=f"round {r}: path")
        np.testing.assert_array_equal(np.asarray(resp.moved), want["moved"],
                                      err_msg=f"round {r}: moved")
        snap = telemetry.snapshot(cfg, state)
        assert snap["conservation_residual"] == 0, \
            f"round {r}: conservation residual {snap['conservation_residual']}"
        slots[r * T:(r + 1) * T] = got_ptr


@pytest.mark.parametrize("seed", (0, 5))
def test_fuzz_oracle_differential(seed):
    run_oracle_differential(seed)


# --------------------------------------------------------------------------
# differential oracle: arena/tlregion vs plain-Python PyArena, round by round
# --------------------------------------------------------------------------
def run_arena_oracle_differential(kind: str, seed: int, rounds: int = 30):
    """Closed-loop mixed-op stream (incl. EPOCH_RESET rounds and frees of
    reset-staled pointers) through the layered arena kinds vs the `PyArena`
    oracle: semantic fields equal and conservation holds after EVERY round.
    Stale frees are deliberately kept in the stream — both sides must agree
    on dropping them (the reset applies at round start)."""
    cfg = sysm.SystemConfig(kind=kind, heap_bytes=HEAP, num_threads=T)
    state = heap.init(cfg)
    step = heap.REGISTRY[kind]
    py = PyArena(heap_bytes=HEAP, num_threads=T,
                 tlregion=(kind == "tlregion"))
    rng = np.random.default_rng(seed)
    live = []
    for r in range(rounds):
        op = np.zeros(T, np.int32)
        size = np.zeros(T, np.int32)
        ptr = np.full(T, -1, np.int32)
        if r % 9 == 8:
            op[rng.random(T) < 0.6] = heap.OP_EPOCH_RESET
            # `live` is NOT cleared: later frees of staled arena pointers
            # must drop identically on both sides
        else:
            for t in range(T):
                u = rng.random()
                if u < 0.45 or not live:
                    op[t] = int(rng.choice((heap.OP_MALLOC, heap.OP_CALLOC)))
                    size[t] = int(rng.choice(ALLOC_SIZES[2:]))
                elif u < 0.70:
                    op[t] = heap.OP_FREE
                    if live:
                        ptr[t] = live.pop(int(rng.integers(len(live))))
                else:
                    op[t] = heap.OP_REALLOC
                    size[t] = int(rng.choice((0,) + REALLOC_SIZES + (8192,)))
                    if live and rng.random() < 0.8:
                        ptr[t] = live.pop(int(rng.integers(len(live))))
        req = heap.AllocRequest(op=jnp.asarray(op), size=jnp.asarray(size),
                                ptr=jnp.asarray(ptr))
        state, resp = step(cfg, state, req)
        want = py.request(op.tolist(), size.tolist(), ptr.tolist())
        for f in ("ptr", "ok", "path", "moved"):
            np.testing.assert_array_equal(
                np.asarray(getattr(resp, f)), want[f],
                err_msg=f"{kind} round {r}: {f}")
        live += [int(p) for p in np.asarray(resp.ptr) if p >= 0]
        snap = telemetry.snapshot(cfg, state)
        assert snap["conservation_residual"] == 0, \
            f"{kind} round {r}: residual {snap['conservation_residual']}"


@pytest.mark.parametrize("kind", ("arena", "tlregion"))
@pytest.mark.parametrize("seed", (0, 3))
def test_fuzz_arena_oracle_differential(kind, seed):
    run_arena_oracle_differential(kind, seed)


# --------------------------------------------------------------------------
# hypothesis property variants (skip cleanly when hypothesis is absent)
# --------------------------------------------------------------------------
@settings(max_examples=MAX_EXAMPLES, deadline=None, derandomize=True)
@given(st.integers(0, 2**31 - 1))
def test_property_stream_contract(seed):
    """Any seed's stream satisfies sw/hwsw/pallas parity + conservation."""
    assert_stream_contract(fuzz_trace(seed, rounds=20),
                           kinds=("sw", "hwsw", "pallas"))


@settings(max_examples=max(MAX_EXAMPLES // 3, 3), deadline=None,
          derandomize=True)
@given(st.integers(0, 2**31 - 1))
def test_property_oracle_differential(seed):
    run_oracle_differential(seed, rounds=12)
