"""FleetServe (closed-loop serving tier) + fleet placement/scatter pieces.

The serving refactor must not be able to silently reorder responses —
scatter/gather are pinned as exact inverses at the capacity boundaries —
and the serve loop must honor the queueing contract: bounded admission,
drop accounting that balances, deterministic seeded sessions, tenant-sticky
placement, and per-core trace export that replays bitwise.
"""
import numpy as np
import pytest


from repro.core import heap, system as sysm
from repro.launch import fleet
from repro.launch.serve_fleet import FleetServe, TrafficConfig, serve_session
from repro.workloads.replay import replay

T = 4
HEAP = 1 << 19
SHAPE = (2, 2, T)
CAP = 2 * 2 * T


def _cfg(kind="sw"):
    return sysm.SystemConfig(kind=kind, heap_bytes=HEAP, num_threads=T)


def _tc(**kw):
    base = dict(seed=3, rounds=24, arrival_rate=8.0, num_tenants=10,
                queue_cap=32)
    base.update(kw)
    return TrafficConfig(**base)


# --------------------------------------------------------------------------
# scatter/gather: exact inverses at the capacity boundaries
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n", [0, 1, CAP - 1, CAP])
@pytest.mark.parametrize("placement", sorted(fleet.PLACEMENTS))
def test_scatter_gather_exact_inverse(n, placement):
    """For every N in {0, 1, capacity-1, capacity} and every slot policy,
    gather(scatter(stream)) == stream field-for-field, and untouched slots
    are NOOPs — the serve loop cannot silently reorder responses."""
    rng = np.random.RandomState(n + 17)
    op = rng.choice([heap.OP_MALLOC, heap.OP_FREE, heap.OP_REALLOC,
                     heap.OP_CALLOC], n).astype(np.int32)
    size = rng.randint(0, 1 << 14, n).astype(np.int32)
    ptr = rng.randint(-1, 1 << 16, n).astype(np.int32)
    loads = rng.rand(SHAPE[0], SHAPE[1])
    slots = fleet.PLACEMENTS[placement](n, SHAPE, loads=loads)
    assert len(np.unique(slots)) == n              # distinct slots
    req = fleet.scatter_slots(op, size, ptr, SHAPE, slots)
    for field, flat, fill in (("op", op, heap.OP_NOOP), ("size", size, 0),
                              ("ptr", ptr, -1)):
        grid = np.asarray(getattr(req, field)).reshape(-1)
        np.testing.assert_array_equal(grid[slots], flat)
        mask = np.ones(CAP, bool)
        mask[slots] = False
        assert (grid[mask] == fill).all()


def test_route_flat_least_loaded_guards_pointer_streams():
    """Stateful placement + unpinned pointer-carrying ops is a misroute
    hazard: route_flat must refuse unless the caller pins slots=."""
    router = fleet.FleetRouter(heap.ShardedHeap(_cfg(), 2, 2))
    n = 4
    out = router.route_flat(np.full(n, heap.OP_MALLOC, np.int32),
                            np.full(n, 256, np.int32),
                            np.full(n, -1, np.int32),
                            placement="least_loaded")
    with pytest.raises(ValueError):
        router.route_flat(np.full(n, heap.OP_FREE, np.int32),
                          np.zeros(n, np.int32), out["ptr"],
                          placement="least_loaded")
    # pinning the producing round's slots routes the frees correctly
    out2 = router.route_flat(np.full(n, heap.OP_FREE, np.int32),
                             np.zeros(n, np.int32), out["ptr"],
                             placement="least_loaded", slots=out["slots"])
    assert out2["ok"].all()


def test_failed_realloc_slot_resolves_to_surviving_pointer():
    """C contract end to end: when a relocating realloc fails, the old
    block survives — a later ref to the realloc's slot must reach it, so
    the block is freed, not leaked as a NULL no-op."""
    from repro.workloads.trace import Trace

    T_ = 2
    rounds = 3
    op = np.zeros((rounds, T_), np.int32)
    size = np.zeros_like(op)
    ref = np.full_like(op, -1)
    raw = np.full_like(op, -1)
    op[0, 0], size[0, 0] = heap.OP_MALLOC, 8192          # bypass block
    op[1, 0], size[1, 0], ref[1, 0] = heap.OP_REALLOC, HEAP * 2, 0
    op[2, 0], ref[2, 0] = heap.OP_FREE, 1 * T_ + 0       # ref realloc slot
    tr = Trace(name="failed_realloc", heap_bytes=HEAP, num_threads=T_,
               recorded_kind="hwsw", description="", op=op, size=size,
               ptr_ref=ref, ptr_raw=raw)
    resps, state, rep = replay(tr, "hwsw")
    ok = np.asarray(resps.ok)
    path = np.asarray(resps.path)
    assert not ok[1, 0] and path[1, 0] == 3              # realloc failed
    assert ok[2, 0] and path[2, 0] == 1                  # old block freed
    assert rep["telemetry"]["live_bytes"] == 0           # nothing leaked
    assert rep["telemetry"]["conservation_residual"] == 0


def test_scatter_rejects_over_capacity_and_bad_slots():
    over = CAP + 1
    z = np.zeros(over, np.int32)
    with pytest.raises(ValueError):
        fleet.scatter_flat(z, z, z, SHAPE)
    z2 = np.zeros(2, np.int32)
    with pytest.raises(ValueError):                # duplicate slots
        fleet.scatter_slots(z2, z2, z2, SHAPE, np.array([1, 1]))
    with pytest.raises(ValueError):                # out-of-range slot
        fleet.scatter_slots(z2, z2, z2, SHAPE, np.array([0, CAP]))
    with pytest.raises(ValueError):                # length mismatch
        fleet.scatter_slots(z2, z2, z2, SHAPE, np.array([0]))


def test_gather_flat_is_chunked_inverse_through_a_live_round():
    """End to end through a real heap: flat -> grid -> step -> flat keeps
    request order for the boundary N values."""
    for n in (1, CAP - 1, CAP):
        router = fleet.FleetRouter(heap.ShardedHeap(_cfg(), 2, 2))
        sizes = ((np.arange(n) % 5 + 1) * 32).astype(np.int32)
        out = router.route_flat(np.full(n, heap.OP_MALLOC, np.int32), sizes,
                                np.full(n, -1, np.int32))
        assert out["ptr"].shape == (n,) and (out["ptr"] >= 0).all()
        out2 = router.route_flat(np.full(n, heap.OP_FREE, np.int32),
                                 np.zeros(n, np.int32), out["ptr"])
        assert out2["ok"].all()


# --------------------------------------------------------------------------
# placement policies
# --------------------------------------------------------------------------
def test_round_robin_stripes_across_ranks():
    slots = fleet.place_round_robin(4, SHAPE)
    ranks = slots // (SHAPE[1] * T)
    assert sorted(ranks.tolist()) == [0, 0, 1, 1]
    assert len(set(slots.tolist())) == 4


def test_least_loaded_fills_lightest_core_first():
    loads = np.array([[5.0, 0.0], [3.0, 1.0]])
    slots = fleet.place_least_loaded(T + 1, SHAPE, loads=loads)
    # core (0,1) is lightest: its T slots first, then core (1,1)
    assert (slots[:T] // T == 1).all()
    assert slots[T] // T == 3


def test_tenant_core_policies():
    assert fleet.tenant_core("round_robin", 0, SHAPE) == (0, 0)
    assert fleet.tenant_core("round_robin", 1, SHAPE) == (1, 0)
    assert fleet.tenant_core("round_robin", 2, SHAPE) == (0, 1)
    loads = np.array([[4.0, 2.0], [9.0, 1.0]])
    assert fleet.tenant_core("least_loaded", 0, SHAPE, loads=loads) == (1, 1)
    # chunked: contiguous tenant blocks per core (8 tenants over 4 cores)
    homes = [fleet.tenant_core("chunked", i, SHAPE, expected_tenants=8)
             for i in range(8)]
    assert homes == [(0, 0), (0, 0), (0, 1), (0, 1),
                     (1, 0), (1, 0), (1, 1), (1, 1)]
    with pytest.raises(ValueError):
        fleet.tenant_core("nope", 0, SHAPE)


# --------------------------------------------------------------------------
# the serve loop
# --------------------------------------------------------------------------
@pytest.mark.parametrize("placement", ("round_robin", "least_loaded"))
def test_serve_session_accounting_balances(placement):
    rep = serve_session(_cfg(), 2, 2, traffic=_tc(), placement=placement)
    # every external arrival is dropped, dispatched, or still queued
    ext_left = rep["offered"] - rep["dropped"] - rep["external_dispatched"]
    assert 0 <= ext_left <= rep["backlog_end"]
    assert rep["dispatched"] == (rep["external_dispatched"]
                                 + rep["expiry_frees_dispatched"])
    assert rep["ops"] == rep["dispatched"]         # one grid slot per op
    assert rep["conservation_residual"] == 0
    assert rep["accounting"]["ops"] == rep["ops"]
    assert rep["external_queue_depth_max"] <= 32   # the admission bound
    # percentile ordering
    assert (rep["e2e_p50_cyc"] <= rep["e2e_p95_cyc"] <= rep["e2e_p99_cyc"])
    assert rep["service_p99_cyc"] <= rep["e2e_p99_cyc"] + 1e-6
    assert len(rep["queue_depth"]) == rep["rounds"]


def test_serve_underload_never_drops():
    rep = serve_session(_cfg(), 2, 2, placement="round_robin",
                        traffic=_tc(arrival_rate=2.0, rounds=32,
                                    queue_cap=64))
    assert rep["dropped"] == 0 and rep["drop_rate"] == 0.0
    assert rep["queue_depth_max"] <= 64


def test_serve_overload_applies_backpressure():
    rep = serve_session(_cfg(), 1, 1, placement="chunked",
                        traffic=_tc(arrival_rate=16.0, rounds=20,
                                    queue_cap=8))
    assert rep["dropped"] > 0
    assert 0.0 < rep["drop_rate"] <= 1.0
    assert sum(rep["drops_per_round"]) == rep["dropped"]
    # the admission queue itself never exceeds its bound (the combined
    # backlog series also counts never-droppable expiry frees, hence the
    # dedicated external series)
    assert rep["external_queue_depth_max"] <= 8


def test_serve_deterministic_in_seed():
    a = serve_session(_cfg(), 2, 2, traffic=_tc(seed=11))
    b = serve_session(_cfg(), 2, 2, traffic=_tc(seed=11))
    assert a == b
    c = serve_session(_cfg(), 2, 2, traffic=_tc(seed=12))
    assert c["queue_depth"] != a["queue_depth"] or c["offered"] != a["offered"]


def test_serve_tenant_stickiness():
    """Every op of a tenant lands on the tenant's home core."""
    eng = FleetServe(_cfg(), 2, 2, traffic=_tc(rounds=20),
                     placement="round_robin")
    plan = eng.plan()
    C = eng.num_cores
    for k, (rk, ck) in plan.tenant_home.items():
        sel = plan.tenant == k
        cores = plan.slot[sel] // T
        assert (cores == rk * C + ck).all()


def test_serve_trace_export_replays_bitwise():
    """Each core's exported tape replays through the workloads engine with
    responses bitwise-equal to the serve scan's slice of that core."""
    cfg = _cfg("hwsw")
    eng = FleetServe(cfg, 2, 2, traffic=_tc(rounds=20, arrival_rate=10.0),
                     placement="least_loaded")
    plan = eng.plan()
    _, resps = eng.run(plan)
    checked = 0
    for rk in range(2):
        for ck in range(2):
            tr = eng.trace(plan, rk, ck)
            if tr.ops == 0:
                continue
            r2, _, _ = replay(tr, "hwsw")
            for f in ("ptr", "ok", "path", "moved", "latency_cyc"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(resps, f))[:, rk, ck, :],
                    np.asarray(getattr(r2, f)), err_msg=f"{rk},{ck}:{f}")
            checked += 1
    assert checked >= 2


def test_serve_mesh_and_vmap_paths_agree():
    """mesh=None (shard_map over a 1-device mesh) == mesh=False (pure vmap)
    on the same plan, response for response."""
    cfg = _cfg()
    a = FleetServe(cfg, 2, 2, traffic=_tc(rounds=10), placement="round_robin",
                   mesh=False)
    b = FleetServe(cfg, 2, 2, traffic=_tc(rounds=10), placement="round_robin",
                   mesh=None)
    assert b.mesh is not None
    plan = a.plan()
    _, ra = a.run(plan)
    _, rb = b.run(plan)
    for f in ("ptr", "latency_cyc"):
        np.testing.assert_array_equal(np.asarray(getattr(ra, f)),
                                      np.asarray(getattr(rb, f)))


def test_serve_epoch_mode_contract():
    """``epoch_rounds`` mode: boundary rounds dedicate the whole grid to
    OP_EPOCH_RESET (no traffic dispatches), small allocations become
    round-scoped Temp blocks (no expiry frees — every dispatched FREE
    targets a big bypass block), conservation holds on the arena fleet,
    and the report carries the epoch ledger."""
    cfg = sysm.SystemConfig(kind="arena", heap_bytes=1 << 20, num_threads=T)
    tc = _tc(rounds=24, arrival_rate=10.0, epoch_rounds=6)
    eng = FleetServe(cfg, 2, 2, traffic=tc, placement="round_robin")
    plan, rep = eng.serve()
    boundary = np.arange(24) % 6 == 5
    assert (plan.op[boundary] == heap.OP_EPOCH_RESET).all()
    assert plan.dispatched_per_round[boundary].sum() == 0
    assert (plan.op[~boundary] != heap.OP_EPOCH_RESET).all()
    assert rep["epoch_rounds"] == 6 and rep["epoch_resets"] == 4
    assert rep["epoch_managed_allocs"] > 0
    assert rep["conservation_residual"] == 0
    assert rep["failed_allocs"] == 0
    assert rep["us_per_call"] > 0
    # every dispatched FREE targets a big block: Temp allocations are
    # reclaimed only by the resets
    cap = eng.capacity
    opf = plan.op.reshape(24, -1)
    sizef = plan.size.reshape(24, -1)
    reff = plan.ptr_ref.reshape(24, -1)
    frees = list(zip(*np.nonzero(opf == heap.OP_FREE)))
    for r, s in frees:
        rs, gs = divmod(int(reff[r, s]), cap)
        assert sizef[rs, gs] > tc.epoch_max_class


def test_serve_epoch_trace_lints_and_replays():
    """An epoch session's per-core tape passes trace_lint (no small ref
    crosses a reset round) and replays bitwise on the recording kind."""
    from repro.workloads.trace import trace_lint

    cfg = sysm.SystemConfig(kind="tlregion", heap_bytes=1 << 20,
                            num_threads=T)
    tc = _tc(rounds=18, arrival_rate=8.0, epoch_rounds=5)
    eng = FleetServe(cfg, 1, 2, traffic=tc, placement="round_robin")
    plan = eng.plan()
    _, resps = eng.run(plan)
    checked = 0
    for ck in range(2):
        tr = eng.trace(plan, 0, ck)
        assert tr.meta["epoch_rounds"] == 5
        assert tr.meta["max_size_class"] == tc.epoch_max_class
        assert trace_lint(tr) == []
        if tr.ops == 0:
            continue
        r2, _, _ = replay(tr, "tlregion")
        for f in ("ptr", "ok", "path", "latency_cyc"):
            np.testing.assert_array_equal(
                np.asarray(getattr(resps, f))[:, 0, ck, :],
                np.asarray(getattr(r2, f)), err_msg=f"{ck}:{f}")
        checked += 1
    assert checked >= 1


def test_serve_least_loaded_spreads_ranks():
    """least_loaded keeps every rank busy where chunked may concentrate."""
    tc = _tc(rounds=24, arrival_rate=12.0, num_tenants=12)
    rep = serve_session(_cfg(), 2, 2, traffic=tc, placement="least_loaded")
    per_rank = rep["accounting"]["per_rank"]["ops"]
    assert all(o > 0 for o in per_rank)
