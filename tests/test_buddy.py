"""Unit + property tests for the tensorized buddy allocator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import hypothesis_or_skip

given, settings, st = hypothesis_or_skip()

from repro.core import buddy
from repro.core.oracle import PyBuddy

CFG = buddy.BuddyConfig(heap_bytes=1 << 14, min_block=32)


@pytest.fixture(scope="module")
def ops():
    return (
        jax.jit(lambda s, z: buddy.alloc(CFG, s, z)),
        jax.jit(lambda s, o, z: buddy.free(CFG, s, o, z)),
    )


def test_init_longest():
    st_ = buddy.init(CFG)
    assert int(st_.longest[1]) == CFG.heap_bytes
    assert int(st_.longest[2]) == CFG.heap_bytes // 2
    assert int(st_.longest[CFG.n_nodes - 1]) == CFG.min_block


def test_alloc_whole_heap(ops):
    alloc, free = ops
    st_ = buddy.init(CFG)
    st_, off, ev = alloc(st_, jnp.int32(CFG.heap_bytes))
    assert int(off) == 0 and bool(ev.ok)
    assert int(st_.longest[1]) == 0
    st_, off2, ev2 = alloc(st_, jnp.int32(32))
    assert int(off2) == -1 and not bool(ev2.ok)
    st_, fev = free(st_, jnp.int32(0), jnp.int32(CFG.heap_bytes))
    assert bool(fev.ok)
    assert int(st_.longest[1]) == CFG.heap_bytes


def test_alignment_and_rounding(ops):
    alloc, _ = ops
    st_ = buddy.init(CFG)
    for req in (1, 31, 33, 100, 1000):
        st_, off, ev = alloc(st_, jnp.int32(req))
        size = max(1 << (req - 1).bit_length(), CFG.min_block)
        assert int(off) % size == 0, (req, int(off))


def test_split_merge_roundtrip(ops):
    alloc, free = ops
    st_ = buddy.init(CFG)
    offs = []
    for _ in range(4):
        st_, off, _ = alloc(st_, jnp.int32(4096))
        offs.append(int(off))
    assert offs == [0, 4096, 8192, 12288]
    assert int(buddy.free_bytes(CFG, st_)) == 0
    for off in offs:
        st_, _ = free(st_, jnp.int32(off), jnp.int32(4096))
    assert int(st_.longest[1]) == CFG.heap_bytes  # fully merged back


def test_trace_shape_and_levels(ops):
    alloc, _ = ops
    st_ = buddy.init(CFG)
    st_, off, ev = alloc(st_, jnp.int32(32))
    # depth = log2(16K/32) = 9 levels down for the smallest block
    assert int(ev.levels_down) == CFG.depth
    assert ev.trace.shape == (CFG.trace_len,)
    tr = [int(x) for x in ev.trace if int(x) >= 0]
    assert tr[0] == 1 and len(tr) == 1 + CFG.depth + CFG.depth


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 99), min_size=1, max_size=60), st.randoms())
def test_property_matches_oracle(seq, rnd):
    """Random alloc/free interleavings match the Python oracle exactly."""
    cfg = buddy.BuddyConfig(heap_bytes=1 << 12, min_block=32)
    st_ = buddy.init(cfg)
    py = PyBuddy(1 << 12, 32)
    alloc = jax.jit(lambda s, z: buddy.alloc(cfg, s, z))
    free = jax.jit(lambda s, o, z: buddy.free(cfg, s, o, z))
    live = []
    for v in seq:
        if live and v % 2 == 0:
            off, size = live.pop(rnd.randrange(len(live)))
            st_, ev = free(st_, jnp.int32(off), jnp.int32(size))
            assert py.free(off, size) == bool(ev.ok)
        else:
            size = [16, 32, 64, 100, 256, 512, 1024][v % 7]
            st_, off, _ = alloc(st_, jnp.int32(size))
            assert int(off) == py.alloc(size)
            if int(off) >= 0:
                live.append((int(off), size))
    assert py.longest == [int(x) for x in st_.longest]
    assert int(buddy.free_bytes(cfg, st_)) == py.free_bytes()


def test_no_overlap_invariant(ops):
    """Live blocks never overlap (checked via interval arithmetic)."""
    alloc, free = ops
    st_ = buddy.init(CFG)
    live = []
    import random

    rng = random.Random(7)
    for _ in range(80):
        if live and rng.random() < 0.4:
            off, size = live.pop(rng.randrange(len(live)))
            st_, _ = free(st_, jnp.int32(off), jnp.int32(size))
        else:
            size = rng.choice([32, 64, 128, 512, 2048])
            st_, off, _ = alloc(st_, jnp.int32(size))
            if int(off) >= 0:
                live.append((int(off), size))
        ivs = sorted((o, o + max(s, 32)) for o, s in live)
        for (a0, a1), (b0, b1) in zip(ivs, ivs[1:]):
            assert a1 <= b0, ivs


def _fill_then_free_permuted(cfg, rnd_seed, permute, sizes_pool):
    """Alloc until the heap is exhausted, then free every block in an
    adversarial permutation, asserting the no-overlap invariant throughout.
    Returns the final state."""
    import random

    rng = random.Random(rnd_seed)
    alloc = jax.jit(lambda s, z: buddy.alloc(cfg, s, z))
    free = jax.jit(lambda s, o, z: buddy.free(cfg, s, o, z))
    st_ = buddy.init(cfg)
    live = []
    while True:
        size = rng.choice(sizes_pool)
        st_, off, _ = alloc(st_, jnp.int32(size))
        if int(off) < 0:
            st_, off, _ = alloc(st_, jnp.int32(cfg.min_block))
            if int(off) < 0:
                break                       # not even min_block fits: full
            size = cfg.min_block
        live.append((int(off), size))
        # live blocks never overlap (rounded extents)
        ivs = sorted((o, o + max(1 << (s - 1).bit_length(), cfg.min_block))
                     for o, s in live)
        for (a0, a1), (b0, b1) in zip(ivs, ivs[1:]):
            assert a1 <= b0, ivs
    assert int(buddy.free_bytes(cfg, st_)) == 0    # genuinely full

    order = permute(list(range(len(live))), rng)
    for i in order:
        off, size = live[i]
        st_, ev = free(st_, jnp.int32(off), jnp.int32(size))
        assert bool(ev.ok), (off, size)
    return st_


_PERMUTERS = {
    "shuffled": lambda idx, rng: rng.sample(idx, len(idx)),
    "reversed": lambda idx, rng: idx[::-1],
    "inorder": lambda idx, rng: idx,
    # adversarial interleave: alternately from both ends, so coalescing
    # partners arrive as far apart in time as possible
    "interleaved": lambda idx, rng: [idx[i // 2] if i % 2 == 0
                                     else idx[-1 - i // 2]
                                     for i in range(len(idx))],
}


@pytest.mark.parametrize("permuter", sorted(_PERMUTERS))
@pytest.mark.parametrize("seed", (0, 7))
def test_full_cycle_restores_fresh_histogram(permuter, seed):
    """Coalescing invariant: after a full alloc-then-permuted-free cycle the
    per-level maximal-free-block histogram equals a fresh heap's — every
    split is undone no matter the free order."""
    from repro.core import telemetry

    cfg = buddy.BuddyConfig(heap_bytes=1 << 13, min_block=32)
    st_ = _fill_then_free_permuted(cfg, seed, _PERMUTERS[permuter],
                                   [32, 64, 100, 256, 512, 1000])
    fresh = telemetry.free_block_histogram(cfg, buddy.init(cfg).longest)
    hist = telemetry.free_block_histogram(cfg, st_.longest)
    np.testing.assert_array_equal(hist, fresh)
    assert fresh[0] == 1 and fresh.sum() == 1      # one maximal whole-heap block
    assert int(st_.longest[1]) == cfg.heap_bytes
    np.testing.assert_array_equal(np.asarray(st_.longest),
                                  np.asarray(buddy.init(cfg).longest))


@settings(max_examples=20, deadline=None, derandomize=True)
@given(st.integers(0, 2**31 - 1), st.permutations(list(range(6))))
def test_property_permuted_free_restores_histogram(seed, size_order):
    """Any full alloc/permuted-free cycle over any size mix coalesces back
    to the fresh-heap histogram, with no live-block overlap on the way."""
    from repro.core import telemetry

    pool = [[32, 64, 128, 256, 512, 1024][i] for i in size_order]
    cfg = buddy.BuddyConfig(heap_bytes=1 << 12, min_block=32)
    st_ = _fill_then_free_permuted(cfg, seed, _PERMUTERS["shuffled"], pool)
    np.testing.assert_array_equal(
        telemetry.free_block_histogram(cfg, st_.longest),
        telemetry.free_block_histogram(cfg, buddy.init(cfg).longest))


def test_vmap_over_cores():
    """Per-core independence: vmapped allocs equal per-core sequential ones."""
    cfg = buddy.BuddyConfig(heap_bytes=1 << 12, min_block=32)
    n_cores = 4
    states = jax.vmap(lambda _: buddy.init(cfg))(jnp.arange(n_cores))
    sizes = jnp.array([32, 64, 128, 256], jnp.int32)
    st2, offs, evs = jax.vmap(lambda s, z: buddy.alloc(cfg, s, z))(states, sizes)
    for i in range(n_cores):
        py = PyBuddy(1 << 12, 32)
        assert int(offs[i]) == py.alloc(int(sizes[i]))
        assert py.longest == [int(x) for x in st2.longest[i]]
