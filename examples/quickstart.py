"""Quickstart: the PIM-malloc public API + one allocator-vs-allocator race.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import system as sysm
from repro.core.api import initAllocator


def main():
    # --- Table 2 API --------------------------------------------------------
    a = initAllocator(1 << 20)  # 1 MB per-core heap
    p1 = a.pimMalloc(100)       # thread-cache hit (128 B class)
    p2 = a.pimMalloc(100)
    p3 = a.pimMalloc(8192)      # bypass -> buddy backend
    print(f"pimMalloc: {p1=} {p2=} {p3=}")
    a.pimFree(p2)
    p4 = a.pimMalloc(100)       # LIFO: reuses p2's sub-block
    print(f"after free+malloc: {p4=} (== {p2=}: {p4 == p2})")
    a.pimFree(p1), a.pimFree(p3), a.pimFree(p4)
    print("stats:", a.stats)

    # --- straw-man vs PIM-malloc-SW vs HW/SW on one request burst -----------
    print("\n64 rounds x 16 threads x 32 B allocations (DPU cost model):")
    for kind in sysm.KINDS:
        cfg = sysm.SystemConfig(kind=kind, heap_bytes=1 << 22)
        st = sysm.system_init(cfg)
        import jax
        run = jax.jit(lambda s, z: sysm.run_alloc_rounds(cfg, s, z))
        st, ptrs, infos = run(st, jnp.full((64, 16), 32, jnp.int32))
        us = np.asarray(infos.latency_cyc) / 350e6 * 1e6
        print(f"  {kind:9s}: mean {us.mean():8.3f} us   p99 "
              f"{np.percentile(us, 99):8.3f} us")


if __name__ == "__main__":
    main()
