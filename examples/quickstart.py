"""Quickstart: the unified PIM-malloc allocator surface.

    PYTHONPATH=src python examples/quickstart.py

Three views of ONE protocol (`repro.core.heap`):
  1. the paper's Table-2 facade — initAllocator / pimMalloc / pimFree /
     pimRealloc / pimCalloc (stateful convenience, one jitted step inside),
  2. raw `heap.step` with a mixed-op `AllocRequest` (what jit/vmap/shard_map
     compose over),
  3. a `MultiCoreHeap` — the whole multi-core PIM system as one
     `jit(vmap(step))` over stacked per-core states — raced across the
     paper's three design points with the DPU cost model.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import heap
from repro.core import system as sysm
from repro.core.api import initAllocator


def main():
    # --- 1. Table 2 facade --------------------------------------------------
    a = initAllocator(1 << 20)  # 1 MB per-core heap, PIM-malloc-SW kind
    p1 = a.pimMalloc(100)       # thread-cache hit (128 B class)
    p2 = a.pimMalloc(100)
    p3 = a.pimMalloc(8192)      # bypass -> buddy backend
    print(f"pimMalloc: {p1=} {p2=} {p3=}")
    a.pimFree(p2)
    p4 = a.pimMalloc(100)       # LIFO: reuses p2's sub-block
    print(f"after free+malloc: {p4=} (== {p2=}: {p4 == p2})")
    p5 = a.pimRealloc(p4, 120)  # same 128 B class -> grows in place
    p6 = a.pimRealloc(p5, 300)  # 512 B class -> relocates (alloc+copy+free)
    print(f"pimRealloc: in-place {p5 == p4}, then moved to {p6=}")
    p7 = a.pimCalloc(64, 16)    # 1 KB zeroed -> 1024 B class
    a.pimFree(p1), a.pimFree(p3), a.pimFree(p6), a.pimFree(p7)
    print("stats:", a.stats)

    # --- 2. one mixed-op protocol round -------------------------------------
    cfg = sysm.SystemConfig(kind="hwsw", heap_bytes=1 << 20, num_threads=4)
    st = heap.init(cfg)
    st, r0 = heap.step(cfg, st, heap.malloc_request(
        jnp.array([64, 256, 64, 8192], jnp.int32)))
    req = heap.AllocRequest(
        op=jnp.array([heap.OP_REALLOC, heap.OP_FREE, heap.OP_CALLOC,
                      heap.OP_NOOP], jnp.int32),
        size=jnp.array([512, 0, 96, 0], jnp.int32),
        ptr=jnp.array([int(r0.ptr[0]), int(r0.ptr[1]), -1, -1], jnp.int32))
    st, r1 = heap.step(cfg, st, req)
    print("mixed round ptrs:", np.asarray(r1.ptr), "paths:",
          np.asarray(r1.path), f"moved: {np.asarray(r1.moved)}")

    # --- 3. multi-core race: straw-man vs SW vs HW/SW -----------------------
    C, R = 8, 64
    print(f"\n{R} rounds x {C} cores x 16 threads x 32 B (DPU cost model):")
    for kind in sysm.KINDS:
        cfg = sysm.SystemConfig(kind=kind, heap_bytes=1 << 22)
        mch = heap.MultiCoreHeap(cfg, num_cores=C)
        run = jax.jit(jax.vmap(functools.partial(
            heap.run_rounds, cfg), in_axes=(0, 1), out_axes=(0, 1)))
        reqs = jax.vmap(jax.vmap(heap.malloc_request))(
            jnp.full((R, C, 16), 32, jnp.int32))
        mch.state, resp = run(mch.state, reqs)
        us = np.asarray(resp.latency_cyc) / cfg.dpu.freq_hz * 1e6
        print(f"  {kind:9s}: mean {us.mean():8.3f} us   p99 "
              f"{np.percentile(us, 99):8.3f} us")


if __name__ == "__main__":
    main()
