"""FleetServe demo: steady-state multi-tenant traffic over the PIM fleet.

    PYTHONPATH=src python examples/serve_fleet.py \
        [--ranks 2] [--cores 2] [--threads 4] [--rounds 48] [--rate 12] \
        [--placement round_robin|least_loaded|chunked] [--kind sw] \
        [--seed 0] [--queue-cap 64] [--export-trace PATH] [--chaos]

Plans a Poisson/Zipf tenant session, drives it through the donated
`lax.scan` round driver, and prints the serving report: admission /
backpressure counters, end-to-end latency percentiles in modeled DPU
cycles, queue-depth trace, and the fleet cost accounting. ``--export-trace``
writes rank 0 / core 0's slice as a ``pim-malloc-trace/v1`` tape replayable
with ``python -m repro.workloads.replay``.

``--chaos`` serves the same session through `ElasticFleetServe` instead:
a seed-derived `FaultPlan` (core kill, one-round stall, dropped round)
plus heap-pressure tenant migration, with the extra elastic counters
(migrations, kills, pressure checks) appended to the report. The chaos
session still pins dropped_frees == 0 and conservation_residual == 0.
"""
import argparse

from repro.core import system as sysm
from repro.launch.elastic import ElasticFleetServe, FaultPlan, MigrationConfig
from repro.launch.serve_fleet import FleetServe, TrafficConfig


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ranks", type=int, default=2)
    ap.add_argument("--cores", type=int, default=2)
    ap.add_argument("--threads", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=48)
    ap.add_argument("--rate", type=float, default=12.0,
                    help="mean external arrivals per round (Poisson)")
    ap.add_argument("--placement", default="round_robin",
                    choices=("chunked", "round_robin", "least_loaded"))
    ap.add_argument("--kind", default="sw",
                    choices=("strawman", "sw", "hwsw", "pallas"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--queue-cap", type=int, default=64)
    ap.add_argument("--tenants", type=int, default=16)
    ap.add_argument("--export-trace", default=None, metavar="PATH")
    ap.add_argument("--chaos", action="store_true",
                    help="elastic session: seed-derived fault plan + "
                         "heap-pressure tenant migration")
    args = ap.parse_args()

    cfg = sysm.SystemConfig(kind=args.kind, heap_bytes=1 << 19,
                            num_threads=args.threads)
    traffic = TrafficConfig(seed=args.seed, rounds=args.rounds,
                            arrival_rate=args.rate, num_tenants=args.tenants,
                            queue_cap=args.queue_cap)
    if args.chaos:
        faults = FaultPlan.generate(seed=args.seed + 1, rounds=args.rounds,
                                    shape=(args.ranks, args.cores,
                                           args.threads))
        engine = ElasticFleetServe(
            cfg, args.ranks, args.cores, traffic=traffic,
            placement=args.placement, faults=faults,
            migration=MigrationConfig(ratio=1.3, min_bytes=1 << 10,
                                      drain="interval", check_rounds=8))
    else:
        engine = FleetServe(cfg, args.ranks, args.cores, traffic=traffic,
                            placement=args.placement)
    plan, rep = engine.serve()

    R, C, T = plan.shape
    print(f"fleet [{R} ranks x {C} cores x {T} threads] kind={args.kind} "
          f"placement={args.placement} capacity={rep['capacity_per_round']}/round")
    print(f"offered={rep['offered']} dropped={rep['dropped']} "
          f"(drop_rate={rep['drop_rate']:.2f}) "
          f"dispatched={rep['external_dispatched']} external "
          f"+ {rep['expiry_frees_dispatched']} expiry frees "
          f"backlog_end={rep['backlog_end']}")
    print(f"latency e2e cyc: p50={rep['e2e_p50_cyc']:.0f} "
          f"p95={rep['e2e_p95_cyc']:.0f} p99={rep['e2e_p99_cyc']:.0f}  "
          f"service p99={rep['service_p99_cyc']:.0f}  "
          f"us/op={rep['us_per_op']:.3f}")
    print(f"queue depth mean={rep['queue_depth_mean']:.1f} "
          f"max={rep['queue_depth_max']}  modeled wall "
          f"{rep['modeled_wall_us']:.0f}us  "
          f"{rep['ops_per_sec']:.0f} ops/s")
    print(f"heap: live={rep['live_bytes']}B failed_allocs="
          f"{rep['failed_allocs']} dropped_frees={rep['dropped_frees']} "
          f"conservation_residual={rep['conservation_residual']}")
    print("per-rank ops:", rep["accounting"]["per_rank"]["ops"])
    if args.chaos:
        faults = ", ".join(f"r{ev['round']} {ev['kind']}"
                           + (f"@({ev['rank']},{ev['core']})"
                              if ev["kind"] != "drop" else "")
                           for ev in rep["faults"]) or "none"
        print(f"chaos: faults=[{faults}] kills={len(rep['kills'])} "
              f"migrations={len(rep['migrations'])} "
              f"(+{rep['migration_ops_dispatched']} migration ops) "
              f"killed_cores={rep['killed_cores']}")
        for ev in rep["migrations"]:
            src = tuple(ev["src"]) if ev["src"] else "?"
            print(f"  round {ev['round']:4d} migrate tenant {ev['tenant']} "
                  f"{src} -> {tuple(ev['dst'])} ({ev['bytes']}B live)")
    depths = rep["queue_depth"]
    peak = max(max(depths), 1)
    for r0 in range(0, len(depths), max(len(depths) // 12, 1)):
        bar = "#" * int(depths[r0] / peak * 40)
        print(f"  round {r0:4d} queue {depths[r0]:4d} |{bar}")

    if args.export_trace:
        tr = engine.trace(plan, 0, 0)
        tr.save(args.export_trace)
        print(f"wrote rank0/core0 tape ({tr.ops} ops) -> "
              f"{args.export_trace}")


if __name__ == "__main__":
    main()
