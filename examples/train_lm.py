"""End-to-end fault-tolerant LM training (reduced granite-3-8b, ~100M-class
family at smoke scale) for a few hundred steps with an injected failure +
checkpoint recovery.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import shutil
import sys

from repro.launch import train

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args, _ = ap.parse_known_args()
    shutil.rmtree("/tmp/repro_train_lm", ignore_errors=True)
    sys.argv = [sys.argv[0], "--arch", "granite_3_8b", "--reduced",
                "--steps", str(args.steps), "--batch", "8", "--seq", "128",
                "--n-micro", "2", "--ckpt-dir", "/tmp/repro_train_lm",
                "--ckpt-every", "25", "--fail-at", str(args.steps // 2)]
    train.main()
