"""DecodeServe demo: paged-KV LLM decode through the PIM-malloc fleet.

    PYTHONPATH=src python examples/serve_decode.py \
        [--ranks 2] [--cores 2] [--threads 4] [--rounds 64] [--rate 1.5] \
        [--tenants 8] [--max-context 576] [--placement least_loaded] \
        [--kind sw] [--mesh] [--seed 0] [--smoke] [--export-trace PATH]

Plans a multi-tenant continuous-batching decode session — Poisson session
arrivals, Zipf tenant popularity, prefill bursts, one KV page per
page-boundary token, eviction on completion or context overflow — runs it
as one donated `lax.scan` over the fleet heap, and prints the coupled
report: tokens/sec + TTFT next to allocator percentiles, per-rank heap
high-water marks and the conservation residual. ``--export-trace`` writes
the Zipf-head tenant's home-core slice as a ``pim-malloc-trace/v1`` tape
(replayable with ``python -m repro.workloads.replay``).
"""
import argparse

from repro.core import system as sysm
from repro.launch.serve_decode import DecodeServe, DecodeTraffic


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ranks", type=int, default=2)
    ap.add_argument("--cores", type=int, default=2)
    ap.add_argument("--threads", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=64)
    ap.add_argument("--rate", type=float, default=1.5,
                    help="mean new sessions per round (Poisson)")
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--max-context", type=int, default=576)
    ap.add_argument("--queue-cap", type=int, default=16)
    ap.add_argument("--placement", default="least_loaded",
                    choices=("chunked", "round_robin", "least_loaded"))
    ap.add_argument("--kind", default="sw",
                    choices=("strawman", "sw", "hwsw", "sanitizer",
                             "pallas"))
    ap.add_argument("--mesh", action="store_true",
                    help="shard_map over the rank mesh (default pure vmap)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny deterministic session (CI decode-smoke)")
    ap.add_argument("--export-trace", default=None, metavar="PATH")
    args = ap.parse_args()

    if args.smoke:
        args.rounds, args.rate, args.threads = 24, 1.0, 4

    cfg = sysm.SystemConfig(kind=args.kind, heap_bytes=1 << 20,
                            num_threads=args.threads)
    traffic = DecodeTraffic(seed=args.seed, rounds=args.rounds,
                            session_rate=args.rate,
                            num_tenants=args.tenants,
                            max_context=args.max_context,
                            queue_cap=args.queue_cap)
    engine = DecodeServe(cfg, args.ranks, args.cores, traffic=traffic,
                         placement=args.placement,
                         mesh=None if args.mesh else False)
    plan, rep = engine.serve()

    R, C, T = plan.shape
    print(f"fleet [{R} ranks x {C} cores x {T} threads] kind={args.kind} "
          f"placement={args.placement} mesh={bool(args.mesh)}")
    print(f"sessions: offered={rep['sessions_offered']} "
          f"dropped={rep['sessions_dropped']} "
          f"prefilled={rep['sessions_prefilled']} "
          f"completed={rep['sessions_completed']} "
          f"overflow={rep['sessions_evicted_overflow']} "
          f"active_end={rep['sessions_active_end']}")
    print(f"tokens: prefill={rep['prefill_tokens']} "
          f"decode={rep['decode_tokens']} "
          f"-> {rep['tokens_per_sec']:.0f} tok/s (modeled)  "
          f"stalls={rep['decode_stalls']}")
    print(f"TTFT cyc: p50={rep['ttft_p50_cyc']:.0f} "
          f"p95={rep['ttft_p95_cyc']:.0f} p99={rep['ttft_p99_cyc']:.0f}")
    print(f"alloc cyc: p50={rep['alloc_p50_cyc']:.0f} "
          f"p95={rep['alloc_p95_cyc']:.0f} "
          f"p99={rep['alloc_p99_cyc']:.0f}  "
          f"us/op={rep['us_per_op']:.3f}  "
          f"({rep['prefill_allocs']} prefills + "
          f"{rep['decode_page_allocs']} pages + "
          f"{rep['evict_frees']} frees)")
    print(f"heap: live={rep['live_bytes']}B "
          f"hwm/rank={rep['hwm_bytes_per_rank']} "
          f"frag={rep['external_frag_mean']:.3f} "
          f"failed_allocs={rep['failed_allocs']} "
          f"dropped_frees={rep['dropped_frees']} "
          f"conservation_residual={rep['conservation_residual']}")
    assert rep["conservation_residual"] == 0

    toks = rep["decode_tokens_per_round"]
    peak = max(max(toks), 1)
    for r0 in range(0, len(toks), max(len(toks) // 12, 1)):
        bar = "#" * int(toks[r0] / peak * 40)
        print(f"  round {r0:4d} tokens {toks[r0]:4d} |{bar}")

    if args.export_trace:
        rank, core = plan.tenant_home.get(0, (0, 0))
        tr = engine.trace(plan, rank, core)
        tr.save(args.export_trace)
        print(f"wrote rank{rank}/core{core} tape ({tr.ops} ops) -> "
              f"{args.export_trace}")


if __name__ == "__main__":
    main()
