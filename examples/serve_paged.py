"""Paged-KV serving with PIM-malloc page management + Pallas paged attention.

    PYTHONPATH=src python examples/serve_paged.py

Thin wrapper over the production driver (launch/serve.py) at smoke scale.
Page extents come from the unified heap API (PagePool -> Table-2 facade ->
heap.step); decode-time page growth routes through a 2-rank ShardedHeap
fleet (the shard_map tier + FleetRouter accounting); the attention impl is
threaded through ArchConfig.attend_impl (no module globals).
"""
import sys

from repro.launch import serve

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "granite_3_8b", "--reduced",
                "--batch", "4", "--prompt-len", "32", "--decode-steps", "48",
                "--impl", "kernel", "--fleet-ranks", "2"]
    serve.main()
