"""Dynamic graph updates — the paper's case study (Section 6.2 / Fig 16).

    PYTHONPATH=src python examples/graph_update.py

Static CSR vs linked-list adjacency on three allocators. The dynamic
structure is functionally real (pointers into an allocator-managed heap);
throughput comes from the DPU cost model.
"""
from repro.graphupd.workload import GraphConfig, compare_all


def main():
    cfg = GraphConfig()
    print(f"partition: {cfg.n_nodes} nodes, {cfg.n_edges_pre} pre-edges, "
          f"{cfg.n_edges_new} new edges (1:2, paper methodology)\n")
    res = compare_all(cfg)
    st = res["static_csr"]["us_per_edge"]
    print(f"{'structure':22s} {'us/edge':>9s} {'edges/s':>12s} {'vs static':>10s}")
    for name, v in res.items():
        speed = st / v["us_per_edge"]
        print(f"{name:22s} {v['us_per_edge']:9.3f} {v['edges_per_s']:12.0f} "
              f"{speed:9.1f}x")
    sw, hw = res["sw"], res["hwsw"]
    fr = sw["frontend_ops"] / (sw["frontend_ops"] + sw["backend_ops"])
    print(f"\nfrontend service rate (PIM-malloc-SW): {fr:.1%} (paper: >90%)")
    if sw["dram_bytes"]:
        red = 1 - hw["dram_bytes"] / sw["dram_bytes"]
        print(f"metadata DRAM traffic reduction HW/SW vs SW: {red:.0%} "
              "(paper: 33%)")


if __name__ == "__main__":
    main()
