"""Wall-clock lane for the fig14 mix: *measured* kernel-path round time.

Every other figure reports modeled `us_per_call` from the cost model; this
one times the compiled `pallas` round loop itself (warmup + repeated
`block_until_ready` execution, median) for the fig14 size/thread cells,
twice — batched same-class refill on, and forced off
(``kernel_batch_refill=False``, the pre-batching serial walk) — and emits
the mix speedup as its own row. Both settings are bitwise-identical in
responses and state, so this lane measures execution speed only.

Rows land under ``fig14_wall/*`` with ``lane="wall"`` and an ``env_key``
stamp; `perf_gate.py` diffs them only against same-env baselines and with
the looser ``--fail-over-wall`` threshold (see benchmarks/README.md).
"""
from __future__ import annotations

from .common import emit, micro_alloc_wall, wall_env_key

# the fig14 mix's pallas column: all-hit rounds (32 B), periodic
# whole-round refill bursts (256 B drains the prepopulated freelists every
# 16 rounds), and per-round block-granularity bypass (4096 B)
CELLS = ((32, 1), (32, 16), (256, 16), (4096, 16))


def bench(smoke: bool = False):
    rounds = 24 if smoke else 96
    repeats = 3 if smoke else 5
    env = wall_env_key()
    recs = []
    mix_round_us = {}
    for batch, tag in ((True, "pallas"), (False, "pallas_nobatch")):
        total = 0.0
        for size, nt in CELLS:
            r = micro_alloc_wall("pallas", size, nt, rounds=rounds,
                                 repeats=repeats, batch_refill=batch)
            total += r["wall_us_per_round"]
            recs.append(emit(
                f"fig14_wall/{tag}/size={size}/threads={nt}",
                r["wall_us_per_call"],
                f"round={r['wall_us_per_round']:.0f}us "
                f"modeled={r['modeled_us_per_call']:.2f}us",
                backend="pallas", lane="wall", env_key=env,
                batch_refill=int(batch),
                wall_us_per_round=r["wall_us_per_round"],
                modeled_us_per_call=r["modeled_us_per_call"],
                rounds_per_sec=r["rounds_per_sec"],
                rounds=r["rounds"], ops=r["ops"]))
        mix_round_us[tag] = total
    speedup = mix_round_us["pallas_nobatch"] / max(mix_round_us["pallas"],
                                                   1e-9)
    recs.append(emit(
        "fig14_wall/kernel_batch_speedup",
        mix_round_us["pallas"] / len(CELLS),
        f"{speedup:.2f}x round throughput vs pre-batching serial walk "
        f"(mix {mix_round_us['pallas_nobatch']:.0f} -> "
        f"{mix_round_us['pallas']:.0f} us)",
        backend="pallas", lane="wall", env_key=env,
        speedup_vs_serial=speedup,
        mix_wall_us_batched=mix_round_us["pallas"],
        mix_wall_us_serial=mix_round_us["pallas_nobatch"]))
    return recs
