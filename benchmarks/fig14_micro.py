"""Fig 14: the headline microbenchmark — straw-man vs PIM-malloc-SW vs
PIM-malloc-HW/SW at {32 B, 256 B, 4 KB} x {1, 16 threads}; 128 allocs/thread.

Overall speedups use the workload-weighted mean with the paper-cited
allocation-size distribution (>90% of real requests are small: 98% <= 1 KB
datacenter [63,68,131], 93% <= 512 B serverless [123])."""
import numpy as np

from .common import emit, micro_alloc

# datacenter allocation-size mix (98% <= 1 KB [63,68,131]): small requests
# dominate, large (backend/bypass) requests are the 2% tail
WEIGHTS = {32: 0.60, 256: 0.38, 4096: 0.02}


def bench(smoke: bool = False):
    recs = []
    rounds = 8 if smoke else 128
    res = {}
    for nt in (1, 16):
        for size in (32, 256, 4096):
            for kind in ("strawman", "sw", "hwsw", "pallas"):
                r = micro_alloc(kind, size, nthreads=nt, rounds=rounds)
                res[(kind, size, nt)] = r["mean_us"]
                recs.append(emit(
                    f"fig14/{kind}/size={size}/threads={nt}", r["mean_us"],
                    f"p95={r['p95_us']:.3f}us", backend=kind,
                    allocs_per_sec=r["allocs_per_sec"],
                    metadata_bytes_per_op=r["metadata_bytes_per_op"]))

    for nt in (1, 16):
        w = {z: WEIGHTS[z] for z in WEIGHTS}
        straw = sum(w[z] * res[("strawman", z, nt)] for z in w)
        sw = sum(w[z] * res[("sw", z, nt)] for z in w)
        hw = sum(w[z] * res[("hwsw", z, nt)] for z in w)
        recs.append(emit(
            f"fig14/overall_sw_speedup/threads={nt}", sw,
            f"{straw / sw:.0f}x_vs_strawman (paper: 66x)",
            speedup_vs_strawman=straw / sw))
        recs.append(emit(
            f"fig14/overall_hwsw_gain/threads={nt}", hw,
            f"+{(sw / hw - 1) * 100:.0f}%_vs_sw (paper: +31%)",
            gain_vs_sw=sw / hw - 1))
    g4k = np.mean([res[("sw", 4096, nt)] / res[("hwsw", 4096, nt)]
                   for nt in (1, 16)])
    recs.append(emit(
        "fig14/hwsw_4kb_latency_reduction", res[("hwsw", 4096, 16)],
        f"-{(1 - 1 / g4k) * 100:.0f}% vs sw (paper: -39%)"))
    # bracketing range: pure small-size cells (the thread-cache fast path)
    for nt in (1, 16):
        r32 = res[("strawman", 32, nt)] / res[("sw", 32, nt)]
        recs.append(emit(
            f"fig14/small_size_speedup/threads={nt}", res[("sw", 32, nt)],
            f"{r32:.0f}x at 32B (brackets the paper's 66x from above)",
            speedup_32b=r32))
    # fused-kernel design point: modeled latency must track hwsw 1:1 (the
    # kernel is bitwise-conformant; this row guards the claim in the bench
    # trajectory, CI fails the ERROR row if parity drifts)
    par = np.mean([res[("pallas", z, nt)] / res[("hwsw", z, nt)]
                   for z in (32, 256, 4096) for nt in (1, 16)])
    if not 0.999 <= par <= 1.001:
        raise AssertionError(f"pallas/hwsw modeled-latency parity broke: {par}")
    recs.append(emit(
        "fig14/pallas_parity_vs_hwsw", res[("pallas", 256, 16)],
        f"mean_ratio={par:.4f} (fused kernel == hwsw model)",
        backend="pallas", parity_ratio=par))
    return recs


def run():
    bench()
