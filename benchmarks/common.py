"""Shared benchmark helpers: compiled microbench loops + CSV emission.

Microbenchmarks drive the allocator through the `repro.core.heap` protocol
(`run_rounds` / `run_alloc_free_rounds` — the same `step` that serves every
backend kind), so figures measure exactly the public surface.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import heap as heap_api
from repro.core import system as sysm

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.4f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def micro_alloc(kind: str, size: int, nthreads: int, rounds: int = 128,
                heap: int = 1 << 25, T: int = 16, alloc_free: bool = False):
    """Fig 14-style microbenchmark: per-thread latency stats (us)."""
    cfg = sysm.SystemConfig(kind=kind, heap_bytes=heap, num_threads=T)
    st = heap_api.init(cfg)
    sizes = jnp.where(jnp.arange(T) < nthreads, size, 0).astype(jnp.int32)
    sz = jnp.tile(sizes[None, :], (rounds, 1))
    if alloc_free:
        run = jax.jit(lambda s, z: heap_api.run_alloc_free_rounds(cfg, s, z))
        st, resp_a, resp_f = run(st, sz)
        lat = (np.asarray(resp_a.latency_cyc)
               + np.asarray(resp_f.latency_cyc))[:, :nthreads]
        dram = (np.asarray(resp_a.dram_bytes).sum()
                + np.asarray(resp_f.dram_bytes).sum())
    else:
        run = jax.jit(lambda s, z: heap_api.run_rounds(
            cfg, s, jax.vmap(heap_api.malloc_request)(z)))
        st, resp = run(st, sz)
        lat = np.asarray(resp.latency_cyc)[:, :nthreads]
        dram = np.asarray(resp.dram_bytes).sum()
    us = lat / cfg.dpu.freq_hz * 1e6
    return {
        "mean_us": float(us.mean()),
        "p95_us": float(np.percentile(us, 95)),
        "max_us": float(us.max()),
        "series_us": us.mean(axis=1),
        "dram_bytes": int(dram),
    }
