"""Shared benchmark helpers: compiled microbench loops + CSV/JSON records.

Microbenchmarks drive the allocator through the `repro.core.heap` protocol
(`run_rounds` / `run_alloc_free_rounds` — the same `step` that serves every
backend kind), so figures measure exactly the public surface.

Every figure module exposes ``bench(smoke=False) -> [record]``; a record is
one emitted row plus its structured metrics (the JSON trajectory's unit —
see benchmarks/README.md for the schema). ``emit`` prints the CSV row and
returns the record, so modules stay single-sourced.
"""
from __future__ import annotations

import numbers

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import heap as heap_api
from repro.core import system as sysm

ROWS = []


def emit(name: str, us_per_call: float, derived: str = "",
         backend: str = None, **metrics) -> dict:
    """Print one `name,us_per_call,derived` CSV row; return the record.

    Extra keyword metrics land in the record as numbers (allocs_per_sec,
    metadata_bytes_per_op, ...) for the JSON artifact. Every record is
    stamped with the jax version, and — when the row measures a specific
    allocator design point — with its ``backend`` name
    (strawman/sw/hwsw/pallas), so baseline diffs stay attributable across
    environments and backend axes.
    """
    row = f"{name},{us_per_call:.4f},{derived}"
    ROWS.append(row)
    print(row, flush=True)
    rec = {"name": name, "us_per_call": float(us_per_call),
           "derived": str(derived), "jax": jax.__version__}
    if backend is not None:
        rec["backend"] = str(backend)
    for k, v in metrics.items():
        rec[k] = float(v) if isinstance(v, numbers.Number) else v
    return rec


def micro_alloc(kind: str, size: int, nthreads: int, rounds: int = 128,
                heap: int = 1 << 25, T: int = 16, alloc_free: bool = False):
    """Fig 14-style microbenchmark: per-thread latency stats (us).

    Also derives the JSON schema's throughput metrics: threads within a
    round run concurrently (mutex queuing is inside the cost model), rounds
    serialize, so modeled wall time is the sum of per-round maxima.
    """
    cfg = sysm.SystemConfig(kind=kind, heap_bytes=heap, num_threads=T)
    st = heap_api.init(cfg)
    sizes = jnp.where(jnp.arange(T) < nthreads, size, 0).astype(jnp.int32)
    sz = jnp.tile(sizes[None, :], (rounds, 1))
    if alloc_free:
        run = jax.jit(lambda s, z: heap_api.run_alloc_free_rounds(cfg, s, z))
        st, resp_a, resp_f = run(st, sz)
        lat_a = np.asarray(resp_a.latency_cyc)[:, :nthreads]
        lat_f = np.asarray(resp_f.latency_cyc)[:, :nthreads]
        lat = lat_a + lat_f
        # alloc and free are two serialized protocol rounds: wall = sum of
        # each subround's slowest thread (matches fig_fleet._alloc_free)
        wall_cyc = lat_a.max(axis=1).sum() + lat_f.max(axis=1).sum()
        dram = (np.asarray(resp_a.dram_bytes).sum()
                + np.asarray(resp_f.dram_bytes).sum())
    else:
        run = jax.jit(lambda s, z: heap_api.run_rounds(
            cfg, s, jax.vmap(heap_api.malloc_request)(z)))
        st, resp = run(st, sz)
        lat = np.asarray(resp.latency_cyc)[:, :nthreads]
        wall_cyc = lat.max(axis=1).sum()
        dram = np.asarray(resp.dram_bytes).sum()
    us = lat / cfg.dpu.freq_hz * 1e6
    ops = rounds * nthreads * (2 if alloc_free else 1)
    modeled_s = float(wall_cyc) / cfg.dpu.freq_hz
    return {
        "mean_us": float(us.mean()),
        "p95_us": float(np.percentile(us, 95)),
        "max_us": float(us.max()),
        "series_us": us.mean(axis=1),
        "dram_bytes": int(dram),
        "ops": ops,
        "allocs_per_sec": ops / max(modeled_s, 1e-12),
        "metadata_bytes_per_op": dram / max(ops, 1),
    }
