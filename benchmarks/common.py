"""Shared benchmark helpers: compiled microbench loops + CSV/JSON records.

Microbenchmarks drive the allocator through the `repro.core.heap` protocol
(`run_rounds` / `run_alloc_free_rounds` — the same `step` that serves every
backend kind), so figures measure exactly the public surface.

Every figure module exposes ``bench(smoke=False) -> [record]``; a record is
one emitted row plus its structured metrics (the JSON trajectory's unit —
see benchmarks/README.md for the schema). ``emit`` prints the CSV row and
returns the record, so modules stay single-sourced.
"""
from __future__ import annotations

import numbers
import platform
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import heap as heap_api
from repro.core import system as sysm

ROWS = []


def emit(name: str, us_per_call: float, derived: str = "",
         backend: str = None, **metrics) -> dict:
    """Print one `name,us_per_call,derived` CSV row; return the record.

    Extra keyword metrics land in the record as numbers (allocs_per_sec,
    metadata_bytes_per_op, ...) for the JSON artifact. Every record is
    stamped with the jax version, and — when the row measures a specific
    allocator design point — with its ``backend`` name
    (strawman/sw/hwsw/pallas), so baseline diffs stay attributable across
    environments and backend axes.
    """
    row = f"{name},{us_per_call:.4f},{derived}"
    ROWS.append(row)
    print(row, flush=True)
    rec = {"name": name, "us_per_call": float(us_per_call),
           "derived": str(derived), "jax": jax.__version__}
    if backend is not None:
        rec["backend"] = str(backend)
    for k, v in metrics.items():
        rec[k] = float(v) if isinstance(v, numbers.Number) else v
    return rec


def micro_alloc(kind: str, size: int, nthreads: int, rounds: int = 128,
                heap: int = 1 << 25, T: int = 16, alloc_free: bool = False):
    """Fig 14-style microbenchmark: per-thread latency stats (us).

    Also derives the JSON schema's throughput metrics: threads within a
    round run concurrently (mutex queuing is inside the cost model), rounds
    serialize, so modeled wall time is the sum of per-round maxima.
    """
    cfg = sysm.SystemConfig(kind=kind, heap_bytes=heap, num_threads=T)
    st = heap_api.init(cfg)
    sizes = jnp.where(jnp.arange(T) < nthreads, size, 0).astype(jnp.int32)
    sz = jnp.tile(sizes[None, :], (rounds, 1))
    if alloc_free:
        run = jax.jit(lambda s, z: heap_api.run_alloc_free_rounds(cfg, s, z))
        st, resp_a, resp_f = run(st, sz)
        lat_a = np.asarray(resp_a.latency_cyc)[:, :nthreads]
        lat_f = np.asarray(resp_f.latency_cyc)[:, :nthreads]
        lat = lat_a + lat_f
        # alloc and free are two serialized protocol rounds: wall = sum of
        # each subround's slowest thread (matches fig_fleet._alloc_free)
        wall_cyc = lat_a.max(axis=1).sum() + lat_f.max(axis=1).sum()
        dram = (np.asarray(resp_a.dram_bytes).sum()
                + np.asarray(resp_f.dram_bytes).sum())
    else:
        run = jax.jit(lambda s, z: heap_api.run_rounds(
            cfg, s, jax.vmap(heap_api.malloc_request)(z)))
        st, resp = run(st, sz)
        lat = np.asarray(resp.latency_cyc)[:, :nthreads]
        wall_cyc = lat.max(axis=1).sum()
        dram = np.asarray(resp.dram_bytes).sum()
    us = lat / cfg.dpu.freq_hz * 1e6
    ops = rounds * nthreads * (2 if alloc_free else 1)
    modeled_s = float(wall_cyc) / cfg.dpu.freq_hz
    return {
        "mean_us": float(us.mean()),
        "p95_us": float(np.percentile(us, 95)),
        "max_us": float(us.max()),
        "series_us": us.mean(axis=1),
        "dram_bytes": int(dram),
        "ops": ops,
        "allocs_per_sec": ops / max(modeled_s, 1e-12),
        "metadata_bytes_per_op": dram / max(ops, 1),
    }


def wall_env_key() -> str:
    """Coarse runner class stamped on wall-clock rows.

    Wall numbers are only comparable between runs on the same OS / arch /
    jax backend / execution mode (CPU-interpret vs compiled device) — the
    perf gate refuses to diff wall rows across different env keys, so a
    TPU baseline can never gate a CPU CI runner or vice versa. Machine
    *speed* within a class still varies; that's what the generous
    ``--fail-over-wall`` threshold absorbs.
    """
    from repro.kernels.ops import on_tpu
    mode = "compiled" if on_tpu() else "interpret"
    return f"{sys.platform}-{platform.machine()}-{jax.default_backend()}-{mode}"


def timed(fn, *args, warmup: int = 1, repeats: int = 5):
    """Median wall seconds of ``fn(*args)``, fully materialized.

    Compiles/warms with ``warmup`` untimed calls, then times ``repeats``
    calls under `jax.block_until_ready` and returns
    ``(median_seconds, last_output)``.
    """
    out = None
    for _ in range(max(warmup, 1)):
        out = jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples)), out


def micro_alloc_wall(kind: str, size: int, nthreads: int, rounds: int = 96,
                     heap: int = 1 << 25, T: int = 16, warmup: int = 1,
                     repeats: int = 5, batch_refill: bool = None):
    """Wall-clock companion of `micro_alloc`: measured execution time of the
    same compiled round loop, plus modeled stats from the executed responses
    so every wall row carries its modeled counterpart for delta reporting.

    ``batch_refill`` only affects the ``pallas`` kind (None = env default);
    passing False measures the pre-batching serial kernel for the committed
    speedup row.
    """
    cfg = sysm.SystemConfig(kind=kind, heap_bytes=heap, num_threads=T,
                            kernel_batch_refill=batch_refill)
    st = heap_api.init(cfg)
    sizes = jnp.where(jnp.arange(T) < nthreads, size, 0).astype(jnp.int32)
    sz = jnp.tile(sizes[None, :], (rounds, 1))
    run = jax.jit(lambda s, z: heap_api.run_rounds(
        cfg, s, jax.vmap(heap_api.malloc_request)(z)))
    wall_s, (_, resp) = timed(run, st, sz, warmup=warmup, repeats=repeats)
    lat = np.asarray(resp.latency_cyc)[:, :nthreads]
    modeled_s = float(lat.max(axis=1).sum()) / cfg.dpu.freq_hz
    ops = rounds * nthreads
    return {
        "wall_us_per_round": wall_s / rounds * 1e6,
        "wall_us_per_call": wall_s / ops * 1e6,
        "modeled_us_per_call": modeled_s / ops * 1e6,
        "rounds_per_sec": rounds / max(wall_s, 1e-12),
        "ops": ops,
        "rounds": rounds,
    }
