"""Benchmark harness: one module per paper table/figure, CSV + JSON out.

    PYTHONPATH=src python -m benchmarks.run [fig5 fig6 ...] \
        [--smoke] [--json BENCH_out.json]

Prints ``name,us_per_call,derived`` CSV rows (any failure becomes a
``<fig>/ERROR`` row — CI greps for those), and with ``--json`` also writes
the schema'd artifact CI uploads for the perf trajectory (schema documented
in benchmarks/README.md, validated here before writing). ``--smoke``
shrinks every sweep to seconds for the CI bench-smoke job. `roofline` reads
the dry-run artifacts (run repro.launch.dryrun first for that section).
"""
from __future__ import annotations

import argparse
import datetime
import importlib
import json
import numbers
import os
import platform
import subprocess
import sys
import time

ALL = ("fig5", "fig6", "fig7", "fig14", "fig14_wall", "fig15", "fig16",
       "fig_fleet", "fleet_serve", "fig_decode", "workloads", "fig_arena",
       "fig_elastic", "roofline")
SCHEMA = "pim-malloc-bench/v1"
# per-record attribution stamps (the only non-numeric record fields besides
# name/derived): allocator design point, jax version, and for wall-clock
# rows the row family marker + runner class (see common.wall_env_key)
STRING_FIELDS = ("backend", "jax", "lane", "env_key")

_MODULES = {
    "fig5": "fig5_design_space",
    "fig6": "fig6_heap_sweep",
    "fig7": "fig7_contention",
    "fig14": "fig14_micro",
    "fig14_wall": "fig14_wall",
    "fig15": "fig15_cache_size",
    "fig16": "fig16_graph",
    "fig_fleet": "fig_fleet",
    "fleet_serve": "fig_serve",
    "fig_decode": "fig_decode",
    "workloads": "fig_workloads",
    "fig_arena": "fig_arena",
    "fig_elastic": "fig_elastic",
    "roofline": "roofline",
}


def env_stamp(smoke: bool, root: str = None) -> dict:
    import jax
    if root is None:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=root, timeout=10).stdout.strip() or "unknown"
        # a baseline generated from an uncommitted tree must say so: the
        # stamped revision alone could not reproduce its rows. Tracked
        # files only — stray __pycache__/ dirs or editor droppings must
        # not mark a clean checkout's baseline as irreproducible.
        dirty = subprocess.run(
            ["git", "status", "--porcelain", "--untracked-files=no"],
            capture_output=True, text=True,
            cwd=root, timeout=10).stdout.strip()
        if commit != "unknown" and dirty:
            commit += "-dirty"
    except Exception:
        commit = "unknown"
    return {
        "python": sys.version.split()[0],
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "platform": platform.platform(),
        "commit": commit,
        "smoke": bool(smoke),
    }


def validate(doc: dict) -> list:
    """Schema check for the JSON artifact; returns a list of error strings."""
    errs = []
    if doc.get("schema") != SCHEMA:
        errs.append(f"schema != {SCHEMA}")
    env = doc.get("env")
    if not isinstance(env, dict):
        errs.append("env missing")
    else:
        for k in ("python", "jax", "backend", "device_count", "commit",
                  "smoke"):
            if k not in env:
                errs.append(f"env.{k} missing")
    figs = doc.get("figs")
    if not isinstance(figs, dict) or not figs:
        errs.append("figs missing/empty")
        return errs
    for fig, cell in figs.items():
        if cell.get("status") not in ("ok", "error"):
            errs.append(f"figs.{fig}.status invalid")
        if not isinstance(cell.get("wall_s"), numbers.Number):
            errs.append(f"figs.{fig}.wall_s missing")
        recs = cell.get("records")
        if not isinstance(recs, list):
            errs.append(f"figs.{fig}.records not a list")
            continue
        names = [r.get("name") for r in recs]
        for dup in sorted({n for n in names if names.count(n) > 1}):
            errs.append(f"figs.{fig} duplicate record name {dup!r}")
        for i, r in enumerate(recs):
            if not isinstance(r.get("name"), str):
                errs.append(f"figs.{fig}.records[{i}].name missing")
            if not isinstance(r.get("us_per_call"), numbers.Number):
                errs.append(f"figs.{fig}.records[{i}].us_per_call missing")
            if not isinstance(r.get("derived", ""), str):
                errs.append(f"figs.{fig}.records[{i}].derived not a string")
            for k, v in r.items():
                if k in ("name", "derived"):
                    continue
                if k in STRING_FIELDS:  # attribution stamps
                    if not isinstance(v, str):
                        errs.append(f"figs.{fig}.records[{i}].{k} not a string")
                    continue
                if not isinstance(v, numbers.Number):
                    errs.append(f"figs.{fig}.records[{i}].{k} not numeric")
    return errs


def run_fig(name: str, smoke: bool) -> dict:
    t0 = time.time()
    try:
        m = importlib.import_module(f".{_MODULES[name]}", package=__package__)
        records = m.bench(smoke=smoke)
        status, error = "ok", None
    except Exception as e:  # keep the harness going; report the failure
        print(f"{name}/ERROR,0,{type(e).__name__}: {e}", flush=True)
        records, status, error = [], "error", f"{type(e).__name__}: {e}"
    cell = {"status": status, "wall_s": round(time.time() - t0, 2),
            "records": records}
    if error:
        cell["error"] = error
    print(f"# {name} done in {cell['wall_s']:.1f}s", flush=True)
    return cell


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("figs", nargs="*", help=f"subset of {ALL}")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweeps for CI (seconds, not minutes)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the schema'd BENCH_*.json artifact here")
    args = ap.parse_args(argv)
    which = list(dict.fromkeys(args.figs)) or list(ALL)
    for name in which:
        if name not in _MODULES:
            raise SystemExit(f"unknown benchmark {name} (have {ALL})")

    print("name,us_per_call,derived")
    figs = {name: run_fig(name, args.smoke) for name in which}

    doc = {
        "schema": SCHEMA,
        "generated_at": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "env": env_stamp(args.smoke),
        "figs": figs,
    }
    errs = validate(doc)
    if errs:
        raise SystemExit("schema-invalid bench doc: " + "; ".join(errs))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
