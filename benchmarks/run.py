"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [fig5 fig6 ...]

Prints ``name,us_per_call,derived`` CSV rows. `roofline` reads the dry-run
artifacts (run repro.launch.dryrun first for that section).
"""
from __future__ import annotations

import sys
import time

ALL = ("fig5", "fig6", "fig7", "fig14", "fig15", "fig16", "roofline")


def main() -> None:
    which = [a for a in sys.argv[1:] if not a.startswith("-")] or list(ALL)
    print("name,us_per_call,derived")
    for name in which:
        t0 = time.time()
        if name == "fig5":
            from . import fig5_design_space as m
        elif name == "fig6":
            from . import fig6_heap_sweep as m
        elif name == "fig7":
            from . import fig7_contention as m
        elif name == "fig14":
            from . import fig14_micro as m
        elif name == "fig15":
            from . import fig15_cache_size as m
        elif name == "fig16":
            from . import fig16_graph as m
        elif name == "roofline":
            from . import roofline as m
        else:
            raise SystemExit(f"unknown benchmark {name}")
        try:
            m.run()
        except Exception as e:  # keep the harness going; report the failure
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}", flush=True)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
