"""fig_arena: the layered-frontend design points (arena / tlregion) vs the
buddy-backed baseline, on the two workloads epoch reset is built for.

Two lanes, both modeled (deterministic functions of the cost model, so
every row is perf-gate trackable):

  * **graph_churn tape** — the committed dynamic-graph churn tape replayed
    on strawman / hwsw / arena / tlregion: small node cells served by the
    O(1) bump frontend (``arena``: shared region, atomic-bump wait;
    ``tlregion``: per-thread regions, zero cross-thread wait) vs the
    freelist+buddy baseline. Rows are modeled us/op.
  * **FleetServe expiry lane** — the same external arrival stream served
    two ways: ``hwsw`` with explicit per-block expiry FREEs vs the arena
    kinds in ``TrafficConfig.epoch_rounds`` mode (small blocks become
    round-scoped Temp allocations, reclaimed by whole-grid
    ``OP_EPOCH_RESET`` rounds; big bypass blocks keep explicit expiry).
    Rows are modeled wall us per *external* request served
    (``us_per_call`` — management traffic is overhead, not calls).

The module **raises** — an errored figure, which the perf gate hard-fails —
if either arena kind stops beating the buddy-only baseline on its lane:
the layering win is an acceptance criterion, not a trend to drift.

Sessions and the tape are smoke-sized, so ``--smoke`` and full runs
measure identical rows (same policy as fig_workloads).
"""
from __future__ import annotations

import os
import time

from repro.core import system as sysm
from repro.launch.serve_fleet import FleetServe, TrafficConfig
from repro.workloads.replay import replay
from repro.workloads.trace import Trace

from .common import emit

TAPES_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "tapes")

TAPE_KINDS = ("strawman", "hwsw", "arena", "tlregion")

# the expiry-lane session: one arrival stream (seed-pinned), served with
# explicit expiry frees on hwsw and in epoch mode on the arena kinds
SERVE = dict(R=1, C=2, T=8, heap=1 << 21, rounds=32, rate=12.0,
             tenants=12, seed=5, epoch_rounds=8)


def _serve(kind: str, epoch_rounds: int):
    cfg = sysm.SystemConfig(kind=kind, heap_bytes=SERVE["heap"],
                            num_threads=SERVE["T"])
    tc = TrafficConfig(seed=SERVE["seed"], rounds=SERVE["rounds"],
                       arrival_rate=SERVE["rate"],
                       num_tenants=SERVE["tenants"],
                       epoch_rounds=epoch_rounds)
    eng = FleetServe(cfg, SERVE["R"], SERVE["C"], traffic=tc,
                     placement="round_robin")
    _, rep = eng.serve()
    return rep


def bench(smoke: bool = False):
    recs = []

    # -- lane 1: the committed graph_churn tape ---------------------------
    tape = Trace.load(os.path.join(TAPES_DIR, "graph_churn.json"))
    us = {}
    for kind in TAPE_KINDS:
        _, _, rep = replay(tape, kind)
        us[kind] = rep["us_per_op"]
        tel = rep["telemetry"]
        recs.append(emit(
            f"fig_arena/graph_churn/{kind}", rep["us_per_op"],
            f"ok={rep['ok_ops']}/{rep['ops']};"
            f"wall={rep['modeled_wall_us']:.2f}us", backend=kind,
            ok_ops=rep["ok_ops"], failed_allocs=rep["failed_allocs"],
            dropped_frees=rep["dropped_frees"],
            live_bytes=tel["live_bytes"],
            conservation_residual=tel["conservation_residual"]))
    for kind in ("arena", "tlregion"):
        if us[kind] >= us["hwsw"]:
            raise RuntimeError(
                f"layering regression: {kind} ({us[kind]:.4f} us/op) no "
                f"longer beats hwsw ({us['hwsw']:.4f}) on graph_churn")
    recs.append(emit(
        "fig_arena/graph_churn/claim_speedup", 0.0,
        f"arena={us['hwsw'] / us['arena']:.2f}x "
        f"tlregion={us['hwsw'] / us['tlregion']:.2f}x vs hwsw",
        arena_speedup=us["hwsw"] / us["arena"],
        tlregion_speedup=us["hwsw"] / us["tlregion"]))

    # -- lane 2: the FleetServe expiry lane -------------------------------
    calls = {}
    for name, kind, er in (("hwsw_explicit", "hwsw", 0),
                           ("arena_epoch", "arena", SERVE["epoch_rounds"]),
                           ("tlregion_epoch", "tlregion",
                            SERVE["epoch_rounds"])):
        t0 = time.time()
        rep = _serve(kind, er)
        assert rep["failed_allocs"] == 0, (name, rep["failed_allocs"])
        assert rep["conservation_residual"] == 0, name
        calls[name] = rep["us_per_call"]
        recs.append(emit(
            f"fig_arena/expiry/{name}", rep["us_per_call"],
            f"ext={rep['external_dispatched']};"
            f"frees={rep['expiry_frees_dispatched']};"
            f"p95={rep['e2e_p95_cyc']:.0f}cyc;"
            f"backlog={rep['backlog_end']}", backend=kind,
            external_dispatched=rep["external_dispatched"],
            expiry_frees_dispatched=rep["expiry_frees_dispatched"],
            epoch_resets=rep.get("epoch_resets", 0),
            epoch_managed_allocs=rep.get("epoch_managed_allocs", 0),
            e2e_p95_cyc=rep["e2e_p95_cyc"], backlog_end=rep["backlog_end"],
            modeled_wall_us=rep["modeled_wall_us"],
            wall_s=time.time() - t0))
    for name in ("arena_epoch", "tlregion_epoch"):
        if calls[name] >= calls["hwsw_explicit"]:
            raise RuntimeError(
                f"epoch-reset regression: {name} ({calls[name]:.4f} "
                f"us/call) no longer beats hwsw explicit expiry "
                f"({calls['hwsw_explicit']:.4f}) on the serve lane")
    recs.append(emit(
        "fig_arena/expiry/claim_speedup", 0.0,
        f"arena={calls['hwsw_explicit'] / calls['arena_epoch']:.2f}x "
        f"tlregion={calls['hwsw_explicit'] / calls['tlregion_epoch']:.2f}x "
        "vs explicit expiry",
        arena_speedup=calls["hwsw_explicit"] / calls["arena_epoch"],
        tlregion_speedup=calls["hwsw_explicit"] / calls["tlregion_epoch"]))
    return recs


def run():
    bench()
