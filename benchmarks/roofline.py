"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md SSRoofline).

Reads results/dryrun/*.json (written by repro.launch.dryrun), prints the
three per-device roofline terms, the dominant bottleneck, MODEL_FLOPS/HLO
ratio, and per-cell one-liners. Markdown table via --markdown.
"""
from __future__ import annotations

import glob
import json
import os

from repro import configs
from repro.models.config import SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")


def n_params(cfg) -> float:
    """Total (and active for MoE) parameter counts from the config."""
    import numpy as np

    from repro.models import registry
    sds = registry.param_sds(cfg)
    import jax
    total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(sds))
    active = total
    if cfg.is_moe:
        # replace full expert compute with top-k experts for 'active'
        moe_per_layer = 3 * cfg.d_model * cfg.expert_d_ff
        total_moe = cfg.n_layers * cfg.n_experts * moe_per_layer
        active_moe = cfg.n_layers * cfg.top_k * moe_per_layer
        active = total - total_moe + active_moe
    return total, active


def model_flops(arch: str, shape_name: str, kind: str) -> float:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    total, active = n_params(cfg)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * active * tokens


def load_cells(results_dir: str = RESULTS_DIR):
    cells = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        cells.append(json.load(open(f)))
    return cells


def analyze_cell(r: dict) -> dict:
    if r["status"] != "ok":
        return {**r, "note": r.get("reason", r.get("error", ""))[:80]}
    n = r["devices"]
    hlo = r["hlo"]
    # the SPMD HLO is the per-device program: terms are per-device already
    terms = {
        "compute_s": hlo["flops_scaled"] / PEAK_FLOPS,
        "memory_s": hlo["memory_bytes_scaled"] / HBM_BW,
        "collective_s": hlo["collective_bytes_scaled"] / ICI_BW,
    }
    bound = max(terms, key=terms.get)
    total = max(sum(terms.values()), 1e-30)
    mf = model_flops(r["arch"], r["shape"], r["kind"])   # global model flops
    useful = (mf / n) / max(hlo["flops_scaled"], 1.0)
    # roofline fraction: useful per-device compute time / sum of terms
    frac = (mf / n / PEAK_FLOPS) / total
    return {
        **r, "terms": terms, "bottleneck": bound, "model_flops": mf,
        "useful_flops_ratio": useful, "roofline_frac": frac,
    }


def bench(smoke: bool = False):
    from .common import emit
    recs = []
    cells = [analyze_cell(r) for r in load_cells()]
    ok = [c for c in cells if c["status"] == "ok"]
    for c in sorted(ok, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        t = c["terms"]
        recs.append(emit(
            f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
            sum(t.values()) * 1e6,
            f"compute={t['compute_s']:.2e}s;mem={t['memory_s']:.2e}s;"
            f"coll={t['collective_s']:.2e}s;bound={c['bottleneck']};"
            f"useful={c['useful_flops_ratio']:.2f};"
            f"roofline_frac={c['roofline_frac']:.3f}",
            roofline_frac=c["roofline_frac"],
        ))
    skipped = [c for c in cells if c["status"] == "skipped"]
    errs = [c for c in cells if c["status"] == "error"]
    recs.append(emit(
        "roofline/summary", 0.0,
        f"ok={len(ok)};skipped={len(skipped)};error={len(errs)}",
        cells_ok=len(ok), cells_error=len(errs)))
    return recs


def run(markdown: bool = False):
    bench()


def markdown_table():
    cells = [analyze_cell(r) for r in load_cells()]
    rows = ["| arch | shape | mesh | compute (s) | memory (s) | collective (s)"
            " | bound | useful | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        if c["status"] != "ok":
            rows.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | - | - |"
                        f" - | {c['status']} | - | - |")
            continue
        t = c["terms"]
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {t['compute_s']:.2e} | {t['memory_s']:.2e} "
            f"| {t['collective_s']:.2e} | {c['bottleneck'].replace('_s','')} "
            f"| {c['useful_flops_ratio']:.2f} | {c['roofline_frac']:.3f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    import sys
    if "--markdown" in sys.argv:
        print(markdown_table())
    else:
        run()
