"""Workload-tape replay rows: the paper's application workloads (Section 6)
as recorded AllocRequest tapes, replayed per backend with heap telemetry.

Each committed tape under ``benchmarks/tapes/`` (dynamic-graph churn,
paged-KV serving, hash-table grow-rehash — regenerate with
``python -m repro.workloads.record``) replays closed-loop on every
registered backend. Rows are fig16-style: modeled us/op per
(workload, backend), with the replayer's fragmentation/utilization
telemetry (live bytes, high-water mark, external fragmentation, dropped
frees) as record metrics, plus one speedup claim row per tape
(PIM-malloc-SW vs the shared-mutex strawman).

Tapes are committed at smoke scale, so ``--smoke`` and full runs measure
the same rows — the perf gate tracks them either way.
"""
from __future__ import annotations

import glob
import os

from repro.workloads.replay import replay_all_kinds
from repro.workloads.trace import Trace

from .common import emit

TAPES_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "tapes")


def bench(smoke: bool = False):
    recs = []
    tapes = sorted(glob.glob(os.path.join(TAPES_DIR, "*.json")))
    if not tapes:
        raise FileNotFoundError(f"no committed tapes under {TAPES_DIR}")
    for path in tapes:
        trace = Trace.load(path)
        results = replay_all_kinds(trace)
        by_kind = {k: rep for k, (_, rep) in results.items()}
        for kind, rep in sorted(by_kind.items()):
            tel = rep["telemetry"]
            wall_s = rep["modeled_wall_us"] * 1e-6
            recs.append(emit(
                f"workload/{trace.name}/{kind}", rep["us_per_op"],
                f"ok={rep['ok_ops']}/{rep['ops']};"
                f"dropped={rep['dropped_frees']};"
                f"util={tel['utilization']:.2f};"
                f"frag={tel['external_frag']:.2f}",
                backend=kind,
                allocs_per_sec=rep["ops"] / max(wall_s, 1e-12),
                metadata_bytes_per_op=rep["meta_dram_bytes"]
                / max(rep["ops"], 1),
                ok_ops=rep["ok_ops"],
                failed_allocs=rep["failed_allocs"],
                dropped_frees=rep["dropped_frees"],
                moved_reallocs=rep["moved_reallocs"],
                live_bytes=tel["live_bytes"],
                hwm_bytes=tel["hwm_bytes"],
                utilization=tel["utilization"],
                external_frag=tel["external_frag"],
                cached_frontend_bytes=tel["cached_frontend_bytes"],
                conservation_residual=tel["conservation_residual"],
            ))
        if "sw" in by_kind and "strawman" in by_kind:
            speed = (by_kind["strawman"]["us_per_op"]
                     / max(by_kind["sw"]["us_per_op"], 1e-12))
            recs.append(emit(
                f"workload/{trace.name}/claim_speedup", 0.0,
                f"sw_vs_strawman={speed:.0f}x on the recorded tape",
                speedup_vs_strawman=speed))
    return recs


def run():
    bench()
