"""Fig 16 + Fig 10: dynamic graph updates — throughput, per-round latency
series, frontend/backend characterization, and metadata DRAM traffic."""
import numpy as np

from repro.graphupd.workload import GraphConfig, compare_all, run_dynamic

from .common import emit


def bench(smoke: bool = False):
    recs = []
    cfg = (GraphConfig(n_nodes=96, n_edges_pre=320, n_edges_new=160)
           if smoke else GraphConfig())
    res = compare_all(cfg)
    st = res["static_csr"]["us_per_edge"]
    for name, v in res.items():
        speed = st / v["us_per_edge"]
        recs.append(emit(
            f"fig16/{name}", v["us_per_edge"],
            f"edges_per_s={v['edges_per_s']:.0f};vs_static={speed:.1f}x",
            allocs_per_sec=v["edges_per_s"], speedup_vs_static=speed,
            **({"metadata_bytes_per_op":
                v["dram_bytes"] / max(cfg.n_edges_new, 1)}
               if "dram_bytes" in v else {})))
    recs.append(emit(
        "fig16/claim_28x", res["sw"]["us_per_edge"],
        f"sw={st / res['sw']['us_per_edge']:.0f}x vs static (paper: 28x); "
        f"strawman={st / res['strawman']['us_per_edge']:.2f}x (paper: <1x)"))
    if res["sw"]["dram_bytes"]:
        red = 1 - res["hwsw"]["dram_bytes"] / res["sw"]["dram_bytes"]
        recs.append(emit(
            "fig16c/dram_reduction", 0.0,
            f"hwsw_vs_sw=-{red:.0%} (paper: -33%)", dram_reduction=red))

    # ---- Fig 10 characterization on the same workload ----------------------
    g, infos, per_round, us = run_dynamic(cfg, "sw")
    path = np.concatenate([np.asarray(i.path) for i in infos])
    lat = np.concatenate([np.asarray(i.latency_cyc) for i in infos])
    front = path == 0
    back = (path == 1) | (path == 2)
    f_us = lat[front].mean() / 350e6 * 1e6
    b_us = lat[back].mean() / 350e6 * 1e6 if back.any() else float("nan")
    recs.append(emit(
        "fig10a/frontend_service_rate", f_us,
        f"{front.sum() / max(front.sum() + back.sum(), 1):.1%} (paper: >90%)",
        frontend_share=front.sum() / max(front.sum() + back.sum(), 1)))
    if np.isfinite(b_us):
        recs.append(emit(
            "fig10b/backend_vs_frontend_latency", b_us,
            f"ratio={b_us / f_us:.0f}x (paper: ~80x)"))
    agg_b = lat[back].sum() / max(lat[front | back].sum(), 1)
    recs.append(emit(
        "fig10c/backend_share_of_aggregate_latency", 0.0,
        f"{agg_b:.0%} (paper: 87%)"))
    # Fig 16(b): latency-over-time spikes = thread-cache misses
    spikes = (per_round > 10 * np.median(per_round)).sum()
    recs.append(emit(
        "fig16b/latency_spike_rounds", float(np.median(per_round)),
        f"spikes={spikes}/{len(per_round)} rounds (refill fallbacks)"))
    return recs


def run():
    bench()
