"""CI perf-regression gate: diff a bench-smoke JSON against the committed
baseline (`BENCH_BASELINE.json`, schema ``pim-malloc-bench/v1``).

    PYTHONPATH=src python benchmarks/perf_gate.py bench_smoke.json \
        [--baseline BENCH_BASELINE.json] [--fail-over 0.20] [--warn-over 0.05]

Every baseline record with a positive ``us_per_call`` is a *tracked row*
(the modeled latencies are deterministic functions of the cost model, so
they are stable across runner machines; wall-clock metrics such as
``wall_us_per_step`` are never gated). The gate

  * FAILS (exit 1) when any tracked row regresses by more than
    ``--fail-over`` (default +20% us_per_call),
  * FAILS when a tracked row disappears from the current run — a deleted
    or renamed benchmark must refresh the committed baseline explicitly,
    never fall out of the trajectory silently,
  * WARNS on regressions above ``--warn-over`` (default +5%),
  * reports improvements and newly appearing rows informationally,

and writes the delta table as GitHub-flavored markdown to
``$GITHUB_STEP_SUMMARY`` when that env var is set (always to stdout).
Refreshing the baseline after an intentional perf change is documented in
benchmarks/README.md ("Perf gate & baseline refresh").
"""
from __future__ import annotations

import argparse
import json
import os
import sys

SCHEMA = "pim-malloc-bench/v1"


def load_rows(path: str) -> dict:
    """{record name: record} for every ok-figure record in a bench doc."""
    rows, _ = load_rows_and_errors(path)
    return rows


def load_rows_and_errors(path: str):
    """(rows, errored-figure dict) — errored figures carry no usable rows,
    and a gate run must treat them as failures, not as missing rows."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != SCHEMA:
        raise SystemExit(f"{path}: schema != {SCHEMA}")
    rows, errors = {}, {}
    for fig, cell in doc.get("figs", {}).items():
        if cell.get("status") != "ok":
            errors[fig] = cell.get("error", "status != ok")
            continue
        for rec in cell.get("records", []):
            rows[rec["name"]] = rec
    return rows, errors


def diff_rows(base: dict, cur: dict, fail_over: float, warn_over: float):
    """Compare tracked rows; returns (entries, failures, warnings).

    entries: (name, base_us, cur_us, delta, verdict) sorted worst-first;
    delta is None for missing/new rows.
    """
    entries, failures, warnings = [], [], []
    tracked = {n: r for n, r in base.items() if r.get("us_per_call", 0) > 0}
    for name, brec in sorted(tracked.items()):
        b = float(brec["us_per_call"])
        crec = cur.get(name)
        if crec is None:
            failures.append(f"tracked row disappeared: {name} "
                            "(refresh BENCH_BASELINE.json if intentional)")
            entries.append((name, b, None, None, "MISSING"))
            continue
        c = float(crec["us_per_call"])
        delta = c / b - 1.0
        if delta > fail_over:
            verdict = "FAIL"
            failures.append(f"{name}: {b:.4f} -> {c:.4f} us "
                            f"(+{delta * 100:.1f}% > {fail_over * 100:.0f}%)")
        elif delta > warn_over:
            verdict = "warn"
            warnings.append(f"{name}: +{delta * 100:.1f}%")
        else:
            verdict = "ok"
        entries.append((name, b, c, delta, verdict))
    for name in sorted(set(cur) - set(base)):
        entries.append((name, None,
                        float(cur[name].get("us_per_call", 0.0)), None, "new"))
    entries.sort(key=lambda e: (-(e[3] if e[3] is not None else -1e9), e[0]))
    return entries, failures, warnings


def markdown_table(entries, limit: int = 40) -> str:
    lines = ["| row | baseline us | current us | delta | verdict |",
             "|---|---|---|---|---|"]
    for name, b, c, d, v in entries[:limit]:
        bs = f"{b:.4f}" if b is not None else "—"
        cs = f"{c:.4f}" if c is not None else "—"
        ds = f"{d * 100:+.1f}%" if d is not None else "—"
        mark = {"FAIL": "❌", "warn": "⚠️", "MISSING": "❌",
                "new": "🆕", "ok": ""}.get(v, "")
        lines.append(f"| `{name}` | {bs} | {cs} | {ds} | {mark} {v} |")
    if len(entries) > limit:
        lines.append(f"| … {len(entries) - limit} more rows … | | | | |")
    return "\n".join(lines)


def run_gate(current_path: str, baseline_path: str, fail_over: float,
             warn_over: float, summary_path: str = None) -> int:
    base = load_rows(baseline_path)
    cur, cur_errors = load_rows_and_errors(current_path)
    entries, failures, warnings = diff_rows(base, cur, fail_over, warn_over)
    # a figure that errored in the current run is a hard failure: its
    # tracked rows would otherwise all degrade to "missing" warnings and
    # a catastrophically broken run would read as a pass
    for fig, err in sorted(cur_errors.items()):
        failures.append(f"figure {fig} errored in the current run: {err}")
    n_tracked = sum(1 for e in entries if e[4] != "new")
    verdict = "FAILED" if failures else "passed"
    report = [
        f"## Perf gate {verdict}",
        f"{n_tracked} tracked rows vs `{os.path.basename(baseline_path)}` "
        f"(fail > +{fail_over * 100:.0f}%, warn > +{warn_over * 100:.0f}% "
        "modeled us_per_call)", "",
        markdown_table(entries), "",
    ]
    if failures:
        report += ["**Regressions over threshold:**"] + \
            [f"- {f}" for f in failures] + [""]
    if warnings:
        report += ["**Warnings:**"] + [f"- {w}" for w in warnings] + [""]
    text = "\n".join(report)
    print(text)
    if summary_path:
        with open(summary_path, "a") as f:
            f.write(text + "\n")
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="bench JSON of this run (bench_smoke.json)")
    ap.add_argument("--baseline",
                    default=os.path.join(os.path.dirname(os.path.dirname(
                        os.path.abspath(__file__))), "BENCH_BASELINE.json"))
    ap.add_argument("--fail-over", type=float, default=0.20,
                    help="fail when us_per_call regresses past this fraction")
    ap.add_argument("--warn-over", type=float, default=0.05)
    args = ap.parse_args(argv)
    return run_gate(args.current, args.baseline, args.fail_over,
                    args.warn_over, os.environ.get("GITHUB_STEP_SUMMARY"))


if __name__ == "__main__":
    sys.exit(main())
