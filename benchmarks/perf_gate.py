"""CI perf-regression gate: diff a bench-smoke JSON against the committed
baseline (`BENCH_BASELINE.json`, schema ``pim-malloc-bench/v1``).

    PYTHONPATH=src python benchmarks/perf_gate.py bench_smoke.json \
        [--baseline BENCH_BASELINE.json] [--fail-over 0.20] [--warn-over 0.05]
        [--fail-over-wall 1.50] [--warn-over-wall 0.50] [--lane all]

Every baseline record with a positive ``us_per_call`` is a *tracked row*,
in one of two families:

  * **modeled** rows (the default): deterministic functions of the cost
    model, stable across runner machines. FAIL (exit 1) past
    ``--fail-over`` (default +20%), and FAIL when a tracked row disappears
    from the current run — a deleted or renamed benchmark must refresh the
    committed baseline explicitly, never fall out of the trajectory
    silently. WARN above ``--warn-over`` (default +5%).
  * **wall** rows (``lane == "wall"``, e.g. ``fig14_wall/*``): measured
    execution time. Machine-dependent, so the thresholds are generous
    (``--fail-over-wall``, default +150%; warn +50%), rows are only
    compared when baseline and current carry the same ``env_key`` (runner
    class — CPU-interpret and compiled-device numbers never cross-gate;
    mismatches report as ``env-skip``), and a wall row *missing* from the
    current run is a warning, not a failure (a lane that only ran a subset
    must not read as a regression).

``--lane modeled|wall`` restricts the gate to one family (CI runs the
modeled gate on the full smoke artifact and the wall gate on the
bench-wall artifact separately); default ``all`` gates both. Improvements
and newly appearing rows report informationally, and the delta table —
grouped by row family (modeled, then wall), each group worst-first and
closed with a per-lane subtotal (summed us, aggregate delta, verdict
counts) — is written as GitHub-flavored markdown to
``$GITHUB_STEP_SUMMARY`` when that env var is set (always to stdout). Refreshing the baseline after an
intentional perf change is documented in benchmarks/README.md ("Perf gate
& baseline refresh").
"""
from __future__ import annotations

import argparse
import json
import os
import sys

SCHEMA = "pim-malloc-bench/v1"
WALL_FAIL_OVER = 1.50
WALL_WARN_OVER = 0.50


def load_rows(path: str) -> dict:
    """{record name: record} for every ok-figure record in a bench doc."""
    rows, _ = load_rows_and_errors(path)
    return rows


def load_rows_and_errors(path: str):
    """(rows, errored-figure dict) — errored figures carry no usable rows,
    and a gate run must treat them as failures, not as missing rows."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != SCHEMA:
        raise SystemExit(f"{path}: schema != {SCHEMA}")
    rows, errors = {}, {}
    for fig, cell in doc.get("figs", {}).items():
        if cell.get("status") != "ok":
            errors[fig] = cell.get("error", "status != ok")
            continue
        for rec in cell.get("records", []):
            rows[rec["name"]] = rec
    return rows, errors


def row_lane(rec: dict) -> str:
    """Row family: ``wall`` for measured-execution rows, else ``modeled``."""
    return "wall" if rec.get("lane") == "wall" else "modeled"


def diff_rows(base: dict, cur: dict, fail_over: float, warn_over: float,
              fail_over_wall: float = None, warn_over_wall: float = None,
              lane: str = "all"):
    """Compare tracked rows; returns (entries, failures, warnings).

    entries: (name, base_us, cur_us, delta, verdict, lane) sorted
    worst-first; delta is None for missing/new/env-skipped rows.
    """
    if fail_over_wall is None:
        fail_over_wall = WALL_FAIL_OVER
    if warn_over_wall is None:
        warn_over_wall = WALL_WARN_OVER
    entries, failures, warnings = [], [], []
    tracked = {n: r for n, r in base.items()
               if r.get("us_per_call", 0) > 0
               and lane in ("all", row_lane(r))}
    for name, brec in sorted(tracked.items()):
        rl = row_lane(brec)
        wall = rl == "wall"
        b = float(brec["us_per_call"])
        crec = cur.get(name)
        if crec is None:
            if wall:
                warnings.append(
                    f"wall row missing from current run: {name} "
                    "(wall lane warns, never fails, on absence)")
                entries.append((name, b, None, None, "no-wall", rl))
            else:
                failures.append(f"tracked row disappeared: {name} "
                                "(refresh BENCH_BASELINE.json if intentional)")
                entries.append((name, b, None, None, "MISSING", rl))
            continue
        c = float(crec["us_per_call"])
        if wall and str(brec.get("env_key")) != str(crec.get("env_key")):
            # different runner class: wall numbers are not comparable
            entries.append((name, b, c, None, "env-skip", rl))
            continue
        delta = c / b - 1.0
        fo, wo = ((fail_over_wall, warn_over_wall) if wall
                  else (fail_over, warn_over))
        if delta > fo:
            verdict = "FAIL"
            failures.append(
                f"{name}: {b:.4f} -> {c:.4f} us (+{delta * 100:.1f}% > "
                f"{fo * 100:.0f}%{' wall' if wall else ''})")
        elif delta > wo:
            verdict = "warn"
            warnings.append(f"{name}: +{delta * 100:.1f}%")
        else:
            verdict = "ok"
        entries.append((name, b, c, delta, verdict, rl))
    for name in sorted(set(cur) - set(base)):
        if lane not in ("all", row_lane(cur[name])):
            continue
        entries.append((name, None,
                        float(cur[name].get("us_per_call", 0.0)), None, "new",
                        row_lane(cur[name])))
    entries.sort(key=lambda e: (-(e[3] if e[3] is not None else -1e9), e[0]))
    return entries, failures, warnings


def markdown_table(entries, limit: int = 40) -> str:
    """Delta table grouped by row family (modeled, then wall), each group
    worst-first and closed by a subtotal row: summed tracked us on both
    sides, the aggregate delta of those sums, and per-verdict counts. The
    row budget (`limit`) is shared across groups."""
    lines = ["| row | baseline us | current us | delta | verdict |",
             "|---|---|---|---|---|"]
    shown = 0
    for fam in ("modeled", "wall"):
        group = [e for e in entries if e[5] == fam]
        if not group:
            continue
        lines.append(f"| **{fam} lane** — {len(group)} rows | | | | |")
        for name, b, c, d, v, _ in group[:max(0, limit - shown)]:
            bs = f"{b:.4f}" if b is not None else "—"
            cs = f"{c:.4f}" if c is not None else "—"
            ds = f"{d * 100:+.1f}%" if d is not None else "—"
            mark = {"FAIL": "❌", "warn": "⚠️", "MISSING": "❌",
                    "no-wall": "⚠️", "env-skip": "ℹ️", "new": "🆕",
                    "ok": ""}.get(v, "")
            lines.append(f"| `{name}` | {bs} | {cs} | {ds} | {mark} {v} |")
        hidden = len(group) - max(0, limit - shown)
        if hidden > 0:
            lines.append(f"| … {hidden} more {fam} rows … | | | | |")
        shown += len(group)
        # subtotal over rows compared on both sides (delta is not None)
        cmp_rows = [e for e in group if e[3] is not None]
        counts = {}
        for e in group:
            counts[e[4]] = counts.get(e[4], 0) + 1
        cstr = " ".join(f"{k}={counts[k]}" for k in
                        ("ok", "warn", "FAIL", "MISSING", "no-wall",
                         "env-skip", "new") if k in counts)
        if cmp_rows:
            sb = sum(e[1] for e in cmp_rows)
            sc = sum(e[2] for e in cmp_rows)
            sd = (sc / sb - 1.0) if sb > 0 else 0.0
            lines.append(f"| _{fam} subtotal ({len(cmp_rows)} compared)_ | "
                         f"{sb:.4f} | {sc:.4f} | {sd * 100:+.1f}% | {cstr} |")
        else:
            lines.append(f"| _{fam} subtotal (0 compared)_ | — | — | — | "
                         f"{cstr} |")
    return "\n".join(lines)


def run_gate(current_path: str, baseline_path: str, fail_over: float,
             warn_over: float, summary_path: str = None,
             fail_over_wall: float = None, warn_over_wall: float = None,
             lane: str = "all") -> int:
    if fail_over_wall is None:
        fail_over_wall = WALL_FAIL_OVER
    if warn_over_wall is None:
        warn_over_wall = WALL_WARN_OVER
    base = load_rows(baseline_path)
    cur, cur_errors = load_rows_and_errors(current_path)
    entries, failures, warnings = diff_rows(
        base, cur, fail_over, warn_over, fail_over_wall, warn_over_wall,
        lane)
    # a figure that errored in the current run is a hard failure: its
    # tracked rows would otherwise all degrade to "missing" warnings and
    # a catastrophically broken run would read as a pass
    for fig, err in sorted(cur_errors.items()):
        failures.append(f"figure {fig} errored in the current run: {err}")
    n_tracked = sum(1 for e in entries if e[4] != "new")
    verdict = "FAILED" if failures else "passed"
    report = [
        f"## Perf gate {verdict} (lane: {lane})",
        f"{n_tracked} tracked rows vs `{os.path.basename(baseline_path)}` "
        f"(modeled fail > +{fail_over * 100:.0f}%, warn > "
        f"+{warn_over * 100:.0f}%; wall fail > +{fail_over_wall * 100:.0f}%, "
        f"warn > +{warn_over_wall * 100:.0f}%, same env_key only)", "",
        markdown_table(entries), "",
    ]
    if failures:
        report += ["**Regressions over threshold:**"] + \
            [f"- {f}" for f in failures] + [""]
    if warnings:
        report += ["**Warnings:**"] + [f"- {w}" for w in warnings] + [""]
    text = "\n".join(report)
    print(text)
    if summary_path:
        with open(summary_path, "a") as f:
            f.write(text + "\n")
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="bench JSON of this run (bench_smoke.json)")
    ap.add_argument("--baseline",
                    default=os.path.join(os.path.dirname(os.path.dirname(
                        os.path.abspath(__file__))), "BENCH_BASELINE.json"))
    ap.add_argument("--fail-over", type=float, default=0.20,
                    help="fail when us_per_call regresses past this fraction")
    ap.add_argument("--warn-over", type=float, default=0.05)
    ap.add_argument("--fail-over-wall", type=float, default=WALL_FAIL_OVER,
                    help="wall-lane failure threshold (generous: measured "
                    "time varies with runner load)")
    ap.add_argument("--warn-over-wall", type=float, default=WALL_WARN_OVER)
    ap.add_argument("--lane", choices=("all", "modeled", "wall"),
                    default="all",
                    help="restrict the gate to one row family")
    args = ap.parse_args(argv)
    return run_gate(args.current, args.baseline, args.fail_over,
                    args.warn_over, os.environ.get("GITHUB_STEP_SUMMARY"),
                    args.fail_over_wall, args.warn_over_wall, args.lane)


if __name__ == "__main__":
    sys.exit(main())
