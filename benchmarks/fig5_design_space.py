"""Fig 5: design-space exploration — avg alloc latency vs #PIM cores for the
four (metadata placement x executor) strategies; breakdown at 512 cores."""
from repro.core import design_space as ds

from .common import emit


def run():
    sweep = ds.sweep(n_cores_list=(1, 8, 64, 512))
    for strat in ds.STRATEGIES:
        for n, r in sweep[strat].items():
            emit(f"fig5/{strat}/cores={n}", r["total"],
                 f"exec={r['exec']:.2f}us;xfer={r['xfer']:.2f}us")
    # paper's qualitative claims
    red = sweep["pim_meta_pim_exec"]
    flat = red[512]["total"] / red[1]["total"]
    emit("fig5/winner_scaling_512c_vs_1c", red[512]["total"],
         f"ratio={flat:.2f} (flat=1.0; paper: scalable)")
    worst = max(sweep[s][512]["total"] for s in ds.STRATEGIES)
    emit("fig5/worst_vs_winner_at_512", worst,
         f"{worst / red[512]['total']:.0f}x slower than PIM-meta/PIM-exec")
