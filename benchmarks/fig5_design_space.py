"""Fig 5: design-space exploration — avg alloc latency vs #PIM cores for the
four (metadata placement x executor) strategies; breakdown at 512 cores."""
from repro.core import design_space as ds

from .common import emit


def bench(smoke: bool = False):
    recs = []
    n_cores = (1, 8, 64) if smoke else (1, 8, 64, 512)
    top = n_cores[-1]
    sweep = ds.sweep(n_cores_list=n_cores)
    for strat in ds.STRATEGIES:
        for n, r in sweep[strat].items():
            recs.append(emit(
                f"fig5/{strat}/cores={n}", r["total"],
                f"exec={r['exec']:.2f}us;xfer={r['xfer']:.2f}us",
                allocs_per_sec=n * 1e6 / max(r["total"], 1e-12)))
    # paper's qualitative claims
    red = sweep["pim_meta_pim_exec"]
    flat = red[top]["total"] / red[1]["total"]
    recs.append(emit(
        f"fig5/winner_scaling_{top}c_vs_1c", red[top]["total"],
        f"ratio={flat:.2f} (flat=1.0; paper: scalable)", flat_ratio=flat))
    worst = max(sweep[s][top]["total"] for s in ds.STRATEGIES)
    recs.append(emit(
        f"fig5/worst_vs_winner_at_{top}", worst,
        f"{worst / red[top]['total']:.0f}x slower than PIM-meta/PIM-exec"))
    return recs


def run():
    bench()
