"""fig_elastic: heap-pressure tenant migration under a hot-rank storm.

One skewed-Zipf arrival stream (zipf_a = 2.2: one dominant tenant) homed
with ``chunked`` placement, which concentrates the hot tenants on rank 0 —
the non-stationary worst case the elastic tier exists for. The same
session is served twice:

  * **migration_off** — plain segmented serving; the hot core saturates,
    its admission queue drops arrivals, and queue wait dominates p99.
  * **migration_on** — `ElasticFleetServe` with the ``hottest_tenant``
    policy at ``interval`` drain points: when per-rank HWMs diverge past
    the ratio, the biggest tenants on the hot rank are drained (FREE on
    the source core) and replayed (MALLOC on the destination) onto the
    least-loaded rank, and their traffic follows.

Rows are modeled (deterministic functions of the cost model) so the perf
gate tracks them. The module **raises** — an errored figure, which the
gate hard-fails — if migration stops improving the storm: the ON arm must
beat OFF on e2e p99 AND drop no more arrivals. Conservation and the
never-droppable expiry lane are asserted on both arms.

Sessions are smoke-sized (the storm is the committed row), so ``--smoke``
and full runs measure identical rows — the fig_arena policy.
"""
from __future__ import annotations

import time

from repro.core import system as sysm
from repro.launch.elastic import ElasticFleetServe, MigrationConfig
from repro.launch.serve_fleet import TrafficConfig

from .common import emit

STORM = dict(R=2, C=2, T=8, heap=1 << 20, kind="hwsw", rounds=64,
             rate=14.0, tenants=8, zipf_a=2.2, queue_cap=24,
             max_lifetime=24, seed=9)
MIG = dict(ratio=1.3, min_bytes=1 << 11, policy="hottest_tenant",
           drain="interval", check_rounds=8, max_moves=2)


def _storm(migration):
    cfg = sysm.SystemConfig(kind=STORM["kind"], heap_bytes=STORM["heap"],
                            num_threads=STORM["T"])
    tc = TrafficConfig(seed=STORM["seed"], rounds=STORM["rounds"],
                       arrival_rate=STORM["rate"],
                       num_tenants=STORM["tenants"],
                       zipf_a=STORM["zipf_a"],
                       queue_cap=STORM["queue_cap"],
                       max_lifetime=STORM["max_lifetime"])
    eng = ElasticFleetServe(cfg, STORM["R"], STORM["C"], traffic=tc,
                            placement="chunked", migration=migration)
    _, rep = eng.serve()
    return rep


def bench(smoke: bool = False):
    recs = []
    reps = {}
    for name, migration in (("migration_off", None),
                            ("migration_on", MigrationConfig(**MIG))):
        t0 = time.time()
        rep = _storm(migration)
        assert rep["conservation_residual"] == 0, name
        assert rep["dropped_frees"] == 0, name
        reps[name] = rep
        recs.append(emit(
            f"fig_elastic/storm/{name}", rep["us_per_call"],
            f"p99={rep['e2e_p99_cyc']:.0f}cyc;drops={rep['dropped']};"
            f"disp={rep['dispatched']};migs={len(rep['migrations'])}",
            backend=STORM["kind"],
            e2e_p99_cyc=rep["e2e_p99_cyc"],
            e2e_p50_cyc=rep["e2e_p50_cyc"],
            dropped=rep["dropped"],
            drop_rate=rep["drop_rate"],
            dispatched=rep["dispatched"],
            backlog_end=rep["backlog_end"],
            migrations=len(rep["migrations"]),
            migration_ops_dispatched=rep["migration_ops_dispatched"],
            wall_s=time.time() - t0))

    off, on = reps["migration_off"], reps["migration_on"]
    if not on["migrations"]:
        raise RuntimeError("elastic storm no longer triggers migration — "
                           "the ON arm measured nothing")
    if on["e2e_p99_cyc"] >= off["e2e_p99_cyc"]:
        raise RuntimeError(
            f"migration regression: ON p99 {on['e2e_p99_cyc']:.0f}cyc no "
            f"longer beats OFF {off['e2e_p99_cyc']:.0f}cyc under the storm")
    if on["dropped"] > off["dropped"]:
        raise RuntimeError(
            f"migration regression: ON drops {on['dropped']} arrivals > "
            f"OFF {off['dropped']} under the storm")
    recs.append(emit(
        "fig_elastic/storm/claim_migration_win", 0.0,
        f"p99={off['e2e_p99_cyc'] / on['e2e_p99_cyc']:.2f}x better; "
        f"drops {off['dropped']}->{on['dropped']}; "
        f"dispatched {off['dispatched']}->{on['dispatched']}",
        p99_improvement=off["e2e_p99_cyc"] / on["e2e_p99_cyc"],
        drops_avoided=off["dropped"] - on["dropped"],
        extra_dispatched=on["dispatched"] - off["dispatched"]))
    return recs


def run():
    bench()
