"""Modeled-vs-wall delta table for wall-lane bench rows.

    PYTHONPATH=src python benchmarks/wall_report.py bench_wall.json

Each ``fig14_wall/*`` row carries both its measured ``us_per_call`` and the
``modeled_us_per_call`` derived from the same executed responses; this
prints the side-by-side table (GitHub-flavored markdown, appended to
``$GITHUB_STEP_SUMMARY`` when set) so every CI run shows how far the cost
model and real execution have drifted, plus the committed batched-refill
speedup row. Reporting only — the pass/fail decision lives in
`perf_gate.py`'s wall lane.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def wall_rows(path: str) -> list:
    with open(path) as f:
        doc = json.load(f)
    rows = []
    for cell in doc.get("figs", {}).values():
        for rec in cell.get("records", []):
            if rec.get("lane") == "wall":
                rows.append(rec)
    return rows


def report(path: str) -> str:
    rows = wall_rows(path)
    lines = ["## Modeled vs wall-clock (fig14_wall)", "",
             "| row | modeled us/call | wall us/call | wall/modeled |",
             "|---|---|---|---|"]
    for rec in sorted(rows, key=lambda r: r["name"]):
        name, wall = rec["name"], float(rec.get("us_per_call", 0.0))
        if "speedup_vs_serial" in rec:
            lines.append(
                f"| `{name}` | — | {wall:.2f} | "
                f"**{rec['speedup_vs_serial']:.2f}x vs serial refill** |")
            continue
        modeled = float(rec.get("modeled_us_per_call", 0.0))
        ratio = wall / modeled if modeled > 0 else float("inf")
        lines.append(f"| `{name}` | {modeled:.2f} | {wall:.2f} "
                     f"| {ratio:.1f}x |")
    if not rows:
        lines.append("| (no wall rows in artifact) | | | |")
    env = next((r.get("env_key") for r in rows if r.get("env_key")), None)
    if env:
        lines += ["", f"env_key: `{env}`"]
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="bench JSON with wall rows")
    args = ap.parse_args(argv)
    text = report(args.current)
    print(text)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(text + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
