"""fig_fleet: fleet scaling sweep over the three-tier transform stack.

ShardedHeap (shard_map of the vmapped `heap.step` over a rank mesh) swept
over 1->R ranks x 1->C cores, three request mixes:

  * alloc_free : every thread mallocs 256 B then frees it (Fig 6's loop)
  * mixed      : malloc / realloc-half / free rounds through the
                 FleetRouter (the REALLOC path at fleet scale)
  * contention : strawman's shared mutex vs PIM-malloc-SW at the largest
                 fleet (Fig 7's scenario, per-core metadata never crossing
                 cores — the paper's x66-at-2560-DPUs scaling claim)

Per cell: modeled us/alloc (threads concurrent, rounds serialized), fleet
allocs/sec, metadata bytes/op, wall-clock us per jitted fleet step, and
scaling efficiency vs the 1x1 cell (flat = the paper's claim).
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import heap as heap_api
from repro.core import system as sysm
from repro.launch.fleet import FleetRouter

from .common import emit

SIZES = (32, 256, 128, 4096, 64, 256, 32, 1024, 32, 256, 128, 2048, 64, 32,
         256, 512)


def _sizes(R, C, T, seed=0):
    pattern = np.asarray(SIZES[:T] if T <= len(SIZES)
                         else SIZES * (T // len(SIZES) + 1), np.int32)[:T]
    return jnp.asarray(np.broadcast_to(pattern, (R, C, T)).copy())


def _alloc_free(router, sizes, rounds):
    """Fig-6 loop at fleet scale; returns per-round fleet max latencies."""
    round_max = []
    for _ in range(rounds):
        ra = router.route(heap_api.malloc_request(sizes))
        rf = router.route(heap_api.free_request(ra.ptr))
        round_max.append(float(np.asarray(ra.latency_cyc).max())
                         + float(np.asarray(rf.latency_cyc).max()))
    return round_max


def _mixed(router, sizes, rounds):
    """malloc -> realloc half the fleet -> free: the full protocol."""
    round_max = []
    half = (jnp.arange(sizes.shape[-1]) % 2) == 0
    for r in range(rounds):
        ra = router.route(heap_api.malloc_request(sizes))
        rr = router.route(heap_api.realloc_request(
            ra.ptr, jnp.roll(sizes, r + 1, axis=-1),
            active=jnp.broadcast_to(half, sizes.shape)))
        live = jnp.where(rr.ptr >= 0, rr.ptr, ra.ptr)
        rf = router.route(heap_api.free_request(live))
        round_max.append(float(np.asarray(ra.latency_cyc).max())
                         + float(np.asarray(rr.latency_cyc).max())
                         + float(np.asarray(rf.latency_cyc).max()))
    return round_max


def _cell(kind, R, C, T, rounds, mix="alloc_free"):
    cfg = sysm.SystemConfig(kind=kind, heap_bytes=1 << 20, num_threads=T)
    sh = heap_api.ShardedHeap(cfg, num_ranks=R, num_cores=C)
    sizes = _sizes(R, C, T)
    run = _mixed if mix == "mixed" else _alloc_free
    run(FleetRouter(sh), sizes, 1)             # compile outside the clock
    router = FleetRouter(sh)                   # fresh accounting for the clock
    t0 = time.time()
    round_max = run(router, sizes, rounds)
    wall_us = (time.time() - t0) / router.rounds * 1e6
    st = router.stats
    freq = cfg.dpu.freq_hz
    modeled_s = sum(round_max) / freq
    return {
        "us_per_call": st["us_per_op"],
        "allocs_per_sec": st["ops"] / max(modeled_s, 1e-12),
        "metadata_bytes_per_op": st["dram_bytes_per_op"],
        "wall_us_per_step": wall_us,
        "ops": st["ops"],
    }


def bench(smoke: bool = False):
    recs = []
    if smoke:
        ranks_list, cores_list, T, rounds = (1, 2), (1, 2), 4, 3
    else:
        ranks_list, cores_list, T, rounds = (1, 2, 4), (1, 4, 16), 16, 12

    base = None
    for R in ranks_list:
        for C in cores_list:
            r = _cell("sw", R, C, T, rounds)
            if base is None:
                base = r
            sw_top = r                         # last cell = largest fleet
            # scaling efficiency: fleet throughput vs (R*C) x the 1x1 cell
            eff = r["allocs_per_sec"] / (R * C * base["allocs_per_sec"])
            flat = r["us_per_call"] / base["us_per_call"]
            recs.append(emit(
                f"fig_fleet/sw/ranks={R}/cores={C}", r["us_per_call"],
                f"eff={eff:.2f};lat_ratio={flat:.2f};"
                f"wall_step={r['wall_us_per_step']:.0f}us", backend="sw",
                allocs_per_sec=r["allocs_per_sec"],
                metadata_bytes_per_op=r["metadata_bytes_per_op"],
                scaling_efficiency=eff, latency_ratio_vs_1x1=flat,
                wall_us_per_step=r["wall_us_per_step"]))
    top = recs[-1]
    recs.append(emit(
        "fig_fleet/claim_flat_scaling", top["us_per_call"],
        f"per-core latency ratio at max fleet={top['latency_ratio_vs_1x1']:.2f}"
        " (flat=1.0; paper: x66 sustained across 2560 DPUs)",
        latency_ratio=top["latency_ratio_vs_1x1"]))

    # mixed-op fleet round (REALLOC path under shard_map)
    R, C = ranks_list[-1], cores_list[-1]
    r = _cell("sw", R, C, T, rounds, mix="mixed")
    recs.append(emit(
        f"fig_fleet/sw_mixed/ranks={R}/cores={C}", r["us_per_call"],
        f"allocs_per_sec={r['allocs_per_sec']:.0f}", backend="sw",
        allocs_per_sec=r["allocs_per_sec"],
        metadata_bytes_per_op=r["metadata_bytes_per_op"]))

    # Fig-7 contention at the largest fleet: shared-mutex strawman vs sw on
    # the SAME alloc_free mix (sw_top is the sweep's largest cell)
    straw = _cell("strawman", R, C, T, rounds)
    slow = straw["us_per_call"] / sw_top["us_per_call"]
    recs.append(emit(
        f"fig_fleet/contention/ranks={R}/cores={C}", straw["us_per_call"],
        f"strawman_vs_sw={slow:.1f}x (shared mutex vs per-thread caches)",
        backend="strawman", slowdown_vs_sw=slow))

    # fused-kernel backend at fleet scale: the same router/mesh path with
    # heap.step served by one pallas_call per core (vmap -> kernel grid)
    Rk, Ck = (ranks_list[0], cores_list[-1])
    rk = _cell("pallas", Rk, Ck, T, max(rounds // 3, 2))
    recs.append(emit(
        f"fig_fleet/pallas/ranks={Rk}/cores={Ck}", rk["us_per_call"],
        f"allocs_per_sec={rk['allocs_per_sec']:.0f};"
        f"wall_step={rk['wall_us_per_step']:.0f}us", backend="pallas",
        allocs_per_sec=rk["allocs_per_sec"],
        metadata_bytes_per_op=rk["metadata_bytes_per_op"],
        wall_us_per_step=rk["wall_us_per_step"]))
    return recs


def run():
    bench()
