"""Fig 6: straw-man buddy latency vs (heap size x alloc size) — single thread
consecutive (de)allocations; normalized to 32KB/2KB."""
from .common import emit, micro_alloc


def run():
    base = None
    for heap_log in (15, 20, 25):             # 32 KB, 1 MB, 32 MB
        for size in (2048, 256, 32):
            r = micro_alloc("strawman", size, nthreads=1, rounds=64,
                            heap=1 << heap_log, alloc_free=True)
            if base is None:
                base = r["mean_us"]
            emit(f"fig6/heap={1 << heap_log}/alloc={size}", r["mean_us"],
                 f"slowdown_vs_32KB_2KB={r['mean_us'] / base:.2f}x")
    r_big = micro_alloc("strawman", 32, 1, rounds=64, heap=1 << 25,
                        alloc_free=True)
    emit("fig6/claim_12x_slowdown", r_big["mean_us"],
         f"measured={r_big['mean_us'] / base:.1f}x (paper: up to 12x)")
