"""Fig 6: straw-man buddy latency vs (heap size x alloc size) — single thread
consecutive (de)allocations; normalized to 32KB/2KB."""
from .common import emit, micro_alloc


def bench(smoke: bool = False):
    recs = []
    rounds = 8 if smoke else 64
    heap_logs = (15, 20) if smoke else (15, 20, 25)
    base = None
    for heap_log in heap_logs:                # 32 KB, 1 MB, 32 MB
        for size in (2048, 256, 32):
            r = micro_alloc("strawman", size, nthreads=1, rounds=rounds,
                            heap=1 << heap_log, alloc_free=True)
            if base is None:
                base = r["mean_us"]
            recs.append(emit(
                f"fig6/heap={1 << heap_log}/alloc={size}", r["mean_us"],
                f"slowdown_vs_32KB_2KB={r['mean_us'] / base:.2f}x",
                allocs_per_sec=r["allocs_per_sec"],
                metadata_bytes_per_op=r["metadata_bytes_per_op"]))
    r_big = micro_alloc("strawman", 32, 1, rounds=rounds,
                        heap=1 << heap_logs[-1], alloc_free=True)
    recs.append(emit(
        "fig6/claim_12x_slowdown", r_big["mean_us"],
        f"measured={r_big['mean_us'] / base:.1f}x (paper: up to 12x)",
        slowdown=r_big["mean_us"] / base))
    return recs


def run():
    bench()
