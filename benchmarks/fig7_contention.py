"""Fig 7: thread contention on the straw-man allocator — 1 vs 16 threads,
latency fluctuation + busy-wait share of the mutex queue."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import system as sysm

from .common import emit, micro_alloc


def bench(smoke: bool = False):
    recs = []
    rounds = 8 if smoke else 96
    r1 = micro_alloc("strawman", 256, nthreads=1, rounds=rounds)
    r16 = micro_alloc("strawman", 256, nthreads=16, rounds=rounds)
    recs.append(emit(
        "fig7/1thread_mean", r1["mean_us"],
        f"fluctuation=p95/mean={r1['p95_us'] / r1['mean_us']:.2f}",
        allocs_per_sec=r1["allocs_per_sec"]))
    recs.append(emit(
        "fig7/16threads_mean", r16["mean_us"],
        f"fluctuation=p95/mean={r16['p95_us'] / r16['mean_us']:.2f}",
        allocs_per_sec=r16["allocs_per_sec"]))

    # busy-wait share: recompute one round and separate queue wait from service
    cfg = sysm.SystemConfig(kind="strawman", heap_bytes=1 << 25)
    st = sysm.system_init(cfg)
    st, ptrs, info = jax.jit(lambda s, z: sysm.malloc_round(cfg, s, z))(
        st, jnp.full((16,), 256, jnp.int32))
    total = float(np.asarray(info.latency_cyc).sum())
    service = float(np.asarray(info.backend_cyc).sum())
    wait = total - service
    recs.append(emit(
        "fig7/busywait_share_16t", total / 16 / 350e6 * 1e6,
        f"lock_wait={wait / total:.0%};alloc={service / total:.0%} "
        "(paper Fig 7b: wait dominates)", busywait_share=wait / total))
    return recs


def run():
    bench()
