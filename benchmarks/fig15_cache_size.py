"""Fig 15: buddy-cache size sensitivity — speedup over PIM-malloc-SW and hit
rate vs cache capacity (16 B ... 256 B); 16 threads, 4 KB requests."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import buddy_cache, system as sysm

from .common import emit, micro_alloc


def _cache_cell(kind, cache_bytes, rounds):
    """One (kind, cache size) cell; the hwsw sim path and the fused-kernel
    path share this loop, so the sweep exercises both designs."""
    cfg = sysm.SystemConfig(
        kind=kind, heap_bytes=1 << 25,
        bc=buddy_cache.BuddyCacheConfig(n_entries=cache_bytes // 4))
    st = sysm.system_init(cfg)
    sz = jnp.tile(jnp.full((16,), 4096, jnp.int32)[None], (rounds, 1))
    run_fn = jax.jit(lambda s, z: sysm.run_alloc_rounds(cfg, s, z))
    st, ptrs, infos = run_fn(st, sz)
    us = float(np.asarray(infos.latency_cyc).mean() / 350e6 * 1e6)
    hits = int(np.asarray(infos.meta_hits).sum())
    misses = int(np.asarray(infos.meta_misses).sum())
    dram = int(np.asarray(infos.dram_bytes).sum())
    return us, hits / max(hits + misses, 1), dram / (rounds * 16)


def bench(smoke: bool = False):
    recs = []
    rounds = 8 if smoke else 96
    cache_sizes = (16, 64) if smoke else (16, 32, 64, 128, 256)
    sw = micro_alloc("sw", 4096, nthreads=16, rounds=rounds)
    recs.append(emit("fig15/sw_baseline", sw["mean_us"], "", backend="sw",
                     allocs_per_sec=sw["allocs_per_sec"]))
    for cache_bytes in cache_sizes:
        us, hr, meta = _cache_cell("hwsw", cache_bytes, rounds)
        recs.append(emit(
            f"fig15/cache={cache_bytes}B", us,
            f"speedup_vs_sw={sw['mean_us'] / us:.2f}x;hit_rate={hr:.2f}",
            backend="hwsw", hit_rate=hr, speedup_vs_sw=sw["mean_us"] / us,
            metadata_bytes_per_op=meta))
        # same sweep (same rounds) on the kernel path: the in-kernel LRU is
        # bitwise-conformant in interpret mode (exactly equal cells); on a
        # TPU the compiled kernel may differ by float ulps, so guard with
        # the same tolerance band fig14's parity row uses
        us_k, hr_k, meta_k = _cache_cell("pallas", cache_bytes, rounds)
        close = all(abs(a - b) <= 1e-3 * max(abs(b), 1e-9)
                    for a, b in ((us_k, us), (hr_k, hr), (meta_k, meta)))
        if not close:
            raise AssertionError(
                f"pallas/hwsw fig15 cell diverged at {cache_bytes}B: "
                f"{(us_k, hr_k, meta_k)} != {(us, hr, meta)}")
        recs.append(emit(
            f"fig15/pallas/cache={cache_bytes}B", us_k,
            f"hit_rate={hr_k:.2f} (in-kernel LRU == hwsw sim)",
            backend="pallas", hit_rate=hr_k, metadata_bytes_per_op=meta_k))
    recs.append(emit(
        "fig15/claim", 0.0,
        "paper: speedup and hit rate saturate at 64B (=256 nodes at 2b)"))
    return recs


def run():
    bench()
