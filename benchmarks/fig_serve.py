"""fleet_serve: the closed-loop multi-tenant serving benchmark.

Runs `repro.launch.serve_fleet.FleetServe` sessions — Poisson arrivals,
Zipf tenants, bounded admission queue — over the [R, C, T] fleet and emits
one row per placement policy plus an overload (backpressure) cell:

  * ``us_per_call``    — modeled us per dispatched op (gated by perf_gate)
  * ``p50/p95/p99``    — end-to-end latency percentiles in modeled DPU
                         cycles (queue wait through round barriers + own
                         service latency), plus service-only percentiles
  * ``queue_depth_*``  — backlog time-series summary
  * ``drop_rate``      — share of external arrivals rejected at the full
                         admission queue (nonzero only under overload)

All modeled metrics are deterministic functions of (seed, traffic config,
cost model), so every row is stable across runner machines and trackable
by the perf gate; only ``wall_s`` is wall-clock (never gated).
"""
import time

from repro.core import system as sysm
from repro.launch.serve_fleet import TrafficConfig, serve_session

from .common import emit

POLICIES = ("round_robin", "least_loaded", "chunked")


def _row(name, rep, wall, **extra):
    return emit(
        name, rep["us_per_op"],
        f"p99={rep['e2e_p99_cyc']:.0f}cyc;drop={rep['drop_rate']:.2f};"
        f"q={rep['queue_depth_mean']:.1f}", backend="sw",
        p50_cyc=rep["e2e_p50_cyc"], p95_cyc=rep["e2e_p95_cyc"],
        p99_cyc=rep["e2e_p99_cyc"], service_p50_cyc=rep["service_p50_cyc"],
        service_p99_cyc=rep["service_p99_cyc"],
        queue_depth_mean=rep["queue_depth_mean"],
        queue_depth_max=rep["queue_depth_max"], drop_rate=rep["drop_rate"],
        offered=rep["offered"], dropped=rep["dropped"],
        dispatched=rep["dispatched"], failed_allocs=rep["failed_allocs"],
        ops_per_sec=rep["ops_per_sec"], wall_s=wall, **extra)


def bench(smoke: bool = False):
    if smoke:
        R, C, T, rounds, rate = 2, 2, 4, 32, 10.0
    else:
        R, C, T, rounds, rate = 2, 4, 16, 96, 64.0
    cfg = sysm.SystemConfig(kind="sw", heap_bytes=1 << 19, num_threads=T)
    recs = []

    # steady-state sessions, one per placement policy (same traffic tape)
    for pol in POLICIES:
        tc = TrafficConfig(seed=17, rounds=rounds, arrival_rate=rate,
                           num_tenants=4 * R * C, queue_cap=8 * R * C)
        t0 = time.time()
        rep = serve_session(cfg, R, C, traffic=tc, placement=pol)
        recs.append(_row(f"fleet_serve/sw/placement={pol}", rep,
                         time.time() - t0))

    # overload cell: arrivals at ~3x capacity against a tight queue — the
    # backpressure path (nonzero drop_rate) stays on the perf trajectory
    tc = TrafficConfig(seed=23, rounds=rounds, arrival_rate=3.0 * R * C * T,
                       num_tenants=2 * R * C, queue_cap=2 * R * C)
    t0 = time.time()
    rep = serve_session(cfg, R, C, traffic=tc, placement="least_loaded")
    recs.append(_row("fleet_serve/sw/overload", rep, time.time() - t0))
    assert rep["drop_rate"] > 0, "overload cell no longer overloads"
    return recs


def run():
    bench()
