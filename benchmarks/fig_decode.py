"""fig_decode: closed-loop paged-KV LLM decode on the fleet mesh.

Runs `repro.launch.serve_decode.DecodeServe` sessions — Poisson session
arrivals, Zipf tenant popularity, prefill bursts, per-token page appends,
eviction — on the shard_mapped rank mesh (``mesh=None``), for the hwsw
reference backend and the fused pallas kernel. One row per backend:

  * ``us_per_call``     — modeled us per dispatched allocator op (the
                          perf-gated trajectory number)
  * ``tokens_per_sec``  — decode tokens over modeled wall time, the
                          serving-side throughput the gate tracks
  * ``alloc_p99_cyc``   — p99 allocator service latency under the decode
                          mix (frontend pages + bypass prefill bursts)
  * ``ttft_p50_cyc``    — arrival -> first token through round barriers

All metrics are modeled (deterministic in seed + cost model), so rows are
machine-stable; ``wall_s`` is the only wall-clock field (never gated).
Per-core conservation is asserted after every scan — a decode session that
leaks pages fails the bench before it ever reaches the gate.
"""
import time

from repro.core import system as sysm
from repro.launch.serve_decode import DecodeTraffic, serve_decode_session

from .common import emit

KINDS = ("hwsw", "pallas")


def bench(smoke: bool = False):
    if smoke:
        R, C, T, rounds, rate = 2, 2, 4, 32, 1.5
    else:
        R, C, T, rounds, rate = 2, 4, 16, 96, 6.0
    tc = DecodeTraffic(seed=29, rounds=rounds, session_rate=rate,
                       num_tenants=4 * R * C, max_context=576,
                       queue_cap=4 * R * C)
    recs = []
    for kind in KINDS:
        cfg = sysm.SystemConfig(kind=kind, heap_bytes=1 << 20,
                                num_threads=T)
        t0 = time.time()
        rep = serve_decode_session(cfg, R, C, traffic=tc, mesh=None)
        wall = time.time() - t0
        assert rep["conservation_residual"] == 0, \
            f"{kind}: per-core conservation broken after decode scan"
        recs.append(emit(
            f"fig_decode/{kind}/mesh", rep["us_per_op"],
            f"tok/s={rep['tokens_per_sec']:.0f};"
            f"p99={rep['alloc_p99_cyc']:.0f}cyc;"
            f"ttft={rep['ttft_p50_cyc']:.0f}cyc", backend=kind,
            tokens_per_sec=rep["tokens_per_sec"],
            alloc_p50_cyc=rep["alloc_p50_cyc"],
            alloc_p99_cyc=rep["alloc_p99_cyc"],
            ttft_p50_cyc=rep["ttft_p50_cyc"],
            ttft_p99_cyc=rep["ttft_p99_cyc"],
            decode_tokens=rep["decode_tokens"],
            prefill_tokens=rep["prefill_tokens"],
            sessions_prefilled=rep["sessions_prefilled"],
            sessions_dropped=rep["sessions_dropped"],
            decode_stalls=rep["decode_stalls"],
            hwm_bytes_max=rep["hwm_bytes_max"],
            external_frag_mean=rep["external_frag_mean"],
            failed_allocs=rep["failed_allocs"],
            dropped_frees=rep["dropped_frees"],
            ops_per_sec=rep["ops_per_sec"], wall_s=wall))
    return recs


def run():
    bench()
